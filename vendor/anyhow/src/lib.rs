//! Offline shim for the [`anyhow`](https://docs.rs/anyhow) crate.
//!
//! This image has no network access and no vendored registry, so the real
//! crate cannot be fetched. lpgd only uses a small surface — `Result`,
//! `Error`, `anyhow!` / `bail!` / `ensure!`, and the `Context` extension
//! trait — which this ~150-line shim reimplements with the same semantics:
//!
//! * `Error` wraps a message chain (outermost context first, root cause
//!   last) and converts from any `std::error::Error`;
//! * `{e}` displays the outermost message, `{e:#}` the full chain joined
//!   with `": "` (matching anyhow's alternate formatting);
//! * like the real crate, `Error` deliberately does **not** implement
//!   `std::error::Error`, which is what makes the blanket
//!   `From<E: std::error::Error>` impl coherent.

use std::error::Error as StdError;
use std::fmt;

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error carrying a human-readable cause chain.
pub struct Error {
    /// Message chain: `chain[0]` is the outermost context, the last entry
    /// is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap the error in one more layer of context (outermost).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

#[doc(hidden)]
pub trait ToError {
    /// Convert into an [`Error`] (identity for `Error` itself).
    fn to_anyhow(self) -> Error;
}

impl ToError for Error {
    fn to_anyhow(self) -> Error {
        self
    }
}

impl<E: StdError + Send + Sync + 'static> ToError for E {
    fn to_anyhow(self) -> Error {
        Error::from(self)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`.
pub trait Context<T> {
    /// Attach a context message to the error, if any.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Attach a lazily-built context message to the error, if any.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ToError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.to_anyhow().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.to_anyhow().context(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_and_display() {
        let e: Error = io_err().into();
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero");
        assert_eq!(f(-2).unwrap_err().to_string(), "negative: -2");
        let named = anyhow!("v={}", 7);
        assert_eq!(named.to_string(), "v=7");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }
}
