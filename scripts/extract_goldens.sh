#!/usr/bin/env bash
# Regenerate every checked-in golden artifact from the current tree.
#
#   ./scripts/extract_goldens.sh
#
# Builds the release binary, runs `lpgd goldens extract` (figure CSVs,
# band sidecars, native-provenance expected-round bit table, manifest),
# then re-stamps the bit table from the independent Python generator so
# the committed table carries cross-language provenance — the golden
# check then verifies Rust-vs-Python agreement (<= 1 ulp) on every run
# instead of Rust against itself. Commit the resulting goldens/ diff
# from the CI reference platform (figure goldens pin libm; see
# goldens/README.md).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== lpgd goldens extract =="
./target/release/lpgd goldens extract --dir goldens

echo "== cross-language expected-round table =="
python3 scripts/gen_expected_round_goldens.py goldens

echo "== goldens/ status =="
git status --short goldens/ || true
echo "review and commit the goldens/ diff (reference platform only)"
