#!/usr/bin/env bash
# Build the static HTML validation report (report/index.html).
#
#   ./scripts/report.sh            # tolerant: report reflects pass/fail
#   LPGD_GOLDEN_REQUIRE=1 ./scripts/report.sh   # also exit non-zero on
#                                               # missing/drifted goldens
#
# Pipeline: run the golden check with a machine-readable validation
# index (`lpgd goldens check --report report/validation.json`), then
# render the index plus every goldens/ figure CSV into a single static
# HTML page with inline SVG charts (scripts/render_report.py, stdlib
# only). CI uploads report/ as the `golden-report` artifact.
set -euo pipefail
cd "$(dirname "$0")/.."

mkdir -p report

echo "== cargo build --release =="
cargo build --release

echo "== lpgd goldens check --report report/validation.json =="
check_args=(goldens check --dir goldens --report report/validation.json)
if [ "${LPGD_GOLDEN_REQUIRE:-0}" = "1" ]; then
    check_args+=(--require)
fi
status=0
./target/release/lpgd "${check_args[@]}" || status=$?

echo "== rendering report/index.html =="
python3 scripts/render_report.py goldens report/validation.json report/index.html

echo "report written to report/index.html (golden check exit: $status)"
exit "$status"
