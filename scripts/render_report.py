#!/usr/bin/env python3
"""Render the golden validation index + figure CSVs as one static HTML page.

Consumes ``report/validation.json`` (written by ``lpgd goldens check
--report``) and every ``goldens/<id>.csv`` figure artifact, and emits a
single self-contained HTML file with inline SVG line charts — no
JavaScript, no external assets, suitable for a CI artifact upload.

Stdlib only. Usage:
    python3 scripts/render_report.py <goldens-dir> <validation.json> <out.html>
"""

import csv
import html
import json
import math
import os
import sys

# Chart geometry (pixels).
W, H = 640, 320
ML, MR, MT, MB = 56, 16, 16, 36
PALETTE = [
    "#1f77b4", "#d62728", "#2ca02c", "#ff7f0e", "#9467bd",
    "#8c564b", "#e377c2", "#7f7f7f", "#bcbd22", "#17becf",
]


def parse_float(cell):
    """A figure cell as float, or None for the NaN marker / non-numerics."""
    cell = cell.strip()
    if cell in ("", "-"):
        return None
    try:
        v = float(cell)
    except ValueError:
        return None
    return v if math.isfinite(v) else None


def load_csv(path):
    with open(path, newline="") as f:
        rows = list(csv.reader(f))
    if not rows:
        return [], []
    return rows[0], rows[1:]


def axis_ticks(lo, hi, n=5):
    """n evenly spaced tick values across [lo, hi]."""
    if hi <= lo:
        return [lo]
    return [lo + (hi - lo) * i / (n - 1) for i in range(n)]


def fmt_tick(v, log):
    if log:
        v = 10.0 ** v
    if v == 0:
        return "0"
    if abs(v) >= 1e4 or abs(v) < 1e-3:
        return f"{v:.1e}"
    return f"{v:g}"


def svg_chart(header, rows, title):
    """An inline SVG line chart: first numeric-looking column as x (row
    index otherwise), every other numeric column a polyline. Returns None
    when nothing is chartable (e.g. all-text tables)."""
    if not rows or len(header) < 2:
        return None
    cols = list(zip(*rows))  # column-major cell strings
    x_vals = [parse_float(c) for c in cols[0]]
    use_index = any(v is None for v in x_vals)
    xs = list(range(len(rows))) if use_index else x_vals
    series = []
    for ci in range(1, len(header)):
        ys = [parse_float(c) for c in cols[ci]]
        pts = [(x, y) for x, y in zip(xs, ys) if y is not None]
        if len(pts) >= 2:
            series.append((header[ci], pts))
    if not series:
        return None

    all_y = [y for _, pts in series for _, y in pts]
    # Log y-axis when the data is positive and spans several decades
    # (typical for the loss/error curves in this repo).
    log_y = min(all_y) > 0.0 and max(all_y) / min(all_y) > 1e3
    if log_y:
        series = [(n, [(x, math.log10(y)) for x, y in pts]) for n, pts in series]
        all_y = [y for _, pts in series for _, y in pts]
    all_x = [x for _, pts in series for x, _ in pts]
    x0, x1 = min(all_x), max(all_x)
    y0, y1 = min(all_y), max(all_y)
    if x1 == x0:
        x1 = x0 + 1.0
    if y1 == y0:
        y0, y1 = y0 - 0.5, y1 + 0.5
    pad = 0.04 * (y1 - y0)
    y0, y1 = y0 - pad, y1 + pad

    def px(x):
        return ML + (x - x0) / (x1 - x0) * (W - ML - MR)

    def py(y):
        return H - MB - (y - y0) / (y1 - y0) * (H - MT - MB)

    parts = [
        f'<svg viewBox="0 0 {W} {H}" width="{W}" height="{H}" role="img" '
        f'aria-label="{html.escape(title, quote=True)}">',
        f'<rect x="{ML}" y="{MT}" width="{W - ML - MR}" height="{H - MT - MB}" '
        'fill="none" stroke="#ccc"/>',
    ]
    for t in axis_ticks(y0, y1):
        y = py(t)
        parts.append(f'<line x1="{ML}" y1="{y:.1f}" x2="{W - MR}" y2="{y:.1f}" stroke="#eee"/>')
        parts.append(
            f'<text x="{ML - 6}" y="{y + 4:.1f}" text-anchor="end" font-size="11" '
            f'fill="#555">{fmt_tick(t, log_y)}</text>'
        )
    for t in axis_ticks(x0, x1):
        x = px(t)
        parts.append(
            f'<text x="{x:.1f}" y="{H - MB + 16}" text-anchor="middle" font-size="11" '
            f'fill="#555">{fmt_tick(t, False)}</text>'
        )
    x_label = "row" if use_index else html.escape(header[0])
    parts.append(
        f'<text x="{(ML + W - MR) / 2:.0f}" y="{H - 6}" text-anchor="middle" '
        f'font-size="12" fill="#333">{x_label}'
        f'{" (log y)" if log_y else ""}</text>'
    )
    for si, (name, pts) in enumerate(series):
        color = PALETTE[si % len(PALETTE)]
        d = " ".join(f"{px(x):.1f},{py(y):.1f}" for x, y in pts)
        parts.append(f'<polyline points="{d}" fill="none" stroke="{color}" stroke-width="1.5"/>')
        ly = MT + 14 + 14 * si
        parts.append(f'<line x1="{ML + 8}" y1="{ly - 4}" x2="{ML + 26}" y2="{ly - 4}" stroke="{color}" stroke-width="2"/>')
        parts.append(
            f'<text x="{ML + 30}" y="{ly}" font-size="11" fill="#333">{html.escape(name)}</text>'
        )
    parts.append("</svg>")
    return "\n".join(parts)


STATUS_STYLE = {
    "pass": ("PASS", "#2e7d32"),
    "bootstrapped": ("BOOTSTRAPPED", "#e65100"),
    "fail": ("FAIL", "#c62828"),
}


def main():
    if len(sys.argv) != 4:
        print(__doc__, file=sys.stderr)
        return 2
    goldens_dir, validation_path, out_path = sys.argv[1:4]

    validation = {"entries": [], "passed": False}
    if os.path.exists(validation_path):
        with open(validation_path) as f:
            validation = json.load(f)
    entries = validation.get("entries", [])
    passed = validation.get("passed", False)

    body = []
    verdict, vcolor = ("OK", "#2e7d32") if passed else ("FAIL", "#c62828")
    body.append(f'<h1>Golden replication report — <span style="color:{vcolor}">{verdict}</span></h1>')
    counts = {}
    for e in entries:
        counts[e.get("status", "?")] = counts.get(e.get("status", "?"), 0) + 1
    body.append(
        "<p>"
        + ", ".join(f"{n} {s}" for s, n in sorted(counts.items()))
        + f" — {len(entries)} checks total.</p>"
    )

    body.append("<h2>Validation index</h2>")
    body.append('<table><tr><th>check</th><th>status</th><th>mode</th><th>cells</th><th>detail</th></tr>')
    for e in entries:
        label, color = STATUS_STYLE.get(e.get("status", ""), (e.get("status", "?"), "#333"))
        body.append(
            "<tr>"
            f'<td><a href="#{html.escape(e.get("id", ""), quote=True)}">{html.escape(e.get("id", "?"))}</a></td>'
            f'<td style="color:{color};font-weight:bold">{label}</td>'
            f'<td>{html.escape(e.get("mode", ""))}</td>'
            f'<td>{e.get("cells", 0)}</td>'
            f'<td>{html.escape(e.get("detail", ""))}</td>'
            "</tr>"
        )
    body.append("</table>")

    body.append("<h2>Figures</h2>")
    charted = 0
    names = sorted(
        n for n in os.listdir(goldens_dir)
        if n.endswith(".csv") and not n.endswith(".band.csv")
    ) if os.path.isdir(goldens_dir) else []
    for name in names:
        stem = name[:-4]
        body.append(f'<h3 id="{html.escape(stem, quote=True)}">{html.escape(stem)}</h3>')
        if stem.startswith("expected_round_"):
            header, rows = load_csv(os.path.join(goldens_dir, name))
            body.append(
                f"<p>Bit-level expectation table: {len(rows)} rows × {len(header)} "
                "columns of hex f64 bit patterns (see goldens/README.md for "
                "decoding) — compared exactly, not charted.</p>"
            )
            continue
        header, rows = load_csv(os.path.join(goldens_dir, name))
        svg = svg_chart(header, rows, stem)
        if svg is None:
            body.append(f"<p>No numeric series to chart ({len(rows)} rows).</p>")
        else:
            body.append(svg)
            charted += 1
        band_path = os.path.join(goldens_dir, f"{stem}.band.csv")
        if os.path.exists(band_path):
            bh, _ = load_csv(band_path)
            banded = ", ".join(html.escape(c) for c in bh[1:])
            body.append(f"<p class=note>Stochastic columns (CLT-banded under stream change): {banded}.</p>")
        else:
            body.append("<p class=note>Fully deterministic table: byte-exact comparison.</p>")
    if not names:
        body.append(
            "<p>No golden CSVs found — run <code>./scripts/extract_goldens.sh</code> "
            "or <code>cargo test -q golden</code> to bootstrap them.</p>"
        )

    doc = f"""<!DOCTYPE html>
<html lang="en"><head><meta charset="utf-8">
<title>Golden replication report</title>
<style>
body {{ font: 14px/1.5 system-ui, sans-serif; margin: 2em auto; max-width: 760px; color: #222; }}
table {{ border-collapse: collapse; width: 100%; }}
th, td {{ border: 1px solid #ddd; padding: 4px 8px; text-align: left; font-size: 13px; }}
th {{ background: #f5f5f5; }}
.note {{ color: #666; font-size: 12px; }}
svg {{ max-width: 100%; height: auto; }}
</style></head><body>
{os.linesep.join(body)}
</body></html>
"""
    with open(out_path, "w") as f:
        f.write(doc)
    print(f"render_report: wrote {out_path} ({len(entries)} checks, {charted} charts)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
