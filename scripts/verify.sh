#!/usr/bin/env bash
# Tier-1 verification plus the documentation gate.
#
#   ./scripts/verify.sh
#
# 1. release build          (tier-1)
# 2. full test suite        (tier-1)
# 3. cargo doc with the crate's #![warn(missing_docs)] escalated to an
#    error, so any undocumented public API — notably the new scheduler
#    surface — fails loudly instead of rotting silently.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo doc --no-deps (missing_docs -> error) =="
RUSTDOCFLAGS="-D missing_docs" cargo doc --no-deps --quiet

echo "verify OK"
