#!/usr/bin/env bash
# Tier-1 verification plus the documentation and lint gates.
#
#   ./scripts/verify.sh
#
# 1. release build          (tier-1)
# 2. full test suite        (tier-1)
# 2b. the fault-injection / crash-resume acceptance tests, run by name so
#    a regression in the robustness layer (docs/robustness.md) is
#    reported as its own failing stage rather than buried in the suite.
# 2c. the golden-figure replication suite in REQUIRE mode: stage 2 already
#    ran it permissively (bootstrapping any missing goldens), so this
#    stage exits non-zero if goldens are still missing or drifted —
#    verify.sh no longer warn-skips an empty goldens/ (docs/testing.md).
# 2d. the experiment-service acceptance tests: the result-registry and
#    serve unit suites plus the process-level test over the built binary
#    and real sockets (docs/service.md).
# 3. cargo doc with the crate's #![warn(missing_docs)] escalated to an
#    error, so any undocumented public API — notably the new scheduler
#    and kernel surfaces — fails loudly instead of rotting silently.
# 4. cargo clippy over every target with warnings denied. Two style lint
#    families with systematic false positives on numeric kernel code
#    (index loops over parallel buffers, many-scalar kernel signatures)
#    are allowed crate-wide at the top of rust/src/lib.rs; everything
#    else — including the correctness lints — is enforced.
# 5. release build of every example (the docs' runnable front doors used
#    to bit-rot silently: `cargo build --release` does not touch them).
# 6. cargo fmt --check (house style in rustfmt.toml) when rustfmt is
#    installed, keeping the local gate equivalent to the CI lint job.
# 7. shellcheck over scripts/*.sh when the tool is installed (the CI
#    `lint` job always runs it; locally we warn-and-skip if absent so the
#    tier-1 gate stays runnable on minimal images).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== fault-injection + crash-resume acceptance tests =="
cargo test -q --test integration fault_tolerance
cargo test -q --lib journal
cargo test -q --lib health

echo "== experiment service + result registry acceptance tests =="
# The serve subsystem's own gate (docs/service.md): registry durability
# and bit-identity at the unit layer, then the process-level suite over
# the built binary and real sockets.
cargo test -q --lib registry
cargo test -q --lib serve
cargo test -q --test serve

echo "== golden-figure replication (LPGD_GOLDEN_REQUIRE=1) =="
LPGD_GOLDEN_REQUIRE=1 cargo test -q --test golden_diff

echo "== cargo doc --no-deps (missing_docs -> error) =="
RUSTDOCFLAGS="-D missing_docs" cargo doc --no-deps --quiet

echo "== cargo clippy --all-targets (-D warnings) =="
cargo clippy --all-targets -- -D warnings

echo "== cargo build --examples --release =="
cargo build --examples --release

echo "== cargo fmt --all -- --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "rustfmt not installed; skipping locally (the CI lint job enforces it)"
fi

echo "== shellcheck scripts/*.sh =="
if command -v shellcheck >/dev/null 2>&1; then
    shellcheck scripts/*.sh
else
    echo "shellcheck not installed; skipping locally (the CI lint job enforces it)"
fi

echo "verify OK"
