#!/usr/bin/env bash
# Run the bench suite and refresh the machine-readable perf artifacts
# (BENCH_<name>.json in the repo root — the cross-PR perf trajectory).
#
#   ./scripts/bench.sh            # all benches with JSON emitters
#   ./scripts/bench.sh gd_step    # just one
#   BENCH_SMOKE=1 ./scripts/bench.sh   # ~10x reduced iterations (the CI
#                                      # bench-smoke job; noisier numbers)
#
# The figures/runtime benches are excluded: `figures` regenerates paper
# CSVs (minutes), `runtime_pjrt` needs the non-default pjrt feature.
set -euo pipefail
cd "$(dirname "$0")/.."

benches=("$@")
if [ ${#benches[@]} -eq 0 ]; then
    benches=(rounding gd_step opt_step sweep serve)
fi

# Staleness guard: checked-in artifacts carrying the literal SEED ESTIMATE
# provenance marker are hand-projected seed estimates, not measurements
# (the benches print the same warning via warn_if_hand_projected in
# benches/harness.rs). Measured artifacts carry an honest "measured on
# this machine" provenance line instead and pass silently.
check_provenance() {
    local stage="$1" stale=0 f
    for f in BENCH_*.json; do
        [ -e "$f" ] || continue
        if grep -q 'SEED ESTIMATE' "$f"; then
            echo "WARNING ($stage): $f carries the hand-projected 'SEED ESTIMATE' marker — not measured numbers." >&2
            stale=1
        fi
    done
    return "$stale"
}

check_provenance "before run" || true

for b in "${benches[@]}"; do
    echo "== cargo bench --bench $b =="
    cargo bench --bench "$b"
done

echo "== refreshed artifacts =="
ls -l BENCH_*.json
if ! check_provenance "after run"; then
    echo "WARNING: some artifacts above were NOT refreshed by this run (stale seed estimates remain)." >&2
fi

# Append the freshly measured artifacts to the cross-PR perf trajectory
# (BENCH_history.jsonl) with machine provenance. The appender refuses any
# artifact still carrying the SEED ESTIMATE marker, so a partially stale
# run records only its measured entries.
echo "== appending to BENCH_history.jsonl =="
python3 scripts/bench_history.py
