#!/usr/bin/env bash
# Run the bench suite and refresh the machine-readable perf artifacts
# (BENCH_<name>.json in the repo root — the cross-PR perf trajectory).
#
#   ./scripts/bench.sh            # all benches with JSON emitters
#   ./scripts/bench.sh gd_step    # just one
#
# The figures/runtime benches are excluded: `figures` regenerates paper
# CSVs (minutes), `runtime_pjrt` needs the non-default pjrt feature.
set -euo pipefail
cd "$(dirname "$0")/.."

benches=("$@")
if [ ${#benches[@]} -eq 0 ]; then
    benches=(rounding gd_step sweep)
fi

for b in "${benches[@]}"; do
    echo "== cargo bench --bench $b =="
    cargo bench --bench "$b"
done

echo "== refreshed artifacts =="
ls -l BENCH_*.json
