#!/usr/bin/env python3
"""Generate goldens/expected_round_binary8.csv from first principles.

This is the *independent* (cross-language) generator for the expected-round
golden table: it re-derives the closed-form ``E[fl(x)]`` bias law of every
built-in rounding scheme on the full binary8 grid directly from the paper's
definitions (arXiv:2202.12276, Definitions 1-3), with no Rust code in the
loop. The Rust golden check (``rust/src/coordinator/goldens.rs``) compares
its native closed forms against this table with <= 1 ulp of slack (the
``cross-language`` provenance sidecar); ``lpgd goldens extract`` re-stamps
the table from the Rust side (``native``), after which the comparison is
bit-exact.

Every arithmetic step below mirrors the Rust implementation operation for
operation (same IEEE double ops, same order), so the two tables are
expected to agree bit for bit; the 1-ulp slack is cushion, not a license.

Stdlib only. Usage:  python3 scripts/gen_expected_round_goldens.py [outdir]
"""

import math
import struct
import sys


SIG_BITS = 3          # binary8 (E5M2): significand bits incl. implicit
E_MIN, E_MAX = -14, 15


def bits(x):
    """IEEE-754 bit pattern of a double, as 16 hex digits."""
    return "%016x" % struct.unpack("<Q", struct.pack("<d", x))[0]


def phi(y):
    """Clamp to [0, 1] (the paper's phi; matches Rust f64::clamp here)."""
    return min(max(y, 0.0), 1.0)


def positive_points():
    """Ascending positive binary8 grid: subnormals m*2^-16 (m=1..3), then
    m*2^(e-2) (m=4..7) per binade e in [E_MIN, E_MAX] — the same
    enumeration as the Rust side (goldens::binary8_positive_points)."""
    q = math.ldexp(1.0, E_MIN - SIG_BITS + 1)   # 2^-16
    pts = [m * q for m in range(1, 4)]
    for e in range(E_MIN, E_MAX + 1):
        ulp = math.ldexp(1.0, e - SIG_BITS + 1)
        pts.extend(m * ulp for m in range(4, 8))
    return pts


def samples():
    """0, every grid point, every gap's quarter/half/three-quarter points,
    then the negative mirror of everything (matching the Rust order)."""
    pts = positive_points()
    xs = [0.0]
    prev = 0.0
    for p in pts:
        g = p - prev
        xs.append(prev + 0.25 * g)
        xs.append(prev + 0.5 * g)
        xs.append(prev + 0.75 * g)
        xs.append(p)
        prev = p
    xs.extend(-x for x in xs[1:])
    return xs


def round_nearest_even(x, lo, hi):
    """RN on an interior point: nearer neighbor; ties to the neighbor with
    even significand multiple (parity of |lo|/gap, valid across binades
    and signs — mirrors fp::round::round_nearest_even)."""
    dlo, dhi = x - lo, hi - x
    if dlo < dhi:
        return lo
    if dhi < dlo:
        return hi
    m_lo = abs(lo / (hi - lo))
    return lo if int(m_lo) % 2 == 0 else hi


def expected(mode, x, lo, hi, v):
    """Closed-form E[fl(x)] for interior x in (lo, hi); mirrors
    fp::round::expected_round arm by arm."""
    if mode == "rn":
        return round_nearest_even(x, lo, hi)
    if mode == "rd":
        return lo
    if mode == "ru":
        return hi
    if mode == "rz":
        return lo if x > 0.0 else hi
    frac = (x - lo) / (hi - lo)
    if mode == "sr":
        p_down = 1.0 - frac
    elif mode.startswith("sr_eps:"):
        eps = float(mode.split(":")[1])
        p_down = phi(1.0 - frac - math.copysign(1.0, x) * eps)
    else:  # signed:<eps>
        eps = float(mode.split(":")[1])
        sv = 0.0 if v == 0.0 else math.copysign(1.0, v)
        p_down = phi(1.0 - frac + sv * eps)
    return p_down * lo + (1.0 - p_down) * hi


# (column label, mode spec, steering v: "x" | +1 | -1 | 0) — order must
# match goldens::expected_round_columns on the Rust side.
COLUMNS = [
    ("rn", "rn", "x"),
    ("rd", "rd", "x"),
    ("ru", "ru", "x"),
    ("rz", "rz", "x"),
    ("sr", "sr", "x"),
    ("sr_eps_0.1", "sr_eps:0.1", "x"),
    ("sr_eps_0.25", "sr_eps:0.25", "x"),
    ("sr_eps_0.4", "sr_eps:0.4", "x"),
    ("signed_0.1_vpos", "signed:0.1", 1.0),
    ("signed_0.1_vneg", "signed:0.1", -1.0),
    ("signed_0.25_vpos", "signed:0.25", 1.0),
    ("signed_0.25_vneg", "signed:0.25", -1.0),
    ("signed_0.4_vpos", "signed:0.4", 1.0),
    ("signed_0.4_vneg", "signed:0.4", -1.0),
    ("signed_0.25_v0", "signed:0.25", 0.0),
]


def main():
    outdir = sys.argv[1] if len(sys.argv) > 1 else "goldens"
    pts = positive_points()
    grid = set(pts) | {0.0} | {-p for p in pts}
    # Neighbor lookup for interior samples: sorted grid, bisect by value.
    ordered = sorted(grid)

    def neighbors(x):
        import bisect

        i = bisect.bisect_left(ordered, x)
        return ordered[i - 1], ordered[i]

    rows = []
    for x in samples():
        row = [bits(x)]
        on_grid = x in grid
        if on_grid:
            row.extend(bits(x) for _ in COLUMNS)
        else:
            lo, hi = neighbors(x)
            for _, mode, steer in COLUMNS:
                v = x if steer == "x" else steer
                row.append(bits(expected(mode, x, lo, hi, v)))
        rows.append(row)

    header = ["x_bits"] + [c[0] for c in COLUMNS]
    csv_path = f"{outdir}/expected_round_binary8.csv"
    with open(csv_path, "w") as f:
        f.write(",".join(header) + "\n")
        for row in rows:
            f.write(",".join(row) + "\n")
    with open(f"{outdir}/expected_round_binary8.provenance", "w") as f:
        f.write("cross-language\n")

    # Self-checks on the laws themselves (cheap invariants; a violation
    # means the generator, not the data, is wrong).
    hdr_idx = {name: i + 1 for i, (name, _, _) in enumerate(COLUMNS)}
    for row in rows:
        x = struct.unpack("<d", struct.pack("<Q", int(row[0], 16)))[0]
        sr = struct.unpack("<d", struct.pack("<Q", int(row[hdr_idx["sr"]], 16)))[0]
        assert sr == x, f"SR must be unbiased: x={x!r} sr={sr!r}"
        assert row[hdr_idx["signed_0.25_v0"]] == row[hdr_idx["sr"]], "v=0 degenerates to SR"
        rd = struct.unpack("<d", struct.pack("<Q", int(row[hdr_idx["rd"]], 16)))[0]
        ru = struct.unpack("<d", struct.pack("<Q", int(row[hdr_idx["ru"]], 16)))[0]
        assert rd <= x <= ru, f"RD/RU must bracket x={x!r}"

    print(f"wrote {csv_path}: {len(rows)} rows x {len(header)} columns (cross-language provenance)")


if __name__ == "__main__":
    main()
