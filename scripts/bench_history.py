#!/usr/bin/env python3
"""Maintain and police BENCH_history.jsonl, the cross-PR perf trajectory.

Two modes, stdlib only:

``append`` (the default, for backward compatibility)
    Append the current BENCH_*.json artifacts to BENCH_history.jsonl, one
    JSONL line per artifact per invocation, stamped with machine
    provenance (hostname, platform, CPU count, UTC timestamp, git
    commit), so the perf trajectory is tracked *across* PRs instead of
    each PR overwriting the last measurement. Artifacts still carrying
    the hand-projected ``SEED ESTIMATE`` marker are refused: history
    records measurements only.

``compare``
    Regression gate over the recorded trajectory: for every (artifact,
    machine) pair, take the two newest entries and compare each named
    result's median ns/iter. Exit non-zero if any median regressed more
    than the threshold (default 15%). "Machine" means the provenance
    ``platform`` string (kernel + arch + libc) — CI runner *hostnames*
    are randomized per job, but runners drawn from the same image
    generation share a platform string, so consecutive CI runs compare
    while a runner-image upgrade starts a fresh baseline instead of
    producing a false alarm. Pairs with fewer than two entries are
    skipped (nothing to compare is a pass, not a failure).

Usage:
    python3 scripts/bench_history.py [append] [artifact.json ...]
    python3 scripts/bench_history.py compare [--threshold 0.15]
        [--history BENCH_history.jsonl] [--ignore-machine]
"""

import datetime
import glob
import json
import os
import platform
import socket
import subprocess
import sys


def git_commit():
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, check=True,
        )
        return out.stdout.strip()
    except Exception:
        return "unknown"


def repo_root():
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def append(argv):
    explicit = [os.path.abspath(p) for p in argv]
    os.chdir(repo_root())
    paths = explicit or sorted(glob.glob("BENCH_*.json"))
    if not paths:
        print("bench_history: no BENCH_*.json artifacts found, nothing to append")
        return 0
    provenance = {
        "host": socket.gethostname(),
        "platform": platform.platform(),
        "cpus": os.cpu_count(),
        "utc": datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%dT%H:%M:%SZ"),
        "commit": git_commit(),
    }
    appended = 0
    with open("BENCH_history.jsonl", "a") as hist:
        for path in paths:
            with open(path) as f:
                try:
                    artifact = json.load(f)
                except json.JSONDecodeError as e:
                    print(f"bench_history: skipping unparsable {path}: {e}", file=sys.stderr)
                    continue
            blob = json.dumps(artifact)
            if "SEED ESTIMATE" in blob:
                print(
                    f"bench_history: refusing {path}: carries the hand-projected "
                    "'SEED ESTIMATE' marker (history records measurements only)",
                    file=sys.stderr,
                )
                continue
            hist.write(json.dumps({
                "artifact": os.path.basename(path),
                "provenance": provenance,
                "data": artifact,
            }, sort_keys=True) + "\n")
            appended += 1
    print(f"bench_history: appended {appended} artifact(s) to BENCH_history.jsonl")
    return 0


def compare(argv):
    threshold = 0.15
    history = "BENCH_history.jsonl"
    ignore_machine = False
    it = iter(argv)
    for arg in it:
        if arg == "--threshold":
            threshold = float(next(it, "") or "nan")
            if not threshold >= 0:
                print("bench_history: --threshold needs a non-negative fraction",
                      file=sys.stderr)
                return 2
        elif arg == "--history":
            history = next(it, "")
        elif arg == "--ignore-machine":
            ignore_machine = True
        else:
            print(f"bench_history: unknown compare option '{arg}'", file=sys.stderr)
            return 2
    os.chdir(repo_root())
    if not os.path.exists(history):
        print(f"bench_history: {history} does not exist yet — nothing to compare")
        return 0
    entries = []
    with open(history) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                entries.append(json.loads(line))
            except json.JSONDecodeError as e:
                print(f"bench_history: skipping unparsable history line {ln}: {e}",
                      file=sys.stderr)
    # Group chronologically (file order == append order) per machine key.
    groups = {}
    for e in entries:
        prov = e.get("provenance", {})
        machine = "any" if ignore_machine else prov.get("platform", "unknown")
        groups.setdefault((e.get("artifact", "?"), machine), []).append(e)
    regressions = []
    compared = 0
    for (artifact, machine), seq in sorted(groups.items()):
        if len(seq) < 2:
            print(f"compare: {artifact} on [{machine}]: only {len(seq)} entry(ies), skipping")
            continue
        prev, new = seq[-2], seq[-1]
        prev_medians = {r["name"]: r.get("median_ns", 0.0)
                        for r in prev.get("data", {}).get("results", [])}
        for r in new.get("data", {}).get("results", []):
            name = r["name"]
            if name not in prev_medians or not prev_medians[name] > 0:
                continue
            old_ns, new_ns = prev_medians[name], r.get("median_ns", 0.0)
            compared += 1
            ratio = new_ns / old_ns
            if ratio > 1.0 + threshold:
                regressions.append(
                    f"{artifact} [{machine}] '{name}': median {old_ns:.0f} -> "
                    f"{new_ns:.0f} ns/iter ({(ratio - 1.0) * 100:.1f}% slower, "
                    f"commits {prev['provenance'].get('commit')} -> "
                    f"{new['provenance'].get('commit')})"
                )
    if regressions:
        print(f"compare: {len(regressions)} regression(s) beyond "
              f"{threshold * 100:.0f}% of {compared} compared medians:", file=sys.stderr)
        for r in regressions:
            print(f"  REGRESSION: {r}", file=sys.stderr)
        return 1
    print(f"compare: OK — {compared} median(s) compared, none regressed beyond "
          f"{threshold * 100:.0f}%")
    return 0


def main():
    argv = sys.argv[1:]
    if argv and argv[0] == "compare":
        return compare(argv[1:])
    if argv and argv[0] == "append":
        return append(argv[1:])
    return append(argv)


if __name__ == "__main__":
    sys.exit(main())
