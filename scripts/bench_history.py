#!/usr/bin/env python3
"""Append the current BENCH_*.json artifacts to BENCH_history.jsonl.

One JSONL line per artifact per invocation, stamped with machine
provenance (hostname, platform, CPU count, UTC timestamp, git commit), so
the perf trajectory is tracked *across* PRs instead of each PR
overwriting the last measurement. Artifacts still carrying the
hand-projected ``SEED ESTIMATE`` marker are refused: history records
measurements only.

Stdlib only. Usage:  python3 scripts/bench_history.py [artifact.json ...]
(defaults to every BENCH_*.json in the repo root).
"""

import datetime
import glob
import json
import os
import platform
import socket
import subprocess
import sys


def git_commit():
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, check=True,
        )
        return out.stdout.strip()
    except Exception:
        return "unknown"


def main():
    explicit = [os.path.abspath(p) for p in sys.argv[1:]]
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    os.chdir(root)
    paths = explicit or sorted(glob.glob("BENCH_*.json"))
    if not paths:
        print("bench_history: no BENCH_*.json artifacts found, nothing to append")
        return 0
    provenance = {
        "host": socket.gethostname(),
        "platform": platform.platform(),
        "cpus": os.cpu_count(),
        "utc": datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%dT%H:%M:%SZ"),
        "commit": git_commit(),
    }
    appended = 0
    with open("BENCH_history.jsonl", "a") as hist:
        for path in paths:
            with open(path) as f:
                try:
                    artifact = json.load(f)
                except json.JSONDecodeError as e:
                    print(f"bench_history: skipping unparsable {path}: {e}", file=sys.stderr)
                    continue
            blob = json.dumps(artifact)
            if "SEED ESTIMATE" in blob:
                print(
                    f"bench_history: refusing {path}: carries the hand-projected "
                    "'SEED ESTIMATE' marker (history records measurements only)",
                    file=sys.stderr,
                )
                continue
            hist.write(json.dumps({
                "artifact": os.path.basename(path),
                "provenance": provenance,
                "data": artifact,
            }, sort_keys=True) + "\n")
            appended += 1
    print(f"bench_history: appended {appended} artifact(s) to BENCH_history.jsonl")
    return 0


if __name__ == "__main__":
    sys.exit(main())
