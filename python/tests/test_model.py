"""Layer-2 correctness: loss/gradient checks and rounded-update semantics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref

C, D, N, H = 10, 196, 64, 20
P_MLR = C * (D + 1)
P_NN = H * (D + 2) + 1


def _data(seed=0):
    rng = np.random.default_rng(seed)
    x = rng.random((N, D)).astype(np.float32)
    labels = rng.integers(0, C, N)
    y = np.eye(C, dtype=np.float32)[labels]
    return jnp.array(x), jnp.array(y), labels


def test_mlr_loss_at_zero_is_log_c():
    x, y, _ = _data()
    loss, grad = model.mlr_loss_and_grad(jnp.zeros(P_MLR), x, y, C)
    assert abs(float(loss) - np.log(C)) < 1e-5
    assert grad.shape == (P_MLR,)


def test_mlr_grad_matches_autodiff():
    x, y, _ = _data(1)
    rng = np.random.default_rng(2)
    params = jnp.array(rng.standard_normal(P_MLR).astype(np.float32) * 0.1)
    _, g_manual = model.mlr_loss_and_grad(params, x, y, C)
    g_auto = jax.grad(lambda p: model.mlr_loss_and_grad(p, x, y, C)[0])(params)
    np.testing.assert_allclose(np.asarray(g_manual), np.asarray(g_auto),
                               rtol=1e-4, atol=1e-6)


def test_nn_grad_finite_diff_spotcheck():
    rng = np.random.default_rng(3)
    x = jnp.array(rng.random((N, D)).astype(np.float32))
    y = jnp.array(rng.integers(0, 2, N).astype(np.float32))
    params = jnp.array(rng.standard_normal(P_NN).astype(np.float32) * 0.05)
    loss, grad = model.nn_loss_and_grad(params, x, y, H)
    assert np.isfinite(float(loss))
    f = lambda p: float(model.nn_loss_and_grad(p, x, y, H)[0])
    h = 1e-3
    for i in [0, P_NN // 2, P_NN - 1]:
        e = np.zeros(P_NN, dtype=np.float32)
        e[i] = h
        fd = (f(params + e) - f(params - e)) / (2 * h)
        assert abs(fd - float(grad[i])) < 5e-3, (i, fd, float(grad[i]))


def _uniforms(p, seed):
    return jnp.array(np.random.default_rng(seed).random((3, p)).astype(np.float32))


def test_rounded_update_output_in_format():
    """After (8c) every parameter is exactly representable in binary8."""
    x, y, _ = _data(4)
    rng = np.random.default_rng(5)
    params = jnp.array((rng.standard_normal(P_MLR) * 0.1).astype(np.float32))
    modes = jnp.array([1, 1, 1], dtype=jnp.int32)
    new_p, _ = model.mlr_train_step(
        params, x, y, _uniforms(P_MLR, 6), jnp.float32(0.5), jnp.float32(0.1),
        modes, n_classes=C, fmt=model.FMT_BINARY8)
    s, emin, emax = model.FMT_BINARY8
    lo, hi, _ = ref.floor_ceil(jnp.array(new_p), s, emin, emax)
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(new_p))
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(new_p))


def test_mlr_training_reduces_loss_sr():
    x, y, _ = _data(7)
    params = jnp.zeros(P_MLR, dtype=jnp.float32)
    modes = jnp.array([1, 1, 1], dtype=jnp.int32)
    losses = []
    for k in range(30):
        params, loss = model.mlr_train_step(
            params, x, y, _uniforms(P_MLR, 100 + k), jnp.float32(0.5),
            jnp.float32(0.0), modes, n_classes=C, fmt=model.FMT_BINARY8)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.2, losses[::10]


def test_rn_vs_sr_stagnation_contrast():
    """Under RN at binary8 updates far below half an ulp of the iterate are
    lost entirely; under SR parameters keep moving with probability
    proportional to the update (the Gupta et al. effect, paper section 3.2).
    Starting at 1.0 (ulp = 2^-2), updates of order t*g ~ 2e-4 vanish under
    RN but not under SR."""
    x, y, _ = _data(8)
    params0 = jnp.ones(P_MLR, dtype=jnp.float32)

    def run(mode, steps=25, t=0.01):
        p = params0
        modes = jnp.array([mode] * 3, dtype=jnp.int32)
        for k in range(steps):
            p, _ = model.mlr_train_step(
                p, x, y, _uniforms(P_MLR, 200 + k), jnp.float32(t),
                jnp.float32(0.0), modes, n_classes=C, fmt=model.FMT_BINARY8)
        return np.asarray(p)

    p_rn = run(0)
    p_sr = run(1)
    moved_rn = np.count_nonzero(p_rn != np.asarray(params0))
    moved_sr = np.count_nonzero(p_sr != np.asarray(params0))
    assert moved_rn == 0, moved_rn           # full stagnation under RN
    assert moved_sr >= 10, moved_sr  # SR keeps parameters moving (E~40 here)


def test_nn_train_step_runs_and_loss_finite():
    rng = np.random.default_rng(9)
    x = jnp.array(rng.random((N, D)).astype(np.float32))
    y = jnp.array(rng.integers(0, 2, N).astype(np.float32))
    params = jnp.array((rng.standard_normal(P_NN) * 0.05).astype(np.float32))
    modes = jnp.array([1, 1, 3], dtype=jnp.int32)
    new_p, loss = model.nn_train_step(
        params, x, y, _uniforms(P_NN, 10), jnp.float32(0.1), jnp.float32(0.1),
        modes, hidden=H, fmt=model.FMT_BINARY8)
    assert np.isfinite(float(loss))
    assert new_p.shape == (P_NN,)
    assert not np.array_equal(np.asarray(new_p), np.asarray(params))
