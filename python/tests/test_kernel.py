"""Layer-1 correctness: Pallas kernel vs pure-jnp oracle, plus the paper's
rounding-scheme properties (Definitions 1-3, Lemma 1, Table 2)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.rounding import quantize, quantize_flat, BLOCK_ROWS, LANES

B8 = (3, -14, 15)
BF16 = (8, -126, 127)


def _rand(n, seed=0, scale=3.0):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(n) * scale).astype(np.float32)
    u = rng.random(n).astype(np.float32)
    v = rng.standard_normal(n).astype(np.float32)
    return jnp.array(x), jnp.array(u), jnp.array(v)


@pytest.mark.parametrize("mode", [0, 1, 2, 3])
@pytest.mark.parametrize("fmt", [B8, BF16])
def test_kernel_matches_oracle_bitexact(mode, fmt):
    x, u, v = _rand(4096, seed=mode)
    s, lo, hi = fmt
    r = ref.quantize_ref(x, u, v, jnp.int32(mode), jnp.float32(0.25), s, lo, hi)
    k = quantize_flat(x, u, v, jnp.int32(mode), jnp.float32(0.25),
                      sig_bits=s, e_min=lo, e_max=hi)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(k))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    mode=st.integers(0, 3),
    eps=st.floats(0.0, 0.9),
    scale=st.sampled_from([1e-4, 1e-2, 1.0, 1e2, 1e4]),
    rows=st.sampled_from([8, 16, 32]),
)
def test_kernel_oracle_property_sweep(seed, mode, eps, scale, rows):
    n = rows * LANES
    x, u, v = _rand(n, seed=seed, scale=scale)
    s, lo, hi = B8
    r = ref.quantize_ref(x, u, v, jnp.int32(mode), jnp.float32(eps), s, lo, hi)
    k = quantize_flat(x, u, v, jnp.int32(mode), jnp.float32(eps),
                      sig_bits=s, e_min=lo, e_max=hi)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(k))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), mode=st.integers(0, 3))
def test_output_is_floor_or_ceil(seed, mode):
    """fl(x) in {floor(x), ceil(x)} for every scheme (paper section 2.2)."""
    x, u, v = _rand(1024, seed=seed)
    s, emin, emax = B8
    lo, hi, _ = ref.floor_ceil(x, s, emin, emax)
    out = ref.quantize_ref(x, u, v, jnp.int32(mode), jnp.float32(0.3), s, emin, emax)
    out, lo, hi = map(np.asarray, (out, lo, hi))
    assert np.all((out == lo) | (out == hi))


@pytest.mark.parametrize("mode", [0, 1, 2, 3])
def test_representable_values_are_fixed_points(mode):
    s, emin, emax = B8
    # All binary8 values in a couple of binades, exactly representable.
    vals = []
    for e in [-2, 0, 5, 10]:
        q = 2.0 ** (e - s + 1)
        for m in range(2 ** (s - 1), 2**s):
            vals.extend([m * q, -m * q])
    x = jnp.array(vals, dtype=jnp.float32)
    u = jnp.full_like(x, 0.99)
    out = ref.quantize_ref(x, u, x, jnp.int32(mode), jnp.float32(0.4), s, emin, emax)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_sr_unbiased():
    """Definition 1: E[SR(x)] = x."""
    s, emin, emax = B8
    x = jnp.full((200_000,), 1.1, dtype=jnp.float32)
    u = jax.random.uniform(jax.random.PRNGKey(0), x.shape, dtype=jnp.float32)
    out = ref.quantize_ref(x, u, x, jnp.int32(1), jnp.float32(0.0), s, emin, emax)
    mean = float(jnp.mean(out))
    assert abs(mean - 1.1) < 1e-3, mean


@pytest.mark.parametrize("xval,sign", [(1.1, 1.0), (-1.1, -1.0)])
def test_sreps_bias_away_from_zero(xval, sign):
    """Eq. (3) middle case: bias = sign(x) * eps * gap."""
    s, emin, emax = B8
    eps = 0.25
    x = jnp.full((200_000,), xval, dtype=jnp.float32)
    u = jax.random.uniform(jax.random.PRNGKey(1), x.shape, dtype=jnp.float32)
    out = ref.quantize_ref(x, u, x, jnp.int32(2), jnp.float32(eps), s, emin, emax)
    bias = float(jnp.mean(out)) - xval
    assert bias * sign > 0
    assert abs(bias - sign * eps * 0.25) < 2e-3  # gap = 0.25 in [1,2)


@pytest.mark.parametrize("vsign", [1.0, -1.0])
def test_signed_sreps_bias_opposes_v(vsign):
    """Eq. (4) middle case: bias = sign(-v) * eps * gap."""
    s, emin, emax = B8
    eps = 0.25
    x = jnp.full((200_000,), 1.1, dtype=jnp.float32)
    v = jnp.full_like(x, vsign)
    u = jax.random.uniform(jax.random.PRNGKey(2), x.shape, dtype=jnp.float32)
    out = ref.quantize_ref(x, u, v, jnp.int32(3), jnp.float32(eps), s, emin, emax)
    bias = float(jnp.mean(out)) - 1.1
    assert bias * (-vsign) > 0, bias


def test_lemma1_relative_bias_bound():
    """0 <= E[delta^{SReps}(x)] <= 2*eps*u for nonzero x."""
    s, emin, emax = B8
    eps = 0.3
    uu = 2.0**-s
    rng = np.random.default_rng(3)
    xs = np.concatenate([rng.uniform(0.01, 100, 50), -rng.uniform(0.01, 100, 50)])
    for xval in xs.astype(np.float32):
        x = jnp.full((20_000,), xval, dtype=jnp.float32)
        u = jax.random.uniform(jax.random.PRNGKey(int(abs(xval) * 997)), x.shape)
        out = ref.quantize_ref(x, u, x, jnp.int32(2), jnp.float32(eps), s, emin, emax)
        rel = (float(jnp.mean(out)) - float(xval)) / float(xval)
        assert rel >= -6e-3
        assert rel <= 2 * eps * uu + 6e-3


def test_table2_format_params():
    u, xmin_sub, xmax = ref.format_params(*B8)
    assert u == 0.125
    assert xmax == 57344.0
    u, _, xmax = ref.format_params(*BF16)
    assert u == 2.0**-8
    assert abs(xmax - 3.39e38) / 3.39e38 < 1e-2


def test_saturation_no_inf():
    s, emin, emax = B8
    x = jnp.array([1e6, -1e6, 6e4], dtype=jnp.float32)
    u = jnp.array([0.9, 0.1, 0.5], dtype=jnp.float32)
    out = np.asarray(ref.quantize_ref(x, u, x, jnp.int32(1), jnp.float32(0.0), s, emin, emax))
    assert np.all(np.isfinite(out))
    assert np.all(np.abs(out) <= 57344.0)


def test_zero_maps_to_zero():
    s, emin, emax = B8
    x = jnp.zeros((LANES,), dtype=jnp.float32)
    u = jnp.full_like(x, 0.2)
    for mode in range(4):
        out = ref.quantize_ref(x, u, x, jnp.int32(mode), jnp.float32(0.4), s, emin, emax)
        assert float(jnp.max(jnp.abs(out))) == 0.0


def test_block_shape_invariance():
    """Different BlockSpec tilings must not change results (pure map)."""
    x, u, v = _rand(32 * LANES, seed=9)
    s, emin, emax = B8
    base = quantize(x.reshape(-1, LANES), u.reshape(-1, LANES), v.reshape(-1, LANES),
                    jnp.int32(1), jnp.float32(0.0),
                    sig_bits=s, e_min=emin, e_max=emax, block_rows=8)
    wide = quantize(x.reshape(-1, LANES), u.reshape(-1, LANES), v.reshape(-1, LANES),
                    jnp.int32(1), jnp.float32(0.0),
                    sig_bits=s, e_min=emin, e_max=emax, block_rows=16)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(wide))
