"""AOT pipeline: every artifact lowers, emits parseable HLO text, and the
quantizer artifact's semantics survive the stablehlo->HLO round trip."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import ref


@pytest.mark.parametrize("name", list(aot.ARTIFACTS))
def test_lowering_produces_hlo_text(name):
    text = aot.to_hlo_text(aot.ARTIFACTS[name]())
    assert "ENTRY" in text
    assert "HloModule" in text
    # No Mosaic custom-calls: interpret=True must have lowered pallas away.
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_artifact_files_match_registry():
    """`make artifacts` output exists and is fresh enough to load."""
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.isdir(art_dir):
        pytest.skip("artifacts/ not built yet")
    for name in aot.ARTIFACTS:
        path = os.path.join(art_dir, name)
        assert os.path.exists(path), f"run `make artifacts` ({name} missing)"
        head = open(path).read(200)
        assert "HloModule" in head


def test_quantize_artifact_numerics_roundtrip():
    """Executing the lowered computation (via jax CPU) == oracle."""
    lowered = aot.lower_quantize()
    compiled = lowered.compile()
    rng = np.random.default_rng(0)
    x = (rng.standard_normal(aot.QUANT_N) * 2).astype(np.float32)
    u = rng.random(aot.QUANT_N).astype(np.float32)
    v = rng.standard_normal(aot.QUANT_N).astype(np.float32)
    (out,) = compiled(jnp.array(x), jnp.array(u), jnp.array(v),
                      jnp.int32(2), jnp.float32(0.25))
    want = ref.quantize_ref(jnp.array(x), jnp.array(u), jnp.array(v),
                            jnp.int32(2), jnp.float32(0.25), 3, -14, 15)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_mlr_artifact_step_executes():
    lowered = aot.lower_mlr()
    compiled = lowered.compile()
    p = aot.MLR_C * (aot.MLR_D + 1)
    rng = np.random.default_rng(1)
    params = jnp.zeros(p, dtype=jnp.float32)
    x = jnp.array(rng.random((aot.MLR_N, aot.MLR_D)).astype(np.float32))
    y = jnp.array(np.eye(aot.MLR_C, dtype=np.float32)[
        rng.integers(0, aot.MLR_C, aot.MLR_N)])
    uni = jnp.array(rng.random((3, p)).astype(np.float32))
    modes = jnp.array([1, 1, 3], dtype=jnp.int32)
    new_p, loss = compiled(params, x, y, uni, jnp.float32(0.5),
                           jnp.float32(0.1), modes)
    assert np.isfinite(float(loss))
    assert abs(float(loss) - np.log(10)) < 1e-3  # loss at zero params
    assert new_p.shape == (p,)
