"""Layer-1 Pallas kernel: the stochastic-rounding quantizer.

The paper's compute hot-spot is the rounding applied at every GD step to
every parameter -- an elementwise map over (parameters, uniforms, steering
values). On TPU this is a pure-VPU kernel: the parameter vector is tiled
into (BLOCK_ROWS, 128) VMEM blocks via BlockSpec; each block runs the
mantissa-scale / floor / ceil / select arithmetic entirely in vector
registers, with the uniform randomness streamed in as an input field (no
in-kernel RNG, so the same HLO runs on CPU-interpret and TPU).

Hardware adaptation (DESIGN.md section 3): the paper targets no specific
accelerator; we tile for VMEM rather than porting CUDA idioms. VMEM per
block at (8, 128) f32 = 3 inputs + 1 output = 16 KiB -- far below the
~16 MiB budget, leaving room to widen blocks for bandwidth (see
EXPERIMENTS.md section Perf).

MUST be lowered with interpret=True for CPU PJRT execution; real-TPU
lowering emits a Mosaic custom-call the CPU plugin cannot run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# Default VMEM block: one (8, 128) f32 tile per operand.
BLOCK_ROWS = 8
LANES = 128



def _pow2_f32(k):
    """Exact 2**k as float32 for integer k in [-149, 127], via bit patterns.
    jnp.exp2 is NOT exact in f32 (exp2(13) -> 8192.004 on this backend)."""
    k = k.astype(jnp.int32)
    normal = lax.bitcast_convert_type(
        jnp.clip(k + 127, 1, 254).astype(jnp.int32) << 23, jnp.float32
    )
    sub = lax.bitcast_convert_type(
        (jnp.int32(1) << jnp.clip(k + 149, 0, 22)).astype(jnp.int32), jnp.float32
    )
    return jnp.where(k >= -126, normal, sub)


def _quantize_block(x, u, v, mode, eps, sig_bits: int, e_min: int, e_max: int):
    """The in-register rounding math (shared with the standalone kernel)."""
    x_max = (2.0 - 2.0 ** (1 - sig_bits)) * 2.0**e_max
    x = jnp.clip(x, -x_max, x_max)
    bits = lax.bitcast_convert_type(x, jnp.int32)
    raw_e = ((bits >> 23) & 0xFF) - 127
    e = jnp.maximum(raw_e, e_min)
    q = _pow2_f32(e - sig_bits + 1)
    m = x / q
    lo = jnp.floor(m) * q
    hi = jnp.ceil(m) * q
    gap = hi - lo
    inexact = gap > 0
    frac = jnp.where(inexact, (x - lo) / jnp.where(inexact, gap, 1.0), 0.0)

    m_lo = jnp.abs(lo / q)
    lo_even = jnp.mod(m_lo, 2.0) < 0.5
    rn = jnp.where(frac < 0.5, lo, jnp.where(frac > 0.5, hi, jnp.where(lo_even, lo, hi)))

    sx = jnp.sign(x)
    sv = jnp.sign(v)
    p_sr = 1.0 - frac
    p_eps = jnp.clip(1.0 - frac - sx * eps, 0.0, 1.0)
    p_sgn = jnp.clip(1.0 - frac + sv * eps, 0.0, 1.0)
    p_down = jnp.where(mode == 1, p_sr, jnp.where(mode == 2, p_eps, p_sgn))
    st = jnp.where(u < p_down, lo, hi)

    out = jnp.where(mode == 0, rn, st)
    return jnp.where(inexact, out, lo)


def _kernel(x_ref, u_ref, v_ref, mode_ref, eps_ref, o_ref, *, sig_bits, e_min, e_max):
    mode = mode_ref[0]
    eps = eps_ref[0]
    o_ref[...] = _quantize_block(
        x_ref[...], u_ref[...], v_ref[...], mode, eps, sig_bits, e_min, e_max
    )


@functools.partial(jax.jit, static_argnames=("sig_bits", "e_min", "e_max", "block_rows"))
def quantize(x, uniforms, v, mode, eps, *, sig_bits: int, e_min: int, e_max: int,
             block_rows: int = BLOCK_ROWS):
    """Pallas quantizer over a 2-D (rows, 128·k) array.

    x, uniforms, v: same shape, float32. mode: int32 scalar. eps: f32 scalar.
    """
    assert x.ndim == 2 and x.shape == uniforms.shape == v.shape
    rows, cols = x.shape
    assert rows % block_rows == 0 and cols % LANES == 0, (rows, cols)
    grid = (rows // block_rows, cols // LANES)
    spec = pl.BlockSpec((block_rows, LANES), lambda i, j: (i, j))
    # Scalars are broadcast to every block (whole-array spec).
    sspec = pl.BlockSpec((1,), lambda i, j: (0,))
    return pl.pallas_call(
        functools.partial(_kernel, sig_bits=sig_bits, e_min=e_min, e_max=e_max),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        grid=grid,
        in_specs=[spec, spec, spec, sspec, sspec],
        out_specs=spec,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, uniforms, v, mode.reshape(1), eps.reshape(1))


def quantize_flat(x, uniforms, v, mode, eps, *, sig_bits: int, e_min: int, e_max: int):
    """Convenience wrapper for 1-D inputs whose length is a multiple of
    BLOCK_ROWS*LANES (pads otherwise)."""
    n = x.shape[0]
    width = BLOCK_ROWS * LANES
    pad = (-n) % width
    if pad:
        x = jnp.pad(x, (0, pad))
        uniforms = jnp.pad(uniforms, (0, pad), constant_values=0.5)
        v = jnp.pad(v, (0, pad))
    shaped = lambda a: a.reshape(-1, LANES)
    out = quantize(shaped(x), shaped(uniforms), shaped(v), mode, eps,
                   sig_bits=sig_bits, e_min=e_min, e_max=e_max)
    return out.reshape(-1)[:n]
