"""Pure-jnp oracle for the stochastic-rounding quantizer (Layer 1 reference).

Semantics mirror the Rust substrate (`rust/src/fp/`): round a float32 carrier
value into the format F(sig_bits, e_min, e_max) using one of

    mode 0: RN  (round to nearest, ties to even)
    mode 1: SR  (Definition 1 -- unbiased stochastic rounding)
    mode 2: SReps (Definition 2 -- bias away from zero, magnitude eps)
    mode 3: signed-SReps (Definition 3 -- bias sign(-v), v an auxiliary input)

Stochastic modes consume one uniform sample per element. Out-of-range
magnitudes saturate to +/-x_max (chop-style; artifacts never exercise the
IEEE overflow-to-inf path). Representable inputs are fixed points of every
mode.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def format_params(sig_bits: int, e_min: int, e_max: int):
    """(u, x_min_sub, x_max) of the simulated format, as python floats."""
    u = 2.0 ** (-sig_bits)
    x_min_sub = 2.0 ** (e_min - sig_bits + 1)
    x_max = (2.0 - 2.0 ** (1 - sig_bits)) * 2.0**e_max
    return u, x_min_sub, x_max


def _exponent_of(ax):
    """floor(log2(ax)) for positive finite float32 ax, via bit extraction.

    float32 subnormals report -127, which is <= any target e_min we simulate,
    so the subsequent clamp handles them correctly.
    """
    bits = lax.bitcast_convert_type(ax.astype(jnp.float32), jnp.int32)
    raw = (bits >> 23) & 0xFF
    return raw - 127



def _pow2_f32(k):
    """Exact 2**k as float32 for integer k in [-149, 127], via bit patterns.
    jnp.exp2 is NOT exact in f32 (exp2(13) -> 8192.004 on this backend)."""
    k = k.astype(jnp.int32)
    normal = lax.bitcast_convert_type(
        jnp.clip(k + 127, 1, 254).astype(jnp.int32) << 23, jnp.float32
    )
    sub = lax.bitcast_convert_type(
        (jnp.int32(1) << jnp.clip(k + 149, 0, 22)).astype(jnp.int32), jnp.float32
    )
    return jnp.where(k >= -126, normal, sub)


def floor_ceil(x, sig_bits: int, e_min: int, e_max: int):
    """(lo, hi, q) neighbors of x in F, with saturation to +/-x_max."""
    _, _, x_max = format_params(sig_bits, e_min, e_max)
    x = jnp.clip(x, -x_max, x_max)
    ax = jnp.abs(x)
    e = jnp.maximum(_exponent_of(ax), e_min)
    q = _pow2_f32(e - sig_bits + 1)
    m = x / q
    lo = jnp.floor(m) * q
    hi = jnp.ceil(m) * q
    # x == 0 -> both neighbors 0 (q from the e_min binade keeps this exact).
    return lo, hi, q


def quantize_ref(x, uniforms, v, mode, eps, sig_bits: int, e_min: int, e_max: int):
    """Round `x` elementwise into F. `uniforms` in [0,1), `v` steers mode 3.

    `mode` is a traced int32 scalar (one compiled executable serves all
    schemes); `eps` is a traced float32 scalar.
    """
    x = x.astype(jnp.float32)
    lo, hi, q = floor_ceil(x, sig_bits, e_min, e_max)
    gap = hi - lo
    inexact = gap > 0
    frac = jnp.where(inexact, (x - lo) / jnp.where(inexact, gap, 1.0), 0.0)

    # --- RN, ties to even ---
    m_lo = jnp.abs(lo / q)
    lo_even = jnp.mod(m_lo, 2.0) < 0.5
    rn = jnp.where(
        frac < 0.5, lo, jnp.where(frac > 0.5, hi, jnp.where(lo_even, lo, hi))
    )

    # --- stochastic p(round down) per scheme ---
    sx = jnp.sign(x)
    sv = jnp.sign(v)
    p_sr = 1.0 - frac
    p_eps = jnp.clip(1.0 - frac - sx * eps, 0.0, 1.0)
    p_sgn = jnp.clip(1.0 - frac + sv * eps, 0.0, 1.0)
    p_down = jnp.where(mode == 1, p_sr, jnp.where(mode == 2, p_eps, p_sgn))
    st = jnp.where(uniforms < p_down, lo, hi)

    out = jnp.where(mode == 0, rn, st)
    return jnp.where(inexact, out, lo)
