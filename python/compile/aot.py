"""AOT pipeline: lower the Layer-2 graphs to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that the
xla_extension 0.5.1 used by the Rust `xla` crate rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (shapes are static; the Rust runtime marshals accordingly):

  quantize.hlo.txt   standalone Layer-1 quantizer, n = 8192 f32
                       args: x(8192) u(8192) v(8192) mode(i32[]) eps(f32[])
                       fmt: binary8
  mlr_step.hlo.txt   MLR rounded train step, N=256 D=196 C=10, binary8
                       args: params(1970) x(256,196) y(256,10)
                             uniforms(3,1970) t(f32[]) eps(f32[]) modes(i32[3])
                       out: (params'(1970), loss(f32[]))
  nn_step.hlo.txt    NN rounded train step, N=256 D=196 H=100, binary8
                       args: params(19801) x(256,196) y(256)
                             uniforms(3,19801) t(f32[]) eps(f32[]) modes(i32[3])
                       out: (params'(19801), loss(f32[]))

Run `make artifacts` (no-op when artifacts are newer than their inputs).
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.rounding import quantize_flat

MLR_N, MLR_D, MLR_C = 256, 196, 10
NN_N, NN_D, NN_H = 256, 196, 100
QUANT_N = 8192


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_quantize():
    fn = functools.partial(quantize_flat, sig_bits=3, e_min=-14, e_max=15)

    def wrapped(x, u, v, mode, eps):
        return (fn(x, u, v, mode, eps),)

    return jax.jit(wrapped).lower(
        f32(QUANT_N), f32(QUANT_N), f32(QUANT_N), i32(), f32()
    )


def lower_mlr():
    p = MLR_C * (MLR_D + 1)
    fn = functools.partial(
        model.mlr_train_step, n_classes=MLR_C, fmt=model.FMT_BINARY8
    )

    def wrapped(params, x, y, uniforms, t, eps, modes):
        new_p, loss = fn(params, x, y, uniforms, t, eps, modes)
        return (new_p, loss)

    return jax.jit(wrapped).lower(
        f32(p), f32(MLR_N, MLR_D), f32(MLR_N, MLR_C), f32(3, p), f32(), f32(), i32(3)
    )


def lower_nn():
    p = NN_H * (NN_D + 2) + 1
    fn = functools.partial(model.nn_train_step, hidden=NN_H, fmt=model.FMT_BINARY8)

    def wrapped(params, x, y, uniforms, t, eps, modes):
        new_p, loss = fn(params, x, y, uniforms, t, eps, modes)
        return (new_p, loss)

    return jax.jit(wrapped).lower(
        f32(p), f32(NN_N, NN_D), f32(NN_N), f32(3, p), f32(), f32(), i32(3)
    )


ARTIFACTS = {
    "quantize.hlo.txt": lower_quantize,
    "mlr_step.hlo.txt": lower_mlr,
    "nn_step.hlo.txt": lower_nn,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, lower in ARTIFACTS.items():
        path = os.path.join(args.out_dir, name)
        text = to_hlo_text(lower())
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
