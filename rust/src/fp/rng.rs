//! Deterministic pseudo-random number generation.
//!
//! Experiments average over 20 independent simulations (paper §5); every
//! stream must be reproducible and cheaply forkable per (experiment, seed,
//! purpose). We use xoshiro256++ seeded through SplitMix64 — tiny, fast,
//! and free of external dependencies.

/// SplitMix64 step — used for seeding and stream derivation.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single u64 via SplitMix64 (recommended by the authors).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Self { s }
    }

    /// Split off the numbered child stream `stream_id`, derived purely from
    /// this generator's *current state* (the parent is not advanced).
    ///
    /// This is the scheduler's determinism primitive: every
    /// (experiment × rounding-mode × repetition) cell derives its stream as
    /// `Rng::new(root_seed).split(cell_id)`, a pure function of
    /// `(root_seed, cell_id)`. A cell's trajectory is therefore
    /// bit-identical regardless of which worker thread runs it, in what
    /// order, or how many workers exist (`--jobs 1` ≡ `--jobs N`).
    ///
    /// `split` differs from [`Rng::fork`] in that the child is keyed by a
    /// plain integer (cheap, no string hashing) and mixes *all four* state
    /// words, so child streams of distinct parents never collide merely
    /// because the parents share `s[0]`.
    pub fn split(&self, stream_id: u64) -> Self {
        // Two SplitMix64 rounds over the state words keyed by the stream id
        // (odd multiplier from MCG128 literature) decorrelate neighbouring
        // ids; the child state is then drawn through SplitMix64 like `new`.
        let key = stream_id.wrapping_mul(0xD1342543DE82EF95).rotate_left(32);
        let mut sm = self.s[0] ^ self.s[2].rotate_left(17) ^ key;
        let a = splitmix64(&mut sm);
        let mut sm2 = a ^ self.s[1].rotate_left(29) ^ self.s[3].rotate_left(41);
        let s = [
            splitmix64(&mut sm2),
            splitmix64(&mut sm2),
            splitmix64(&mut sm2),
            splitmix64(&mut sm2),
        ];
        Self { s }
    }

    /// Derive an independent stream for a named purpose. Streams produced
    /// with different tags (or indices) are statistically independent.
    pub fn fork(&self, tag: &str, index: u64) -> Self {
        let mut h = 0xcbf29ce484222325u64; // FNV-1a over the tag
        for b in tag.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        let mut sm = self.s[0] ^ h.rotate_left(17) ^ index.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Self { s }
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity — this is not on the hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 0.0 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection not needed here;
    /// modulo bias is negligible for our n ≪ 2^64 but we reject anyway).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Fisher–Yates shuffle of indices `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below((i + 1) as u64) as usize;
            p.swap(i, j);
        }
        p
    }

    // ---- bulk (block-counter) API ----------------------------------------
    //
    // The slice rounding kernels consume randomness a *block* at a time: one
    // `fill_u64s` call refills a word buffer that then serves many elements
    // (see [`BitBlock`]), instead of one generator step per element. The
    // block index acts as the counter; within a block the words are the
    // consecutive raw outputs of the stream, so a filled buffer is a pure
    // function of `(state, block-counter)` and bulk consumers remain exactly
    // reproducible.

    /// Fill `out` with consecutive raw 64-bit outputs — the bulk counterpart
    /// of [`Rng::next_u64`]. Equivalent to calling `next_u64` `out.len()`
    /// times; kernels call this once per block rather than once per element.
    #[inline]
    pub fn fill_u64s(&mut self, out: &mut [u64]) {
        for w in out.iter_mut() {
            *w = self.next_u64();
        }
    }

    /// Fill `out` with uniforms in `[0, 1)` (53 random bits each) — the bulk
    /// counterpart of [`Rng::uniform`].
    #[inline]
    pub fn fill_uniform(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.uniform();
        }
    }
}

/// Block-buffered random-bit dispenser — the *few-random-bits* stochastic
/// rounding path (Fitzgibbon & Felix 2025; Xia et al. 2020). One stochastic
/// rounding decision needs only `k` random bits (the slice kernels default
/// to `k = 32`), so the dispenser draws up to [`BitBlock::WORDS`] words at a
/// time through [`Rng::fill_u64s`] and slices them into `k`-bit chunks:
/// one bulk RNG call amortizes over `WORDS · ⌊64/k⌋` roundings.
///
/// Chunks never straddle words — a word's unusable remainder (`64 mod k`
/// bits) is discarded — so the `i`-th chunk served is a pure function of the
/// generator state at construction plus `(i, k)`, independent of interleaved
/// direct draws from the same `Rng` between refills.
#[derive(Debug)]
pub struct BitBlock {
    buf: [u64; Self::WORDS],
    /// Words drawn per refill (sized to the expected element count).
    refill: usize,
    /// Valid words currently in `buf`.
    len: usize,
    /// Index of the word being served.
    word: usize,
    /// Bits already consumed from the current word.
    used: u32,
}

impl BitBlock {
    /// Maximum words drawn per refill.
    pub const WORDS: usize = 32;

    /// An empty dispenser sized for about `elems` upcoming `bits`-wide
    /// chunks: the refill size is the number of words those chunks need,
    /// clamped to `[1, WORDS]`, so short slices do not over-draw from the
    /// stream and long slices amortize maximally.
    pub fn for_elems(elems: usize, bits: u32) -> Self {
        let per_word = (64 / bits.clamp(1, 64)) as usize;
        let need = elems.max(1).div_ceil(per_word);
        Self { buf: [0; Self::WORDS], refill: need.clamp(1, Self::WORDS), len: 0, word: 0, used: 0 }
    }

    /// Serve `bits` (1..=64) random bits as the low bits of the returned
    /// word, refilling from `rng` when the buffer runs dry.
    #[inline]
    pub fn take(&mut self, bits: u32, rng: &mut Rng) -> u64 {
        debug_assert!((1..=64).contains(&bits));
        if self.word >= self.len || self.used + bits > 64 {
            self.word += 1;
            self.used = 0;
            if self.word >= self.len {
                rng.fill_u64s(&mut self.buf[..self.refill]);
                self.len = self.refill;
                self.word = 0;
            }
        }
        let chunk = (self.buf[self.word] >> self.used) & (u64::MAX >> (64 - bits));
        self.used += bits;
        chunk
    }
}

/// Multi-lane counterpart of [`BitBlock`] for the structure-of-arrays lane
/// mode: one shared buffer, partitioned into per-lane regions, dispensing
/// `k`-bit chunks to `lanes` independent repetitions of one experiment cell.
///
/// The determinism contract is the whole point of this type: **lane `l`
/// consumes exactly the chunk sequence that a scalar
/// `BitBlock::for_elems(elems, bits)` would serve from lane `l`'s own
/// generator.** Each lane's region has the same word capacity as the scalar
/// dispenser's refill and is refilled from that lane's `Rng` with one bulk
/// [`Rng::fill_u64s`] call, so lane width is an execution strategy — running
/// 1, 8 or 64 lanes never changes any lane's stream, and per-lane streams
/// are disjoint whenever the lane generators are (seeded per repetition).
/// Chunks never straddle words, and therefore never straddle refills.
#[derive(Debug)]
pub struct LaneBits {
    /// Lane `l`'s words live at `buf[l * refill .. (l + 1) * refill]`.
    buf: Vec<u64>,
    /// Words drawn per refill, per lane — identical to the scalar
    /// [`BitBlock::for_elems`] sizing for the same `(elems, bits)`.
    refill: usize,
    /// Per-lane: valid words currently in the lane's region.
    len: Vec<usize>,
    /// Per-lane: index (within the region) of the word being served.
    word: Vec<usize>,
    /// Per-lane: bits already consumed from the current word.
    used: Vec<u32>,
}

impl LaneBits {
    /// A dispenser for `lanes` lanes, each sized for about `elems` upcoming
    /// `bits`-wide chunks — the lane-batched analogue of
    /// [`BitBlock::for_elems`].
    pub fn for_elems(elems: usize, bits: u32, lanes: usize) -> Self {
        let lanes = lanes.max(1);
        let per_word = (64 / bits.clamp(1, 64)) as usize;
        let need = elems.max(1).div_ceil(per_word);
        let refill = need.clamp(1, BitBlock::WORDS);
        Self {
            buf: vec![0; refill * lanes],
            refill,
            len: vec![0; lanes],
            word: vec![0; lanes],
            used: vec![0; lanes],
        }
    }

    /// Number of lanes this dispenser serves.
    pub fn lanes(&self) -> usize {
        self.len.len()
    }

    /// Serve `bits` (1..=64) random bits to lane `lane`, refilling that
    /// lane's region from `rng` — which must be the lane's own generator —
    /// when it runs dry. Bit-identical to [`BitBlock::take`] on a scalar
    /// dispenser driven by the same generator.
    #[inline]
    pub fn take(&mut self, lane: usize, bits: u32, rng: &mut Rng) -> u64 {
        debug_assert!((1..=64).contains(&bits));
        if self.word[lane] >= self.len[lane] || self.used[lane] + bits > 64 {
            self.word[lane] += 1;
            self.used[lane] = 0;
            if self.word[lane] >= self.len[lane] {
                let base = lane * self.refill;
                rng.fill_u64s(&mut self.buf[base..base + self.refill]);
                self.len[lane] = self.refill;
                self.word[lane] = 0;
            }
        }
        let w = self.buf[lane * self.refill + self.word[lane]];
        let chunk = (w >> self.used[lane]) & (u64::MAX >> (64 - bits));
        self.used[lane] += bits;
        chunk
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let mut c = Rng::new(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
    }

    #[test]
    fn forks_are_independent_and_stable() {
        let root = Rng::new(1);
        let mut f1 = root.fork("sigma1", 0);
        let mut f2 = root.fork("sigma1", 1);
        let mut f3 = root.fork("delta2", 0);
        let mut f1b = root.fork("sigma1", 0);
        let a = f1.next_u64();
        assert_ne!(a, f2.next_u64());
        assert_ne!(a, f3.next_u64());
        assert_eq!(a, f1b.next_u64());
    }

    #[test]
    fn split_is_pure_and_stream_sensitive() {
        let root = Rng::new(42);
        let mut a = root.split(0);
        let mut b = root.split(0);
        let mut c = root.split(1);
        let mut d = Rng::new(43).split(0);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        let vd: Vec<u64> = (0..8).map(|_| d.next_u64()).collect();
        assert_eq!(va, vb, "split must be a pure function of (state, id)");
        assert_ne!(va, vc, "distinct stream ids must differ");
        assert_ne!(va, vd, "distinct root seeds must differ");
        // Splitting does not advance the parent.
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        let _ = r2.split(7);
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    fn split_streams_look_independent() {
        // Crude independence check: the union of many child streams has a
        // near-uniform mean and no duplicated first outputs.
        let root = Rng::new(7);
        let mut firsts = std::collections::HashSet::new();
        let mut sum = 0.0;
        let n = 4096;
        for id in 0..n {
            let mut child = root.split(id);
            assert!(firsts.insert(child.next_u64()), "collision at id={id}");
            sum += child.uniform();
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn fill_matches_scalar_draws() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let mut words = [0u64; 17];
        a.fill_u64s(&mut words);
        for (i, &w) in words.iter().enumerate() {
            assert_eq!(w, b.next_u64(), "word {i}");
        }
        let mut us = [0.0f64; 9];
        a.fill_uniform(&mut us);
        for (i, &u) in us.iter().enumerate() {
            assert_eq!(u, b.uniform(), "uniform {i}");
        }
    }

    #[test]
    fn bit_block_chunks_are_stream_bits() {
        // 32-bit chunks: chunk 2i is the low half and chunk 2i+1 the high
        // half of the stream's i-th word.
        let mut rng = Rng::new(4);
        let mut blk = BitBlock::for_elems(64, 32);
        let mut mirror = Rng::new(4);
        for _ in 0..64 / 2 {
            let w = mirror.next_u64();
            assert_eq!(blk.take(32, &mut rng), w & 0xffff_ffff);
            assert_eq!(blk.take(32, &mut rng), w >> 32);
        }
        // Odd widths discard the word remainder but stay reproducible.
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let mut b1 = BitBlock::for_elems(100, 20);
        let mut b2 = BitBlock::for_elems(100, 20);
        for i in 0..100 {
            let c1 = b1.take(20, &mut r1);
            assert!(c1 < 1 << 20);
            assert_eq!(c1, b2.take(20, &mut r2), "chunk {i}");
        }
    }

    #[test]
    fn bit_block_short_slices_draw_few_words() {
        // A 2-element 32-bit consumer must draw exactly one word.
        let mut rng = Rng::new(6);
        let mut blk = BitBlock::for_elems(2, 32);
        let _ = blk.take(32, &mut rng);
        let _ = blk.take(32, &mut rng);
        let mut mirror = Rng::new(6);
        let _ = mirror.next_u64();
        // The parent streams are now aligned: next outputs agree.
        assert_eq!(rng.next_u64(), mirror.next_u64());
        // Full-width chunks occupy one word each.
        let mut rng = Rng::new(7);
        let mut blk = BitBlock::for_elems(3, 64);
        let mut mirror = Rng::new(7);
        for _ in 0..3 {
            assert_eq!(blk.take(64, &mut rng), mirror.next_u64());
        }
    }

    #[test]
    fn bit_block_mean_is_uniform() {
        let mut rng = Rng::new(8);
        let mut blk = BitBlock::for_elems(1 << 16, 16);
        let n = 1 << 16;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += blk.take(16, &mut rng) as f64 / (1u64 << 16) as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(3);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    // ---- LaneBits lane-packing suite (sr_bits k ∈ 1..=8 × lane widths) ----

    const LANE_WIDTHS: [usize; 4] = [1, 8, 16, 64];

    /// Lane 0 of a 1-lane batch is bit-identical to the scalar dispenser:
    /// same chunks, same generator end state, for every few-random-bits k.
    #[test]
    fn lane_bits_single_lane_matches_scalar_dispenser() {
        for k in 1u32..=8 {
            for elems in [1usize, 5, 64, 700] {
                let mut r_scalar = Rng::new(1000 + k as u64);
                let mut r_lane = r_scalar.clone();
                let mut blk = BitBlock::for_elems(elems, k);
                let mut lb = LaneBits::for_elems(elems, k, 1);
                for i in 0..elems {
                    assert_eq!(
                        blk.take(k, &mut r_scalar),
                        lb.take(0, k, &mut r_lane),
                        "k={k} elems={elems} chunk {i}"
                    );
                }
                // Same number of words drawn from the stream.
                assert_eq!(r_scalar.next_u64(), r_lane.next_u64(), "k={k} elems={elems}");
            }
        }
    }

    /// Every lane of every batch width serves exactly the scalar chunk
    /// sequence of its own generator — interleaved across lanes in element
    /// order, as the lane kernels consume it — and refill boundaries never
    /// split a chunk (each chunk equals the shift+mask of one stream word).
    #[test]
    fn lane_bits_every_lane_matches_its_scalar_stream() {
        for k in 1u32..=8 {
            for &lanes in &LANE_WIDTHS {
                // Enough elements to force several refills per lane.
                let per_word = (64 / k) as usize;
                let elems = BitBlock::WORDS * per_word * 2 + 3;
                let root = Rng::new(7 * k as u64 + lanes as u64);
                let mut rngs: Vec<Rng> = (0..lanes).map(|l| root.split(l as u64)).collect();
                let mut expect: Vec<(BitBlock, Rng)> = (0..lanes)
                    .map(|l| (BitBlock::for_elems(elems, k), rngs[l].clone()))
                    .collect();
                let mut lb = LaneBits::for_elems(elems, k, lanes);
                for i in 0..elems {
                    for l in 0..lanes {
                        let (blk, r) = &mut expect[l];
                        assert_eq!(
                            lb.take(l, k, &mut rngs[l]),
                            blk.take(k, r),
                            "k={k} lanes={lanes} elem {i} lane {l}"
                        );
                    }
                }
            }
        }
    }

    /// A chunk is always `k` consecutive low-order bits of a single word of
    /// its lane's stream: reconstructing the chunk sequence directly from
    /// the raw stream words (refill-block by refill-block) reproduces the
    /// dispenser output exactly, so no chunk ever crosses a word or a
    /// refill boundary.
    #[test]
    fn lane_bits_chunks_never_straddle_refill_boundaries() {
        for k in 1u32..=8 {
            for &lanes in &LANE_WIDTHS {
                let per_word = (64 / k) as usize;
                let elems = 150; // small refills → many refill boundaries
                let refill = elems.div_ceil(per_word).clamp(1, BitBlock::WORDS);
                let root = Rng::new(999 + k as u64 * 64 + lanes as u64);
                let mut rngs: Vec<Rng> = (0..lanes).map(|l| root.split(l as u64)).collect();
                let mut mirrors: Vec<Rng> = rngs.clone();
                let mut lb = LaneBits::for_elems(elems, k, lanes);
                let mask = u64::MAX >> (64 - k);
                for l in 0..lanes {
                    let mut expected = Vec::with_capacity(elems);
                    'fill: loop {
                        let mut block = vec![0u64; refill];
                        mirrors[l].fill_u64s(&mut block);
                        for w in block {
                            for j in 0..per_word {
                                expected.push((w >> (j as u32 * k)) & mask);
                                if expected.len() == elems {
                                    break 'fill;
                                }
                            }
                        }
                    }
                    for (i, &e) in expected.iter().enumerate() {
                        assert_eq!(
                            lb.take(l, k, &mut rngs[l]),
                            e,
                            "k={k} lanes={lanes} lane {l} chunk {i}"
                        );
                    }
                }
            }
        }
    }

    /// Per-lane streams are disjoint: distinct lanes (seeded per
    /// repetition through `split`) never serve identical chunk sequences.
    #[test]
    fn lane_bits_per_lane_streams_are_disjoint() {
        for k in 1u32..=8 {
            for &lanes in &LANE_WIDTHS[1..] {
                let elems = 128;
                let root = Rng::new(k as u64);
                let mut rngs: Vec<Rng> = (0..lanes).map(|l| root.split(l as u64)).collect();
                let mut lb = LaneBits::for_elems(elems, k, lanes);
                let mut seqs: Vec<Vec<u64>> = vec![Vec::with_capacity(elems); lanes];
                for _ in 0..elems {
                    for l in 0..lanes {
                        seqs[l].push(lb.take(l, k, &mut rngs[l]));
                    }
                }
                for a in 0..lanes {
                    for b in a + 1..lanes {
                        assert_ne!(seqs[a], seqs[b], "k={k} lanes {a} and {b} collide");
                    }
                }
            }
        }
    }
}
