//! Rounded linear algebra: every elementary operation is rounded into the
//! target format, following the standard model (5)/(6) of the paper —
//! `fl(x op y) = (x op y)(1 + δ)`.
//!
//! This is how the *gradient evaluation* (step (8a)) accumulates its error
//! σ₁: inner products and matrix–vector products lose high relative accuracy
//! when cancellation occurs ([13, §3.1/3.5]), which eq. (9) models with the
//! mixed absolute/relative bound `|σ₁,ᵢ| ≤ c·u·(|∇f(x)ᵢ| + 1)`.
//!
//! [`LpCtx`] bundles (format, rounding mode, RNG stream) and is threaded
//! through every op so a whole gradient evaluation can be switched between
//! RN / SR / SRε / signed-SRε with one configuration knob.

use super::format::FpFormat;
use super::grid::Grid;
use super::round::{RoundPlan, Rounding};
use super::rng::Rng;
use super::scheme::Scheme;

/// A low-precision computation context: all ops round into a fixed
/// `(grid, scheme)` pair chosen at construction.
///
/// The rounding constants are precomputed once ([`RoundPlan`]) — this is
/// the (8a) gradient hot path, where a single evaluation performs
/// `samples × features` scalar roundings. Grid and scheme are private so
/// the cached plan can never desynchronize; build a fresh context to
/// switch either. The grid is either backend (a float [`FpFormat`] or a
/// fixed-point [`crate::fp::FixedPoint`], both convert into [`Grid`]); the
/// scheme is any open-API [`Scheme`] handle — built-in schemes dispatch
/// through their cached [`Rounding`] tag (no virtual call on the
/// per-scalar path, bit-identical to the historic enum dispatch).
#[derive(Debug, Clone)]
pub struct LpCtx {
    grid: Grid,
    mode: Scheme,
    /// Randomness stream for the stochastic schemes.
    pub rng: Rng,
    /// Number of rounding operations performed (profiling / op counting).
    pub rounding_ops: u64,
    /// Constants precomputed from `grid` at construction.
    plan: RoundPlan,
}

impl LpCtx {
    /// A context rounding into `grid` (an [`FpFormat`], a
    /// [`crate::fp::FixedPoint`] or a [`Grid`]) with `mode` (a [`Scheme`]
    /// or a legacy [`Rounding`], both convert), drawing from `rng`.
    pub fn new(grid: impl Into<Grid>, mode: impl Into<Scheme>, rng: Rng) -> Self {
        let grid = grid.into();
        Self { grid, mode: mode.into(), rng, rounding_ops: 0, plan: RoundPlan::new(grid) }
    }

    /// The same context with `bits` random bits per stochastic slice
    /// rounding (see [`RoundPlan::with_sr_bits`]); scalar entry points are
    /// unaffected.
    pub fn with_sr_bits(mut self, bits: u32) -> Self {
        self.plan = RoundPlan::new(self.grid).with_sr_bits(bits);
        self
    }

    /// An exact (binary64) context — the "exact arithmetic" baseline.
    pub fn exact() -> Self {
        Self::new(FpFormat::BINARY64, Rounding::RoundNearestEven, Rng::new(0))
    }

    /// Target grid every operation result is rounded into.
    pub fn grid(&self) -> Grid {
        self.grid
    }

    /// Rounding scheme applied to every operation result.
    pub fn mode(&self) -> Scheme {
        self.mode
    }

    /// Split the context into the pieces the fused kernels
    /// ([`crate::fp::kernels`]) need: the precomputed plan (a `Copy`), the
    /// scheme, and a mutable borrow of the randomness stream. The plan can
    /// never desynchronize from the format because both are private and
    /// fixed at construction.
    #[inline]
    pub fn kernel_parts(&mut self) -> (RoundPlan, Scheme, &mut Rng) {
        (self.plan, self.mode, &mut self.rng)
    }

    /// Account `n` rounding operations performed on this context's behalf by
    /// an external fused kernel (keeps [`LpCtx::rounding_ops`] meaningful
    /// for profiling when the per-scalar entry points are bypassed).
    #[inline]
    pub fn add_rounding_ops(&mut self, n: u64) {
        self.rounding_ops += n;
    }

    /// Round a scalar into the context's format.
    #[inline]
    pub fn fl(&mut self, x: f64) -> f64 {
        self.fl_with(x, x)
    }

    /// Round with an explicit steering value for steered schemes.
    #[inline]
    pub fn fl_with(&mut self, x: f64, v: f64) -> f64 {
        self.rounding_ops += 1;
        // One dispatch site for the builtin-tag/dyn rule: the plan's
        // scheme entry point (built-ins take the cached-tag path).
        self.plan.round_scheme_with(self.mode, x, v, &mut self.rng)
    }

    // ---- rounded elementary ops: fl(x op y) ----

    /// Rounded addition `fl(x + y)`.
    #[inline]
    pub fn add(&mut self, x: f64, y: f64) -> f64 {
        self.fl(x + y)
    }
    /// Rounded subtraction `fl(x − y)`.
    #[inline]
    pub fn sub(&mut self, x: f64, y: f64) -> f64 {
        self.fl(x - y)
    }
    /// Rounded multiplication `fl(x · y)`.
    #[inline]
    pub fn mul(&mut self, x: f64, y: f64) -> f64 {
        self.fl(x * y)
    }
    /// Rounded division `fl(x / y)`.
    #[inline]
    pub fn div(&mut self, x: f64, y: f64) -> f64 {
        self.fl(x / y)
    }
    /// Rounded exponential `fl(eˣ)`.
    #[inline]
    pub fn exp(&mut self, x: f64) -> f64 {
        self.fl(x.exp())
    }
    /// Rounded natural log `fl(ln x)`.
    #[inline]
    pub fn ln(&mut self, x: f64) -> f64 {
        self.fl(x.ln())
    }
    /// Rounded square root `fl(√x)`.
    #[inline]
    pub fn sqrt(&mut self, x: f64) -> f64 {
        self.fl(x.sqrt())
    }

    /// Rounded inner product `fl(xᵀy)`: sequential accumulation, each
    /// multiply and each add rounded (the [13, §3.1] error model).
    pub fn dot(&mut self, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        let mut acc = 0.0;
        for (&a, &b) in x.iter().zip(y.iter()) {
            let p = self.mul(a, b);
            acc = self.add(acc, p);
        }
        acc
    }

    /// Rounded matrix–vector product `fl(A·x)`, `A` row-major `m × n`.
    pub fn gemv(&mut self, a: &[f64], m: usize, n: usize, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(a.len(), m * n);
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(out.len(), m);
        for i in 0..m {
            out[i] = self.dot(&a[i * n..(i + 1) * n], x);
        }
    }

    /// Rounded transposed matrix–vector product `fl(Aᵀ·x)` (`A` `m × n`).
    /// Accumulates column-wise with rounded ops.
    pub fn gemv_t(&mut self, a: &[f64], m: usize, n: usize, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(a.len(), m * n);
        debug_assert_eq!(x.len(), m);
        debug_assert_eq!(out.len(), n);
        out.fill(0.0);
        for i in 0..m {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = &a[i * n..(i + 1) * n];
            for j in 0..n {
                let p = self.mul(row[j], xi);
                out[j] = self.add(out[j], p);
            }
        }
    }

    /// Rounded `y ← fl(fl(α·x) + y)` (axpy with per-op rounding).
    pub fn axpy(&mut self, alpha: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        for (yi, &xi) in y.iter_mut().zip(x.iter()) {
            let p = self.mul(alpha, xi);
            *yi = self.add(*yi, p);
        }
    }

    /// Round a whole slice into the format (entrywise storage rounding),
    /// **scalar reference semantics**: one [`LpCtx::fl`] call — and thus one
    /// full-width uniform per inexact element — in element order. This is
    /// the historic per-scalar path, retained for the reference gradient
    /// implementations and the speedup benches; the hot paths use the fused
    /// [`RoundPlan::round_slice`] kernels (batched few-random-bits stream)
    /// via [`LpCtx::kernel_parts`] instead.
    pub fn fl_slice(&mut self, xs: &mut [f64]) {
        for x in xs.iter_mut() {
            *x = self.fl(*x);
        }
    }
}

/// Exact (f64) helpers used by the "exact arithmetic" reference paths and by
/// tests — kept here so problem code can share one vocabulary.
pub mod exact {
    /// Exact inner product `xᵀy`.
    pub fn dot(x: &[f64], y: &[f64]) -> f64 {
        x.iter().zip(y).map(|(a, b)| a * b).sum()
    }
    /// Exact Euclidean norm `‖x‖₂`.
    pub fn norm2(x: &[f64]) -> f64 {
        dot(x, x).sqrt()
    }
    /// Exact matrix–vector product `A·x` (`A` row-major `m × n`).
    pub fn gemv(a: &[f64], m: usize, n: usize, x: &[f64], out: &mut [f64]) {
        for i in 0..m {
            out[i] = dot(&a[i * n..(i + 1) * n], x);
        }
    }
    /// Exact transposed matrix–vector product `Aᵀ·x` (`A` `m × n`).
    pub fn gemv_t(a: &[f64], m: usize, n: usize, x: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        for i in 0..m {
            let xi = x[i];
            for j in 0..n {
                out[j] += a[i * n + j] * xi;
            }
        }
    }
    /// Elementwise difference `x − y` as a new vector.
    pub fn sub(x: &[f64], y: &[f64]) -> Vec<f64> {
        x.iter().zip(y).map(|(a, b)| a - b).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(fmt: FpFormat, mode: Rounding) -> LpCtx {
        LpCtx::new(fmt, mode, Rng::new(123))
    }

    #[test]
    fn exact_ctx_is_identity_on_f64() {
        let mut c = LpCtx::exact();
        for &x in &[1.0, 3.14159265358979, -2.5e-300, 1e300] {
            assert_eq!(c.fl(x), x);
        }
        assert_eq!(c.dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }

    #[test]
    fn rounded_add_standard_model() {
        // binary8, u = 1/8: fl(x+y) = (x+y)(1+δ), |δ| ≤ u for RN.
        let mut c = ctx(FpFormat::BINARY8, Rounding::RoundNearestEven);
        let u = FpFormat::BINARY8.unit_roundoff();
        for &(x, y) in &[(1.0, 0.1), (3.3, 4.7), (-1.9, 0.33), (100.0, 3.0)] {
            let z = c.add(x, y);
            let delta = (z - (x + y)) / (x + y);
            assert!(delta.abs() <= u + 1e-15, "x={x} y={y} δ={delta}");
        }
    }

    #[test]
    fn rounded_ops_sr_model_2u() {
        // For SR the standard model holds with 2u (paper after eq. (5)).
        let mut c = ctx(FpFormat::BINARY8, Rounding::Sr);
        let u = FpFormat::BINARY8.unit_roundoff();
        for i in 0..500 {
            let x = 0.3 + 0.01 * i as f64;
            let z = c.mul(x, 1.7);
            let delta = (z - x * 1.7) / (x * 1.7);
            assert!(delta.abs() <= 2.0 * u + 1e-15, "x={x} δ={delta}");
        }
    }

    #[test]
    fn dot_error_bound_sequential() {
        // |fl(xᵀy) − xᵀy| ≤ γ_n |x|ᵀ|y| with γ_n = n·2u/(1−n·2u) for SR
        // (probabilistic bounds are tighter; the deterministic one must hold
        // surely for RN).
        let n = 16;
        let x: Vec<f64> = (0..n).map(|i| 0.07 * (i as f64 + 1.0)).collect();
        let y: Vec<f64> = (0..n).map(|i| 0.11 * (n - i) as f64).collect();
        let exact: f64 = exact::dot(&x, &y);
        let abs_sum: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
        let u = FpFormat::BFLOAT16.unit_roundoff();
        let gamma = (n as f64) * u / (1.0 - n as f64 * u);
        let mut c = ctx(FpFormat::BFLOAT16, Rounding::RoundNearestEven);
        let z = c.dot(&x, &y);
        assert!((z - exact).abs() <= 1.1 * gamma * abs_sum, "z={z} exact={exact}");
    }

    #[test]
    fn gemv_matches_exact_in_binary64_ctx() {
        let mut c = LpCtx::exact();
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2×3
        let x = vec![1.0, 0.5, -1.0];
        let mut out = vec![0.0; 2];
        c.gemv(&a, 2, 3, &x, &mut out);
        assert_eq!(out, vec![1.0 + 1.0 - 3.0, 4.0 + 2.5 - 6.0]);
        let mut out_t = vec![0.0; 3];
        c.gemv_t(&a, 2, 3, &[1.0, 2.0], &mut out_t);
        assert_eq!(out_t, vec![1.0 + 8.0, 2.0 + 10.0, 3.0 + 12.0]);
    }

    #[test]
    fn rounding_op_counter() {
        let mut c = ctx(FpFormat::BINARY8, Rounding::Sr);
        let before = c.rounding_ops;
        let _ = c.dot(&[1.0, 2.0], &[3.0, 4.0]); // 2 muls + 2 adds
        assert_eq!(c.rounding_ops - before, 4);
    }

    #[test]
    fn axpy_rounds_into_format() {
        let mut c = ctx(FpFormat::BINARY8, Rounding::RoundNearestEven);
        let x = vec![0.313, 0.771];
        let mut y = vec![1.0, -2.0];
        c.axpy(0.5, &x, &mut y);
        for &v in &y {
            assert!(FpFormat::BINARY8.contains(v), "v={v}");
        }
    }
}
