//! Floating-point *format* descriptions and exact neighbor arithmetic.
//!
//! A format `F(s, e_min, e_max)` is the set of reals `± μ · 2^(e−s+1)` with
//! `μ ∈ [2^(s−1), 2^s)` (normal numbers, exponent `e ∈ [e_min, e_max]`) plus
//! `± μ · 2^(e_min−s+1)` with `μ ∈ [0, 2^(s−1))` (subnormals) — i.e. the
//! classical IEEE-754-style number line with `s` significand bits *including*
//! the implicit bit, exactly the convention of the paper (§2.1, Table 2).
//!
//! Every simulated value is carried as an `f64` that is *exactly* an element
//! of the target format. This works because all formats we simulate have
//! `s ≤ 24 < 53` and exponent ranges inside binary64's, so the embedding
//! 𝔽 ⊂ binary64 is exact (the same trick as Higham & Pranesh's `chop`).
//!
//! Neighbor arithmetic (`floor_ceil`, `successor`, `predecessor`, `contains`)
//! operates **directly on the binary64 bit pattern**: the target floor of a
//! magnitude is its f64 encoding with the sub-ulp tail masked off, and the
//! target ceiling is one integer increment of the target ulp above it (the
//! carry into the exponent field is exactly the binade crossing). The
//! original float-arithmetic implementations are retained verbatim in
//! [`reference`] as the oracle the bit kernels are tested against — see the
//! exhaustive sweep in `rust/tests/properties.rs` and `docs/performance.md`.

/// A binary floating-point format with `s` significand bits (implicit bit
/// included), exponent range `[e_min, e_max]`, and optional subnormals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FpFormat {
    /// Significand precision in bits, including the implicit leading bit.
    pub sig_bits: u32,
    /// Minimum normalized exponent (value of `e` for the smallest normal).
    pub e_min: i32,
    /// Maximum exponent.
    pub e_max: i32,
    /// Whether subnormal numbers are representable (chop's `subnormal=1`).
    pub subnormals: bool,
}

impl FpFormat {
    /// A format with `sig_bits` significand bits (implicit bit included),
    /// exponent range `[e_min, e_max]` and subnormals enabled.
    pub const fn new(sig_bits: u32, e_min: i32, e_max: i32) -> Self {
        Self { sig_bits, e_min, e_max, subnormals: true }
    }

    /// binary8 in the E5M2 layout (NVIDIA H100 / OCP FP8): 2 stored mantissa
    /// bits, 5 exponent bits. `u = 2^{-3}`, `x_min = 2^{-14} ≈ 6.10e-5`,
    /// `x_max = 1.75 · 2^{15} = 57344 ≈ 5.73e4` — the paper's Table 2 row.
    pub const BINARY8: Self = Self::new(3, -14, 15);
    /// bfloat16: 7 stored mantissa bits, 8 exponent bits. `u = 2^{-8}`.
    pub const BFLOAT16: Self = Self::new(8, -126, 127);
    /// IEEE binary16 (half): `u = 2^{-11}`.
    pub const BINARY16: Self = Self::new(11, -14, 15);
    /// IEEE binary32 (single): `u = 2^{-24}`.
    pub const BINARY32: Self = Self::new(24, -126, 127);
    /// IEEE binary64 (double): `u = 2^{-53}`. Identity for our f64 carrier.
    pub const BINARY64: Self = Self::new(53, -1022, 1023);

    /// Look a preset up by name (CLI / config front-end).
    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "binary8" | "fp8" | "e5m2" | "b8" => Some(Self::BINARY8),
            "bfloat16" | "bf16" => Some(Self::BFLOAT16),
            "binary16" | "fp16" | "half" | "b16" => Some(Self::BINARY16),
            "binary32" | "fp32" | "single" | "b32" => Some(Self::BINARY32),
            "binary64" | "fp64" | "double" | "b64" => Some(Self::BINARY64),
            _ => None,
        }
    }

    /// Canonical name of a preset format ("custom" for anything else).
    pub fn name(&self) -> &'static str {
        match *self {
            Self::BINARY8 => "binary8",
            Self::BFLOAT16 => "bfloat16",
            Self::BINARY16 => "binary16",
            Self::BINARY32 => "binary32",
            Self::BINARY64 => "binary64",
            _ => "custom",
        }
    }

    /// Unit roundoff `u = 2^{-s}` (max relative error of RN on normals).
    #[inline]
    pub fn unit_roundoff(&self) -> f64 {
        pow2(-(self.sig_bits as i32))
    }

    /// Machine epsilon `2u = 2^{1-s}` (spacing of the binade `[1,2)`).
    #[inline]
    pub fn eps(&self) -> f64 {
        2.0 * self.unit_roundoff()
    }

    /// Smallest positive *normalized* number `2^{e_min}`.
    #[inline]
    pub fn x_min(&self) -> f64 {
        pow2(self.e_min)
    }

    /// Smallest positive *subnormal* number `2^{e_min - s + 1}`
    /// (equals `x_min` when subnormals are disabled).
    #[inline]
    pub fn x_min_sub(&self) -> f64 {
        if self.subnormals {
            pow2(self.e_min - self.sig_bits as i32 + 1)
        } else {
            self.x_min()
        }
    }

    /// Largest finite number `(2 - 2^{1-s}) · 2^{e_max}`.
    #[inline]
    pub fn x_max(&self) -> f64 {
        (2.0 - self.eps()) * pow2(self.e_max)
    }

    /// The spacing (ulp) of the format in the binade that contains `x`
    /// (for nonzero finite `x`; the subnormal region has the `e_min` spacing).
    #[inline]
    pub fn spacing_at(&self, x: f64) -> f64 {
        debug_assert!(x.is_finite());
        let e = exponent_of(x.abs()).max(self.e_min);
        pow2(e - self.sig_bits as i32 + 1)
    }

    /// Number of binary64 mantissa bits of `|x|` that lie *below* the target
    /// ulp, i.e. the width of the discarded tail, together with the raw f64
    /// exponent field. `shift ≤ 0` means the format is at least as fine as
    /// binary64 at `|x|` (always representable); `shift ≥ 53` means the
    /// entire significand sits below the subnormal spacing (`0 < |x| < q`).
    /// For `shift ∈ [1, 52]` the target floor of the magnitude is
    /// `bits & !((1 << shift) − 1)` and the ceiling is one `2^shift`
    /// increment above it (the mantissa carry into the exponent field is
    /// exactly the binade crossing, which is itself a grid point).
    #[inline]
    fn tail_shift(&self, mag: f64) -> i32 {
        let bits = mag.to_bits();
        let raw_e = ((bits >> 52) & 0x7ff) as i32;
        let (e, e_lsb) = if raw_e == 0 {
            (exponent_of(mag), -1074)
        } else {
            (raw_e - 1023, raw_e - 1023 - 52)
        };
        (e.max(self.e_min) - self.sig_bits as i32 + 1) - e_lsb
    }

    /// Is `x` exactly an element of this format (finite values only)?
    /// Bit-level: `x ∈ F` iff the sub-ulp tail of its magnitude is zero.
    pub fn contains(&self, x: f64) -> bool {
        if x == 0.0 {
            return true;
        }
        if !x.is_finite() {
            return false;
        }
        let a = x.abs();
        if a > self.x_max() {
            return false;
        }
        if !self.subnormals && a < self.x_min() {
            return false;
        }
        let shift = self.tail_shift(a);
        shift <= 0 || (shift < 53 && a.to_bits() & ((1u64 << shift) - 1) == 0)
    }

    /// `⌊x⌋_F = max{ y ∈ F : y ≤ x }` and `⌈x⌉_F = min{ y ∈ F : y ≥ x }`,
    /// computed exactly on the binary64 bit pattern (mantissa masking plus
    /// one integer increment; see [`FpFormat::tail_shift`]). Magnitudes
    /// beyond `x_max` clamp to `±x_max` on the inward side and `±∞` on the
    /// outward side (chop-style saturation is applied by the rounding layer,
    /// which never returns ±∞ for the stochastic schemes — see `round.rs`).
    pub fn floor_ceil(&self, x: f64) -> (f64, f64) {
        if x == 0.0 {
            return (0.0, 0.0);
        }
        if x.is_nan() {
            return (f64::NAN, f64::NAN);
        }
        let xmax = self.x_max();
        if x.is_infinite() {
            return if x > 0.0 { (xmax, f64::INFINITY) } else { (f64::NEG_INFINITY, -xmax) };
        }
        if x > xmax {
            return (xmax, f64::INFINITY);
        }
        if x < -xmax {
            return (f64::NEG_INFINITY, -xmax);
        }
        let (lo_mag, hi_mag) = self.floor_ceil_mag(x.abs());
        let (lo, hi) = if x < 0.0 { (-hi_mag, -lo_mag) } else { (lo_mag, hi_mag) };
        if self.subnormals {
            (lo, hi)
        } else {
            // Flush the open subnormal interval (−x_min, x_min) \ {0} to its
            // representable endpoints {−x_min, 0, x_min}.
            let xmin = self.x_min();
            let fix = |v: f64| -> f64 {
                if v != 0.0 && v.abs() < xmin {
                    if v > 0.0 { 0.0 } else { -0.0 }
                } else {
                    v
                }
            };
            let (mut lo2, mut hi2) = (fix(lo), fix(hi));
            // Flushing can collapse both sides to 0 even when x ≠ 0; widen to
            // the true neighbors in that case.
            if lo2 == 0.0 && x < 0.0 && lo != 0.0 {
                lo2 = -xmin;
            }
            if hi2 == 0.0 && x > 0.0 && hi != 0.0 {
                hi2 = xmin;
            }
            (lo2, hi2)
        }
    }

    /// Neighbor pair of a magnitude `0 < m ≤ x_max` on the *subnormal-enabled*
    /// grid (the caller applies sign and the flush-to-zero policy).
    #[inline]
    fn floor_ceil_mag(&self, m: f64) -> (f64, f64) {
        let shift = self.tail_shift(m);
        if shift <= 0 {
            return (m, m); // binary64 is not finer than the target here
        }
        if shift >= 53 {
            // The whole significand sits below the subnormal spacing q:
            // 0 < m < q, so the neighbors are 0 and q.
            return (0.0, pow2(self.e_min - self.sig_bits as i32 + 1));
        }
        let bits = m.to_bits();
        let mask = (1u64 << shift) - 1;
        if bits & mask == 0 {
            return (m, m);
        }
        let lo = bits & !mask;
        (f64::from_bits(lo), f64::from_bits(lo + mask + 1))
    }

    /// Successor `su(x̂) = min{ ŷ ∈ F : ŷ > x̂ }` for a value already in `F`
    /// (paper eq. (10); strict, unlike `⌈·⌉`). Bit-level: the format-ceiling
    /// of the binary64 value one ulp₆₄ above `x̂` — strictness is inherited
    /// from the strict monotonicity of the f64 bit pattern.
    pub fn successor(&self, x: f64) -> f64 {
        debug_assert!(self.contains(x), "successor() requires x ∈ F (got {x})");
        if x >= self.x_max() {
            return f64::INFINITY;
        }
        if x == 0.0 {
            return self.x_min_sub();
        }
        self.floor_ceil(next_up(x)).1
    }

    /// Predecessor `pr(x̂) = max{ ŷ ∈ F : ŷ < x̂ }` for a value already in `F`.
    pub fn predecessor(&self, x: f64) -> f64 {
        -self.successor(-x)
    }
}

/// Smallest binary64 value strictly greater than finite `x` (both ±0 map to
/// the smallest positive subnormal — the standard `nextUp` bit increment).
#[inline]
fn next_up(x: f64) -> f64 {
    debug_assert!(x.is_finite());
    let bits = x.to_bits();
    if x == 0.0 {
        f64::from_bits(1)
    } else if bits >> 63 == 0 {
        f64::from_bits(bits + 1)
    } else {
        f64::from_bits(bits - 1)
    }
}

/// Exact `2^e` for any `e ∈ [-1074, 1023]`, built from the binary64 bit
/// pattern. `f64::powi` is *not* exact here: it can evaluate `2^{-1048}` as
/// `1 / 2^{1048} = 1/∞ = 0`, which poisons neighbor arithmetic with NaNs.
///
/// Saturation at the edges is part of the contract: `e > 1023` overflows to
/// `+∞` and `e < -1074` (below the binary64 subnormal range, e.g. the
/// `e_min − sig_bits` halfway exponent of a binary64-wide format) underflows
/// to `+0.0`. Callers that need the round-trip `exponent_of(pow2(e)) == e`
/// must therefore stay inside `[-1074, 1023]` — see
/// `tests::pow2_exponent_roundtrip_subnormal_edge`.
#[inline]
pub fn pow2(e: i32) -> f64 {
    if e > 1023 {
        f64::INFINITY
    } else if e >= -1022 {
        f64::from_bits(((e + 1023) as u64) << 52)
    } else if e >= -1074 {
        f64::from_bits(1u64 << (e + 1074))
    } else {
        0.0
    }
}

/// Exponent `e` such that `2^e ≤ |x| < 2^{e+1}`, for finite positive `x`,
/// extracted from the binary64 bit pattern (exact; no `log2` rounding).
/// Total on the whole binary64 subnormal range down to `2^{-1074}`
/// (the `e_min − sig_bits + 1` edge of a binary64-wide format); `x = 0`
/// is rejected by the debug assertion and has no meaningful exponent.
#[inline]
pub fn exponent_of(x: f64) -> i32 {
    debug_assert!(x > 0.0 && x.is_finite());
    let bits = x.to_bits();
    let raw = ((bits >> 52) & 0x7ff) as i32;
    if raw == 0 {
        // binary64 subnormal: normalize via the mantissa's leading zero count.
        let mant = bits & ((1u64 << 52) - 1);
        -1022 - (52 - (63 - mant.leading_zeros() as i32))
    } else {
        raw - 1023
    }
}

/// The original float-arithmetic neighbor kernels, retained **verbatim** as
/// the oracle for the bit-level implementations on [`FpFormat`]. These walk
/// exponents with `pow2`/division (exact, but several times slower than the
/// mask-and-increment fast path); every bit kernel is tested against them —
/// exhaustively over all representable binary8 values plus halfway points,
/// subnormals, ±overflow and ±0 in `rust/tests/properties.rs`.
pub mod reference {
    use super::{exponent_of, pow2, FpFormat};

    /// Reference ulp: `2^{max(e, e_min) − s + 1}` via exponent walking.
    #[inline]
    pub fn spacing_at(fmt: &FpFormat, x: f64) -> f64 {
        debug_assert!(x.is_finite());
        let e = exponent_of(x.abs()).max(fmt.e_min);
        pow2(e - fmt.sig_bits as i32 + 1)
    }

    /// Reference membership test via exact division by the spacing.
    pub fn contains(fmt: &FpFormat, x: f64) -> bool {
        if x == 0.0 {
            return true;
        }
        if !x.is_finite() || x.abs() > fmt.x_max() {
            return false;
        }
        let q = spacing_at(fmt, x);
        let m = x / q; // exact: division by a power of two
        if m != m.trunc() {
            return false;
        }
        if !fmt.subnormals && x.abs() < fmt.x_min() {
            return false;
        }
        true
    }

    /// Reference `(⌊x⌋_F, ⌈x⌉_F)` via exact float division / floor / ceil.
    pub fn floor_ceil(fmt: &FpFormat, x: f64) -> (f64, f64) {
        if x == 0.0 {
            return (0.0, 0.0);
        }
        if x.is_nan() {
            return (f64::NAN, f64::NAN);
        }
        let xmax = fmt.x_max();
        if x.is_infinite() {
            return if x > 0.0 { (xmax, f64::INFINITY) } else { (f64::NEG_INFINITY, -xmax) };
        }
        if x > xmax {
            return (xmax, f64::INFINITY);
        }
        if x < -xmax {
            return (f64::NEG_INFINITY, -xmax);
        }
        let q = spacing_at(fmt, x);
        // Exact: x/q has magnitude < 2^s ≤ 2^24, and x is a binary64 value.
        let m = x / q;
        let (lo, hi) = (m.floor() * q, m.ceil() * q);
        if fmt.subnormals {
            (lo, hi)
        } else {
            // Flush the open subnormal interval (−x_min, x_min) \ {0} to its
            // representable endpoints {−x_min, 0, x_min}.
            let xmin = fmt.x_min();
            let fix = |v: f64| -> f64 {
                if v != 0.0 && v.abs() < xmin {
                    if v > 0.0 { 0.0 } else { -0.0 }
                } else {
                    v
                }
            };
            let (mut lo2, mut hi2) = (fix(lo), fix(hi));
            // Flushing can collapse both sides to 0 even when x ≠ 0; widen to
            // the true neighbors in that case.
            if lo2 == 0.0 && x < 0.0 && lo != 0.0 {
                lo2 = -xmin;
            }
            if hi2 == 0.0 && x > 0.0 && hi != 0.0 {
                hi2 = xmin;
            }
            (lo2, hi2)
        }
    }

    /// Reference strict successor via spacing arithmetic.
    pub fn successor(fmt: &FpFormat, x: f64) -> f64 {
        debug_assert!(contains(fmt, x), "successor() requires x ∈ F (got {x})");
        if x >= fmt.x_max() {
            return f64::INFINITY;
        }
        if x == 0.0 {
            return fmt.x_min_sub();
        }
        let q = spacing_at(fmt, x);
        if x < 0.0 {
            // Moving toward zero: crossing −2^e into the finer binade.
            let m = x / q;
            if m == -(1i64 << (fmt.sig_bits - 1)) as f64 && x.abs() > fmt.x_min() {
                x + q / 2.0
            } else {
                x + q
            }
        } else {
            x + q // may land exactly on 2^{e+1}, which is representable
        }
    }

    /// Reference strict predecessor (mirror of [`successor`]).
    pub fn predecessor(fmt: &FpFormat, x: f64) -> f64 {
        -successor(fmt, -x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_parameters() {
        // Paper Table 2, reproduced bit-exactly.
        let b8 = FpFormat::BINARY8;
        assert_eq!(b8.unit_roundoff(), 0.125);
        assert!((b8.x_min() - 6.10e-5).abs() / 6.10e-5 < 1e-2);
        assert_eq!(b8.x_max(), 57344.0); // 5.73e4

        let bf16 = FpFormat::BFLOAT16;
        assert_eq!(bf16.unit_roundoff(), (2.0f64).powi(-8));
        assert!((bf16.x_min() - 1.18e-38).abs() / 1.18e-38 < 1e-2);
        assert!((bf16.x_max() - 3.39e38).abs() / 3.39e38 < 1e-2);

        let b16 = FpFormat::BINARY16;
        assert_eq!(b16.unit_roundoff(), (2.0f64).powi(-11));
        assert_eq!(b16.x_max(), 65504.0); // 6.55e4

        let b32 = FpFormat::BINARY32;
        assert_eq!(b32.unit_roundoff(), (2.0f64).powi(-24));
        assert!((b32.x_max() - 3.40e38).abs() / 3.40e38 < 1e-2);

        let b64 = FpFormat::BINARY64;
        assert_eq!(b64.unit_roundoff(), (2.0f64).powi(-53));
        assert!((b64.x_min() - 2.22e-308).abs() / 2.22e-308 < 1e-2);
        assert_eq!(b64.x_max(), f64::MAX); // 1.80e308
    }

    #[test]
    fn exponent_extraction() {
        assert_eq!(exponent_of(1.0), 0);
        assert_eq!(exponent_of(1.5), 0);
        assert_eq!(exponent_of(2.0), 1);
        assert_eq!(exponent_of(0.5), -1);
        assert_eq!(exponent_of(1024.0), 10);
        assert_eq!(exponent_of(1023.9), 9);
        assert_eq!(exponent_of(f64::MIN_POSITIVE), -1022);
        assert_eq!(exponent_of(f64::MIN_POSITIVE / 2.0), -1023);
    }

    /// `pow2` / `exponent_of` must round-trip across the *entire* binary64
    /// subnormal range, including the `e_min − sig_bits + 1` edge of every
    /// preset; below `2^{-1074}` `pow2` saturates to `+0.0` by contract.
    #[test]
    fn pow2_exponent_roundtrip_subnormal_edge() {
        for e in [-1074, -1073, -1060, -1023, -1022, -1021, -160, -1, 0, 1, 1023] {
            let p = pow2(e);
            assert!(p > 0.0 && p.is_finite(), "pow2({e}) = {p}");
            assert_eq!(exponent_of(p), e, "round-trip failed at e={e}");
            assert_eq!(pow2(exponent_of(p)), p, "pow2∘exponent_of not identity at e={e}");
        }
        // Saturation contract at both edges.
        assert_eq!(pow2(-1075), 0.0);
        assert_eq!(pow2(i32::MIN), 0.0);
        assert_eq!(pow2(1024), f64::INFINITY);
        assert_eq!(pow2(i32::MAX), f64::INFINITY);
        // Every preset's extreme subnormal boundary: x_min_sub round-trips,
        // and its exponent is exactly e_min − s + 1.
        for fmt in [
            FpFormat::BINARY8,
            FpFormat::BFLOAT16,
            FpFormat::BINARY16,
            FpFormat::BINARY32,
            FpFormat::BINARY64,
        ] {
            let q = fmt.x_min_sub();
            let eq = fmt.e_min - fmt.sig_bits as i32 + 1;
            assert_eq!(exponent_of(q), eq, "{}", fmt.name());
            assert_eq!(pow2(eq), q, "{}", fmt.name());
        }
    }

    /// The halfway magnitude `2^{e_min − s}` (one exponent below the smallest
    /// subnormal) must round-trip through the neighbor kernels as the open
    /// interval `(0, x_min_sub)` for every preset where it is a binary64
    /// value (all but binary64 itself, whose halfway point underflows f64).
    #[test]
    fn floor_ceil_at_extreme_subnormal_boundary() {
        for fmt in
            [FpFormat::BINARY8, FpFormat::BFLOAT16, FpFormat::BINARY16, FpFormat::BINARY32]
        {
            let half = pow2(fmt.e_min - fmt.sig_bits as i32);
            let q = fmt.x_min_sub();
            assert_eq!(fmt.floor_ceil(half), (0.0, q), "{}", fmt.name());
            assert_eq!(fmt.floor_ceil(-half), (-q, 0.0), "{}", fmt.name());
            assert!(!fmt.contains(half), "{}", fmt.name());
            assert_eq!(fmt.successor(0.0), q, "{}", fmt.name());
            assert_eq!(fmt.predecessor(q), 0.0, "{}", fmt.name());
            assert_eq!(fmt.successor(-q), 0.0, "{}", fmt.name());
        }
    }

    #[test]
    fn floor_ceil_basic_binary8() {
        let f = FpFormat::BINARY8;
        // In [1, 2) the spacing is 2^{-2} = 0.25.
        assert_eq!(f.floor_ceil(1.1), (1.0, 1.25));
        assert_eq!(f.floor_ceil(1.25), (1.25, 1.25));
        assert_eq!(f.floor_ceil(-1.1), (-1.25, -1.0));
        // In [1024, 2048) the spacing is 2^{10-2} = 256.
        assert_eq!(f.floor_ceil(1030.0), (1024.0, 1280.0));
        assert_eq!(f.floor_ceil(1024.0), (1024.0, 1024.0));
    }

    #[test]
    fn floor_ceil_subnormals() {
        let f = FpFormat::BINARY8;
        let q = f.x_min_sub(); // 2^{-16}
        assert_eq!(q, (2.0f64).powi(-16));
        let x = q * 0.4;
        assert_eq!(f.floor_ceil(x), (0.0, q));
        assert_eq!(f.floor_ceil(-x), (-q, 0.0));
        assert!(f.contains(q));
        assert!(f.contains(3.0 * q));
        assert!(!f.contains(0.5 * q));
    }

    #[test]
    fn floor_ceil_no_subnormals_flushes() {
        let mut f = FpFormat::BINARY8;
        f.subnormals = false;
        let xmin = f.x_min();
        let x = xmin * 0.3;
        assert_eq!(f.floor_ceil(x), (0.0, xmin));
        assert_eq!(f.floor_ceil(-x), (-xmin, 0.0));
        assert!(!f.contains(f.x_min_sub() / 2.0));
    }

    #[test]
    fn floor_ceil_overflow() {
        let f = FpFormat::BINARY8;
        let (lo, hi) = f.floor_ceil(60000.0);
        assert_eq!(lo, 57344.0);
        assert_eq!(hi, f64::INFINITY);
        let (lo, hi) = f.floor_ceil(-60000.0);
        assert_eq!(lo, f64::NEG_INFINITY);
        assert_eq!(hi, -57344.0);
    }

    #[test]
    fn successor_predecessor() {
        let f = FpFormat::BINARY8;
        assert_eq!(f.successor(1.0), 1.25);
        assert_eq!(f.predecessor(1.0), 1.0 - 0.125); // finer binade below 2^0
        assert_eq!(f.predecessor(1.25), 1.0);
        assert_eq!(f.successor(0.0), f.x_min_sub());
        assert_eq!(f.predecessor(0.0), -f.x_min_sub());
        assert_eq!(f.successor(f.x_max()), f64::INFINITY);
        assert_eq!(f.predecessor(-f.x_max()), f64::NEG_INFINITY);
        // su/pr are strict inverses away from the extremes.
        for &x in &[0.25, 1.0, 1.25, 1024.0, -3.5, f.x_min(), -f.x_min(), f.x_min_sub()] {
            assert_eq!(f.predecessor(f.successor(x)), x, "x={x}");
            assert_eq!(f.successor(f.predecessor(x)), x, "x={x}");
        }
    }

    #[test]
    fn contains_agrees_with_floor_ceil() {
        let f = FpFormat::BFLOAT16;
        for &x in &[1.0, 1.0 + f.eps(), 3.14159, -2.5e-3, 1e30, -7.0] {
            let (lo, hi) = f.floor_ceil(x);
            assert!(f.contains(lo) || lo.is_infinite());
            assert!(f.contains(hi) || hi.is_infinite());
            assert_eq!(lo == hi, f.contains(x), "x={x}");
            assert!(lo <= x && x <= hi);
        }
    }

    #[test]
    fn spacing_matches_eps_scaling() {
        let f = FpFormat::BFLOAT16;
        assert_eq!(f.spacing_at(1.0), f.eps());
        assert_eq!(f.spacing_at(1.5), f.eps());
        assert_eq!(f.spacing_at(2.0), 2.0 * f.eps());
        assert_eq!(f.spacing_at(0.75), 0.5 * f.eps());
    }

    /// Quick randomized bit-vs-reference equivalence spot check (the
    /// exhaustive binary8 grid sweep lives in `rust/tests/properties.rs`).
    #[test]
    fn bit_kernels_match_reference_random() {
        use crate::fp::rng::Rng;
        let mut rng = Rng::new(2024);
        for fmt in [
            FpFormat::BINARY8,
            FpFormat::BFLOAT16,
            FpFormat::BINARY16,
            FpFormat::BINARY32,
            FpFormat::BINARY64,
            FpFormat { subnormals: false, ..FpFormat::BINARY8 },
        ] {
            for _ in 0..4000 {
                let e = rng.uniform_in(fmt.e_min as f64 - 6.0, fmt.e_max as f64 + 2.0);
                let m = rng.uniform_in(1.0, 2.0);
                let s = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
                let x = s * m * pow2(e.clamp(-1070.0, 1020.0) as i32);
                let want = reference::floor_ceil(&fmt, x);
                let got = fmt.floor_ceil(x);
                assert_eq!(want, got, "{} floor_ceil({x:e})", fmt.name());
                assert_eq!(
                    reference::contains(&fmt, x),
                    fmt.contains(x),
                    "{} contains({x:e})",
                    fmt.name()
                );
                // Neighbors are format members; successor/predecessor agree
                // with the reference on them. (Skipped for subnormals=false:
                // the reference walks `x + q` out of the flushed zone and can
                // return a non-representable value there — the bit kernel
                // flushes correctly; covered by `floor_ceil_no_subnormals_flushes`.)
                if !fmt.subnormals {
                    continue;
                }
                for v in [got.0, got.1] {
                    if v.is_finite() && v != 0.0 && v.abs() < fmt.x_max() {
                        assert_eq!(
                            reference::successor(&fmt, v),
                            fmt.successor(v),
                            "{} successor({v:e})",
                            fmt.name()
                        );
                        assert_eq!(
                            reference::predecessor(&fmt, v),
                            fmt.predecessor(v),
                            "{} predecessor({v:e})",
                            fmt.name()
                        );
                    }
                }
            }
        }
    }
}
