//! Floating-point *format* descriptions and exact neighbor arithmetic.
//!
//! A format `F(s, e_min, e_max)` is the set of reals `± μ · 2^(e−s+1)` with
//! `μ ∈ [2^(s−1), 2^s)` (normal numbers, exponent `e ∈ [e_min, e_max]`) plus
//! `± μ · 2^(e_min−s+1)` with `μ ∈ [0, 2^(s−1))` (subnormals) — i.e. the
//! classical IEEE-754-style number line with `s` significand bits *including*
//! the implicit bit, exactly the convention of the paper (§2.1, Table 2).
//!
//! Every simulated value is carried as an `f64` that is *exactly* an element
//! of the target format. This works because all formats we simulate have
//! `s ≤ 24 < 53` and exponent ranges inside binary64's, so the embedding
//! 𝔽 ⊂ binary64 is exact (the same trick as Higham & Pranesh's `chop`).


/// A binary floating-point format with `s` significand bits (implicit bit
/// included), exponent range `[e_min, e_max]`, and optional subnormals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FpFormat {
    /// Significand precision in bits, including the implicit leading bit.
    pub sig_bits: u32,
    /// Minimum normalized exponent (value of `e` for the smallest normal).
    pub e_min: i32,
    /// Maximum exponent.
    pub e_max: i32,
    /// Whether subnormal numbers are representable (chop's `subnormal=1`).
    pub subnormals: bool,
}

impl FpFormat {
    /// A format with `sig_bits` significand bits (implicit bit included),
    /// exponent range `[e_min, e_max]` and subnormals enabled.
    pub const fn new(sig_bits: u32, e_min: i32, e_max: i32) -> Self {
        Self { sig_bits, e_min, e_max, subnormals: true }
    }

    /// binary8 in the E5M2 layout (NVIDIA H100 / OCP FP8): 2 stored mantissa
    /// bits, 5 exponent bits. `u = 2^{-3}`, `x_min = 2^{-14} ≈ 6.10e-5`,
    /// `x_max = 1.75 · 2^{15} = 57344 ≈ 5.73e4` — the paper's Table 2 row.
    pub const BINARY8: Self = Self::new(3, -14, 15);
    /// bfloat16: 7 stored mantissa bits, 8 exponent bits. `u = 2^{-8}`.
    pub const BFLOAT16: Self = Self::new(8, -126, 127);
    /// IEEE binary16 (half): `u = 2^{-11}`.
    pub const BINARY16: Self = Self::new(11, -14, 15);
    /// IEEE binary32 (single): `u = 2^{-24}`.
    pub const BINARY32: Self = Self::new(24, -126, 127);
    /// IEEE binary64 (double): `u = 2^{-53}`. Identity for our f64 carrier.
    pub const BINARY64: Self = Self::new(53, -1022, 1023);

    /// Look a preset up by name (CLI / config front-end).
    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "binary8" | "fp8" | "e5m2" | "b8" => Some(Self::BINARY8),
            "bfloat16" | "bf16" => Some(Self::BFLOAT16),
            "binary16" | "fp16" | "half" | "b16" => Some(Self::BINARY16),
            "binary32" | "fp32" | "single" | "b32" => Some(Self::BINARY32),
            "binary64" | "fp64" | "double" | "b64" => Some(Self::BINARY64),
            _ => None,
        }
    }

    /// Canonical name of a preset format ("custom" for anything else).
    pub fn name(&self) -> &'static str {
        match *self {
            Self::BINARY8 => "binary8",
            Self::BFLOAT16 => "bfloat16",
            Self::BINARY16 => "binary16",
            Self::BINARY32 => "binary32",
            Self::BINARY64 => "binary64",
            _ => "custom",
        }
    }

    /// Unit roundoff `u = 2^{-s}` (max relative error of RN on normals).
    #[inline]
    pub fn unit_roundoff(&self) -> f64 {
        pow2(-(self.sig_bits as i32))
    }

    /// Machine epsilon `2u = 2^{1-s}` (spacing of the binade `[1,2)`).
    #[inline]
    pub fn eps(&self) -> f64 {
        2.0 * self.unit_roundoff()
    }

    /// Smallest positive *normalized* number `2^{e_min}`.
    #[inline]
    pub fn x_min(&self) -> f64 {
        pow2(self.e_min)
    }

    /// Smallest positive *subnormal* number `2^{e_min - s + 1}`
    /// (equals `x_min` when subnormals are disabled).
    #[inline]
    pub fn x_min_sub(&self) -> f64 {
        if self.subnormals {
            pow2(self.e_min - self.sig_bits as i32 + 1)
        } else {
            self.x_min()
        }
    }

    /// Largest finite number `(2 - 2^{1-s}) · 2^{e_max}`.
    #[inline]
    pub fn x_max(&self) -> f64 {
        (2.0 - self.eps()) * pow2(self.e_max)
    }

    /// The spacing (ulp) of the format in the binade that contains `x`
    /// (for nonzero finite `x`; the subnormal region has the `e_min` spacing).
    #[inline]
    pub fn spacing_at(&self, x: f64) -> f64 {
        debug_assert!(x.is_finite());
        let e = exponent_of(x.abs()).max(self.e_min);
        pow2(e - self.sig_bits as i32 + 1)
    }

    /// Is `x` exactly an element of this format (finite values only)?
    pub fn contains(&self, x: f64) -> bool {
        if x == 0.0 {
            return true;
        }
        if !x.is_finite() || x.abs() > self.x_max() {
            return false;
        }
        let q = self.spacing_at(x);
        let m = x / q; // exact: division by a power of two
        if m != m.trunc() {
            return false;
        }
        if !self.subnormals && x.abs() < self.x_min() {
            return false;
        }
        true
    }

    /// `⌊x⌋_F = max{ y ∈ F : y ≤ x }` and `⌈x⌉_F = min{ y ∈ F : y ≥ x }`,
    /// computed exactly. Magnitudes beyond `x_max` clamp to `±x_max` on the
    /// inward side and `±∞` on the outward side (chop-style saturation is
    /// applied by the rounding layer, which never returns ±∞ for the
    /// stochastic schemes — see `round.rs`).
    pub fn floor_ceil(&self, x: f64) -> (f64, f64) {
        if x == 0.0 {
            return (0.0, 0.0);
        }
        if x.is_nan() {
            return (f64::NAN, f64::NAN);
        }
        let xmax = self.x_max();
        if x.is_infinite() {
            return if x > 0.0 { (xmax, f64::INFINITY) } else { (f64::NEG_INFINITY, -xmax) };
        }
        if x > xmax {
            return (xmax, f64::INFINITY);
        }
        if x < -xmax {
            return (f64::NEG_INFINITY, -xmax);
        }
        let q = self.spacing_at(x);
        // Exact: x/q has magnitude < 2^s ≤ 2^24, and x is a binary64 value.
        let m = x / q;
        let (lo, hi) = (m.floor() * q, m.ceil() * q);
        if self.subnormals {
            (lo, hi)
        } else {
            // Flush the open subnormal interval (−x_min, x_min) \ {0} to its
            // representable endpoints {−x_min, 0, x_min}.
            let xmin = self.x_min();
            let fix = |v: f64| -> f64 {
                if v != 0.0 && v.abs() < xmin {
                    if v > 0.0 { 0.0 } else { -0.0 }
                } else {
                    v
                }
            };
            let (mut lo2, mut hi2) = (fix(lo), fix(hi));
            // Flushing can collapse both sides to 0 even when x ≠ 0; widen to
            // the true neighbors in that case.
            if lo2 == 0.0 && x < 0.0 && lo != 0.0 {
                lo2 = -xmin;
            }
            if hi2 == 0.0 && x > 0.0 && hi != 0.0 {
                hi2 = xmin;
            }
            (lo2, hi2)
        }
    }

    /// Successor `su(x̂) = min{ ŷ ∈ F : ŷ > x̂ }` for a value already in `F`
    /// (paper eq. (10); strict, unlike `⌈·⌉`).
    pub fn successor(&self, x: f64) -> f64 {
        debug_assert!(self.contains(x), "successor() requires x ∈ F (got {x})");
        if x >= self.x_max() {
            return f64::INFINITY;
        }
        if x == 0.0 {
            return self.x_min_sub();
        }
        let q = self.spacing_at(x);
        if x < 0.0 {
            // Moving toward zero: crossing −2^e into the finer binade.
            let m = x / q;
            if m == -(1i64 << (self.sig_bits - 1)) as f64 && x.abs() > self.x_min() {
                x + q / 2.0
            } else {
                x + q
            }
        } else {
            x + q // may land exactly on 2^{e+1}, which is representable
        }
    }

    /// Predecessor `pr(x̂) = max{ ŷ ∈ F : ŷ < x̂ }` for a value already in `F`.
    pub fn predecessor(&self, x: f64) -> f64 {
        -self.successor(-x)
    }
}

/// Exact `2^e` for any `e ∈ [-1074, 1023]`, built from the binary64 bit
/// pattern. `f64::powi` is *not* exact here: it can evaluate `2^{-1048}` as
/// `1 / 2^{1048} = 1/∞ = 0`, which poisons neighbor arithmetic with NaNs.
#[inline]
pub fn pow2(e: i32) -> f64 {
    if e > 1023 {
        f64::INFINITY
    } else if e >= -1022 {
        f64::from_bits(((e + 1023) as u64) << 52)
    } else if e >= -1074 {
        f64::from_bits(1u64 << (e + 1074))
    } else {
        0.0
    }
}

/// Exponent `e` such that `2^e ≤ |x| < 2^{e+1}`, for finite positive `x`,
/// extracted from the binary64 bit pattern (exact; no `log2` rounding).
#[inline]
pub fn exponent_of(x: f64) -> i32 {
    debug_assert!(x > 0.0 && x.is_finite());
    let bits = x.to_bits();
    let raw = ((bits >> 52) & 0x7ff) as i32;
    if raw == 0 {
        // binary64 subnormal: normalize via the mantissa's leading zero count.
        let mant = bits & ((1u64 << 52) - 1);
        -1022 - (52 - (63 - mant.leading_zeros() as i32))
    } else {
        raw - 1023
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_parameters() {
        // Paper Table 2, reproduced bit-exactly.
        let b8 = FpFormat::BINARY8;
        assert_eq!(b8.unit_roundoff(), 0.125);
        assert!((b8.x_min() - 6.10e-5).abs() / 6.10e-5 < 1e-2);
        assert_eq!(b8.x_max(), 57344.0); // 5.73e4

        let bf16 = FpFormat::BFLOAT16;
        assert_eq!(bf16.unit_roundoff(), (2.0f64).powi(-8));
        assert!((bf16.x_min() - 1.18e-38).abs() / 1.18e-38 < 1e-2);
        assert!((bf16.x_max() - 3.39e38).abs() / 3.39e38 < 1e-2);

        let b16 = FpFormat::BINARY16;
        assert_eq!(b16.unit_roundoff(), (2.0f64).powi(-11));
        assert_eq!(b16.x_max(), 65504.0); // 6.55e4

        let b32 = FpFormat::BINARY32;
        assert_eq!(b32.unit_roundoff(), (2.0f64).powi(-24));
        assert!((b32.x_max() - 3.40e38).abs() / 3.40e38 < 1e-2);

        let b64 = FpFormat::BINARY64;
        assert_eq!(b64.unit_roundoff(), (2.0f64).powi(-53));
        assert!((b64.x_min() - 2.22e-308).abs() / 2.22e-308 < 1e-2);
        assert_eq!(b64.x_max(), f64::MAX); // 1.80e308
    }

    #[test]
    fn exponent_extraction() {
        assert_eq!(exponent_of(1.0), 0);
        assert_eq!(exponent_of(1.5), 0);
        assert_eq!(exponent_of(2.0), 1);
        assert_eq!(exponent_of(0.5), -1);
        assert_eq!(exponent_of(1024.0), 10);
        assert_eq!(exponent_of(1023.9), 9);
        assert_eq!(exponent_of(f64::MIN_POSITIVE), -1022);
        assert_eq!(exponent_of(f64::MIN_POSITIVE / 2.0), -1023);
    }

    #[test]
    fn floor_ceil_basic_binary8() {
        let f = FpFormat::BINARY8;
        // In [1, 2) the spacing is 2^{-2} = 0.25.
        assert_eq!(f.floor_ceil(1.1), (1.0, 1.25));
        assert_eq!(f.floor_ceil(1.25), (1.25, 1.25));
        assert_eq!(f.floor_ceil(-1.1), (-1.25, -1.0));
        // In [1024, 2048) the spacing is 2^{10-2} = 256.
        assert_eq!(f.floor_ceil(1030.0), (1024.0, 1280.0));
        assert_eq!(f.floor_ceil(1024.0), (1024.0, 1024.0));
    }

    #[test]
    fn floor_ceil_subnormals() {
        let f = FpFormat::BINARY8;
        let q = f.x_min_sub(); // 2^{-16}
        assert_eq!(q, (2.0f64).powi(-16));
        let x = q * 0.4;
        assert_eq!(f.floor_ceil(x), (0.0, q));
        assert_eq!(f.floor_ceil(-x), (-q, 0.0));
        assert!(f.contains(q));
        assert!(f.contains(3.0 * q));
        assert!(!f.contains(0.5 * q));
    }

    #[test]
    fn floor_ceil_no_subnormals_flushes() {
        let mut f = FpFormat::BINARY8;
        f.subnormals = false;
        let xmin = f.x_min();
        let x = xmin * 0.3;
        assert_eq!(f.floor_ceil(x), (0.0, xmin));
        assert_eq!(f.floor_ceil(-x), (-xmin, 0.0));
        assert!(!f.contains(f.x_min_sub() / 2.0));
    }

    #[test]
    fn floor_ceil_overflow() {
        let f = FpFormat::BINARY8;
        let (lo, hi) = f.floor_ceil(60000.0);
        assert_eq!(lo, 57344.0);
        assert_eq!(hi, f64::INFINITY);
        let (lo, hi) = f.floor_ceil(-60000.0);
        assert_eq!(lo, f64::NEG_INFINITY);
        assert_eq!(hi, -57344.0);
    }

    #[test]
    fn successor_predecessor() {
        let f = FpFormat::BINARY8;
        assert_eq!(f.successor(1.0), 1.25);
        assert_eq!(f.predecessor(1.0), 1.0 - 0.125); // finer binade below 2^0
        assert_eq!(f.predecessor(1.25), 1.0);
        assert_eq!(f.successor(0.0), f.x_min_sub());
        assert_eq!(f.predecessor(0.0), -f.x_min_sub());
        assert_eq!(f.successor(f.x_max()), f64::INFINITY);
        assert_eq!(f.predecessor(-f.x_max()), f64::NEG_INFINITY);
        // su/pr are strict inverses away from the extremes.
        for &x in &[0.25, 1.0, 1.25, 1024.0, -3.5, f.x_min(), -f.x_min(), f.x_min_sub()] {
            assert_eq!(f.predecessor(f.successor(x)), x, "x={x}");
            assert_eq!(f.successor(f.predecessor(x)), x, "x={x}");
        }
    }

    #[test]
    fn contains_agrees_with_floor_ceil() {
        let f = FpFormat::BFLOAT16;
        for &x in &[1.0, 1.0 + f.eps(), 3.14159, -2.5e-3, 1e30, -7.0] {
            let (lo, hi) = f.floor_ceil(x);
            assert!(f.contains(lo) || lo.is_infinite());
            assert!(f.contains(hi) || hi.is_infinite());
            assert_eq!(lo == hi, f.contains(x), "x={x}");
            assert!(lo <= x && x <= hi);
        }
    }

    #[test]
    fn spacing_matches_eps_scaling() {
        let f = FpFormat::BFLOAT16;
        assert_eq!(f.spacing_at(1.0), f.eps());
        assert_eq!(f.spacing_at(1.5), f.eps());
        assert_eq!(f.spacing_at(2.0), 2.0 * f.eps());
        assert_eq!(f.spacing_at(0.75), 0.5 * f.eps());
    }
}
