//! Runtime-dispatched x86-64 SIMD (AVX2) slice-rounding kernels.
//!
//! The scalar bit-pattern kernels in [`crate::fp::round`] stay the
//! reference implementation and the oracle; this module adds 4-wide AVX2
//! versions of the deterministic and stochastic float slice loops behind
//! **runtime feature detection**. The whole point of the design is that the
//! SIMD path is not a different algorithm — it is the same bit-pattern
//! arithmetic evaluated four elements at a time:
//!
//! * **Deterministic modes** are pure integer mask/compare/add on the f64
//!   bit patterns, so the vector path is trivially **bit-identical** to the
//!   scalar loop.
//! * **Stochastic modes** are *stream-preserving*: random chunks are drawn
//!   from the same [`BitBlock`] in the same element order (only inexact,
//!   eligible elements draw), the probability math is elementwise IEEE
//!   arithmetic (`vmulpd`/`vsubpd` are exact per lane, no FMA, no
//!   reassociation), and any 4-group containing a slow-path element or a
//!   NaN steering value is delegated wholesale to the scalar per-element
//!   body. The SIMD backend therefore produces **bit-identical outputs and
//!   an identical RNG end state** for every mode — no `--stream-change`
//!   gating is needed, and journals/goldens replay exactly regardless of
//!   backend (asserted by the `simd_*` tests in `fp::round`).
//!
//! # Backend selection
//!
//! Priority: explicit [`set_backend`] (the CLI `--simd` flag) > the
//! `LPGD_SIMD` environment variable (`auto` | `avx2` | `scalar`) > runtime
//! `is_x86_feature_detected!("avx2")`. Forcing `avx2` on a CPU without it
//! warns and falls back to scalar rather than crashing. On non-x86-64
//! targets everything resolves to scalar and no `unsafe` is compiled at
//! all. See the feature-detection matrix in `docs/performance.md`.

use std::sync::atomic::{AtomicU8, Ordering};

/// Which kernel backend the process should use (CLI `--simd`, env
/// `LPGD_SIMD`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdChoice {
    /// Detect at runtime: AVX2 when the CPU supports it, scalar otherwise.
    Auto,
    /// Force the AVX2 kernels (warns and falls back to scalar on CPUs
    /// without AVX2 — never crashes).
    Avx2,
    /// Force the scalar reference kernels.
    Scalar,
}

impl SimdChoice {
    /// Parse a `--simd` / `LPGD_SIMD` spelling (case-insensitive).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(SimdChoice::Auto),
            "avx2" => Ok(SimdChoice::Avx2),
            "scalar" => Ok(SimdChoice::Scalar),
            other => {
                Err(format!("unknown SIMD backend '{other}' (expected auto, avx2 or scalar)"))
            }
        }
    }
}

/// Resolved backend, cached for the process: 0 = unresolved, 1 = scalar,
/// 2 = AVX2.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

fn detect_avx2() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn resolve(choice: SimdChoice) -> u8 {
    match choice {
        SimdChoice::Scalar => 1,
        SimdChoice::Avx2 => {
            if detect_avx2() {
                2
            } else {
                eprintln!(
                    "warning: SIMD backend 'avx2' requested but AVX2 is unavailable; \
                     using scalar kernels"
                );
                1
            }
        }
        SimdChoice::Auto => {
            if detect_avx2() {
                2
            } else {
                1
            }
        }
    }
}

/// Pin the kernel backend for the process (the CLI `--simd` flag). Safe to
/// call repeatedly — benches use it to measure both paths; every backend
/// produces bit-identical results, so flipping mid-run changes speed only.
pub fn set_backend(choice: SimdChoice) {
    ACTIVE.store(resolve(choice), Ordering::Relaxed);
}

fn resolved() -> u8 {
    match ACTIVE.load(Ordering::Relaxed) {
        0 => {
            let choice = match std::env::var("LPGD_SIMD") {
                Ok(s) => SimdChoice::parse(&s).unwrap_or_else(|e| {
                    eprintln!("warning: LPGD_SIMD ignored: {e}");
                    SimdChoice::Auto
                }),
                Err(_) => SimdChoice::Auto,
            };
            let r = resolve(choice);
            // A concurrent first resolution computes the same value (the
            // environment is stable), so a plain racy store is benign.
            ACTIVE.store(r, Ordering::Relaxed);
            r
        }
        r => r,
    }
}

/// True when slice kernels should take the AVX2 path.
#[inline]
pub fn avx2_active() -> bool {
    resolved() == 2
}

/// The resolved backend as a label for logs and bench provenance.
pub fn backend_label() -> &'static str {
    if avx2_active() {
        "avx2"
    } else {
        "scalar"
    }
}

/// Serializes tests that flip the process-global backend, so a
/// forced-scalar measurement cannot race a forced-AVX2 one in a sibling
/// test. (Results are bit-identical either way; the lock keeps the tests
/// honest about which path they exercised.)
#[cfg(test)]
pub(crate) static BACKEND_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(target_arch = "x86_64")]
pub(crate) use x86::{round_slice_det_avx2, round_slice_stoch_avx2};

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    use crate::fp::rng::{BitBlock, Rng};
    use crate::fp::round::{RoundPlan, Rounding};

    const SIGN: i64 = i64::MIN;

    /// `max(min(y, 1), 0)` — agrees with the scalar `phi` (`f64::clamp`)
    /// for every finite input; the only divergence is the sign of a zero
    /// result, which cannot change an `r < p` comparison.
    #[inline(always)]
    #[target_feature(enable = "avx2")]
    unsafe fn clamp01(y: __m256d, one: __m256d, zero: __m256d) -> __m256d {
        _mm256_max_pd(_mm256_min_pd(y, one), zero)
    }

    /// Raw-exponent eligibility band `[lo, hi]` (inclusive) of the float
    /// fast path: f64-normal, target-normal, strictly below the top binade —
    /// exactly the scalar gate `raw_e != 0 && raw_e != 0x7ff && e >= e_min
    /// && e < e_max`.
    #[inline(always)]
    fn raw_exp_band(plan: &RoundPlan) -> (i64, i64) {
        let lo = (plan.e_min + 1023).max(1) as i64;
        let hi = (plan.e_max + 1022).min(0x7fe) as i64;
        (lo, hi)
    }

    /// AVX2 deterministic slice kernel over a float grid — bit-identical to
    /// the scalar loop in `RoundPlan::round_slice_det` (pinned by
    /// `simd_det_matches_scalar_bitwise`). `xs.len()` must be a multiple of
    /// 4; the dispatcher rounds down and runs the remainder through the
    /// scalar loop. Ineligible elements (subnormal / overflow / non-finite)
    /// are handed to `slow` in element order; deterministic slow rounding
    /// consumes no randomness, so delegation order is observable only
    /// through exactness, which is preserved.
    ///
    /// # Safety
    /// Requires AVX2; dispatch is gated on runtime detection.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn round_slice_det_avx2(
        plan: &RoundPlan,
        mode: Rounding,
        xs: &mut [f64],
        slow: &mut dyn FnMut(&mut f64),
    ) {
        debug_assert_eq!(xs.len() % 4, 0);
        let rn = mode == Rounding::RoundNearestEven;
        let vsign = _mm256_set1_epi64x(SIGN);
        let vmask = _mm256_set1_epi64x(plan.mask as i64);
        let vhalf = _mm256_set1_epi64x(plan.half as i64);
        let vinc = _mm256_set1_epi64x((plan.mask + 1) as i64);
        let vone = _mm256_set1_epi64x(1);
        let zero = _mm256_setzero_si256();
        let ones = _mm256_set1_epi64x(-1);
        let (lo, hi) = raw_exp_band(plan);
        let vlo = _mm256_set1_epi64x(lo - 1);
        let vhi = _mm256_set1_epi64x(hi + 1);
        let shift_cnt = _mm_cvtsi32_si128(plan.shift as i32);
        for i in (0..xs.len()).step_by(4) {
            let p = xs.as_mut_ptr().add(i);
            let bits = _mm256_loadu_si256(p as *const __m256i);
            let mag = _mm256_andnot_si256(vsign, bits);
            let raw_e = _mm256_srli_epi64::<52>(mag);
            // Signed 64-bit compares are exact here: raw_e ∈ [0, 0x7ff].
            let eligible = _mm256_and_si256(
                _mm256_cmpgt_epi64(raw_e, vlo),
                _mm256_cmpgt_epi64(vhi, raw_e),
            );
            let elig = _mm256_movemask_pd(_mm256_castsi256_pd(eligible));
            let tail = _mm256_and_si256(mag, vmask);
            let exact = _mm256_cmpeq_epi64(tail, zero);
            let process = _mm256_andnot_si256(exact, eligible);
            let lo_mag = _mm256_andnot_si256(vmask, mag);
            let negm = _mm256_cmpgt_epi64(zero, bits);
            // `pick_lo` = keep the magnitude-floor. Derived from the scalar
            // decision `down` by `pick_lo = down ^ neg`, which is sign-free
            // for RN and RZ: RN picks lo iff tail < half (or an even-floor
            // tie), RZ always truncates magnitude, RD/RU fold to ±neg.
            let pick_lo = if rn {
                let lt = _mm256_cmpgt_epi64(vhalf, tail);
                let tie = _mm256_cmpeq_epi64(tail, vhalf);
                let lobit = _mm256_and_si256(_mm256_srl_epi64(lo_mag, shift_cnt), vone);
                let lo_even = _mm256_cmpeq_epi64(lobit, zero);
                _mm256_or_si256(lt, _mm256_and_si256(tie, lo_even))
            } else {
                match mode {
                    Rounding::RoundDown => _mm256_xor_si256(negm, ones),
                    Rounding::RoundUp => negm,
                    _ => ones, // RZ
                }
            };
            let inc = _mm256_andnot_si256(pick_lo, vinc);
            let out_mag = _mm256_add_epi64(lo_mag, inc);
            let out = _mm256_or_si256(out_mag, _mm256_and_si256(bits, vsign));
            let res = _mm256_blendv_pd(
                _mm256_castsi256_pd(bits),
                _mm256_castsi256_pd(out),
                _mm256_castsi256_pd(process),
            );
            _mm256_storeu_pd(p, res);
            if elig != 0b1111 {
                for lane in 0..4 {
                    if elig & (1 << lane) == 0 {
                        slow(&mut xs[i + lane]);
                    }
                }
            }
        }
    }

    /// AVX2 stochastic slice kernel over a float grid — **stream-preserving**
    /// and therefore bit-identical to the scalar loop, RNG end state
    /// included: chunks are drawn from the shared [`BitBlock`] per inexact
    /// eligible element in element order, and any 4-group containing a
    /// slow-path element or a NaN steering value is delegated wholesale to
    /// the scalar per-element body `elem` (the exact loop body of
    /// `RoundPlan::round_slice_stoch`). The vectorized probability math
    /// must mirror the closures in `round_slice` / `round_slice_with`; the
    /// `simd_stoch_matches_scalar_bitwise` test pins this. Requires
    /// `plan.sr_bits <= 52` (the u64→f64 magic conversion below is exact
    /// under 2^52; `k = 53` stays scalar) and a finite `eps`.
    ///
    /// # Safety
    /// Requires AVX2; dispatch is gated on runtime detection.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn round_slice_stoch_avx2(
        plan: &RoundPlan,
        mode: Rounding,
        xs: &mut [f64],
        vs: Option<&[f64]>,
        bsrc: &mut BitBlock,
        rng: &mut Rng,
        elem: &mut dyn FnMut(&mut f64, f64, &mut BitBlock, &mut Rng),
    ) {
        debug_assert_eq!(xs.len() % 4, 0);
        let k = plan.sr_bits;
        debug_assert!(k <= 52);
        let vsign = _mm256_set1_epi64x(SIGN);
        let vmask = _mm256_set1_epi64x(plan.mask as i64);
        let vinc = _mm256_set1_epi64x((plan.mask + 1) as i64);
        let zero = _mm256_setzero_si256();
        let (lo, hi) = raw_exp_band(plan);
        let vlo = _mm256_set1_epi64x(lo - 1);
        let vhi = _mm256_set1_epi64x(hi + 1);
        let magic = _mm256_set1_epi64x(0x4330_0000_0000_0000); // bits of 2^52
        let magic_pd = _mm256_castsi256_pd(magic);
        let vinv_gap = _mm256_set1_pd(plan.inv_gap);
        let vinv_sr = _mm256_set1_pd(plan.inv_sr);
        let onef = _mm256_set1_pd(1.0);
        let zerof = _mm256_setzero_pd();
        let signf = _mm256_castsi256_pd(vsign);
        let eps = match mode {
            Rounding::SrEps(e) | Rounding::SignedSrEps(e) => e,
            _ => 0.0,
        };
        let veps = _mm256_set1_pd(eps);
        let steered = vs.is_some() && matches!(mode, Rounding::SignedSrEps(_));
        for i in (0..xs.len()).step_by(4) {
            let p = xs.as_mut_ptr().add(i);
            let bits = _mm256_loadu_si256(p as *const __m256i);
            let mag = _mm256_andnot_si256(vsign, bits);
            let raw_e = _mm256_srli_epi64::<52>(mag);
            let eligible = _mm256_and_si256(
                _mm256_cmpgt_epi64(raw_e, vlo),
                _mm256_cmpgt_epi64(vhi, raw_e),
            );
            let elig = _mm256_movemask_pd(_mm256_castsi256_pd(eligible));
            let vv = if steered {
                _mm256_loadu_pd(vs.unwrap().as_ptr().add(i))
            } else {
                zerof
            };
            let v_nan =
                steered && _mm256_movemask_pd(_mm256_cmp_pd::<_CMP_UNORD_Q>(vv, vv)) != 0;
            if elig != 0b1111 || v_nan {
                // A slow-path or NaN-steered lane: the whole group runs the
                // scalar reference body so draws interleave in exactly the
                // scalar order.
                for lane in 0..4 {
                    let j = i + lane;
                    let v = vs.map_or(xs[j], |vs| vs[j]);
                    elem(&mut xs[j], v, bsrc, rng);
                }
                continue;
            }
            let tail = _mm256_and_si256(mag, vmask);
            let exact = _mm256_cmpeq_epi64(tail, zero);
            let proc = !_mm256_movemask_pd(_mm256_castsi256_pd(exact)) & 0b1111;
            if proc == 0 {
                continue; // whole group representable: no draws
            }
            // Draw each processed lane's chunk in element order — the same
            // `take` sequence the scalar loop performs.
            let mut ch = [0u64; 4];
            for lane in 0..4 {
                if proc & (1 << lane) != 0 {
                    ch[lane] = bsrc.take(k, rng);
                }
            }
            let chv = _mm256_loadu_si256(ch.as_ptr() as *const __m256i);
            // Exact u64→f64 for values < 2^52: OR into the mantissa of 2^52
            // and subtract 2^52 (also used for the tail, which is < 2^shift
            // ≤ 2^52). Identical to the scalar `as f64` conversion.
            let r = _mm256_mul_pd(
                _mm256_sub_pd(_mm256_castsi256_pd(_mm256_or_si256(chv, magic)), magic_pd),
                vinv_sr,
            );
            let tail_f =
                _mm256_sub_pd(_mm256_castsi256_pd(_mm256_or_si256(tail, magic)), magic_pd);
            let frac_mag = _mm256_mul_pd(tail_f, vinv_gap);
            let negm = _mm256_cmpgt_epi64(zero, bits);
            let negf = _mm256_castsi256_pd(negm);
            let frac = _mm256_blendv_pd(frac_mag, _mm256_sub_pd(onef, frac_mag), negf);
            let omf = _mm256_sub_pd(onef, frac);
            let p_down = match mode {
                Rounding::Sr => omf,
                Rounding::SrEps(_) => {
                    // phi(1 − frac − sign(x)·eps)
                    let se = _mm256_xor_pd(veps, _mm256_and_pd(negf, signf));
                    clamp01(_mm256_sub_pd(omf, se), onef, zerof)
                }
                Rounding::SignedSrEps(_) => {
                    if steered {
                        // phi(1 − frac + sv·eps), sv = 0 when v == 0.
                        let sv_eps = _mm256_xor_pd(veps, _mm256_and_pd(vv, signf));
                        let v_zero = _mm256_cmp_pd::<_CMP_EQ_OQ>(vv, zerof);
                        let b = _mm256_andnot_pd(v_zero, sv_eps);
                        clamp01(_mm256_add_pd(omf, b), onef, zerof)
                    } else {
                        // Unsteered: sv = −1 for negative x, +1 otherwise
                        // (x ≠ 0 on this path — zero is representable).
                        let sv_eps = _mm256_xor_pd(veps, _mm256_and_pd(negf, signf));
                        clamp01(_mm256_add_pd(omf, sv_eps), onef, zerof)
                    }
                }
                _ => unreachable!("deterministic mode in the stochastic kernel"),
            };
            let down = _mm256_castpd_si256(_mm256_cmp_pd::<_CMP_LT_OQ>(r, p_down));
            let pick_lo = _mm256_xor_si256(down, negm);
            let lo_mag = _mm256_andnot_si256(vmask, mag);
            let inc = _mm256_andnot_si256(pick_lo, vinc);
            let out_mag = _mm256_add_epi64(lo_mag, inc);
            let out = _mm256_or_si256(out_mag, _mm256_and_si256(bits, vsign));
            let process = _mm256_andnot_si256(exact, eligible);
            let res = _mm256_blendv_pd(
                _mm256_castsi256_pd(bits),
                _mm256_castsi256_pd(out),
                _mm256_castsi256_pd(process),
            );
            _mm256_storeu_pd(p, res);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choice_parsing_accepts_the_three_backends() {
        assert_eq!(SimdChoice::parse("auto"), Ok(SimdChoice::Auto));
        assert_eq!(SimdChoice::parse("AVX2"), Ok(SimdChoice::Avx2));
        assert_eq!(SimdChoice::parse(" scalar "), Ok(SimdChoice::Scalar));
        let err = SimdChoice::parse("avx512").unwrap_err();
        assert!(err.contains("avx512") && err.contains("scalar"), "{err}");
    }

    #[test]
    fn forcing_scalar_deactivates_avx2() {
        let _guard = BACKEND_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_backend(SimdChoice::Scalar);
        assert!(!avx2_active());
        assert_eq!(backend_label(), "scalar");
        set_backend(SimdChoice::Auto);
        // Auto matches the hardware either way; just exercise the label.
        let _ = backend_label();
    }
}
