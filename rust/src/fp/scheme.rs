//! The open rounding-scheme API: the [`RoundingScheme`] trait, the
//! [`Scheme`] handle, and the [`SchemeRegistry`].
//!
//! The paper studies a *family* of rounding schemes (RN, directed modes,
//! SR, SRε, signed-SRε) under one GD harness, and follow-up work keeps
//! extending the family — fixed-point SR under the PŁ inequality
//! (arXiv:2301.09511), few-random-bit SR variants (arXiv:2504.20634).
//! Historically the family was the closed [`Rounding`] enum, matched in
//! five layers; adding a scheme meant editing all of them. This module
//! opens the family:
//!
//! * [`RoundingScheme`] is the scheme *law*: the scalar rounding rule
//!   `round(plan, x, v, rng)`, the closed-form bias oracle
//!   [`RoundingScheme::expected_round`], and the metadata
//!   (`is_stochastic`, `bits_per_element`, `label`) the harness needs.
//! * [`Scheme`] is a `Copy` handle (`&'static dyn RoundingScheme` plus a
//!   cached [`RoundingScheme::as_builtin`] tag) that flows through configs
//!   and kernels. Built-in schemes resolve through the tag to the same
//!   monomorphized fused slice kernels as before — **bit-identical
//!   trajectories** — while user schemes take a dyn per-element fallback.
//! * [`SchemeRegistry`] maps spec strings (`"rn"`, `"sr"`,
//!   `"sr_eps:0.25"`, …) to schemes, lists every registered scheme for
//!   CLI help and error messages, and accepts new schemes at runtime via
//!   [`SchemeRegistry::register`].
//!
//! The old [`Rounding`] enum remains as a thin deprecated shim: it
//! converts into a [`Scheme`] (`Rounding::scheme()` / `From`), and
//! `Rounding::parse` is a registry lookup restricted to built-ins.
//! See `docs/api.md` for the front-door walkthrough and migration table.

use std::collections::HashMap;
use std::fmt;
use std::sync::{Mutex, OnceLock, RwLock};

use super::grid::Grid;
use super::rng::Rng;
use super::round::{self, RoundPlan, Rounding};

/// One rounding scheme: the scalar law plus the metadata the GD harness,
/// the bias oracle (Figure 1) and the conformance suite consume.
///
/// # Contract
///
/// * [`RoundingScheme::round`] must return a value representable in
///   `plan.grid` (or NaN for NaN input); the conformance suite
///   (`rust/tests/scheme_conformance.rs`) checks outputs are (saturated)
///   neighbors of the input — on floating-point *and* fixed-point grids.
/// * [`RoundingScheme::expected_round`] must be the exact closed-form mean
///   of `round` (it is checked against the empirical mean).
/// * Deterministic schemes (`is_stochastic() == false`) must not consume
///   randomness.
/// * Implementations registered with [`SchemeRegistry::register`] must be
///   `'static` (typically a `static` unit/tuple struct).
pub trait RoundingScheme: Sync + Send {
    /// Canonical spec string, re-parseable by [`SchemeRegistry::lookup`]
    /// (e.g. `"sr_eps:0.25"`).
    fn name(&self) -> String;

    /// Human-readable label for reports (e.g. `"SR_eps(0.25)"`). Defaults
    /// to [`RoundingScheme::name`].
    fn label(&self) -> String {
        self.name()
    }

    /// Does the scheme consume randomness?
    fn is_stochastic(&self) -> bool;

    /// Does the scalar law read the steering value `v` (as signed-SRε
    /// does)? Steered schemes receive per-element steering vectors from
    /// the GD engine; unsteered ones get `v = x`. Defaults to `false`.
    fn uses_steering(&self) -> bool {
        false
    }

    /// Random bits consumed per inexact element on the slice path.
    /// Default: 0 for deterministic schemes; `plan.sr_bits()` for
    /// stochastic *built-ins* (they run the fused few-random-bits
    /// kernels); 64 for stochastic custom schemes, whose per-element dyn
    /// fallback typically draws one full word per inexact rounding
    /// (`Rng::uniform`). Override when your law consumes differently.
    fn bits_per_element(&self, plan: &RoundPlan) -> u32 {
        if !self.is_stochastic() {
            0
        } else if self.as_builtin().is_some() {
            plan.sr_bits()
        } else {
            64
        }
    }

    /// The scalar rounding law: round `x` into `plan.grid`, steering by
    /// `v` where applicable, drawing randomness from `rng`. A law written
    /// against the [`crate::fp::grid::NumberGrid`] surface (neighbors,
    /// residual, saturation bounds) runs unchanged on both backends.
    fn round(&self, plan: &RoundPlan, x: f64, v: f64, rng: &mut Rng) -> f64;

    /// Closed-form expectation `E[fl(x)]` under this scheme on `grid` —
    /// the bias oracle used by Figure 1 and the conformance suite.
    fn expected_round(&self, grid: &Grid, x: f64, v: f64) -> f64;

    /// The built-in [`Rounding`] mode this scheme is, if any. Built-in
    /// schemes return `Some`, which routes every slice entry point to the
    /// monomorphized fused kernels of [`RoundPlan`] (bit-identical to the
    /// pre-trait paths); user schemes keep the default `None` and take
    /// the dyn per-element fallback.
    fn as_builtin(&self) -> Option<Rounding> {
        None
    }
}

/// A copyable handle to a registered rounding scheme — the type that flows
/// through [`crate::gd::PolicyMap`], [`crate::fp::LpCtx`] and the fused
/// kernels. Obtain one from [`SchemeRegistry::lookup`], the named
/// constructors ([`Scheme::rn`], [`Scheme::sr`], [`Scheme::sr_eps`], …) or
/// a legacy [`Rounding`] via `From`.
#[derive(Clone, Copy)]
pub struct Scheme {
    imp: &'static dyn RoundingScheme,
    /// Cached `imp.as_builtin()` so hot paths dispatch without a virtual
    /// call.
    builtin: Option<Rounding>,
}

impl Scheme {
    /// Wrap a `'static` scheme implementation.
    pub fn from_impl(imp: &'static dyn RoundingScheme) -> Self {
        Scheme { builtin: imp.as_builtin(), imp }
    }

    /// Round-to-nearest, ties to even (the paper's RN).
    pub fn rn() -> Self {
        Self::from_impl(&RnScheme)
    }

    /// Round toward −∞.
    pub fn rd() -> Self {
        Self::from_impl(&RdScheme)
    }

    /// Round toward +∞.
    pub fn ru() -> Self {
        Self::from_impl(&RuScheme)
    }

    /// Round toward zero.
    pub fn rz() -> Self {
        Self::from_impl(&RzScheme)
    }

    /// Unbiased stochastic rounding (Definition 1).
    pub fn sr() -> Self {
        Self::from_impl(&SrScheme)
    }

    /// ε-biased stochastic rounding (Definition 2), bias away from zero.
    pub fn sr_eps(eps: f64) -> Self {
        intern(1, eps, || Box::new(SrEpsScheme(eps)))
    }

    /// Signed ε-biased stochastic rounding (Definition 3), bias steered by
    /// the per-element value `v` (the gradient entry in GD).
    pub fn signed_sr_eps(eps: f64) -> Self {
        intern(2, eps, || Box::new(SignedSrEpsScheme(eps)))
    }

    /// Parse a spec string through the registry (`"sr"`, `"sr_eps:0.4"`,
    /// any registered custom name). Shorthand for
    /// [`SchemeRegistry::lookup`].
    pub fn parse(spec: &str) -> Result<Self, SchemeError> {
        SchemeRegistry::lookup(spec)
    }

    /// The underlying trait implementation.
    pub fn as_impl(&self) -> &'static dyn RoundingScheme {
        self.imp
    }

    /// The built-in [`Rounding`] mode, if this scheme is one (cached; no
    /// virtual call).
    #[inline]
    pub fn as_builtin(&self) -> Option<Rounding> {
        self.builtin
    }

    /// Canonical spec string (see [`RoundingScheme::name`]).
    pub fn name(&self) -> String {
        self.imp.name()
    }

    /// Human-readable label (see [`RoundingScheme::label`]).
    pub fn label(&self) -> String {
        self.imp.label()
    }

    /// Does the scheme consume randomness?
    #[inline]
    pub fn is_stochastic(&self) -> bool {
        match self.builtin {
            Some(m) => m.is_stochastic(),
            None => self.imp.is_stochastic(),
        }
    }

    /// Does the scalar law read the steering value `v`?
    #[inline]
    pub fn uses_steering(&self) -> bool {
        match self.builtin {
            Some(m) => matches!(m, Rounding::SignedSrEps(_)),
            None => self.imp.uses_steering(),
        }
    }

    /// Random bits per inexact element on the fused slice path.
    pub fn bits_per_element(&self, plan: &RoundPlan) -> u32 {
        self.imp.bits_per_element(plan)
    }

    /// Scalar rounding with steering — dispatches to the monomorphized
    /// built-in path or the dyn law (see [`RoundPlan::round_scheme_with`]).
    #[inline]
    pub fn round_with(&self, plan: &RoundPlan, x: f64, v: f64, rng: &mut Rng) -> f64 {
        plan.round_scheme_with(*self, x, v, rng)
    }

    /// Scalar rounding with `v = x`.
    #[inline]
    pub fn round(&self, plan: &RoundPlan, x: f64, rng: &mut Rng) -> f64 {
        plan.round_scheme_with(*self, x, x, rng)
    }

    /// Closed-form expectation `E[fl(x)]` on `grid` (an [`crate::fp::FpFormat`],
    /// [`crate::fp::FixedPoint`] or [`Grid`]) — see
    /// [`RoundingScheme::expected_round`].
    pub fn expected_round(&self, grid: impl Into<Grid>, x: f64, v: f64) -> f64 {
        let grid = grid.into();
        match self.builtin {
            Some(m) => round::expected_round(grid, m, x, v),
            None => self.imp.expected_round(&grid, x, v),
        }
    }
}

impl fmt::Debug for Scheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Scheme({})", self.imp.name())
    }
}

impl PartialEq for Scheme {
    fn eq(&self, other: &Self) -> bool {
        match (self.builtin, other.builtin) {
            (Some(a), Some(b)) => a == b,
            (None, None) => {
                // Thin-pointer identity: custom schemes are registered
                // statics (or interned leaks), so one instance == one law.
                std::ptr::eq(
                    self.imp as *const dyn RoundingScheme as *const u8,
                    other.imp as *const dyn RoundingScheme as *const u8,
                )
            }
            _ => false,
        }
    }
}

impl From<Rounding> for Scheme {
    fn from(mode: Rounding) -> Self {
        match mode {
            Rounding::RoundNearestEven => Scheme::rn(),
            Rounding::RoundDown => Scheme::rd(),
            Rounding::RoundUp => Scheme::ru(),
            Rounding::RoundTowardZero => Scheme::rz(),
            Rounding::Sr => Scheme::sr(),
            Rounding::SrEps(e) => Scheme::sr_eps(e),
            Rounding::SignedSrEps(e) => Scheme::signed_sr_eps(e),
        }
    }
}

// ------------------------------------------------------------ built-ins --

macro_rules! builtin_scheme {
    ($(#[$doc:meta])* $ty:ident, $name:expr, $mode:expr, $stochastic:expr) => {
        $(#[$doc])*
        pub struct $ty;

        impl RoundingScheme for $ty {
            fn name(&self) -> String {
                $name.into()
            }
            fn label(&self) -> String {
                $mode.label()
            }
            fn is_stochastic(&self) -> bool {
                $stochastic
            }
            fn round(&self, plan: &RoundPlan, x: f64, v: f64, rng: &mut Rng) -> f64 {
                plan.round_with($mode, x, v, rng)
            }
            fn expected_round(&self, grid: &Grid, x: f64, v: f64) -> f64 {
                round::expected_round(grid, $mode, x, v)
            }
            fn as_builtin(&self) -> Option<Rounding> {
                Some($mode)
            }
        }
    };
}

builtin_scheme!(
    /// Round-to-nearest, ties to even, as a registered scheme.
    RnScheme,
    "rn",
    Rounding::RoundNearestEven,
    false
);
builtin_scheme!(
    /// Round toward −∞ as a registered scheme.
    RdScheme,
    "rd",
    Rounding::RoundDown,
    false
);
builtin_scheme!(
    /// Round toward +∞ as a registered scheme.
    RuScheme,
    "ru",
    Rounding::RoundUp,
    false
);
builtin_scheme!(
    /// Round toward zero as a registered scheme.
    RzScheme,
    "rz",
    Rounding::RoundTowardZero,
    false
);
builtin_scheme!(
    /// Unbiased stochastic rounding (Definition 1) as a registered scheme.
    SrScheme,
    "sr",
    Rounding::Sr,
    true
);

/// ε-biased stochastic rounding (Definition 2) as a registered scheme.
pub struct SrEpsScheme(
    /// The ε bias parameter (the paper's ε ∈ [0, ½]).
    pub f64,
);

impl RoundingScheme for SrEpsScheme {
    fn name(&self) -> String {
        format!("sr_eps:{}", self.0)
    }
    fn label(&self) -> String {
        Rounding::SrEps(self.0).label()
    }
    fn is_stochastic(&self) -> bool {
        true
    }
    fn round(&self, plan: &RoundPlan, x: f64, v: f64, rng: &mut Rng) -> f64 {
        plan.round_with(Rounding::SrEps(self.0), x, v, rng)
    }
    fn expected_round(&self, grid: &Grid, x: f64, v: f64) -> f64 {
        round::expected_round(grid, Rounding::SrEps(self.0), x, v)
    }
    fn as_builtin(&self) -> Option<Rounding> {
        Some(Rounding::SrEps(self.0))
    }
}

/// Signed ε-biased stochastic rounding (Definition 3) as a registered
/// scheme; the bias direction is steered per element.
pub struct SignedSrEpsScheme(
    /// The ε bias parameter (the paper's ε ∈ [0, ½]).
    pub f64,
);

impl RoundingScheme for SignedSrEpsScheme {
    fn name(&self) -> String {
        format!("signed_sr_eps:{}", self.0)
    }
    fn label(&self) -> String {
        Rounding::SignedSrEps(self.0).label()
    }
    fn is_stochastic(&self) -> bool {
        true
    }
    fn uses_steering(&self) -> bool {
        true
    }
    fn round(&self, plan: &RoundPlan, x: f64, v: f64, rng: &mut Rng) -> f64 {
        plan.round_with(Rounding::SignedSrEps(self.0), x, v, rng)
    }
    fn expected_round(&self, grid: &Grid, x: f64, v: f64) -> f64 {
        round::expected_round(grid, Rounding::SignedSrEps(self.0), x, v)
    }
    fn as_builtin(&self) -> Option<Rounding> {
        Some(Rounding::SignedSrEps(self.0))
    }
}

/// Intern table for parameterized built-in instances: one leaked instance
/// per distinct `(family, ε)`, so `Scheme` handles stay `Copy` and repeated
/// lookups of the same spec return the same `'static` reference.
fn intern(
    family: u8,
    eps: f64,
    make: impl FnOnce() -> Box<dyn RoundingScheme>,
) -> Scheme {
    static TABLE: OnceLock<Mutex<HashMap<(u8, u64), &'static dyn RoundingScheme>>> =
        OnceLock::new();
    let table = TABLE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = table.lock().unwrap();
    let imp = *map
        .entry((family, eps.to_bits()))
        .or_insert_with(|| Box::leak(make()));
    Scheme::from_impl(imp)
}

// ------------------------------------------------------------- registry --

/// Errors from scheme parsing, registration and the [`crate::gd::RunBuilder`].
#[derive(Debug, Clone, PartialEq)]
pub enum SchemeError {
    /// The spec named no registered scheme; carries the registered names.
    UnknownScheme {
        /// The spec string as given.
        given: String,
        /// Comma-separated registered scheme names.
        known: String,
    },
    /// The scheme exists but its `:ε` parameter did not parse.
    BadParam {
        /// The scheme family name.
        family: String,
        /// The unparseable parameter text.
        given: String,
    },
    /// [`SchemeRegistry::register`] was given an already-taken or invalid
    /// name.
    BadRegistration(String),
    /// The spec resolved to a registered scheme that is not expressible as
    /// the legacy [`Rounding`] enum (raised only by `Rounding::parse`).
    NotBuiltin(String),
    /// An unknown number-format / grid spec (raised by the run builder).
    UnknownFormat(String),
    /// A malformed optimizer / policy / LR-schedule spec; carries the full
    /// human-readable diagnostic.
    BadSpec(String),
}

impl fmt::Display for SchemeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SchemeError::UnknownScheme { given, known } => {
                write!(f, "unknown rounding scheme '{given}' (registered schemes: {known})")
            }
            SchemeError::BadParam { family, given } => {
                write!(f, "bad parameter '{given}' for scheme '{family}' (expected '{family}:<eps>', e.g. '{family}:0.25')")
            }
            SchemeError::BadRegistration(msg) => write!(f, "scheme registration rejected: {msg}"),
            SchemeError::NotBuiltin(name) => {
                write!(f, "scheme '{name}' is registered but is not a built-in `Rounding` mode; use `SchemeRegistry::lookup` / the run builder instead of `Rounding::parse`")
            }
            SchemeError::UnknownFormat(name) => {
                write!(f, "unknown number format '{name}' (known: binary8, bfloat16, binary16, binary32, binary64, or a fixed-point spec like 'q3.8' / 'uq4.8' / 'fixed:Q3.8')")
            }
            SchemeError::BadSpec(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for SchemeError {}

/// Default ε for `sr_eps` / `signed_sr_eps` specs given without a
/// parameter (the mid-range value used throughout the repo's tests).
pub const DEFAULT_EPS: f64 = 0.25;

/// A built-in scheme family the registry can instantiate from a spec.
struct Family {
    /// Canonical name (what error messages and `--help` list).
    name: &'static str,
    /// Accepted aliases (legacy spellings kept parseable).
    aliases: &'static [&'static str],
    /// Does the family take a `:ε` parameter?
    takes_param: bool,
    /// One-line description for `--help`.
    summary: &'static str,
    /// Instantiate; `None` means no parameter was given.
    build: fn(Option<f64>) -> Scheme,
}

const FAMILIES: &[Family] = &[
    Family {
        name: "rn",
        aliases: &[],
        takes_param: false,
        summary: "round-to-nearest, ties to even (IEEE default; stagnates, Fig. 2)",
        build: |_| Scheme::rn(),
    },
    Family {
        name: "rd",
        aliases: &[],
        takes_param: false,
        summary: "round toward -inf",
        build: |_| Scheme::rd(),
    },
    Family {
        name: "ru",
        aliases: &[],
        takes_param: false,
        summary: "round toward +inf",
        build: |_| Scheme::ru(),
    },
    Family {
        name: "rz",
        aliases: &[],
        takes_param: false,
        summary: "round toward zero",
        build: |_| Scheme::rz(),
    },
    Family {
        name: "sr",
        aliases: &[],
        takes_param: false,
        summary: "unbiased stochastic rounding (Definition 1)",
        build: |_| Scheme::sr(),
    },
    Family {
        name: "sr_eps",
        aliases: &["sreps"],
        takes_param: true,
        summary: "eps-biased stochastic rounding, bias away from zero (Definition 2)",
        build: |p| Scheme::sr_eps(p.unwrap_or(DEFAULT_EPS)),
    },
    Family {
        name: "signed_sr_eps",
        aliases: &["signed", "signed-sr_eps"],
        takes_param: true,
        summary: "signed eps-biased stochastic rounding, bias steered per element (Definition 3)",
        build: |p| Scheme::signed_sr_eps(p.unwrap_or(DEFAULT_EPS)),
    },
];

fn custom_registry() -> &'static RwLock<Vec<&'static dyn RoundingScheme>> {
    static CUSTOM: OnceLock<RwLock<Vec<&'static dyn RoundingScheme>>> = OnceLock::new();
    CUSTOM.get_or_init(|| RwLock::new(Vec::new()))
}

fn unknown(spec: &str) -> SchemeError {
    SchemeError::UnknownScheme {
        given: spec.trim().to_string(),
        known: SchemeRegistry::names().join(", "),
    }
}

/// The process-wide scheme registry: every built-in family plus any scheme
/// added through [`SchemeRegistry::register`]. Spec strings are
/// case-insensitive and whitespace-trimmed.
pub struct SchemeRegistry;

impl SchemeRegistry {
    /// Resolve a spec string to a scheme: a built-in family (optionally
    /// parameterized, `"sr_eps:0.4"`), a legacy alias (`"signed:0.1"`),
    /// or the exact name of a registered custom scheme.
    pub fn lookup(spec: &str) -> Result<Scheme, SchemeError> {
        let s = spec.trim().to_ascii_lowercase();
        if s.is_empty() {
            return Err(unknown(spec));
        }
        // Custom schemes match on their exact registered name.
        for imp in custom_registry().read().unwrap().iter() {
            if imp.name().to_ascii_lowercase() == s {
                return Ok(Scheme::from_impl(*imp));
            }
        }
        let (fam_name, param) = match s.split_once(':') {
            Some((f, p)) => (f, Some(p)),
            None => (s.as_str(), None),
        };
        let fam = FAMILIES
            .iter()
            .find(|f| f.name == fam_name || f.aliases.contains(&fam_name))
            .ok_or_else(|| unknown(spec))?;
        let param = match param {
            None => None,
            Some(p) if fam.takes_param => Some(p.parse::<f64>().map_err(|_| {
                SchemeError::BadParam { family: fam.name.into(), given: p.into() }
            })?),
            Some(_) => return Err(unknown(spec)), // e.g. "rn:0.5"
        };
        Ok((fam.build)(param))
    }

    /// Register a custom scheme under its [`RoundingScheme::name`]. The
    /// name must be non-empty, contain no `':'`, and collide with no
    /// built-in family, alias, or previously registered scheme.
    pub fn register(imp: &'static dyn RoundingScheme) -> Result<(), SchemeError> {
        let name = imp.name().trim().to_ascii_lowercase();
        if name.is_empty() || name.contains(':') {
            return Err(SchemeError::BadRegistration(format!(
                "invalid scheme name '{name}' (must be non-empty, no ':')"
            )));
        }
        if FAMILIES.iter().any(|f| f.name == name || f.aliases.contains(&name.as_str())) {
            return Err(SchemeError::BadRegistration(format!(
                "name '{name}' collides with a built-in scheme"
            )));
        }
        let mut custom = custom_registry().write().unwrap();
        if custom.iter().any(|c| c.name().to_ascii_lowercase() == name) {
            return Err(SchemeError::BadRegistration(format!(
                "name '{name}' is already registered"
            )));
        }
        custom.push(imp);
        Ok(())
    }

    /// Registered scheme names with parameter hints, built-ins first —
    /// what `--help` and the unknown-scheme error list.
    pub fn names() -> Vec<String> {
        let mut out: Vec<String> = FAMILIES
            .iter()
            .map(|f| if f.takes_param { format!("{}[:eps]", f.name) } else { f.name.into() })
            .collect();
        out.extend(custom_registry().read().unwrap().iter().map(|c| c.name()));
        out
    }

    /// `(name-with-hint, aliases, summary)` rows for every registered
    /// scheme — the `--help` listing.
    pub fn entries() -> Vec<(String, String, String)> {
        let mut out: Vec<(String, String, String)> = FAMILIES
            .iter()
            .map(|f| {
                let name =
                    if f.takes_param { format!("{}[:eps]", f.name) } else { f.name.to_string() };
                (name, f.aliases.join(", "), f.summary.to_string())
            })
            .collect();
        out.extend(
            custom_registry()
                .read()
                .unwrap()
                .iter()
                .map(|c| (c.name(), String::new(), format!("custom scheme ({})", c.label()))),
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::format::FpFormat;

    #[test]
    fn lookup_builtins_and_aliases() {
        for (spec, mode) in [
            ("rn", Rounding::RoundNearestEven),
            ("RD", Rounding::RoundDown),
            ("ru", Rounding::RoundUp),
            ("rz", Rounding::RoundTowardZero),
            (" sr ", Rounding::Sr),
            ("sr_eps:0.1", Rounding::SrEps(0.1)),
            ("SREPS:0.1", Rounding::SrEps(0.1)),
            ("signed:0.4", Rounding::SignedSrEps(0.4)),
            ("signed-sr_eps:0.4", Rounding::SignedSrEps(0.4)),
            ("signed_sr_eps:0.4", Rounding::SignedSrEps(0.4)),
        ] {
            let s = SchemeRegistry::lookup(spec).unwrap();
            assert_eq!(s.as_builtin(), Some(mode), "{spec}");
        }
        // Parameterized families without a parameter use the default ε.
        assert_eq!(
            SchemeRegistry::lookup("sr_eps").unwrap().as_builtin(),
            Some(Rounding::SrEps(DEFAULT_EPS))
        );
    }

    #[test]
    fn lookup_errors_are_descriptive() {
        let e = SchemeRegistry::lookup("bogus").unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("bogus") && msg.contains("sr_eps"), "{msg}");
        assert!(matches!(
            SchemeRegistry::lookup("sr_eps:xyz").unwrap_err(),
            SchemeError::BadParam { .. }
        ));
        // Parameter on a parameterless family is unknown, not a panic.
        assert!(SchemeRegistry::lookup("rn:0.5").is_err());
        assert!(SchemeRegistry::lookup("").is_err());
    }

    #[test]
    fn interning_is_stable_and_eq_works() {
        let a = Scheme::sr_eps(0.25);
        let b = SchemeRegistry::lookup("sr_eps:0.25").unwrap();
        assert_eq!(a, b);
        assert!(std::ptr::eq(
            a.as_impl() as *const dyn RoundingScheme as *const u8,
            b.as_impl() as *const dyn RoundingScheme as *const u8
        ));
        assert_ne!(Scheme::sr_eps(0.25), Scheme::sr_eps(0.1));
        assert_ne!(Scheme::sr(), Scheme::rn());
        assert_eq!(Scheme::from(Rounding::Sr), Scheme::sr());
    }

    #[test]
    fn names_roundtrip_through_lookup() {
        for scheme in [
            Scheme::rn(),
            Scheme::rd(),
            Scheme::ru(),
            Scheme::rz(),
            Scheme::sr(),
            Scheme::sr_eps(0.3),
            Scheme::signed_sr_eps(0.15),
        ] {
            let again = SchemeRegistry::lookup(&scheme.name()).unwrap();
            assert_eq!(scheme, again, "{}", scheme.name());
        }
    }

    #[test]
    fn metadata_matches_the_enum() {
        assert!(!Scheme::rn().is_stochastic());
        assert!(Scheme::sr().is_stochastic());
        assert!(!Scheme::sr().uses_steering());
        assert!(Scheme::signed_sr_eps(0.1).uses_steering());
        assert_eq!(Scheme::sr_eps(0.1).label(), Rounding::SrEps(0.1).label());
        let plan = RoundPlan::new(FpFormat::BINARY8);
        assert_eq!(Scheme::rn().bits_per_element(&plan), 0);
        assert_eq!(Scheme::sr().bits_per_element(&plan), plan.sr_bits());
    }

    #[test]
    fn register_rejects_collisions() {
        struct Dup;
        impl RoundingScheme for Dup {
            fn name(&self) -> String {
                "sr".into()
            }
            fn is_stochastic(&self) -> bool {
                false
            }
            fn round(&self, _: &RoundPlan, x: f64, _: f64, _: &mut Rng) -> f64 {
                x
            }
            fn expected_round(&self, _: &Grid, x: f64, _: f64) -> f64 {
                x
            }
        }
        static DUP: Dup = Dup;
        assert!(matches!(
            SchemeRegistry::register(&DUP),
            Err(SchemeError::BadRegistration(_))
        ));
    }

    #[test]
    fn custom_scheme_registers_and_resolves() {
        /// Always-floor test scheme (deterministic, trivially conformant).
        struct AlwaysDown;
        impl RoundingScheme for AlwaysDown {
            fn name(&self) -> String {
                "unit_test_down".into()
            }
            fn is_stochastic(&self) -> bool {
                false
            }
            fn round(&self, plan: &RoundPlan, x: f64, v: f64, rng: &mut Rng) -> f64 {
                plan.round_with(Rounding::RoundDown, x, v, rng)
            }
            fn expected_round(&self, grid: &Grid, x: f64, v: f64) -> f64 {
                round::expected_round(grid, Rounding::RoundDown, x, v)
            }
        }
        static DOWN: AlwaysDown = AlwaysDown;
        // Idempotent across test orderings within the process.
        let _ = SchemeRegistry::register(&DOWN);
        let s = SchemeRegistry::lookup("unit_test_down").unwrap();
        assert_eq!(s.as_builtin(), None);
        assert!(SchemeRegistry::names().iter().any(|n| n == "unit_test_down"));
        let plan = RoundPlan::new(FpFormat::BINARY8);
        let mut rng = Rng::new(0);
        assert_eq!(s.round(&plan, 1.1, &mut rng), 1.0);
        // Second registration under the same name is rejected.
        assert!(SchemeRegistry::register(&DOWN).is_err());
    }
}
