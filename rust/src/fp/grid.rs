//! Number *grids*: the set of representable values a rounding scheme maps
//! into, abstracted over the number system.
//!
//! The paper's analysis is floating-point, but its companion work ("On the
//! Convergence of the Gradient Descent Method with Stochastic Fixed-point
//! Rounding Errors under the Polyak–Łojasiewicz Inequality",
//! arXiv:2301.09511) runs the same bias-in-a-descent-direction story on
//! *fixed-point* grids, and "Stochastic Rounding 2.0" (arXiv:2410.10517)
//! frames SR as a general grid-quantization tool. Every rounding law in
//! this repo only ever needs four things from the number system:
//!
//! 1. the neighbor pair `(⌊x⌋, ⌈x⌉)` of an arbitrary real `x`,
//! 2. the residual `(x − ⌊x⌋)/(⌈x⌉ − ⌊x⌋)` driving the stochastic laws,
//! 3. strict successor/predecessor for stagnation analysis, and
//! 4. the saturation bounds `[min, max]`.
//!
//! [`NumberGrid`] captures exactly that contract; [`crate::fp::FpFormat`]
//! (non-uniform, binade-scaled spacing) and [`FixedPoint`] (uniform spacing
//! `δ = 2^{−f}`) both implement it, and the `Copy`-able [`Grid`] enum is
//! the closed dispatch handle that [`crate::fp::round::RoundPlan`], the
//! fused slice kernels, [`crate::fp::LpCtx`] and the GD engine carry —
//! so every registered [`crate::fp::scheme::RoundingScheme`] runs
//! unchanged on either backend. The uniform fixed-point grid gets a fast
//! integer-quantization rounding path (scale, `floor`, exact residual)
//! instead of the float backend's bit-pattern kernels — see
//! `docs/fixed-point.md` for the grid definition, the saturation contract
//! and the mapping to the companion paper's notation.

use super::format::{pow2, FpFormat};

/// The operations a rounding scheme needs from a number system: neighbor
/// arithmetic, residuals, membership and saturation bounds.
///
/// # Contract
///
/// * `floor_ceil(x)` returns `(max{y ∈ G : y ≤ x}, min{y ∈ G : y ≥ x})`,
///   with the out-of-range sides reported as `±∞` (e.g. `x > max` yields
///   `(max, +∞)`); both components equal `x` iff `x ∈ G`. NaN propagates.
/// * `successor`/`predecessor` are *strict* and require `x ∈ G`.
/// * `min_value()`/`max_value()` are the finite saturation endpoints the
///   stochastic schemes clamp to (the deterministic overflow rule is
///   backend-specific: floats overflow to `±∞` under RN past the IEEE
///   threshold, fixed-point always saturates — see `docs/fixed-point.md`).
pub trait NumberGrid {
    /// `(⌊x⌋_G, ⌈x⌉_G)` — see the trait-level contract.
    fn floor_ceil(&self, x: f64) -> (f64, f64);
    /// Is `x` exactly an element of the grid (finite values only)?
    fn contains(&self, x: f64) -> bool;
    /// Strict successor `min{y ∈ G : y > x}` for `x ∈ G` (`+∞` past `max`).
    fn successor(&self, x: f64) -> f64;
    /// Strict predecessor `max{y ∈ G : y < x}` for `x ∈ G`.
    fn predecessor(&self, x: f64) -> f64;
    /// Most negative finite grid point (the lower saturation endpoint).
    fn min_value(&self) -> f64;
    /// Largest finite grid point (the upper saturation endpoint).
    fn max_value(&self) -> f64;
    /// Short human-readable name (`"binary8"`, `"Q3.8"`, …).
    fn label(&self) -> String;
    /// The residual `(x − ⌊x⌋)/(⌈x⌉ − ⌊x⌋) ∈ [0, 1)` that drives the
    /// stochastic rounding laws; `0` when `x ∈ G`.
    fn residual(&self, x: f64) -> f64 {
        let (lo, hi) = self.floor_ceil(x);
        if lo == hi {
            0.0
        } else {
            (x - lo) / (hi - lo)
        }
    }

    /// Clamp to the finite grid range `[min_value, max_value]` — the
    /// saturation every stochastic scheme applies to out-of-range
    /// neighbors (NaN passes through, as `f64::clamp` keeps it). Custom
    /// schemes should use this instead of re-deriving the clamp.
    fn saturate(&self, x: f64) -> f64 {
        x.clamp(self.min_value(), self.max_value())
    }
}

impl NumberGrid for FpFormat {
    fn floor_ceil(&self, x: f64) -> (f64, f64) {
        FpFormat::floor_ceil(self, x)
    }
    fn contains(&self, x: f64) -> bool {
        FpFormat::contains(self, x)
    }
    fn successor(&self, x: f64) -> f64 {
        FpFormat::successor(self, x)
    }
    fn predecessor(&self, x: f64) -> f64 {
        FpFormat::predecessor(self, x)
    }
    fn min_value(&self) -> f64 {
        -self.x_max()
    }
    fn max_value(&self) -> f64 {
        self.x_max()
    }
    fn label(&self) -> String {
        self.name().to_string()
    }
}

/// A binary fixed-point grid in the Qm.n convention of the companion paper
/// (arXiv:2301.09511, §2): the values `k · δ` with `δ = 2^{−frac_bits}` and
/// the stored integer `k` ranging over a `word_bits`-wide two's-complement
/// (signed) or unsigned word. `Q3.8` is signed with 3 integer bits and
/// 8 fractional bits (12-bit word); `uQ3.8` is the unsigned 11-bit variant.
///
/// Every grid point is carried exactly as an `f64` (the same embedding
/// trick as [`FpFormat`]): `word_bits ≤ 52` guarantees `k`, `k·δ` and the
/// residual arithmetic are all exact in binary64.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FixedPoint {
    /// Fractional bits `n` — the spacing is `δ = 2^{−n}`.
    pub frac_bits: u32,
    /// Total word width in bits (sign bit included when `signed`).
    pub word_bits: u32,
    /// Two's-complement (`k ∈ [−2^{w−1}, 2^{w−1}−1]`) vs unsigned
    /// (`k ∈ [0, 2^w−1]`).
    pub signed: bool,
}

impl FixedPoint {
    /// A signed Qm.n grid: `m` integer bits, `n` fractional bits, one sign
    /// bit (`word_bits = m + n + 1`). Panics when the word exceeds the
    /// 52-bit exact-embedding budget.
    pub const fn q(int_bits: u32, frac_bits: u32) -> Self {
        let word_bits = int_bits + frac_bits + 1;
        assert!(word_bits >= 2, "fixed-point word must be at least 2 bits");
        assert!(word_bits <= 52, "fixed-point word must fit the 52-bit exact-embedding budget");
        Self { frac_bits, word_bits, signed: true }
    }

    /// An unsigned uQm.n grid (`word_bits = m + n`).
    pub const fn uq(int_bits: u32, frac_bits: u32) -> Self {
        let word_bits = int_bits + frac_bits;
        assert!(word_bits >= 1, "fixed-point word must be at least 1 bit");
        assert!(word_bits <= 52, "fixed-point word must fit the 52-bit exact-embedding budget");
        Self { frac_bits, word_bits, signed: false }
    }

    /// Integer bits `m` of the Qm.n form (sign bit excluded).
    pub fn int_bits(&self) -> u32 {
        self.word_bits - self.frac_bits - self.signed as u32
    }

    /// The grid spacing `δ = 2^{−frac_bits}` — the uniform-grid analogue of
    /// the floating-point unit roundoff (the companion paper's ε).
    #[inline]
    pub fn delta(&self) -> f64 {
        pow2(-(self.frac_bits as i32))
    }

    /// Smallest stored integer `k_min` (0 when unsigned).
    #[inline]
    fn k_min(&self) -> f64 {
        if self.signed {
            -((1u64 << (self.word_bits - 1)) as f64)
        } else {
            0.0
        }
    }

    /// Largest stored integer `k_max`.
    #[inline]
    fn k_max(&self) -> f64 {
        if self.signed {
            ((1u64 << (self.word_bits - 1)) - 1) as f64
        } else {
            ((1u64 << self.word_bits) - 1) as f64
        }
    }

    /// Parse `"Q3.8"` / `"q3.8"` (signed) or `"uQ3.8"` (unsigned), with an
    /// optional `"fixed:"` prefix — the CLI `--backend fixed:Qm.n` spelling.
    /// Returns `None` on malformed specs or words outside the constructor
    /// bounds (signed ≥ 2 bits, unsigned ≥ 1 bit, ≤ 52 either way), so
    /// [`FixedPoint::name`] always round-trips.
    pub fn parse(spec: &str) -> Option<Self> {
        let s = spec.trim().to_ascii_lowercase();
        let s = s.strip_prefix("fixed:").unwrap_or(&s);
        let (signed, body) = match s.strip_prefix("uq") {
            Some(rest) => (false, rest),
            None => (true, s.strip_prefix('q')?),
        };
        let (m, n) = body.split_once('.')?;
        let int_bits: u32 = m.parse().ok()?;
        let frac_bits: u32 = n.parse().ok()?;
        let word_bits = int_bits.checked_add(frac_bits)?.checked_add(signed as u32)?;
        let min_bits = if signed { 2u32 } else { 1 };
        if !(min_bits..=52).contains(&word_bits) {
            return None;
        }
        Some(Self { frac_bits, word_bits, signed })
    }

    /// Canonical spec string, re-parseable by [`FixedPoint::parse`].
    pub fn name(&self) -> String {
        if self.signed {
            format!("q{}.{}", self.int_bits(), self.frac_bits)
        } else {
            format!("uq{}.{}", self.int_bits(), self.frac_bits)
        }
    }
}

impl NumberGrid for FixedPoint {
    fn floor_ceil(&self, x: f64) -> (f64, f64) {
        if x == 0.0 {
            return (0.0, 0.0); // 0 = 0·δ is a grid point of every variant
        }
        if x.is_nan() {
            return (f64::NAN, f64::NAN);
        }
        let (vmin, vmax) = (self.min_value(), self.max_value());
        if x > vmax {
            return (vmax, f64::INFINITY);
        }
        if x < vmin {
            return (f64::NEG_INFINITY, vmin);
        }
        // Exact integer quantization: δ is a power of two and |k| < 2^52,
        // so the scaling, the floor and the rescaling are all exact.
        let m = x * (1.0 / self.delta());
        let k = m.floor();
        let lo = k * self.delta();
        if k == m {
            (lo, lo)
        } else {
            (lo, (k + 1.0) * self.delta())
        }
    }

    fn contains(&self, x: f64) -> bool {
        if x == 0.0 {
            return true;
        }
        if !x.is_finite() || x > self.max_value() || x < self.min_value() {
            return false;
        }
        let m = x * (1.0 / self.delta());
        m == m.floor()
    }

    fn successor(&self, x: f64) -> f64 {
        debug_assert!(self.contains(x), "successor() requires x on the grid (got {x})");
        if x >= self.max_value() {
            f64::INFINITY
        } else {
            x + self.delta() // exact: one step on the uniform grid
        }
    }

    fn predecessor(&self, x: f64) -> f64 {
        debug_assert!(self.contains(x), "predecessor() requires x on the grid (got {x})");
        if x <= self.min_value() {
            f64::NEG_INFINITY
        } else {
            x - self.delta()
        }
    }

    fn min_value(&self) -> f64 {
        self.k_min() * self.delta()
    }

    fn max_value(&self) -> f64 {
        self.k_max() * self.delta()
    }

    fn label(&self) -> String {
        if self.signed {
            format!("Q{}.{}", self.int_bits(), self.frac_bits)
        } else {
            format!("uQ{}.{}", self.int_bits(), self.frac_bits)
        }
    }
}

/// The closed, `Copy`-able dispatch handle over the supported number-grid
/// backends — what [`crate::fp::round::RoundPlan`], [`crate::fp::LpCtx`],
/// `GdConfig` and the CLI carry. Build one from an [`FpFormat`] or a
/// [`FixedPoint`] via `From`/`Into` (every constructor in the repo accepts
/// `impl Into<Grid>`, so float-only call sites are unchanged), or parse a
/// spec string with [`Grid::parse`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Grid {
    /// A floating-point format (binade-scaled spacing) — the source paper.
    Float(FpFormat),
    /// A fixed-point Qm.n grid (uniform spacing) — the companion paper.
    Fixed(FixedPoint),
}

impl Grid {
    /// Parse a backend spec: any [`FpFormat::by_name`] name (`"binary8"`,
    /// `"bfloat16"`, …) or a fixed-point spec (`"q3.8"` / `"uQ3.8"` /
    /// `"fixed:Q3.8"`).
    pub fn parse(spec: &str) -> Option<Self> {
        if let Some(f) = FpFormat::by_name(spec) {
            return Some(Grid::Float(f));
        }
        FixedPoint::parse(spec).map(Grid::Fixed)
    }

    /// The underlying float format, when this is a float grid.
    pub fn as_float(&self) -> Option<FpFormat> {
        match self {
            Grid::Float(f) => Some(*f),
            Grid::Fixed(_) => None,
        }
    }

    /// The underlying fixed-point grid, when this is one.
    pub fn as_fixed(&self) -> Option<FixedPoint> {
        match self {
            Grid::Float(_) => None,
            Grid::Fixed(f) => Some(*f),
        }
    }

    /// Canonical spec string (`"binary8"`, `"q3.8"`), re-parseable by
    /// [`Grid::parse`].
    pub fn name(&self) -> String {
        match self {
            Grid::Float(f) => f.name().to_string(),
            Grid::Fixed(f) => f.name(),
        }
    }

    /// The τ_k stagnation threshold of the backend: GD under RN freezes
    /// once the scaled update falls to or below it — `u/2` on a float grid
    /// (paper §3.2), `1/2` (i.e. half a spacing, in spacings) on a uniform
    /// fixed-point grid.
    pub fn stagnation_threshold(&self) -> f64 {
        match self {
            Grid::Float(f) => f.unit_roundoff() / 2.0,
            Grid::Fixed(_) => 0.5,
        }
    }

    /// Is `x` inside the finite representable range
    /// `[min_value, max_value]` of this grid? A value outside it either
    /// saturates (clamps to the nearer endpoint — every mode on a fixed
    /// grid, directed/stochastic modes on a float grid) or overflows to
    /// `±∞` (float RN). Non-finite `x` (±∞, NaN) is out of range. This is
    /// the predicate the [`crate::fp::round::RunHealth`] saturation
    /// counter keys on.
    pub fn in_range(&self, x: f64) -> bool {
        x >= NumberGrid::min_value(self) && x <= NumberGrid::max_value(self)
    }
}

impl NumberGrid for Grid {
    fn floor_ceil(&self, x: f64) -> (f64, f64) {
        match self {
            Grid::Float(f) => f.floor_ceil(x),
            Grid::Fixed(f) => NumberGrid::floor_ceil(f, x),
        }
    }
    fn contains(&self, x: f64) -> bool {
        match self {
            Grid::Float(f) => f.contains(x),
            Grid::Fixed(f) => NumberGrid::contains(f, x),
        }
    }
    fn successor(&self, x: f64) -> f64 {
        match self {
            Grid::Float(f) => f.successor(x),
            Grid::Fixed(f) => NumberGrid::successor(f, x),
        }
    }
    fn predecessor(&self, x: f64) -> f64 {
        match self {
            Grid::Float(f) => f.predecessor(x),
            Grid::Fixed(f) => NumberGrid::predecessor(f, x),
        }
    }
    fn min_value(&self) -> f64 {
        match self {
            Grid::Float(f) => NumberGrid::min_value(f),
            Grid::Fixed(f) => NumberGrid::min_value(f),
        }
    }
    fn max_value(&self) -> f64 {
        match self {
            Grid::Float(f) => NumberGrid::max_value(f),
            Grid::Fixed(f) => NumberGrid::max_value(f),
        }
    }
    fn label(&self) -> String {
        match self {
            Grid::Float(f) => NumberGrid::label(f),
            Grid::Fixed(f) => NumberGrid::label(f),
        }
    }
}

impl From<FpFormat> for Grid {
    fn from(f: FpFormat) -> Self {
        Grid::Float(f)
    }
}

impl From<&FpFormat> for Grid {
    fn from(f: &FpFormat) -> Self {
        Grid::Float(*f)
    }
}

impl From<FixedPoint> for Grid {
    fn from(f: FixedPoint) -> Self {
        Grid::Fixed(f)
    }
}

impl From<&FixedPoint> for Grid {
    fn from(f: &FixedPoint) -> Self {
        Grid::Fixed(*f)
    }
}

impl From<&Grid> for Grid {
    fn from(g: &Grid) -> Self {
        *g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const Q2_3: FixedPoint = FixedPoint::q(2, 3); // δ=1/8, range [-4, 3.875]

    #[test]
    fn q_parameters() {
        assert_eq!(Q2_3.delta(), 0.125);
        assert_eq!(Q2_3.word_bits, 6);
        assert_eq!(NumberGrid::min_value(&Q2_3), -4.0);
        assert_eq!(NumberGrid::max_value(&Q2_3), 3.875);
        let u = FixedPoint::uq(2, 3);
        assert_eq!(NumberGrid::min_value(&u), 0.0);
        assert_eq!(NumberGrid::max_value(&u), 31.0 * 0.125);
        assert_eq!(Q2_3.int_bits(), 2);
    }

    #[test]
    fn parse_roundtrips() {
        for spec in ["q2.3", "Q2.3", "fixed:Q2.3", "uq4.8", "fixed:uQ4.8", "q0.7", "uq1.0"] {
            let fx = FixedPoint::parse(spec).unwrap_or_else(|| panic!("parse {spec}"));
            assert_eq!(FixedPoint::parse(&fx.name()), Some(fx), "{spec}");
            assert_eq!(Grid::parse(spec), Some(Grid::Fixed(fx)), "{spec}");
        }
        assert_eq!(Grid::parse("binary8"), Some(Grid::Float(FpFormat::BINARY8)));
        for bad in ["q2", "q.3", "qx.y", "fixed:", "q60.0", "binary7", ""] {
            assert_eq!(Grid::parse(bad), None, "{bad}");
        }
        assert_eq!(Q2_3.name(), "q2.3");
        assert_eq!(NumberGrid::label(&Q2_3), "Q2.3");
        assert_eq!(NumberGrid::label(&FixedPoint::uq(2, 3)), "uQ2.3");
    }

    #[test]
    fn floor_ceil_on_the_uniform_grid() {
        assert_eq!(NumberGrid::floor_ceil(&Q2_3, 0.0), (0.0, 0.0));
        assert_eq!(NumberGrid::floor_ceil(&Q2_3, 1.1), (1.0, 1.125));
        assert_eq!(NumberGrid::floor_ceil(&Q2_3, -1.1), (-1.125, -1.0));
        assert_eq!(NumberGrid::floor_ceil(&Q2_3, 0.125), (0.125, 0.125));
        // Out of range: inward saturation endpoint, outward infinity.
        assert_eq!(NumberGrid::floor_ceil(&Q2_3, 5.0), (3.875, f64::INFINITY));
        assert_eq!(NumberGrid::floor_ceil(&Q2_3, -5.0), (f64::NEG_INFINITY, -4.0));
        assert_eq!(NumberGrid::floor_ceil(&Q2_3, f64::INFINITY), (3.875, f64::INFINITY));
        // Unsigned grid: everything below zero ceils to 0.
        let u = FixedPoint::uq(2, 3);
        assert_eq!(NumberGrid::floor_ceil(&u, -0.01), (f64::NEG_INFINITY, 0.0));
        // Residual is the exact position in the gap.
        assert_eq!(NumberGrid::residual(&Q2_3, 1.0625), 0.5);
        assert_eq!(NumberGrid::residual(&Q2_3, 1.0), 0.0);
    }

    #[test]
    fn membership_and_neighbors() {
        for k in -32i64..=31 {
            let x = k as f64 * 0.125;
            assert!(NumberGrid::contains(&Q2_3, x), "{x}");
            let (lo, hi) = NumberGrid::floor_ceil(&Q2_3, x);
            assert_eq!((lo, hi), (x, x));
        }
        assert!(!NumberGrid::contains(&Q2_3, 0.1));
        assert!(!NumberGrid::contains(&Q2_3, 4.0)); // past k_max
        assert!(!NumberGrid::contains(&Q2_3, f64::INFINITY));
        // su/pr walk the grid in δ steps and are strict inverses inside.
        assert_eq!(NumberGrid::successor(&Q2_3, 0.0), 0.125);
        assert_eq!(NumberGrid::predecessor(&Q2_3, 0.0), -0.125);
        assert_eq!(NumberGrid::successor(&Q2_3, 3.875), f64::INFINITY);
        assert_eq!(NumberGrid::predecessor(&Q2_3, -4.0), f64::NEG_INFINITY);
        for k in -31i64..=30 {
            let x = k as f64 * 0.125;
            assert_eq!(NumberGrid::predecessor(&Q2_3, NumberGrid::successor(&Q2_3, x)), x);
        }
    }

    #[test]
    fn grid_enum_delegates_and_converts() {
        let g: Grid = Q2_3.into();
        assert_eq!(g.as_fixed(), Some(Q2_3));
        assert_eq!(g.as_float(), None);
        assert_eq!(g.floor_ceil(1.1), (1.0, 1.125));
        assert_eq!(g.name(), "q2.3");
        assert_eq!(g.stagnation_threshold(), 0.5);
        let f: Grid = FpFormat::BINARY8.into();
        assert_eq!(f.as_float(), Some(FpFormat::BINARY8));
        assert_eq!(f.stagnation_threshold(), 0.0625);
        assert_eq!(Grid::from(&FpFormat::BINARY8), f);
        assert_eq!(Grid::from(&g), g);
        assert_ne!(f, g);
    }

    #[test]
    fn in_range_matches_the_saturation_endpoints() {
        let q: Grid = Q2_3.into();
        assert!(q.in_range(0.0) && q.in_range(3.875) && q.in_range(-4.0));
        assert!(!q.in_range(3.9) && !q.in_range(-4.1));
        let f: Grid = FpFormat::BINARY8.into();
        let xmax = FpFormat::BINARY8.x_max();
        assert!(f.in_range(xmax) && f.in_range(-xmax) && f.in_range(1.0));
        assert!(!f.in_range(xmax * 1.01));
        for g in [q, f] {
            assert!(!g.in_range(f64::INFINITY));
            assert!(!g.in_range(f64::NEG_INFINITY));
            assert!(!g.in_range(f64::NAN));
        }
    }
}
