//! Structure-of-arrays lane batches for multi-seed execution.
//!
//! A [`LaneBatch`] holds `lanes` independent copies ("lanes") of an
//! `n`-element vector in one contiguous slab, element-major and
//! lane-minor: element `i` of lane `l` lives at `i * lanes + l`. That
//! layout puts the same element of every lane side by side, so the
//! per-element math of the GD hot path (gradient accumulation, rounding,
//! the update kernels) runs once over the slab and vectorizes across
//! lanes, while each lane still carries its own RNG stream and therefore
//! reproduces, bit for bit, the scalar run it stands for (see
//! `docs/performance.md`).
//!
//! Lanes are an execution strategy, never part of a result's identity:
//! everything downstream (journals, goldens, CSV artifacts) sees per-lane
//! columns identical to scalar runs.

/// A structure-of-arrays slab of `lanes` interleaved `n`-element vectors.
#[derive(Debug, Clone, PartialEq)]
pub struct LaneBatch {
    n: usize,
    lanes: usize,
    data: Vec<f64>,
}

impl LaneBatch {
    /// An all-zero batch of `lanes` vectors of `n` elements each.
    pub fn zeros(n: usize, lanes: usize) -> Self {
        let lanes = lanes.max(1);
        Self { n, lanes, data: vec![0.0; n * lanes] }
    }

    /// A batch with every lane initialised to a copy of `xs`.
    pub fn broadcast(xs: &[f64], lanes: usize) -> Self {
        let lanes = lanes.max(1);
        let mut data = Vec::with_capacity(xs.len() * lanes);
        for &x in xs {
            data.extend(std::iter::repeat(x).take(lanes));
        }
        Self { n: xs.len(), lanes, data }
    }

    /// Number of elements per lane.
    pub fn elems(&self) -> usize {
        self.n
    }

    /// Number of lanes.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Flat index of element `i` in lane `l`.
    #[inline(always)]
    pub fn idx(&self, i: usize, l: usize) -> usize {
        i * self.lanes + l
    }

    /// Element `i` of lane `l`.
    #[inline(always)]
    pub fn get(&self, i: usize, l: usize) -> f64 {
        self.data[i * self.lanes + l]
    }

    /// Set element `i` of lane `l`.
    #[inline(always)]
    pub fn set(&mut self, i: usize, l: usize, v: f64) {
        self.data[i * self.lanes + l] = v;
    }

    /// The whole interleaved slab (element-major, lane-minor).
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable view of the interleaved slab.
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Gather lane `l` out into a contiguous vector.
    pub fn lane(&self, l: usize) -> Vec<f64> {
        assert!(l < self.lanes, "lane {l} out of {}", self.lanes);
        (0..self.n).map(|i| self.data[i * self.lanes + l]).collect()
    }

    /// Scatter a contiguous vector into lane `l`.
    pub fn set_lane(&mut self, l: usize, xs: &[f64]) {
        assert!(l < self.lanes, "lane {l} out of {}", self.lanes);
        assert_eq!(xs.len(), self.n, "lane length mismatch");
        for (i, &x) in xs.iter().enumerate() {
            self.data[i * self.lanes + l] = x;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_element_major_lane_minor() {
        let mut b = LaneBatch::zeros(3, 2);
        b.set(0, 0, 1.0);
        b.set(0, 1, 2.0);
        b.set(2, 1, 5.0);
        assert_eq!(b.as_slice(), &[1.0, 2.0, 0.0, 0.0, 0.0, 5.0]);
        assert_eq!(b.get(2, 1), 5.0);
        assert_eq!(b.idx(2, 1), 5);
    }

    #[test]
    fn broadcast_then_gather_roundtrips() {
        let xs = [1.5, -2.0, 0.25];
        let b = LaneBatch::broadcast(&xs, 4);
        assert_eq!(b.elems(), 3);
        assert_eq!(b.lanes(), 4);
        for l in 0..4 {
            assert_eq!(b.lane(l), xs.to_vec());
        }
    }

    #[test]
    fn scatter_updates_only_its_lane() {
        let mut b = LaneBatch::broadcast(&[1.0, 1.0], 3);
        b.set_lane(1, &[7.0, 8.0]);
        assert_eq!(b.lane(0), vec![1.0, 1.0]);
        assert_eq!(b.lane(1), vec![7.0, 8.0]);
        assert_eq!(b.lane(2), vec![1.0, 1.0]);
    }

    #[test]
    fn zero_lane_requests_are_clamped_to_one() {
        let b = LaneBatch::zeros(2, 0);
        assert_eq!(b.lanes(), 1);
        assert_eq!(LaneBatch::broadcast(&[3.0], 0).lanes(), 1);
    }
}
