//! Blocked, rounding-aware linear-algebra kernels — the per-cell hot path.
//!
//! The paper's learning experiments (binary8/bfloat16 MLR and NN training,
//! §5.2–5.3) spend nearly all of their time evaluating rounded gradients.
//! Before this layer existed, every elementary result went through a scalar
//! [`crate::fp::linalg::LpCtx::fl`] call — per-call mode dispatch, per-call
//! format constants, and one full-width uniform per stochastic rounding.
//! These kernels restructure the same computations around the fused slice
//! rounders of [`RoundPlan`] (which batch the randomness through the
//! few-random-bits block source), so rounding cost is paid per *slice*, not
//! per scalar. `benches/gd_step.rs` measures the resulting ≥3× speedup on
//! the binary8 MLR gradient step and writes `BENCH_gd_step.json`.
//!
//! # Determinism contract (mode-scoped)
//!
//! * **Deterministic modes (RN/RD/RU/RZ)** round elementwise — a value's
//!   rounding never depends on its neighbors — and consume no randomness,
//!   so the kernels only need to feed the *same f64 intermediates* through
//!   the same rounding steps to be bit-identical to the historic scalar
//!   path. Exact summations therefore run in the seed's sequential order
//!   ([`dot_seq`]) under these modes: trajectories are **bit-identical** to
//!   the pre-kernel implementation.
//! * **Stochastic modes (SR/SRε/signed-SRε)** are free to re-stream
//!   randomness (see `round.rs`), so the kernels also use the faster
//!   multi-accumulator summation ([`dot_fast`]) — same law, different
//!   stream and O(u) different f64 intermediates. The distributional tests
//!   and the paper's figures are invariant to both.
//!
//! [`dot_auto`] encodes this contract; `docs/performance.md` spells it out.

use super::rng::Rng;
use super::round::{RoundPlan, RunHealth};
use super::scheme::Scheme;

/// Accumulator-rounding granularity of the *absorption* (low-precision
/// accumulation) model: the running sum is rounded into the working format
/// every `ACC_BLOCK` accumulated terms. For N ≫ ACC_BLOCK/u the absorption
/// threshold is identical to per-op accumulation while costing ACC_BLOCK×
/// fewer roundings — see DESIGN.md §8 and the problem implementations.
pub const ACC_BLOCK: usize = 32;

/// Exact inner product in the seed's sequential order (one running
/// accumulator) — the order the deterministic-mode contract preserves.
/// Delegates to [`crate::fp::linalg::exact::dot`] so the load-bearing
/// summation order is defined in exactly one place.
#[inline]
pub fn dot_seq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    crate::fp::linalg::exact::dot(a, b)
}

/// Exact inner product with four independent accumulators (breaks the
/// serial FMA dependency chain so the compiler can vectorize). Same value
/// up to f64 reassociation — only used under stochastic modes.
#[inline]
pub fn dot_fast(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for (pa, pb) in (&mut ca).zip(&mut cb) {
        s0 += pa[0] * pb[0];
        s1 += pa[1] * pb[1];
        s2 += pa[2] * pb[2];
        s3 += pa[3] * pb[3];
    }
    let mut acc = (s0 + s2) + (s1 + s3);
    for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
        acc += x * y;
    }
    acc
}

/// Mode-scoped exact dot: sequential (seed order) for deterministic
/// schemes, multi-accumulator for stochastic schemes — the determinism
/// contract.
#[inline]
pub fn dot_auto(mode: Scheme, a: &[f64], b: &[f64]) -> f64 {
    if mode.is_stochastic() {
        dot_fast(a, b)
    } else {
        dot_seq(a, b)
    }
}

/// Rounded GEMM against a transposed weight matrix, with bias:
/// `out[r·c + k] = fl-model(x_r · w_k + bias[k])` for `rows` input rows of
/// width `d` and `c` output channels (both matrices row-major).
///
/// * `acc_rounded = false` (chop protocol, §2.4): the dot products run
///   exactly in f64 and the *results* are rounded — one fused
///   [`RoundPlan::round_slice`] over the whole output.
/// * `acc_rounded = true` (absorption model): the accumulator is rounded
///   into the working format every [`ACC_BLOCK`] features, batched across
///   the `c` channels of a row so each rounding is slice-granular, then
///   `fl(acc + bias)` as the final rounding — the outputs are already
///   representable when the row is copied out, so no trailing whole-output
///   pass runs on this path (the scalar reference's extra identity `fl`
///   per logit rounds a representable value and changes nothing).
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt_bias_rounded(
    plan: &RoundPlan,
    mode: Scheme,
    x: &[f64],
    rows: usize,
    d: usize,
    w: &[f64],
    c: usize,
    bias: &[f64],
    out: &mut [f64],
    acc_rounded: bool,
    rng: &mut Rng,
) {
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(w.len(), c * d);
    debug_assert_eq!(bias.len(), c);
    debug_assert_eq!(out.len(), rows * c);
    if !acc_rounded {
        for r in 0..rows {
            let xr = &x[r * d..(r + 1) * d];
            let orow = &mut out[r * c..(r + 1) * c];
            for (k, o) in orow.iter_mut().enumerate() {
                *o = dot_auto(mode, xr, &w[k * d..(k + 1) * d]) + bias[k];
            }
        }
        plan.round_slice_scheme(mode, out, rng);
        return;
    }
    let mut acc = vec![0.0f64; c];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        acc.fill(0.0);
        let mut j = 0;
        while j < d {
            let hi = (j + ACC_BLOCK).min(d);
            for (k, a) in acc.iter_mut().enumerate() {
                *a += dot_auto(mode, &xr[j..hi], &w[k * d + j..k * d + hi]);
            }
            // acc ← fl(acc + block-sum), batched across the c channels.
            plan.round_slice_scheme(mode, &mut acc, rng);
            j = hi;
        }
        for (a, &bk) in acc.iter_mut().zip(bias) {
            *a += bk;
        }
        plan.round_slice_scheme(mode, &mut acc, rng);
        out[r * c..(r + 1) * c].copy_from_slice(&acc);
    }
}

/// In-place rounded softmax over `rows` rows of width `c`: takes *rounded*
/// logits, leaves rounded probabilities. Mirrors the scalar sequence of the
/// historic gradient path elementwise — `e = fl(exp(z − rowmax))`,
/// `s = fl(Σe)` (the Σ itself exact in f64, seed order), `p = fl(e/s)` —
/// with each rounding pass fused across the whole matrix. `sums` is caller
/// scratch, resized to `rows`.
pub fn softmax_rows_rounded(
    plan: &RoundPlan,
    mode: Scheme,
    z: &mut [f64],
    rows: usize,
    c: usize,
    sums: &mut Vec<f64>,
    rng: &mut Rng,
) {
    debug_assert_eq!(z.len(), rows * c);
    for r in 0..rows {
        let row = &mut z[r * c..(r + 1) * c];
        let maxz = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        for v in row.iter_mut() {
            *v = (*v - maxz).exp();
        }
    }
    plan.round_slice_scheme(mode, z, rng);
    sums.clear();
    for r in 0..rows {
        let mut s = 0.0;
        for &e in &z[r * c..(r + 1) * c] {
            s += e;
        }
        sums.push(s);
    }
    plan.round_slice_scheme(mode, sums, rng);
    for r in 0..rows {
        let s = sums[r];
        for v in z[r * c..(r + 1) * c].iter_mut() {
            *v /= s;
        }
    }
    plan.round_slice_scheme(mode, z, rng);
}

/// Fused rounded axpy with per-op semantics: `y ← fl(y + fl(α·x))`,
/// elementwise identical to the scalar `mul`-then-`add` sequence but with
/// both rounding passes fused slice-wise. `tmp` is caller scratch.
pub fn axpy_rounded(
    plan: &RoundPlan,
    mode: Scheme,
    alpha: f64,
    x: &[f64],
    y: &mut [f64],
    tmp: &mut Vec<f64>,
    rng: &mut Rng,
) {
    debug_assert_eq!(x.len(), y.len());
    tmp.clear();
    tmp.extend(x.iter().map(|&v| alpha * v));
    plan.round_slice_scheme(mode, tmp, rng);
    for (yi, &t) in y.iter_mut().zip(tmp.iter()) {
        *yi += t;
    }
    plan.round_slice_scheme(mode, y, rng);
}

/// The fused (8b)+(8c) tail of one GD iteration (the engine's step after
/// the gradient): `m = fl₂(t·ĝ)` steered by `−ĝ`, then `x⁺ = fl₃(x̂ − m)`
/// steered by `+ĝ` (the §4.2.2 descent steering). Scratch buffers `mbuf`,
/// `vneg`, `zbuf` are caller-owned (the engine reuses them across steps).
/// Returns `true` when any coordinate moved. δ₂ and δ₃ draw from their own
/// streams, preserving the engine's per-step stream separation.
#[allow(clippy::too_many_arguments)]
pub fn gd_update(
    plan: &RoundPlan,
    mul_mode: Scheme,
    sub_mode: Scheme,
    t: f64,
    x: &mut [f64],
    ghat: &[f64],
    mbuf: &mut [f64],
    vneg: &mut [f64],
    zbuf: &mut [f64],
    rng_mul: &mut Rng,
    rng_sub: &mut Rng,
) -> bool {
    debug_assert!(
        x.len() == ghat.len()
            && x.len() == mbuf.len()
            && x.len() == vneg.len()
            && x.len() == zbuf.len()
    );
    // (8b): m = fl₂(t·ĝᵢ). The steering buffer is only consulted by
    // steered schemes (signed-SRε and steered user schemes); skip the
    // negation pass for every other scheme.
    for (m, &g) in mbuf.iter_mut().zip(ghat) {
        *m = t * g;
    }
    if mul_mode.uses_steering() {
        for (v, &g) in vneg.iter_mut().zip(ghat) {
            *v = -g;
        }
    }
    plan.round_slice_scheme_with(mul_mode, mbuf, vneg, rng_mul);
    // (8c): x̂ᵢ⁺ = fl₃(x̂ᵢ − mᵢ), steering v = +ĝᵢ.
    for ((z, &xi), &m) in zbuf.iter_mut().zip(x.iter()).zip(mbuf.iter()) {
        *z = xi - m;
    }
    plan.round_slice_scheme_with(sub_mode, zbuf, ghat, rng_sub);
    let mut moved = false;
    for (xi, &z) in x.iter_mut().zip(zbuf.iter()) {
        if z != *xi {
            moved = true;
        }
        *xi = z;
    }
    moved
}

/// [`gd_update`] with numeric-health accounting: bit-identical iterates and
/// RNG streams (it calls the very same fused slice rounders on the very same
/// intermediates), plus a [`RoundPlan::classify`] pass over each rounding
/// site. Pre-rounding values are *recomputed* from inputs the kernel has not
/// yet overwritten — `t·ĝᵢ` for (8b) and `x̂ᵢ − mᵢ` for (8c), both the exact
/// same f64 operations the kernel performed — so no snapshot buffer and no
/// allocation is needed on the hot path.
#[allow(clippy::too_many_arguments)]
pub fn gd_update_health(
    plan: &RoundPlan,
    mul_mode: Scheme,
    sub_mode: Scheme,
    t: f64,
    x: &mut [f64],
    ghat: &[f64],
    mbuf: &mut [f64],
    vneg: &mut [f64],
    zbuf: &mut [f64],
    rng_mul: &mut Rng,
    rng_sub: &mut Rng,
    health: &mut RunHealth,
) -> bool {
    gd_update_split_health(
        Site { plan, scheme: mul_mode },
        Site { plan, scheme: sub_mode },
        t,
        x,
        ghat,
        mbuf,
        vneg,
        zbuf,
        rng_mul,
        rng_sub,
        health,
    )
}

/// One rounding site of a fused optimizer kernel: the plan (grid +
/// `sr_bits`) and scheme that round that site's results. Built per step by
/// the GD engine from its [`crate::gd::PolicyMap`] — per-tensor bindings
/// resolve to sites with their own grids, which is how
/// master-weights-in-high-precision lanes run through the same kernels as
/// fully-low-precision ones.
#[derive(Clone, Copy)]
pub struct Site<'a> {
    /// The precomputed rounding plan of this site's grid.
    pub plan: &'a RoundPlan,
    /// The rounding scheme applied at this site.
    pub scheme: Scheme,
}

/// [`gd_update_health`] with independent rounding sites for the (8b) and
/// (8c) passes. With both sites on one plan this is *the* body of
/// [`gd_update_health`] (which delegates here): same staging, same fused
/// slice rounders on the same intermediates, same recomputed-pre-image
/// classify passes — bit-identical trajectories, RNG streams and health
/// counters. A distinct `sub` site (a `weights=` policy binding) only
/// changes where the iterate lands.
#[allow(clippy::too_many_arguments)]
pub fn gd_update_split_health(
    mul: Site<'_>,
    sub: Site<'_>,
    t: f64,
    x: &mut [f64],
    ghat: &[f64],
    mbuf: &mut [f64],
    vneg: &mut [f64],
    zbuf: &mut [f64],
    rng_mul: &mut Rng,
    rng_sub: &mut Rng,
    health: &mut RunHealth,
) -> bool {
    debug_assert!(
        x.len() == ghat.len()
            && x.len() == mbuf.len()
            && x.len() == vneg.len()
            && x.len() == zbuf.len()
    );
    // (8b), same staging as `gd_update`.
    for (m, &g) in mbuf.iter_mut().zip(ghat) {
        *m = t * g;
    }
    if mul.scheme.uses_steering() {
        for (v, &g) in vneg.iter_mut().zip(ghat) {
            *v = -g;
        }
    }
    mul.plan.round_slice_scheme_with(mul.scheme, mbuf, vneg, rng_mul);
    for (&m, &g) in mbuf.iter().zip(ghat) {
        mul.plan.classify(t * g, m, health);
    }
    // (8c): x is untouched until the commit loop below, so `x̂ᵢ − mᵢ` is
    // still recomputable after the rounding pass.
    for ((z, &xi), &m) in zbuf.iter_mut().zip(x.iter()).zip(mbuf.iter()) {
        *z = xi - m;
    }
    sub.plan.round_slice_scheme_with(sub.scheme, zbuf, ghat, rng_sub);
    for ((&z, &xi), &m) in zbuf.iter().zip(x.iter()).zip(mbuf.iter()) {
        sub.plan.classify(xi - m, z, health);
    }
    let mut moved = false;
    for (xi, &z) in x.iter_mut().zip(zbuf.iter()) {
        if z != *xi {
            moved = true;
        }
        *xi = z;
    }
    moved
}

/// The fused heavy-ball / Nesterov momentum step:
///
/// ```text
/// m⁺ = fl_m(β·m + t·ĝ)            buffer update at the `m_site`
/// u  = m⁺                         (heavy ball), or
/// u  = fl₂(β·m⁺ + t·ĝ)            (Nesterov lookahead, at the `mul` site)
/// x̂⁺ = fl₃(x̂ − u)                 landing at the `sub` site
/// ```
///
/// Steering follows §4.2.2: update-shaped values (`m⁺`, `u`) steer by
/// `−ĝ`, the landing point by `+ĝ`. Pre-rounding values are recomputed
/// from inputs not yet overwritten (the state tensor commits only after
/// its classify pass), so health accounting allocates nothing. Heavy ball
/// performs no (8b) pass: the update *is* the state tensor, already
/// resident on the `m_site` grid. Returns `true` when the iterate moved.
#[allow(clippy::too_many_arguments)]
pub fn momentum_update_health(
    m_site: Site<'_>,
    mul: Site<'_>,
    sub: Site<'_>,
    beta: f64,
    nesterov: bool,
    t: f64,
    x: &mut [f64],
    ghat: &[f64],
    m: &mut [f64],
    mbuf: &mut [f64],
    vneg: &mut [f64],
    zbuf: &mut [f64],
    rng_m: &mut Rng,
    rng_mul: &mut Rng,
    rng_sub: &mut Rng,
    health: &mut RunHealth,
) -> bool {
    debug_assert!(
        x.len() == ghat.len()
            && x.len() == m.len()
            && x.len() == mbuf.len()
            && x.len() == vneg.len()
            && x.len() == zbuf.len()
    );
    // Buffer update m⁺ = fl_m(β·m + t·ĝ), staged into scratch so the old
    // state stays recomputable for the classify pass.
    for ((b, &mi), &g) in mbuf.iter_mut().zip(m.iter()).zip(ghat) {
        *b = beta * mi + t * g;
    }
    if m_site.scheme.uses_steering() {
        for (v, &g) in vneg.iter_mut().zip(ghat) {
            *v = -g;
        }
    }
    m_site.plan.round_slice_scheme_with(m_site.scheme, mbuf, vneg, rng_m);
    for ((&b, &mi), &g) in mbuf.iter().zip(m.iter()).zip(ghat) {
        m_site.plan.classify(beta * mi + t * g, b, health);
    }
    m.copy_from_slice(mbuf);
    if nesterov {
        // Lookahead blend u = fl₂(β·m⁺ + t·ĝ) at the (8b) site; `m` holds
        // the committed m⁺ and is not overwritten, so the pre-image stays
        // recomputable.
        for ((b, &mi), &g) in mbuf.iter_mut().zip(m.iter()).zip(ghat) {
            *b = beta * mi + t * g;
        }
        if mul.scheme.uses_steering() {
            for (v, &g) in vneg.iter_mut().zip(ghat) {
                *v = -g;
            }
        }
        mul.plan.round_slice_scheme_with(mul.scheme, mbuf, vneg, rng_mul);
        for ((&b, &mi), &g) in mbuf.iter().zip(m.iter()).zip(ghat) {
            mul.plan.classify(beta * mi + t * g, b, health);
        }
    }
    // Landing x̂⁺ = fl₃(x̂ − u), steering v = +ĝ; `mbuf` holds u either way.
    for ((z, &xi), &u) in zbuf.iter_mut().zip(x.iter()).zip(mbuf.iter()) {
        *z = xi - u;
    }
    sub.plan.round_slice_scheme_with(sub.scheme, zbuf, ghat, rng_sub);
    for ((&z, &xi), &u) in zbuf.iter().zip(x.iter()).zip(mbuf.iter()) {
        sub.plan.classify(xi - u, z, health);
    }
    let mut moved = false;
    for (xi, &z) in x.iter_mut().zip(zbuf.iter()) {
        if z != *xi {
            moved = true;
        }
        *xi = z;
    }
    moved
}

/// Scalar parameters of one fused Adam step. The bias corrections
/// `bc1 = 1 − β₁^{k+1}` / `bc2 = 1 − β₂^{k+1}` are computed by the caller
/// in exact f64 — they are scalars, not tensor arithmetic, so they carry
/// no rounding site.
#[derive(Debug, Clone, Copy)]
pub struct AdamParams {
    /// First-moment coefficient β₁.
    pub beta1: f64,
    /// Second-moment coefficient β₂.
    pub beta2: f64,
    /// Denominator offset ε.
    pub eps: f64,
    /// First-moment bias correction `1 − β₁^{k+1}`.
    pub bc1: f64,
    /// Second-moment bias correction `1 − β₂^{k+1}`.
    pub bc2: f64,
}

/// The fused Adam step with per-tensor rounding sites:
///
/// ```text
/// m⁺ = fl_m(β₁·m + (1−β₁)·ĝ)            first moment at the `m_site`
/// v⁺ = fl_v(β₂·v + (1−β₂)·ĝ²)           second moment at the `v_site`
/// u  = fl₂(t·(m⁺/bc1)/(√(v⁺/bc2) + ε))  update at the (8b) `mul` site
/// x̂⁺ = fl₃(x̂ − u)                       landing at the `sub` site
/// ```
///
/// Update-shaped values steer by `−ĝ`, the landing point by `+ĝ` (§4.2.2);
/// moments commit only after their classify passes so every pre-rounding
/// value is recomputed, not snapshotted. Returns `true` when the iterate
/// moved.
#[allow(clippy::too_many_arguments)]
pub fn adam_update_health(
    m_site: Site<'_>,
    v_site: Site<'_>,
    mul: Site<'_>,
    sub: Site<'_>,
    params: &AdamParams,
    t: f64,
    x: &mut [f64],
    ghat: &[f64],
    m: &mut [f64],
    v: &mut [f64],
    mbuf: &mut [f64],
    vneg: &mut [f64],
    zbuf: &mut [f64],
    rng_m: &mut Rng,
    rng_v: &mut Rng,
    rng_mul: &mut Rng,
    rng_sub: &mut Rng,
    health: &mut RunHealth,
) -> bool {
    debug_assert!(
        x.len() == ghat.len()
            && x.len() == m.len()
            && x.len() == v.len()
            && x.len() == mbuf.len()
            && x.len() == vneg.len()
            && x.len() == zbuf.len()
    );
    let AdamParams { beta1, beta2, eps, bc1, bc2 } = *params;
    // First moment m⁺ = fl_m(β₁·m + (1−β₁)·ĝ).
    for ((b, &mi), &g) in mbuf.iter_mut().zip(m.iter()).zip(ghat) {
        *b = beta1 * mi + (1.0 - beta1) * g;
    }
    if m_site.scheme.uses_steering() {
        for (w, &g) in vneg.iter_mut().zip(ghat) {
            *w = -g;
        }
    }
    m_site.plan.round_slice_scheme_with(m_site.scheme, mbuf, vneg, rng_m);
    for ((&b, &mi), &g) in mbuf.iter().zip(m.iter()).zip(ghat) {
        m_site.plan.classify(beta1 * mi + (1.0 - beta1) * g, b, health);
    }
    m.copy_from_slice(mbuf);
    // Second moment v⁺ = fl_v(β₂·v + (1−β₂)·ĝ²).
    for ((b, &vi), &g) in mbuf.iter_mut().zip(v.iter()).zip(ghat) {
        *b = beta2 * vi + (1.0 - beta2) * (g * g);
    }
    if v_site.scheme.uses_steering() {
        for (w, &g) in vneg.iter_mut().zip(ghat) {
            *w = -g;
        }
    }
    v_site.plan.round_slice_scheme_with(v_site.scheme, mbuf, vneg, rng_v);
    for ((&b, &vi), &g) in mbuf.iter().zip(v.iter()).zip(ghat) {
        v_site.plan.classify(beta2 * vi + (1.0 - beta2) * (g * g), b, health);
    }
    v.copy_from_slice(mbuf);
    // Update u = fl₂(t·m̂/(√v̂ + ε)); both moments are committed and no
    // longer overwritten, so the pre-image stays recomputable.
    for ((b, &mi), &vi) in mbuf.iter_mut().zip(m.iter()).zip(v.iter()) {
        *b = t * (mi / bc1) / ((vi / bc2).sqrt() + eps);
    }
    if mul.scheme.uses_steering() {
        for (w, &g) in vneg.iter_mut().zip(ghat) {
            *w = -g;
        }
    }
    mul.plan.round_slice_scheme_with(mul.scheme, mbuf, vneg, rng_mul);
    for ((&b, &mi), &vi) in mbuf.iter().zip(m.iter()).zip(v.iter()) {
        mul.plan.classify(t * (mi / bc1) / ((vi / bc2).sqrt() + eps), b, health);
    }
    // Landing x̂⁺ = fl₃(x̂ − u), steering v = +ĝ.
    for ((z, &xi), &u) in zbuf.iter_mut().zip(x.iter()).zip(mbuf.iter()) {
        *z = xi - u;
    }
    sub.plan.round_slice_scheme_with(sub.scheme, zbuf, ghat, rng_sub);
    for ((&z, &xi), &u) in zbuf.iter().zip(x.iter()).zip(mbuf.iter()) {
        sub.plan.classify(xi - u, z, health);
    }
    let mut moved = false;
    for (xi, &z) in x.iter_mut().zip(zbuf.iter()) {
        if z != *xi {
            moved = true;
        }
        *xi = z;
    }
    moved
}

/// Lane-batched [`gd_update_health`]: one fused (8b)+(8c) pass over a
/// structure-of-arrays slab of `lanes` interleaved repetitions (element `i`
/// of lane `l` at `i * lanes + l`; see [`crate::fp::lanes::LaneBatch`]).
/// Per lane, iterates, `moved` flags, health counters and RNG consumption
/// are bit-identical to running [`gd_update_health`] on that lane's column
/// with that lane's generators — lane width is an execution strategy, not
/// part of a trajectory's identity. `rngs_mul[l]` / `rngs_sub[l]` are lane
/// `l`'s δ₂/δ₃ streams; `health[l]` / `moved[l]` accumulate per lane.
#[allow(clippy::too_many_arguments)]
pub fn gd_update_lanes(
    plan: &RoundPlan,
    mul_mode: Scheme,
    sub_mode: Scheme,
    t: f64,
    x: &mut [f64],
    ghat: &[f64],
    lanes: usize,
    mbuf: &mut [f64],
    vneg: &mut [f64],
    zbuf: &mut [f64],
    rngs_mul: &mut [Rng],
    rngs_sub: &mut [Rng],
    health: &mut [RunHealth],
    moved: &mut [bool],
) {
    debug_assert!(lanes >= 1 && x.len() % lanes == 0);
    debug_assert!(
        x.len() == ghat.len()
            && x.len() == mbuf.len()
            && x.len() == vneg.len()
            && x.len() == zbuf.len()
    );
    debug_assert!(rngs_mul.len() == lanes && rngs_sub.len() == lanes);
    debug_assert!(health.len() == lanes && moved.len() == lanes);
    // (8b): m = fl₂(t·ĝ), steered by −ĝ for steered schemes only (same
    // staging as `gd_update`; unsteered schemes never read `vneg`).
    for (m, &g) in mbuf.iter_mut().zip(ghat) {
        *m = t * g;
    }
    let vs_mul: Option<&[f64]> = if mul_mode.uses_steering() {
        for (v, &g) in vneg.iter_mut().zip(ghat) {
            *v = -g;
        }
        Some(vneg)
    } else {
        None
    };
    plan.round_slice_lanes_scheme_with(mul_mode, mbuf, lanes, vs_mul, rngs_mul);
    for (idx, (&m, &g)) in mbuf.iter().zip(ghat).enumerate() {
        plan.classify(t * g, m, &mut health[idx % lanes]);
    }
    // (8c): x̂⁺ = fl₃(x̂ − m), steering v = +ĝ. `x` is untouched until the
    // commit loop, so the pre-rounding value stays recomputable.
    for ((z, &xi), &m) in zbuf.iter_mut().zip(x.iter()).zip(mbuf.iter()) {
        *z = xi - m;
    }
    let vs_sub: Option<&[f64]> = if sub_mode.uses_steering() { Some(ghat) } else { None };
    plan.round_slice_lanes_scheme_with(sub_mode, zbuf, lanes, vs_sub, rngs_sub);
    for (idx, ((&z, &xi), &m)) in zbuf.iter().zip(x.iter()).zip(mbuf.iter()).enumerate() {
        plan.classify(xi - m, z, &mut health[idx % lanes]);
    }
    for (idx, (xi, &z)) in x.iter_mut().zip(zbuf.iter()).enumerate() {
        if z != *xi {
            moved[idx % lanes] = true;
        }
        *xi = z;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::format::FpFormat;
    use crate::fp::linalg::LpCtx;
    use crate::fp::round::Rounding;

    const B8: FpFormat = FpFormat::BINARY8;

    fn rand_vec(n: usize, seed: u64, scale: f64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() * scale).collect()
    }

    #[test]
    fn dot_variants_agree_to_roundoff() {
        let a = rand_vec(203, 1, 1.0);
        let b = rand_vec(203, 2, 1.0);
        let s = dot_seq(&a, &b);
        let f = dot_fast(&a, &b);
        assert!((s - f).abs() <= 1e-12 * s.abs().max(1.0), "{s} vs {f}");
        assert_eq!(dot_auto(Rounding::RoundNearestEven.scheme(), &a, &b), s);
        assert_eq!(dot_auto(Rounding::Sr.scheme(), &a, &b), f);
    }

    /// Chop-model GEMM under a deterministic mode is bit-identical to the
    /// scalar reference sequence `fl(dot_seq + bias)` per output.
    #[test]
    fn gemm_chop_deterministic_matches_scalar_reference() {
        let (rows, d, c) = (13, 37, 5);
        let x = rand_vec(rows * d, 3, 0.5);
        let w = rand_vec(c * d, 4, 0.5);
        let bias = rand_vec(c, 5, 0.1);
        for mode in [Rounding::RoundNearestEven, Rounding::RoundTowardZero] {
            for fmt in [B8, FpFormat::BFLOAT16] {
                let plan = RoundPlan::new(fmt);
                let mut out = vec![0.0; rows * c];
                let mut rng = Rng::new(0);
                gemm_nt_bias_rounded(&plan, mode.scheme(), &x, rows, d, &w, c, &bias, &mut out, false, &mut rng);
                let mut ctx = LpCtx::new(fmt, mode, Rng::new(0));
                for r in 0..rows {
                    for k in 0..c {
                        let want =
                            ctx.fl(dot_seq(&x[r * d..(r + 1) * d], &w[k * d..(k + 1) * d]) + bias[k]);
                        assert_eq!(out[r * c + k], want, "{mode:?} r={r} k={k}");
                    }
                }
            }
        }
    }

    /// Absorption-model GEMM under a deterministic mode matches the seed's
    /// blocked scalar accumulation exactly.
    #[test]
    fn gemm_absorption_deterministic_matches_scalar_reference() {
        let (rows, d, c) = (7, 70, 4);
        let x = rand_vec(rows * d, 6, 0.5);
        let w = rand_vec(c * d, 7, 0.5);
        let bias = rand_vec(c, 8, 0.1);
        let mode = Rounding::RoundNearestEven;
        let plan = RoundPlan::new(B8);
        let mut out = vec![0.0; rows * c];
        let mut rng = Rng::new(0);
        gemm_nt_bias_rounded(&plan, mode.scheme(), &x, rows, d, &w, c, &bias, &mut out, true, &mut rng);
        let mut ctx = LpCtx::new(B8, mode, Rng::new(0));
        for r in 0..rows {
            for k in 0..c {
                let xr = &x[r * d..(r + 1) * d];
                let wk = &w[k * d..(k + 1) * d];
                let mut acc = 0.0;
                let mut j = 0;
                while j < d {
                    let hi = (j + ACC_BLOCK).min(d);
                    acc = ctx.add(acc, dot_seq(&xr[j..hi], &wk[j..hi]));
                    j = hi;
                }
                let want = ctx.add(acc, bias[k]);
                assert_eq!(out[r * c + k], want, "r={r} k={k}");
            }
        }
    }

    /// Rounded softmax matches the scalar per-element sequence under RN and
    /// produces valid, format-resident probability rows under SR.
    #[test]
    fn softmax_rows_matches_scalar_and_is_resident() {
        let (rows, c) = (11, 10);
        let plan = RoundPlan::new(B8);
        // Rounded logits as input (the kernel contract).
        let mut z = rand_vec(rows * c, 9, 2.0);
        let mut rng = Rng::new(1);
        plan.round_slice(Rounding::RoundNearestEven, &mut z, &mut rng);
        // RN: scalar reference comparison.
        let mut got = z.clone();
        let mut sums = Vec::new();
        softmax_rows_rounded(&plan, Rounding::RoundNearestEven.scheme(), &mut got, rows, c, &mut sums, &mut rng);
        let mut ctx = LpCtx::new(B8, Rounding::RoundNearestEven, Rng::new(2));
        for r in 0..rows {
            let row = &z[r * c..(r + 1) * c];
            let maxz = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let es: Vec<f64> = row.iter().map(|&v| ctx.fl((v - maxz).exp())).collect();
            let s = ctx.fl(es.iter().sum::<f64>());
            for k in 0..c {
                let want = ctx.fl(es[k] / s);
                assert_eq!(got[r * c + k], want, "r={r} k={k}");
            }
        }
        // SR: probabilities are representable and rows roughly normalize.
        let mut sr = z.clone();
        softmax_rows_rounded(&plan, Rounding::Sr.scheme(), &mut sr, rows, c, &mut sums, &mut Rng::new(3));
        for r in 0..rows {
            let row = &sr[r * c..(r + 1) * c];
            assert!(row.iter().all(|&p| B8.contains(p) && (0.0..=2.0).contains(&p)));
            let s: f64 = row.iter().sum();
            assert!((s - 1.0).abs() < 0.8, "row {r} sums to {s}");
        }
    }

    #[test]
    fn axpy_matches_scalar_reference() {
        let n = 57;
        let x = rand_vec(n, 10, 1.0);
        let y0 = rand_vec(n, 11, 1.0);
        let plan = RoundPlan::new(B8);
        let mut y = y0.clone();
        let mut tmp = Vec::new();
        axpy_rounded(&plan, Rounding::RoundNearestEven.scheme(), 0.37, &x, &mut y, &mut tmp, &mut Rng::new(0));
        let mut ctx = LpCtx::new(B8, Rounding::RoundNearestEven, Rng::new(0));
        let mut want = y0.clone();
        ctx.axpy(0.37, &x, &mut want);
        assert_eq!(y, want);
        // Stochastic: result stays format-resident.
        let mut ys = y0.clone();
        axpy_rounded(&plan, Rounding::Sr.scheme(), 0.37, &x, &mut ys, &mut tmp, &mut Rng::new(4));
        assert!(ys.iter().all(|&v| B8.contains(v)));
    }

    /// `gd_update` under deterministic modes reproduces the unfused
    /// two-pass update exactly; under stochastic modes the iterate stays
    /// format-resident and the two streams remain separate.
    #[test]
    fn gd_update_matches_unfused_reference() {
        let n = 41;
        let plan = RoundPlan::new(B8);
        let ghat = rand_vec(n, 12, 1.0);
        let x0: Vec<f64> = {
            let mut v = rand_vec(n, 13, 1.0);
            plan.round_slice(Rounding::RoundNearestEven, &mut v, &mut Rng::new(0));
            v
        };
        let t = 0.5;
        // Deterministic reference.
        let mode = Rounding::RoundTowardZero;
        let mut x = x0.clone();
        let (mut m, mut vneg, mut z) = (vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        gd_update(
            &plan, mode.scheme(), mode.scheme(), t, &mut x, &ghat, &mut m, &mut vneg, &mut z,
            &mut Rng::new(1), &mut Rng::new(2),
        );
        let mut want = x0.clone();
        let mut rng = Rng::new(9);
        for (wi, &g) in want.iter_mut().zip(&ghat) {
            let mi = plan.round(mode, t * g, &mut rng);
            *wi = plan.round(mode, *wi - mi, &mut rng);
        }
        assert_eq!(x, want);
        // Stochastic: residency.
        let mut xs = x0.clone();
        let moved = gd_update(
            &plan,
            Rounding::Sr.scheme(),
            Rounding::SignedSrEps(0.25).scheme(),
            t,
            &mut xs,
            &ghat,
            &mut m,
            &mut vneg,
            &mut z,
            &mut Rng::new(5),
            &mut Rng::new(6),
        );
        assert!(moved);
        assert!(xs.iter().all(|&v| B8.contains(v)));
    }

    /// The health-instrumented update is a pure observer: iterates, `moved`
    /// flag, and both RNG streams are bit-identical to the plain kernel, for
    /// a deterministic and a stochastic (steered) mode pairing.
    #[test]
    fn gd_update_health_is_a_pure_observer() {
        let n = 57;
        let plan = RoundPlan::new(B8);
        let ghat = rand_vec(n, 21, 1.0);
        let x0: Vec<f64> = {
            let mut v = rand_vec(n, 22, 1.0);
            plan.round_slice(Rounding::RoundNearestEven, &mut v, &mut Rng::new(0));
            v
        };
        let pairings = [
            (Rounding::RoundTowardZero.scheme(), Rounding::RoundNearestEven.scheme()),
            (Rounding::Sr.scheme(), Rounding::SignedSrEps(0.25).scheme()),
        ];
        for (mul_mode, sub_mode) in pairings {
            let (mut m, mut vneg, mut z) = (vec![0.0; n], vec![0.0; n], vec![0.0; n]);
            let mut xa = x0.clone();
            let (mut ra_mul, mut ra_sub) = (Rng::new(5), Rng::new(6));
            let moved_a = gd_update(
                &plan, mul_mode, sub_mode, 0.5, &mut xa, &ghat, &mut m, &mut vneg, &mut z,
                &mut ra_mul, &mut ra_sub,
            );
            let mut xb = x0.clone();
            let (mut rb_mul, mut rb_sub) = (Rng::new(5), Rng::new(6));
            let mut health = RunHealth::default();
            let moved_b = gd_update_health(
                &plan, mul_mode, sub_mode, 0.5, &mut xb, &ghat, &mut m, &mut vneg, &mut z,
                &mut rb_mul, &mut rb_sub, &mut health,
            );
            assert_eq!(xa, xb);
            assert_eq!(moved_a, moved_b);
            assert_eq!(ra_mul.next_u64(), rb_mul.next_u64());
            assert_eq!(ra_sub.next_u64(), rb_sub.next_u64());
            // Well-scaled inputs on binary8: no overflow, no NaN.
            assert_eq!(health.nan_inf, 0);
        }
    }

    /// Per lane, the lane-batched update is bit-identical to
    /// `gd_update_health` on that lane's column: iterates, `moved` flags,
    /// health counters, and both RNG streams.
    #[test]
    fn gd_update_lanes_matches_per_lane_scalar() {
        let n = 33;
        let plan = RoundPlan::new(B8);
        let pairings = [
            (Rounding::RoundTowardZero.scheme(), Rounding::RoundNearestEven.scheme()),
            (Rounding::Sr.scheme(), Rounding::SignedSrEps(0.25).scheme()),
        ];
        for lanes in [1usize, 4, 8] {
            // Distinct x and ĝ columns per lane.
            let cols_x: Vec<Vec<f64>> = (0..lanes)
                .map(|l| {
                    let mut v = rand_vec(n, 100 + l as u64, 1.0);
                    plan.round_slice(Rounding::RoundNearestEven, &mut v, &mut Rng::new(0));
                    v
                })
                .collect();
            let cols_g: Vec<Vec<f64>> =
                (0..lanes).map(|l| rand_vec(n, 200 + l as u64, 1.0)).collect();
            for (mul_mode, sub_mode) in pairings {
                let mut xslab = vec![0.0; n * lanes];
                let mut gslab = vec![0.0; n * lanes];
                for i in 0..n {
                    for l in 0..lanes {
                        xslab[i * lanes + l] = cols_x[l][i];
                        gslab[i * lanes + l] = cols_g[l][i];
                    }
                }
                let (mut m, mut vneg, mut z) =
                    (vec![0.0; n * lanes], vec![0.0; n * lanes], vec![0.0; n * lanes]);
                let mut rmul: Vec<Rng> = (0..lanes as u64).map(|l| Rng::new(5).split(l)).collect();
                let mut rsub: Vec<Rng> = (0..lanes as u64).map(|l| Rng::new(6).split(l)).collect();
                let mut health = vec![RunHealth::default(); lanes];
                let mut moved = vec![false; lanes];
                gd_update_lanes(
                    &plan, mul_mode, sub_mode, 0.5, &mut xslab, &gslab, lanes, &mut m, &mut vneg,
                    &mut z, &mut rmul, &mut rsub, &mut health, &mut moved,
                );
                for l in 0..lanes {
                    let mut xw = cols_x[l].clone();
                    let (mut sm, mut sv, mut sz) = (vec![0.0; n], vec![0.0; n], vec![0.0; n]);
                    let mut om = Rng::new(5).split(l as u64);
                    let mut os = Rng::new(6).split(l as u64);
                    let mut oh = RunHealth::default();
                    let omoved = gd_update_health(
                        &plan, mul_mode, sub_mode, 0.5, &mut xw, &cols_g[l], &mut sm, &mut sv,
                        &mut sz, &mut om, &mut os, &mut oh,
                    );
                    for i in 0..n {
                        assert_eq!(
                            xslab[i * lanes + l].to_bits(),
                            xw[i].to_bits(),
                            "lanes={lanes} lane={l} i={i}"
                        );
                    }
                    assert_eq!(moved[l], omoved, "lane {l} moved");
                    assert_eq!(health[l], oh, "lane {l} health");
                    assert_eq!(rmul[l].next_u64(), om.next_u64(), "lane {l} mul stream");
                    assert_eq!(rsub[l].next_u64(), os.next_u64(), "lane {l} sub stream");
                }
            }
        }
    }

    /// With β = 0 the heavy-ball step degenerates to plain GD: the buffer
    /// update is `fl(t·ĝ)` at the `m` site and the landing is (8c), so with
    /// the `m` site on the (8b) plan/scheme and the `m` stream seeded like
    /// δ₂, iterates, health and streams are bit-identical to
    /// `gd_update_health`.
    #[test]
    fn momentum_beta_zero_matches_gd_update_health() {
        let n = 47;
        let plan = RoundPlan::new(B8);
        let ghat = rand_vec(n, 31, 1.0);
        let x0: Vec<f64> = {
            let mut v = rand_vec(n, 32, 1.0);
            plan.round_slice(Rounding::RoundNearestEven, &mut v, &mut Rng::new(0));
            v
        };
        for (mul_mode, sub_mode) in [
            (Rounding::RoundNearestEven.scheme(), Rounding::RoundNearestEven.scheme()),
            (Rounding::Sr.scheme(), Rounding::SignedSrEps(0.25).scheme()),
        ] {
            let (mut mb, mut vb, mut zb) = (vec![0.0; n], vec![0.0; n], vec![0.0; n]);
            let mut xa = x0.clone();
            let (mut ra_mul, mut ra_sub) = (Rng::new(5), Rng::new(6));
            let mut ha = RunHealth::default();
            let moved_a = gd_update_health(
                &plan, mul_mode, sub_mode, 0.5, &mut xa, &ghat, &mut mb, &mut vb, &mut zb,
                &mut ra_mul, &mut ra_sub, &mut ha,
            );
            let mut xb = x0.clone();
            let mut state = vec![0.0; n];
            // β = 0 never reads the stale buffer, only overwrites it.
            let (mut rb_m, mut rb_mul, mut rb_sub) = (Rng::new(5), Rng::new(7), Rng::new(6));
            let mut hb = RunHealth::default();
            let moved_b = momentum_update_health(
                Site { plan: &plan, scheme: mul_mode },
                Site { plan: &plan, scheme: mul_mode },
                Site { plan: &plan, scheme: sub_mode },
                0.0,
                false,
                0.5,
                &mut xb,
                &ghat,
                &mut state,
                &mut mb,
                &mut vb,
                &mut zb,
                &mut rb_m,
                &mut rb_mul,
                &mut rb_sub,
                &mut hb,
            );
            assert_eq!(xa, xb);
            assert_eq!(moved_a, moved_b);
            assert_eq!(ha, hb);
            // Heavy ball has no (8b) blend pass: δ₂ is untouched.
            assert_eq!(rb_mul.next_u64(), Rng::new(7).next_u64());
            assert_eq!(ra_sub.next_u64(), rb_sub.next_u64());
        }
    }

    /// A distinct `sub` site (master-weights binding) lands the iterate on
    /// its own grid while the update still rounds on the run grid.
    #[test]
    fn split_sites_land_the_iterate_on_the_weights_grid() {
        let n = 29;
        let plan8 = RoundPlan::new(B8);
        let plan64 = RoundPlan::new(FpFormat::BINARY64);
        let ghat = rand_vec(n, 41, 1.0);
        let mut x = rand_vec(n, 42, 1.0);
        let (mut m, mut vneg, mut z) = (vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        let mut health = RunHealth::default();
        let moved = gd_update_split_health(
            Site { plan: &plan8, scheme: Rounding::Sr.scheme() },
            Site { plan: &plan64, scheme: Rounding::RoundNearestEven.scheme() },
            0.5,
            &mut x,
            &ghat,
            &mut m,
            &mut vneg,
            &mut z,
            &mut Rng::new(1),
            &mut Rng::new(2),
            &mut health,
        );
        assert!(moved);
        for i in 0..n {
            // The update m rounded into binary8; the landing x − m exact
            // (binary64 is the carrier, RN there is the identity).
            assert!(B8.contains(m[i]), "m[{i}]={}", m[i]);
            assert_eq!(x[i], z[i]);
        }
    }

    /// Adam's moments stay resident on their bound grids while the iterate
    /// stays on the run grid — the fully-low-precision-state lane.
    #[test]
    fn adam_moments_stay_on_their_site_grids() {
        let n = 23;
        let bf16 = FpFormat::BFLOAT16;
        let plan_run = RoundPlan::new(B8);
        let plan_state = RoundPlan::new(bf16);
        let ghat = rand_vec(n, 51, 1.0);
        let mut x: Vec<f64> = {
            let mut v = rand_vec(n, 52, 1.0);
            plan_run.round_slice(Rounding::RoundNearestEven, &mut v, &mut Rng::new(0));
            v
        };
        let (mut m, mut v) = (vec![0.0; n], vec![0.0; n]);
        let (mut mb, mut vb, mut zb) = (vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        let (mut rm, mut rv, mut rmul, mut rsub) =
            (Rng::new(1), Rng::new(2), Rng::new(3), Rng::new(4));
        let mut health = RunHealth::default();
        for k in 0..5 {
            let params = AdamParams {
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
                bc1: 1.0 - 0.9f64.powi(k + 1),
                bc2: 1.0 - 0.999f64.powi(k + 1),
            };
            adam_update_health(
                Site { plan: &plan_state, scheme: Rounding::Sr.scheme() },
                Site { plan: &plan_state, scheme: Rounding::Sr.scheme() },
                Site { plan: &plan_run, scheme: Rounding::Sr.scheme() },
                Site { plan: &plan_run, scheme: Rounding::Sr.scheme() },
                &params,
                0.05,
                &mut x,
                &ghat,
                &mut m,
                &mut v,
                &mut mb,
                &mut vb,
                &mut zb,
                &mut rm,
                &mut rv,
                &mut rmul,
                &mut rsub,
                &mut health,
            );
            for i in 0..n {
                assert!(bf16.contains(m[i]), "k={k} m[{i}]={}", m[i]);
                assert!(bf16.contains(v[i]) && v[i] >= 0.0, "k={k} v[{i}]={}", v[i]);
                assert!(B8.contains(x[i]), "k={k} x[{i}]={}", x[i]);
            }
        }
        assert_eq!(health.nan_inf, 0, "{}", health.summary());
    }

    /// Deterministic schemes consume no randomness through the optimizer
    /// kernels — same contract the GD kernels and the conformance suite
    /// enforce elsewhere.
    #[test]
    fn optimizer_kernels_consume_no_randomness_when_deterministic() {
        let n = 19;
        let plan = RoundPlan::new(B8);
        let rn = Rounding::RoundNearestEven.scheme();
        let site = Site { plan: &plan, scheme: rn };
        let ghat = rand_vec(n, 61, 1.0);
        let mut x = rand_vec(n, 62, 1.0);
        let (mut m, mut v) = (vec![0.0; n], vec![0.0; n]);
        let (mut mb, mut vb, mut zb) = (vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        let (mut r1, mut r2, mut r3, mut r4) = (Rng::new(1), Rng::new(2), Rng::new(3), Rng::new(4));
        let mut health = RunHealth::default();
        momentum_update_health(
            site, site, site, 0.9, true, 0.1, &mut x, &ghat, &mut m, &mut mb, &mut vb, &mut zb,
            &mut r1, &mut r2, &mut r3, &mut health,
        );
        let params =
            AdamParams { beta1: 0.9, beta2: 0.999, eps: 1e-8, bc1: 0.1, bc2: 0.001 };
        adam_update_health(
            site, site, site, site, &params, 0.1, &mut x, &ghat, &mut m, &mut v, &mut mb, &mut vb,
            &mut zb, &mut r1, &mut r2, &mut r3, &mut r4, &mut health,
        );
        for (rng, seed) in [(&mut r1, 1), (&mut r2, 2), (&mut r3, 3), (&mut r4, 4)] {
            assert_eq!(rng.next_u64(), Rng::new(seed).next_u64(), "stream {seed} was consumed");
        }
    }
}
