//! Rounding schemes: the paper's Definitions 1–3 plus the IEEE deterministic
//! modes, implemented over any [`Grid`] backend — the floating-point
//! [`FpFormat`]s (bit-pattern kernels) and the fixed-point
//! [`FixedPoint`] Qm.n grids (exact integer-quantization kernels; see
//! `docs/fixed-point.md`).
//!
//! * `RoundNearestEven` — IEEE-754 default (RN, ties to even);
//! * `RoundDown` / `RoundUp` / `RoundTowardZero` — directed modes;
//! * `Sr` — unbiased stochastic rounding (Definition 1): `P(⌈x⌉) ∝ x − ⌊x⌋`;
//! * `SrEps(ε)` — ε-biased stochastic rounding (Definition 2): rounds *away
//!   from zero* with probability at least ε, so the expected absolute error
//!   has the sign of `x` (eq. (3));
//! * `SignedSrEps(ε)` — signed ε-biased stochastic rounding (Definition 3):
//!   the bias direction is steered by an auxiliary value `v` so the expected
//!   absolute error has the sign of `−v` (eq. (4)). In GD, `v` is the
//!   computed gradient entry, forcing the bias into a descent direction.
//!
//! # Randomness contract (per entry point)
//!
//! The **scalar** entry points ([`round`], [`round_with`],
//! [`RoundPlan::round`], [`RoundPlan::round_with`]) consume exactly one
//! 53-bit uniform per inexact rounding and none when `x ∈ F` — the historic
//! reference semantics (as in `chop`/`roundit`), kept bit-stable for
//! reproducibility of seeded experiments.
//!
//! The **slice** kernels ([`RoundPlan::round_slice`],
//! [`RoundPlan::round_slice_with`]) instead drive the stochastic schemes
//! from a block-buffered *few-random-bits* source ([`BitBlock`]):
//! [`RoundPlan::sr_bits`] random bits per inexact element (default
//! [`DEFAULT_SR_BITS`] = 32), drawn in bulk one block at a time. This makes
//! one RNG call per chunk instead of per element and quantizes the rounding
//! probability to multiples of `2^{-sr_bits}` — an expected-value
//! perturbation below `2^{-32}` of one gap at the default, far inside the
//! tolerance of every distributional test and invisible next to the Monte
//! Carlo noise of the experiments. Consequences:
//!
//! * deterministic modes (RN/RD/RU/RZ) consume no randomness anywhere, so
//!   scalar and slice kernels are **bit-identical** — the engine's
//!   deterministic trajectories are unchanged by kernel choice;
//! * stochastic modes produce the *same law* but a **different stream** than
//!   the scalar path (and re-seeding `sr_bits` re-streams again); slice
//!   results remain a pure function of `(plan, inputs, rng state)`.
//!
//! The slice kernels additionally dispatch to runtime-detected AVX2
//! implementations ([`crate::fp::simd`]) that are **bit-identical to the
//! scalar loops for every mode** — the stochastic SIMD path preserves the
//! draw order of the `BitBlock` stream rather than re-streaming — and a
//! lane-batched entry point ([`RoundPlan::round_slice_lanes_scheme_with`])
//! rounds a structure-of-arrays slab of independent repetitions, each lane
//! bit-identical to a scalar run of its own stream.
//!
//! See `docs/performance.md` for the full determinism contract.

use super::format::FpFormat;
use super::grid::{FixedPoint, Grid, NumberGrid};
use super::rng::{BitBlock, LaneBits, Rng};
use super::scheme::{Scheme, SchemeError, SchemeRegistry};

/// A rounding scheme. `SignedSrEps` requires a steering value `v` supplied
/// per-element through [`round_with`]; the plain [`round`] entry point uses
/// `v = x`, which makes `SignedSrEps(ε)` degenerate to `SrEps(ε)` — exactly
/// the relationship noted under the paper's Algorithm 1.
///
/// **Deprecated shim.** This enum is the closed pre-redesign scheme set,
/// kept for compatibility; the open API is the
/// [`crate::fp::scheme::RoundingScheme`] trait, looked up through the
/// [`SchemeRegistry`] and carried as a [`Scheme`] handle. Every variant
/// converts losslessly (`Rounding::scheme()` / `From`), and the fused
/// kernels below stay bit-identical either way.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Rounding {
    /// Round to nearest, ties to even (IEEE default). The paper's "RN".
    RoundNearestEven,
    /// Round toward −∞.
    RoundDown,
    /// Round toward +∞.
    RoundUp,
    /// Round toward zero.
    RoundTowardZero,
    /// Unbiased stochastic rounding (Definition 1). The paper's "SR".
    Sr,
    /// ε-biased stochastic rounding (Definition 2), bias away from zero.
    SrEps(f64),
    /// Signed ε-biased stochastic rounding (Definition 3), bias `sign(−v)`.
    SignedSrEps(f64),
}

impl Rounding {
    /// Does this scheme consume randomness (SR / SRε / signed-SRε)?
    pub fn is_stochastic(&self) -> bool {
        matches!(self, Rounding::Sr | Rounding::SrEps(_) | Rounding::SignedSrEps(_))
    }

    /// Short name for reports ("RN", "SR", "SR_eps(0.1)", "signed-SR_eps(0.1)").
    pub fn label(&self) -> String {
        match self {
            Rounding::RoundNearestEven => "RN".into(),
            Rounding::RoundDown => "RD".into(),
            Rounding::RoundUp => "RU".into(),
            Rounding::RoundTowardZero => "RZ".into(),
            Rounding::Sr => "SR".into(),
            Rounding::SrEps(e) => format!("SR_eps({e})"),
            Rounding::SignedSrEps(e) => format!("signed-SR_eps({e})"),
        }
    }

    /// Parse "rn" | "rd" | "ru" | "rz" | "sr" | "sr_eps:0.1" | "signed:0.1"
    /// (case-insensitive). A thin shim over [`SchemeRegistry::lookup`]: on
    /// failure the error lists every registered scheme name, and specs
    /// naming a registered *custom* scheme (not expressible as this enum)
    /// are reported as such rather than silently dropped.
    pub fn parse(s: &str) -> Result<Self, SchemeError> {
        let scheme = SchemeRegistry::lookup(s)?;
        scheme.as_builtin().ok_or_else(|| SchemeError::NotBuiltin(s.trim().to_string()))
    }

    /// This mode as an open-API [`Scheme`] handle (same law, same fused
    /// kernels; see [`crate::fp::scheme`]).
    pub fn scheme(self) -> Scheme {
        Scheme::from(self)
    }
}

/// The clipping function φ of Definition 2: clamp to `[0, 1]`.
#[inline]
pub fn phi(y: f64) -> f64 {
    y.clamp(0.0, 1.0)
}

/// Saturate an out-of-range magnitude to `±x_max` (chop-style). Covers every
/// out-of-range shape the stochastic schemes can meet: finite `|x| > x_max`
/// clamps to `±x_max`, ±∞ inputs clamp to `±x_max` as well (the stochastic
/// schemes never produce ±∞), and NaN passes through (`f64::clamp` keeps
/// NaN). Deterministic RN instead overflows to ±∞ past the IEEE overflow
/// threshold `x_max + ulp/2`, handled in [`round_nearest_even`] — saturation
/// is *not* applied there.
#[inline]
fn saturate(fmt: &FpFormat, x: f64) -> f64 {
    x.clamp(-fmt.x_max(), fmt.x_max())
}

/// Default random bits consumed per stochastic slice rounding (the
/// "few-random-bits" knob; see [`RoundPlan::with_sr_bits`]). 32 bits packs
/// two roundings per RNG word while keeping the probability quantization
/// (`2^{-32}` of one gap) far below every statistical tolerance in the
/// test-suite and the paper's figures.
pub const DEFAULT_SR_BITS: u32 = 32;

/// Precomputed per-[`Grid`] rounding constants — the "grid table".
///
/// The scalar entry points recompute five integers (`shift`, `mask`, the
/// tie point, the gap scale, the exponent gates) from the format on every
/// call. One GD step rounds three full vectors (paper eq. (8a)/(8b)/(8c)),
/// so the engine and the slice kernels build a plan once and reuse it,
/// hoisting both the constant derivation and the mode dispatch out of the
/// per-element loop (≈2× for the stochastic schemes; see `benches/rounding.rs`).
///
/// A plan is built over either backend ([`RoundPlan::new`] takes any
/// `impl Into<Grid>`): floating-point grids keep the historic bit-pattern
/// fast path below — **bit-identical** to the pre-grid plans — while
/// fixed-point grids take a fast *integer-quantization* path (scale by
/// `2^{frac_bits}`, `floor`, exact residual) with no bit twiddling at all.
///
/// Correctness notes for the float fast path: with `shift = 53 − s`, the
/// f64 bits of |x| split as `lo_mag = bits & !mask` (the magnitude-floor,
/// exactly `⌊|x|⌋_F`) and `hi_mag = lo_mag + 2^shift` (magnitude-ceil;
/// carries into the exponent field exactly when the mantissa overflows to
/// the next binade, which is still a representable value). `tail/2^shift`
/// is exactly `(|x| − ⌊|x|⌋)/(⌈|x|⌉ − ⌊|x|⌋)` because the gap is one
/// target-ulp. For the fixed path, `m = x·2^f`, `⌊m⌋` and `m − ⌊m⌋` are
/// all exact in binary64 because the word is ≤ 52 bits wide.
#[derive(Debug, Clone, Copy)]
pub struct RoundPlan {
    /// The number grid this plan was precomputed for.
    pub grid: Grid,
    /// Float: `53 − s`, binary64 mantissa bits below the target ulp.
    /// (These float-path constants are `pub(crate)` for the AVX2 kernels in
    /// [`crate::fp::simd`], which evaluate the same bit-pattern arithmetic
    /// four lanes at a time.)
    pub(crate) shift: u32,
    /// Float: `2^shift − 1`, mask selecting the discarded tail bits.
    pub(crate) mask: u64,
    /// Float: `2^{shift−1}`, the RN tie point (0 when `shift = 0`, i.e.
    /// binary64, where the tail is always 0 and the tie point is never
    /// consulted).
    pub(crate) half: u64,
    /// Float: `2^{−shift}` exactly, converts the tail to a gap fraction.
    pub(crate) inv_gap: f64,
    /// Float: normalized-exponent eligibility gates of the fast path.
    pub(crate) e_min: i32,
    /// Float: see `e_min`.
    pub(crate) e_max: i32,
    /// Fixed: `2^{frac_bits}`, the exact integer-quantization scale.
    scale: f64,
    /// Fixed: the spacing `δ = 2^{−frac_bits}`.
    delta: f64,
    /// Fixed: lower saturation endpoint `k_min·δ`.
    vmin: f64,
    /// Fixed: upper saturation endpoint `k_max·δ`.
    vmax: f64,
    /// Random bits per stochastic slice rounding (the few-random-bits knob).
    pub(crate) sr_bits: u32,
    /// `2^{−sr_bits}` exactly: converts a bit chunk to a uniform in `[0,1)`.
    pub(crate) inv_sr: f64,
}

impl RoundPlan {
    /// Precompute the rounding constants for `grid` (an [`FpFormat`], a
    /// [`FixedPoint`] or a [`Grid`]) with the default [`DEFAULT_SR_BITS`]
    /// few-random-bits setting.
    #[inline]
    pub fn new(grid: impl Into<Grid>) -> Self {
        let grid = grid.into();
        let mut plan = Self {
            grid,
            shift: 0,
            mask: 0,
            half: 0,
            inv_gap: 0.0,
            e_min: 0,
            e_max: 0,
            scale: 0.0,
            delta: 0.0,
            vmin: 0.0,
            vmax: 0.0,
            sr_bits: DEFAULT_SR_BITS,
            inv_sr: inv_pow2(DEFAULT_SR_BITS),
        };
        match grid {
            Grid::Float(fmt) => {
                let shift = 53 - fmt.sig_bits;
                plan.shift = shift;
                plan.mask = (1u64 << shift) - 1;
                plan.half = if shift == 0 { 0 } else { 1u64 << (shift - 1) };
                plan.inv_gap = inv_pow2(shift);
                plan.e_min = fmt.e_min;
                plan.e_max = fmt.e_max;
            }
            Grid::Fixed(fx) => {
                plan.delta = fx.delta();
                plan.scale = 1.0 / fx.delta();
                plan.vmin = fx.min_value();
                plan.vmax = fx.max_value();
            }
        }
        plan
    }

    /// The same plan with `bits` random bits per stochastic slice rounding
    /// (clamped to `[1, 53]` so the chunk-to-uniform conversion stays exact).
    /// Lower settings stretch the random stream further at the price of a
    /// coarser rounding probability (quantized to multiples of `2^{-bits}`,
    /// i.e. an expected-value perturbation of at most `2^{-bits}` of one
    /// gap). Deterministic modes are unaffected. The scalar entry points
    /// always use the full-width reference draw regardless of this knob.
    #[inline]
    pub fn with_sr_bits(mut self, bits: u32) -> Self {
        let b = bits.clamp(1, 53);
        self.sr_bits = b;
        self.inv_sr = inv_pow2(b);
        self
    }

    /// Random bits consumed per stochastic slice rounding.
    #[inline]
    pub fn sr_bits(&self) -> u32 {
        self.sr_bits
    }

    /// Hot path: rounding a value whose magnitude is *target-normal* and in
    /// range reduces to rounding the binary64 mantissa tail — pure integer
    /// bit-twiddling, no divisions and no `pow2` reconstruction. This covers
    /// essentially every rounding in a GD run; subnormal/overflow/NaN inputs
    /// fall back to the general path. Returns `None` when ineligible.
    #[inline(always)]
    fn fast(&self, mode: Rounding, x: f64, v: f64, rng: &mut Rng) -> Option<f64> {
        let bits = x.to_bits();
        let mag = bits & 0x7fff_ffff_ffff_ffff;
        let raw_e = (mag >> 52) as i32;
        let e = raw_e - 1023;
        // Eligibility: finite, f64-normal, target-normal, strictly inside the
        // target's largest binade (so the magnitude-ceil cannot overflow past
        // x_max: for e < e_max, ceil ≤ 2^{e+1} ≤ 2^{e_max} ≤ x_max).
        if raw_e == 0 || raw_e == 0x7ff || e < self.e_min || e >= self.e_max {
            return None;
        }
        let tail = mag & self.mask;
        if tail == 0 {
            return Some(x); // representable
        }
        let neg = bits >> 63 == 1;
        let lo_mag = mag & !self.mask;
        let hi_mag = lo_mag + (self.mask + 1);
        // Value-scale neighbors.
        let (lo_bits, hi_bits) = if neg {
            (hi_mag | (1u64 << 63), lo_mag | (1u64 << 63))
        } else {
            (lo_mag, hi_mag)
        };
        // frac on the VALUE scale: distance from the value-floor, in gaps.
        let frac_mag = tail as f64 * self.inv_gap;
        let frac = if neg { 1.0 - frac_mag } else { frac_mag };
        let down = match mode {
            Rounding::RoundDown => true,
            Rounding::RoundUp => false,
            Rounding::RoundTowardZero => !neg,
            Rounding::RoundNearestEven => {
                if tail != self.half {
                    // Nearest in magnitude == nearest in value.
                    (tail < self.half) ^ neg
                } else {
                    // Tie: keep the endpoint with even target significand.
                    let lo_even = (lo_mag >> self.shift) & 1 == 0;
                    lo_even ^ neg // value-floor is the magnitude-floor iff !neg
                }
            }
            Rounding::Sr => rng.uniform() < 1.0 - frac,
            Rounding::SrEps(eps) => {
                let sx = if neg { -1.0 } else { 1.0 };
                rng.uniform() < phi(1.0 - frac - sx * eps)
            }
            Rounding::SignedSrEps(eps) => {
                let sv = if v == 0.0 { 0.0 } else { v.signum() };
                rng.uniform() < phi(1.0 - frac + sv * eps)
            }
        };
        Some(f64::from_bits(if down { lo_bits } else { hi_bits }))
    }

    /// Fixed-point counterpart of [`RoundPlan::fast`]: in-range values
    /// round through exact integer quantization — scale by `2^f`, `floor`,
    /// exact residual — with no neighbor search. Out-of-range, non-finite
    /// and NaN inputs fall back to the saturating slow path. The RN tie
    /// rule is ties-to-even on the stored integer `k` (the uniform-grid
    /// analogue of the even-significand rule).
    #[inline(always)]
    fn fast_fixed(&self, mode: Rounding, x: f64, v: f64, rng: &mut Rng) -> Option<f64> {
        // NaN and ±∞ fail the containment test and take the slow path.
        if !(self.vmin..=self.vmax).contains(&x) {
            return None;
        }
        let m = x * self.scale; // exact power-of-two scaling
        let k = m.floor();
        if k == m {
            return Some(x); // on the grid
        }
        let frac = m - k; // exact: the fractional bits of an exact f64
        let down = match mode {
            Rounding::RoundDown => true,
            Rounding::RoundUp => false,
            Rounding::RoundTowardZero => x > 0.0,
            Rounding::RoundNearestEven => {
                if frac != 0.5 {
                    frac < 0.5
                } else {
                    (k as i64) & 1 == 0
                }
            }
            Rounding::Sr => rng.uniform() < 1.0 - frac,
            Rounding::SrEps(eps) => rng.uniform() < phi(1.0 - frac - x.signum() * eps),
            Rounding::SignedSrEps(eps) => {
                let sv = if v == 0.0 { 0.0 } else { v.signum() };
                rng.uniform() < phi(1.0 - frac + sv * eps)
            }
        };
        Some(if down { k * self.delta } else { (k + 1.0) * self.delta })
    }

    /// Round `x` using scheme `mode`, steering `SignedSrEps` by `v`. Same
    /// contract as the free [`round_with`], without re-deriving the grid
    /// constants per call.
    #[inline]
    pub fn round_with(&self, mode: Rounding, x: f64, v: f64, rng: &mut Rng) -> f64 {
        if x == 0.0 || x.is_nan() {
            return x;
        }
        match self.grid {
            Grid::Float(_) => {
                if let Some(y) = self.fast(mode, x, v, rng) {
                    return y;
                }
            }
            Grid::Fixed(_) => {
                if let Some(y) = self.fast_fixed(mode, x, v, rng) {
                    return y;
                }
            }
        }
        round_slow_grid(&self.grid, mode, x, v, rng)
    }

    /// Round `x` with `v = x` (see the [`Rounding`] type-level docs).
    #[inline]
    pub fn round(&self, mode: Rounding, x: f64, rng: &mut Rng) -> f64 {
        self.round_with(mode, x, x, rng)
    }
}

/// `2^{-k}` for `k ∈ [0, 63]`, exact (table-free bit construction).
#[inline(always)]
fn inv_pow2(k: u32) -> f64 {
    f64::from_bits(((1023 - k as u64) & 0x7ff) << 52)
}

/// Round `x` into `grid` (an [`FpFormat`], [`FixedPoint`] or [`Grid`])
/// using scheme `mode`, steering `SignedSrEps` by `v`. One uniform is
/// drawn from `rng` iff the scheme is stochastic and `x ∉ G`.
#[inline]
pub fn round_with(grid: impl Into<Grid>, mode: Rounding, x: f64, v: f64, rng: &mut Rng) -> f64 {
    RoundPlan::new(grid).round_with(mode, x, v, rng)
}

/// General (slow) path shared by the scalar and slice kernels: exact
/// neighbor arithmetic through [`FpFormat::floor_ceil`]. Handles
/// subnormals, overflow saturation and the deterministic overflow-to-∞
/// rule. Requires `x != 0` and `x` not NaN (the callers guard).
fn round_slow(fmt: &FpFormat, mode: Rounding, x: f64, v: f64, rng: &mut Rng) -> f64 {
    let (lo, hi) = fmt.floor_ceil(x);
    if lo == hi {
        return lo; // x ∈ F (includes ±∞ inputs)
    }
    match mode {
        Rounding::RoundDown => lo,
        Rounding::RoundUp => hi,
        Rounding::RoundTowardZero => {
            if x > 0.0 {
                lo
            } else {
                hi
            }
        }
        Rounding::RoundNearestEven => round_nearest_even(fmt, x, lo, hi),
        Rounding::Sr | Rounding::SrEps(_) | Rounding::SignedSrEps(_) => {
            // Stochastic schemes: saturating endpoints keeps them finite.
            let (lo, hi) = (saturate(fmt, lo), saturate(fmt, hi));
            if lo == hi {
                return lo;
            }
            let frac = (x - lo) / (hi - lo); // ∈ (0,1), exact denominators
            let p_down = match mode {
                // Definition 1: P(⌊x⌋) = 1 − (x−⌊x⌋)/(⌈x⌉−⌊x⌋).
                Rounding::Sr => 1.0 - frac,
                // Definition 2: p_ε(x) = φ(1 − frac − sign(x)·ε).
                Rounding::SrEps(eps) => phi(1.0 - frac - x.signum() * eps),
                // Definition 3: p̂_ε(x) = φ(1 − frac + sign(v)·ε).
                Rounding::SignedSrEps(eps) => {
                    let sv = if v == 0.0 { 0.0 } else { v.signum() };
                    phi(1.0 - frac + sv * eps)
                }
                _ => unreachable!(),
            };
            if rng.uniform() < p_down {
                lo
            } else {
                hi
            }
        }
    }
}

/// Saturate to the fixed-point range `[k_min·δ, k_max·δ]`
/// ([`NumberGrid::saturate`]). Unlike the float backend (whose
/// deterministic RN overflows to `±∞` past the IEEE threshold), *every*
/// scheme saturates on a fixed-point grid — hardware fixed-point
/// accumulators clamp, they do not produce infinities. This is the
/// saturation contract of `docs/fixed-point.md`.
#[inline]
fn saturate_fixed(fx: &FixedPoint, x: f64) -> f64 {
    fx.saturate(x)
}

/// General (slow) path for fixed-point grids: exact neighbor arithmetic
/// through [`FixedPoint::floor_ceil`] with the saturating overflow rule
/// for every mode (deterministic and stochastic alike — see
/// [`saturate_fixed`]). Requires `x != 0` and `x` not NaN (callers guard).
fn round_slow_fixed(fx: &FixedPoint, mode: Rounding, x: f64, v: f64, rng: &mut Rng) -> f64 {
    let (lo, hi) = fx.floor_ceil(x);
    if lo == hi {
        return lo; // x on the grid
    }
    let (lo, hi) = (saturate_fixed(fx, lo), saturate_fixed(fx, hi));
    if lo == hi {
        return lo; // out of range: both neighbors clamp to the endpoint
    }
    match mode {
        Rounding::RoundDown => lo,
        Rounding::RoundUp => hi,
        Rounding::RoundTowardZero => {
            if x > 0.0 {
                lo
            } else {
                hi
            }
        }
        Rounding::RoundNearestEven => {
            let frac = (x - lo) / (hi - lo);
            if frac != 0.5 {
                if frac < 0.5 {
                    lo
                } else {
                    hi
                }
            } else {
                // Tie: keep the endpoint whose stored integer k is even.
                if ((lo / fx.delta()) as i64) & 1 == 0 {
                    lo
                } else {
                    hi
                }
            }
        }
        Rounding::Sr | Rounding::SrEps(_) | Rounding::SignedSrEps(_) => {
            let frac = (x - lo) / (hi - lo);
            let p_down = match mode {
                Rounding::Sr => 1.0 - frac,
                Rounding::SrEps(eps) => phi(1.0 - frac - x.signum() * eps),
                Rounding::SignedSrEps(eps) => {
                    let sv = if v == 0.0 { 0.0 } else { v.signum() };
                    phi(1.0 - frac + sv * eps)
                }
                _ => unreachable!(),
            };
            if rng.uniform() < p_down {
                lo
            } else {
                hi
            }
        }
    }
}

/// Backend dispatch for the shared slow path (rare in hot loops: only
/// out-of-range / non-finite elements land here).
fn round_slow_grid(grid: &Grid, mode: Rounding, x: f64, v: f64, rng: &mut Rng) -> f64 {
    match grid {
        Grid::Float(fmt) => round_slow(fmt, mode, x, v, rng),
        Grid::Fixed(fx) => round_slow_fixed(fx, mode, x, v, rng),
    }
}

/// Round `x` with `v = x` (see type-level docs).
#[inline]
pub fn round(grid: impl Into<Grid>, mode: Rounding, x: f64, rng: &mut Rng) -> f64 {
    round_with(grid, mode, x, x, rng)
}

/// IEEE round-to-nearest, ties to even, with the standard overflow rule
/// (|x| ≥ x_max + ulp/2 → ±∞).
fn round_nearest_even(fmt: &FpFormat, x: f64, lo: f64, hi: f64) -> f64 {
    if hi.is_infinite() {
        // Binade above x_max: overflow threshold is x_max + ulp(x_max)/2.
        let thr = fmt.x_max() + fmt.spacing_at(fmt.x_max()) / 2.0;
        return if x >= thr { f64::INFINITY } else { fmt.x_max() };
    }
    if lo.is_infinite() {
        let thr = -(fmt.x_max() + fmt.spacing_at(fmt.x_max()) / 2.0);
        return if x <= thr { f64::NEG_INFINITY } else { -fmt.x_max() };
    }
    let dlo = x - lo;
    let dhi = hi - x;
    if dlo < dhi {
        lo
    } else if dhi < dlo {
        hi
    } else {
        // Tie: pick the endpoint with even significand.
        let q = hi - lo;
        let m_lo = (lo / q).abs();
        if (m_lo as i64) % 2 == 0 {
            lo
        } else {
            hi
        }
    }
}

/// Expected rounded value `E[fl(x)]` under a scheme — closed form, no
/// sampling (used for Figure 1 and for property tests against the empirical
/// mean). For deterministic schemes this is just the rounded value. Works
/// on either backend: the stochastic laws read only the grid's neighbor
/// pair and saturation endpoints.
pub fn expected_round(grid: impl Into<Grid>, mode: Rounding, x: f64, v: f64) -> f64 {
    let grid = grid.into();
    if x == 0.0 || x.is_nan() {
        return x;
    }
    let (lo, hi) = grid.floor_ceil(x);
    if lo == hi {
        return lo;
    }
    match mode {
        Rounding::Sr | Rounding::SrEps(_) | Rounding::SignedSrEps(_) => {
            let (lo, hi) = (grid.saturate(lo), grid.saturate(hi));
            if lo == hi {
                return lo;
            }
            let frac = (x - lo) / (hi - lo);
            let p_down = match mode {
                Rounding::Sr => 1.0 - frac,
                Rounding::SrEps(eps) => phi(1.0 - frac - x.signum() * eps),
                Rounding::SignedSrEps(eps) => {
                    let sv = if v == 0.0 { 0.0 } else { v.signum() };
                    phi(1.0 - frac + sv * eps)
                }
                _ => unreachable!(),
            };
            p_down * lo + (1.0 - p_down) * hi
        }
        _ => {
            let mut rng = Rng::new(0); // unused by deterministic modes
            round_with(grid, mode, x, v, &mut rng)
        }
    }
}

impl RoundPlan {
    /// Round every entry of a slice in place (plain `v = x` steering).
    ///
    /// Deterministic modes run a fused bit-twiddled loop that is
    /// **bit-identical** to the scalar path; stochastic modes run the fused
    /// loop on the block-buffered few-random-bits source (see the module
    /// docs for the randomness contract). Either way the mode dispatch and
    /// format constants are hoisted out of the element loop.
    pub fn round_slice(&self, mode: Rounding, xs: &mut [f64], rng: &mut Rng) {
        match mode {
            Rounding::RoundNearestEven
            | Rounding::RoundDown
            | Rounding::RoundUp
            | Rounding::RoundTowardZero => self.round_slice_det(mode, xs, rng),
            Rounding::Sr => {
                self.round_slice_stoch(mode, xs, None, |_, _, _| 0.0, rng);
            }
            Rounding::SrEps(eps) => {
                self.round_slice_stoch(
                    mode,
                    xs,
                    None,
                    |frac, neg, _| {
                        let sx = if neg { -1.0 } else { 1.0 };
                        phi(1.0 - frac - sx * eps)
                    },
                    rng,
                );
            }
            Rounding::SignedSrEps(eps) => {
                // Unsteered: v = x, so sign(v) = sign(x) (x ≠ 0 on the fused
                // path — a zero entry is representable and never rounds).
                self.round_slice_stoch(
                    mode,
                    xs,
                    None,
                    |frac, neg, _| {
                        let sv = if neg { -1.0 } else { 1.0 };
                        phi(1.0 - frac + sv * eps)
                    },
                    rng,
                );
            }
        }
    }

    /// Round every entry, steering `SignedSrEps` per element by `vs`.
    ///
    /// Only `SignedSrEps` reads the steering value; every other mode
    /// delegates to the unsteered [`RoundPlan::round_slice`] kernel, which
    /// is exactly equivalent for them. This is the (8b)/(8c) hot path of
    /// the GD engine, where the steering vector is the computed gradient.
    pub fn round_slice_with(&self, mode: Rounding, xs: &mut [f64], vs: &[f64], rng: &mut Rng) {
        debug_assert_eq!(xs.len(), vs.len());
        let eps = match mode {
            Rounding::SignedSrEps(e) => e,
            _ => return self.round_slice(mode, xs, rng),
        };
        self.round_slice_stoch(
            mode,
            xs,
            Some(vs),
            |frac, _, v| {
                let sv = if v == 0.0 { 0.0 } else { v.signum() };
                phi(1.0 - frac + sv * eps)
            },
            rng,
        );
    }

    /// Fused deterministic slice kernel (no randomness): bit-identical to
    /// the scalar path element-by-element. Fixed-point grids divert to the
    /// integer-quantization kernel (same elementwise law as the scalar
    /// path, hence also bit-identical). When the AVX2 backend is active
    /// (see [`crate::fp::simd`]) the 4-aligned prefix runs the vector
    /// kernel — also bit-identical — and the remainder stays on this loop.
    fn round_slice_det(&self, mode: Rounding, xs: &mut [f64], rng: &mut Rng) {
        if let Grid::Fixed(_) = self.grid {
            return self.round_slice_det_fixed(mode, xs, rng);
        }
        #[allow(unused_mut)] // mutated only on the x86-64 SIMD path
        let mut start = 0usize;
        #[cfg(target_arch = "x86_64")]
        if super::simd::avx2_active() {
            let n4 = xs.len() & !3;
            {
                let mut slow = |x: &mut f64| {
                    if *x != 0.0 && !x.is_nan() {
                        *x = round_slow_grid(&self.grid, mode, *x, *x, rng);
                    }
                };
                // SAFETY: gated on runtime AVX2 detection via avx2_active().
                unsafe { super::simd::round_slice_det_avx2(self, mode, &mut xs[..n4], &mut slow) };
            }
            start = n4;
        }
        let (mask, shift, half) = (self.mask, self.shift, self.half);
        let (e_min, e_max) = (self.e_min, self.e_max);
        // Value-scale floor decision per sign for the directed modes (RN
        // overrides per element below).
        let (down_pos, down_neg) = match mode {
            Rounding::RoundDown => (true, true),
            Rounding::RoundUp => (false, false),
            _ => (true, false), // RZ: toward zero
        };
        let rn = mode == Rounding::RoundNearestEven;
        for x in xs[start..].iter_mut() {
            let bits = x.to_bits();
            let mag = bits & 0x7fff_ffff_ffff_ffff;
            let raw_e = (mag >> 52) as i32;
            let e = raw_e - 1023;
            if raw_e == 0 || raw_e == 0x7ff || e < e_min || e >= e_max {
                if *x != 0.0 && !x.is_nan() {
                    *x = round_slow_grid(&self.grid, mode, *x, *x, rng); // rare slow path
                }
                continue;
            }
            let tail = mag & mask;
            if tail == 0 {
                continue; // representable
            }
            let neg = bits >> 63 == 1;
            let lo_mag = mag & !mask;
            let down = if rn {
                if tail != half {
                    (tail < half) ^ neg
                } else {
                    ((lo_mag >> shift) & 1 == 0) ^ neg
                }
            } else if neg {
                down_neg
            } else {
                down_pos
            };
            // down on the VALUE scale: pick magnitude-ceil when negative.
            let out_mag = if down != neg { lo_mag } else { lo_mag + (mask + 1) };
            *x = f64::from_bits(out_mag | (bits & (1u64 << 63)));
        }
    }

    /// Fused stochastic slice kernel over the few-random-bits source.
    /// `p_down(frac, neg, v)` returns the value-scale round-down
    /// probability; for `Sr` the caller passes a dummy closure and the
    /// kernel uses `1 − frac` directly (avoids re-deriving it). Slow-path
    /// elements (subnormal / overflow / non-finite) fall back to
    /// [`round_slow`], which draws its own full-width uniform from `rng`;
    /// the result remains a pure function of the stream state.
    ///
    /// When the AVX2 backend is active the 4-aligned prefix runs the vector
    /// kernel in [`crate::fp::simd`]. That kernel is *stream-preserving* —
    /// it draws from the same `BitBlock` per inexact eligible element in
    /// element order and delegates mixed groups to the exact per-element
    /// body below — so backend choice never changes outputs or the RNG end
    /// state, for any mode (pinned by `simd_stoch_matches_scalar_bitwise`).
    fn round_slice_stoch<F: Fn(f64, bool, f64) -> f64>(
        &self,
        mode: Rounding,
        xs: &mut [f64],
        vs: Option<&[f64]>,
        p_down: F,
        rng: &mut Rng,
    ) {
        debug_assert!(mode.is_stochastic());
        if let Grid::Fixed(_) = self.grid {
            return self.round_slice_stoch_fixed(mode, xs, vs, p_down, rng);
        }
        let (mask, inv) = (self.mask, self.inv_gap);
        let (e_min, e_max) = (self.e_min, self.e_max);
        let (k, inv_sr) = (self.sr_bits, self.inv_sr);
        let plain_sr = matches!(mode, Rounding::Sr);
        let mut bsrc = BitBlock::for_elems(xs.len(), k);
        // The reference per-element body, shared verbatim by the scalar
        // loop below and the SIMD kernel's mixed-group fallback, so both
        // consume the stream identically.
        let elem = |x: &mut f64, v: f64, bsrc: &mut BitBlock, rng: &mut Rng| {
            let bits = x.to_bits();
            let mag = bits & 0x7fff_ffff_ffff_ffff;
            let raw_e = (mag >> 52) as i32;
            let e = raw_e - 1023;
            if raw_e == 0 || raw_e == 0x7ff || e < e_min || e >= e_max {
                if *x != 0.0 && !x.is_nan() {
                    *x = round_slow_grid(&self.grid, mode, *x, v, rng); // rare slow path
                }
                return;
            }
            let tail = mag & mask;
            if tail == 0 {
                return; // representable
            }
            let neg = bits >> 63 == 1;
            let frac_mag = tail as f64 * inv;
            let frac = if neg { 1.0 - frac_mag } else { frac_mag };
            let p = if plain_sr { 1.0 - frac } else { p_down(frac, neg, v) };
            let r = bsrc.take(k, rng) as f64 * inv_sr;
            let down = r < p;
            let lo_mag = mag & !mask;
            let out_mag = if down != neg { lo_mag } else { lo_mag + (mask + 1) };
            *x = f64::from_bits(out_mag | (bits & (1u64 << 63)));
        };
        #[allow(unused_mut)] // mutated only on the x86-64 SIMD path
        let mut start = 0usize;
        #[cfg(target_arch = "x86_64")]
        {
            let eps_finite = match mode {
                Rounding::SrEps(e) | Rounding::SignedSrEps(e) => e.is_finite(),
                _ => true,
            };
            if k <= 52 && eps_finite && super::simd::avx2_active() {
                let n4 = xs.len() & !3;
                let mut elem_dyn = |x: &mut f64, v: f64, b: &mut BitBlock, r: &mut Rng| {
                    elem(x, v, b, r);
                };
                // SAFETY: gated on runtime AVX2 detection via avx2_active().
                unsafe {
                    super::simd::round_slice_stoch_avx2(
                        self,
                        mode,
                        &mut xs[..n4],
                        vs.map(|v| &v[..n4]),
                        &mut bsrc,
                        rng,
                        &mut elem_dyn,
                    );
                }
                start = n4;
            }
        }
        for (i, x) in xs.iter_mut().enumerate().skip(start) {
            let v = vs.map_or(*x, |vs| vs[i]);
            elem(x, v, &mut bsrc, rng);
        }
    }

    /// Fused deterministic slice kernel for fixed-point grids: the exact
    /// integer-quantization path per element (scale, `floor`, pick a side),
    /// bit-identical to the scalar [`RoundPlan::fast_fixed`] law. No
    /// randomness anywhere.
    fn round_slice_det_fixed(&self, mode: Rounding, xs: &mut [f64], rng: &mut Rng) {
        let (scale, delta, vmin, vmax) = (self.scale, self.delta, self.vmin, self.vmax);
        let (down_pos, down_neg) = match mode {
            Rounding::RoundDown => (true, true),
            Rounding::RoundUp => (false, false),
            _ => (true, false), // RZ: toward zero
        };
        let rn = mode == Rounding::RoundNearestEven;
        for x in xs.iter_mut() {
            if !(vmin..=vmax).contains(x) {
                if *x != 0.0 && !x.is_nan() {
                    *x = round_slow_grid(&self.grid, mode, *x, *x, rng); // rare slow path
                }
                continue;
            }
            let m = *x * scale;
            let k = m.floor();
            if k == m {
                continue; // on the grid
            }
            let frac = m - k;
            let down = if rn {
                if frac != 0.5 {
                    frac < 0.5
                } else {
                    (k as i64) & 1 == 0
                }
            } else if *x < 0.0 {
                down_neg
            } else {
                down_pos
            };
            *x = if down { k * delta } else { (k + 1.0) * delta };
        }
    }

    /// Fused stochastic slice kernel for fixed-point grids, over the same
    /// block-buffered few-random-bits source — and thus the same
    /// [`RoundPlan::sr_bits`] randomness contract — as the float kernel.
    /// `p_down(frac, neg, v)` receives the exact value-scale residual
    /// directly (uniform grids have no magnitude/value asymmetry to undo).
    fn round_slice_stoch_fixed<F: Fn(f64, bool, f64) -> f64>(
        &self,
        mode: Rounding,
        xs: &mut [f64],
        vs: Option<&[f64]>,
        p_down: F,
        rng: &mut Rng,
    ) {
        let (scale, delta, vmin, vmax) = (self.scale, self.delta, self.vmin, self.vmax);
        let (kbits, inv_sr) = (self.sr_bits, self.inv_sr);
        let plain_sr = matches!(mode, Rounding::Sr);
        let mut bsrc = BitBlock::for_elems(xs.len(), kbits);
        for (i, x) in xs.iter_mut().enumerate() {
            if !(vmin..=vmax).contains(x) {
                if *x != 0.0 && !x.is_nan() {
                    let v = vs.map_or(*x, |vs| vs[i]);
                    *x = round_slow_grid(&self.grid, mode, *x, v, rng); // rare slow path
                }
                continue;
            }
            let m = *x * scale;
            let k = m.floor();
            if k == m {
                continue; // on the grid
            }
            let frac = m - k;
            let p = if plain_sr {
                1.0 - frac
            } else {
                p_down(frac, *x < 0.0, vs.map_or(*x, |vs| vs[i]))
            };
            let r = bsrc.take(kbits, rng) as f64 * inv_sr;
            *x = if r < p { k * delta } else { (k + 1.0) * delta };
        }
    }
}

/// Round every entry of a slice in place (plain `v = x` steering) — free
/// wrapper building a [`RoundPlan`] per call; prefer the plan method when
/// rounding repeatedly into the same grid.
pub fn round_slice(grid: impl Into<Grid>, mode: Rounding, xs: &mut [f64], rng: &mut Rng) {
    RoundPlan::new(grid).round_slice(mode, xs, rng);
}

/// Round every entry, steering `SignedSrEps` per element by `vs` — free
/// wrapper over [`RoundPlan::round_slice_with`].
pub fn round_slice_with(
    grid: impl Into<Grid>,
    mode: Rounding,
    xs: &mut [f64],
    vs: &[f64],
    rng: &mut Rng,
) {
    RoundPlan::new(grid).round_slice_with(mode, xs, vs, rng);
}

// ------------------------------------------------- open-scheme dispatch --
//
// The `Scheme` entry points below are what the fused kernels, `LpCtx` and
// the GD engine call. Built-in schemes carry their `Rounding` tag
// (`Scheme::as_builtin`, cached at construction) and resolve to the exact
// monomorphized paths above — bit-identical to pre-trait dispatch; user
// schemes take a per-element dyn fallback through their scalar law.

impl RoundPlan {
    /// Round `x` under `scheme`, steering by `v` — the scheme-handle
    /// counterpart of [`RoundPlan::round_with`].
    #[inline]
    pub fn round_scheme_with(&self, scheme: Scheme, x: f64, v: f64, rng: &mut Rng) -> f64 {
        match scheme.as_builtin() {
            Some(mode) => self.round_with(mode, x, v, rng),
            None => scheme.as_impl().round(self, x, v, rng),
        }
    }

    /// Round `x` under `scheme` with `v = x`.
    #[inline]
    pub fn round_scheme(&self, scheme: Scheme, x: f64, rng: &mut Rng) -> f64 {
        self.round_scheme_with(scheme, x, x, rng)
    }

    /// Round every entry of a slice in place under `scheme` (plain `v = x`
    /// steering) — the scheme-handle counterpart of
    /// [`RoundPlan::round_slice`]. Built-ins run the fused kernels; user
    /// schemes loop their scalar law.
    pub fn round_slice_scheme(&self, scheme: Scheme, xs: &mut [f64], rng: &mut Rng) {
        match scheme.as_builtin() {
            Some(mode) => self.round_slice(mode, xs, rng),
            None => {
                let imp = scheme.as_impl();
                for x in xs.iter_mut() {
                    *x = imp.round(self, *x, *x, rng);
                }
            }
        }
    }

    /// Round every entry under `scheme`, steering steered schemes per
    /// element by `vs` — the scheme-handle counterpart of
    /// [`RoundPlan::round_slice_with`]. Unsteered schemes ignore `vs`
    /// (each element steers by itself), exactly as the enum path does.
    pub fn round_slice_scheme_with(
        &self,
        scheme: Scheme,
        xs: &mut [f64],
        vs: &[f64],
        rng: &mut Rng,
    ) {
        match scheme.as_builtin() {
            Some(mode) => self.round_slice_with(mode, xs, vs, rng),
            None if scheme.uses_steering() => {
                debug_assert_eq!(xs.len(), vs.len());
                let imp = scheme.as_impl();
                for (x, &v) in xs.iter_mut().zip(vs) {
                    *x = imp.round(self, *x, v, rng);
                }
            }
            None => self.round_slice_scheme(scheme, xs, rng),
        }
    }
}

// ---------------------------------------------------- multi-seed lanes --
//
// The structure-of-arrays lane mode: `lanes` independent repetitions of one
// experiment cell share a single data pass. A slab stores element `i` of
// lane `l` at `slab[i * lanes + l]` (element-major, lane-minor), so the
// per-element math vectorizes across lanes; each lane draws from its own
// generator through a shared `LaneBits` dispenser. The contract — asserted
// by `lanes_slice_matches_per_lane_scalar` and the engine-level lane tests
// — is that **lane `l` of a slab rounding is bit-identical to rounding lane
// `l`'s column with the scalar slice kernel and lane `l`'s generator**:
// lane width is an execution strategy, never part of a result's identity.

impl RoundPlan {
    /// Round a lane slab in place under `scheme`, steering steered schemes
    /// by `vs` (same slab layout) when supplied — the lane-batched
    /// counterpart of [`RoundPlan::round_slice_scheme_with`].
    ///
    /// `slab` holds `lanes` interleaved repetitions (element `i` of lane
    /// `l` at `i * lanes + l`); `rngs[l]` is lane `l`'s generator. Per
    /// lane, outputs and RNG consumption are bit-identical to the scalar
    /// slice kernels run on that lane's column.
    pub fn round_slice_lanes_scheme_with(
        &self,
        scheme: Scheme,
        slab: &mut [f64],
        lanes: usize,
        vs: Option<&[f64]>,
        rngs: &mut [Rng],
    ) {
        assert!(lanes >= 1, "lane batches need at least one lane");
        assert_eq!(slab.len() % lanes, 0, "slab length must be a multiple of the lane count");
        assert_eq!(rngs.len(), lanes, "one RNG stream per lane");
        if let Some(vs) = vs {
            debug_assert_eq!(vs.len(), slab.len());
        }
        match scheme.as_builtin() {
            Some(
                mode @ (Rounding::RoundNearestEven
                | Rounding::RoundDown
                | Rounding::RoundUp
                | Rounding::RoundTowardZero),
            ) => {
                // Deterministic rounding is elementwise and stateless: the
                // fused (and, when active, SIMD) det kernel over the whole
                // slab is already per-lane bit-identical. No randomness is
                // consumed on any det path, slow elements included.
                self.round_slice_det(mode, slab, &mut rngs[0]);
            }
            Some(mode @ Rounding::Sr) => {
                self.round_slice_stoch_lanes(mode, slab, lanes, None, |_, _, _| 0.0, rngs);
            }
            Some(mode @ Rounding::SrEps(eps)) => {
                self.round_slice_stoch_lanes(
                    mode,
                    slab,
                    lanes,
                    None,
                    |frac, neg, _| {
                        let sx = if neg { -1.0 } else { 1.0 };
                        phi(1.0 - frac - sx * eps)
                    },
                    rngs,
                );
            }
            Some(mode @ Rounding::SignedSrEps(eps)) => match vs {
                Some(vs) => self.round_slice_stoch_lanes(
                    mode,
                    slab,
                    lanes,
                    Some(vs),
                    |frac, _, v| {
                        let sv = if v == 0.0 { 0.0 } else { v.signum() };
                        phi(1.0 - frac + sv * eps)
                    },
                    rngs,
                ),
                None => self.round_slice_stoch_lanes(
                    mode,
                    slab,
                    lanes,
                    None,
                    |frac, neg, _| {
                        let sv = if neg { -1.0 } else { 1.0 };
                        phi(1.0 - frac + sv * eps)
                    },
                    rngs,
                ),
            },
            None => {
                // Custom schemes already take a per-element dyn path in the
                // scalar kernels; the lane loop replays exactly that, with
                // each lane's own generator.
                let imp = scheme.as_impl();
                let steer = scheme.uses_steering() && vs.is_some();
                let n = slab.len() / lanes;
                for i in 0..n {
                    for l in 0..lanes {
                        let idx = i * lanes + l;
                        let v = if steer { vs.unwrap()[idx] } else { slab[idx] };
                        slab[idx] = imp.round(self, slab[idx], v, &mut rngs[l]);
                    }
                }
            }
        }
    }

    /// Lane-batched stochastic slice kernel: the float/fixed per-element
    /// bodies of [`RoundPlan::round_slice_stoch`] replayed per `(element,
    /// lane)` with lane-private streams through a shared [`LaneBits`]
    /// dispenser.
    fn round_slice_stoch_lanes<F: Fn(f64, bool, f64) -> f64>(
        &self,
        mode: Rounding,
        slab: &mut [f64],
        lanes: usize,
        vs: Option<&[f64]>,
        p_down: F,
        rngs: &mut [Rng],
    ) {
        debug_assert!(mode.is_stochastic());
        let n = slab.len() / lanes;
        let (k, inv_sr) = (self.sr_bits, self.inv_sr);
        let plain_sr = matches!(mode, Rounding::Sr);
        let mut lb = LaneBits::for_elems(n, k, lanes);
        if let Grid::Fixed(_) = self.grid {
            let (scale, delta, vmin, vmax) = (self.scale, self.delta, self.vmin, self.vmax);
            for i in 0..n {
                for l in 0..lanes {
                    let idx = i * lanes + l;
                    let x = &mut slab[idx];
                    if !(vmin..=vmax).contains(x) {
                        if *x != 0.0 && !x.is_nan() {
                            let v = vs.map_or(*x, |vs| vs[idx]);
                            *x = round_slow_grid(&self.grid, mode, *x, v, &mut rngs[l]);
                        }
                        continue;
                    }
                    let m = *x * scale;
                    let kf = m.floor();
                    if kf == m {
                        continue; // on the grid
                    }
                    let frac = m - kf;
                    let p = if plain_sr {
                        1.0 - frac
                    } else {
                        p_down(frac, *x < 0.0, vs.map_or(*x, |vs| vs[idx]))
                    };
                    let r = lb.take(l, k, &mut rngs[l]) as f64 * inv_sr;
                    *x = if r < p { kf * delta } else { (kf + 1.0) * delta };
                }
            }
            return;
        }
        let (mask, inv) = (self.mask, self.inv_gap);
        let (e_min, e_max) = (self.e_min, self.e_max);
        for i in 0..n {
            for l in 0..lanes {
                let idx = i * lanes + l;
                let x = &mut slab[idx];
                let bits = x.to_bits();
                let mag = bits & 0x7fff_ffff_ffff_ffff;
                let raw_e = (mag >> 52) as i32;
                let e = raw_e - 1023;
                if raw_e == 0 || raw_e == 0x7ff || e < e_min || e >= e_max {
                    if *x != 0.0 && !x.is_nan() {
                        let v = vs.map_or(*x, |vs| vs[idx]);
                        *x = round_slow_grid(&self.grid, mode, *x, v, &mut rngs[l]);
                    }
                    continue;
                }
                let tail = mag & mask;
                if tail == 0 {
                    continue; // representable
                }
                let neg = bits >> 63 == 1;
                let frac_mag = tail as f64 * inv;
                let frac = if neg { 1.0 - frac_mag } else { frac_mag };
                let p = if plain_sr {
                    1.0 - frac
                } else {
                    p_down(frac, neg, vs.map_or(*x, |vs| vs[idx]))
                };
                let r = lb.take(l, k, &mut rngs[l]) as f64 * inv_sr;
                let down = r < p;
                let lo_mag = mag & !mask;
                let out_mag = if down != neg { lo_mag } else { lo_mag + (mask + 1) };
                *x = f64::from_bits(out_mag | (bits & (1u64 << 63)));
            }
        }
    }
}

// ------------------------------------------------------ numeric health --
//
// The paper's failure modes — saturation at the grid edge, vanishing
// updates, overflow to ±∞ — made observable at runtime. A rounding site is
// classified from its *transition* `before → after` (the exact value in
// and the grid value out), so the counters are a pure function of the
// trajectory and never perturb it: deterministic runs stay bit-identical
// with or without monitoring.

/// Counters of numerically notable events along one run (or one slice):
/// the observability half of the fault-tolerance layer (see
/// `docs/robustness.md`). Merge cell-level counters with
/// [`RunHealth::merge`]; a fresh default value means "nothing notable".
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RunHealth {
    /// Roundings that produced a non-finite output (±∞ or NaN) from a
    /// finite input — float RN overflow, or a NaN fabricated upstream.
    /// Non-finite *inputs* are propagation, not production, and are not
    /// recounted here.
    pub nan_inf: u64,
    /// Finite inputs outside the grid's representable range clamped to a
    /// finite saturation endpoint (every mode on a fixed-point grid,
    /// directed/stochastic modes on a float grid).
    pub saturations: u64,
    /// Nonzero finite inputs rounded to exactly zero — the underflow /
    /// absorption mechanism behind RN stagnation.
    pub underflows: u64,
    /// GD steps on which the iterate did not move at all (x̂⁺ == x̂) —
    /// accumulated by the engine, not by the slice kernels.
    pub stalled_steps: u64,
    /// GD steps observed (denominator for the per-step rates).
    pub steps: u64,
}

impl RunHealth {
    /// Fold another counter set into this one (sweep-level aggregation).
    pub fn merge(&mut self, other: &RunHealth) {
        self.nan_inf += other.nan_inf;
        self.saturations += other.saturations;
        self.underflows += other.underflows;
        self.stalled_steps += other.stalled_steps;
        self.steps += other.steps;
    }

    /// True when no numeric event was recorded (stalls included: a fully
    /// clean run both stayed finite and kept moving).
    pub fn is_clean(&self) -> bool {
        self.nan_inf == 0 && self.saturations == 0 && self.underflows == 0 && self.stalled_steps == 0
    }

    /// Compact one-line rendering for logs and table notes, e.g.
    /// `nan_inf=0 sat=12 underflow=3 stalled=40/200`.
    pub fn summary(&self) -> String {
        format!(
            "nan_inf={} sat={} underflow={} stalled={}/{}",
            self.nan_inf, self.saturations, self.underflows, self.stalled_steps, self.steps
        )
    }
}

impl RoundPlan {
    /// Classify one rounding transition `before → after` into `health`.
    /// `before` is the exact (binary64) value that entered the rounding,
    /// `after` the grid value that left it. Inline and branch-cheap: the
    /// fused health kernels call this once per element after rounding.
    #[inline]
    pub fn classify(&self, before: f64, after: f64, health: &mut RunHealth) {
        if !before.is_finite() {
            return; // propagation of an already-counted event
        }
        if !after.is_finite() {
            health.nan_inf += 1;
        } else if !self.grid.in_range(before) {
            health.saturations += 1;
        } else if before != 0.0 && after == 0.0 {
            health.underflows += 1;
        }
    }

    /// Classify a whole pre-image/image slice pair (the slice counterpart
    /// of [`RoundPlan::classify`]).
    pub fn classify_slice(&self, before: &[f64], after: &[f64], health: &mut RunHealth) {
        debug_assert_eq!(before.len(), after.len());
        for (&b, &a) in before.iter().zip(after) {
            self.classify(b, a, health);
        }
    }

    /// [`RoundPlan::round_slice_scheme_with`] plus health accounting: the
    /// pre-image is snapshotted, the slice is rounded through the ordinary
    /// fused kernels (same RNG consumption, hence bit-identical outputs),
    /// and every transition is classified into `health`. Allocates one
    /// scratch buffer per call; the GD hot path avoids even that by
    /// recomputing its pre-images (see `fp::kernels::gd_update_health`).
    pub fn round_slice_scheme_health(
        &self,
        scheme: Scheme,
        xs: &mut [f64],
        vs: &[f64],
        rng: &mut Rng,
        health: &mut RunHealth,
    ) {
        let before = xs.to_vec();
        self.round_slice_scheme_with(scheme, xs, vs, rng);
        self.classify_slice(&before, xs, health);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const B8: FpFormat = FpFormat::BINARY8;

    #[test]
    fn representable_values_are_fixed_points() {
        let mut rng = Rng::new(0);
        for mode in [
            Rounding::RoundNearestEven,
            Rounding::RoundDown,
            Rounding::RoundUp,
            Rounding::RoundTowardZero,
            Rounding::Sr,
            Rounding::SrEps(0.3),
            Rounding::SignedSrEps(0.3),
        ] {
            for &x in &[0.0, 1.0, -1.25, 1024.0, B8.x_min(), B8.x_min_sub(), -B8.x_max()] {
                assert_eq!(round(&B8, mode, x, &mut rng), x, "{mode:?} x={x}");
            }
        }
    }

    #[test]
    fn deterministic_modes() {
        let mut rng = Rng::new(0);
        // x = 1.1 ∈ (1.0, 1.25) in binary8.
        assert_eq!(round(&B8, Rounding::RoundDown, 1.1, &mut rng), 1.0);
        assert_eq!(round(&B8, Rounding::RoundUp, 1.1, &mut rng), 1.25);
        assert_eq!(round(&B8, Rounding::RoundTowardZero, 1.1, &mut rng), 1.0);
        assert_eq!(round(&B8, Rounding::RoundTowardZero, -1.1, &mut rng), -1.0);
        assert_eq!(round(&B8, Rounding::RoundNearestEven, 1.1, &mut rng), 1.0);
        assert_eq!(round(&B8, Rounding::RoundNearestEven, 1.2, &mut rng), 1.25);
    }

    #[test]
    fn rn_ties_to_even() {
        let mut rng = Rng::new(0);
        // Midpoint of (1.0, 1.25): 1.125. Significands: 1.0 → m=4 (even),
        // 1.25 → m=5 (odd) at spacing 0.25 ⇒ tie goes to 1.0.
        assert_eq!(round(&B8, Rounding::RoundNearestEven, 1.125, &mut rng), 1.0);
        // Midpoint of (1.25, 1.5): 1.375 → 1.5 (m=6 even).
        assert_eq!(round(&B8, Rounding::RoundNearestEven, 1.375, &mut rng), 1.5);
        // Negative mirror.
        assert_eq!(round(&B8, Rounding::RoundNearestEven, -1.125, &mut rng), -1.0);
    }

    #[test]
    fn rn_overflow_to_infinity() {
        let mut rng = Rng::new(0);
        let xmax = B8.x_max(); // 57344, ulp = 2^13 = 8192
        assert_eq!(round(&B8, Rounding::RoundNearestEven, xmax + 4095.0, &mut rng), xmax);
        assert_eq!(round(&B8, Rounding::RoundNearestEven, xmax + 4096.0, &mut rng), f64::INFINITY);
        assert_eq!(round(&B8, Rounding::RoundNearestEven, -(xmax + 5000.0), &mut rng), f64::NEG_INFINITY);
    }

    #[test]
    fn stochastic_saturates_no_infinity() {
        let mut rng = Rng::new(0);
        for _ in 0..100 {
            let y = round(&B8, Rounding::Sr, 60000.0, &mut rng);
            assert_eq!(y, B8.x_max());
        }
    }

    /// Monte-Carlo false-failure bound for this module's empirical-mean
    /// tests: every draw lies in `[⌊x⌋, ⌈x⌉]` (one gap), so by Hoeffding
    /// each assertion fails spuriously with probability at most
    /// `MC_P_FAIL`. The value is chosen so the half-width coincides with
    /// the historic `4·gap/√n` tolerance (`ln(2/p) ≈ 32`), i.e. the
    /// fixed-seed outcomes are unchanged — the bound is now just explicit
    /// (see `util::stats::hoeffding_halfwidth` and docs/testing.md).
    const MC_P_FAIL: f64 = 2.5e-14;

    /// SR empirical mean ≈ x (zero bias, Definition 1). Fixed seed;
    /// spurious-failure probability ≤ `MC_P_FAIL` per input (Hoeffding).
    #[test]
    fn sr_is_unbiased() {
        let mut rng = Rng::new(42);
        for &x in &[1.1, 1.24, -2.6, 0.001, 1030.0] {
            let n = 40_000;
            let mean: f64 = (0..n).map(|_| round(&B8, Rounding::Sr, x, &mut rng)).sum::<f64>() / n as f64;
            let (lo, hi) = B8.floor_ceil(x);
            let tol = crate::util::stats::hoeffding_halfwidth(hi - lo, n, MC_P_FAIL);
            assert!((mean - x).abs() < tol, "x={x} mean={mean} tol={tol}");
        }
    }

    /// SRε bias has the sign of x and magnitude ε·(⌈x⌉−⌊x⌋) in the interior
    /// regime (eq. (3) middle case). Fixed seed; spurious-failure
    /// probability ≤ `MC_P_FAIL` per input (Hoeffding).
    #[test]
    fn sr_eps_bias_matches_eq3() {
        let mut rng = Rng::new(7);
        let eps = 0.25;
        for &x in &[1.1, -1.1, 3.3, -900.0] {
            let (lo, hi) = B8.floor_ceil(x);
            let frac = (x - lo) / (hi - lo);
            let eta = 1.0 - frac - x.signum() * eps;
            if !(0.0..=1.0).contains(&eta) {
                continue; // pick interior cases only
            }
            let n = 60_000;
            let mean: f64 =
                (0..n).map(|_| round(&B8, Rounding::SrEps(eps), x, &mut rng)).sum::<f64>() / n as f64;
            let expected_bias = x.signum() * eps * (hi - lo);
            let tol = crate::util::stats::hoeffding_halfwidth(hi - lo, n, MC_P_FAIL);
            assert!(
                ((mean - x) - expected_bias).abs() < tol,
                "x={x} bias={} expected={expected_bias}",
                mean - x
            );
        }
    }

    /// signed-SRε bias has the sign of −v (eq. (4) middle case). Fixed
    /// seed; spurious-failure probability ≤ `MC_P_FAIL` per pair.
    #[test]
    fn signed_sr_eps_bias_opposes_v() {
        let mut rng = Rng::new(9);
        let eps = 0.25;
        for &(x, v) in &[(1.1, 1.0), (1.1, -1.0), (-1.1, 1.0), (-1.1, -1.0)] {
            let (lo, hi) = B8.floor_ceil(x);
            let n = 60_000;
            let mean: f64 = (0..n)
                .map(|_| round_with(&B8, Rounding::SignedSrEps(eps), x, v, &mut rng))
                .sum::<f64>()
                / n as f64;
            let expected_bias = -v.signum() * eps * (hi - lo);
            let tol = crate::util::stats::hoeffding_halfwidth(hi - lo, n, MC_P_FAIL);
            assert!(
                ((mean - x) - expected_bias).abs() < tol,
                "x={x} v={v} bias={} expected={expected_bias}",
                mean - x
            );
        }
    }

    /// Closed-form expectation matches the empirical mean for all schemes.
    /// Fixed seed; spurious-failure probability ≤ `MC_P_FAIL` per case.
    #[test]
    fn expected_round_matches_empirical() {
        let mut rng = Rng::new(3);
        for mode in [Rounding::Sr, Rounding::SrEps(0.4), Rounding::SignedSrEps(0.15)] {
            for &(x, v) in &[(1.07, -2.0), (-5.3, 1.0), (0.011, 0.5)] {
                let n = 60_000;
                let mean: f64 =
                    (0..n).map(|_| round_with(&B8, mode, x, v, &mut rng)).sum::<f64>() / n as f64;
                let exp = expected_round(&B8, mode, x, v);
                let (lo, hi) = B8.floor_ceil(x);
                let tol = crate::util::stats::hoeffding_halfwidth(hi - lo, n, MC_P_FAIL);
                assert!((mean - exp).abs() < tol, "{mode:?} x={x}: {mean} vs {exp}");
            }
        }
    }

    /// Lemma 1: 0 ≤ E[δ^{SRε}(x)] ≤ 2εu for all nonzero x.
    #[test]
    fn lemma1_relative_bias_bound() {
        let eps = 0.3;
        let u = B8.unit_roundoff();
        let mut vals = vec![];
        let mut t = 0.013;
        while t < 2.0e4 {
            vals.push(t);
            vals.push(-t);
            t *= 1.7;
        }
        for &x in &vals {
            let e = expected_round(&B8, Rounding::SrEps(eps), x, x);
            let rel = (e - x) / x;
            assert!(rel >= -1e-15, "x={x} rel={rel}");
            assert!(rel <= 2.0 * eps * u + 1e-15, "x={x} rel={rel} bound={}", 2.0 * eps * u);
        }
    }

    /// With ε = 0 both new schemes coincide with SR in expectation.
    #[test]
    fn eps_zero_degenerates_to_sr() {
        for &x in &[1.1, -2.6, 100.3] {
            let e_sr = expected_round(&B8, Rounding::Sr, x, x);
            let e_eps = expected_round(&B8, Rounding::SrEps(0.0), x, x);
            let e_sgn = expected_round(&B8, Rounding::SignedSrEps(0.0), x, -x);
            assert!((e_sr - e_eps).abs() < 1e-15);
            assert!((e_sr - e_sgn).abs() < 1e-15);
        }
    }

    /// With v = x, signed-SRε(x) has the same law as SRε mirrored: per
    /// Definition 3, sign(v)=sign(x) gives p̂ = φ(1 − frac + sign(x)ε) — the
    /// bias *toward zero* variant; check the closed forms are consistent.
    #[test]
    fn signed_with_v_eq_x_biases_toward_zero() {
        let eps = 0.25;
        for &x in &[1.1, -1.1] {
            let e = expected_round(&B8, Rounding::SignedSrEps(eps), x, x);
            // bias sign must be −sign(x): toward zero
            assert!((e - x) * x.signum() < 0.0, "x={x} e={e}");
        }
    }

    fn test_inputs(fmt: &FpFormat, n: usize) -> (Vec<f64>, Vec<f64>) {
        let mut gen = Rng::new(77);
        // Mix of normals, subnormals, representables, overflow, specials.
        let mut xs: Vec<f64> = (0..n).map(|_| gen.normal() * 1e3).collect();
        xs.extend([
            0.0,
            1.0,
            -1.25,
            fmt.x_min() * 0.3,
            -fmt.x_min_sub() * 0.5,
            fmt.x_max() * 1.5,
            f64::NAN,
            f64::INFINITY,
        ]);
        let vs: Vec<f64> = (0..xs.len()).map(|_| gen.normal()).collect();
        (xs, vs)
    }

    /// The plan-based scalar path is bit-identical to the scalar reference
    /// path for *every* mode, drawing the same number of uniforms in the
    /// same order (the historic reference semantics).
    #[test]
    fn round_plan_scalar_matches_reference() {
        let modes = [
            Rounding::RoundNearestEven,
            Rounding::RoundDown,
            Rounding::RoundUp,
            Rounding::RoundTowardZero,
            Rounding::Sr,
            Rounding::SrEps(0.3),
            Rounding::SignedSrEps(0.3),
        ];
        for fmt in [FpFormat::BINARY8, FpFormat::BFLOAT16, FpFormat::BINARY64] {
            let plan = RoundPlan::new(fmt);
            let (xs, vs) = test_inputs(&fmt, 200);
            for mode in modes {
                let mut ra = Rng::new(5);
                let mut rb = Rng::new(5);
                for (&x, &v) in xs.iter().zip(&vs) {
                    let want = round_with(&fmt, mode, x, v, &mut ra);
                    let got = plan.round_with(mode, x, v, &mut rb);
                    assert!(
                        want == got || (want.is_nan() && got.is_nan()),
                        "{mode:?} {} x={x}: {want} vs {got}",
                        fmt.name()
                    );
                }
                assert_eq!(ra.next_u64(), rb.next_u64(), "RNG streams diverged");
            }
        }
    }

    /// Deterministic-mode slice kernels are bit-identical to the scalar path
    /// (the engine's deterministic trajectory contract rests on this).
    #[test]
    fn slice_deterministic_matches_scalar() {
        let modes = [
            Rounding::RoundNearestEven,
            Rounding::RoundDown,
            Rounding::RoundUp,
            Rounding::RoundTowardZero,
        ];
        for fmt in [FpFormat::BINARY8, FpFormat::BFLOAT16, FpFormat::BINARY64] {
            let plan = RoundPlan::new(fmt);
            let (xs, vs) = test_inputs(&fmt, 300);
            for mode in modes {
                let mut rng = Rng::new(9);
                let mut buf = xs.clone();
                plan.round_slice_with(mode, &mut buf, &vs, &mut rng);
                let mut rd = Rng::new(9);
                for (i, &x) in xs.iter().enumerate() {
                    let want = round_with(&fmt, mode, x, vs[i], &mut rd);
                    assert!(
                        want == buf[i] || (want.is_nan() && buf[i].is_nan()),
                        "slice {mode:?} {} i={i} x={x}: {want} vs {}",
                        fmt.name(),
                        buf[i]
                    );
                }
                // Deterministic modes consume no randomness at all.
                assert_eq!(rng.next_u64(), rd.next_u64(), "det mode consumed randomness");
                // And the unsteered kernel agrees.
                let mut buf2 = xs.clone();
                plan.round_slice(mode, &mut buf2, &mut Rng::new(1));
                for (a, b) in buf.iter().zip(&buf2) {
                    assert!(a == b || (a.is_nan() && b.is_nan()));
                }
            }
        }
    }

    /// Stochastic slice kernels: outputs are always saturated neighbors of
    /// the input, the kernel is a pure function of the RNG state
    /// (reproducible), and distinct seeds give distinct streams.
    #[test]
    fn slice_stochastic_neighbors_and_reproducible() {
        let modes = [Rounding::Sr, Rounding::SrEps(0.3), Rounding::SignedSrEps(0.3)];
        for fmt in [FpFormat::BINARY8, FpFormat::BFLOAT16] {
            let plan = RoundPlan::new(fmt);
            let (xs, vs) = test_inputs(&fmt, 400);
            for mode in modes {
                let mut out1 = xs.clone();
                plan.round_slice_with(mode, &mut out1, &vs, &mut Rng::new(3));
                let mut out2 = xs.clone();
                plan.round_slice_with(mode, &mut out2, &vs, &mut Rng::new(3));
                let mut out3 = xs.clone();
                plan.round_slice_with(mode, &mut out3, &vs, &mut Rng::new(4));
                let mut any_diff = false;
                for i in 0..xs.len() {
                    let (a, b) = (out1[i], out2[i]);
                    assert!(a == b || (a.is_nan() && b.is_nan()), "{mode:?} not reproducible");
                    any_diff |= out1[i] != out3[i];
                    let x = xs[i];
                    if x.is_nan() {
                        assert!(a.is_nan());
                        continue;
                    }
                    let (lo, hi) = fmt.floor_ceil(x);
                    let (slo, shi) = (saturate(&fmt, lo), saturate(&fmt, hi));
                    assert!(
                        a == lo || a == hi || a == slo || a == shi,
                        "{mode:?} {}: {a} not a neighbor of {x}",
                        fmt.name()
                    );
                }
                assert!(any_diff, "{mode:?}: distinct seeds produced identical streams");
            }
        }
    }

    /// The few-random-bits slice kernel stays unbiased for SR (and keeps the
    /// eq. (3) bias for SRε) at both the default and an aggressively small
    /// bit width — the probability quantization of `2^{-bits}` gaps is far
    /// below the statistical tolerance.
    #[test]
    fn slice_few_bits_sr_unbiased() {
        for bits in [DEFAULT_SR_BITS, 8] {
            let plan = RoundPlan::new(B8).with_sr_bits(bits);
            let mut rng = Rng::new(11);
            for &x in &[1.1, -2.6, 0.3] {
                let n = 40_000usize;
                let mut buf = vec![x; n];
                plan.round_slice(Rounding::Sr, &mut buf, &mut rng);
                let mean = buf.iter().sum::<f64>() / n as f64;
                let (lo, hi) = B8.floor_ceil(x);
                let gap = hi - lo;
                // Hoeffding tolerance (fails spuriously w.p. ≤ MC_P_FAIL)
                // plus the few-bits probability-quantization allowance.
                let tol = crate::util::stats::hoeffding_halfwidth(gap, n, MC_P_FAIL)
                    + gap * inv_pow2(bits);
                assert!((mean - x).abs() < tol, "bits={bits} x={x} mean={mean} tol={tol}");
            }
        }
    }

    /// Steered signed-SRε via the slice kernel keeps the Definition-3 law:
    /// the empirical mean matches the closed form per steering sign.
    #[test]
    fn slice_signed_sr_eps_matches_expectation() {
        let eps = 0.25;
        let plan = RoundPlan::new(B8);
        let mut rng = Rng::new(21);
        for &(x, v) in &[(1.1, 1.0), (1.1, -1.0), (-1.1, 1.0), (-1.1, -1.0)] {
            let n = 40_000usize;
            let mut buf = vec![x; n];
            let vs = vec![v; n];
            plan.round_slice_with(Rounding::SignedSrEps(eps), &mut buf, &vs, &mut rng);
            let mean = buf.iter().sum::<f64>() / n as f64;
            let want = expected_round(&B8, Rounding::SignedSrEps(eps), x, v);
            let (lo, hi) = B8.floor_ceil(x);
            // Fixed seed; fails spuriously w.p. ≤ MC_P_FAIL per pair.
            let tol = crate::util::stats::hoeffding_halfwidth(hi - lo, n, MC_P_FAIL);
            assert!((mean - want).abs() < tol, "x={x} v={v}: {mean} vs {want}");
        }
    }

    #[test]
    fn parse_labels_roundtrip() {
        for (s, m) in [
            ("rn", Rounding::RoundNearestEven),
            ("sr", Rounding::Sr),
            ("SR", Rounding::Sr),
            ("sr_eps:0.1", Rounding::SrEps(0.1)),
            ("signed:0.4", Rounding::SignedSrEps(0.4)),
            ("rd", Rounding::RoundDown),
            ("ru", Rounding::RoundUp),
            ("rz", Rounding::RoundTowardZero),
        ] {
            assert_eq!(Rounding::parse(s), Ok(m));
        }
        let err = Rounding::parse("bogus").unwrap_err().to_string();
        assert!(err.contains("bogus") && err.contains("signed_sr_eps"), "{err}");
    }

    /// The `Scheme`-handle dispatch is bit-identical to the enum paths for
    /// every built-in mode, scalar and slice, consuming the same stream.
    #[test]
    fn scheme_dispatch_matches_enum_paths_bitwise() {
        let modes = [
            Rounding::RoundNearestEven,
            Rounding::RoundDown,
            Rounding::RoundUp,
            Rounding::RoundTowardZero,
            Rounding::Sr,
            Rounding::SrEps(0.3),
            Rounding::SignedSrEps(0.3),
        ];
        for fmt in [FpFormat::BINARY8, FpFormat::BFLOAT16] {
            let plan = RoundPlan::new(fmt);
            let (xs, vs) = test_inputs(&fmt, 250);
            for mode in modes {
                let scheme = mode.scheme();
                // Scalar.
                let (mut ra, mut rb) = (Rng::new(13), Rng::new(13));
                for (&x, &v) in xs.iter().zip(&vs) {
                    let want = plan.round_with(mode, x, v, &mut ra);
                    let got = plan.round_scheme_with(scheme, x, v, &mut rb);
                    assert!(
                        want == got || (want.is_nan() && got.is_nan()),
                        "{mode:?} scalar x={x}"
                    );
                }
                assert_eq!(ra.next_u64(), rb.next_u64(), "{mode:?} scalar stream");
                // Slice, steered.
                let (mut ra, mut rb) = (Rng::new(14), Rng::new(14));
                let mut a = xs.clone();
                let mut b = xs.clone();
                plan.round_slice_with(mode, &mut a, &vs, &mut ra);
                plan.round_slice_scheme_with(scheme, &mut b, &vs, &mut rb);
                for (x, y) in a.iter().zip(&b) {
                    assert!(x == y || (x.is_nan() && y.is_nan()), "{mode:?} slice");
                }
                assert_eq!(ra.next_u64(), rb.next_u64(), "{mode:?} slice stream");
            }
        }
    }

    // ------------------------------------------------ fixed-point backend --

    const Q3_8: FixedPoint = FixedPoint::q(3, 8); // δ=2^-8, range [-8, 8)

    fn fixed_test_inputs(fx: &FixedPoint, n: usize) -> (Vec<f64>, Vec<f64>) {
        let mut gen = Rng::new(31);
        let mut xs: Vec<f64> = (0..n).map(|_| gen.normal() * 2.0).collect();
        xs.extend([
            0.0,
            1.0,
            fx.delta(),
            -3.0 * fx.delta(),
            fx.max_value(),
            fx.min_value(),
            fx.max_value() + 0.3 * fx.delta(),
            fx.max_value() * 4.0,
            fx.min_value() - 2.0,
            f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
        ]);
        let vs: Vec<f64> = (0..xs.len()).map(|_| gen.normal()).collect();
        (xs, vs)
    }

    /// Fixed-point scalar rounding: representable fixed points, neighbor
    /// residency, deterministic directed modes, RN ties-to-even-k, and the
    /// saturation contract (no ±∞ under any mode).
    #[test]
    fn fixed_scalar_modes_and_saturation() {
        let plan = RoundPlan::new(Q3_8);
        let mut rng = Rng::new(0);
        let d = Q3_8.delta();
        let x = 1.0 + 0.3 * d; // strictly inside a gap
        assert_eq!(plan.round_with(Rounding::RoundDown, x, x, &mut rng), 1.0);
        assert_eq!(plan.round_with(Rounding::RoundUp, x, x, &mut rng), 1.0 + d);
        assert_eq!(plan.round_with(Rounding::RoundTowardZero, x, x, &mut rng), 1.0);
        assert_eq!(plan.round_with(Rounding::RoundTowardZero, -x, -x, &mut rng), -1.0);
        assert_eq!(plan.round_with(Rounding::RoundNearestEven, x, x, &mut rng), 1.0);
        // Ties to even stored integer: 1.0 = 256δ (even) vs 1.0+δ (odd).
        assert_eq!(plan.round_with(Rounding::RoundNearestEven, 1.0 + 0.5 * d, 0.0, &mut rng), 1.0);
        // (1.0+δ, 1.0+2δ) midpoint → 1.0+2δ (even k=258).
        assert_eq!(
            plan.round_with(Rounding::RoundNearestEven, 1.0 + 1.5 * d, 0.0, &mut rng),
            1.0 + 2.0 * d
        );
        // Saturation: every mode clamps out-of-range values, never ±∞.
        for mode in [
            Rounding::RoundNearestEven,
            Rounding::RoundDown,
            Rounding::RoundUp,
            Rounding::RoundTowardZero,
            Rounding::Sr,
            Rounding::SrEps(0.3),
            Rounding::SignedSrEps(0.3),
        ] {
            for &(x, want) in &[
                (100.0, Q3_8.max_value()),
                (f64::INFINITY, Q3_8.max_value()),
                (-100.0, Q3_8.min_value()),
                (f64::NEG_INFINITY, Q3_8.min_value()),
            ] {
                assert_eq!(plan.round_with(mode, x, x, &mut rng), want, "{mode:?} x={x}");
            }
            // Representable values are fixed points.
            for &x in &[0.0, 1.0, -1.0, Q3_8.max_value(), Q3_8.min_value(), 3.0 * d] {
                assert_eq!(plan.round_with(mode, x, x, &mut rng), x, "{mode:?} x={x}");
            }
        }
        assert!(plan.round_with(Rounding::Sr, f64::NAN, 0.0, &mut rng).is_nan());
    }

    /// Fixed-point slice kernels: deterministic modes bit-identical to the
    /// scalar path consuming zero randomness; stochastic modes resident,
    /// reproducible and seed-sensitive — the same contract as the float
    /// kernels.
    #[test]
    fn fixed_slice_kernels_match_contract() {
        let plan = RoundPlan::new(Q3_8);
        let (xs, vs) = fixed_test_inputs(&Q3_8, 300);
        for mode in [
            Rounding::RoundNearestEven,
            Rounding::RoundDown,
            Rounding::RoundUp,
            Rounding::RoundTowardZero,
        ] {
            let mut rng = Rng::new(9);
            let mut buf = xs.clone();
            plan.round_slice_with(mode, &mut buf, &vs, &mut rng);
            let mut rd = Rng::new(9);
            for (i, &x) in xs.iter().enumerate() {
                let want = plan.round_with(mode, x, vs[i], &mut rd);
                assert!(
                    want == buf[i] || (want.is_nan() && buf[i].is_nan()),
                    "fixed slice {mode:?} i={i} x={x}: {want} vs {}",
                    buf[i]
                );
            }
            assert_eq!(rng.next_u64(), rd.next_u64(), "det mode consumed randomness");
        }
        for mode in [Rounding::Sr, Rounding::SrEps(0.3), Rounding::SignedSrEps(0.3)] {
            let mut a = xs.clone();
            plan.round_slice_with(mode, &mut a, &vs, &mut Rng::new(3));
            let mut b = xs.clone();
            plan.round_slice_with(mode, &mut b, &vs, &mut Rng::new(3));
            let mut c = xs.clone();
            plan.round_slice_with(mode, &mut c, &vs, &mut Rng::new(4));
            let mut any_diff = false;
            for i in 0..xs.len() {
                assert!(
                    a[i] == b[i] || (a[i].is_nan() && b[i].is_nan()),
                    "{mode:?} not reproducible"
                );
                any_diff |= a[i] != c[i];
                if xs[i].is_nan() {
                    assert!(a[i].is_nan());
                    continue;
                }
                let (lo, hi) = Q3_8.floor_ceil(xs[i]);
                let sat =
                    |y: f64| y.clamp(NumberGrid::min_value(&Q3_8), NumberGrid::max_value(&Q3_8));
                assert!(
                    a[i] == lo || a[i] == hi || a[i] == sat(lo) || a[i] == sat(hi),
                    "{mode:?}: {} not a (saturated) neighbor of {}",
                    a[i],
                    xs[i]
                );
                assert!(a[i].is_finite(), "{mode:?} produced non-finite {}", a[i]);
            }
            assert!(any_diff, "{mode:?}: seeds 3 and 4 gave identical streams");
        }
    }

    /// SR on a fixed-point grid is unbiased and SRε keeps the eq. (3) bias
    /// shape — the laws transfer verbatim to the uniform grid. Fixed
    /// seeds; spurious-failure probability ≤ `MC_P_FAIL` per assertion.
    #[test]
    fn fixed_sr_laws_hold() {
        let plan = RoundPlan::new(Q3_8);
        let d = Q3_8.delta();
        let mut rng = Rng::new(42);
        for &x in &[1.0 + 0.3 * d, -2.0 - 0.7 * d, 0.41 * d] {
            let n = 40_000usize;
            let mut buf = vec![x; n];
            plan.round_slice(Rounding::Sr, &mut buf, &mut rng);
            let mean = buf.iter().sum::<f64>() / n as f64;
            let tol = crate::util::stats::hoeffding_halfwidth(d, n, MC_P_FAIL)
                + d * inv_pow2(plan.sr_bits());
            assert!((mean - x).abs() < tol, "x={x} mean={mean} tol={tol}");
        }
        // Closed-form expectation matches the empirical mean for SRε.
        let eps = 0.25;
        let x = 1.0 + 0.4 * d;
        let n = 60_000usize;
        let mut buf = vec![x; n];
        plan.round_slice(Rounding::SrEps(eps), &mut buf, &mut rng);
        let mean = buf.iter().sum::<f64>() / n as f64;
        let want = expected_round(Q3_8, Rounding::SrEps(eps), x, x);
        assert!((want - x - eps * d).abs() < 1e-12, "closed form bias must be eps*delta");
        let tol = crate::util::stats::hoeffding_halfwidth(d, n, MC_P_FAIL);
        assert!((mean - want).abs() < tol, "mean={mean} want={want}");
    }

    /// The `Scheme`-handle dispatch runs the fused fixed kernels for
    /// built-ins: bit-identical to the enum path on a fixed grid too.
    #[test]
    fn fixed_scheme_dispatch_matches_enum() {
        let plan = RoundPlan::new(Q3_8);
        let (xs, vs) = fixed_test_inputs(&Q3_8, 200);
        for mode in [Rounding::RoundNearestEven, Rounding::Sr, Rounding::SignedSrEps(0.25)] {
            let scheme = mode.scheme();
            let (mut ra, mut rb) = (Rng::new(14), Rng::new(14));
            let mut a = xs.clone();
            let mut b = xs.clone();
            plan.round_slice_with(mode, &mut a, &vs, &mut ra);
            plan.round_slice_scheme_with(scheme, &mut b, &vs, &mut rb);
            for (x, y) in a.iter().zip(&b) {
                assert!(x == y || (x.is_nan() && y.is_nan()), "{mode:?} fixed slice");
            }
            assert_eq!(ra.next_u64(), rb.next_u64(), "{mode:?} fixed stream");
        }
    }

    /// `classify` sorts transitions into exactly one counter: overflow to
    /// ±∞ is `nan_inf`, an out-of-range clamp is a saturation, a vanished
    /// nonzero value is an underflow, and non-finite *inputs* (propagation)
    /// count nowhere.
    #[test]
    fn classify_separates_the_event_kinds() {
        let plan = RoundPlan::new(B8);
        let xmax = B8.x_max();
        let mut h = RunHealth::default();
        plan.classify(xmax * 4.0, f64::INFINITY, &mut h); // RN overflow
        plan.classify(xmax * 4.0, xmax, &mut h); // directed/SR clamp
        plan.classify(-xmax * 4.0, -xmax, &mut h); // clamp, other sign
        plan.classify(B8.x_min_sub() * 0.1, 0.0, &mut h); // underflow
        plan.classify(f64::INFINITY, f64::INFINITY, &mut h); // propagation
        plan.classify(f64::NAN, f64::NAN, &mut h); // propagation
        plan.classify(1.0, 1.0, &mut h); // clean
        assert_eq!(
            h,
            RunHealth { nan_inf: 1, saturations: 2, underflows: 1, stalled_steps: 0, steps: 0 }
        );
        assert!(!h.is_clean());
        assert!(RunHealth::default().is_clean());
        let mut total = RunHealth::default();
        total.merge(&h);
        total.merge(&h);
        assert_eq!(total.saturations, 4);
        assert!(h.summary().contains("sat=2"));
    }

    /// The health wrapper is bit-identical to the plain fused kernel (same
    /// outputs, same RNG stream) and its counters match a per-element
    /// oracle on a fixed grid, where every mode saturates.
    #[test]
    fn round_slice_scheme_health_matches_plain_kernel() {
        let plan = RoundPlan::new(Q3_8);
        let (mut xs, vs) = fixed_test_inputs(&Q3_8, 300);
        // Salt in out-of-range and vanishing values at known positions.
        xs[3] = 100.0;
        xs[7] = -100.0;
        xs[11] = f64::NAN;
        for scheme in [Rounding::RoundNearestEven.scheme(), Rounding::Sr.scheme()] {
            let (mut ra, mut rb) = (Rng::new(21), Rng::new(21));
            let mut plain = xs.clone();
            let mut monitored = xs.clone();
            plan.round_slice_scheme_with(scheme, &mut plain, &vs, &mut ra);
            let mut h = RunHealth::default();
            plan.round_slice_scheme_health(scheme, &mut monitored, &vs, &mut rb, &mut h);
            for (x, y) in plain.iter().zip(&monitored) {
                assert!(x == y || (x.is_nan() && y.is_nan()));
            }
            assert_eq!(ra.next_u64(), rb.next_u64(), "health wrapper must not re-stream");
            let oracle_sat =
                xs.iter().filter(|v| v.is_finite() && !plan.grid.in_range(**v)).count() as u64;
            assert_eq!(h.saturations, oracle_sat);
            assert_eq!(h.nan_inf, 0, "fixed grids never produce non-finite outputs");
        }
    }

    // ------------------------------------------------------ SIMD dispatch --

    /// Forced-scalar and forced-AVX2 backends are bit-identical for every
    /// mode — outputs *and* RNG end state (the vector stochastic kernel
    /// preserves the scalar draw stream). On hosts without AVX2 the forced
    /// request falls back to scalar and the comparison is trivially true;
    /// the CI AVX2 lane keeps the vector side honest.
    #[test]
    fn simd_backends_agree_bitwise_and_stream() {
        use super::super::simd::{set_backend, SimdChoice, BACKEND_TEST_LOCK};
        let _lock = BACKEND_TEST_LOCK.lock().unwrap();
        let modes = [
            Rounding::RoundNearestEven,
            Rounding::RoundDown,
            Rounding::RoundUp,
            Rounding::RoundTowardZero,
            Rounding::Sr,
            Rounding::SrEps(0.3),
            Rounding::SignedSrEps(0.3),
        ];
        for fmt in [FpFormat::BINARY8, FpFormat::BFLOAT16] {
            for bits in [DEFAULT_SR_BITS, 8] {
                let plan = RoundPlan::new(fmt).with_sr_bits(bits);
                // 201 + 8 specials = 209 elements: not a multiple of 4, so
                // the scalar remainder after the vector body runs too.
                let (xs, vs) = test_inputs(&fmt, 201);
                for mode in modes {
                    for steered in [false, true] {
                        set_backend(SimdChoice::Scalar);
                        let mut rs = Rng::new(17);
                        let mut a = xs.clone();
                        if steered {
                            plan.round_slice_with(mode, &mut a, &vs, &mut rs);
                        } else {
                            plan.round_slice(mode, &mut a, &mut rs);
                        }
                        set_backend(SimdChoice::Avx2);
                        let mut rv = Rng::new(17);
                        let mut b = xs.clone();
                        if steered {
                            plan.round_slice_with(mode, &mut b, &vs, &mut rv);
                        } else {
                            plan.round_slice(mode, &mut b, &mut rv);
                        }
                        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                            assert_eq!(
                                x.to_bits(),
                                y.to_bits(),
                                "{mode:?} {} bits={bits} steered={steered} i={i}: {x} vs {y}",
                                fmt.name()
                            );
                        }
                        assert_eq!(rs.next_u64(), rv.next_u64(), "{mode:?} stream diverged");
                    }
                }
            }
        }
        set_backend(SimdChoice::Auto);
    }

    /// The SR law holds under both forced backends: the slice mean stays
    /// unbiased whichever kernel runs (the distribution-level counterpart
    /// of the bitwise check above). Fixed seed; spurious-failure
    /// probability ≤ `MC_P_FAIL` per backend (Hoeffding).
    #[test]
    fn simd_backends_keep_sr_law() {
        use super::super::simd::{set_backend, SimdChoice, BACKEND_TEST_LOCK};
        let _lock = BACKEND_TEST_LOCK.lock().unwrap();
        let plan = RoundPlan::new(B8);
        for choice in [SimdChoice::Scalar, SimdChoice::Avx2] {
            set_backend(choice);
            let x = 1.1;
            let n = 40_000usize;
            let mut buf = vec![x; n];
            plan.round_slice(Rounding::Sr, &mut buf, &mut Rng::new(5));
            let mean = buf.iter().sum::<f64>() / n as f64;
            let (lo, hi) = B8.floor_ceil(x);
            let tol = crate::util::stats::hoeffding_halfwidth(hi - lo, n, MC_P_FAIL)
                + (hi - lo) * inv_pow2(plan.sr_bits());
            assert!((mean - x).abs() < tol, "{choice:?}: mean={mean} tol={tol}");
        }
        set_backend(SimdChoice::Auto);
    }

    // --------------------------------------------------- multi-seed lanes --

    /// Every lane of `round_slice_lanes_scheme_with` is bit-identical to a
    /// scalar slice pass over that lane's column with the same generator —
    /// the lane batch is an execution strategy, not a new rounding law.
    /// Checked on float and fixed grids, deterministic + stochastic +
    /// steered, including per-lane RNG end states.
    #[test]
    fn lanes_slice_matches_per_lane_scalar() {
        let modes = [
            Rounding::RoundNearestEven,
            Rounding::Sr,
            Rounding::SrEps(0.3),
            Rounding::SignedSrEps(0.3),
        ];
        for plan in [RoundPlan::new(B8).with_sr_bits(8), RoundPlan::new(Q3_8)] {
            for lanes in [1usize, 4, 8] {
                let n = 97; // odd on purpose: no alignment crutch
                let mut gen = Rng::new(61);
                let cols: Vec<Vec<f64>> =
                    (0..lanes).map(|_| (0..n).map(|_| gen.normal() * 4.0).collect()).collect();
                let vcols: Vec<Vec<f64>> =
                    (0..lanes).map(|_| (0..n).map(|_| gen.normal()).collect()).collect();
                let mut xslab = vec![0.0; n * lanes];
                let mut vslab = vec![0.0; n * lanes];
                for i in 0..n {
                    for l in 0..lanes {
                        xslab[i * lanes + l] = cols[l][i];
                        vslab[i * lanes + l] = vcols[l][i];
                    }
                }
                for mode in modes {
                    let scheme = mode.scheme();
                    for steered in [false, true] {
                        let root = Rng::new(300);
                        let mut rngs: Vec<Rng> =
                            (0..lanes as u64).map(|l| root.split(l)).collect();
                        let mut got = xslab.clone();
                        let vs = if steered { Some(&vslab[..]) } else { None };
                        plan.round_slice_lanes_scheme_with(scheme, &mut got, lanes, vs, &mut rngs);
                        for l in 0..lanes {
                            let mut want = cols[l].clone();
                            let mut oracle = root.split(l as u64);
                            if steered {
                                plan.round_slice_scheme_with(
                                    scheme,
                                    &mut want,
                                    &vcols[l],
                                    &mut oracle,
                                );
                            } else {
                                plan.round_slice_scheme(scheme, &mut want, &mut oracle);
                            }
                            for i in 0..n {
                                assert_eq!(
                                    want[i].to_bits(),
                                    got[i * lanes + l].to_bits(),
                                    "{mode:?} lanes={lanes} lane={l} i={i} steered={steered}"
                                );
                            }
                            assert_eq!(
                                rngs[l].next_u64(),
                                oracle.next_u64(),
                                "{mode:?} lanes={lanes} lane={l} stream diverged"
                            );
                        }
                    }
                }
            }
        }
    }
}
