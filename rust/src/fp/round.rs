//! Rounding schemes: the paper's Definitions 1–3 plus the IEEE deterministic
//! modes, implemented over [`FpFormat`].
//!
//! * `RoundNearestEven` — IEEE-754 default (RN, ties to even);
//! * `RoundDown` / `RoundUp` / `RoundTowardZero` — directed modes;
//! * `Sr` — unbiased stochastic rounding (Definition 1): `P(⌈x⌉) ∝ x − ⌊x⌋`;
//! * `SrEps(ε)` — ε-biased stochastic rounding (Definition 2): rounds *away
//!   from zero* with probability at least ε, so the expected absolute error
//!   has the sign of `x` (eq. (3));
//! * `SignedSrEps(ε)` — signed ε-biased stochastic rounding (Definition 3):
//!   the bias direction is steered by an auxiliary value `v` so the expected
//!   absolute error has the sign of `−v` (eq. (4)). In GD, `v` is the
//!   computed gradient entry, forcing the bias into a descent direction.
//!
//! All stochastic schemes consume exactly one uniform sample per inexact
//! rounding and none when `x ∈ F` (so representable values are fixed points
//! of every scheme, as in `chop`/`roundit`).

use super::format::FpFormat;
use super::rng::Rng;

/// A rounding scheme. `SignedSrEps` requires a steering value `v` supplied
/// per-element through [`round_with`]; the plain [`round`] entry point uses
/// `v = x`, which makes `SignedSrEps(ε)` degenerate to `SrEps(ε)` — exactly
/// the relationship noted under the paper's Algorithm 1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Rounding {
    /// Round to nearest, ties to even (IEEE default). The paper's "RN".
    RoundNearestEven,
    /// Round toward −∞.
    RoundDown,
    /// Round toward +∞.
    RoundUp,
    /// Round toward zero.
    RoundTowardZero,
    /// Unbiased stochastic rounding (Definition 1). The paper's "SR".
    Sr,
    /// ε-biased stochastic rounding (Definition 2), bias away from zero.
    SrEps(f64),
    /// Signed ε-biased stochastic rounding (Definition 3), bias `sign(−v)`.
    SignedSrEps(f64),
}

impl Rounding {
    /// Does this scheme consume randomness (SR / SRε / signed-SRε)?
    pub fn is_stochastic(&self) -> bool {
        matches!(self, Rounding::Sr | Rounding::SrEps(_) | Rounding::SignedSrEps(_))
    }

    /// Short name for reports ("RN", "SR", "SR_eps(0.1)", "signed-SR_eps(0.1)").
    pub fn label(&self) -> String {
        match self {
            Rounding::RoundNearestEven => "RN".into(),
            Rounding::RoundDown => "RD".into(),
            Rounding::RoundUp => "RU".into(),
            Rounding::RoundTowardZero => "RZ".into(),
            Rounding::Sr => "SR".into(),
            Rounding::SrEps(e) => format!("SR_eps({e})"),
            Rounding::SignedSrEps(e) => format!("signed-SR_eps({e})"),
        }
    }

    /// Parse "rn" | "rd" | "ru" | "rz" | "sr" | "sr_eps:0.1" | "signed:0.1".
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "rn" => return Some(Rounding::RoundNearestEven),
            "rd" => return Some(Rounding::RoundDown),
            "ru" => return Some(Rounding::RoundUp),
            "rz" => return Some(Rounding::RoundTowardZero),
            "sr" => return Some(Rounding::Sr),
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("sr_eps:").or_else(|| s.strip_prefix("sreps:")) {
            return rest.parse().ok().map(Rounding::SrEps);
        }
        if let Some(rest) = s.strip_prefix("signed:").or_else(|| s.strip_prefix("signed-sr_eps:")) {
            return rest.parse().ok().map(Rounding::SignedSrEps);
        }
        None
    }
}

/// The clipping function φ of Definition 2: clamp to `[0, 1]`.
#[inline]
pub fn phi(y: f64) -> f64 {
    y.clamp(0.0, 1.0)
}

/// Saturate an out-of-range magnitude to `±x_max` (chop-style: the
/// stochastic schemes never produce ±∞; deterministic RN overflows to ±∞
/// past the IEEE overflow threshold, handled in `round_det`).
#[inline]
fn saturate(fmt: &FpFormat, x: f64) -> f64 {
    x.clamp(-fmt.x_max(), fmt.x_max())
}

/// Precomputed per-[`FpFormat`] rounding constants — the "format table".
///
/// The scalar entry points recompute five integers (`shift`, `mask`, the
/// tie point, the gap scale, the exponent gates) from the format on every
/// call. One GD step rounds three full vectors (paper eq. (8a)/(8b)/(8c)),
/// so the engine and the slice kernels build a plan once and reuse it,
/// hoisting both the constant derivation and the mode dispatch out of the
/// per-element loop (≈2× for the stochastic schemes; see `benches/rounding.rs`).
///
/// Correctness notes for the fast path: with `shift = 53 − s`, the f64 bits
/// of |x| split as `lo_mag = bits & !mask` (the magnitude-floor, exactly
/// `⌊|x|⌋_F`) and `hi_mag = lo_mag + 2^shift` (magnitude-ceil; carries into
/// the exponent field exactly when the mantissa overflows to the next
/// binade, which is still a representable value). `tail/2^shift` is exactly
/// `(|x| − ⌊|x|⌋)/(⌈|x|⌉ − ⌊|x|⌋)` because the gap is one target-ulp.
#[derive(Debug, Clone, Copy)]
pub struct RoundPlan {
    /// The format this plan was precomputed for.
    pub fmt: FpFormat,
    /// `53 − s`: binary64 mantissa bits below the target ulp.
    shift: u32,
    /// `2^shift − 1`: mask selecting the discarded tail bits.
    mask: u64,
    /// `2^{shift−1}`: the RN tie point (0 when `shift = 0`, i.e. binary64,
    /// where the tail is always 0 and the tie point is never consulted).
    half: u64,
    /// `2^{−shift}` exactly: converts the tail to a fraction of the gap.
    inv_gap: f64,
}

impl RoundPlan {
    /// Precompute the rounding constants for `fmt`.
    #[inline]
    pub fn new(fmt: FpFormat) -> Self {
        let shift = 53 - fmt.sig_bits;
        Self {
            fmt,
            shift,
            mask: (1u64 << shift) - 1,
            half: if shift == 0 { 0 } else { 1u64 << (shift - 1) },
            inv_gap: inv_pow2(shift),
        }
    }

    /// Hot path: rounding a value whose magnitude is *target-normal* and in
    /// range reduces to rounding the binary64 mantissa tail — pure integer
    /// bit-twiddling, no divisions and no `pow2` reconstruction. This covers
    /// essentially every rounding in a GD run; subnormal/overflow/NaN inputs
    /// fall back to the general path. Returns `None` when ineligible.
    #[inline(always)]
    fn fast(&self, mode: Rounding, x: f64, v: f64, rng: &mut Rng) -> Option<f64> {
        let bits = x.to_bits();
        let mag = bits & 0x7fff_ffff_ffff_ffff;
        let raw_e = (mag >> 52) as i32;
        let e = raw_e - 1023;
        // Eligibility: finite, f64-normal, target-normal, strictly inside the
        // target's largest binade (so the magnitude-ceil cannot overflow past
        // x_max: for e < e_max, ceil ≤ 2^{e+1} ≤ 2^{e_max} ≤ x_max).
        if raw_e == 0 || raw_e == 0x7ff || e < self.fmt.e_min || e >= self.fmt.e_max {
            return None;
        }
        let tail = mag & self.mask;
        if tail == 0 {
            return Some(x); // representable
        }
        let neg = bits >> 63 == 1;
        let lo_mag = mag & !self.mask;
        let hi_mag = lo_mag + (self.mask + 1);
        // Value-scale neighbors.
        let (lo_bits, hi_bits) = if neg {
            (hi_mag | (1u64 << 63), lo_mag | (1u64 << 63))
        } else {
            (lo_mag, hi_mag)
        };
        // frac on the VALUE scale: distance from the value-floor, in gaps.
        let frac_mag = tail as f64 * self.inv_gap;
        let frac = if neg { 1.0 - frac_mag } else { frac_mag };
        let down = match mode {
            Rounding::RoundDown => true,
            Rounding::RoundUp => false,
            Rounding::RoundTowardZero => !neg,
            Rounding::RoundNearestEven => {
                if tail != self.half {
                    // Nearest in magnitude == nearest in value.
                    (tail < self.half) ^ neg
                } else {
                    // Tie: keep the endpoint with even target significand.
                    let lo_even = (lo_mag >> self.shift) & 1 == 0;
                    lo_even ^ neg // value-floor is the magnitude-floor iff !neg
                }
            }
            Rounding::Sr => rng.uniform() < 1.0 - frac,
            Rounding::SrEps(eps) => {
                let sx = if neg { -1.0 } else { 1.0 };
                rng.uniform() < phi(1.0 - frac - sx * eps)
            }
            Rounding::SignedSrEps(eps) => {
                let sv = if v == 0.0 { 0.0 } else { v.signum() };
                rng.uniform() < phi(1.0 - frac + sv * eps)
            }
        };
        Some(f64::from_bits(if down { lo_bits } else { hi_bits }))
    }

    /// Round `x` using scheme `mode`, steering `SignedSrEps` by `v`. Same
    /// contract as the free [`round_with`], without re-deriving the format
    /// constants per call.
    #[inline]
    pub fn round_with(&self, mode: Rounding, x: f64, v: f64, rng: &mut Rng) -> f64 {
        if x == 0.0 || x.is_nan() {
            return x;
        }
        if let Some(y) = self.fast(mode, x, v, rng) {
            return y;
        }
        round_slow(&self.fmt, mode, x, v, rng)
    }

    /// Round `x` with `v = x` (see the [`Rounding`] type-level docs).
    #[inline]
    pub fn round(&self, mode: Rounding, x: f64, rng: &mut Rng) -> f64 {
        self.round_with(mode, x, x, rng)
    }
}

/// `2^{-k}` for `k ∈ [0, 63]`, exact (table-free bit construction).
#[inline(always)]
fn inv_pow2(k: u32) -> f64 {
    f64::from_bits(((1023 - k as u64) & 0x7ff) << 52)
}

/// Round `x` into `fmt` using scheme `mode`, steering `SignedSrEps` by `v`.
/// One uniform is drawn from `rng` iff the scheme is stochastic and `x ∉ F`.
#[inline]
pub fn round_with(fmt: &FpFormat, mode: Rounding, x: f64, v: f64, rng: &mut Rng) -> f64 {
    RoundPlan::new(*fmt).round_with(mode, x, v, rng)
}

/// General (slow) path shared by the scalar and slice kernels: exact
/// neighbor arithmetic through [`FpFormat::floor_ceil`]. Handles
/// subnormals, overflow saturation and the deterministic overflow-to-∞
/// rule. Requires `x != 0` and `x` not NaN (the callers guard).
fn round_slow(fmt: &FpFormat, mode: Rounding, x: f64, v: f64, rng: &mut Rng) -> f64 {
    let (lo, hi) = fmt.floor_ceil(x);
    if lo == hi {
        return lo; // x ∈ F (includes ±∞ inputs)
    }
    match mode {
        Rounding::RoundDown => lo,
        Rounding::RoundUp => hi,
        Rounding::RoundTowardZero => {
            if x > 0.0 {
                lo
            } else {
                hi
            }
        }
        Rounding::RoundNearestEven => round_nearest_even(fmt, x, lo, hi),
        Rounding::Sr | Rounding::SrEps(_) | Rounding::SignedSrEps(_) => {
            // Stochastic schemes: saturating endpoints keeps them finite.
            let (lo, hi) = (saturate(fmt, lo), saturate(fmt, hi));
            if lo == hi {
                return lo;
            }
            let frac = (x - lo) / (hi - lo); // ∈ (0,1), exact denominators
            let p_down = match mode {
                // Definition 1: P(⌊x⌋) = 1 − (x−⌊x⌋)/(⌈x⌉−⌊x⌋).
                Rounding::Sr => 1.0 - frac,
                // Definition 2: p_ε(x) = φ(1 − frac − sign(x)·ε).
                Rounding::SrEps(eps) => phi(1.0 - frac - x.signum() * eps),
                // Definition 3: p̂_ε(x) = φ(1 − frac + sign(v)·ε).
                Rounding::SignedSrEps(eps) => {
                    let sv = if v == 0.0 { 0.0 } else { v.signum() };
                    phi(1.0 - frac + sv * eps)
                }
                _ => unreachable!(),
            };
            if rng.uniform() < p_down {
                lo
            } else {
                hi
            }
        }
    }
}

/// Round `x` with `v = x` (see type-level docs).
#[inline]
pub fn round(fmt: &FpFormat, mode: Rounding, x: f64, rng: &mut Rng) -> f64 {
    round_with(fmt, mode, x, x, rng)
}

/// IEEE round-to-nearest, ties to even, with the standard overflow rule
/// (|x| ≥ x_max + ulp/2 → ±∞).
fn round_nearest_even(fmt: &FpFormat, x: f64, lo: f64, hi: f64) -> f64 {
    if hi.is_infinite() {
        // Binade above x_max: overflow threshold is x_max + ulp(x_max)/2.
        let thr = fmt.x_max() + fmt.spacing_at(fmt.x_max()) / 2.0;
        return if x >= thr { f64::INFINITY } else { fmt.x_max() };
    }
    if lo.is_infinite() {
        let thr = -(fmt.x_max() + fmt.spacing_at(fmt.x_max()) / 2.0);
        return if x <= thr { f64::NEG_INFINITY } else { -fmt.x_max() };
    }
    let dlo = x - lo;
    let dhi = hi - x;
    if dlo < dhi {
        lo
    } else if dhi < dlo {
        hi
    } else {
        // Tie: pick the endpoint with even significand.
        let q = hi - lo;
        let m_lo = (lo / q).abs();
        if (m_lo as i64) % 2 == 0 {
            lo
        } else {
            hi
        }
    }
}

/// Expected rounded value `E[fl(x)]` under a scheme — closed form, no
/// sampling (used for Figure 1 and for property tests against the empirical
/// mean). For deterministic schemes this is just the rounded value.
pub fn expected_round(fmt: &FpFormat, mode: Rounding, x: f64, v: f64) -> f64 {
    if x == 0.0 || x.is_nan() {
        return x;
    }
    let (lo, hi) = fmt.floor_ceil(x);
    if lo == hi {
        return lo;
    }
    match mode {
        Rounding::Sr | Rounding::SrEps(_) | Rounding::SignedSrEps(_) => {
            let (lo, hi) = (saturate(fmt, lo), saturate(fmt, hi));
            if lo == hi {
                return lo;
            }
            let frac = (x - lo) / (hi - lo);
            let p_down = match mode {
                Rounding::Sr => 1.0 - frac,
                Rounding::SrEps(eps) => phi(1.0 - frac - x.signum() * eps),
                Rounding::SignedSrEps(eps) => {
                    let sv = if v == 0.0 { 0.0 } else { v.signum() };
                    phi(1.0 - frac + sv * eps)
                }
                _ => unreachable!(),
            };
            p_down * lo + (1.0 - p_down) * hi
        }
        _ => {
            let mut rng = Rng::new(0); // unused by deterministic modes
            round_with(fmt, mode, x, v, &mut rng)
        }
    }
}

impl RoundPlan {
    /// Round every entry of a slice in place (plain `v = x` steering).
    /// Specialized per scheme so the mode dispatch and the format constants
    /// are hoisted out of the element loop (≈2× over calling [`round`] per
    /// element for the stochastic schemes; see `benches/rounding.rs`).
    pub fn round_slice(&self, mode: Rounding, xs: &mut [f64], rng: &mut Rng) {
        let (mask, inv, shift) = (self.mask, self.inv_gap, self.shift);
        let (e_min, e_max) = (self.fmt.e_min, self.fmt.e_max);
        macro_rules! specialized {
            (|$tail:ident, $frac:ident, $neg:ident, $lo_mag:ident| $p_down:expr) => {
                for x in xs.iter_mut() {
                    let bits = x.to_bits();
                    let mag = bits & 0x7fff_ffff_ffff_ffff;
                    let raw_e = (mag >> 52) as i32;
                    let e = raw_e - 1023;
                    if raw_e == 0 || raw_e == 0x7ff || e < e_min || e >= e_max {
                        if *x != 0.0 && !x.is_nan() {
                            *x = round_slow(&self.fmt, mode, *x, *x, rng); // rare slow path
                        }
                        continue;
                    }
                    let $tail = mag & mask;
                    if $tail == 0 {
                        continue; // representable
                    }
                    let $neg = bits >> 63 == 1;
                    let $lo_mag = mag & !mask;
                    let hi_mag = $lo_mag + (mask + 1);
                    let frac_mag = $tail as f64 * inv;
                    let $frac = if $neg { 1.0 - frac_mag } else { frac_mag };
                    let down: bool = $p_down;
                    // down on the VALUE scale: pick magnitude-ceil when negative.
                    let out_mag = if down != $neg { $lo_mag } else { hi_mag };
                    *x = f64::from_bits(out_mag | (bits & (1u64 << 63)));
                }
            };
        }
        match mode {
            Rounding::Sr => {
                specialized!(|tail, frac, neg, lo_mag| rng.uniform() < 1.0 - frac)
            }
            Rounding::SrEps(eps) => specialized!(|tail, frac, neg, lo_mag| {
                let sx = if neg { -1.0 } else { 1.0 };
                rng.uniform() < phi(1.0 - frac - sx * eps)
            }),
            Rounding::RoundNearestEven => specialized!(|tail, frac, neg, lo_mag| {
                let half = self.half;
                let _ = frac;
                if tail != half {
                    (tail < half) ^ neg
                } else {
                    ((lo_mag >> shift) & 1 == 0) ^ neg
                }
            }),
            _ => {
                for x in xs.iter_mut() {
                    *x = self.round(mode, *x, rng);
                }
            }
        }
    }

    /// Round every entry, steering `SignedSrEps` per element by `vs`.
    ///
    /// Only `SignedSrEps` reads the steering value; every other mode
    /// delegates to the unsteered [`RoundPlan::round_slice`] kernel, which
    /// is exactly equivalent for them. The `SignedSrEps` loop is fused the
    /// same way (constants and dispatch hoisted out of the element loop) —
    /// this is the (8b)/(8c) hot path of the GD engine, where the steering
    /// vector is the computed gradient.
    pub fn round_slice_with(&self, mode: Rounding, xs: &mut [f64], vs: &[f64], rng: &mut Rng) {
        debug_assert_eq!(xs.len(), vs.len());
        let eps = match mode {
            Rounding::SignedSrEps(e) => e,
            _ => return self.round_slice(mode, xs, rng),
        };
        let (mask, inv) = (self.mask, self.inv_gap);
        let (e_min, e_max) = (self.fmt.e_min, self.fmt.e_max);
        for (x, &v) in xs.iter_mut().zip(vs.iter()) {
            let bits = x.to_bits();
            let mag = bits & 0x7fff_ffff_ffff_ffff;
            let raw_e = (mag >> 52) as i32;
            let e = raw_e - 1023;
            if raw_e == 0 || raw_e == 0x7ff || e < e_min || e >= e_max {
                if *x != 0.0 && !x.is_nan() {
                    *x = round_slow(&self.fmt, mode, *x, v, rng); // rare slow path
                }
                continue;
            }
            let tail = mag & mask;
            if tail == 0 {
                continue; // representable
            }
            let neg = bits >> 63 == 1;
            let lo_mag = mag & !mask;
            let hi_mag = lo_mag + (mask + 1);
            let frac_mag = tail as f64 * inv;
            let frac = if neg { 1.0 - frac_mag } else { frac_mag };
            let sv = if v == 0.0 { 0.0 } else { v.signum() };
            let down = rng.uniform() < phi(1.0 - frac + sv * eps);
            let out_mag = if down != neg { lo_mag } else { hi_mag };
            *x = f64::from_bits(out_mag | (bits & (1u64 << 63)));
        }
    }
}

/// Round every entry of a slice in place (plain `v = x` steering) — free
/// wrapper building a [`RoundPlan`] per call; prefer the plan method when
/// rounding repeatedly into the same format.
pub fn round_slice(fmt: &FpFormat, mode: Rounding, xs: &mut [f64], rng: &mut Rng) {
    RoundPlan::new(*fmt).round_slice(mode, xs, rng);
}

/// Round every entry, steering `SignedSrEps` per element by `vs` — free
/// wrapper over [`RoundPlan::round_slice_with`].
pub fn round_slice_with(fmt: &FpFormat, mode: Rounding, xs: &mut [f64], vs: &[f64], rng: &mut Rng) {
    RoundPlan::new(*fmt).round_slice_with(mode, xs, vs, rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    const B8: FpFormat = FpFormat::BINARY8;

    #[test]
    fn representable_values_are_fixed_points() {
        let mut rng = Rng::new(0);
        for mode in [
            Rounding::RoundNearestEven,
            Rounding::RoundDown,
            Rounding::RoundUp,
            Rounding::RoundTowardZero,
            Rounding::Sr,
            Rounding::SrEps(0.3),
            Rounding::SignedSrEps(0.3),
        ] {
            for &x in &[0.0, 1.0, -1.25, 1024.0, B8.x_min(), B8.x_min_sub(), -B8.x_max()] {
                assert_eq!(round(&B8, mode, x, &mut rng), x, "{mode:?} x={x}");
            }
        }
    }

    #[test]
    fn deterministic_modes() {
        let mut rng = Rng::new(0);
        // x = 1.1 ∈ (1.0, 1.25) in binary8.
        assert_eq!(round(&B8, Rounding::RoundDown, 1.1, &mut rng), 1.0);
        assert_eq!(round(&B8, Rounding::RoundUp, 1.1, &mut rng), 1.25);
        assert_eq!(round(&B8, Rounding::RoundTowardZero, 1.1, &mut rng), 1.0);
        assert_eq!(round(&B8, Rounding::RoundTowardZero, -1.1, &mut rng), -1.0);
        assert_eq!(round(&B8, Rounding::RoundNearestEven, 1.1, &mut rng), 1.0);
        assert_eq!(round(&B8, Rounding::RoundNearestEven, 1.2, &mut rng), 1.25);
    }

    #[test]
    fn rn_ties_to_even() {
        let mut rng = Rng::new(0);
        // Midpoint of (1.0, 1.25): 1.125. Significands: 1.0 → m=4 (even),
        // 1.25 → m=5 (odd) at spacing 0.25 ⇒ tie goes to 1.0.
        assert_eq!(round(&B8, Rounding::RoundNearestEven, 1.125, &mut rng), 1.0);
        // Midpoint of (1.25, 1.5): 1.375 → 1.5 (m=6 even).
        assert_eq!(round(&B8, Rounding::RoundNearestEven, 1.375, &mut rng), 1.5);
        // Negative mirror.
        assert_eq!(round(&B8, Rounding::RoundNearestEven, -1.125, &mut rng), -1.0);
    }

    #[test]
    fn rn_overflow_to_infinity() {
        let mut rng = Rng::new(0);
        let xmax = B8.x_max(); // 57344, ulp = 2^13 = 8192
        assert_eq!(round(&B8, Rounding::RoundNearestEven, xmax + 4095.0, &mut rng), xmax);
        assert_eq!(round(&B8, Rounding::RoundNearestEven, xmax + 4096.0, &mut rng), f64::INFINITY);
        assert_eq!(round(&B8, Rounding::RoundNearestEven, -(xmax + 5000.0), &mut rng), f64::NEG_INFINITY);
    }

    #[test]
    fn stochastic_saturates_no_infinity() {
        let mut rng = Rng::new(0);
        for _ in 0..100 {
            let y = round(&B8, Rounding::Sr, 60000.0, &mut rng);
            assert_eq!(y, B8.x_max());
        }
    }

    /// SR empirical mean ≈ x (zero bias, Definition 1).
    #[test]
    fn sr_is_unbiased() {
        let mut rng = Rng::new(42);
        for &x in &[1.1, 1.24, -2.6, 0.001, 1030.0] {
            let n = 40_000;
            let mean: f64 = (0..n).map(|_| round(&B8, Rounding::Sr, x, &mut rng)).sum::<f64>() / n as f64;
            let (lo, hi) = B8.floor_ceil(x);
            let tol = 4.0 * (hi - lo) / (n as f64).sqrt();
            assert!((mean - x).abs() < tol, "x={x} mean={mean} tol={tol}");
        }
    }

    /// SRε bias has the sign of x and magnitude ε·(⌈x⌉−⌊x⌋) in the interior
    /// regime (eq. (3) middle case).
    #[test]
    fn sr_eps_bias_matches_eq3() {
        let mut rng = Rng::new(7);
        let eps = 0.25;
        for &x in &[1.1, -1.1, 3.3, -900.0] {
            let (lo, hi) = B8.floor_ceil(x);
            let frac = (x - lo) / (hi - lo);
            let eta = 1.0 - frac - x.signum() * eps;
            if !(0.0..=1.0).contains(&eta) {
                continue; // pick interior cases only
            }
            let n = 60_000;
            let mean: f64 =
                (0..n).map(|_| round(&B8, Rounding::SrEps(eps), x, &mut rng)).sum::<f64>() / n as f64;
            let expected_bias = x.signum() * eps * (hi - lo);
            let tol = 4.0 * (hi - lo) / (n as f64).sqrt();
            assert!(
                ((mean - x) - expected_bias).abs() < tol,
                "x={x} bias={} expected={expected_bias}",
                mean - x
            );
        }
    }

    /// signed-SRε bias has the sign of −v (eq. (4) middle case).
    #[test]
    fn signed_sr_eps_bias_opposes_v() {
        let mut rng = Rng::new(9);
        let eps = 0.25;
        for &(x, v) in &[(1.1, 1.0), (1.1, -1.0), (-1.1, 1.0), (-1.1, -1.0)] {
            let (lo, hi) = B8.floor_ceil(x);
            let n = 60_000;
            let mean: f64 = (0..n)
                .map(|_| round_with(&B8, Rounding::SignedSrEps(eps), x, v, &mut rng))
                .sum::<f64>()
                / n as f64;
            let expected_bias = -v.signum() * eps * (hi - lo);
            let tol = 4.0 * (hi - lo) / (n as f64).sqrt();
            assert!(
                ((mean - x) - expected_bias).abs() < tol,
                "x={x} v={v} bias={} expected={expected_bias}",
                mean - x
            );
        }
    }

    /// Closed-form expectation matches the empirical mean for all schemes.
    #[test]
    fn expected_round_matches_empirical() {
        let mut rng = Rng::new(3);
        for mode in [Rounding::Sr, Rounding::SrEps(0.4), Rounding::SignedSrEps(0.15)] {
            for &(x, v) in &[(1.07, -2.0), (-5.3, 1.0), (0.011, 0.5)] {
                let n = 60_000;
                let mean: f64 =
                    (0..n).map(|_| round_with(&B8, mode, x, v, &mut rng)).sum::<f64>() / n as f64;
                let exp = expected_round(&B8, mode, x, v);
                let (lo, hi) = B8.floor_ceil(x);
                let tol = 4.0 * (hi - lo) / (n as f64).sqrt();
                assert!((mean - exp).abs() < tol, "{mode:?} x={x}: {mean} vs {exp}");
            }
        }
    }

    /// Lemma 1: 0 ≤ E[δ^{SRε}(x)] ≤ 2εu for all nonzero x.
    #[test]
    fn lemma1_relative_bias_bound() {
        let eps = 0.3;
        let u = B8.unit_roundoff();
        let mut vals = vec![];
        let mut t = 0.013;
        while t < 2.0e4 {
            vals.push(t);
            vals.push(-t);
            t *= 1.7;
        }
        for &x in &vals {
            let e = expected_round(&B8, Rounding::SrEps(eps), x, x);
            let rel = (e - x) / x;
            assert!(rel >= -1e-15, "x={x} rel={rel}");
            assert!(rel <= 2.0 * eps * u + 1e-15, "x={x} rel={rel} bound={}", 2.0 * eps * u);
        }
    }

    /// With ε = 0 both new schemes coincide with SR in expectation.
    #[test]
    fn eps_zero_degenerates_to_sr() {
        for &x in &[1.1, -2.6, 100.3] {
            let e_sr = expected_round(&B8, Rounding::Sr, x, x);
            let e_eps = expected_round(&B8, Rounding::SrEps(0.0), x, x);
            let e_sgn = expected_round(&B8, Rounding::SignedSrEps(0.0), x, -x);
            assert!((e_sr - e_eps).abs() < 1e-15);
            assert!((e_sr - e_sgn).abs() < 1e-15);
        }
    }

    /// With v = x, signed-SRε(x) has the same law as SRε mirrored: per
    /// Definition 3, sign(v)=sign(x) gives p̂ = φ(1 − frac + sign(x)ε) — the
    /// bias *toward zero* variant; check the closed forms are consistent.
    #[test]
    fn signed_with_v_eq_x_biases_toward_zero() {
        let eps = 0.25;
        for &x in &[1.1, -1.1] {
            let e = expected_round(&B8, Rounding::SignedSrEps(eps), x, x);
            // bias sign must be −sign(x): toward zero
            assert!((e - x) * x.signum() < 0.0, "x={x} e={e}");
        }
    }

    /// The plan-based scalar and fused slice kernels are bit-identical to
    /// the scalar reference path, drawing the same number of uniforms in
    /// the same order (the engine's determinism contract rests on this).
    #[test]
    fn round_plan_matches_scalar_reference() {
        let modes = [
            Rounding::RoundNearestEven,
            Rounding::RoundDown,
            Rounding::RoundUp,
            Rounding::RoundTowardZero,
            Rounding::Sr,
            Rounding::SrEps(0.3),
            Rounding::SignedSrEps(0.3),
        ];
        for fmt in [FpFormat::BINARY8, FpFormat::BFLOAT16, FpFormat::BINARY64] {
            let plan = RoundPlan::new(fmt);
            let mut gen = Rng::new(77);
            // Mix of normals, subnormals, representables, overflow, specials.
            let mut xs: Vec<f64> = (0..200).map(|_| gen.normal() * 1e3).collect();
            xs.extend([
                0.0,
                1.0,
                -1.25,
                fmt.x_min() * 0.3,
                -fmt.x_min_sub() * 0.5,
                fmt.x_max() * 1.5,
                f64::NAN,
                f64::INFINITY,
            ]);
            let vs: Vec<f64> = (0..xs.len()).map(|_| gen.normal()).collect();
            for mode in modes {
                // Scalar reference vs plan scalar, lock-stepped RNG clones.
                let mut ra = Rng::new(5);
                let mut rb = Rng::new(5);
                for (&x, &v) in xs.iter().zip(&vs) {
                    let want = round_with(&fmt, mode, x, v, &mut ra);
                    let got = plan.round_with(mode, x, v, &mut rb);
                    assert!(
                        want == got || (want.is_nan() && got.is_nan()),
                        "{mode:?} {} x={x}: {want} vs {got}",
                        fmt.name()
                    );
                }
                assert_eq!(ra.next_u64(), rb.next_u64(), "RNG streams diverged");
                // Fused steered slice vs per-element reference.
                let mut buf = xs.clone();
                let mut rc = Rng::new(9);
                plan.round_slice_with(mode, &mut buf, &vs, &mut rc);
                let mut rd = Rng::new(9);
                for (i, (&x, &v)) in xs.iter().zip(&vs).enumerate() {
                    let want = round_with(&fmt, mode, x, v, &mut rd);
                    assert!(
                        want == buf[i] || (want.is_nan() && buf[i].is_nan()),
                        "slice {mode:?} {} i={i} x={x}: {want} vs {}",
                        fmt.name(),
                        buf[i]
                    );
                }
                assert_eq!(rc.next_u64(), rd.next_u64(), "slice RNG diverged");
            }
        }
    }

    #[test]
    fn parse_labels_roundtrip() {
        for (s, m) in [
            ("rn", Rounding::RoundNearestEven),
            ("sr", Rounding::Sr),
            ("sr_eps:0.1", Rounding::SrEps(0.1)),
            ("signed:0.4", Rounding::SignedSrEps(0.4)),
            ("rd", Rounding::RoundDown),
            ("ru", Rounding::RoundUp),
            ("rz", Rounding::RoundTowardZero),
        ] {
            assert_eq!(Rounding::parse(s), Some(m));
        }
        assert_eq!(Rounding::parse("bogus"), None);
    }
}
