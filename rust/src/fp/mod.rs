//! Low-precision number substrate (systems S1–S4 of DESIGN.md): number
//! grids (floating-point *formats* and fixed-point Qm.n grids behind the
//! [`grid`] abstraction), rounding schemes (RN / directed / SR / SRε /
//! signed-SRε plus any user scheme registered through the open [`scheme`]
//! API), deterministic RNG streams with a bulk/few-random-bits API,
//! rounded linear algebra, and the blocked rounding-aware kernels that
//! drive the per-cell hot path — with runtime-dispatched SIMD backends
//! ([`simd`]) and structure-of-arrays multi-seed lane batches ([`lanes`])
//! on top (see `docs/performance.md`, `docs/fixed-point.md` and
//! `docs/api.md`).

pub mod format;
pub mod grid;
pub mod kernels;
pub mod lanes;
pub mod linalg;
pub mod rng;
pub mod round;
pub mod scheme;
pub mod simd;

pub use format::FpFormat;
pub use grid::{FixedPoint, Grid, NumberGrid};
pub use lanes::LaneBatch;
pub use linalg::LpCtx;
pub use rng::{BitBlock, LaneBits, Rng};
pub use simd::{avx2_active, backend_label, set_backend, SimdChoice};
pub use round::{
    expected_round, phi, round, round_slice, round_slice_with, round_with, RoundPlan, Rounding,
    RunHealth, DEFAULT_SR_BITS,
};
pub use scheme::{RoundingScheme, Scheme, SchemeError, SchemeRegistry};
