//! Low-precision floating-point substrate (systems S1–S4 of DESIGN.md):
//! formats, rounding schemes (RN / directed / SR / SRε / signed-SRε),
//! deterministic RNG streams, and rounded linear algebra.

pub mod format;
pub mod linalg;
pub mod rng;
pub mod round;

pub use format::FpFormat;
pub use linalg::LpCtx;
pub use rng::Rng;
pub use round::{
    expected_round, phi, round, round_slice, round_slice_with, round_with, RoundPlan, Rounding,
};
