//! Dataset substrate (system S11).
//!
//! The paper trains on MNIST [9]; this environment has no network access, so
//! we generate a *procedural digit dataset* with the same interface: 28×28
//! (or any side) grayscale digits 0–9, pixel values in [0, 1], with a
//! 10-class split (MLR, §5.2) and a 3-vs-8 binary split (NN, §5.3). The
//! substitution is behaviour-preserving for this paper because every studied
//! phenomenon depends only on gradient magnitudes relative to `u·|x̂|`
//! (stagnation, rounding-bias direction), not on the image distribution —
//! see DESIGN.md §2. An IDX loader is provided so real MNIST is used
//! automatically when the files exist.

pub mod idx;
pub mod synth;

/// A dense classification dataset: row-major images, one label per row.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// n_samples × n_features, values in [0, 1].
    pub x: Vec<f64>,
    /// One class label per sample row.
    pub labels: Vec<u8>,
    /// Feature count per row (side² for square images).
    pub n_features: usize,
}

impl Dataset {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True when the dataset holds no samples.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// The i-th sample's feature row.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.x[i * self.n_features..(i + 1) * self.n_features]
    }

    /// Keep only samples whose label is in `keep`, remapping labels to
    /// 0..keep.len() (paper §5.3: digits {3, 8} → {0, 1}).
    pub fn filter_classes(&self, keep: &[u8]) -> Dataset {
        let mut x = Vec::new();
        let mut labels = Vec::new();
        for i in 0..self.len() {
            if let Some(pos) = keep.iter().position(|&k| k == self.labels[i]) {
                x.extend_from_slice(self.row(i));
                labels.push(pos as u8);
            }
        }
        Dataset { x, labels, n_features: self.n_features }
    }

    /// Number of classes (1 + the largest label; 0 when empty).
    pub fn n_classes(&self) -> usize {
        self.labels.iter().map(|&l| l as usize).max().map_or(0, |m| m + 1)
    }
}

/// Train/test pair.
#[derive(Debug, Clone)]
pub struct Splits {
    /// Training split.
    pub train: Dataset,
    /// Held-out test split.
    pub test: Dataset,
}

/// Load MNIST from `dir` if the IDX files are present, otherwise generate
/// the procedural dataset with `train_n`/`test_n` samples and `side`² pixels.
pub fn load_or_synth(dir: Option<&str>, train_n: usize, test_n: usize, side: usize, seed: u64) -> Splits {
    if let Some(d) = dir {
        if let Ok(s) = idx::load_mnist(d) {
            return s;
        }
    }
    Splits {
        train: synth::generate(train_n, side, seed),
        test: synth::generate(test_n, side, seed ^ 0x7e57_da7a),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filter_classes_remaps() {
        let d = Dataset {
            x: vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6],
            labels: vec![3, 5, 8],
            n_features: 2,
        };
        let f = d.filter_classes(&[3, 8]);
        assert_eq!(f.labels, vec![0, 1]);
        assert_eq!(f.len(), 2);
        assert_eq!(f.row(1), &[0.5, 0.6]);
    }

    #[test]
    fn load_or_synth_falls_back() {
        let s = load_or_synth(Some("/nonexistent"), 50, 20, 14, 0);
        assert_eq!(s.train.len(), 50);
        assert_eq!(s.test.len(), 20);
        assert_eq!(s.train.n_features, 196);
    }
}
