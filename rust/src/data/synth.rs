//! Procedural digit dataset: 7×5 glyph prototypes rasterized to `side`×`side`
//! with random translation, intensity jitter and pixel noise. Deterministic
//! per (n, side, seed).

use super::Dataset;
use crate::fp::rng::Rng;

/// 7-row × 5-column bitmap fonts for digits 0–9.
const GLYPHS: [[u8; 7]; 10] = [
    // Each row is 5 bits, msb = leftmost column.
    [0b01110, 0b10001, 0b10011, 0b10101, 0b11001, 0b10001, 0b01110], // 0
    [0b00100, 0b01100, 0b00100, 0b00100, 0b00100, 0b00100, 0b01110], // 1
    [0b01110, 0b10001, 0b00001, 0b00010, 0b00100, 0b01000, 0b11111], // 2
    [0b11111, 0b00010, 0b00100, 0b00010, 0b00001, 0b10001, 0b01110], // 3
    [0b00010, 0b00110, 0b01010, 0b10010, 0b11111, 0b00010, 0b00010], // 4
    [0b11111, 0b10000, 0b11110, 0b00001, 0b00001, 0b10001, 0b01110], // 5
    [0b00110, 0b01000, 0b10000, 0b11110, 0b10001, 0b10001, 0b01110], // 6
    [0b11111, 0b00001, 0b00010, 0b00100, 0b01000, 0b01000, 0b01000], // 7
    [0b01110, 0b10001, 0b10001, 0b01110, 0b10001, 0b10001, 0b01110], // 8
    [0b01110, 0b10001, 0b10001, 0b01111, 0b00001, 0b00010, 0b01100], // 9
];

/// Rasterize one digit into an `side×side` image with jitter.
fn rasterize(digit: usize, side: usize, rng: &mut Rng, out: &mut [f64]) {
    debug_assert_eq!(out.len(), side * side);
    out.fill(0.0);
    let g = &GLYPHS[digit];
    // Scale the 7×5 glyph into roughly 70% of the canvas.
    let gh = (side as f64 * 0.68).max(7.0);
    let gw = gh * 5.0 / 7.0;
    let max_shift = ((side as f64 - gh) / 2.0).max(0.0);
    let dy = (side as f64 - gh) / 2.0 + rng.uniform_in(-1.0, 1.0) * max_shift * 0.8;
    let dx = (side as f64 - gw) / 2.0 + rng.uniform_in(-1.0, 1.0) * max_shift * 0.8;
    let intensity = rng.uniform_in(0.72, 1.0);
    for py in 0..side {
        for px in 0..side {
            // Map pixel center back into glyph coordinates.
            let gy = (py as f64 + 0.5 - dy) / gh * 7.0;
            let gx = (px as f64 + 0.5 - dx) / gw * 5.0;
            if gy >= 0.0 && gy < 7.0 && gx >= 0.0 && gx < 5.0 {
                let (r, c) = (gy as usize, gx as usize);
                if (g[r] >> (4 - c)) & 1 == 1 {
                    out[py * side + px] = intensity;
                }
            }
        }
    }
    // Additive pixel noise, clamped to [0, 1] (paper: values normalized to [0,1]).
    for v in out.iter_mut() {
        let noisy = *v + 0.08 * rng.normal();
        *v = noisy.clamp(0.0, 1.0);
    }
}

/// Generate `n` samples of `side`×`side` digits with balanced classes.
pub fn generate(n: usize, side: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed).fork("synth-digits", side as u64);
    let nf = side * side;
    let mut x = vec![0.0; n * nf];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let digit = (i % 10) as u8; // balanced classes
        rasterize(digit as usize, side, &mut rng, &mut x[i * nf..(i + 1) * nf]);
        labels.push(digit);
    }
    // Shuffle rows so mini-batch order is class-mixed.
    let perm = rng.permutation(n);
    let mut xs = vec![0.0; n * nf];
    let mut ls = vec![0u8; n];
    for (dst, &src) in perm.iter().enumerate() {
        xs[dst * nf..(dst + 1) * nf].copy_from_slice(&x[src * nf..(src + 1) * nf]);
        ls[dst] = labels[src];
    }
    Dataset { x: xs, labels: ls, n_features: nf }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(30, 14, 7);
        let b = generate(30, 14, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.labels, b.labels);
        let c = generate(30, 14, 8);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn values_in_unit_interval() {
        let d = generate(100, 14, 1);
        assert!(d.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn classes_balanced() {
        let d = generate(200, 14, 2);
        let mut counts = [0usize; 10];
        for &l in &d.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean images of different digits should differ substantially more
        // than noise: a sanity floor for learnability.
        let d = generate(400, 14, 3);
        let nf = d.n_features;
        let mean_img = |digit: u8| -> Vec<f64> {
            let rows: Vec<usize> =
                (0..d.len()).filter(|&i| d.labels[i] == digit).collect();
            let mut m = vec![0.0; nf];
            for &i in &rows {
                for (mj, xj) in m.iter_mut().zip(d.row(i)) {
                    *mj += xj;
                }
            }
            for mj in m.iter_mut() {
                *mj /= rows.len() as f64;
            }
            m
        };
        let m3 = mean_img(3);
        let m8 = mean_img(8);
        let dist: f64 =
            m3.iter().zip(&m8).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        assert!(dist > 0.5, "class means too close: {dist}");
    }
}
