//! IDX (MNIST) file loader — used automatically when real MNIST files are
//! present on disk (`train-images-idx3-ubyte` etc.), so the reproduction can
//! run on the paper's exact data where available.

use super::{Dataset, Splits};
use anyhow::{bail, Context, Result};
use std::fs;
use std::path::Path;

/// Parse an IDX3 image file into row-major [0,1] floats.
pub fn parse_idx_images(bytes: &[u8]) -> Result<(Vec<f64>, usize)> {
    if bytes.len() < 16 {
        bail!("idx3 header truncated");
    }
    let magic = u32::from_be_bytes(bytes[0..4].try_into().unwrap());
    if magic != 0x0000_0803 {
        bail!("bad idx3 magic {magic:#x}");
    }
    let n = u32::from_be_bytes(bytes[4..8].try_into().unwrap()) as usize;
    let rows = u32::from_be_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let cols = u32::from_be_bytes(bytes[12..16].try_into().unwrap()) as usize;
    let nf = rows * cols;
    let data = &bytes[16..];
    if data.len() != n * nf {
        bail!("idx3 payload size mismatch: {} != {}", data.len(), n * nf);
    }
    Ok((data.iter().map(|&b| b as f64 / 255.0).collect(), nf))
}

/// Parse an IDX1 label file.
pub fn parse_idx_labels(bytes: &[u8]) -> Result<Vec<u8>> {
    if bytes.len() < 8 {
        bail!("idx1 header truncated");
    }
    let magic = u32::from_be_bytes(bytes[0..4].try_into().unwrap());
    if magic != 0x0000_0801 {
        bail!("bad idx1 magic {magic:#x}");
    }
    let n = u32::from_be_bytes(bytes[4..8].try_into().unwrap()) as usize;
    let data = &bytes[8..];
    if data.len() != n {
        bail!("idx1 payload size mismatch");
    }
    Ok(data.to_vec())
}

fn load_pair(dir: &Path, img: &str, lbl: &str) -> Result<Dataset> {
    let ib = fs::read(dir.join(img)).with_context(|| format!("reading {img}"))?;
    let lb = fs::read(dir.join(lbl)).with_context(|| format!("reading {lbl}"))?;
    let (x, nf) = parse_idx_images(&ib)?;
    let labels = parse_idx_labels(&lb)?;
    if x.len() / nf != labels.len() {
        bail!("image/label count mismatch");
    }
    Ok(Dataset { x, labels, n_features: nf })
}

/// Load the four standard MNIST files from `dir`.
pub fn load_mnist(dir: &str) -> Result<Splits> {
    let d = Path::new(dir);
    Ok(Splits {
        train: load_pair(d, "train-images-idx3-ubyte", "train-labels-idx1-ubyte")?,
        test: load_pair(d, "t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_idx3(n: usize, r: usize, c: usize) -> Vec<u8> {
        let mut v = Vec::new();
        v.extend_from_slice(&0x0803u32.to_be_bytes());
        v.extend_from_slice(&(n as u32).to_be_bytes());
        v.extend_from_slice(&(r as u32).to_be_bytes());
        v.extend_from_slice(&(c as u32).to_be_bytes());
        v.extend((0..n * r * c).map(|i| (i % 256) as u8));
        v
    }

    fn fake_idx1(n: usize) -> Vec<u8> {
        let mut v = Vec::new();
        v.extend_from_slice(&0x0801u32.to_be_bytes());
        v.extend_from_slice(&(n as u32).to_be_bytes());
        v.extend((0..n).map(|i| (i % 10) as u8));
        v
    }

    #[test]
    fn parses_well_formed_idx() {
        let (x, nf) = parse_idx_images(&fake_idx3(3, 4, 4)).unwrap();
        assert_eq!(nf, 16);
        assert_eq!(x.len(), 48);
        assert_eq!(x[0], 0.0);
        assert!((x[1] - 1.0 / 255.0).abs() < 1e-12);
        let l = parse_idx_labels(&fake_idx1(5)).unwrap();
        assert_eq!(l, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(parse_idx_images(&[0, 0, 8, 1, 0, 0, 0, 0]).is_err());
        let mut bad = fake_idx3(2, 2, 2);
        bad.truncate(bad.len() - 1);
        assert!(parse_idx_images(&bad).is_err());
        assert!(parse_idx_labels(&[0u8; 4]).is_err());
    }

    #[test]
    fn load_mnist_missing_dir_errors() {
        assert!(load_mnist("/definitely/not/here").is_err());
    }
}
