//! # lpgd — Low-Precision Gradient Descent with Stochastic Rounding
//!
//! A production-grade reproduction of *"On the influence of stochastic
//! roundoff errors and their bias on the convergence of the gradient descent
//! method with low-precision floating-point computation"* (Xia, Massei,
//! Hochstenbach, Koren; 2022).
//!
//! The crate provides:
//! * [`fp`] — a bit-exact software simulator of low-precision floating-point
//!   formats (binary8/E5M2, bfloat16, …) with every rounding scheme in the
//!   paper — RN, directed modes, SR, SRε and signed-SRε — plus the open
//!   [`fp::scheme::RoundingScheme`] trait and [`fp::scheme::SchemeRegistry`]
//!   for registering new schemes (see `docs/api.md`);
//! * [`gd`] — the three-step GD iteration (8a)/(8b)/(8c) with per-tensor
//!   rounding control ([`gd::PolicyMap`]), the optimizer zoo
//!   ([`gd::Optimizer`]: plain GD, momentum, Nesterov, Adam with LR-decay
//!   schedules), the [`gd::RunBuilder`] front door, stagnation analysis
//!   (τ_k) and the paper's convergence bounds;
//! * [`problems`] — quadratics (Settings I/II), multinomial logistic
//!   regression and a two-layer NN;
//! * [`data`] — dataset substrate (procedural digits + IDX loader);
//! * [`runtime`] — PJRT execution of AOT-compiled JAX/Pallas train steps;
//! * [`coordinator`] — the experiment registry, the sharded multi-threaded
//!   scheduler (deterministic for every `--jobs` value) and the aggregation
//!   path that regenerate every table and figure of the paper;
//! * [`registry`] — the content-addressed, append-only result store shared
//!   by the offline CLI (`--registry DIR`) and the experiment service;
//! * [`serve`] — `lpgd serve`: the HTTP/1.1 experiment service that
//!   answers RunBuilder-shaped requests from the registry and computes
//!   only misses (see `docs/service.md`);
//! * [`util`] — the in-repo CLI/config/CSV/JSON/hash/bench plumbing (this
//!   image is offline: the only dependency is the vendored `anyhow` shim
//!   under `vendor/`, and the PJRT `xla` binding is gated behind the
//!   optional `pjrt` feature).
//!
//! See the top-level `README.md` for a quickstart and `docs/` for the
//! rounding-scheme ↔ paper mapping and the coordinator architecture.

#![warn(missing_docs)]
// Numeric-kernel style allowances for the clippy gate in scripts/verify.sh:
// index-based loops over several parallel buffers are the clearest way to
// write the paper's blocked linear algebra, and the fused kernel entry
// points legitimately take many scalars. Correctness lints stay enforced.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod coordinator;
pub mod data;
pub mod fp;
pub mod gd;
pub mod problems;
pub mod registry;
pub mod runtime;
pub mod serve;
pub mod util;
