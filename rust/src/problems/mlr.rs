//! Multinomial logistic regression (paper §5.2): softmax + cross-entropy
//! over a dense dataset, full-batch gradient descent.
//!
//! Parameters are the flattened `x = [W (C×D row-major) ; b (C)]`,
//! n = C·(D+1). The objective is convex [2], making this the paper's main
//! convex learning benchmark.
//!
//! The rounded gradient evaluators run on the fused kernel layer
//! ([`crate::fp::kernels`]): logits through the rounded GEMM, softmax
//! through the fused row kernel, and the gradient accumulators through the
//! fused slice rounders — identical values to the historic per-scalar path
//! under deterministic modes, same law (re-streamed randomness) under the
//! stochastic ones. The per-scalar implementation is retained as
//! [`Mlr::gradient_reference`] for the equivalence tests and the speedup
//! bench (`benches/gd_step.rs`).

use super::Problem;
use crate::data::Dataset;
use crate::fp::kernels::{self, ACC_BLOCK};
use crate::fp::linalg::LpCtx;

/// Multinomial logistic regression over a dense dataset (paper §5.2).
pub struct Mlr {
    /// Training data (the full batch of every GD step).
    pub data: Dataset,
    /// Number of classes C.
    pub n_classes: usize,
    d: usize,
}

impl Mlr {
    /// An MLR problem over `data` with `n_classes` output classes.
    pub fn new(data: Dataset, n_classes: usize) -> Self {
        let d = data.n_features;
        Self { data, n_classes, d }
    }

    #[inline]
    fn w<'a>(&self, x: &'a [f64]) -> &'a [f64] {
        &x[..self.n_classes * self.d]
    }

    #[inline]
    fn b<'a>(&self, x: &'a [f64]) -> &'a [f64] {
        &x[self.n_classes * self.d..]
    }

    /// Softmax probabilities for one sample, exact arithmetic.
    fn probs_exact(&self, x: &[f64], row: &[f64], out: &mut [f64]) {
        let (w, b) = (self.w(x), self.b(x));
        let c = self.n_classes;
        let mut maxz = f64::NEG_INFINITY;
        for k in 0..c {
            let z = crate::fp::linalg::exact::dot(&w[k * self.d..(k + 1) * self.d], row) + b[k];
            out[k] = z;
            maxz = maxz.max(z);
        }
        let mut sum = 0.0;
        for k in 0..c {
            out[k] = (out[k] - maxz).exp();
            sum += out[k];
        }
        for k in 0..c {
            out[k] /= sum;
        }
    }

    /// Classification test error (misclassification rate) — the metric of
    /// Figures 4 and 5.
    pub fn test_error(&self, x: &[f64], test: &Dataset) -> f64 {
        let c = self.n_classes;
        let mut p = vec![0.0; c];
        let mut wrong = 0usize;
        for i in 0..test.len() {
            self.probs_exact(x, test.row(i), &mut p);
            let pred = p
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(k, _)| k)
                .unwrap();
            if pred != test.labels[i] as usize {
                wrong += 1;
            }
        }
        wrong as f64 / test.len() as f64
    }

    /// The retained **scalar-reference** gradient kernel — the pre-kernel
    /// per-scalar implementation, byte-for-byte the historic rounding
    /// sequence (one [`LpCtx::fl`] per inexact result, one uniform per
    /// stochastic rounding). With a rounding context this models the
    /// paper's low-precision gradient evaluation (8a): forward logits,
    /// softmax ops, and — crucially — the *accumulations* are performed in
    /// the working format. Accumulating the per-sample contributions in
    /// binary8 is what loses gradient information under RN ("absorption":
    /// once the running sum S satisfies `term < u·S/2` the term vanishes;
    /// Gupta et al. 2015, paper §1/§5.2); SR preserves the terms in
    /// expectation. The accumulation is simulated at block granularity
    /// [`ACC_BLOCK`] (round the accumulator every B adds): for N ≫ B/u the
    /// absorption threshold is identical to per-op accumulation while
    /// costing B× fewer rounding calls — see DESIGN.md §8.
    ///
    /// Deterministic modes produce bit-identical gradients through this and
    /// the kernel path (asserted by the tests); the benches measure the
    /// kernel speedup against this method.
    pub fn gradient_reference(&self, x: &[f64], ctx: &mut LpCtx, out: &mut [f64], lp_acc: bool) {
        self.gradient_scalar(x, out, Some(ctx), lp_acc);
    }

    /// Scalar path shared by the exact evaluator (`ctx = None`) and
    /// [`Mlr::gradient_reference`].
    fn gradient_scalar(&self, x: &[f64], out: &mut [f64], ctx: Option<&mut LpCtx>, lp_acc: bool) {
        let (c, d, n) = (self.n_classes, self.d, self.data.len());
        let w = self.w(x);
        let b = self.b(x);
        out.fill(0.0);
        let (gw, gb) = out.split_at_mut(c * d);
        let mut z = vec![0.0; c];
        // When rounding, intermediates are stored in the working format.
        let mut ctx = ctx;
        // Per-sample mean scaling applied *inside* the accumulation so the
        // accumulator lives at gradient scale (as a low-precision
        // accumulator would).
        let inv_n = 1.0 / n as f64;
        for i in 0..n {
            let row = self.data.row(i);
            // Forward: z_k = w_k·row + b_k with blocked low-precision
            // accumulation of the dot product.
            let mut maxz = f64::NEG_INFINITY;
            for k in 0..c {
                let wrow = &w[k * d..(k + 1) * d];
                let mut zk = match ctx.as_deref_mut() {
                    Some(cx) if lp_acc => {
                        let mut acc = 0.0;
                        let mut j = 0;
                        while j < d {
                            let hi = (j + ACC_BLOCK).min(d);
                            let part: f64 = (j..hi).map(|t| wrow[t] * row[t]).sum();
                            acc = cx.add(acc, part);
                            j = hi;
                        }
                        cx.add(acc, b[k])
                    }
                    _ => crate::fp::linalg::exact::dot(wrow, row) + b[k],
                };
                if let Some(cx) = ctx.as_deref_mut() {
                    zk = cx.fl(zk);
                }
                z[k] = zk;
                maxz = maxz.max(zk);
            }
            // Softmax with max-shift; exp and normalization rounded.
            let mut sum = 0.0;
            for k in 0..c {
                let mut e = (z[k] - maxz).exp();
                if let Some(cx) = ctx.as_deref_mut() {
                    e = cx.fl(e);
                }
                z[k] = e;
                sum += e;
            }
            if let Some(cx) = ctx.as_deref_mut() {
                sum = cx.fl(sum);
            }
            let y = self.data.labels[i] as usize;
            for k in 0..c {
                let mut pk = z[k] / sum;
                if let Some(cx) = ctx.as_deref_mut() {
                    pk = cx.fl(pk);
                }
                let diff = (pk - if k == y { 1.0 } else { 0.0 }) * inv_n;
                let grow = &mut gw[k * d..(k + 1) * d];
                for (gj, &xj) in grow.iter_mut().zip(row) {
                    *gj += diff * xj;
                }
                gb[k] += diff;
            }
            // Absorption model only: blocked low-precision accumulation of
            // the gradient sums (round the accumulator every ACC_BLOCK
            // samples). The chop/result-rounding model rounds once at the
            // end instead.
            if (lp_acc && (i + 1) % ACC_BLOCK == 0) || i + 1 == n {
                if let Some(cx) = ctx.as_deref_mut() {
                    cx.fl_slice(gw);
                    cx.fl_slice(gb);
                }
            }
        }
    }

    /// The fused **kernel** gradient path: logits through the rounded GEMM,
    /// softmax through the fused row kernel, gradient accumulators through
    /// the fused slice rounders, processed in [`ACC_BLOCK`]-sample blocks
    /// (the absorption rounding boundary of the scalar path). Same f64
    /// intermediates and rounding steps as [`Mlr::gradient_scalar`]
    /// elementwise — bit-identical under deterministic modes, same law with
    /// batched randomness under the stochastic ones.
    fn gradient_kernel(&self, x: &[f64], out: &mut [f64], cx: &mut LpCtx, lp_acc: bool) {
        let (c, d, n) = (self.n_classes, self.d, self.data.len());
        let w = self.w(x);
        let b = self.b(x);
        out.fill(0.0);
        let (gw, gb) = out.split_at_mut(c * d);
        let inv_n = 1.0 / n as f64;
        let mut probs = vec![0.0; ACC_BLOCK * c];
        let mut sums: Vec<f64> = Vec::with_capacity(ACC_BLOCK);
        {
            let (plan, mode, rng) = cx.kernel_parts();
            let mut i0 = 0;
            while i0 < n {
                let i1 = (i0 + ACC_BLOCK).min(n);
                let rows = i1 - i0;
                let xblk = &self.data.x[i0 * d..i1 * d];
                let z = &mut probs[..rows * c];
                kernels::gemm_nt_bias_rounded(&plan, mode, xblk, rows, d, w, c, b, z, lp_acc, rng);
                kernels::softmax_rows_rounded(&plan, mode, z, rows, c, &mut sums, rng);
                // Gradient accumulation in exact f64, sample order preserved.
                for r in 0..rows {
                    let i = i0 + r;
                    let row = self.data.row(i);
                    let y = self.data.labels[i] as usize;
                    for k in 0..c {
                        let diff = (z[r * c + k] - if k == y { 1.0 } else { 0.0 }) * inv_n;
                        let grow = &mut gw[k * d..(k + 1) * d];
                        for (gj, &xj) in grow.iter_mut().zip(row) {
                            *gj += diff * xj;
                        }
                        gb[k] += diff;
                    }
                }
                // Absorption: round the accumulators at every block
                // boundary; chop: once at the end.
                if lp_acc || i1 == n {
                    plan.round_slice_scheme(mode, gw, rng);
                    plan.round_slice_scheme(mode, gb, rng);
                }
                i0 = i1;
            }
        }
        // Rounding-op accounting for profiling parity with the scalar path
        // (which, under lp_acc, counts ceil(d/B) block adds + the bias add +
        // one identity fl per logit).
        let forward = if lp_acc { (d.div_ceil(ACC_BLOCK) + 2) * c } else { c };
        let acc_events = if lp_acc { n.div_ceil(ACC_BLOCK) } else { 1 };
        cx.add_rounding_ops(
            (n * (forward + 2 * c + 1) + acc_events * (c * d + c)) as u64,
        );
    }
}

impl Problem for Mlr {
    fn dim(&self) -> usize {
        self.n_classes * (self.d + 1)
    }

    /// Mean cross-entropy loss over the training set (exact arithmetic).
    fn objective(&self, x: &[f64]) -> f64 {
        let mut p = vec![0.0; self.n_classes];
        let mut loss = 0.0;
        for i in 0..self.data.len() {
            self.probs_exact(x, self.data.row(i), &mut p);
            let y = self.data.labels[i] as usize;
            loss -= p[y].max(1e-300).ln();
        }
        loss / self.data.len() as f64
    }

    fn gradient_exact(&self, x: &[f64], out: &mut [f64]) {
        self.gradient_scalar(x, out, None, false);
    }

    /// chop protocol (paper §2.4): operation *results* rounded entrywise —
    /// evaluated through the fused kernel layer.
    fn gradient_rounded(&self, x: &[f64], ctx: &mut LpCtx, out: &mut [f64]) {
        self.gradient_kernel(x, out, ctx, false);
    }

    /// Absorption model: dot products and gradient sums accumulate in the
    /// working format (blocked, block 32) — the low-precision-accumulation
    /// mechanism behind Gupta et al.'s RN stagnation. Exposed through
    /// `GradModel::PerOp` and the `fig4a-acc` ablation experiment.
    /// Evaluated through the fused kernel layer.
    fn gradient_per_op(&self, x: &[f64], ctx: &mut LpCtx, out: &mut [f64]) {
        self.gradient_kernel(x, out, ctx, true);
    }

    /// L ≤ ‖X‖² / (2N) · const; we report the standard bound λ_max(XᵀX)/(4N)
    /// estimated by a few power iterations — cached would be nicer but this
    /// is called once per experiment.
    fn lipschitz(&self) -> Option<f64> {
        let (n, d) = (self.data.len(), self.d);
        // Power iteration on XᵀX / N.
        let mut v = vec![1.0 / (d as f64).sqrt(); d];
        let mut tmp = vec![0.0; n];
        for _ in 0..20 {
            for i in 0..n {
                tmp[i] = crate::fp::linalg::exact::dot(self.data.row(i), &v);
            }
            let mut nv = vec![0.0; d];
            for i in 0..n {
                for j in 0..d {
                    nv[j] += self.data.row(i)[j] * tmp[i];
                }
            }
            let norm = crate::fp::linalg::exact::norm2(&nv);
            for j in 0..d {
                v[j] = nv[j] / norm;
            }
        }
        for i in 0..n {
            tmp[i] = crate::fp::linalg::exact::dot(self.data.row(i), &v);
        }
        let lam = tmp.iter().map(|t| t * t).sum::<f64>() / n as f64;
        // Softmax Hessian spectral bound: ½ λ_max(XᵀX/N) (Böhning [2]).
        Some(0.5 * lam)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::fp::format::FpFormat;
    use crate::fp::rng::Rng;
    use crate::fp::round::Rounding;

    fn small_mlr() -> Mlr {
        Mlr::new(synth::generate(60, 8, 0), 10)
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let p = small_mlr();
        let n = p.dim();
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..n).map(|_| 0.05 * rng.normal()).collect();
        let mut g = vec![0.0; n];
        p.gradient_exact(&x, &mut g);
        let h = 1e-6;
        // Spot-check a handful of coordinates.
        for &i in &[0usize, 7, n / 2, n - 11, n - 1] {
            let mut xp = x.clone();
            xp[i] += h;
            let mut xm = x.clone();
            xm[i] -= h;
            let fd = (p.objective(&xp) - p.objective(&xm)) / (2.0 * h);
            assert!((fd - g[i]).abs() < 1e-5, "i={i} fd={fd} g={}", g[i]);
        }
    }

    #[test]
    fn zero_params_give_uniform_probs_and_log10_loss() {
        let p = small_mlr();
        let x = vec![0.0; p.dim()];
        let loss = p.objective(&x);
        assert!((loss - (10.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn rounded_gradient_close_to_exact_in_bfloat16() {
        let p = small_mlr();
        let n = p.dim();
        let x = vec![0.0; n];
        let mut ge = vec![0.0; n];
        let mut gr = vec![0.0; n];
        p.gradient_exact(&x, &mut ge);
        let mut ctx = LpCtx::new(FpFormat::BFLOAT16, Rounding::Sr, Rng::new(1));
        p.gradient_rounded(&x, &mut ctx, &mut gr);
        let rel = crate::fp::linalg::exact::norm2(&crate::fp::linalg::exact::sub(&gr, &ge))
            / crate::fp::linalg::exact::norm2(&ge);
        assert!(rel < 0.05, "rel={rel}");
        // All entries format-resident.
        assert!(gr.iter().all(|&v| FpFormat::BFLOAT16.contains(v)));
    }

    /// The kernel gradient path is bit-identical to the retained scalar
    /// reference under deterministic modes, for both the chop and the
    /// absorption σ₁ models — the per-mode determinism contract.
    #[test]
    fn kernel_gradient_matches_reference_deterministic() {
        let p = small_mlr();
        let n = p.dim();
        let mut rng = Rng::new(5);
        let x: Vec<f64> = (0..n).map(|_| 0.3 * rng.normal()).collect();
        for mode in [Rounding::RoundNearestEven, Rounding::RoundTowardZero, Rounding::RoundUp] {
            for (lp_acc, label) in [(false, "chop"), (true, "absorption")] {
                let mut gk = vec![0.0; n];
                let mut ck = LpCtx::new(FpFormat::BINARY8, mode, Rng::new(7));
                if lp_acc {
                    p.gradient_per_op(&x, &mut ck, &mut gk);
                } else {
                    p.gradient_rounded(&x, &mut ck, &mut gk);
                }
                let mut gr = vec![0.0; n];
                let mut cr = LpCtx::new(FpFormat::BINARY8, mode, Rng::new(7));
                p.gradient_reference(&x, &mut cr, &mut gr, lp_acc);
                assert_eq!(gk, gr, "{mode:?} {label}");
            }
        }
    }

    /// Stochastic kernel gradients stay format-resident and statistically
    /// close to the exact gradient (the law is unchanged by the fused path).
    #[test]
    fn kernel_gradient_stochastic_resident_and_close() {
        let p = small_mlr();
        let n = p.dim();
        let mut rng = Rng::new(6);
        let x: Vec<f64> = (0..n).map(|_| 0.1 * rng.normal()).collect();
        let mut ge = vec![0.0; n];
        p.gradient_exact(&x, &mut ge);
        for mode in [Rounding::Sr, Rounding::SrEps(0.2), Rounding::SignedSrEps(0.2)] {
            let mut g = vec![0.0; n];
            let mut cx = LpCtx::new(FpFormat::BFLOAT16, mode, Rng::new(8));
            p.gradient_per_op(&x, &mut cx, &mut g);
            assert!(g.iter().all(|&v| FpFormat::BFLOAT16.contains(v)), "{mode:?}");
            let rel = crate::fp::linalg::exact::norm2(&crate::fp::linalg::exact::sub(&g, &ge))
                / crate::fp::linalg::exact::norm2(&ge);
            assert!(rel < 0.2, "{mode:?} rel={rel}");
        }
    }

    #[test]
    fn training_reduces_test_error() {
        // A few exact GD steps must beat chance (90% error) decisively.
        let train = synth::generate(300, 8, 5);
        let test = synth::generate(100, 8, 6);
        let p = Mlr::new(train, 10);
        let mut x = vec![0.0; p.dim()];
        let mut g = vec![0.0; p.dim()];
        for _ in 0..40 {
            p.gradient_exact(&x, &mut g);
            for (xi, gi) in x.iter_mut().zip(&g) {
                *xi -= 1.0 * gi;
            }
        }
        let err = p.test_error(&x, &test);
        assert!(err < 0.45, "test error {err} (chance = 0.9)");
    }

    #[test]
    fn lipschitz_positive_and_moderate() {
        let p = small_mlr();
        let l = p.lipschitz().unwrap();
        assert!(l > 0.0 && l < 1e4, "L={l}");
    }
}
