//! Quadratic objectives f(x) = ½ (x − x*)ᵀ A (x − x*) — paper §5.1.
//!
//! The rounded gradient evaluators run through [`LpCtx`], so they accept
//! any registered rounding scheme (built-in or custom) via the open
//! [`crate::fp::scheme::Scheme`] handle the context carries.
//!
//! Two constructors mirror the paper's settings:
//! * [`Quadratic::setting1`]: A = diag(10⁻³, …, 10⁻³, 1) ∈ ℝ¹⁰⁰⁰ˣ¹⁰⁰⁰,
//!   x⁰ = [10⁻³, …, 10⁻³, 1]ᵀ, x* = 0, t = 10⁻⁵;
//! * [`Quadratic::setting2`]: dense symmetric A with eigenvalues 1…n
//!   (all entries nonzero), x⁰ = [n, n−1, …, 1]ᵀ, x* = 2⁻⁴·1, t = 1/L.

use super::Problem;
use crate::fp::linalg::{exact, LpCtx};
use crate::fp::rng::Rng;

/// Quadratic problem with either a diagonal or a dense symmetric matrix.
pub struct Quadratic {
    /// Diagonal (length n) when dense is None.
    diag: Vec<f64>,
    /// Row-major dense n×n symmetric matrix (takes precedence when set).
    dense: Option<Vec<f64>>,
    /// The minimizer x*.
    xstar: Vec<f64>,
    /// Largest eigenvalue = Lipschitz constant of ∇f.
    lip: f64,
    /// Smallest eigenvalue = PL constant μ (None when unknown).
    mu: Option<f64>,
    /// Scratch for (x − x*).
    n: usize,
}

impl Quadratic {
    /// Diagonal quadratic `½ Σ dᵢ (xᵢ − x*ᵢ)²`.
    pub fn diagonal(diag: Vec<f64>, xstar: Vec<f64>) -> Self {
        assert_eq!(diag.len(), xstar.len());
        let lip = diag.iter().cloned().fold(0.0f64, f64::max);
        let mu = diag.iter().cloned().fold(f64::INFINITY, f64::min);
        let n = diag.len();
        Self { diag, dense: None, xstar, lip, mu: Some(mu), n }
    }

    /// Dense symmetric quadratic with matrix `a` (row-major n×n) and
    /// largest eigenvalue `lip` (smallest eigenvalue unknown ⇒ no PL
    /// constant; see [`Quadratic::setting2`], which knows its spectrum).
    pub fn dense(a: Vec<f64>, xstar: Vec<f64>, lip: f64) -> Self {
        let n = xstar.len();
        assert_eq!(a.len(), n * n);
        Self { diag: vec![], dense: Some(a), xstar, lip, mu: None, n }
    }

    /// Paper Setting I (§5.1).
    pub fn setting1(n: usize) -> (Self, Vec<f64>, f64) {
        let mut diag = vec![1e-3; n];
        diag[n - 1] = 1.0;
        let mut x0 = vec![1e-3; n];
        x0[n - 1] = 1.0;
        let xstar = vec![0.0; n];
        (Self::diagonal(diag, xstar), x0, 1e-5)
    }

    /// Paper Setting II (§5.1): symmetric A with spectrum {1, …, n} and no
    /// zero entries, built as A = Q D Qᵀ for a random orthogonal Q
    /// (Householder-based). x⁰ = [n, …, 1]ᵀ, x* = 2⁻⁴·1, t = 1/L = 1/n.
    pub fn setting2(n: usize, seed: u64) -> (Self, Vec<f64>, f64) {
        let mut rng = Rng::new(seed ^ 0x5e771462);
        // Householder reflector H = I − 2vvᵀ applied to D: A = H D H is
        // symmetric with the same spectrum, and dense for generic v.
        let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let nv = exact::norm2(&v);
        for vi in v.iter_mut() {
            *vi /= nv;
        }
        // A = (I − 2vvᵀ) D (I − 2vvᵀ) = D − 2vwᵀ − 2wvᵀ + 4(vᵀw) vvᵀ,
        // where w = Dv.
        let d: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let w: Vec<f64> = (0..n).map(|i| d[i] * v[i]).collect();
        let vtw = exact::dot(&v, &w);
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut val = -2.0 * v[i] * w[j] - 2.0 * w[i] * v[j] + 4.0 * vtw * v[i] * v[j];
                if i == j {
                    val += d[i];
                }
                a[i * n + j] = val;
            }
        }
        let x0: Vec<f64> = (0..n).map(|i| (n - i) as f64).collect();
        let xstar = vec![0.0625; n]; // 2⁻⁴
        let lip = n as f64;
        let mut p = Self::dense(a, xstar, lip);
        p.mu = Some(1.0); // spectrum {1, …, n} by construction
        (p, x0, 1.0 / n as f64)
    }

    fn residual(&self, x: &[f64]) -> Vec<f64> {
        exact::sub(x, &self.xstar)
    }
}

/// Lane-batched exact gemv over an interleaved slab: per lane bit-identical
/// to [`exact::gemv`] on that lane's column (one running accumulator per
/// lane, summed in the same sequential `j` order), with a single pass over
/// `a` shared by all lanes — the cache-reuse move the multi-seed lane mode
/// is built on (the matrix is read once per batch instead of once per rep).
fn gemv_lanes(a: &[f64], n: usize, lanes: usize, xslab: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), n * n);
    debug_assert_eq!(xslab.len(), n * lanes);
    debug_assert_eq!(out.len(), n * lanes);
    let mut acc = vec![0.0f64; lanes];
    for i in 0..n {
        acc.fill(0.0);
        let row = &a[i * n..(i + 1) * n];
        for (j, &aij) in row.iter().enumerate() {
            let col = &xslab[j * lanes..(j + 1) * lanes];
            // Independent lanes: the inner loop autovectorizes without any
            // reassociation inside a lane's sum.
            for (s, &x) in acc.iter_mut().zip(col) {
                *s += aij * x;
            }
        }
        out[i * lanes..(i + 1) * lanes].copy_from_slice(&acc);
    }
}

impl Problem for Quadratic {
    fn dim(&self) -> usize {
        self.n
    }

    fn objective(&self, x: &[f64]) -> f64 {
        let r = self.residual(x);
        match &self.dense {
            None => 0.5 * r.iter().zip(&self.diag).map(|(ri, di)| di * ri * ri).sum::<f64>(),
            Some(a) => {
                let mut ar = vec![0.0; self.n];
                exact::gemv(a, self.n, self.n, &r, &mut ar);
                0.5 * exact::dot(&r, &ar)
            }
        }
    }

    fn gradient_exact(&self, x: &[f64], out: &mut [f64]) {
        let r = self.residual(x);
        match &self.dense {
            None => {
                for i in 0..self.n {
                    out[i] = self.diag[i] * r[i];
                }
            }
            Some(a) => exact::gemv(a, self.n, self.n, &r, out),
        }
    }

    /// chop-style: r = fl(x − x*), then g = fl(A·r) rounded entrywise
    /// (diagonal: g = fl(dᵢ·rᵢ); dense: binary64 gemv then entrywise round —
    /// the matrix entries themselves are stored rounded once).
    fn gradient_rounded(&self, x: &[f64], ctx: &mut LpCtx, out: &mut [f64]) {
        let mut r = vec![0.0; self.n];
        for i in 0..self.n {
            r[i] = ctx.sub(x[i], self.xstar[i]);
        }
        match &self.dense {
            None => {
                for i in 0..self.n {
                    out[i] = ctx.mul(self.diag[i], r[i]);
                }
            }
            Some(a) => {
                exact::gemv(a, self.n, self.n, &r, out);
                ctx.fl_slice(out);
            }
        }
    }

    /// Strict per-op model: every multiply and add of the gemv is rounded.
    fn gradient_per_op(&self, x: &[f64], ctx: &mut LpCtx, out: &mut [f64]) {
        let mut r = vec![0.0; self.n];
        for i in 0..self.n {
            r[i] = ctx.sub(x[i], self.xstar[i]);
        }
        match &self.dense {
            None => {
                for i in 0..self.n {
                    out[i] = ctx.mul(self.diag[i], r[i]);
                }
            }
            Some(a) => ctx.gemv(a, self.n, self.n, &r, out),
        }
    }

    /// Shared-pass lane objective: the residuals and (dense) `A·r` pass run
    /// once over the slab; per lane the arithmetic order matches the scalar
    /// [`Quadratic::objective`] exactly, so the values are bit-identical.
    fn objective_lanes(&self, xslab: &[f64], lanes: usize, out: &mut [f64]) {
        let n = self.n;
        debug_assert_eq!(xslab.len(), n * lanes);
        debug_assert_eq!(out.len(), lanes);
        let mut r = vec![0.0; n * lanes];
        for i in 0..n {
            for l in 0..lanes {
                r[i * lanes + l] = xslab[i * lanes + l] - self.xstar[i];
            }
        }
        out.fill(0.0);
        match &self.dense {
            None => {
                for i in 0..n {
                    let di = self.diag[i];
                    for (l, o) in out.iter_mut().enumerate() {
                        let ri = r[i * lanes + l];
                        *o += di * ri * ri;
                    }
                }
            }
            Some(a) => {
                let mut ar = vec![0.0; n * lanes];
                gemv_lanes(a, n, lanes, &r, &mut ar);
                for i in 0..n {
                    for (l, o) in out.iter_mut().enumerate() {
                        *o += r[i * lanes + l] * ar[i * lanes + l];
                    }
                }
            }
        }
        for o in out.iter_mut() {
            *o *= 0.5;
        }
    }

    /// Shared-pass lane exact gradient (dense: one matrix pass for all
    /// lanes via [`gemv_lanes`]); per lane bit-identical to
    /// [`Quadratic::gradient_exact`] on that lane's column.
    fn gradient_exact_lanes(&self, xslab: &[f64], lanes: usize, out: &mut [f64]) {
        let n = self.n;
        debug_assert_eq!(xslab.len(), n * lanes);
        debug_assert_eq!(out.len(), n * lanes);
        match &self.dense {
            None => {
                for i in 0..n {
                    let di = self.diag[i];
                    let xs = self.xstar[i];
                    for l in 0..lanes {
                        let idx = i * lanes + l;
                        out[idx] = di * (xslab[idx] - xs);
                    }
                }
            }
            Some(a) => {
                let mut r = vec![0.0; n * lanes];
                for i in 0..n {
                    for l in 0..lanes {
                        r[i * lanes + l] = xslab[i * lanes + l] - self.xstar[i];
                    }
                }
                gemv_lanes(a, n, lanes, &r, out);
            }
        }
    }

    /// Shared-pass lane chop gradient: per-`(i, l)` rounded ops in element
    /// order through lane `l`'s context (the same call sequence the scalar
    /// [`Quadratic::gradient_rounded`] makes per lane — bit-identical
    /// values *and* stream consumption), with the exact dense gemv shared
    /// across lanes.
    fn gradient_rounded_lanes(
        &self,
        xslab: &[f64],
        lanes: usize,
        ctxs: &mut [LpCtx],
        out: &mut [f64],
    ) {
        let n = self.n;
        debug_assert_eq!(xslab.len(), n * lanes);
        debug_assert_eq!(out.len(), n * lanes);
        debug_assert_eq!(ctxs.len(), lanes);
        let mut r = vec![0.0; n * lanes];
        for i in 0..n {
            for (l, ctx) in ctxs.iter_mut().enumerate() {
                let idx = i * lanes + l;
                r[idx] = ctx.sub(xslab[idx], self.xstar[i]);
            }
        }
        match &self.dense {
            None => {
                for i in 0..n {
                    let di = self.diag[i];
                    for (l, ctx) in ctxs.iter_mut().enumerate() {
                        let idx = i * lanes + l;
                        out[idx] = ctx.mul(di, r[idx]);
                    }
                }
            }
            Some(a) => {
                gemv_lanes(a, n, lanes, &r, out);
                // Entrywise storage rounding in `fl_slice` order per lane.
                for i in 0..n {
                    for (l, ctx) in ctxs.iter_mut().enumerate() {
                        let idx = i * lanes + l;
                        out[idx] = ctx.fl(out[idx]);
                    }
                }
            }
        }
    }

    fn lipschitz(&self) -> Option<f64> {
        Some(self.lip)
    }

    fn pl_constant(&self) -> Option<f64> {
        self.mu
    }

    fn optimum(&self) -> Option<&[f64]> {
        Some(&self.xstar)
    }

    fn sigma1_constant(&self) -> Option<f64> {
        // Paper §3.1: c = 2 for diagonal A.
        if self.dense.is_none() {
            Some(2.0)
        } else {
            // c = 2nu‖A‖_∞ M / (1−2nu) with M an iterate bound; report the
            // diagnostic value for M = ‖x⁰‖_∞ upper estimate (n).
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::format::FpFormat;
    use crate::fp::rng::Rng;
    use crate::fp::round::Rounding;

    #[test]
    fn setting1_shapes() {
        let (p, x0, t) = Quadratic::setting1(1000);
        assert_eq!(p.dim(), 1000);
        assert_eq!(t, 1e-5);
        assert_eq!(x0[999], 1.0);
        assert_eq!(x0[0], 1e-3);
        assert_eq!(p.lipschitz(), Some(1.0));
        // f(x0) = ½(999·10⁻³·10⁻⁶ + 1) ≈ ½·1.000999.
        let f0 = p.objective(&x0);
        assert!((f0 - 0.5 * (999.0 * 1e-9 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn setting2_spectrum_and_symmetry() {
        let n = 50;
        let (p, x0, t) = Quadratic::setting2(n, 0);
        let a = p.dense.as_ref().unwrap();
        // Symmetry.
        for i in 0..n {
            for j in 0..n {
                assert!((a[i * n + j] - a[j * n + i]).abs() < 1e-12);
            }
        }
        // trace(A) = Σ eigenvalues = n(n+1)/2.
        let tr: f64 = (0..n).map(|i| a[i * n + i]).sum();
        assert!((tr - (n * (n + 1)) as f64 / 2.0).abs() < 1e-8, "tr={tr}");
        // Dense: essentially no zero entries.
        let zeros = a.iter().filter(|&&v| v == 0.0).count();
        assert_eq!(zeros, 0);
        assert_eq!(t, 1.0 / n as f64);
        assert_eq!(x0[0], n as f64);
        assert_eq!(x0[n - 1], 1.0);
    }

    #[test]
    fn gradient_exact_matches_finite_differences() {
        let (p, _, _) = Quadratic::setting2(10, 3);
        let x: Vec<f64> = (0..10).map(|i| 0.3 * i as f64 - 1.0).collect();
        let mut g = vec![0.0; 10];
        p.gradient_exact(&x, &mut g);
        let h = 1e-6;
        for i in 0..10 {
            let mut xp = x.clone();
            xp[i] += h;
            let mut xm = x.clone();
            xm[i] -= h;
            let fd = (p.objective(&xp) - p.objective(&xm)) / (2.0 * h);
            assert!((fd - g[i]).abs() < 1e-4, "i={i} fd={fd} g={}", g[i]);
        }
    }

    #[test]
    fn rounded_gradient_satisfies_eq9_bound() {
        // Diagonal case: |σ₁ᵢ| ≤ c·u·(|∇fᵢ| + 1) with c = 2 (paper §3.1).
        let (p, x0, _) = Quadratic::setting1(100);
        let fmt = FpFormat::BFLOAT16;
        let u = fmt.unit_roundoff();
        let mut ctx = LpCtx::new(fmt, Rounding::Sr, Rng::new(4));
        let mut g = vec![0.0; 100];
        let mut ge = vec![0.0; 100];
        p.gradient_rounded(&x0, &mut ctx, &mut g);
        p.gradient_exact(&x0, &mut ge);
        // SR has per-op bound 2u, two ops ⇒ c_eff ≈ 2·2 = 4; allow c = 5.
        for i in 0..100 {
            let sigma = (g[i] - ge[i]).abs();
            assert!(sigma <= 5.0 * u * (ge[i].abs() + 1.0), "i={i} σ={sigma}");
        }
    }

    #[test]
    fn per_op_vs_after_op_gradients_close() {
        let (p, x0, _) = Quadratic::setting2(30, 1);
        let fmt = FpFormat::BFLOAT16;
        let mut c1 = LpCtx::new(fmt, Rounding::Sr, Rng::new(9));
        let mut c2 = LpCtx::new(fmt, Rounding::Sr, Rng::new(9));
        let mut g1 = vec![0.0; 30];
        let mut g2 = vec![0.0; 30];
        let mut ge = vec![0.0; 30];
        p.gradient_rounded(&x0, &mut c1, &mut g1);
        p.gradient_per_op(&x0, &mut c2, &mut g2);
        p.gradient_exact(&x0, &mut ge);
        let n2 = exact::norm2(&ge);
        assert!(exact::norm2(&exact::sub(&g1, &ge)) / n2 < 0.05);
        // Per-op accumulates more error but must stay within the γ_n regime.
        assert!(exact::norm2(&exact::sub(&g2, &ge)) / n2 < 0.3);
    }

    /// The shared-pass lane evaluators are bit-identical per lane to the
    /// scalar ones — objective, exact gradient, and the chop gradient
    /// including context stream consumption and op counts — for both the
    /// diagonal and the dense matrix shape.
    #[test]
    fn lane_evaluators_match_scalar_per_lane() {
        let diag =
            Quadratic::diagonal(vec![2.0, 0.5, 1.0, 3.0, 0.1], vec![1.0, -1.0, 0.0, 2.0, 0.5]);
        let dense = Quadratic::setting2(17, 1).0;
        for p in [&diag, &dense] {
            let n = p.dim();
            for lanes in [1usize, 4, 5] {
                let mut gen = Rng::new(88);
                let cols: Vec<Vec<f64>> =
                    (0..lanes).map(|_| (0..n).map(|_| gen.normal() * 3.0).collect()).collect();
                let mut xslab = vec![0.0; n * lanes];
                for i in 0..n {
                    for l in 0..lanes {
                        xslab[i * lanes + l] = cols[l][i];
                    }
                }
                // Objective.
                let mut fs = vec![0.0; lanes];
                p.objective_lanes(&xslab, lanes, &mut fs);
                for l in 0..lanes {
                    assert_eq!(fs[l].to_bits(), p.objective(&cols[l]).to_bits(), "f lane {l}");
                }
                // Exact gradient.
                let mut gslab = vec![0.0; n * lanes];
                p.gradient_exact_lanes(&xslab, lanes, &mut gslab);
                let mut g = vec![0.0; n];
                for l in 0..lanes {
                    p.gradient_exact(&cols[l], &mut g);
                    for i in 0..n {
                        assert_eq!(gslab[i * lanes + l].to_bits(), g[i].to_bits(), "∇ lane {l}");
                    }
                }
                // Chop gradient: values, stream end state, and op counts.
                let root = Rng::new(7);
                let mut ctxs: Vec<LpCtx> = (0..lanes as u64)
                    .map(|l| LpCtx::new(FpFormat::BFLOAT16, Rounding::Sr, root.split(l)))
                    .collect();
                p.gradient_rounded_lanes(&xslab, lanes, &mut ctxs, &mut gslab);
                for l in 0..lanes {
                    let mut oracle =
                        LpCtx::new(FpFormat::BFLOAT16, Rounding::Sr, root.split(l as u64));
                    p.gradient_rounded(&cols[l], &mut oracle, &mut g);
                    for i in 0..n {
                        assert_eq!(gslab[i * lanes + l].to_bits(), g[i].to_bits(), "ĝ lane {l}");
                    }
                    assert_eq!(ctxs[l].rounding_ops, oracle.rounding_ops, "ops lane {l}");
                    assert_eq!(
                        ctxs[l].rng.next_u64(),
                        oracle.rng.next_u64(),
                        "stream lane {l}"
                    );
                }
            }
        }
    }
}
