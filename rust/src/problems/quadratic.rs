//! Quadratic objectives f(x) = ½ (x − x*)ᵀ A (x − x*) — paper §5.1.
//!
//! The rounded gradient evaluators run through [`LpCtx`], so they accept
//! any registered rounding scheme (built-in or custom) via the open
//! [`crate::fp::scheme::Scheme`] handle the context carries.
//!
//! Two constructors mirror the paper's settings:
//! * [`Quadratic::setting1`]: A = diag(10⁻³, …, 10⁻³, 1) ∈ ℝ¹⁰⁰⁰ˣ¹⁰⁰⁰,
//!   x⁰ = [10⁻³, …, 10⁻³, 1]ᵀ, x* = 0, t = 10⁻⁵;
//! * [`Quadratic::setting2`]: dense symmetric A with eigenvalues 1…n
//!   (all entries nonzero), x⁰ = [n, n−1, …, 1]ᵀ, x* = 2⁻⁴·1, t = 1/L.

use super::Problem;
use crate::fp::linalg::{exact, LpCtx};
use crate::fp::rng::Rng;

/// Quadratic problem with either a diagonal or a dense symmetric matrix.
pub struct Quadratic {
    /// Diagonal (length n) when dense is None.
    diag: Vec<f64>,
    /// Row-major dense n×n symmetric matrix (takes precedence when set).
    dense: Option<Vec<f64>>,
    /// The minimizer x*.
    xstar: Vec<f64>,
    /// Largest eigenvalue = Lipschitz constant of ∇f.
    lip: f64,
    /// Smallest eigenvalue = PL constant μ (None when unknown).
    mu: Option<f64>,
    /// Scratch for (x − x*).
    n: usize,
}

impl Quadratic {
    /// Diagonal quadratic `½ Σ dᵢ (xᵢ − x*ᵢ)²`.
    pub fn diagonal(diag: Vec<f64>, xstar: Vec<f64>) -> Self {
        assert_eq!(diag.len(), xstar.len());
        let lip = diag.iter().cloned().fold(0.0f64, f64::max);
        let mu = diag.iter().cloned().fold(f64::INFINITY, f64::min);
        let n = diag.len();
        Self { diag, dense: None, xstar, lip, mu: Some(mu), n }
    }

    /// Dense symmetric quadratic with matrix `a` (row-major n×n) and
    /// largest eigenvalue `lip` (smallest eigenvalue unknown ⇒ no PL
    /// constant; see [`Quadratic::setting2`], which knows its spectrum).
    pub fn dense(a: Vec<f64>, xstar: Vec<f64>, lip: f64) -> Self {
        let n = xstar.len();
        assert_eq!(a.len(), n * n);
        Self { diag: vec![], dense: Some(a), xstar, lip, mu: None, n }
    }

    /// Paper Setting I (§5.1).
    pub fn setting1(n: usize) -> (Self, Vec<f64>, f64) {
        let mut diag = vec![1e-3; n];
        diag[n - 1] = 1.0;
        let mut x0 = vec![1e-3; n];
        x0[n - 1] = 1.0;
        let xstar = vec![0.0; n];
        (Self::diagonal(diag, xstar), x0, 1e-5)
    }

    /// Paper Setting II (§5.1): symmetric A with spectrum {1, …, n} and no
    /// zero entries, built as A = Q D Qᵀ for a random orthogonal Q
    /// (Householder-based). x⁰ = [n, …, 1]ᵀ, x* = 2⁻⁴·1, t = 1/L = 1/n.
    pub fn setting2(n: usize, seed: u64) -> (Self, Vec<f64>, f64) {
        let mut rng = Rng::new(seed ^ 0x5e771462);
        // Householder reflector H = I − 2vvᵀ applied to D: A = H D H is
        // symmetric with the same spectrum, and dense for generic v.
        let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let nv = exact::norm2(&v);
        for vi in v.iter_mut() {
            *vi /= nv;
        }
        // A = (I − 2vvᵀ) D (I − 2vvᵀ) = D − 2vwᵀ − 2wvᵀ + 4(vᵀw) vvᵀ,
        // where w = Dv.
        let d: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let w: Vec<f64> = (0..n).map(|i| d[i] * v[i]).collect();
        let vtw = exact::dot(&v, &w);
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut val = -2.0 * v[i] * w[j] - 2.0 * w[i] * v[j] + 4.0 * vtw * v[i] * v[j];
                if i == j {
                    val += d[i];
                }
                a[i * n + j] = val;
            }
        }
        let x0: Vec<f64> = (0..n).map(|i| (n - i) as f64).collect();
        let xstar = vec![0.0625; n]; // 2⁻⁴
        let lip = n as f64;
        let mut p = Self::dense(a, xstar, lip);
        p.mu = Some(1.0); // spectrum {1, …, n} by construction
        (p, x0, 1.0 / n as f64)
    }

    fn residual(&self, x: &[f64]) -> Vec<f64> {
        exact::sub(x, &self.xstar)
    }
}

impl Problem for Quadratic {
    fn dim(&self) -> usize {
        self.n
    }

    fn objective(&self, x: &[f64]) -> f64 {
        let r = self.residual(x);
        match &self.dense {
            None => 0.5 * r.iter().zip(&self.diag).map(|(ri, di)| di * ri * ri).sum::<f64>(),
            Some(a) => {
                let mut ar = vec![0.0; self.n];
                exact::gemv(a, self.n, self.n, &r, &mut ar);
                0.5 * exact::dot(&r, &ar)
            }
        }
    }

    fn gradient_exact(&self, x: &[f64], out: &mut [f64]) {
        let r = self.residual(x);
        match &self.dense {
            None => {
                for i in 0..self.n {
                    out[i] = self.diag[i] * r[i];
                }
            }
            Some(a) => exact::gemv(a, self.n, self.n, &r, out),
        }
    }

    /// chop-style: r = fl(x − x*), then g = fl(A·r) rounded entrywise
    /// (diagonal: g = fl(dᵢ·rᵢ); dense: binary64 gemv then entrywise round —
    /// the matrix entries themselves are stored rounded once).
    fn gradient_rounded(&self, x: &[f64], ctx: &mut LpCtx, out: &mut [f64]) {
        let mut r = vec![0.0; self.n];
        for i in 0..self.n {
            r[i] = ctx.sub(x[i], self.xstar[i]);
        }
        match &self.dense {
            None => {
                for i in 0..self.n {
                    out[i] = ctx.mul(self.diag[i], r[i]);
                }
            }
            Some(a) => {
                exact::gemv(a, self.n, self.n, &r, out);
                ctx.fl_slice(out);
            }
        }
    }

    /// Strict per-op model: every multiply and add of the gemv is rounded.
    fn gradient_per_op(&self, x: &[f64], ctx: &mut LpCtx, out: &mut [f64]) {
        let mut r = vec![0.0; self.n];
        for i in 0..self.n {
            r[i] = ctx.sub(x[i], self.xstar[i]);
        }
        match &self.dense {
            None => {
                for i in 0..self.n {
                    out[i] = ctx.mul(self.diag[i], r[i]);
                }
            }
            Some(a) => ctx.gemv(a, self.n, self.n, &r, out),
        }
    }

    fn lipschitz(&self) -> Option<f64> {
        Some(self.lip)
    }

    fn pl_constant(&self) -> Option<f64> {
        self.mu
    }

    fn optimum(&self) -> Option<&[f64]> {
        Some(&self.xstar)
    }

    fn sigma1_constant(&self) -> Option<f64> {
        // Paper §3.1: c = 2 for diagonal A.
        if self.dense.is_none() {
            Some(2.0)
        } else {
            // c = 2nu‖A‖_∞ M / (1−2nu) with M an iterate bound; report the
            // diagnostic value for M = ‖x⁰‖_∞ upper estimate (n).
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::format::FpFormat;
    use crate::fp::rng::Rng;
    use crate::fp::round::Rounding;

    #[test]
    fn setting1_shapes() {
        let (p, x0, t) = Quadratic::setting1(1000);
        assert_eq!(p.dim(), 1000);
        assert_eq!(t, 1e-5);
        assert_eq!(x0[999], 1.0);
        assert_eq!(x0[0], 1e-3);
        assert_eq!(p.lipschitz(), Some(1.0));
        // f(x0) = ½(999·10⁻³·10⁻⁶ + 1) ≈ ½·1.000999.
        let f0 = p.objective(&x0);
        assert!((f0 - 0.5 * (999.0 * 1e-9 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn setting2_spectrum_and_symmetry() {
        let n = 50;
        let (p, x0, t) = Quadratic::setting2(n, 0);
        let a = p.dense.as_ref().unwrap();
        // Symmetry.
        for i in 0..n {
            for j in 0..n {
                assert!((a[i * n + j] - a[j * n + i]).abs() < 1e-12);
            }
        }
        // trace(A) = Σ eigenvalues = n(n+1)/2.
        let tr: f64 = (0..n).map(|i| a[i * n + i]).sum();
        assert!((tr - (n * (n + 1)) as f64 / 2.0).abs() < 1e-8, "tr={tr}");
        // Dense: essentially no zero entries.
        let zeros = a.iter().filter(|&&v| v == 0.0).count();
        assert_eq!(zeros, 0);
        assert_eq!(t, 1.0 / n as f64);
        assert_eq!(x0[0], n as f64);
        assert_eq!(x0[n - 1], 1.0);
    }

    #[test]
    fn gradient_exact_matches_finite_differences() {
        let (p, _, _) = Quadratic::setting2(10, 3);
        let x: Vec<f64> = (0..10).map(|i| 0.3 * i as f64 - 1.0).collect();
        let mut g = vec![0.0; 10];
        p.gradient_exact(&x, &mut g);
        let h = 1e-6;
        for i in 0..10 {
            let mut xp = x.clone();
            xp[i] += h;
            let mut xm = x.clone();
            xm[i] -= h;
            let fd = (p.objective(&xp) - p.objective(&xm)) / (2.0 * h);
            assert!((fd - g[i]).abs() < 1e-4, "i={i} fd={fd} g={}", g[i]);
        }
    }

    #[test]
    fn rounded_gradient_satisfies_eq9_bound() {
        // Diagonal case: |σ₁ᵢ| ≤ c·u·(|∇fᵢ| + 1) with c = 2 (paper §3.1).
        let (p, x0, _) = Quadratic::setting1(100);
        let fmt = FpFormat::BFLOAT16;
        let u = fmt.unit_roundoff();
        let mut ctx = LpCtx::new(fmt, Rounding::Sr, Rng::new(4));
        let mut g = vec![0.0; 100];
        let mut ge = vec![0.0; 100];
        p.gradient_rounded(&x0, &mut ctx, &mut g);
        p.gradient_exact(&x0, &mut ge);
        // SR has per-op bound 2u, two ops ⇒ c_eff ≈ 2·2 = 4; allow c = 5.
        for i in 0..100 {
            let sigma = (g[i] - ge[i]).abs();
            assert!(sigma <= 5.0 * u * (ge[i].abs() + 1.0), "i={i} σ={sigma}");
        }
    }

    #[test]
    fn per_op_vs_after_op_gradients_close() {
        let (p, x0, _) = Quadratic::setting2(30, 1);
        let fmt = FpFormat::BFLOAT16;
        let mut c1 = LpCtx::new(fmt, Rounding::Sr, Rng::new(9));
        let mut c2 = LpCtx::new(fmt, Rounding::Sr, Rng::new(9));
        let mut g1 = vec![0.0; 30];
        let mut g2 = vec![0.0; 30];
        let mut ge = vec![0.0; 30];
        p.gradient_rounded(&x0, &mut c1, &mut g1);
        p.gradient_per_op(&x0, &mut c2, &mut g2);
        p.gradient_exact(&x0, &mut ge);
        let n2 = exact::norm2(&ge);
        assert!(exact::norm2(&exact::sub(&g1, &ge)) / n2 < 0.05);
        // Per-op accumulates more error but must stay within the γ_n regime.
        assert!(exact::norm2(&exact::sub(&g2, &ge)) / n2 < 0.3);
    }
}
