//! Optimization problems (systems S8–S10): the paper's three case studies.
//!
//! A [`Problem`] exposes the objective and *three* gradient evaluators,
//! matching the σ₁ models of [`crate::gd::GradModel`]:
//! exact (binary64), chop-style round-after-op, and strict per-op rounding.

use crate::fp::linalg::LpCtx;

pub mod mlr;
pub mod nn;
pub mod quadratic;

pub use mlr::Mlr;
pub use nn::TwoLayerNn;
pub use quadratic::Quadratic;

/// A differentiable objective f: ℝⁿ → ℝ, with gradient evaluation under
/// configurable low-precision arithmetic.
pub trait Problem {
    /// Dimension n of the parameter vector.
    fn dim(&self) -> usize;

    /// Objective value, in exact (binary64) arithmetic (monitoring only).
    fn objective(&self, x: &[f64]) -> f64;

    /// Exact gradient (σ₁ = 0).
    fn gradient_exact(&self, x: &[f64], out: &mut [f64]);

    /// chop-style gradient: operations run in binary64, every operation
    /// *result* is rounded entrywise into `ctx` (the paper's §2.4 protocol).
    fn gradient_rounded(&self, x: &[f64], ctx: &mut LpCtx, out: &mut [f64]);

    /// Strict per-elementary-op rounded gradient ([13, §3.1] accumulation).
    /// Default: fall back to the round-after-op model.
    fn gradient_per_op(&self, x: &[f64], ctx: &mut LpCtx, out: &mut [f64]) {
        self.gradient_rounded(x, ctx, out);
    }

    /// Lipschitz constant L of ∇f, when known analytically.
    fn lipschitz(&self) -> Option<f64> {
        None
    }

    /// Polyak–Łojasiewicz constant μ (‖∇f(x)‖² ≥ 2μ(f(x) − f*)), when
    /// known analytically — drives the fixed-point PL bounds of
    /// [`crate::gd::theory`] and the `plfp*` experiments. For a quadratic
    /// this is the smallest eigenvalue of A.
    fn pl_constant(&self) -> Option<f64> {
        None
    }

    /// The minimizer x*, when known analytically.
    fn optimum(&self) -> Option<&[f64]> {
        None
    }

    /// The constant `c` of the σ₁ bound (9), when known analytically.
    fn sigma1_constant(&self) -> Option<f64> {
        None
    }
}
