//! Optimization problems (systems S8–S10): the paper's three case studies.
//!
//! A [`Problem`] exposes the objective and *three* gradient evaluators,
//! matching the σ₁ models of [`crate::gd::GradModel`]:
//! exact (binary64), chop-style round-after-op, and strict per-op rounding.

use crate::fp::linalg::LpCtx;

pub mod mlr;
pub mod nn;
pub mod quadratic;

pub use mlr::Mlr;
pub use nn::TwoLayerNn;
pub use quadratic::Quadratic;

/// A differentiable objective f: ℝⁿ → ℝ, with gradient evaluation under
/// configurable low-precision arithmetic.
pub trait Problem {
    /// Dimension n of the parameter vector.
    fn dim(&self) -> usize;

    /// Objective value, in exact (binary64) arithmetic (monitoring only).
    fn objective(&self, x: &[f64]) -> f64;

    /// Exact gradient (σ₁ = 0).
    fn gradient_exact(&self, x: &[f64], out: &mut [f64]);

    /// chop-style gradient: operations run in binary64, every operation
    /// *result* is rounded entrywise into `ctx` (the paper's §2.4 protocol).
    fn gradient_rounded(&self, x: &[f64], ctx: &mut LpCtx, out: &mut [f64]);

    /// Strict per-elementary-op rounded gradient ([13, §3.1] accumulation).
    /// Default: fall back to the round-after-op model.
    fn gradient_per_op(&self, x: &[f64], ctx: &mut LpCtx, out: &mut [f64]) {
        self.gradient_rounded(x, ctx, out);
    }

    // ---- multi-seed lane batches (structure-of-arrays slabs) -------------
    //
    // The lane entry points evaluate `lanes` independent iterates at once;
    // slabs are element-major, lane-minor (element `i` of lane `l` at
    // `i * lanes + l`, the `crate::fp::LaneBatch` layout). The contract —
    // asserted by the lane-vs-scalar tests — is per-lane bit-identity: lane
    // `l`'s outputs (and, for the rounded evaluators, lane `l`'s context
    // stream consumption) must equal a scalar call on lane `l`'s column.
    // The defaults gather/scatter columns around the scalar evaluators,
    // which satisfies the contract trivially; problems with an expensive
    // shared data pass (e.g. a dense matrix) override them to amortize that
    // pass across lanes — see `Quadratic` for the pattern.

    /// Lane-batched objective: `out[l] = f(x_l)` for the `lanes` interleaved
    /// iterates of `xslab`. Monitoring only, exact (binary64) arithmetic.
    fn objective_lanes(&self, xslab: &[f64], lanes: usize, out: &mut [f64]) {
        let n = self.dim();
        debug_assert_eq!(xslab.len(), n * lanes);
        debug_assert_eq!(out.len(), lanes);
        let mut col = vec![0.0; n];
        for (l, o) in out.iter_mut().enumerate() {
            for (i, c) in col.iter_mut().enumerate() {
                *c = xslab[i * lanes + l];
            }
            *o = self.objective(&col);
        }
    }

    /// Lane-batched exact gradient: lane `l` of `out` is `∇f` of lane `l`
    /// of `xslab` (both slabs in the same interleaved layout).
    fn gradient_exact_lanes(&self, xslab: &[f64], lanes: usize, out: &mut [f64]) {
        let n = self.dim();
        debug_assert_eq!(xslab.len(), n * lanes);
        debug_assert_eq!(out.len(), n * lanes);
        let mut col = vec![0.0; n];
        let mut g = vec![0.0; n];
        for l in 0..lanes {
            for (i, c) in col.iter_mut().enumerate() {
                *c = xslab[i * lanes + l];
            }
            self.gradient_exact(&col, &mut g);
            for (i, &gi) in g.iter().enumerate() {
                out[i * lanes + l] = gi;
            }
        }
    }

    /// Lane-batched chop-style gradient: lane `l` evaluates through
    /// `ctxs[l]` (its own scheme stream), bit-identical to a scalar
    /// [`Problem::gradient_rounded`] call on lane `l`'s column.
    fn gradient_rounded_lanes(
        &self,
        xslab: &[f64],
        lanes: usize,
        ctxs: &mut [LpCtx],
        out: &mut [f64],
    ) {
        let n = self.dim();
        debug_assert_eq!(xslab.len(), n * lanes);
        debug_assert_eq!(out.len(), n * lanes);
        debug_assert_eq!(ctxs.len(), lanes);
        let mut col = vec![0.0; n];
        let mut g = vec![0.0; n];
        for (l, ctx) in ctxs.iter_mut().enumerate() {
            for (i, c) in col.iter_mut().enumerate() {
                *c = xslab[i * lanes + l];
            }
            self.gradient_rounded(&col, ctx, &mut g);
            for (i, &gi) in g.iter().enumerate() {
                out[i * lanes + l] = gi;
            }
        }
    }

    /// Lane-batched strict per-op gradient; same contract as
    /// [`Problem::gradient_rounded_lanes`].
    fn gradient_per_op_lanes(
        &self,
        xslab: &[f64],
        lanes: usize,
        ctxs: &mut [LpCtx],
        out: &mut [f64],
    ) {
        let n = self.dim();
        debug_assert_eq!(xslab.len(), n * lanes);
        debug_assert_eq!(out.len(), n * lanes);
        debug_assert_eq!(ctxs.len(), lanes);
        let mut col = vec![0.0; n];
        let mut g = vec![0.0; n];
        for (l, ctx) in ctxs.iter_mut().enumerate() {
            for (i, c) in col.iter_mut().enumerate() {
                *c = xslab[i * lanes + l];
            }
            self.gradient_per_op(&col, ctx, &mut g);
            for (i, &gi) in g.iter().enumerate() {
                out[i * lanes + l] = gi;
            }
        }
    }

    /// Lipschitz constant L of ∇f, when known analytically.
    fn lipschitz(&self) -> Option<f64> {
        None
    }

    /// Polyak–Łojasiewicz constant μ (‖∇f(x)‖² ≥ 2μ(f(x) − f*)), when
    /// known analytically — drives the fixed-point PL bounds of
    /// [`crate::gd::theory`] and the `plfp*` experiments. For a quadratic
    /// this is the smallest eigenvalue of A.
    fn pl_constant(&self) -> Option<f64> {
        None
    }

    /// The minimizer x*, when known analytically.
    fn optimum(&self) -> Option<&[f64]> {
        None
    }

    /// The constant `c` of the σ₁ bound (9), when known analytically.
    fn sigma1_constant(&self) -> Option<f64> {
        None
    }
}
