//! Two-layer neural network for binary classification (paper §5.3):
//! ReLU hidden layer (100 units), sigmoid output, binary cross-entropy,
//! Xavier weight init, zero bias init, decision threshold 0.5.
//!
//! Parameters flattened as `x = [W1 (H×D) ; b1 (H) ; w2 (H) ; b2 (1)]`,
//! n = H·(D+2) + 1. Non-convex — the paper uses it to show the rounding
//! phenomenology extends beyond the convex theory.
//!
//! As in [`super::Mlr`], the rounded gradient runs on the fused kernel
//! layer ([`crate::fp::kernels`]); the historic per-scalar path is retained
//! as [`TwoLayerNn::gradient_reference`] for equivalence tests and benches.

use super::Problem;
use crate::data::Dataset;
use crate::fp::kernels::{self, ACC_BLOCK};
use crate::fp::linalg::LpCtx;
use crate::fp::rng::Rng;

/// Two-layer ReLU network with sigmoid output for binary classification
/// (paper §5.3).
pub struct TwoLayerNn {
    /// Training data (binary labels 0/1).
    pub data: Dataset,
    /// Hidden-layer width H (paper: 100).
    pub hidden: usize,
    d: usize,
}

fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

impl TwoLayerNn {
    /// A network over `data` with `hidden` ReLU units.
    pub fn new(data: Dataset, hidden: usize) -> Self {
        let d = data.n_features;
        Self { data, hidden, d }
    }

    /// Xavier/Glorot uniform initialization [10]; biases zero (paper §5.3).
    pub fn init_params(&self, seed: u64) -> Vec<f64> {
        let (h, d) = (self.hidden, self.d);
        let mut rng = Rng::new(seed).fork("xavier", 0);
        let mut x = vec![0.0; self.dim()];
        let lim1 = (6.0 / (d + h) as f64).sqrt();
        for v in x[..h * d].iter_mut() {
            *v = rng.uniform_in(-lim1, lim1);
        }
        // b1 zero.
        let lim2 = (6.0 / (h + 1) as f64).sqrt();
        let off = h * d + h;
        for v in x[off..off + h].iter_mut() {
            *v = rng.uniform_in(-lim2, lim2);
        }
        // b2 zero.
        x
    }

    fn split<'a>(&self, x: &'a [f64]) -> (&'a [f64], &'a [f64], &'a [f64], f64) {
        let (h, d) = (self.hidden, self.d);
        let w1 = &x[..h * d];
        let b1 = &x[h * d..h * d + h];
        let w2 = &x[h * d + h..h * d + 2 * h];
        let b2 = x[h * d + 2 * h];
        (w1, b1, w2, b2)
    }

    /// Forward pass, exact arithmetic. Returns the sigmoid output.
    fn forward_exact(&self, x: &[f64], row: &[f64], hid: &mut [f64]) -> f64 {
        let (w1, b1, w2, b2) = self.split(x);
        let (h, d) = (self.hidden, self.d);
        for j in 0..h {
            let z = crate::fp::linalg::exact::dot(&w1[j * d..(j + 1) * d], row) + b1[j];
            hid[j] = z.max(0.0);
        }
        sigmoid(crate::fp::linalg::exact::dot(w2, hid) + b2)
    }

    /// Misclassification rate at threshold 0.5 — the metric of Figure 6.
    pub fn test_error(&self, x: &[f64], test: &Dataset) -> f64 {
        let mut hid = vec![0.0; self.hidden];
        let mut wrong = 0usize;
        for i in 0..test.len() {
            let p = self.forward_exact(x, test.row(i), &mut hid);
            let pred = if p >= 0.5 { 1 } else { 0 };
            if pred != test.labels[i] {
                wrong += 1;
            }
        }
        wrong as f64 / test.len() as f64
    }

    /// The retained **scalar-reference** gradient (pre-kernel per-scalar
    /// rounding sequence). Dot products and gradient sums use *blocked
    /// low-precision accumulation* (block [`ACC_BLOCK`]) when `lp_acc` —
    /// the absorption mechanism behind the paper's RN stagnation (§5.3);
    /// see DESIGN.md §8. Kept for equivalence tests and the speedup bench.
    pub fn gradient_reference(&self, x: &[f64], ctx: &mut LpCtx, out: &mut [f64], lp_acc: bool) {
        self.gradient_scalar(x, out, Some(ctx), lp_acc);
    }

    /// Scalar path shared by the exact evaluator (`ctx = None`) and
    /// [`TwoLayerNn::gradient_reference`].
    fn gradient_scalar(&self, x: &[f64], out: &mut [f64], mut ctx: Option<&mut LpCtx>, lp_acc: bool) {
        let (w1, b1, w2, b2) = self.split(x);
        let (h, d, n) = (self.hidden, self.d, self.data.len());
        out.fill(0.0);
        let (gw1, rest) = out.split_at_mut(h * d);
        let (gb1, rest) = rest.split_at_mut(h);
        let (gw2, gb2) = rest.split_at_mut(h);
        let mut hid = vec![0.0; h];
        let mut act = vec![false; h];
        let inv_n = 1.0 / n as f64;
        // Blocked low-precision dot product (absorption-faithful).
        let mut lp_dot = |a: &[f64], bvec: &[f64], bias: f64, cx: &mut Option<&mut LpCtx>| -> f64 {
            match cx.as_deref_mut() {
                Some(c) if lp_acc => {
                    let mut acc = 0.0;
                    let mut j = 0;
                    while j < a.len() {
                        let hi = (j + ACC_BLOCK).min(a.len());
                        let part: f64 = (j..hi).map(|t| a[t] * bvec[t]).sum();
                        acc = c.add(acc, part);
                        j = hi;
                    }
                    c.add(acc, bias)
                }
                Some(c) => c.fl(crate::fp::linalg::exact::dot(a, bvec) + bias),
                None => crate::fp::linalg::exact::dot(a, bvec) + bias,
            }
        };
        for i in 0..n {
            let row = self.data.row(i);
            for j in 0..h {
                let z = lp_dot(&w1[j * d..(j + 1) * d], row, b1[j], &mut ctx);
                act[j] = z > 0.0;
                hid[j] = z.max(0.0);
            }
            let zo = lp_dot(w2, &hid, b2, &mut ctx);
            let mut p = sigmoid(zo);
            if let Some(cx) = ctx.as_deref_mut() {
                p = cx.fl(p);
            }
            let y = self.data.labels[i] as f64;
            let delta = (p - y) * inv_n; // dL/dz_out for BCE+sigmoid, pre-averaged
            // Output layer grads.
            for j in 0..h {
                gw2[j] += delta * hid[j];
            }
            gb2[0] += delta;
            // Hidden layer grads through ReLU mask.
            for j in 0..h {
                if act[j] {
                    let dj = delta * w2[j];
                    let grow = &mut gw1[j * d..(j + 1) * d];
                    for (g, &xv) in grow.iter_mut().zip(row) {
                        *g += dj * xv;
                    }
                    gb1[j] += dj;
                }
            }
            // Round the gradient accumulators every ACC_BLOCK samples
            // (absorption model) or once at the end (chop protocol).
            if (lp_acc && (i + 1) % ACC_BLOCK == 0) || i + 1 == n {
                if let Some(cx) = ctx.as_deref_mut() {
                    cx.fl_slice(gw1);
                    cx.fl_slice(gb1);
                    cx.fl_slice(gw2);
                    cx.fl_slice(gb2);
                }
            }
        }
    }

    /// The fused **kernel** gradient path, processed in [`ACC_BLOCK`]-sample
    /// blocks: hidden pre-activations through the rounded GEMM, output
    /// pre-activations through the same kernel with one channel, the
    /// sigmoid outputs through one fused slice rounding, and the gradient
    /// accumulators through the fused slice rounders. Elementwise the same
    /// f64 values and rounding steps as the scalar path — bit-identical
    /// under deterministic modes.
    fn gradient_kernel(&self, x: &[f64], out: &mut [f64], cx: &mut LpCtx, lp_acc: bool) {
        let (w1, b1, w2, b2) = self.split(x);
        let (h, d, n) = (self.hidden, self.d, self.data.len());
        out.fill(0.0);
        let (gw1, rest) = out.split_at_mut(h * d);
        let (gb1, rest) = rest.split_at_mut(h);
        let (gw2, gb2) = rest.split_at_mut(h);
        let inv_n = 1.0 / n as f64;
        let mut hid = vec![0.0; ACC_BLOCK * h];
        let mut po = vec![0.0; ACC_BLOCK];
        let b2s = [b2];
        {
            let (plan, mode, rng) = cx.kernel_parts();
            let mut i0 = 0;
            while i0 < n {
                let i1 = (i0 + ACC_BLOCK).min(n);
                let rows = i1 - i0;
                let xblk = &self.data.x[i0 * d..i1 * d];
                let z1 = &mut hid[..rows * h];
                kernels::gemm_nt_bias_rounded(
                    &plan, mode, xblk, rows, d, w1, h, b1, z1, lp_acc, rng,
                );
                // ReLU on the rounded pre-activations (exact, as the scalar
                // path's `z.max(0.0)`).
                for v in z1.iter_mut() {
                    *v = v.max(0.0);
                }
                // Output pre-activation per sample: fl-model(w2·hid + b2).
                let zo = &mut po[..rows];
                kernels::gemm_nt_bias_rounded(&plan, mode, z1, rows, h, w2, 1, &b2s, zo, lp_acc, rng);
                // p = fl(sigmoid(z_out)), fused across the block.
                for v in zo.iter_mut() {
                    *v = sigmoid(*v);
                }
                plan.round_slice_scheme(mode, zo, rng);
                // Backward in exact f64, sample order preserved.
                for r in 0..rows {
                    let i = i0 + r;
                    let row = self.data.row(i);
                    let y = self.data.labels[i] as f64;
                    let delta = (zo[r] - y) * inv_n;
                    let hrow = &hid[r * h..(r + 1) * h];
                    for (g2, &hj) in gw2.iter_mut().zip(hrow) {
                        *g2 += delta * hj;
                    }
                    gb2[0] += delta;
                    for (j, &hj) in hrow.iter().enumerate() {
                        if hj > 0.0 {
                            let dj = delta * w2[j];
                            let grow = &mut gw1[j * d..(j + 1) * d];
                            for (g, &xv) in grow.iter_mut().zip(row) {
                                *g += dj * xv;
                            }
                            gb1[j] += dj;
                        }
                    }
                }
                if lp_acc || i1 == n {
                    plan.round_slice_scheme(mode, gw1, rng);
                    plan.round_slice_scheme(mode, gb1, rng);
                    plan.round_slice_scheme(mode, gw2, rng);
                    plan.round_slice_scheme(mode, gb2, rng);
                }
                i0 = i1;
            }
        }
        let forward = if lp_acc {
            (d.div_ceil(ACC_BLOCK) + 1) * h + h.div_ceil(ACC_BLOCK) + 1
        } else {
            h + 1
        };
        let acc_events = if lp_acc { n.div_ceil(ACC_BLOCK) } else { 1 };
        cx.add_rounding_ops((n * (forward + 1) + acc_events * (h * d + 2 * h + 1)) as u64);
    }
}

impl Problem for TwoLayerNn {
    fn dim(&self) -> usize {
        self.hidden * (self.d + 2) + 1
    }

    /// Mean binary cross-entropy on the training set (exact).
    fn objective(&self, x: &[f64]) -> f64 {
        let mut hid = vec![0.0; self.hidden];
        let mut loss = 0.0;
        for i in 0..self.data.len() {
            let p = self.forward_exact(x, self.data.row(i), &mut hid).clamp(1e-12, 1.0 - 1e-12);
            let y = self.data.labels[i] as f64;
            loss -= y * p.ln() + (1.0 - y) * (1.0 - p).ln();
        }
        loss / self.data.len() as f64
    }

    fn gradient_exact(&self, x: &[f64], out: &mut [f64]) {
        self.gradient_scalar(x, out, None, false);
    }

    /// chop protocol (paper §2.4): operation results rounded entrywise —
    /// evaluated through the fused kernel layer.
    fn gradient_rounded(&self, x: &[f64], ctx: &mut LpCtx, out: &mut [f64]) {
        self.gradient_kernel(x, out, ctx, false);
    }

    /// Absorption model (see [`super::Mlr::gradient_per_op`]), through the
    /// fused kernel layer.
    fn gradient_per_op(&self, x: &[f64], ctx: &mut LpCtx, out: &mut [f64]) {
        self.gradient_kernel(x, out, ctx, true);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::fp::format::FpFormat;
    use crate::fp::round::Rounding;

    fn binary38() -> (Dataset, Dataset) {
        let tr = synth::generate(200, 8, 11).filter_classes(&[3, 8]);
        let te = synth::generate(100, 8, 12).filter_classes(&[3, 8]);
        (tr, te)
    }

    #[test]
    fn dim_and_init_shapes() {
        let (tr, _) = binary38();
        let nn = TwoLayerNn::new(tr, 16);
        assert_eq!(nn.dim(), 16 * (64 + 2) + 1);
        let x = nn.init_params(0);
        // Biases start at zero.
        let h = 16;
        let d = 64;
        assert!(x[h * d..h * d + h].iter().all(|&v| v == 0.0));
        assert_eq!(x[nn.dim() - 1], 0.0);
        // Weights within Xavier limits.
        let lim1 = (6.0 / (d + h) as f64).sqrt();
        assert!(x[..h * d].iter().all(|&v| v.abs() <= lim1));
        assert!(x[..h * d].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (tr, _) = binary38();
        let nn = TwoLayerNn::new(tr, 8);
        let x = nn.init_params(3);
        let mut g = vec![0.0; nn.dim()];
        nn.gradient_exact(&x, &mut g);
        let h = 1e-6;
        let probe = [0usize, 5, nn.dim() / 2, nn.dim() - 9, nn.dim() - 1];
        for &i in &probe {
            let mut xp = x.clone();
            xp[i] += h;
            let mut xm = x.clone();
            xm[i] -= h;
            let fd = (nn.objective(&xp) - nn.objective(&xm)) / (2.0 * h);
            // ReLU kinks can perturb FD slightly; tolerance accordingly.
            assert!((fd - g[i]).abs() < 1e-4, "i={i} fd={fd} g={}", g[i]);
        }
    }

    #[test]
    fn training_learns_3_vs_8() {
        let (tr, te) = binary38();
        let nn = TwoLayerNn::new(tr, 16);
        let mut x = nn.init_params(1);
        let mut g = vec![0.0; nn.dim()];
        for _ in 0..80 {
            nn.gradient_exact(&x, &mut g);
            for (xi, gi) in x.iter_mut().zip(&g) {
                *xi -= 0.5 * gi;
            }
        }
        let err = nn.test_error(&x, &te);
        assert!(err < 0.25, "test error {err} (chance = 0.5)");
    }

    #[test]
    fn rounded_gradient_is_format_resident() {
        let (tr, _) = binary38();
        let nn = TwoLayerNn::new(tr, 8);
        let x = nn.init_params(2);
        let mut g = vec![0.0; nn.dim()];
        let mut ctx = LpCtx::new(FpFormat::BINARY8, Rounding::Sr, crate::fp::rng::Rng::new(0));
        nn.gradient_rounded(&x, &mut ctx, &mut g);
        assert!(g.iter().all(|&v| FpFormat::BINARY8.contains(v)));
    }

    /// Kernel path vs retained scalar reference: bit-identical under
    /// deterministic modes for both σ₁ models.
    #[test]
    fn kernel_gradient_matches_reference_deterministic() {
        let (tr, _) = binary38();
        let nn = TwoLayerNn::new(tr, 9);
        let x = nn.init_params(4);
        let n = nn.dim();
        for mode in [Rounding::RoundNearestEven, Rounding::RoundDown] {
            for (lp_acc, label) in [(false, "chop"), (true, "absorption")] {
                let mut gk = vec![0.0; n];
                let mut ck = LpCtx::new(FpFormat::BFLOAT16, mode, Rng::new(3));
                if lp_acc {
                    nn.gradient_per_op(&x, &mut ck, &mut gk);
                } else {
                    nn.gradient_rounded(&x, &mut ck, &mut gk);
                }
                let mut gr = vec![0.0; n];
                let mut cr = LpCtx::new(FpFormat::BFLOAT16, mode, Rng::new(3));
                nn.gradient_reference(&x, &mut cr, &mut gr, lp_acc);
                assert_eq!(gk, gr, "{mode:?} {label}");
            }
        }
    }

    #[test]
    fn sigmoid_stable_extremes() {
        assert!(sigmoid(800.0) <= 1.0);
        assert!(sigmoid(-800.0) >= 0.0);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        assert!(sigmoid(-800.0).is_finite() && sigmoid(800.0).is_finite());
    }
}
