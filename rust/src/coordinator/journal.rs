//! Journaled checkpoint/resume for sweeps, and the fault-aware cell driver.
//!
//! A sweep cell is a pure function of its identity — experiment × config
//! label × repetition, plus the run configuration — so its output can be
//! checkpointed by identity and replayed on resume with **bit-identical**
//! results. The journal is an append-only JSONL file: one line per
//! completed cell, written atomically-enough (a single `write` + flush of a
//! complete line) that a `kill -9` mid-sweep loses at most the in-flight
//! cells; a truncated trailing line is detected and ignored on load.
//!
//! Line format (stable; see `docs/robustness.md`):
//!
//! ```text
//! {"cell":"<16-hex cell_stream id>","digest":"<16-hex config digest>","outcome":"ok","series":[1.5,-0.25,...]}
//! ```
//!
//! Series values are written with Rust's shortest round-trip `f64`
//! formatting, so every finite value — subnormals and `-0.0` included —
//! parses back to the identical bits. Non-finite values use the `inf` /
//! `-inf` / `NaN` spellings `f64::from_str` accepts (strict JSON has no
//! such tokens; the journal is a private format, not an interchange one).
//!
//! Resume loads only lines whose `digest` matches the current run
//! configuration: a journal written under different grid/seed/size settings
//! contributes nothing rather than corrupting the sweep.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::coordinator::health::{CellOutcome, FaultInjector, FaultPolicy, InjectedFault};
use crate::coordinator::scheduler::{cell_stream, run_indexed_faulted};
use crate::registry::{sweep_provenance, CellRecord, ResultStore};
use crate::util::hash::registry_key;

/// An append-only cell-result journal backing `--journal PATH --resume`.
#[derive(Debug)]
pub struct Journal {
    path: PathBuf,
    digest: u64,
    file: Mutex<File>,
    seen: HashMap<u64, Vec<f64>>,
}

impl Journal {
    /// Open (or create) the journal at `path` under config `digest`.
    /// With `resume`, previously journaled cells whose digest matches are
    /// loaded for replay and new lines are appended; without it, any
    /// existing file is truncated and the sweep starts clean.
    pub fn open(path: &Path, resume: bool, digest: u64) -> std::io::Result<Self> {
        let mut seen = HashMap::new();
        if resume && path.exists() {
            let reader = BufReader::new(File::open(path)?);
            for line in reader.lines() {
                // An unreadable tail (or a torn final line, caught by the
                // parser) ends the replay; everything before it is intact.
                let Ok(line) = line else { break };
                if let Some((cell, d, series)) = parse_line(&line) {
                    if d == digest {
                        seen.insert(cell, series);
                    }
                }
            }
        }
        let mut opts = OpenOptions::new();
        opts.create(true);
        if resume {
            opts.append(true);
        } else {
            opts.write(true).truncate(true);
        }
        let file = opts.open(path)?;
        Ok(Self { path: path.to_path_buf(), digest, file: Mutex::new(file), seen })
    }

    /// The journal's on-disk location.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Number of completed cells loaded at open time (0 unless resuming).
    pub fn resumed_cells(&self) -> usize {
        self.seen.len()
    }

    /// The journaled series for a cell id, if that cell already completed
    /// under the current config digest.
    pub fn lookup(&self, cell: u64) -> Option<Vec<f64>> {
        self.seen.get(&cell).cloned()
    }

    /// Append one completed cell. Called from worker threads as cells
    /// finish; each line is built in full and written with a single
    /// `write_all` so a concurrent kill cannot interleave torn halves of
    /// two cells. Write errors are reported on stderr but do not fail the
    /// sweep (the journal is a recovery aid, not the result channel).
    pub fn append(&self, cell: u64, series: &[f64]) {
        let mut line = format!(
            "{{\"cell\":\"{cell:016x}\",\"digest\":\"{:016x}\",\"outcome\":\"ok\",\"series\":[",
            self.digest
        );
        for (k, v) in series.iter().enumerate() {
            if k > 0 {
                line.push(',');
            }
            line.push_str(&format!("{v}"));
        }
        line.push_str("]}\n");
        let mut f = self.file.lock().unwrap_or_else(|e| e.into_inner());
        if let Err(e) = f.write_all(line.as_bytes()).and_then(|()| f.flush()) {
            eprintln!("warning: journal write failed ({}): {e}", self.path.display());
        }
    }
}

/// Parse one journal line into (cell id, config digest, series). Returns
/// `None` — the line is skipped — for anything malformed, including a line
/// torn by a mid-write kill (missing `]}` tail).
fn parse_line(line: &str) -> Option<(u64, u64, Vec<f64>)> {
    let cell = hex_field(line, "\"cell\":\"")?;
    let digest = hex_field(line, "\"digest\":\"")?;
    let tag = "\"series\":[";
    let start = line.find(tag)? + tag.len();
    let end = line[start..].find(']')? + start;
    if line[end + 1..].trim_end() != "}" {
        return None;
    }
    let body = line[start..end].trim();
    let mut series = Vec::new();
    if !body.is_empty() {
        for tok in body.split(',') {
            series.push(tok.trim().parse::<f64>().ok()?);
        }
    }
    Some((cell, digest, series))
}

fn hex_field(line: &str, tag: &str) -> Option<u64> {
    let start = line.find(tag)? + tag.len();
    let end = line[start..].find('"')? + start;
    u64::from_str_radix(&line[start..end], 16).ok()
}

/// Fault-handling context of one sweep, distilled from the experiment
/// context (journal, injector, policy, retry budget, worker count).
pub struct SweepFaults<'a> {
    /// Worker threads (0 = auto), as for `run_indexed`.
    pub jobs: usize,
    /// Extra attempts per cell before a panic becomes `Failed`.
    pub max_retries: u32,
    /// What a terminally failed cell does to the sweep.
    pub policy: FaultPolicy,
    /// Checkpoint/resume journal, when `--journal` is active.
    pub journal: Option<&'a Journal>,
    /// Content-addressed result registry, when `--registry` is active:
    /// cells whose key is already stored are served instead of recomputed,
    /// fresh cells are written back (see [`crate::registry`]).
    pub registry: Option<&'a ResultStore>,
    /// The run-configuration digest (`ExpCtx::config_digest`) that keys
    /// registry lookups; `0` when no registry is attached.
    pub config_digest: u64,
    /// Deterministic test-only fault injector.
    pub injector: Option<&'a FaultInjector>,
}

impl SweepFaults<'_> {
    /// A plain sweep: no journal, no registry, no injector, fail-fast, no
    /// retries.
    pub fn none(jobs: usize) -> Self {
        Self {
            jobs,
            max_retries: 0,
            policy: FaultPolicy::FailFast,
            journal: None,
            registry: None,
            config_digest: 0,
            injector: None,
        }
    }
}

/// Run one sweep of `cells` (each a `(config label, repetition)` identity)
/// through the fault-aware scheduler with journaling.
///
/// Per cell, in order: (1) if the journal already holds its series under
/// the current digest, replay it without running anything; (1b) otherwise,
/// if the result registry holds the cell's content address, serve the
/// stored series (counting a registry hit, and journaling it so a later
/// `--resume` replays locally); (2) otherwise run it under `catch_unwind`
/// with up to `max_retries` deterministic retries, journaling — and
/// registering, with a registry miss counted — the series the moment the
/// cell completes; (3) a
/// terminally failed cell is resolved by the [`FaultPolicy`] — fail-fast
/// panics the sweep (caught at the experiment boundary), skip-cell leaves
/// `None` in its slot, degrade substitutes `master(i)` (the exact-arithmetic
/// fallback) when one is supplied. Healthy cells are bit-identical under
/// every policy, any `jobs`, and any kill/resume split — they always run
/// the same pure function of the same identity.
///
/// Returns the per-cell series (index-aligned with `cells`; `None` only for
/// skipped cells) and human-readable fault notes for the sweep report.
pub fn sweep_cells(
    exp: &str,
    faults: &SweepFaults<'_>,
    cells: &[(String, u64)],
    run: &(dyn Fn(usize) -> Vec<f64> + Sync),
    master: Option<&(dyn Fn(usize) -> Vec<f64> + Sync)>,
) -> (Vec<Option<Vec<f64>>>, Vec<String>) {
    let n = cells.len();
    let keys: Vec<u64> =
        cells.iter().map(|(label, rep)| cell_stream(exp, label, *rep)).collect();
    let mut values: Vec<Option<Vec<f64>>> = vec![None; n];
    let mut notes = Vec::new();
    // (1) Replay journaled cells; (1b) serve registry-stored cells.
    let mut todo: Vec<usize> = Vec::new();
    let mut served = 0usize;
    for i in 0..n {
        if let Some(series) = faults.journal.and_then(|j| j.lookup(keys[i])) {
            values[i] = Some(series);
        } else if let Some((reg, rec)) = faults.registry.and_then(|reg| {
            reg.peek(registry_key(faults.config_digest, keys[i])).map(|rec| (reg, rec))
        }) {
            reg.count_hit();
            // Journal the served series too, so a later `--resume` replays
            // without even touching the registry.
            if let Some(j) = faults.journal {
                j.append(keys[i], &rec.series);
            }
            values[i] = Some(rec.series.clone());
            served += 1;
        } else {
            todo.push(i);
        }
    }
    if todo.len() + served < n {
        notes.push(format!(
            "{exp}: resumed {} of {n} cells from journal",
            n - todo.len() - served
        ));
    }
    if served > 0 {
        notes.push(format!("{exp}: served {served} of {n} cells from registry"));
    }
    // (2) Fault-aware execution of the remainder.
    let wrapped = |t: usize| -> Vec<f64> {
        let i = todo[t];
        match faults.injector.and_then(|inj| inj.fire(exp, i)) {
            Some(InjectedFault::Panic) => panic!("injected fault: {exp} cell {i}"),
            Some(InjectedFault::Nan) => {
                let mut v = run(i);
                if let Some(x) = v.first_mut() {
                    *x = f64::NAN;
                }
                v
            }
            None => run(i),
        }
    };
    let runs = run_indexed_faulted(faults.jobs, todo.len(), faults.max_retries, wrapped, |t, r| {
        let Some(v) = &r.value else { return };
        let i = todo[t];
        if let Some(j) = faults.journal {
            j.append(keys[i], v);
        }
        if let Some(reg) = faults.registry {
            let (label, rep) = &cells[i];
            reg.insert(
                registry_key(faults.config_digest, keys[i]),
                CellRecord {
                    digest: faults.config_digest,
                    cell: keys[i],
                    series: v.clone(),
                    health: Default::default(),
                    provenance: sweep_provenance(exp, label, *rep),
                },
            );
            reg.count_miss();
        }
    });
    // (3) Resolve outcomes under the fault policy.
    for (t, r) in runs.into_iter().enumerate() {
        let i = todo[t];
        let (label, rep) = &cells[i];
        match r.outcome {
            CellOutcome::Ok => values[i] = r.value,
            CellOutcome::Retried(k) => {
                notes.push(format!("{exp}: cell {i} ({label}, rep {rep}) recovered on retry {k}"));
                values[i] = r.value;
            }
            CellOutcome::Failed(reason) => match faults.policy {
                FaultPolicy::FailFast => panic!(
                    "{exp}: cell {i} ({label}, rep {rep}) failed after {} retries: {reason}",
                    faults.max_retries
                ),
                FaultPolicy::SkipCell => {
                    notes.push(format!(
                        "{exp}: cell {i} ({label}, rep {rep}) failed, skipped: {reason}"
                    ));
                }
                FaultPolicy::Degrade => {
                    if let Some(m) = master {
                        values[i] = Some(m(i));
                        notes.push(format!(
                            "{exp}: cell {i} ({label}, rep {rep}) failed, \
                             degraded to exact master: {reason}"
                        ));
                    } else {
                        notes.push(format!(
                            "{exp}: cell {i} ({label}, rep {rep}) failed, no master \
                             fallback available, skipped: {reason}"
                        ));
                    }
                }
            },
        }
    }
    (values, notes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("lpgd_journal_{}_{tag}.jsonl", std::process::id()))
    }

    #[test]
    fn series_roundtrip_is_bit_exact() {
        let path = tmp_path("roundtrip");
        let series = vec![
            1.5,
            -0.25,
            0.0,
            -0.0,
            f64::MIN_POSITIVE,
            5e-324, // subnormal
            1.0 / 3.0,
            -1024.0,
            f64::MAX,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
        ];
        {
            let j = Journal::open(&path, false, 0xabcd).unwrap();
            j.append(7, &series);
            j.append(9, &[]);
        }
        let j = Journal::open(&path, true, 0xabcd).unwrap();
        assert_eq!(j.resumed_cells(), 2);
        let got = j.lookup(7).unwrap();
        assert_eq!(got.len(), series.len());
        for (a, b) in got.iter().zip(&series) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
        assert_eq!(j.lookup(9), Some(vec![]));
        assert_eq!(j.lookup(8), None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_final_line_and_foreign_digest_are_ignored() {
        let path = tmp_path("torn");
        {
            let j = Journal::open(&path, false, 1).unwrap();
            j.append(1, &[1.0, 2.0]);
        }
        // A cell journaled under another config digest...
        {
            let j = Journal::open(&path, true, 2).unwrap();
            j.append(5, &[9.0]);
        }
        // ...and a torn trailing line from a mid-write kill.
        {
            use std::io::Write as _;
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"cell\":\"0000000000000003\",\"digest\":\"0000000000000001\",\"outcome\":\"ok\",\"series\":[4.0,5").unwrap();
        }
        let j = Journal::open(&path, true, 1).unwrap();
        assert_eq!(j.lookup(1), Some(vec![1.0, 2.0]));
        assert_eq!(j.lookup(5), None, "foreign digest must not replay");
        assert_eq!(j.lookup(3), None, "torn line must not replay");
        assert_eq!(j.resumed_cells(), 1);
        // Garbage lines don't parse either.
        assert_eq!(parse_line("not json at all"), None);
        assert_eq!(parse_line(""), None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn open_without_resume_truncates() {
        let path = tmp_path("truncate");
        {
            let j = Journal::open(&path, false, 3).unwrap();
            j.append(11, &[1.0]);
        }
        {
            let j = Journal::open(&path, false, 3).unwrap();
            assert_eq!(j.resumed_cells(), 0);
            assert_eq!(j.lookup(11), None);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn sweep_replays_journaled_cells_without_running_them() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let path = tmp_path("sweep");
        let cells: Vec<(String, u64)> =
            (0..6).map(|r| ("cfg".to_string(), r as u64)).collect();
        let run = |i: usize| vec![i as f64, (i * i) as f64];
        // First pass: everything runs and is journaled.
        let (first, ran_first) = {
            let j = Journal::open(&path, false, 77).unwrap();
            let count = AtomicUsize::new(0);
            let faults = SweepFaults { journal: Some(&j), ..SweepFaults::none(1) };
            let (v, _) = sweep_cells(
                "jexp",
                &faults,
                &cells,
                &|i| {
                    count.fetch_add(1, Ordering::Relaxed);
                    run(i)
                },
                None,
            );
            (v, count.load(Ordering::Relaxed))
        };
        assert_eq!(ran_first, 6);
        // Second pass under --resume: zero cells run, values bit-identical.
        let j = Journal::open(&path, true, 77).unwrap();
        assert_eq!(j.resumed_cells(), 6);
        let count = AtomicUsize::new(0);
        let faults = SweepFaults { journal: Some(&j), ..SweepFaults::none(1) };
        let (second, notes) = sweep_cells(
            "jexp",
            &faults,
            &cells,
            &|i| {
                count.fetch_add(1, Ordering::Relaxed);
                run(i)
            },
            None,
        );
        assert_eq!(count.load(Ordering::Relaxed), 0);
        assert_eq!(first, second);
        assert!(notes.iter().any(|s| s.contains("resumed 6 of 6")), "{notes:?}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn policies_resolve_a_terminally_failing_cell() {
        let cells: Vec<(String, u64)> = (0..4).map(|r| ("c".to_string(), r)).collect();
        let run = |i: usize| vec![i as f64];
        // skip-cell: hole at the failed index, siblings intact.
        let inj = FaultInjector::panic_at("pexp", 2, u32::MAX);
        let faults = SweepFaults {
            policy: FaultPolicy::SkipCell,
            max_retries: 1,
            injector: Some(&inj),
            ..SweepFaults::none(1)
        };
        let (v, notes) = sweep_cells("pexp", &faults, &cells, &run, None);
        assert_eq!(v[2], None);
        for i in [0usize, 1, 3] {
            assert_eq!(v[i], Some(vec![i as f64]));
        }
        assert!(notes.iter().any(|s| s.contains("cell 2") && s.contains("skipped")), "{notes:?}");
        // degrade: the master fallback fills the hole.
        let inj = FaultInjector::panic_at("pexp", 2, u32::MAX);
        let faults = SweepFaults {
            policy: FaultPolicy::Degrade,
            injector: Some(&inj),
            ..SweepFaults::none(1)
        };
        let (v, notes) =
            sweep_cells("pexp", &faults, &cells, &run, Some(&|i| vec![100.0 + i as f64]));
        assert_eq!(v[2], Some(vec![102.0]));
        assert!(notes.iter().any(|s| s.contains("degraded")), "{notes:?}");
        // retry beats a transient fault: no holes, a recovery note instead.
        let inj = FaultInjector::panic_at("pexp", 2, 1);
        let faults =
            SweepFaults { max_retries: 2, injector: Some(&inj), ..SweepFaults::none(1) };
        let (v, notes) = sweep_cells("pexp", &faults, &cells, &run, None);
        assert_eq!(v[2], Some(vec![2.0]));
        assert!(notes.iter().any(|s| s.contains("recovered on retry 1")), "{notes:?}");
    }

    #[test]
    fn fail_fast_policy_panics_the_sweep() {
        let cells: Vec<(String, u64)> = (0..2).map(|r| ("c".to_string(), r)).collect();
        let inj = FaultInjector::panic_at("fexp", 1, u32::MAX);
        let faults = SweepFaults { injector: Some(&inj), ..SweepFaults::none(1) };
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            sweep_cells("fexp", &faults, &cells, &|i| vec![i as f64], None)
        }))
        .unwrap_err();
        let msg = crate::coordinator::health::panic_message(err.as_ref());
        assert!(msg.contains("cell 1") && msg.contains("failed after 0 retries"), "{msg}");
    }

    /// `--registry`: a cold sweep registers every cell as a miss; a warm
    /// sweep (fresh store handle, same directory) serves every cell
    /// bit-identically without running anything; a different config digest
    /// keys different content addresses and recomputes.
    #[test]
    fn sweep_serves_registry_hits_and_registers_misses() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let dir =
            std::env::temp_dir().join(format!("lpgd_sweep_registry_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cells: Vec<(String, u64)> = (0..5).map(|r| ("cfg".to_string(), r)).collect();
        let run = |i: usize| vec![i as f64, 0.1 + 0.2, (i * i) as f64];
        // Cold pass: everything computes and registers.
        let first = {
            let reg = ResultStore::open(&dir).unwrap();
            let faults = SweepFaults {
                registry: Some(&reg),
                config_digest: 0x77,
                ..SweepFaults::none(1)
            };
            let (v, notes) = sweep_cells("rexp", &faults, &cells, &run, None);
            assert_eq!((reg.hits(), reg.misses()), (0, 5));
            assert_eq!(reg.len(), 5);
            assert!(notes.is_empty(), "{notes:?}");
            v
        };
        // Warm pass on a reopened store: zero cells run, values identical.
        let reg = ResultStore::open(&dir).unwrap();
        let ran = AtomicUsize::new(0);
        let faults =
            SweepFaults { registry: Some(&reg), config_digest: 0x77, ..SweepFaults::none(1) };
        let (second, notes) = sweep_cells(
            "rexp",
            &faults,
            &cells,
            &|i| {
                ran.fetch_add(1, Ordering::Relaxed);
                run(i)
            },
            None,
        );
        assert_eq!(ran.load(Ordering::Relaxed), 0);
        assert_eq!(first, second);
        assert_eq!((reg.hits(), reg.misses()), (5, 0));
        assert!(
            notes.iter().any(|s| s.contains("served 5 of 5 cells from registry")),
            "{notes:?}"
        );
        // A different config digest keys different addresses: recompute.
        let faults =
            SweepFaults { registry: Some(&reg), config_digest: 0x78, ..SweepFaults::none(1) };
        let (third, _) = sweep_cells("rexp", &faults, &cells, &run, None);
        assert_eq!(first, third);
        assert_eq!(reg.misses(), 5);
        assert_eq!(reg.len(), 10);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn nan_injection_poisons_the_series_without_failing() {
        let cells: Vec<(String, u64)> = (0..3).map(|r| ("c".to_string(), r)).collect();
        let inj = FaultInjector::nan_at("nexp", 1);
        let faults = SweepFaults { injector: Some(&inj), ..SweepFaults::none(1) };
        let (v, notes) = sweep_cells("nexp", &faults, &cells, &|i| vec![i as f64, 1.0], None);
        assert!(v[1].as_ref().unwrap()[0].is_nan());
        assert_eq!(v[1].as_ref().unwrap()[1], 1.0);
        assert_eq!(v[0], Some(vec![0.0, 1.0]));
        assert!(notes.is_empty(), "{notes:?}");
    }
}
