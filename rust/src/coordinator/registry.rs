//! Self-describing experiment registry: one [`ExperimentSpec`] per table or
//! figure of the paper (plus in-repo ablations), mapping a stable id to a
//! description, the paper artifact it reproduces, and the builder function
//! that regenerates it.
//!
//! The registry is the single source of truth consumed by the CLI
//! (`lpgd list` / `lpgd reproduce`), the figure-regeneration bench
//! (`benches/figures.rs`) and the integration tests — adding an experiment
//! means adding exactly one entry here. Builders express their rounding
//! policies through the open scheme API
//! ([`crate::gd::PolicyMap`] over [`crate::fp::Scheme`] handles), so an
//! experiment can sweep any scheme registered with
//! [`crate::fp::SchemeRegistry`], not just the paper's built-ins.
//!
//! Fault tolerance is layered around the registry, not into it: builders
//! remain plain `fn(&ExpCtx) -> Vec<Table>` and pick up journaling, retry
//! and fault policies from the [`ExpCtx`] they receive, while
//! [`crate::coordinator::run_experiment`] wraps every builder invocation in
//! a panic boundary so one aborting experiment cannot take down a multi-id
//! `lpgd reproduce` invocation (see `docs/robustness.md`).

use crate::coordinator::experiments::{self, ExpCtx};
use crate::util::table::Table;

/// One reproducible experiment: id, human description, paper reference and
/// the builder that produces its result tables.
#[derive(Clone, Copy)]
pub struct ExperimentSpec {
    /// Stable id used on the CLI (`lpgd reproduce <id>`) and as the CSV
    /// file stem.
    pub id: &'static str,
    /// One-line description shown by `lpgd list`.
    pub description: &'static str,
    /// The artifact of the source paper this reproduces (or "ablation").
    pub paper_ref: &'static str,
    /// Builder: regenerates the experiment's tables for a given context.
    /// Must be a pure function of `ctx` (the scheduler relies on it).
    pub run: fn(&ExpCtx) -> Vec<Table>,
}

/// Every reproducible experiment, in presentation order.
pub const REGISTRY: &[ExperimentSpec] = &[
    ExperimentSpec {
        id: "table2",
        description: "Number-format parameters (u, x_min, x_max)",
        paper_ref: "Table 2",
        run: |_| vec![experiments::table2()],
    },
    ExperimentSpec {
        id: "fig1",
        description: "E[fl(y)] across one rounding gap for RN/SR/SReps",
        paper_ref: "Figure 1",
        run: |_| vec![experiments::fig1()],
    },
    ExperimentSpec {
        id: "fig2",
        description: "Stagnation of GD with RN on (x-1024)^2 in binary8",
        paper_ref: "Figure 2",
        run: |_| vec![experiments::fig2()],
    },
    ExperimentSpec {
        id: "fig3a",
        description: "Quadratic Setting I: SR vs signed-SReps vs binary32 + Thm2 bound",
        paper_ref: "Figure 3a",
        run: |ctx| vec![experiments::fig3(ctx, false)],
    },
    ExperimentSpec {
        id: "fig3b",
        description: "Quadratic Setting II (dense A): same comparison",
        paper_ref: "Figure 3b",
        run: |ctx| vec![experiments::fig3(ctx, true)],
    },
    ExperimentSpec {
        id: "fig4a",
        description: "MLR test error: RN/SR/SReps for (8a)+(8b), SR for (8c)",
        paper_ref: "Figure 4a",
        run: |ctx| vec![experiments::fig4a(ctx)],
    },
    ExperimentSpec {
        id: "fig4b",
        description: "MLR test error: signed-SReps combinations for (8c)",
        paper_ref: "Figure 4b",
        run: |ctx| vec![experiments::fig4b(ctx)],
    },
    ExperimentSpec {
        id: "fig4a-acc",
        description: "ABLATION: fig4a under low-precision accumulation (absorption)",
        paper_ref: "ablation",
        run: |ctx| vec![experiments::fig4a_acc(ctx)],
    },
    ExperimentSpec {
        id: "fig5a",
        description: "MLR: stepsize sweep under SR",
        paper_ref: "Figure 5a",
        run: |ctx| vec![experiments::fig5(ctx, false)],
    },
    ExperimentSpec {
        id: "fig5b",
        description: "MLR: stepsize sweep under SReps+signed-SReps",
        paper_ref: "Figure 5b",
        run: |ctx| vec![experiments::fig5(ctx, true)],
    },
    ExperimentSpec {
        id: "fig6a",
        description: "NN (3 vs 8) test error: RN/SR/SReps for (8a)+(8b)",
        paper_ref: "Figure 6a",
        run: |ctx| vec![experiments::fig6a(ctx)],
    },
    ExperimentSpec {
        id: "fig6b",
        description: "NN test error: signed-SReps combinations for (8c)",
        paper_ref: "Figure 6b",
        run: |ctx| vec![experiments::fig6b(ctx)],
    },
    ExperimentSpec {
        id: "table1",
        description: "Numerical verification of the theory (Table 1 rows)",
        paper_ref: "Table 1",
        run: |ctx| vec![experiments::table1(ctx)],
    },
    ExperimentSpec {
        id: "plfp1",
        description: "PL quadratic on fixed-point Q3.8: RN/SR/signed-SReps vs PL bounds",
        paper_ref: "arXiv:2301.09511 (companion)",
        run: |ctx| vec![experiments::plfp1(ctx)],
    },
    ExperimentSpec {
        id: "plfp2",
        description: "MLR test error on fixed-point Q4.8: RN/SR/signed-SReps",
        paper_ref: "arXiv:2301.09511 (companion)",
        run: |ctx| vec![experiments::plfp2(ctx)],
    },
    ExperimentSpec {
        id: "plfp3",
        description: "Stagnation-threshold sweep over frac_bits (Q3.f grids) vs theory",
        paper_ref: "arXiv:2301.09511 (companion)",
        run: |ctx| vec![experiments::plfp3(ctx)],
    },
    ExperimentSpec {
        id: "opt1",
        description: "Momentum(0.9) on bfloat16: stagnation vs scheme with rounded state tensor m",
        paper_ref: "arXiv:2410.10517 (optimizer-state ablation)",
        run: |ctx| vec![experiments::opt1(ctx)],
    },
    ExperimentSpec {
        id: "opt2",
        description: "Adam on bfloat16: stagnation vs scheme with rounded state tensors m, v",
        paper_ref: "arXiv:2410.10517 (optimizer-state ablation)",
        run: |ctx| vec![experiments::opt2(ctx)],
    },
    ExperimentSpec {
        id: "opt3",
        description: "Master weights vs fully-low-precision binary8 momentum (PolicyMap bindings)",
        paper_ref: "arXiv:2410.10517 (optimizer-state ablation)",
        run: |ctx| vec![experiments::opt3(ctx)],
    },
];

/// Look an experiment up by id.
pub fn find(id: &str) -> Option<&'static ExperimentSpec> {
    REGISTRY.iter().find(|s| s.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_paper_artifact() {
        let ids: Vec<&str> = REGISTRY.iter().map(|s| s.id).collect();
        for required in [
            "table1", "table2", "fig1", "fig2", "fig3a", "fig3b", "fig4a", "fig4b", "fig5a",
            "fig5b", "fig6a", "fig6b", "plfp1", "plfp2", "plfp3", "opt1", "opt2", "opt3",
        ] {
            assert!(ids.contains(&required), "missing {required}");
        }
    }

    #[test]
    fn ids_are_unique() {
        let mut ids: Vec<&str> = REGISTRY.iter().map(|s| s.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), REGISTRY.len());
    }

    #[test]
    fn find_hits_and_misses() {
        assert_eq!(find("fig2").map(|s| s.paper_ref), Some("Figure 2"));
        assert!(find("fig99").is_none());
    }
}
