//! Layer-3 coordinator (system S13): regenerates every table and figure of
//! the paper, at scale.
//!
//! The layer is split into four pieces (see `docs/architecture.md` for the
//! full data flow):
//!
//! * [`registry`] — the self-describing [`registry::ExperimentSpec`] list:
//!   one entry per paper artifact, mapping a stable id to its builder;
//! * [`scheduler`] — the sharded worker pool that fans independent
//!   (experiment × rounding-mode × repetition) cells across cores with a
//!   deterministic, order-preserving merge (`--jobs N` ≡ `--jobs 1`,
//!   bit for bit);
//! * [`experiments`] — the builder functions themselves plus the shared
//!   [`experiments::ExpCtx`] knobs;
//! * [`aggregate`] — the multi-seed expectation/variance estimator the
//!   cells merge through;
//! * [`health`] + [`journal`] — the fault-tolerance layer: per-cell fault
//!   policies, panic-isolated retry, and the append-only checkpoint/resume
//!   journal behind `--journal` / `--resume` (see `docs/robustness.md`);
//! * [`goldens`] — the golden-figure replication harness: extraction,
//!   byte-exact / CLT-band diffing and the validation report behind
//!   `lpgd goldens` and `tests/golden_diff.rs` (see `docs/testing.md`).

pub mod aggregate;
pub mod experiments;
pub mod goldens;
pub mod health;
pub mod journal;
pub mod registry;
pub mod scheduler;

pub use aggregate::{expectation, expectation_jobs, expectation_sweep, ExpectationResult};
pub use experiments::{list_experiments, run_experiment, ExpCtx};
pub use goldens::{check as golden_check, extract as golden_extract, CheckOpts, CheckStatus, Report};
pub use health::{CellOutcome, FaultInjector, FaultPolicy, InjectedFault};
pub use journal::{sweep_cells, Journal, SweepFaults};
pub use registry::{ExperimentSpec, REGISTRY};
pub use scheduler::{cell_stream, resolve_jobs, run_indexed, run_indexed_faulted, CellRun};
