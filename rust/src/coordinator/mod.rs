//! Layer-3 coordinator (system S13): the experiment registry that
//! regenerates every table and figure of the paper, the multi-seed
//! expectation aggregator, and the report writers.

pub mod aggregate;
pub mod experiments;

pub use aggregate::{expectation, ExpectationResult};
pub use experiments::{list_experiments, run_experiment, ExpCtx};
