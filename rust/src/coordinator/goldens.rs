//! Golden-figure replication harness: pins every registered experiment's
//! CSV output behind checked-in golden artifacts (ROADMAP item 4, the
//! guardrail behind every subsequent perf refactor).
//!
//! # Two comparison tiers
//!
//! * **Byte-exact (default).** Every experiment is a pure function of its
//!   seeds — the scheduler guarantees bit-identical output for every
//!   `--jobs` value, and stochastic schemes draw from seeded streams — so
//!   the default diff demands the fresh CSV equal the golden byte for
//!   byte, deterministic *and* stochastic columns alike. False-failure
//!   probability: 0.
//! * **Tolerance bands (`stream_change`).** After an *intentional* RNG
//!   stream change (e.g. a kernel rewrite that re-streams batched
//!   randomness, see `fp::round`), stochastic expectation curves move
//!   within their sampling noise while deterministic columns must not
//!   move at all. In this mode the columns carrying a SEM band (the
//!   `<id>.band.csv` sidecar written at extraction, populated by
//!   [`crate::util::table::Table::bands`]) are compared under the CLT
//!   band `|fresh − golden| ≤ z(p)·sqrt(sem_g² + sem_f²)` from
//!   [`crate::util::stats::clt_halfwidth`] with per-point
//!   `p =` [`P_POINT_FAIL`] `= 1e-9`; all other columns stay byte-exact.
//!   By the union bound over the fewer than ~5·10³ banded points a full
//!   run produces, the suite-wide false-failure probability is below
//!   ~5·10⁻⁶ (each figure's point count is reported in its entry).
//!   A rendering slack of `5·10⁻⁵·max(|a|,|b|) + 5·10⁻⁷` absorbs the
//!   CSV cell quantization (`{:.6}` / `{:.4e}`, see
//!   [`crate::util::table::Cell`]).
//!
//! # Bootstrap on missing goldens
//!
//! From a clean checkout the figure goldens may be absent (they pin the
//! platform that generated them — cross-libm differences in `exp`/`ln`
//! make them machine artifacts, see `docs/testing.md`). A non-`require`
//! [`check`] then *bootstraps*: it reruns the experiment a second time,
//! asserts both runs byte-identical (a determinism proof), writes the
//! golden atomically and reports [`CheckStatus::Bootstrapped`] with a
//! commit reminder. With `require` set (the `verify.sh` golden stage and
//! CI enforcement path), missing goldens fail with remediation text
//! instead.
//!
//! # The expected-round golden table
//!
//! `goldens/expected_round_binary8.csv` pins the closed-form
//! `E[fl(x)]` bias law of **every built-in scheme** on the full binary8
//! grid — every grid point, every gap's quarter/half/three-quarter
//! points, both signs — as hex `f64` bit patterns. It catches bias-law
//! drift the Monte-Carlo tests can miss (a wrong ε sign flips the bias
//! but stays inside sampling noise at small n). The checked-in table may
//! be produced by the independent generator
//! `scripts/gen_expected_round_goldens.py` (provenance sidecar
//! `cross-language`, compared with ≤ 1 ulp slack); `lpgd goldens
//! extract` re-stamps it from the Rust closed forms (`native`,
//! compared bit-exact).

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::coordinator::experiments::{run_experiment, ExpCtx};
use crate::fp::format::pow2;
use crate::fp::round::{expected_round, Rounding};
use crate::fp::FpFormat;
use crate::util::stats::{clt_halfwidth, ulp_distance};
use crate::util::table::Table;
use anyhow::{bail, Result};

/// Per-point false-failure probability of a tolerance-band comparison
/// (`stream_change` mode). Union-bounded over the banded points of a full
/// suite run (< ~5·10³) this keeps the suite-wide false-failure
/// probability below ~5·10⁻⁶.
pub const P_POINT_FAIL: f64 = 1e-9;

/// File stem of the expected-round golden table under the goldens dir.
pub const EXPECTED_ROUND_STEM: &str = "expected_round_binary8";

/// Manifest file name recording the golden profile's config digest.
pub const MANIFEST_FILE: &str = "MANIFEST.json";

/// How a [`check`] treats missing or drifted goldens.
#[derive(Debug, Clone, Copy, Default)]
pub struct CheckOpts {
    /// Fail on missing goldens instead of bootstrapping them (the
    /// `verify.sh` / CI enforcement mode, CLI `--require`,
    /// env `LPGD_GOLDEN_REQUIRE=1` in the test suite).
    pub require: bool,
    /// Compare SEM-banded stochastic columns under CLT tolerance bands
    /// instead of byte-exactly — only for validating an intentional RNG
    /// stream change (CLI `--stream-change`,
    /// env `LPGD_GOLDEN_STREAM_CHANGE=1`).
    pub stream_change: bool,
}

/// Outcome of one golden comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckStatus {
    /// Fresh output matched the golden (within the active tier).
    Pass,
    /// No golden existed; it was generated from a double-run determinism
    /// proof and should be committed.
    Bootstrapped,
    /// Mismatch, missing-under-`require`, or profile drift.
    Fail,
}

impl CheckStatus {
    /// Stable lower-case name used in the JSON report.
    pub fn name(&self) -> &'static str {
        match self {
            CheckStatus::Pass => "pass",
            CheckStatus::Bootstrapped => "bootstrapped",
            CheckStatus::Fail => "fail",
        }
    }
}

/// One figure's (or the expected-round table's) comparison result.
#[derive(Debug, Clone)]
pub struct FigureReport {
    /// Experiment id (CSV stem), or [`EXPECTED_ROUND_STEM`].
    pub id: String,
    /// Outcome.
    pub status: CheckStatus,
    /// Comparison tier that ran: `"byte-exact"`, `"clt-band"`,
    /// `"bit-table"` or `"bootstrap"`.
    pub mode: String,
    /// Cells compared (0 for a missing golden).
    pub cells: usize,
    /// Human-readable detail: first mismatch, band statistics, or
    /// remediation text. Empty on a clean pass.
    pub detail: String,
}

/// The full validation result rendered to the terminal, the JSON report
/// and the HTML index (`scripts/render_report.py`).
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// One entry per registered experiment plus the expected-round table.
    pub entries: Vec<FigureReport>,
}

impl Report {
    /// True when no entry failed (bootstraps count as passing).
    pub fn passed(&self) -> bool {
        self.entries.iter().all(|e| e.status != CheckStatus::Fail)
    }

    /// Entries that were bootstrapped this run (need committing).
    pub fn bootstrapped(&self) -> Vec<&str> {
        self.entries
            .iter()
            .filter(|e| e.status == CheckStatus::Bootstrapped)
            .map(|e| e.id.as_str())
            .collect()
    }

    /// Aligned terminal rendering, one line per entry.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        let width = self.entries.iter().map(|e| e.id.len()).max().unwrap_or(4);
        for e in &self.entries {
            out.push_str(&format!(
                "{:<w$}  {:<12}  {:<10}  {} cells",
                e.id,
                e.status.name(),
                e.mode,
                e.cells,
                w = width
            ));
            if !e.detail.is_empty() {
                out.push_str(&format!("  [{}]", e.detail));
            }
            out.push('\n');
        }
        let (p, b, f) = self.counts();
        out.push_str(&format!(
            "golden check: {p} pass, {b} bootstrapped, {f} fail -> {}\n",
            if self.passed() { "OK" } else { "FAIL" }
        ));
        out
    }

    /// `(pass, bootstrapped, fail)` counts.
    pub fn counts(&self) -> (usize, usize, usize) {
        let c = |s: CheckStatus| self.entries.iter().filter(|e| e.status == s).count();
        (c(CheckStatus::Pass), c(CheckStatus::Bootstrapped), c(CheckStatus::Fail))
    }

    /// Render the machine-readable validation index consumed by
    /// `scripts/render_report.py`.
    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            let mut out = String::with_capacity(s.len());
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out
        }
        let mut out = String::from("{\n  \"schema\": 1,\n  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"id\": \"{}\", \"status\": \"{}\", \"mode\": \"{}\", \"cells\": {}, \"detail\": \"{}\"}}{}\n",
                esc(&e.id),
                e.status.name(),
                esc(&e.mode),
                e.cells,
                esc(&e.detail),
                if i + 1 < self.entries.len() { "," } else { "" }
            ));
        }
        out.push_str(&format!("  ],\n  \"passed\": {}\n}}\n", self.passed()));
        out
    }

    /// Write the JSON index to `path` (creating parent dirs).
    pub fn write_json(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_json())?;
        Ok(())
    }
}

/// The fixed context every golden run uses: the quick profile (small
/// seeded configs — the extraction and the check must agree on every
/// cell-shaping knob, enforced through the manifest's config digest).
pub fn golden_ctx() -> ExpCtx {
    ExpCtx::quick()
}

fn temp_out_dir(tag: &str) -> PathBuf {
    static N: AtomicUsize = AtomicUsize::new(0);
    let n = N.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("lpgd_goldens_{tag}_{}_{n}", std::process::id()))
}

/// Run experiment `id` ("all" included) into a throwaway directory and
/// return the tables.
fn run_scratch(id: &str, ctx: &ExpCtx) -> Result<Vec<Table>> {
    let mut ctx = ctx.clone();
    let dir = temp_out_dir("run");
    ctx.out_dir = dir.to_string_lossy().into_owned();
    let res = run_experiment(id, &ctx);
    let _ = fs::remove_dir_all(&dir);
    res
}

/// Atomic file write: temp file in the same directory, then rename — a
/// crash mid-extraction never leaves a torn golden behind.
fn write_atomic(path: &Path, content: &str) -> Result<()> {
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("tmp");
    fs::write(&tmp, content)?;
    fs::rename(&tmp, path)?;
    Ok(())
}

fn write_table_goldens(dir: &Path, t: &Table, written: &mut Vec<PathBuf>) -> Result<()> {
    let p = dir.join(format!("{}.csv", t.id));
    write_atomic(&p, &t.to_csv())?;
    written.push(p);
    let band_path = dir.join(format!("{}.band.csv", t.id));
    if t.bands.is_empty() {
        // Drop a stale sidecar from an older profile.
        let _ = fs::remove_file(&band_path);
    } else {
        write_atomic(&band_path, &t.bands_to_csv())?;
        written.push(band_path);
    }
    Ok(())
}

fn write_manifest(dir: &Path, ctx: &ExpCtx) -> Result<()> {
    let content = format!(
        "{{\n  \"schema\": 1,\n  \"config_digest\": \"{:016x}\",\n  \"seeds\": {},\n  \"note\": \"golden profile = ExpCtx::quick(); regenerate with `lpgd goldens extract` after any profile change\"\n}}\n",
        ctx.config_digest(),
        ctx.seeds
    );
    write_atomic(&dir.join(MANIFEST_FILE), &content)
}

/// The manifest's recorded digest, when a manifest exists.
fn manifest_digest(dir: &Path) -> Option<u64> {
    let text = fs::read_to_string(dir.join(MANIFEST_FILE)).ok()?;
    let key = "\"config_digest\": \"";
    let start = text.find(key)? + key.len();
    let end = text[start..].find('"')? + start;
    u64::from_str_radix(&text[start..end], 16).ok()
}

/// Regenerate every golden under `dir` from the current build: all
/// figure CSVs (+ SEM band sidecars), the expected-round bit table
/// (`native` provenance) and the manifest. Returns the written paths.
pub fn extract(dir: &Path, ctx: &ExpCtx) -> Result<Vec<PathBuf>> {
    let tables = run_scratch("all", ctx)?;
    let mut written = Vec::new();
    for t in &tables {
        write_table_goldens(dir, t, &mut written)?;
    }
    written.push(write_expected_round_golden(dir, "native")?);
    write_manifest(dir, ctx)?;
    written.push(dir.join(MANIFEST_FILE));
    Ok(written)
}

/// Diff fresh output for every registered experiment (plus the
/// expected-round table) against the goldens under `dir`; bootstrap
/// missing goldens unless `opts.require`. Returns the full [`Report`];
/// the caller decides how a failure is surfaced (the test asserts,
/// the CLI exits non-zero).
pub fn check(dir: &Path, ctx: &ExpCtx, opts: &CheckOpts) -> Result<Report> {
    let fresh = run_scratch("all", ctx)?;
    let mut report = Report::default();
    let any_figure_golden =
        fresh.iter().any(|t| dir.join(format!("{}.csv", t.id)).exists());
    if any_figure_golden {
        if let Some(recorded) = manifest_digest(dir) {
            if recorded != ctx.config_digest() {
                report.entries.push(FigureReport {
                    id: "golden-profile".into(),
                    status: CheckStatus::Fail,
                    mode: "manifest".into(),
                    cells: 0,
                    detail: format!(
                        "golden profile digest {recorded:016x} != current {:016x}; \
                         rerun `lpgd goldens extract` and commit goldens/",
                        ctx.config_digest()
                    ),
                });
            }
        }
    }
    let mut bootstrapped = false;
    for t in &fresh {
        let gpath = dir.join(format!("{}.csv", t.id));
        if !gpath.exists() {
            report.entries.push(bootstrap_figure(dir, t, ctx, opts)?);
            bootstrapped = true;
            continue;
        }
        let golden_csv = fs::read_to_string(&gpath)?;
        let band_path = dir.join(format!("{}.band.csv", t.id));
        let golden_band = if band_path.exists() {
            Some(fs::read_to_string(&band_path)?)
        } else {
            None
        };
        report.entries.push(diff_table(t, &golden_csv, golden_band.as_deref(), opts));
    }
    report.entries.push(check_expected_round(dir, opts)?);
    if bootstrapped && report.entries.iter().any(|e| e.status == CheckStatus::Bootstrapped) {
        write_manifest(dir, ctx)?;
    }
    Ok(report)
}

/// Missing golden: prove determinism with a second run, then write it —
/// or fail with remediation under `require`.
fn bootstrap_figure(
    dir: &Path,
    fresh: &Table,
    ctx: &ExpCtx,
    opts: &CheckOpts,
) -> Result<FigureReport> {
    if opts.require {
        return Ok(FigureReport {
            id: fresh.id.clone(),
            status: CheckStatus::Fail,
            mode: "bootstrap".into(),
            cells: 0,
            detail: format!(
                "missing golden {}/{}.csv (LPGD_GOLDEN_REQUIRE is set); \
                 run `lpgd goldens extract` (or the golden tests without the \
                 env var) and commit goldens/",
                dir.display(),
                fresh.id
            ),
        });
    }
    let again = run_scratch(&fresh.id, ctx)?;
    let second = again.iter().find(|t| t.id == fresh.id);
    let identical = second.map(|t| t.to_csv() == fresh.to_csv()).unwrap_or(false);
    if !identical {
        return Ok(FigureReport {
            id: fresh.id.clone(),
            status: CheckStatus::Fail,
            mode: "bootstrap".into(),
            cells: 0,
            detail: "two identically-seeded runs differed — the experiment is \
                     not deterministic, refusing to write a golden"
                .into(),
        });
    }
    let mut written = Vec::new();
    write_table_goldens(dir, fresh, &mut written)?;
    Ok(FigureReport {
        id: fresh.id.clone(),
        status: CheckStatus::Bootstrapped,
        mode: "bootstrap".into(),
        cells: fresh.rows.len() * fresh.columns.len(),
        detail: "golden written from a double-run determinism proof; commit goldens/".into(),
    })
}

// ------------------------------------------------------------ CSV diffing --

/// Split one CSV line honoring the double-quote escaping of
/// [`Table::to_csv`].
fn split_csv_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut quoted = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if quoted => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    quoted = false;
                }
            }
            '"' => quoted = true,
            ',' if !quoted => {
                out.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    out.push(cur);
    out
}

fn parse_csv(text: &str) -> (Vec<String>, Vec<Vec<String>>) {
    let mut lines = text.lines();
    let header = lines.next().map(split_csv_line).unwrap_or_default();
    let rows = lines.filter(|l| !l.is_empty()).map(split_csv_line).collect();
    (header, rows)
}

/// Parse a `<id>.band.csv` sidecar into label → SEM-per-row.
fn parse_band(text: &str) -> BTreeMap<String, Vec<f64>> {
    let (header, rows) = parse_csv(text);
    let mut out: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for (ci, label) in header.iter().enumerate().skip(1) {
        let sems = rows
            .iter()
            .map(|r| r.get(ci).and_then(|s| s.parse::<f64>().ok()).unwrap_or(0.0))
            .collect();
        out.insert(label.clone(), sems);
    }
    out
}

/// Columns skipped in `stream_change` mode for tables whose stochastic
/// spread hides in *text* cells instead of banded numeric columns:
/// `table1`'s precondition column embeds the run-dependent χ and
/// gate-held counts, while its verdict columns stay comparable.
const STREAM_SKIP_COLUMNS: &[(&str, &[&str])] = &[("table1", &["precondition"])];

fn diff_table(
    fresh: &Table,
    golden_csv: &str,
    golden_band: Option<&str>,
    opts: &CheckOpts,
) -> FigureReport {
    let fresh_csv = fresh.to_csv();
    let cells = fresh.rows.len() * fresh.columns.len();
    if !opts.stream_change {
        if fresh_csv == golden_csv {
            return FigureReport {
                id: fresh.id.clone(),
                status: CheckStatus::Pass,
                mode: "byte-exact".into(),
                cells,
                detail: String::new(),
            };
        }
        return FigureReport {
            id: fresh.id.clone(),
            status: CheckStatus::Fail,
            mode: "byte-exact".into(),
            cells,
            detail: first_mismatch_detail(&fresh_csv, golden_csv),
        };
    }
    diff_table_banded(fresh, golden_csv, golden_band)
}

/// Locate the first differing cell of two CSVs and describe it; reports
/// the ulp distance when both sides parse as finite numbers (a 1-ulp
/// perturbation of any figure value is therefore always caught *and*
/// named as such).
fn first_mismatch_detail(fresh_csv: &str, golden_csv: &str) -> String {
    let (fh, fr) = parse_csv(fresh_csv);
    let (gh, gr) = parse_csv(golden_csv);
    if fh != gh {
        return format!("header drift: fresh {fh:?} vs golden {gh:?}");
    }
    if fr.len() != gr.len() {
        return format!("row count {} vs golden {}", fr.len(), gr.len());
    }
    for (ri, (frow, grow)) in fr.iter().zip(&gr).enumerate() {
        for (ci, (a, b)) in frow.iter().zip(grow).enumerate() {
            if a != b {
                let col = fh.get(ci).map(String::as_str).unwrap_or("?");
                if let (Ok(x), Ok(y)) = (a.parse::<f64>(), b.parse::<f64>()) {
                    return format!(
                        "row {ri} col '{col}': fresh {a} vs golden {b} ({} ulp apart)",
                        ulp_distance(x, y)
                    );
                }
                return format!("row {ri} col '{col}': fresh '{a}' vs golden '{b}'");
            }
        }
    }
    "content differs outside the parsed cells (trailing bytes?)".into()
}

fn diff_table_banded(
    fresh: &Table,
    golden_csv: &str,
    golden_band: Option<&str>,
) -> FigureReport {
    let (gh, gr) = parse_csv(golden_csv);
    let (fh, fr) = parse_csv(&fresh.to_csv());
    let fail = |detail: String| FigureReport {
        id: fresh.id.clone(),
        status: CheckStatus::Fail,
        mode: "clt-band".into(),
        cells: fr.len() * fh.len(),
        detail,
    };
    if fh != gh {
        return fail(format!("header drift: fresh {fh:?} vs golden {gh:?}"));
    }
    if fr.len() != gr.len() {
        return fail(format!("row count {} vs golden {}", fr.len(), gr.len()));
    }
    let gbands = golden_band.map(parse_band).unwrap_or_default();
    let fbands: BTreeMap<&str, &Vec<f64>> =
        fresh.bands.iter().map(|(l, s)| (l.as_str(), s)).collect();
    let skipped: &[&str] = STREAM_SKIP_COLUMNS
        .iter()
        .find(|(id, _)| *id == fresh.id)
        .map(|(_, cols)| *cols)
        .unwrap_or(&[]);
    let mut banded_points = 0usize;
    for (ri, (frow, grow)) in fr.iter().zip(&gr).enumerate() {
        if frow.len() != fh.len() || grow.len() != fh.len() {
            return fail(format!("row {ri}: ragged width (fresh {}, golden {})", frow.len(), grow.len()));
        }
        for (ci, col) in fh.iter().enumerate() {
            let (a, b) = (frow[ci].as_str(), grow[ci].as_str());
            if skipped.contains(&col.as_str()) {
                continue;
            }
            let gband = gbands.get(col);
            match gband {
                None => {
                    // Deterministic column: byte-exact even here.
                    if a != b {
                        return fail(format!(
                            "deterministic col '{col}' row {ri}: fresh '{a}' vs golden '{b}'"
                        ));
                    }
                }
                Some(gsems) => {
                    banded_points += 1;
                    if a == "-" || b == "-" {
                        if a != b {
                            return fail(format!(
                                "col '{col}' row {ri}: NaN marker mismatch ('{a}' vs '{b}')"
                            ));
                        }
                        continue;
                    }
                    let (x, y) = match (a.parse::<f64>(), b.parse::<f64>()) {
                        (Ok(x), Ok(y)) => (x, y),
                        _ => {
                            return fail(format!(
                                "col '{col}' row {ri}: non-numeric banded cell ('{a}' vs '{b}')"
                            ))
                        }
                    };
                    let sem_g = gsems.get(ri).copied().unwrap_or(0.0);
                    let sem_f = fbands
                        .get(col.as_str())
                        .and_then(|s| s.get(ri))
                        .copied()
                        .unwrap_or(0.0);
                    let render_slack = 5e-5 * x.abs().max(y.abs()) + 5e-7;
                    let tol = clt_halfwidth(sem_g, sem_f, P_POINT_FAIL) + render_slack;
                    if (x - y).abs() > tol {
                        return fail(format!(
                            "col '{col}' row {ri}: |{x} - {y}| = {:.3e} exceeds the \
                             p={P_POINT_FAIL:.0e} CLT band {tol:.3e} \
                             (sem_golden={sem_g:.3e}, sem_fresh={sem_f:.3e})",
                            (x - y).abs()
                        ));
                    }
                }
            }
        }
    }
    FigureReport {
        id: fresh.id.clone(),
        status: CheckStatus::Pass,
        mode: "clt-band".into(),
        cells: fr.len() * fh.len(),
        detail: format!("{banded_points} banded points at p={P_POINT_FAIL:.0e}"),
    }
}

// ------------------------------------------- expected-round golden table --

/// How a signed-scheme column steers `v`.
#[derive(Clone, Copy)]
enum Steer {
    /// `v = x` (the unsteered degenerate case).
    SameAsX,
    /// `v = +1`.
    Plus,
    /// `v = −1`.
    Minus,
    /// `v = 0` (steering sign vanishes; the law degenerates to SR).
    Zero,
}

fn expected_round_columns() -> Vec<(String, Rounding, Steer)> {
    let mut cols: Vec<(String, Rounding, Steer)> = vec![
        ("rn".into(), Rounding::RoundNearestEven, Steer::SameAsX),
        ("rd".into(), Rounding::RoundDown, Steer::SameAsX),
        ("ru".into(), Rounding::RoundUp, Steer::SameAsX),
        ("rz".into(), Rounding::RoundTowardZero, Steer::SameAsX),
        ("sr".into(), Rounding::Sr, Steer::SameAsX),
    ];
    for eps in [0.1, 0.25, 0.4] {
        cols.push((format!("sr_eps_{eps}"), Rounding::SrEps(eps), Steer::SameAsX));
    }
    for eps in [0.1, 0.25, 0.4] {
        cols.push((format!("signed_{eps}_vpos"), Rounding::SignedSrEps(eps), Steer::Plus));
        cols.push((format!("signed_{eps}_vneg"), Rounding::SignedSrEps(eps), Steer::Minus));
    }
    cols.push(("signed_0.25_v0".into(), Rounding::SignedSrEps(0.25), Steer::Zero));
    cols
}

/// Every positive binary8 grid point in ascending order (subnormals
/// `m·2⁻¹⁶` for m ∈ 1..4, then `m·2^{e−2}` for m ∈ 4..8 per binade) —
/// the same enumeration the exhaustive bit-kernel property test walks.
fn binary8_positive_points() -> Vec<f64> {
    let fmt = FpFormat::BINARY8;
    let mut pts = Vec::new();
    let q = fmt.x_min_sub();
    for m in 1..4u32 {
        pts.push(m as f64 * q);
    }
    for e in fmt.e_min..=fmt.e_max {
        let ulp = pow2(e - fmt.sig_bits as i32 + 1);
        for m in 4..8u32 {
            pts.push(m as f64 * ulp);
        }
    }
    pts
}

/// The sampled inputs: 0, every grid point, and every gap's quarter /
/// half / three-quarter points — then the negative mirror of everything.
/// All values stay inside `[−x_max, x_max]`, so every neighbor pair is
/// finite and the laws avoid the float-RN overflow branch (which the
/// property suite covers separately).
fn binary8_samples() -> Vec<f64> {
    let pts = binary8_positive_points();
    let mut xs = vec![0.0];
    let mut prev = 0.0;
    for &p in &pts {
        let g = p - prev;
        xs.push(prev + 0.25 * g);
        xs.push(prev + 0.5 * g);
        xs.push(prev + 0.75 * g);
        xs.push(p);
        prev = p;
    }
    let negs: Vec<f64> = xs.iter().skip(1).map(|&x| -x).collect();
    xs.extend(negs);
    xs
}

/// The expected-round table as `(header, hex rows)`: column 0 is the
/// input's `f64` bit pattern, every further column one scheme's closed
/// form `E[fl(x)]` bit pattern (16 hex digits each).
pub(crate) fn expected_round_table() -> (Vec<String>, Vec<Vec<String>>) {
    let fmt = FpFormat::BINARY8;
    let cols = expected_round_columns();
    let mut header = vec!["x_bits".to_string()];
    header.extend(cols.iter().map(|(n, _, _)| n.clone()));
    let rows = binary8_samples()
        .into_iter()
        .map(|x| {
            let mut row = vec![format!("{:016x}", x.to_bits())];
            for (_, mode, steer) in &cols {
                let v = match steer {
                    Steer::SameAsX => x,
                    Steer::Plus => 1.0,
                    Steer::Minus => -1.0,
                    Steer::Zero => 0.0,
                };
                row.push(format!("{:016x}", expected_round(&fmt, *mode, x, v).to_bits()));
            }
            row
        })
        .collect();
    (header, rows)
}

fn expected_round_csv() -> String {
    let (header, rows) = expected_round_table();
    let mut out = header.join(",");
    out.push('\n');
    for r in rows {
        out.push_str(&r.join(","));
        out.push('\n');
    }
    out
}

/// Write the native expected-round golden (+ provenance sidecar) and
/// return the CSV path.
fn write_expected_round_golden(dir: &Path, provenance: &str) -> Result<PathBuf> {
    let path = dir.join(format!("{EXPECTED_ROUND_STEM}.csv"));
    write_atomic(&path, &expected_round_csv())?;
    write_atomic(
        &dir.join(format!("{EXPECTED_ROUND_STEM}.provenance")),
        &format!("{provenance}\n"),
    )?;
    Ok(path)
}

/// Check (or bootstrap) the expected-round golden table. A
/// `cross-language` provenance (the Python generator) is compared with
/// ≤ 1 ulp slack — enough to absorb any platform printf/strtod corner
/// while still catching every bias-law change, which moves values by
/// many ulps; `native` provenance is compared bit-exactly.
fn check_expected_round(dir: &Path, opts: &CheckOpts) -> Result<FigureReport> {
    let path = dir.join(format!("{EXPECTED_ROUND_STEM}.csv"));
    if !path.exists() {
        if opts.require {
            return Ok(FigureReport {
                id: EXPECTED_ROUND_STEM.into(),
                status: CheckStatus::Fail,
                mode: "bit-table".into(),
                cells: 0,
                detail: format!(
                    "missing golden {} — run `lpgd goldens extract` or \
                     scripts/gen_expected_round_goldens.py and commit goldens/",
                    path.display()
                ),
            });
        }
        let written = write_expected_round_golden(dir, "native")?;
        let (h, r) = expected_round_table();
        return Ok(FigureReport {
            id: EXPECTED_ROUND_STEM.into(),
            status: CheckStatus::Bootstrapped,
            mode: "bit-table".into(),
            cells: r.len() * h.len(),
            detail: format!("wrote {} from the native closed forms; commit goldens/", written.display()),
        });
    }
    let committed = fs::read_to_string(&path)?;
    let prov_path = dir.join(format!("{EXPECTED_ROUND_STEM}.provenance"));
    let provenance = fs::read_to_string(&prov_path).unwrap_or_else(|_| "native".into());
    let slack: u64 = if provenance.trim() == "cross-language" { 1 } else { 0 };
    let (gh, gr) = parse_csv(&committed);
    let (nh, nr) = expected_round_table();
    let fail = |detail: String| FigureReport {
        id: EXPECTED_ROUND_STEM.into(),
        status: CheckStatus::Fail,
        mode: "bit-table".into(),
        cells: nr.len() * nh.len(),
        detail,
    };
    if gh != nh {
        return Ok(fail(format!("header drift: golden {gh:?} vs native {nh:?}")));
    }
    if gr.len() != nr.len() {
        return Ok(fail(format!("row count {} vs native {}", gr.len(), nr.len())));
    }
    for (ri, (grow, nrow)) in gr.iter().zip(&nr).enumerate() {
        if grow.len() != nh.len() {
            return Ok(fail(format!("row {ri}: ragged width {} (want {})", grow.len(), nh.len())));
        }
        for (ci, col) in nh.iter().enumerate() {
            let (g, n) = (grow[ci].as_str(), nrow[ci].as_str());
            let parse = |s: &str| u64::from_str_radix(s, 16).map(f64::from_bits);
            let (gv, nv) = match (parse(g), parse(n)) {
                (Ok(a), Ok(b)) => (a, b),
                _ => return Ok(fail(format!("row {ri} col '{col}': bad hex ('{g}' / '{n}')"))),
            };
            let d = ulp_distance(gv, nv);
            if d > slack {
                return Ok(fail(format!(
                    "row {ri} col '{col}' (x_bits={}): golden {gv:e} vs native {nv:e} \
                     ({d} ulp apart, slack {slack}; provenance {})",
                    grow[0],
                    provenance.trim()
                )));
            }
        }
    }
    Ok(FigureReport {
        id: EXPECTED_ROUND_STEM.into(),
        status: CheckStatus::Pass,
        mode: "bit-table".into(),
        cells: nr.len() * nh.len(),
        detail: format!("provenance {}, ulp slack {slack}", provenance.trim()),
    })
}

/// Bail helper for CLI flows that must turn a failed report into an
/// error exit (the test suite asserts on the report instead).
pub fn ensure_passed(report: &Report) -> Result<()> {
    if report.passed() {
        return Ok(());
    }
    let failing: Vec<String> = report
        .entries
        .iter()
        .filter(|e| e.status == CheckStatus::Fail)
        .map(|e| format!("{}: {}", e.id, e.detail))
        .collect();
    bail!("golden check failed:\n  {}", failing.join("\n  "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_line_splitting_honors_quotes() {
        assert_eq!(split_csv_line("a,b,c"), vec!["a", "b", "c"]);
        assert_eq!(split_csv_line("\"a,b\",c"), vec!["a,b", "c"]);
        assert_eq!(split_csv_line("\"say \"\"hi\"\"\",x"), vec!["say \"hi\"", "x"]);
        assert_eq!(split_csv_line("lone"), vec!["lone"]);
    }

    #[test]
    fn expected_round_table_shape_and_identities() {
        let (header, rows) = expected_round_table();
        // 1 bits column + 15 scheme columns.
        assert_eq!(header.len(), 16);
        assert_eq!(header[0], "x_bits");
        // 0, then (3 subnormal + 30 binades * 4) points with 4 samples per
        // gap, mirrored: 1 + 2 * 4 * 123 rows.
        assert_eq!(rows.len(), 1 + 2 * 4 * 123);
        let sr_col = header.iter().position(|h| h == "sr").unwrap();
        let rd_col = header.iter().position(|h| h == "rd").unwrap();
        let ru_col = header.iter().position(|h| h == "ru").unwrap();
        for row in &rows {
            let x = f64::from_bits(u64::from_str_radix(&row[0], 16).unwrap());
            let sr = f64::from_bits(u64::from_str_radix(&row[sr_col], 16).unwrap());
            // SR is unbiased: E[fl(x)] = x exactly in the closed form.
            assert!((sr - x).abs() < 1e-12, "x={x} sr={sr}");
            let rd = f64::from_bits(u64::from_str_radix(&row[rd_col], 16).unwrap());
            let ru = f64::from_bits(u64::from_str_radix(&row[ru_col], 16).unwrap());
            assert!(rd <= x && x <= ru, "x={x} rd={rd} ru={ru}");
        }
    }

    #[test]
    fn signed_columns_bias_against_the_steer() {
        let (header, rows) = expected_round_table();
        let pos = header.iter().position(|h| h == "signed_0.25_vpos").unwrap();
        let neg = header.iter().position(|h| h == "signed_0.25_vneg").unwrap();
        let v0 = header.iter().position(|h| h == "signed_0.25_v0").unwrap();
        let sr = header.iter().position(|h| h == "sr").unwrap();
        let mut interior = 0;
        for row in &rows {
            let at = |i: usize| f64::from_bits(u64::from_str_radix(&row[i], 16).unwrap());
            let x = f64::from_bits(u64::from_str_radix(&row[0], 16).unwrap());
            // v = 0 degenerates to SR for every x.
            assert_eq!(at(v0).to_bits(), at(sr).to_bits(), "x={x}");
            // Off-grid: bias has the sign of −v (Definition 3).
            let (p, n) = (at(pos), at(neg));
            if p != x && n != x {
                interior += 1;
                assert!(p < x && n > x, "x={x} vpos={p} vneg={n}");
            }
        }
        assert!(interior > 100, "too few interior samples exercised: {interior}");
    }

    #[test]
    fn expected_round_check_bootstraps_then_passes_then_catches_one_ulp() {
        let dir = temp_out_dir("ertest");
        let opts = CheckOpts::default();
        // Missing + require fails with remediation.
        let strict = CheckOpts { require: true, stream_change: false };
        let r = check_expected_round(&dir, &strict).unwrap();
        assert_eq!(r.status, CheckStatus::Fail);
        assert!(r.detail.contains("extract"), "{}", r.detail);
        // Bootstrap, then pass bit-exactly.
        assert_eq!(check_expected_round(&dir, &opts).unwrap().status, CheckStatus::Bootstrapped);
        assert_eq!(check_expected_round(&dir, &opts).unwrap().status, CheckStatus::Pass);
        // Perturb one value by 1 ulp: native provenance must fail...
        let path = dir.join(format!("{EXPECTED_ROUND_STEM}.csv"));
        let text = fs::read_to_string(&path).unwrap();
        let mut lines: Vec<String> = text.lines().map(String::from).collect();
        let cells: Vec<String> = lines[5].split(',').map(String::from).collect();
        let bits = u64::from_str_radix(&cells[1], 16).unwrap();
        let v = f64::from_bits(bits);
        let bumped = if v == 0.0 { f64::from_bits(1) } else { f64::from_bits(bits + 1) };
        let mut cells2 = cells.clone();
        cells2[1] = format!("{:016x}", bumped.to_bits());
        lines[5] = cells2.join(",");
        fs::write(&path, format!("{}\n", lines.join("\n"))).unwrap();
        let r = check_expected_round(&dir, &opts).unwrap();
        assert_eq!(r.status, CheckStatus::Fail);
        assert!(r.detail.contains("1 ulp"), "{}", r.detail);
        // ...while cross-language provenance grants exactly 1 ulp of slack.
        fs::write(dir.join(format!("{EXPECTED_ROUND_STEM}.provenance")), "cross-language\n")
            .unwrap();
        assert_eq!(check_expected_round(&dir, &opts).unwrap().status, CheckStatus::Pass);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn report_json_escapes_and_counts() {
        let mut rep = Report::default();
        rep.entries.push(FigureReport {
            id: "fig1".into(),
            status: CheckStatus::Pass,
            mode: "byte-exact".into(),
            cells: 10,
            detail: String::new(),
        });
        rep.entries.push(FigureReport {
            id: "fig2".into(),
            status: CheckStatus::Fail,
            mode: "byte-exact".into(),
            cells: 4,
            detail: "cell \"x\" drifted\nbadly".into(),
        });
        assert!(!rep.passed());
        assert_eq!(rep.counts(), (1, 0, 1));
        let json = rep.to_json();
        assert!(json.contains("\\\"x\\\""), "{json}");
        assert!(json.contains("\\n"), "{json}");
        assert!(json.contains("\"passed\": false"));
        assert!(ensure_passed(&rep).is_err());
    }

    #[test]
    fn banded_diff_accepts_inside_band_rejects_outside() {
        let mk = |v: f64| {
            let mut t = Table::new("demo", "demo", &["k", "det", "stoch"]);
            t.row(vec![0usize.into(), 1.5.into(), v.into()]);
            t.band("stoch", vec![0.01]);
            t
        };
        let golden = mk(0.5);
        let golden_csv = golden.to_csv();
        let golden_band = golden.bands_to_csv();
        let opts = CheckOpts { require: false, stream_change: true };
        // Inside the band: |0.503 - 0.5| well under z(1e-9)*sqrt(2)*0.01.
        let r = diff_table(&mk(0.503), &golden_csv, Some(&golden_band), &opts);
        assert_eq!(r.status, CheckStatus::Pass, "{}", r.detail);
        // Outside: 0.6 is 10 sems away.
        let r = diff_table(&mk(0.6), &golden_csv, Some(&golden_band), &opts);
        assert_eq!(r.status, CheckStatus::Fail);
        assert!(r.detail.contains("CLT band"), "{}", r.detail);
        // Deterministic column drift always fails, even in band mode.
        let mut det = mk(0.5);
        det.rows[0][1] = 1.6.into();
        let r = diff_table(&det, &golden_csv, Some(&golden_band), &opts);
        assert_eq!(r.status, CheckStatus::Fail);
        assert!(r.detail.contains("deterministic"), "{}", r.detail);
        // Default mode: byte-exact catches the in-band drift too.
        let r = diff_table(&mk(0.503), &golden_csv, Some(&golden_band), &CheckOpts::default());
        assert_eq!(r.status, CheckStatus::Fail);
        assert!(r.detail.contains("ulp"), "{}", r.detail);
    }
}
