//! Multi-seed expectation estimation: the paper reports E[·] and population
//! variance over 20 independent simulations (§5). Deterministic runs
//! (RN / binary32 baselines) are executed once.
//!
//! [`expectation_jobs`] is the scheduler-backed variant: the repetitions
//! fan out across the worker pool as independent cells and are merged in
//! seed order, so the aggregate is bit-identical for every `--jobs` value
//! (floating-point summation order is fixed by the ordered merge).

use crate::coordinator::health::{CellOutcome, FaultPolicy};
use crate::coordinator::journal::{sweep_cells, SweepFaults};
use crate::coordinator::scheduler::{cell_stream, run_indexed, run_indexed_faulted};
use crate::gd::trace::{mean_series, variance_series, Trace};
use crate::registry::{sweep_provenance, CellRecord};
use crate::util::hash::registry_key;

/// Aggregated series over seeds.
#[derive(Debug, Clone)]
pub struct ExpectationResult {
    /// Pointwise mean over the seeds.
    pub mean: Vec<f64>,
    /// Pointwise population variance over the seeds.
    pub variance: Vec<f64>,
    /// How many seeds were aggregated.
    pub seeds: usize,
}

impl ExpectationResult {
    /// Largest pointwise variance along the series.
    pub fn max_variance(&self) -> f64 {
        self.variance.iter().cloned().fold(0.0, f64::max)
    }
}

/// Run `runner(seed)` for `seeds` seeds and aggregate the series selected by
/// `select` (objective, metric, …) pointwise. Serial; equivalent to
/// [`expectation_jobs`] with `jobs = 1`.
pub fn expectation(
    seeds: usize,
    runner: &(dyn Fn(u64) -> Trace + Sync),
    select: &(dyn Fn(&Trace) -> Vec<f64> + Sync),
) -> ExpectationResult {
    expectation_jobs(1, seeds, runner, select)
}

/// Scheduler-backed [`expectation`]: the `seeds` repetitions run as
/// independent cells on a pool of `jobs` workers (`0` = auto, `1` = inline)
/// and are merged in seed order — bit-identical to the serial path.
pub fn expectation_jobs(
    jobs: usize,
    seeds: usize,
    runner: &(dyn Fn(u64) -> Trace + Sync),
    select: &(dyn Fn(&Trace) -> Vec<f64> + Sync),
) -> ExpectationResult {
    let all: Vec<Vec<f64>> = run_indexed(jobs, seeds, |s| select(&runner(s as u64)));
    ExpectationResult { mean: mean_series(&all), variance: variance_series(&all), seeds }
}

/// Fault-aware, journal-backed [`expectation_jobs`]: the repetitions run
/// through [`sweep_cells`] as cells of identity `(exp, label, seed)`, so
/// they checkpoint into (and resume from) the sweep journal and obey the
/// fault policy. Seeds lost to the skip-cell policy drop out of the
/// aggregate — the returned `seeds` field counts the survivors — and the
/// accompanying notes record every resume/retry/skip event. With no
/// journal, injector, or retries configured this is bit-identical to
/// [`expectation_jobs`].
pub fn expectation_sweep(
    exp: &str,
    label: &str,
    faults: &SweepFaults<'_>,
    seeds: usize,
    runner: &(dyn Fn(u64) -> Trace + Sync),
    select: &(dyn Fn(&Trace) -> Vec<f64> + Sync),
) -> (ExpectationResult, Vec<String>) {
    let cells: Vec<(String, u64)> =
        (0..seeds as u64).map(|s| (label.to_string(), s)).collect();
    let (values, notes) =
        sweep_cells(exp, faults, &cells, &|i| select(&runner(i as u64)), None);
    let all: Vec<Vec<f64>> = values.into_iter().flatten().collect();
    let result = ExpectationResult {
        mean: mean_series(&all),
        variance: variance_series(&all),
        seeds: all.len(),
    };
    (result, notes)
}

/// Lane-batched [`expectation_sweep`]: the `seeds` repetitions are mapped
/// onto lane batches of width `lanes` (each batch one scheduler task
/// running all its repetitions over a shared data pass, e.g. through
/// [`crate::gd::run_lane_batch`]) while **cell identities stay per
/// repetition**: journal keys are the same `(exp, label, seed)` streams as
/// the scalar sweep, journal lines are appended one per repetition, and
/// resume replays per repetition — so a journal written at one lane width
/// resumes correctly at any other, and the aggregate is bit-identical to
/// [`expectation_sweep`] at every width (each lane's trace is bit-identical
/// to its scalar run; see `docs/performance.md`).
///
/// `batch(seeds)` must return one [`Trace`] per requested seed, in order.
/// Fault isolation is per batch: a panicking batch retries (deterministic)
/// and, if terminally failed, all its repetitions resolve under the fault
/// policy together (fail-fast panics the sweep; skip/degrade drop them from
/// the aggregate with a note — there is no exact-master fallback at this
/// granularity).
pub fn expectation_sweep_lanes(
    exp: &str,
    label: &str,
    faults: &SweepFaults<'_>,
    seeds: usize,
    lanes: usize,
    batch: &(dyn Fn(&[u64]) -> Vec<Trace> + Sync),
    select: &(dyn Fn(&Trace) -> Vec<f64> + Sync),
) -> (ExpectationResult, Vec<String>) {
    let lanes = lanes.max(1);
    let mut values: Vec<Option<Vec<f64>>> = vec![None; seeds];
    let mut notes = Vec::new();
    // (1) Replay journaled repetitions — per-rep keys, lane-width agnostic
    // — then serve registry-stored ones (same content addresses as the
    // scalar sweep: lane width never changes a cell's identity or bytes).
    let mut todo: Vec<u64> = Vec::new();
    let mut served = 0usize;
    for s in 0..seeds as u64 {
        let key = cell_stream(exp, label, s);
        if let Some(series) = faults.journal.and_then(|j| j.lookup(key)) {
            values[s as usize] = Some(series);
        } else if let Some((reg, rec)) = faults.registry.and_then(|reg| {
            reg.peek(registry_key(faults.config_digest, key)).map(|rec| (reg, rec))
        }) {
            reg.count_hit();
            if let Some(j) = faults.journal {
                j.append(key, &rec.series);
            }
            values[s as usize] = Some(rec.series.clone());
            served += 1;
        } else {
            todo.push(s);
        }
    }
    if todo.len() + served < seeds {
        notes.push(format!(
            "{exp}: resumed {} of {seeds} cells from journal",
            seeds - todo.len() - served
        ));
    }
    if served > 0 {
        notes.push(format!("{exp}: served {served} of {seeds} cells from registry"));
    }
    // (2) Fan the remainder out as lane batches; journal per repetition as
    // each batch completes.
    let chunks: Vec<&[u64]> = todo.chunks(lanes).collect();
    let runs = run_indexed_faulted(
        faults.jobs,
        chunks.len(),
        faults.max_retries,
        |c| {
            let ss = chunks[c];
            let traces = batch(ss);
            assert_eq!(
                traces.len(),
                ss.len(),
                "lane batch returned {} traces for {} repetitions",
                traces.len(),
                ss.len()
            );
            traces.iter().map(|t| select(t)).collect::<Vec<Vec<f64>>>()
        },
        |c, r| {
            let Some(vs) = &r.value else { return };
            for (&s, v) in chunks[c].iter().zip(vs) {
                let key = cell_stream(exp, label, s);
                if let Some(j) = faults.journal {
                    j.append(key, v);
                }
                if let Some(reg) = faults.registry {
                    reg.insert(
                        registry_key(faults.config_digest, key),
                        CellRecord {
                            digest: faults.config_digest,
                            cell: key,
                            series: v.clone(),
                            health: Default::default(),
                            provenance: sweep_provenance(exp, label, s),
                        },
                    );
                    reg.count_miss();
                }
            }
        },
    );
    // (3) Resolve batch outcomes under the fault policy.
    for (c, r) in runs.into_iter().enumerate() {
        let ss = chunks[c];
        match r.outcome {
            CellOutcome::Ok | CellOutcome::Retried(_) => {
                if let CellOutcome::Retried(k) = r.outcome {
                    notes.push(format!(
                        "{exp}: lane batch ({label}, reps {ss:?}) recovered on retry {k}"
                    ));
                }
                for (&s, v) in ss.iter().zip(r.value.expect("succeeded batch has value")) {
                    values[s as usize] = Some(v);
                }
            }
            CellOutcome::Failed(reason) => match faults.policy {
                FaultPolicy::FailFast => panic!(
                    "{exp}: lane batch ({label}, reps {ss:?}) failed after {} retries: {reason}",
                    faults.max_retries
                ),
                FaultPolicy::SkipCell | FaultPolicy::Degrade => notes.push(format!(
                    "{exp}: lane batch ({label}, reps {ss:?}) failed, skipped: {reason}"
                )),
            },
        }
    }
    let all: Vec<Vec<f64>> = values.into_iter().flatten().collect();
    let result = ExpectationResult {
        mean: mean_series(&all),
        variance: variance_series(&all),
        seeds: all.len(),
    };
    (result, notes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gd::trace::IterRecord;

    fn toy_trace(seed: u64) -> Trace {
        let mut t = Trace::default();
        for k in 0..5 {
            t.push(IterRecord {
                k,
                f: (seed as f64) + k as f64,
                grad_norm: 0.0,
                dist_to_opt: f64::NAN,
                tau: f64::NAN,
                stalled: false,
                metric: f64::NAN,
            });
        }
        t
    }

    #[test]
    fn jobs_count_does_not_change_the_aggregate() {
        let serial = expectation_jobs(1, 8, &toy_trace, &|t| t.objective_series());
        let pooled = expectation_jobs(8, 8, &toy_trace, &|t| t.objective_series());
        assert_eq!(serial.mean, pooled.mean);
        assert_eq!(serial.variance, pooled.variance);
    }

    /// expectation_sweep with no faults configured matches expectation_jobs
    /// bit for bit; with a skip-cell injector one seed drops out of the
    /// aggregate and the seed count reflects the survivors.
    #[test]
    fn expectation_sweep_matches_and_degrades() {
        use crate::coordinator::health::{FaultInjector, FaultPolicy};
        let select = |t: &Trace| t.objective_series();
        let plain = expectation_jobs(1, 6, &toy_trace, &select);
        let (swept, notes) =
            expectation_sweep("aexp", "toy", &SweepFaults::none(1), 6, &toy_trace, &select);
        assert_eq!(plain.mean, swept.mean);
        assert_eq!(plain.variance, swept.variance);
        assert_eq!(swept.seeds, 6);
        assert!(notes.is_empty());
        let inj = FaultInjector::panic_at("aexp", 2, u32::MAX);
        let faults = SweepFaults {
            policy: FaultPolicy::SkipCell,
            injector: Some(&inj),
            ..SweepFaults::none(1)
        };
        let (swept, notes) =
            expectation_sweep("aexp", "toy", &faults, 6, &toy_trace, &select);
        assert_eq!(swept.seeds, 5);
        assert!(notes.iter().any(|n| n.contains("skipped")), "{notes:?}");
    }

    /// Lane batching never changes the aggregate: at widths 1, 4 and 8 the
    /// lane sweep is bit-identical to the scalar [`expectation_sweep`] on
    /// real stochastic GD cells, and a journal written at one width resumes
    /// (zero cells re-run) at another.
    #[test]
    fn lane_sweep_is_width_invariant_and_resumes_across_widths() {
        use crate::coordinator::journal::Journal;
        use crate::fp::{FpFormat, Rng, Rounding};
        use crate::gd::engine::{GdConfig, GdEngine};
        use crate::gd::lanes::run_lane_batch;
        use crate::problems::Quadratic;
        use std::sync::atomic::{AtomicUsize, Ordering};

        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        let cfg = GdConfig::new(FpFormat::BINARY8, Rounding::Sr, 0.05, 30);
        let select = |t: &Trace| t.objective_series();
        let scalar_runner = |s: u64| {
            let mut c = cfg.clone();
            c.seed = s;
            GdEngine::new(c, &p, &[1.0]).run(None)
        };
        let batch = |ss: &[u64]| {
            let roots: Vec<Rng> = ss.iter().map(|&s| Rng::new(s)).collect();
            run_lane_batch(&cfg, &p, &[1.0], &roots, None)
        };
        let (plain, _) = expectation_sweep(
            "lexp",
            "sr",
            &SweepFaults::none(1),
            6,
            &scalar_runner,
            &select,
        );
        for width in [1usize, 4, 8] {
            let (laned, notes) = expectation_sweep_lanes(
                "lexp",
                "sr",
                &SweepFaults::none(1),
                6,
                width,
                &batch,
                &select,
            );
            assert_eq!(plain.mean, laned.mean, "width={width}");
            assert_eq!(plain.variance, laned.variance, "width={width}");
            assert_eq!(laned.seeds, 6);
            assert!(notes.is_empty(), "{notes:?}");
        }
        // Journal at width 4, resume at width 3: zero batches run.
        let path = std::env::temp_dir()
            .join(format!("lpgd_lane_sweep_{}.jsonl", std::process::id()));
        {
            let j = Journal::open(&path, false, 5).unwrap();
            let faults = SweepFaults { journal: Some(&j), ..SweepFaults::none(1) };
            expectation_sweep_lanes("lexp", "sr", &faults, 6, 4, &batch, &select);
        }
        let j = Journal::open(&path, true, 5).unwrap();
        assert_eq!(j.resumed_cells(), 6);
        let ran = AtomicUsize::new(0);
        let counting_batch = |ss: &[u64]| {
            ran.fetch_add(ss.len(), Ordering::Relaxed);
            batch(ss)
        };
        let faults = SweepFaults { journal: Some(&j), ..SweepFaults::none(1) };
        let (resumed, notes) =
            expectation_sweep_lanes("lexp", "sr", &faults, 6, 3, &counting_batch, &select);
        assert_eq!(ran.load(Ordering::Relaxed), 0);
        assert_eq!(plain.mean, resumed.mean);
        assert!(notes.iter().any(|n| n.contains("resumed 6 of 6")), "{notes:?}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn expectation_over_seeds() {
        let r = expectation(4, &toy_trace, &|t| t.objective_series());
        // mean over seeds {0,1,2,3} at k: 1.5 + k
        assert_eq!(r.mean, vec![1.5, 2.5, 3.5, 4.5, 5.5]);
        assert_eq!(r.seeds, 4);
        // variance of {0,1,2,3} = 1.25 at every k
        assert!(r.variance.iter().all(|&v| (v - 1.25).abs() < 1e-12));
        assert_eq!(r.max_variance(), 1.25);
    }
}
