//! Multi-seed expectation estimation: the paper reports E[·] and population
//! variance over 20 independent simulations (§5). Deterministic runs
//! (RN / binary32 baselines) are executed once.
//!
//! [`expectation_jobs`] is the scheduler-backed variant: the repetitions
//! fan out across the worker pool as independent cells and are merged in
//! seed order, so the aggregate is bit-identical for every `--jobs` value
//! (floating-point summation order is fixed by the ordered merge).

use crate::coordinator::journal::{sweep_cells, SweepFaults};
use crate::coordinator::scheduler::run_indexed;
use crate::gd::trace::{mean_series, variance_series, Trace};

/// Aggregated series over seeds.
#[derive(Debug, Clone)]
pub struct ExpectationResult {
    /// Pointwise mean over the seeds.
    pub mean: Vec<f64>,
    /// Pointwise population variance over the seeds.
    pub variance: Vec<f64>,
    /// How many seeds were aggregated.
    pub seeds: usize,
}

impl ExpectationResult {
    /// Largest pointwise variance along the series.
    pub fn max_variance(&self) -> f64 {
        self.variance.iter().cloned().fold(0.0, f64::max)
    }
}

/// Run `runner(seed)` for `seeds` seeds and aggregate the series selected by
/// `select` (objective, metric, …) pointwise. Serial; equivalent to
/// [`expectation_jobs`] with `jobs = 1`.
pub fn expectation(
    seeds: usize,
    runner: &(dyn Fn(u64) -> Trace + Sync),
    select: &(dyn Fn(&Trace) -> Vec<f64> + Sync),
) -> ExpectationResult {
    expectation_jobs(1, seeds, runner, select)
}

/// Scheduler-backed [`expectation`]: the `seeds` repetitions run as
/// independent cells on a pool of `jobs` workers (`0` = auto, `1` = inline)
/// and are merged in seed order — bit-identical to the serial path.
pub fn expectation_jobs(
    jobs: usize,
    seeds: usize,
    runner: &(dyn Fn(u64) -> Trace + Sync),
    select: &(dyn Fn(&Trace) -> Vec<f64> + Sync),
) -> ExpectationResult {
    let all: Vec<Vec<f64>> = run_indexed(jobs, seeds, |s| select(&runner(s as u64)));
    ExpectationResult { mean: mean_series(&all), variance: variance_series(&all), seeds }
}

/// Fault-aware, journal-backed [`expectation_jobs`]: the repetitions run
/// through [`sweep_cells`] as cells of identity `(exp, label, seed)`, so
/// they checkpoint into (and resume from) the sweep journal and obey the
/// fault policy. Seeds lost to the skip-cell policy drop out of the
/// aggregate — the returned `seeds` field counts the survivors — and the
/// accompanying notes record every resume/retry/skip event. With no
/// journal, injector, or retries configured this is bit-identical to
/// [`expectation_jobs`].
pub fn expectation_sweep(
    exp: &str,
    label: &str,
    faults: &SweepFaults<'_>,
    seeds: usize,
    runner: &(dyn Fn(u64) -> Trace + Sync),
    select: &(dyn Fn(&Trace) -> Vec<f64> + Sync),
) -> (ExpectationResult, Vec<String>) {
    let cells: Vec<(String, u64)> =
        (0..seeds as u64).map(|s| (label.to_string(), s)).collect();
    let (values, notes) =
        sweep_cells(exp, faults, &cells, &|i| select(&runner(i as u64)), None);
    let all: Vec<Vec<f64>> = values.into_iter().flatten().collect();
    let result = ExpectationResult {
        mean: mean_series(&all),
        variance: variance_series(&all),
        seeds: all.len(),
    };
    (result, notes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gd::trace::IterRecord;

    fn toy_trace(seed: u64) -> Trace {
        let mut t = Trace::default();
        for k in 0..5 {
            t.push(IterRecord {
                k,
                f: (seed as f64) + k as f64,
                grad_norm: 0.0,
                dist_to_opt: f64::NAN,
                tau: f64::NAN,
                stalled: false,
                metric: f64::NAN,
            });
        }
        t
    }

    #[test]
    fn jobs_count_does_not_change_the_aggregate() {
        let serial = expectation_jobs(1, 8, &toy_trace, &|t| t.objective_series());
        let pooled = expectation_jobs(8, 8, &toy_trace, &|t| t.objective_series());
        assert_eq!(serial.mean, pooled.mean);
        assert_eq!(serial.variance, pooled.variance);
    }

    /// expectation_sweep with no faults configured matches expectation_jobs
    /// bit for bit; with a skip-cell injector one seed drops out of the
    /// aggregate and the seed count reflects the survivors.
    #[test]
    fn expectation_sweep_matches_and_degrades() {
        use crate::coordinator::health::{FaultInjector, FaultPolicy};
        let select = |t: &Trace| t.objective_series();
        let plain = expectation_jobs(1, 6, &toy_trace, &select);
        let (swept, notes) =
            expectation_sweep("aexp", "toy", &SweepFaults::none(1), 6, &toy_trace, &select);
        assert_eq!(plain.mean, swept.mean);
        assert_eq!(plain.variance, swept.variance);
        assert_eq!(swept.seeds, 6);
        assert!(notes.is_empty());
        let inj = FaultInjector::panic_at("aexp", 2, u32::MAX);
        let faults = SweepFaults {
            policy: FaultPolicy::SkipCell,
            injector: Some(&inj),
            ..SweepFaults::none(1)
        };
        let (swept, notes) =
            expectation_sweep("aexp", "toy", &faults, 6, &toy_trace, &select);
        assert_eq!(swept.seeds, 5);
        assert!(notes.iter().any(|n| n.contains("skipped")), "{notes:?}");
    }

    #[test]
    fn expectation_over_seeds() {
        let r = expectation(4, &toy_trace, &|t| t.objective_series());
        // mean over seeds {0,1,2,3} at k: 1.5 + k
        assert_eq!(r.mean, vec![1.5, 2.5, 3.5, 4.5, 5.5]);
        assert_eq!(r.seeds, 4);
        // variance of {0,1,2,3} = 1.25 at every k
        assert!(r.variance.iter().all(|&v| (v - 1.25).abs() < 1e-12));
        assert_eq!(r.max_variance(), 1.25);
    }
}
