//! Multi-seed expectation estimation: the paper reports E[·] and population
//! variance over 20 independent simulations (§5). Deterministic runs
//! (RN / binary32 baselines) are executed once.

use crate::gd::trace::{mean_series, variance_series, Trace};

/// Aggregated series over seeds.
#[derive(Debug, Clone)]
pub struct ExpectationResult {
    pub mean: Vec<f64>,
    pub variance: Vec<f64>,
    pub seeds: usize,
}

impl ExpectationResult {
    pub fn max_variance(&self) -> f64 {
        self.variance.iter().cloned().fold(0.0, f64::max)
    }
}

/// Run `runner(seed)` for `seeds` seeds and aggregate the series selected by
/// `select` (objective, metric, …) pointwise.
pub fn expectation(
    seeds: usize,
    runner: &dyn Fn(u64) -> Trace,
    select: &dyn Fn(&Trace) -> Vec<f64>,
) -> ExpectationResult {
    let all: Vec<Vec<f64>> = (0..seeds as u64).map(|s| select(&runner(s))).collect();
    ExpectationResult { mean: mean_series(&all), variance: variance_series(&all), seeds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gd::trace::IterRecord;

    fn toy_trace(seed: u64) -> Trace {
        let mut t = Trace::default();
        for k in 0..5 {
            t.push(IterRecord {
                k,
                f: (seed as f64) + k as f64,
                grad_norm: 0.0,
                dist_to_opt: f64::NAN,
                tau: f64::NAN,
                stalled: false,
                metric: f64::NAN,
            });
        }
        t
    }

    #[test]
    fn expectation_over_seeds() {
        let r = expectation(4, &toy_trace, &|t| t.objective_series());
        // mean over seeds {0,1,2,3} at k: 1.5 + k
        assert_eq!(r.mean, vec![1.5, 2.5, 3.5, 4.5, 5.5]);
        assert_eq!(r.seeds, 4);
        // variance of {0,1,2,3} = 1.25 at every k
        assert!(r.variance.iter().all(|&v| (v - 1.25).abs() < 1e-12));
        assert_eq!(r.max_variance(), 1.25);
    }
}
