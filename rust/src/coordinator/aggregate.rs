//! Multi-seed expectation estimation: the paper reports E[·] and population
//! variance over 20 independent simulations (§5). Deterministic runs
//! (RN / binary32 baselines) are executed once.
//!
//! [`expectation_jobs`] is the scheduler-backed variant: the repetitions
//! fan out across the worker pool as independent cells and are merged in
//! seed order, so the aggregate is bit-identical for every `--jobs` value
//! (floating-point summation order is fixed by the ordered merge).

use crate::coordinator::scheduler::run_indexed;
use crate::gd::trace::{mean_series, variance_series, Trace};

/// Aggregated series over seeds.
#[derive(Debug, Clone)]
pub struct ExpectationResult {
    /// Pointwise mean over the seeds.
    pub mean: Vec<f64>,
    /// Pointwise population variance over the seeds.
    pub variance: Vec<f64>,
    /// How many seeds were aggregated.
    pub seeds: usize,
}

impl ExpectationResult {
    /// Largest pointwise variance along the series.
    pub fn max_variance(&self) -> f64 {
        self.variance.iter().cloned().fold(0.0, f64::max)
    }
}

/// Run `runner(seed)` for `seeds` seeds and aggregate the series selected by
/// `select` (objective, metric, …) pointwise. Serial; equivalent to
/// [`expectation_jobs`] with `jobs = 1`.
pub fn expectation(
    seeds: usize,
    runner: &(dyn Fn(u64) -> Trace + Sync),
    select: &(dyn Fn(&Trace) -> Vec<f64> + Sync),
) -> ExpectationResult {
    expectation_jobs(1, seeds, runner, select)
}

/// Scheduler-backed [`expectation`]: the `seeds` repetitions run as
/// independent cells on a pool of `jobs` workers (`0` = auto, `1` = inline)
/// and are merged in seed order — bit-identical to the serial path.
pub fn expectation_jobs(
    jobs: usize,
    seeds: usize,
    runner: &(dyn Fn(u64) -> Trace + Sync),
    select: &(dyn Fn(&Trace) -> Vec<f64> + Sync),
) -> ExpectationResult {
    let all: Vec<Vec<f64>> = run_indexed(jobs, seeds, |s| select(&runner(s as u64)));
    ExpectationResult { mean: mean_series(&all), variance: variance_series(&all), seeds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gd::trace::IterRecord;

    fn toy_trace(seed: u64) -> Trace {
        let mut t = Trace::default();
        for k in 0..5 {
            t.push(IterRecord {
                k,
                f: (seed as f64) + k as f64,
                grad_norm: 0.0,
                dist_to_opt: f64::NAN,
                tau: f64::NAN,
                stalled: false,
                metric: f64::NAN,
            });
        }
        t
    }

    #[test]
    fn jobs_count_does_not_change_the_aggregate() {
        let serial = expectation_jobs(1, 8, &toy_trace, &|t| t.objective_series());
        let pooled = expectation_jobs(8, 8, &toy_trace, &|t| t.objective_series());
        assert_eq!(serial.mean, pooled.mean);
        assert_eq!(serial.variance, pooled.variance);
    }

    #[test]
    fn expectation_over_seeds() {
        let r = expectation(4, &toy_trace, &|t| t.objective_series());
        // mean over seeds {0,1,2,3} at k: 1.5 + k
        assert_eq!(r.mean, vec![1.5, 2.5, 3.5, 4.5, 5.5]);
        assert_eq!(r.seeds, 4);
        // variance of {0,1,2,3} = 1.25 at every k
        assert!(r.variance.iter().all(|&v| (v - 1.25).abs() < 1e-12));
        assert_eq!(r.max_variance(), 1.25);
    }
}
