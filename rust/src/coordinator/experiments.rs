//! The experiment builders: one function per table/figure of the paper
//! (DESIGN.md §5), plus the shared [`ExpCtx`] knobs and the
//! [`run_experiment`] entry point. The id → builder mapping lives in
//! [`crate::coordinator::registry`]; the multi-threaded fan-out of
//! (experiment × rounding-mode × repetition) cells goes through
//! [`crate::coordinator::scheduler`] (`ExpCtx::jobs`, CLI `--jobs`).
//!
//! Scale notes (documented substitutions, DESIGN.md §2): the learning
//! experiments use the procedural digit dataset at 14×14 by default
//! (`--side 28` for full size) and `--seeds` controls the expectation
//! estimate (paper: 20; default here: 5 for a single-core laptop budget).

use std::sync::Arc;

use crate::coordinator::aggregate::expectation_sweep_lanes;
use crate::coordinator::health::{panic_message, FaultInjector, FaultPolicy};
use crate::coordinator::journal::{sweep_cells, Journal, SweepFaults};
use crate::coordinator::registry;
use crate::coordinator::scheduler::run_indexed;
use crate::data::{load_or_synth, Dataset};
use crate::fp::{FixedPoint, FpFormat, Grid, RoundPlan, Scheme};
use crate::gd::engine::{GdConfig, GdEngine, GradModel, PolicyMap, TensorPolicy};
use crate::gd::optimizer::OptimizerSpec;
use crate::gd::theory;
use crate::gd::trace::Trace;
use crate::problems::{Mlr, Problem, Quadratic, TwoLayerNn};
use crate::registry::ResultStore;
use crate::util::hash::Fnv1a;
use crate::util::stats::{first_at_or_below, sem, sem_from_population_variance};
use crate::util::table::{Cell, Table};
use anyhow::{bail, Result};

/// Shared experiment context (CLI knobs).
#[derive(Debug, Clone)]
pub struct ExpCtx {
    /// Seeds for stochastic-rounding expectations (paper: 20).
    pub seeds: usize,
    /// Worker threads for the cell scheduler (`0` = all cores, `1` =
    /// serial). Any value produces bit-identical results; see
    /// [`crate::coordinator::scheduler`].
    pub jobs: usize,
    /// Lane width for repetition fan-outs (`--lanes`): seeds execute in
    /// structure-of-arrays batches of this many interleaved lanes sharing
    /// one data pass ([`crate::gd::run_lane_batch`]). Like `jobs`, purely
    /// an execution knob — every width produces bit-identical results and
    /// journal lines, so it is excluded from [`ExpCtx::config_digest`].
    pub lanes: usize,
    /// Output directory for CSVs.
    pub out_dir: String,
    /// Image side for the synthetic digit data (paper MNIST: 28).
    pub side: usize,
    /// Training-set size for MLR (paper: 60000).
    pub mlr_train: usize,
    /// Test-set size for MLR (paper: 10000).
    pub mlr_test: usize,
    /// Training-set size for the NN 3-vs-8 task (paper: 11982).
    pub nn_train: usize,
    /// Test-set size for the NN 3-vs-8 task (paper: 1984).
    pub nn_test: usize,
    /// Epochs for MLR (paper: 150).
    pub mlr_epochs: usize,
    /// Epochs for the NN (paper: 50).
    pub nn_epochs: usize,
    /// Quadratic iteration budget (paper fig3: 4000).
    pub quad_steps: usize,
    /// Quadratic dimension (paper: 1000).
    pub quad_n: usize,
    /// Optional real-MNIST directory.
    pub mnist_dir: Option<String>,
    /// Extra attempts per panicking sweep cell before the cell is declared
    /// failed (`--max-retries`; retries are deterministic, see
    /// `docs/robustness.md`).
    pub max_retries: u32,
    /// What a terminally failed cell does to its sweep (`--fault-policy`).
    pub fault_policy: FaultPolicy,
    /// Divergence-guard threshold threaded into every GD cell
    /// (`--escape`): a cell whose loss turns non-finite or exceeds it stops
    /// early with `RunStatus::Diverged`. `None` keeps the historic
    /// run-to-completion behavior and bit-identical CSVs.
    pub escape: Option<f64>,
    /// Checkpoint/resume journal (`--journal PATH`, loaded when `--resume`
    /// is also given). Shared across the experiment's sweeps.
    pub journal: Option<Arc<Journal>>,
    /// Content-addressed result registry (`--registry DIR`): sweep cells
    /// whose key is already in the store are served from it instead of
    /// recomputed, and freshly computed cells are written back. Shared
    /// byte-for-byte with `lpgd serve` (see `docs/service.md`).
    pub registry: Option<Arc<ResultStore>>,
    /// Deterministic fault injector — test/CI hook only, never set by
    /// normal CLI use.
    pub injector: Option<Arc<FaultInjector>>,
}

impl Default for ExpCtx {
    fn default() -> Self {
        Self {
            seeds: 5,
            jobs: 0,
            lanes: 1,
            out_dir: "results".into(),
            side: 14,
            mlr_train: 4000,
            mlr_test: 1000,
            nn_train: 1200,
            nn_test: 400,
            mlr_epochs: 150,
            nn_epochs: 50,
            quad_steps: 4000,
            quad_n: 1000,
            mnist_dir: None,
            max_retries: 0,
            fault_policy: FaultPolicy::FailFast,
            escape: None,
            journal: None,
            registry: None,
            injector: None,
        }
    }
}

impl ExpCtx {
    /// Fast smoke-profile used by `--quick` and the integration tests.
    pub fn quick() -> Self {
        Self {
            seeds: 2,
            side: 8,
            mlr_train: 300,
            mlr_test: 100,
            nn_train: 200,
            nn_test: 80,
            mlr_epochs: 12,
            nn_epochs: 8,
            quad_steps: 300,
            quad_n: 100,
            ..Self::default()
        }
    }

    /// The sweep-level fault-handling view of this context, consumed by
    /// [`sweep_cells`].
    pub fn faults(&self) -> SweepFaults<'_> {
        SweepFaults {
            jobs: self.jobs,
            max_retries: self.max_retries,
            policy: self.fault_policy,
            journal: self.journal.as_deref(),
            registry: self.registry.as_deref(),
            config_digest: self.config_digest(),
            injector: self.injector.as_deref(),
        }
    }

    /// Digest of every knob that changes what a sweep cell *computes* (data
    /// sizes, epochs, problem dimensions, the MNIST source, the escape
    /// guard). Journal lines carry it, and resume replays only matching
    /// lines — so a journal written under different settings is inert
    /// rather than corrupting. `seeds`, `jobs`, `lanes`, `out_dir` and the
    /// fault knobs are deliberately excluded: they select or schedule cells
    /// but never change an individual cell's output.
    pub fn config_digest(&self) -> u64 {
        // The fold order below is the on-disk journal contract — see
        // `util::hash` for the byte-compatibility notes.
        let mut h = Fnv1a::new();
        for v in [
            self.side,
            self.mlr_train,
            self.mlr_test,
            self.nn_train,
            self.nn_test,
            self.mlr_epochs,
            self.nn_epochs,
            self.quad_steps,
            self.quad_n,
        ] {
            h = h.u64(v as u64);
        }
        h.str(self.mnist_dir.as_deref().unwrap_or(""))
            .byte(self.escape.is_some() as u8)
            .u64(self.escape.map_or(0, f64::to_bits))
            .finish()
    }
}

/// List every reproducible experiment as `(id, description)` pairs
/// (compatibility view over [`registry::REGISTRY`]).
pub fn list_experiments() -> Vec<(&'static str, &'static str)> {
    registry::REGISTRY.iter().map(|s| (s.id, s.description)).collect()
}

/// Run one experiment by id (or "all"); returns the produced tables after
/// writing each as CSV under `ctx.out_dir`.
pub fn run_experiment(id: &str, ctx: &ExpCtx) -> Result<Vec<Table>> {
    if id == "all" {
        let mut all = vec![];
        for spec in registry::REGISTRY {
            all.extend(run_experiment(spec.id, ctx)?);
        }
        return Ok(all);
    }
    let spec = match registry::find(id) {
        Some(s) => s,
        None => bail!("unknown experiment '{id}' (see `lpgd list`)"),
    };
    // The fail-fast fault policy (and any unguarded builder bug) surfaces
    // as a panic inside the builder; catch it here so one bad experiment
    // becomes a clean error — and, under `id == "all"`, cannot take down
    // the experiments already journaled or written.
    let tables =
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (spec.run)(ctx))) {
            Ok(tables) => tables,
            Err(payload) => {
                bail!("experiment '{id}' aborted: {}", panic_message(payload.as_ref()))
            }
        };
    for t in &tables {
        t.write_csv(&ctx.out_dir)?;
        t.write_band_csv(&ctx.out_dir)?;
    }
    Ok(tables)
}

// ---------------------------------------------------------------- table2 --

/// Paper Table 2: number-format parameters.
pub(crate) fn table2() -> Table {
    let mut t = Table::new(
        "table2",
        "Number-format parameters (paper Table 2)",
        &["format", "u", "x_min", "x_max"],
    );
    for fmt in [
        FpFormat::BINARY8,
        FpFormat::BFLOAT16,
        FpFormat::BINARY16,
        FpFormat::BINARY32,
        FpFormat::BINARY64,
    ] {
        t.row(vec![
            fmt.name().into(),
            fmt.unit_roundoff().into(),
            fmt.x_min().into(),
            fmt.x_max().into(),
        ]);
    }
    t
}

// ------------------------------------------------------------------ fig1 --

/// Paper Figure 1: closed-form E[fl(y)] across one rounding gap.
pub(crate) fn fig1() -> Table {
    // E[fl(y)] for y spanning one gap of binary8: positive gap (1, 1.25)
    // and negative gap (−1.25, −1), under RN / SR / SRε(0.25) / SRε(0.5).
    let fmt = FpFormat::BINARY8;
    let mut t = Table::new(
        "fig1",
        "E[fl(y)] across one rounding gap (paper Figure 1)",
        &["y", "RN", "SR", "SR_eps(0.25)", "SR_eps(0.5)", "sign"],
    );
    for &(lo, hi, sign) in &[(1.0f64, 1.25, 1.0), (-1.25, -1.0, -1.0)] {
        let steps = 40;
        for i in 1..steps {
            let y = lo + (hi - lo) * i as f64 / steps as f64;
            t.row(vec![
                y.into(),
                Scheme::rn().expected_round(&fmt, y, y).into(),
                Scheme::sr().expected_round(&fmt, y, y).into(),
                Scheme::sr_eps(0.25).expected_round(&fmt, y, y).into(),
                Scheme::sr_eps(0.5).expected_round(&fmt, y, y).into(),
                sign.into(),
            ]);
        }
    }
    t.note("SR_eps combines SR with ceiling for y>0 and flooring for y<0 (paper Fig. 1)");
    t
}

// ------------------------------------------------------------------ fig2 --

/// Paper Figure 2: GD stagnation under RN in binary8, with τ_k.
pub(crate) fn fig2() -> Table {
    // f(x) = (x−1024)², binary8, RN; x0 = 1, t = 0.05 (§3.2 / Figure 2).
    let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
    let mut cfg = GdConfig::new(
        FpFormat::BINARY8,
        PolicyMap::uniform(Scheme::rn()),
        0.05,
        40,
    );
    cfg.record_tau = true;
    let mut e = GdEngine::new(cfg, &p, &[1.0]);
    // Drive the engine step-by-step so the CSV carries the actual iterate
    // x_k (the engine's Trace records scalars only).
    let mut xs = vec![e.x[0]];
    let tr = {
        let mut t = crate::gd::trace::Trace::default();
        for k in 0..40 {
            let mut g = vec![0.0];
            p.gradient_exact(&e.x, &mut g);
            let f = p.objective(&e.x);
            let ghat = {
                let mut rng = crate::fp::Rng::new(0);
                RoundPlan::new(FpFormat::BINARY8).round_scheme(Scheme::rn(), g[0], &mut rng)
            };
            let tau = crate::gd::stagnation::tau_k(&FpFormat::BINARY8, &e.x, &[ghat], 0.05).tau;
            let moved = e.step();
            xs.push(e.x[0]);
            t.push(crate::gd::trace::IterRecord {
                k,
                f,
                grad_norm: g[0].abs(),
                dist_to_opt: (e.x[0] - 1024.0).abs(),
                tau,
                stalled: !moved,
                metric: f64::NAN,
            });
        }
        t
    };
    let u_half = FpFormat::BINARY8.unit_roundoff() / 2.0;
    let mut t = Table::new(
        "fig2",
        "GD stagnation under RN, binary8 (paper Figure 2)",
        &["k", "x_k", "f", "tau_k", "u/2", "stalled"],
    );
    for r in &tr.records {
        t.row(vec![
            r.k.into(),
            xs[r.k].into(),
            r.f.into(),
            r.tau.into(),
            u_half.into(),
            (r.stalled as i64).into(),
        ]);
    }
    if let Some(onset) = tr.stagnation_onset() {
        t.note(format!(
            "stagnates from k={onset} with tau_k={:.4} <= u/2={u_half}",
            tr.records.last().unwrap().tau
        ));
    }
    t
}

// ------------------------------------------------------------------ fig3 --

/// Paper Figure 3 (a: Setting I diagonal, b: Setting II dense): SR vs
/// signed-SRε against the binary32 baseline and the Theorem-2 bound.
pub(crate) fn fig3(ctx: &ExpCtx, dense: bool) -> Table {
    let n = ctx.quad_n;
    let steps = ctx.quad_steps;
    let (p, x0, t_step) =
        if dense { Quadratic::setting2(n, 0) } else { Quadratic::setting1(n) };
    let lip = p.lipschitz().unwrap();
    let dist0 = {
        let d = crate::fp::linalg::exact::sub(&x0, p.optimum().unwrap());
        crate::fp::linalg::exact::norm2(&d)
    };

    let run = |fmt: FpFormat, schemes: PolicyMap, seed: u64| -> Trace {
        let mut cfg = GdConfig::new(fmt, schemes, t_step, steps);
        cfg.seed = seed;
        cfg.escape = ctx.escape;
        GdEngine::new(cfg, &p, &x0).run(None)
    };
    // Lane batch runner: the seed repetitions of one scheme family execute
    // as interleaved lanes over a shared data pass, each lane on the legacy
    // seed-keyed root — bit-identical to `run` per seed at every `--lanes`.
    let run_batch = |fmt: FpFormat, schemes: PolicyMap, seeds: &[u64]| -> Vec<Trace> {
        let mut cfg = GdConfig::new(fmt, schemes, t_step, steps);
        cfg.escape = ctx.escape;
        let roots: Vec<crate::fp::Rng> =
            seeds.iter().map(|&s| crate::fp::Rng::new(s)).collect();
        crate::gd::run_lane_batch(&cfg, &p, &x0, &roots, None)
    };

    let id = if dense { "fig3b" } else { "fig3a" };
    // binary32 + RN baseline ("exact" reference), deterministic.
    let base = run(FpFormat::BINARY32, PolicyMap::uniform(Scheme::rn()), 0);
    // bfloat16: (8a)+(8b) SR with (8c) ∈ {SR, signed-SRε(0.4)}; the seed
    // repetitions fan out across the worker pool through the fault-aware
    // journaled sweep (labels keep the two scheme families' cell identities
    // apart in the journal), `--lanes` at a time as lane batches.
    let faults = ctx.faults();
    let sr_schemes = PolicyMap::uniform(Scheme::sr());
    let (sr, sr_notes) = expectation_sweep_lanes(
        id,
        "bf16_SR",
        &faults,
        ctx.seeds,
        ctx.lanes,
        &|ss| run_batch(FpFormat::BFLOAT16, sr_schemes, ss),
        &|t| t.objective_series(),
    );
    let sg_schemes =
        PolicyMap::sites(Scheme::sr(), Scheme::sr(), Scheme::signed_sr_eps(0.4));
    let (signed, sg_notes) = expectation_sweep_lanes(
        id,
        "bf16_signed_SReps0.4",
        &faults,
        ctx.seeds,
        ctx.lanes,
        &|ss| run_batch(FpFormat::BFLOAT16, sg_schemes, ss),
        &|t| t.objective_series(),
    );
    let setting = if dense { "Setting II" } else { "Setting I" };
    let mut t = Table::new(
        id,
        &format!("Quadratic {setting}, bfloat16 (paper Figure 3)"),
        &["k", "thm2_bound", "binary32_RN", "bf16_SR", "bf16_signed_SReps0.4"],
    );
    // An escape-shortened (diverged) run truncates its aggregate series;
    // pad the missing tail with NaN so the row loop stays rectangular.
    let at = |series: &[f64], k: usize| series.get(k).copied().unwrap_or(f64::NAN);
    let base_f = base.objective_series();
    let stride = (steps / 200).max(1); // keep CSVs compact
    for k in (0..steps).step_by(stride) {
        t.row(vec![
            k.into(),
            theory::theorem2_bound(lip, t_step, k, dist0).into(),
            at(&base_f, k).into(),
            at(&sr.mean, k).into(),
            at(&signed.mean, k).into(),
        ]);
    }
    if ctx.seeds > 1 {
        // SEM bands from the aggregate's population variance, strided
        // exactly like the rows (missing tail entries carry a zero band:
        // their means are NaN and compare as NaN either way).
        let band_of = |res: &crate::coordinator::aggregate::ExpectationResult| -> Vec<f64> {
            (0..steps)
                .step_by(stride)
                .map(|k| {
                    res.variance
                        .get(k)
                        .map_or(0.0, |&v| sem_from_population_variance(v, res.seeds))
                })
                .collect()
        };
        t.band("bf16_SR", band_of(&sr));
        t.band("bf16_signed_SReps0.4", band_of(&signed));
    }
    for n in sr_notes.into_iter().chain(sg_notes) {
        t.note(n);
    }
    // Paper's §5.1 closing metric for Setting II: relative error at k=4000.
    // One cell per seed; the ordered merge fixes the summation order so the
    // average is identical for every jobs count.
    let rel_err = |schemes: PolicyMap| -> f64 {
        let errs = run_indexed(ctx.jobs, ctx.seeds, |s| {
            let mut cfg = GdConfig::new(FpFormat::BFLOAT16, schemes, t_step, steps);
            cfg.seed = s as u64;
            let mut e = GdEngine::new(cfg, &p, &x0);
            e.run(None);
            let d = crate::fp::linalg::exact::sub(&e.x, p.optimum().unwrap());
            crate::fp::linalg::exact::norm2(&d)
                / crate::fp::linalg::exact::norm2(p.optimum().unwrap())
        });
        errs.iter().sum::<f64>() / ctx.seeds as f64
    };
    if dense {
        t.note(format!(
            "relative error ||x(k)-x*||/||x*|| at k={steps}: SR={:.3}, signed-SReps(0.4)={:.3} (paper: 1.50 vs 0.12)",
            rel_err(sr_schemes),
            rel_err(sg_schemes)
        ));
    }
    t.note(format!("seeds={} (paper: 20)", ctx.seeds));
    t
}

// ------------------------------------------------- learning-task helpers --

struct LearnSetup {
    mlr: Mlr,
    test: Dataset,
    x0: Vec<f64>,
}

fn mlr_setup(ctx: &ExpCtx) -> LearnSetup {
    let splits = load_or_synth(
        ctx.mnist_dir.as_deref(),
        ctx.mlr_train,
        ctx.mlr_test,
        ctx.side,
        42,
    );
    let mlr = Mlr::new(splits.train, 10);
    let x0 = vec![0.0; mlr.dim()];
    LearnSetup { mlr, test: splits.test, x0 }
}

/// How many expectation seeds a scheme combination needs: stochastic
/// schemes average over `seeds`, fully deterministic ones run once.
fn seeds_for(schemes: &PolicyMap, seeds: usize) -> usize {
    if schemes.is_stochastic() {
        seeds
    } else {
        1
    }
}

/// Fan a (config × repetition) grid out as **one** batch of scheduler
/// cells and return the per-config mean series, the per-config pointwise
/// standard errors of those means (zero for single-seed configs — the
/// golden harness treats such columns as deterministic), plus the sweep's
/// fault notes (resume/retry/skip/degrade events — empty on a clean run).
///
/// This is the coordinator's main fan-out shape: flattening the whole grid
/// keeps every worker busy even when some configs are deterministic single
/// runs. `seeds_per_cfg[ci]` repetitions are enumerated per config;
/// `run(ci, seed)` produces one cell's series. Results are grouped back
/// per config in cell order, making the means — and the CSVs — bit-
/// identical for any `jobs` value.
///
/// The batch runs through [`sweep_cells`], so every fan-out in the crate
/// gets checkpoint/resume, panic isolation and retry for free: the cell
/// identity is `(exp, labels[ci], seed)` and the journal key is its
/// [`crate::coordinator::scheduler::cell_stream`] hash. Skipped cells
/// (skip-cell policy) drop out of their config's mean; a config that loses
/// *every* cell pads with NaN. Each mean is padded to `rows` entries with
/// NaN so tables stay rectangular when the `--escape` guard shortens a
/// trace. `master`, when given, supplies the degrade-policy fallback for a
/// `(config, seed)` cell.
fn curves_flat(
    exp: &str,
    labels: &[String],
    seeds_per_cfg: &[usize],
    rows: usize,
    ctx: &ExpCtx,
    run: &(dyn Fn(usize, u64) -> Vec<f64> + Sync),
    master: Option<&(dyn Fn(usize, u64) -> Vec<f64> + Sync)>,
) -> (Vec<Vec<f64>>, Vec<Vec<f64>>, Vec<String>) {
    debug_assert_eq!(labels.len(), seeds_per_cfg.len());
    let mut cells: Vec<(String, u64)> = Vec::new();
    let mut map: Vec<(usize, u64)> = Vec::new();
    for (ci, &n) in seeds_per_cfg.iter().enumerate() {
        for s in 0..n as u64 {
            cells.push((labels[ci].clone(), s));
            map.push((ci, s));
        }
    }
    let cell_run = |k: usize| -> Vec<f64> {
        let (ci, s) = map[k];
        run(ci, s)
    };
    let master_run = |k: usize| -> Vec<f64> {
        let (ci, s) = map[k];
        (master.expect("master_run is only reachable when master is Some"))(ci, s)
    };
    let master_opt: Option<&(dyn Fn(usize) -> Vec<f64> + Sync)> =
        if master.is_some() { Some(&master_run) } else { None };
    let (values, notes) = sweep_cells(exp, &ctx.faults(), &cells, &cell_run, master_opt);
    let mut curves = Vec::with_capacity(seeds_per_cfg.len());
    let mut sems = Vec::with_capacity(seeds_per_cfg.len());
    let mut offset = 0;
    for &n in seeds_per_cfg {
        let group: Vec<Vec<f64>> =
            values[offset..offset + n].iter().filter_map(|v| v.clone()).collect();
        let mut mean = crate::gd::trace::mean_series(&group);
        if mean.len() < rows {
            mean.resize(rows, f64::NAN);
        }
        // Pointwise standard error of that mean across the group — the
        // spread the golden harness turns into a CLT band. Zero whenever
        // fewer than two repetitions reach an index.
        let sem_series: Vec<f64> = (0..rows)
            .map(|k| {
                let at_k: Vec<f64> = group.iter().filter_map(|g| g.get(k).copied()).collect();
                sem(&at_k)
            })
            .collect();
        curves.push(mean);
        sems.push(sem_series);
        offset += n;
    }
    (curves, sems, notes)
}

/// One MLR training cell: train `(grid, schemes, grad_model)` at `seed`
/// for `epochs` and return the test-error series. Every MLR fan-out
/// (`learning_table`, `fig4a_acc`, `fig5`, `plfp2`) runs this one body, so
/// a change to how a cell is configured happens in exactly one place.
#[allow(clippy::too_many_arguments)]
fn mlr_cell(
    setup: &LearnSetup,
    grid: Grid,
    schemes: PolicyMap,
    gm: GradModel,
    t_step: f64,
    epochs: usize,
    seed: u64,
    escape: Option<f64>,
) -> Vec<f64> {
    let mut cfg = GdConfig::new(grid, schemes, t_step, epochs);
    cfg.seed = seed;
    cfg.grad_model = gm;
    cfg.escape = escape;
    let mut e = GdEngine::new(cfg, &setup.mlr, &setup.x0);
    let metric = |x: &[f64]| setup.mlr.test_error(x, &setup.test);
    e.run(Some(&metric)).metric_series()
}

// ------------------------------------------------------------------ fig4 --

/// Paper Figure 4a: MLR scheme sweep for (8a)+(8b) with (8c)=SR.
pub(crate) fn fig4a(ctx: &ExpCtx) -> Table {
    let setup = mlr_setup(ctx);
    let t_step = 0.5;
    let b8: Grid = FpFormat::BINARY8.into();
    let sr = Scheme::sr();
    let cfgs: Vec<(String, Grid, PolicyMap)> = vec![
        ("binary32".into(), FpFormat::BINARY32.into(), PolicyMap::uniform(Scheme::rn())),
        ("RN".into(), b8, PolicyMap::sites(Scheme::rn(), Scheme::rn(), sr)),
        ("SR".into(), b8, PolicyMap::sites(sr, sr, sr)),
        ("SR_eps(0.2)".into(), b8, PolicyMap::sites(Scheme::sr_eps(0.2), Scheme::sr_eps(0.2), sr)),
        ("SR_eps(0.4)".into(), b8, PolicyMap::sites(Scheme::sr_eps(0.4), Scheme::sr_eps(0.4), sr)),
    ];
    learning_table(
        "fig4a",
        "MLR test error, binary8, t=0.5: (8a)+(8b) scheme sweep, (8c)=SR (paper Fig. 4a)",
        &setup,
        cfgs,
        t_step,
        ctx.mlr_epochs,
        ctx,
    )
}

/// Paper Figure 4b: MLR with signed-SRε variants on step (8c).
pub(crate) fn fig4b(ctx: &ExpCtx) -> Table {
    let setup = mlr_setup(ctx);
    let t_step = 0.5;
    let b8: Grid = FpFormat::BINARY8.into();
    let sr = Scheme::sr();
    let cfgs: Vec<(String, Grid, PolicyMap)> = vec![
        ("binary32".into(), FpFormat::BINARY32.into(), PolicyMap::uniform(Scheme::rn())),
        ("SR|SR".into(), b8, PolicyMap::sites(sr, sr, sr)),
        ("SR_eps(0.1)|signed(0.1)".into(), b8, PolicyMap::sites(Scheme::sr_eps(0.1), Scheme::sr_eps(0.1), Scheme::signed_sr_eps(0.1))),
        ("SR|signed(0.1)".into(), b8, PolicyMap::sites(sr, sr, Scheme::signed_sr_eps(0.1))),
        ("SR|signed(0.2)".into(), b8, PolicyMap::sites(sr, sr, Scheme::signed_sr_eps(0.2))),
    ];
    let mut t = learning_table(
        "fig4b",
        "MLR test error, binary8, t=0.5: signed-SReps for (8c) (paper Fig. 4b)",
        &setup,
        cfgs,
        t_step,
        ctx.mlr_epochs,
        ctx,
    );
    t.note("paper: signed-SReps(0.1) reaches the binary32-150-epoch error in ~82-84 epochs");
    t
}

/// Ablation (beyond the paper's protocol): rerun the fig-4a comparison with
/// the gradient evaluated under *blocked low-precision accumulation*
/// (GradModel::PerOp) instead of chop-style result rounding. This exposes
/// the absorption mechanism directly: under RN the per-sample gradient
/// contributions vanish against the running sum and training stalls at a
/// high error, while SR preserves them in expectation (Gupta et al. 2015).
pub(crate) fn fig4a_acc(ctx: &ExpCtx) -> Table {
    let setup = mlr_setup(ctx);
    let t_step = 0.5;
    let b8: Grid = FpFormat::BINARY8.into();
    let sr = Scheme::sr();
    let epochs = ctx.mlr_epochs.min(60); // the separation is clear early
    let cfgs: Vec<(String, Grid, PolicyMap, GradModel)> = vec![
        ("binary32".into(), FpFormat::BINARY32.into(), PolicyMap::uniform(Scheme::rn()), GradModel::Exact),
        ("RN_acc".into(), b8, PolicyMap::sites(Scheme::rn(), Scheme::rn(), sr), GradModel::PerOp),
        ("SR_acc".into(), b8, PolicyMap::sites(sr, sr, sr), GradModel::PerOp),
        ("RN_chop".into(), b8, PolicyMap::sites(Scheme::rn(), Scheme::rn(), sr), GradModel::RoundAfterOp),
    ];
    let mut cols = vec!["epoch".to_string()];
    cols.extend(cfgs.iter().map(|(n, _, _, _)| n.clone()));
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "fig4a-acc",
        "MLR: absorption ablation (low-precision accumulation vs chop result-rounding)",
        &col_refs,
    );
    let labels: Vec<String> = cfgs.iter().map(|(n, _, _, _)| n.clone()).collect();
    let seeds_per: Vec<usize> =
        cfgs.iter().map(|(_, _, sch, _)| seeds_for(sch, ctx.seeds)).collect();
    let (curves, sems, notes) = curves_flat(
        "fig4a-acc",
        &labels,
        &seeds_per,
        epochs,
        ctx,
        &|ci, s| {
            let (_, fmt, sch, gm) = &cfgs[ci];
            mlr_cell(&setup, *fmt, *sch, *gm, t_step, epochs, s, ctx.escape)
        },
        None,
    );
    for k in 0..epochs {
        let mut row: Vec<Cell> = vec![k.into()];
        for cv in &curves {
            row.push(cv[k].into());
        }
        t.row(row);
    }
    for (i, label) in labels.iter().enumerate() {
        if seeds_per[i] > 1 {
            t.band(label.clone(), sems[i].clone());
        }
    }
    for n in notes {
        t.note(n);
    }
    t.note("RN_acc should stall well above binary32 while SR_acc keeps tracking it");
    t
}

// ------------------------------------------------------------------ fig5 --

/// Paper Figure 5 (a: SR, b: SRε+signed-SRε): MLR stepsize sweep.
pub(crate) fn fig5(ctx: &ExpCtx, biased: bool) -> Table {
    let setup = mlr_setup(ctx);
    let b8: Grid = FpFormat::BINARY8.into();
    let schemes = if biased {
        PolicyMap::sites(Scheme::sr_eps(0.1), Scheme::signed_sr_eps(0.1), Scheme::signed_sr_eps(0.1))
    } else {
        PolicyMap::uniform(Scheme::sr())
    };
    let id = if biased { "fig5b" } else { "fig5a" };
    let title = if biased {
        "MLR stepsize sweep, SReps(0.1)+signed-SReps(0.1) (paper Fig. 5b)"
    } else {
        "MLR stepsize sweep under SR (paper Fig. 5a)"
    };
    let ts = [0.1, 0.5, 1.0, 1.25];
    let mut cols = vec!["epoch".to_string()];
    cols.push("binary32_t1.25".into());
    for t_ in ts {
        cols.push(format!("t={t_}"));
    }
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(id, title, &col_refs);

    // One flattened batch: the binary32 baseline (t = 1.25) followed by the
    // (stepsize × seed) grid — so the deterministic baseline doesn't hold a
    // core alone while the rest of the pool idles.
    let mut grid: Vec<(Grid, PolicyMap, f64)> =
        vec![(FpFormat::BINARY32.into(), PolicyMap::uniform(Scheme::rn()), 1.25)];
    for &t_ in &ts {
        grid.push((b8, schemes, t_));
    }
    let labels: Vec<String> = cols[1..].to_vec();
    let seeds_per: Vec<usize> =
        grid.iter().map(|(_, sch, _)| seeds_for(sch, ctx.seeds)).collect();
    let (mut all, mut sems, notes) = curves_flat(
        id,
        &labels,
        &seeds_per,
        ctx.mlr_epochs,
        ctx,
        &|ci, s| {
            let (fmt, sch, t_) = grid[ci];
            mlr_cell(&setup, fmt, sch, GradModel::RoundAfterOp, t_, ctx.mlr_epochs, s, ctx.escape)
        },
        None,
    );
    for n in notes {
        table.note(n);
    }
    let baseline = all.remove(0);
    sems.remove(0); // the deterministic baseline carries no band
    let curves = all;
    for k in 0..ctx.mlr_epochs {
        let mut row: Vec<Cell> = vec![k.into(), baseline[k].into()];
        for c in &curves {
            row.push(c[k].into());
        }
        table.row(row);
    }
    for (i, label) in labels.iter().enumerate().skip(1) {
        if seeds_per[i] > 1 {
            table.band(label.clone(), sems[i - 1].clone());
        }
    }
    // Epochs-to-baseline metric (paper: 84 epochs at t=1 for fig5b).
    let target = *baseline.last().unwrap();
    for (i, &t_) in ts.iter().enumerate() {
        let e = first_at_or_below(&curves[i], target)
            .map(|v| v.to_string())
            .unwrap_or_else(|| "-".into());
        table.note(format!("t={t_}: epochs to reach baseline final error {target:.3}: {e}"));
    }
    table
}

// ------------------------------------------------------------------ fig6 --

struct NnSetup {
    nn: TwoLayerNn,
    test: Dataset,
    x0: Vec<f64>,
}

fn nn_setup(ctx: &ExpCtx) -> NnSetup {
    // 3-vs-8 binary task (paper §5.3). Generate enough samples that the
    // filtered subset reaches the requested sizes (2 of 10 classes survive).
    let splits = load_or_synth(
        ctx.mnist_dir.as_deref(),
        ctx.nn_train * 5,
        ctx.nn_test * 5,
        ctx.side,
        77,
    );
    let train = splits.train.filter_classes(&[3, 8]);
    let test = splits.test.filter_classes(&[3, 8]);
    let nn = TwoLayerNn::new(train, 100);
    let x0 = nn.init_params(0);
    NnSetup { nn, test, x0 }
}

/// Fan an NN (config × seed) grid out through [`curves_flat`], returning
/// the per-config mean test-error series, their pointwise standard
/// errors, plus the sweep's fault notes. The degrade fault policy falls
/// back to the binary64 + RN master.
fn nn_curves(
    exp: &str,
    setup: &NnSetup,
    cfgs: &[(String, Grid, PolicyMap)],
    t_step: f64,
    epochs: usize,
    ctx: &ExpCtx,
) -> (Vec<Vec<f64>>, Vec<Vec<f64>>, Vec<String>) {
    let nn_run = |grid: Grid, sch: PolicyMap, s: u64| {
        let mut cfg = GdConfig::new(grid, sch, t_step, epochs);
        cfg.seed = s;
        cfg.escape = ctx.escape;
        let mut e = GdEngine::new(cfg, &setup.nn, &setup.x0);
        let metric = |x: &[f64]| setup.nn.test_error(x, &setup.test);
        e.run(Some(&metric)).metric_series()
    };
    let labels: Vec<String> = cfgs.iter().map(|(n, _, _)| n.clone()).collect();
    let seeds_per: Vec<usize> =
        cfgs.iter().map(|(_, _, sch)| seeds_for(sch, ctx.seeds)).collect();
    let master = |_ci: usize, s: u64| {
        nn_run(FpFormat::BINARY64.into(), PolicyMap::uniform(Scheme::rn()), s)
    };
    curves_flat(
        exp,
        &labels,
        &seeds_per,
        epochs,
        ctx,
        &|ci, s| {
            let (_, fmt, sch) = &cfgs[ci];
            nn_run(*fmt, *sch, s)
        },
        Some(&master),
    )
}

/// Paper Figure 6a: NN scheme sweep for (8a)+(8b).
pub(crate) fn fig6a(ctx: &ExpCtx) -> Table {
    let setup = nn_setup(ctx);
    let t_step = 0.09375;
    let b8: Grid = FpFormat::BINARY8.into();
    let sr = Scheme::sr();
    let cfgs: Vec<(String, Grid, PolicyMap)> = vec![
        ("binary32".into(), FpFormat::BINARY32.into(), PolicyMap::uniform(Scheme::rn())),
        ("RN".into(), b8, PolicyMap::uniform(Scheme::rn())),
        ("SR".into(), b8, PolicyMap::sites(sr, sr, sr)),
        ("SR_eps(0.2)".into(), b8, PolicyMap::sites(Scheme::sr_eps(0.2), Scheme::sr_eps(0.2), sr)),
        ("SR_eps(0.4)".into(), b8, PolicyMap::sites(Scheme::sr_eps(0.4), Scheme::sr_eps(0.4), sr)),
    ];
    let mut t = Table::new(
        "fig6a",
        "NN (3 vs 8) test error, binary8, t=0.09375 (paper Fig. 6a)",
        &["epoch", "binary32", "RN", "SR", "SR_eps(0.2)", "SR_eps(0.4)"],
    );
    let (curves, sems, notes) = nn_curves("fig6a", &setup, &cfgs, t_step, ctx.nn_epochs, ctx);
    for k in 0..ctx.nn_epochs {
        let mut row: Vec<Cell> = vec![k.into()];
        for c in &curves {
            row.push(c[k].into());
        }
        t.row(row);
    }
    for (i, (name, _, sch)) in cfgs.iter().enumerate() {
        if seeds_for(sch, ctx.seeds) > 1 {
            t.band(name.clone(), sems[i].clone());
        }
    }
    for n in notes {
        t.note(n);
    }
    t.note(format!("seeds={} (paper: 20)", ctx.seeds));
    t
}

/// Paper Figure 6b: NN with signed-SRε variants on step (8c).
pub(crate) fn fig6b(ctx: &ExpCtx) -> Table {
    let setup = nn_setup(ctx);
    let t_step = 0.09375;
    let b8: Grid = FpFormat::BINARY8.into();
    let sr = Scheme::sr();
    let cfgs: Vec<(String, Grid, PolicyMap)> = vec![
        ("binary32".into(), FpFormat::BINARY32.into(), PolicyMap::uniform(Scheme::rn())),
        ("SR|SR".into(), b8, PolicyMap::sites(sr, sr, sr)),
        ("SR_eps(0.1)|signed(0.05)".into(), b8, PolicyMap::sites(Scheme::sr_eps(0.1), Scheme::sr_eps(0.1), Scheme::signed_sr_eps(0.05))),
        ("SR|signed(0.1)".into(), b8, PolicyMap::sites(sr, sr, Scheme::signed_sr_eps(0.1))),
        ("SR|signed(0.2)".into(), b8, PolicyMap::sites(sr, sr, Scheme::signed_sr_eps(0.2))),
    ];
    let names: Vec<&str> = ["epoch", "binary32", "SR|SR", "SR_eps(0.1)|signed(0.05)", "SR|signed(0.1)", "SR|signed(0.2)"].to_vec();
    let mut t = Table::new(
        "fig6b",
        "NN (3 vs 8): signed-SReps for (8c) (paper Fig. 6b)",
        &names,
    );
    let (curves, sems, notes) = nn_curves("fig6b", &setup, &cfgs, t_step, ctx.nn_epochs, ctx);
    for k in 0..ctx.nn_epochs {
        let mut row: Vec<Cell> = vec![k.into()];
        for c in &curves {
            row.push(c[k].into());
        }
        t.row(row);
    }
    for (i, (name, _, sch)) in cfgs.iter().enumerate() {
        if seeds_for(sch, ctx.seeds) > 1 {
            t.band(name.clone(), sems[i].clone());
        }
    }
    for n in notes {
        t.note(n);
    }
    let target = *curves[0].last().unwrap();
    for (i, (name, _, _)) in cfgs.iter().enumerate().skip(1) {
        let e = first_at_or_below(&curves[i], target)
            .map(|v| v.to_string())
            .unwrap_or_else(|| "-".into());
        t.note(format!("{name}: epochs to baseline final error {target:.3}: {e}"));
    }
    t.note("paper: signed combo reaches the binary32 50-epoch error in ~25 epochs; eps=0.2 overshoots");
    t
}

// ---------------------------------------------------------------- table1 --

/// Numerically verify each row of the paper's Table 1 on a live Setting-I
/// run: check the precondition gates and the claimed conclusion.
pub(crate) fn table1(ctx: &ExpCtx) -> Table {
    let n = ctx.quad_n.min(200);
    let steps = ctx.quad_steps.min(500);
    let (p, x0, t_step) = Quadratic::setting1(n);
    let lip = p.lipschitz().unwrap();
    let fmt = FpFormat::BFLOAT16;
    let u = fmt.unit_roundoff();
    let c = p.sigma1_constant().unwrap();
    let a = 0.25;

    let mut t = Table::new(
        "table1",
        "Numerical verification of the theory (paper Table 1)",
        &["result", "precondition", "holds", "conclusion", "verified"],
    );

    // Row: u-gate and t-gate shared by Lemma 4 / Thms 5–6.
    let u_ok = u <= theory::u_upper_bound(a, c);
    let t_ok = t_step <= theory::t_upper_bound(lip, u);
    t.row(vec![
        "gates".into(),
        format!("u<=a/(c+4a+4)={:.2e}, t<=1/(L(1+2u)^2)={:.2e}", theory::u_upper_bound(a, c), theory::t_upper_bound(lip, u)).into(),
        ((u_ok && t_ok) as i64).into(),
        "-".into(),
        "-".into(),
    ]);

    // Lemma 4 (monotonicity, general rounding): run RN and check f decreasing
    // while the gradient gate (24) holds.
    {
        let mut cfg = GdConfig::new(fmt, PolicyMap::uniform(Scheme::rn()), t_step, steps);
        cfg.seed = 0;
        let tr = GdEngine::new(cfg, &p, &x0).run(None);
        let gate = theory::lemma4_grad_gate(a, u, n, c);
        let mut ok = true;
        let mut checked = 0;
        for w in tr.records.windows(2) {
            if w[0].grad_norm >= gate {
                checked += 1;
                if w[1].f > w[0].f * (1.0 + 1e-12) {
                    ok = false;
                }
            }
        }
        t.row(vec![
            "Lemma 4 (monotonicity, RN)".into(),
            format!("||grad|| >= {gate:.2e} ({checked} steps)").into(),
            1i64.into(),
            "f non-increasing".into(),
            (ok as i64).into(),
        ]);
    }

    // Theorem 6(i) / Corollary 7: these are *Scenario 1* results — they need
    // condition (11) (updates large relative to the neighbor gaps), which
    // requires a stepsize near the theorem's gate, NOT the paper's tiny
    // fig-3a stepsize (that regime is Scenario 2, where the bound is
    // vacuous). Verify at t = 1/(L(1+2u)²).
    let t_big = theory::t_upper_bound(lip, u);
    let mut verify_rate = |name: &str, sch: PolicyMap| {
        let runner = |s: u64| {
            let mut cfg = GdConfig::new(fmt, sch, t_big, steps);
            cfg.seed = s;
            GdEngine::new(cfg, &p, &x0).run(None)
        };
        let traces: Vec<Trace> = run_indexed(ctx.jobs, ctx.seeds, |s| runner(s as u64));
        // χ over ALL traces (paper: max_j ‖x̂⁽ʲ⁾−x*‖ on the compared runs).
        let chi = traces
            .iter()
            .flat_map(|tr| tr.records.iter().map(|r| r.dist_to_opt))
            .fold(0.0, f64::max);
        // Gradient gate (33) held fraction.
        let gate = theory::theorem6_grad_gate(a, u, n, c);
        let total: usize = traces.iter().map(|tr| tr.records.len()).sum();
        let held: usize = traces
            .iter()
            .flat_map(|tr| tr.records.iter())
            .filter(|r| r.grad_norm >= gate)
            .count();
        let mean: Vec<f64> = {
            let series: Vec<Vec<f64>> = traces.iter().map(|t| t.objective_series()).collect();
            crate::gd::trace::mean_series(&series)
        };
        let mut ok = true;
        for (k, &fk) in mean.iter().enumerate() {
            // Only check while the gate held on average up to k.
            if mean[..=k].len() < 2 {
                continue;
            }
            if fk > theory::theorem6_bound(lip, t_big, k, chi, a) * (1.0 + 1e-9) {
                ok = false;
                break;
            }
        }
        t.row(vec![
            name.into(),
            format!("t={t_big:.3e}, chi={chi:.3}, gate held {held}/{total}").into(),
            ((held * 10 >= total * 9) as i64).into(),
            "E[f-f*] <= 2L chi^2/(4+Ltk(1-2a))".into(),
            (ok as i64).into(),
        ]);
    };
    verify_rate("Theorem 6(i) (SR rate)", PolicyMap::uniform(Scheme::sr()));
    verify_rate(
        "Corollary 7 (SR_eps rate)",
        PolicyMap::sites(Scheme::sr(), Scheme::sr_eps(0.4), Scheme::sr()),
    );

    // Propositions 9/11 (stagnation scenario): compare the SR and signed-SRε
    // average monotonicity on the Figure-2 problem.
    {
        let p2 = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        let avg_drop = |sub: Scheme| -> f64 {
            let drops = run_indexed(ctx.jobs, ctx.seeds, |s| {
                let sch = PolicyMap { grad: Scheme::sr(), mul: Scheme::sr(), sub };
                let mut cfg = GdConfig::new(FpFormat::BINARY8, sch, 0.05, 100);
                cfg.seed = s as u64;
                let tr = GdEngine::new(cfg, &p2, &[1.0]).run(None);
                tr.records[0].f - tr.final_f()
            });
            drops.iter().sum::<f64>() / ctx.seeds as f64
        };
        let d_sr = avg_drop(Scheme::sr());
        let d_sg = avg_drop(Scheme::signed_sr_eps(0.25));
        t.row(vec![
            "Prop 9 vs Prop 11 (stagnation)".into(),
            "binary8, f=(x-1024)^2, eps=0.25<=0.5".into(),
            1i64.into(),
            "E[f drop] signed >= SR".into(),
            ((d_sg >= d_sr * 0.99) as i64).into(),
        ]);
    }

    t.note(format!("verified on Setting I with n={n}, steps={steps}, seeds={}", ctx.seeds));
    t
}

// ----------------------------------------------------------------- plfp --
//
// The fixed-point / PL-inequality experiment family (companion paper
// arXiv:2301.09511): the same GD harness, schemes and scheduler cells as
// the floating-point figures, but on uniform Qm.n grids, compared against
// the PL convergence bounds of `gd::theory`.

/// The `plfp1`/`plfp2` working grid: signed Q3.8 / Q4.8 (δ = 2^{−8}).
const PLFP_GRID: FixedPoint = FixedPoint::q(3, 8);

/// The quadratic the `plfp*` family descends: a diagonal spectrum ramping
/// over `[0.05, 1]` (L = 1, μ = 0.05 — strongly convex, hence PL), with
/// `x* = 0.5·1` and `x⁰ = 2·1` exact grid points of every Q3.f sweep grid
/// (f ≥ 1), and stepsize `t = 0.5 ≤ 1/L`.
fn plfp_quadratic(n: usize) -> (Quadratic, Vec<f64>, f64) {
    let n = n.max(2);
    let diag: Vec<f64> =
        (0..n).map(|i| 0.05 + 0.95 * i as f64 / (n - 1) as f64).collect();
    let p = Quadratic::diagonal(diag, vec![0.5; n]);
    (p, vec![2.0; n], 0.5)
}

/// plfp1: GD on the PL quadratic over the fixed-point Q3.8 grid — RN vs SR
/// vs SR+signed-SRε against the exact-arithmetic PL bound and the
/// fixed-point-SR PL bound (the companion paper's headline comparison).
pub(crate) fn plfp1(ctx: &ExpCtx) -> Table {
    let n = ctx.quad_n.min(200);
    let steps = ctx.quad_steps.min(1500);
    let (p, x0, t_step) = plfp_quadratic(n);
    let n = p.dim(); // plfp_quadratic clamps tiny n up to 2
    let lip = p.lipschitz().unwrap();
    let mu = p.pl_constant().unwrap();
    let gap0 = p.objective(&x0); // f(x*) = 0
    let fx = PLFP_GRID;

    let rn_pol = PolicyMap::uniform(Scheme::rn());
    let sr_pol = PolicyMap::uniform(Scheme::sr());
    let sg_pol = PolicyMap::sites(Scheme::sr(), Scheme::sr(), Scheme::signed_sr_eps(0.25));
    let cfgs = [rn_pol, sr_pol, sg_pol];
    let labels: Vec<String> =
        ["Q3.8_RN", "Q3.8_SR", "Q3.8_SR|signed(0.25)"].map(String::from).to_vec();
    let seeds_per: Vec<usize> = cfgs.iter().map(|sch| seeds_for(sch, ctx.seeds)).collect();
    let (curves, sems, notes) = curves_flat(
        "plfp1",
        &labels,
        &seeds_per,
        steps,
        ctx,
        &|ci, s| {
            let mut cfg = GdConfig::new(fx, cfgs[ci], t_step, steps);
            cfg.seed = s;
            cfg.escape = ctx.escape;
            GdEngine::new(cfg, &p, &x0).run(None).objective_series()
        },
        None,
    );

    let mut t = Table::new(
        "plfp1",
        "PL quadratic on fixed-point Q3.8: RN vs SR vs signed-SReps vs PL bounds (arXiv:2301.09511)",
        &["k", "pl_exact_bound", "pl_sr_bound", "Q3.8_RN", "Q3.8_SR", "Q3.8_SR|signed(0.25)"],
    );
    let stride = (steps / 200).max(1);
    for k in (0..steps).step_by(stride) {
        t.row(vec![
            k.into(),
            theory::pl_exact_bound(mu, lip, t_step, k, gap0).into(),
            theory::pl_fixed_sr_bound(mu, lip, t_step, k, gap0, fx.delta(), n).into(),
            curves[0][k].into(),
            curves[1][k].into(),
            curves[2][k].into(),
        ]);
    }
    // Stride the SEM series exactly like the rows so bands stay aligned.
    for (i, label) in labels.iter().enumerate() {
        if seeds_per[i] > 1 {
            let strided: Vec<f64> = (0..steps).step_by(stride).map(|k| sems[i][k]).collect();
            t.band(label.clone(), strided);
        }
    }
    t.note(format!(
        "theory: SR limiting accuracy {:.3e}, worst-case RN stagnation gap {:.3e} (delta={:.3e}, mu={mu}, L={lip}, t={t_step})",
        theory::pl_fixed_sr_limit(mu, lip, t_step, fx.delta(), n),
        theory::pl_rn_stagnation_gap(mu, t_step, fx.delta(), n),
        fx.delta(),
    ));
    for n in notes {
        t.note(n);
    }
    t.note(format!("seeds={} (companion paper: 20)", ctx.seeds));
    t
}

/// plfp2: MLR training on a fixed-point Q4.8 grid (range ±16 holds the
/// softmax sums, δ = 2^{−8}): RN stalls, SR tracks the binary32 baseline,
/// signed-SRε on (8c) converges fastest — the companion paper's learning
/// experiment transplanted onto the uniform grid.
pub(crate) fn plfp2(ctx: &ExpCtx) -> Table {
    let setup = mlr_setup(ctx);
    let t_step = 0.5;
    let q: Grid = FixedPoint::q(4, 8).into();
    let sr = Scheme::sr();
    let cfgs: Vec<(String, Grid, PolicyMap)> = vec![
        ("binary32".into(), FpFormat::BINARY32.into(), PolicyMap::uniform(Scheme::rn())),
        ("Q4.8_RN".into(), q, PolicyMap::uniform(Scheme::rn())),
        ("Q4.8_SR".into(), q, PolicyMap::sites(sr, sr, sr)),
        (
            "Q4.8_SR|signed(0.1)".into(),
            q,
            PolicyMap::sites(sr, sr, Scheme::signed_sr_eps(0.1)),
        ),
    ];
    let mut t = learning_table(
        "plfp2",
        "MLR test error on fixed-point Q4.8, t=0.5 (companion paper arXiv:2301.09511)",
        &setup,
        cfgs,
        t_step,
        ctx.mlr_epochs,
        ctx,
    );
    t.note("fixed-point analogue of fig4a/fig4b: uniform grid, saturating arithmetic");
    t
}

/// plfp3: the stagnation-threshold sweep over `frac_bits` — for each Q3.f
/// grid, the final objective gap under RN (one deterministic run) and SR
/// (mean over seeds), against the theory columns: the SR limiting accuracy
/// and the worst-case RN stagnation gap, both O(δ²) but separated by the
/// 1/(Lt²μ·…) factor that makes SR win on every grid.
pub(crate) fn plfp3(ctx: &ExpCtx) -> Table {
    let n = ctx.quad_n.min(50);
    let steps = ctx.quad_steps.min(800);
    let (p, x0, t_step) = plfp_quadratic(n);
    let n = p.dim();
    let lip = p.lipschitz().unwrap();
    let mu = p.pl_constant().unwrap();
    let fracs: &[u32] = &[4, 6, 8, 10];

    // One flattened batch over (frac_bits × {RN, SR-seed}) cells.
    let rn_pol = PolicyMap::uniform(Scheme::rn());
    let sr_pol = PolicyMap::uniform(Scheme::sr());
    let mut grids: Vec<(FixedPoint, PolicyMap)> = Vec::new();
    for &f in fracs {
        grids.push((FixedPoint::q(3, f), rn_pol));
        grids.push((FixedPoint::q(3, f), sr_pol));
    }
    let labels: Vec<String> = grids
        .iter()
        .map(|(fx, sch)| {
            let mode = if sch.is_stochastic() { "SR" } else { "RN" };
            format!("Q3.{}_{mode}", fx.frac_bits)
        })
        .collect();
    let seeds_per: Vec<usize> =
        grids.iter().map(|(_, sch)| seeds_for(sch, ctx.seeds)).collect();
    let (finals, final_sems, notes) = curves_flat(
        "plfp3",
        &labels,
        &seeds_per,
        1,
        ctx,
        &|ci, s| {
            let (fx, sch) = grids[ci];
            let mut cfg = GdConfig::new(fx, sch, t_step, steps);
            cfg.seed = s;
            cfg.escape = ctx.escape;
            let mut e = GdEngine::new(cfg, &p, &x0);
            e.run(None);
            vec![p.objective(&e.x)] // the settled gap (f* = 0)
        },
        None,
    );

    let mut t = Table::new(
        "plfp3",
        "Stagnation-threshold sweep over frac_bits: final gap, RN vs SR vs theory (Q3.f grids)",
        &[
            "frac_bits",
            "delta",
            "rn_final_gap",
            "sr_final_gap",
            "sr_limit_theory",
            "rn_stagnation_theory",
        ],
    );
    for (i, &f) in fracs.iter().enumerate() {
        let fx = FixedPoint::q(3, f);
        let d = fx.delta();
        t.row(vec![
            (f as usize).into(),
            d.into(),
            finals[2 * i][0].into(),
            finals[2 * i + 1][0].into(),
            theory::pl_fixed_sr_limit(mu, lip, t_step, d, n).into(),
            theory::pl_rn_stagnation_gap(mu, t_step, d, n).into(),
        ]);
    }
    if ctx.seeds > 1 {
        // One SR cell group per frac_bits row; each contributes its single
        // settled-gap SEM to the seed-averaged column.
        let band: Vec<f64> = (0..fracs.len()).map(|i| final_sems[2 * i + 1][0]).collect();
        t.band("sr_final_gap", band);
    }
    if let Some(fbits) = theory::frac_bits_for_target_gap(mu, lip, t_step, n, 1e-6) {
        t.note(format!(
            "smallest frac_bits with SR limiting accuracy <= 1e-6: {fbits} (theory::frac_bits_for_target_gap)"
        ));
    }
    for note in notes {
        t.note(note);
    }
    t.note(format!("n={n}, steps={steps}, seeds={} per stochastic cell", ctx.seeds));
    t
}

// ------------------------------------------------------------------ opt --

/// The optimizer-zoo quadratic: diagonal spectrum on [0.02, 0.2] with the
/// optimum at `x* = 1100·1` — deliberately *off-grid* for bfloat16 and
/// binary8 (their spacing in [1024, 2048) is 8 and 256) — and the start
/// `x0 = 1280·1` exactly representable on every grid the family sweeps.
/// In this regime every RN lane stagnates far from the optimum from step
/// zero (each proposed update is below the half-ulp), while SR keeps the
/// iterate and the optimizer state moving in expectation.
fn opt_quadratic(n: usize) -> (Quadratic, Vec<f64>) {
    let n = n.max(2);
    let diag: Vec<f64> = (0..n).map(|i| 0.02 + 0.18 * i as f64 / (n - 1) as f64).collect();
    (Quadratic::diagonal(diag, vec![1100.0; n]), vec![1280.0; n])
}

/// Shared builder for the `opt1`–`opt3` tables: one stateful optimizer and
/// a list of (label, grid, policy) lanes, fanned out through
/// [`curves_flat`] (journal resume, retries and `--jobs` sharding for
/// free). The last deterministic lane is re-run locally to surface its
/// optimizer-state [`crate::fp::RunHealth`] counters as a table note.
fn opt_family(
    id: &str,
    title: &str,
    optimizer: OptimizerSpec,
    t_step: f64,
    cfgs: Vec<(String, Grid, PolicyMap)>,
    ctx: &ExpCtx,
) -> Table {
    let n = ctx.quad_n.min(50);
    let steps = ctx.quad_steps.min(500);
    let (p, x0) = opt_quadratic(n);
    let labels: Vec<String> = cfgs.iter().map(|(l, _, _)| l.clone()).collect();
    let seeds_per: Vec<usize> =
        cfgs.iter().map(|(_, _, sch)| seeds_for(sch, ctx.seeds)).collect();
    let (curves, sems, notes) = curves_flat(
        id,
        &labels,
        &seeds_per,
        steps,
        ctx,
        &|ci, s| {
            let (_, grid, sch) = &cfgs[ci];
            let mut cfg = GdConfig::new(*grid, *sch, t_step, steps);
            cfg.seed = s;
            cfg.escape = ctx.escape;
            cfg.optimizer = optimizer;
            GdEngine::new(cfg, &p, &x0).run(None).objective_series()
        },
        None,
    );
    let mut cols = vec!["k".to_string()];
    cols.extend(labels.iter().cloned());
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(id, title, &col_refs);
    let stride = (steps / 200).max(1);
    for k in (0..steps).step_by(stride) {
        let mut row: Vec<Cell> = vec![k.into()];
        for c in &curves {
            row.push(c[k].into());
        }
        t.row(row);
    }
    for (i, label) in labels.iter().enumerate() {
        if seeds_per[i] > 1 {
            let strided: Vec<f64> = (0..steps).step_by(stride).map(|k| sems[i][k]).collect();
            t.band(label.clone(), strided);
        }
    }
    // Optimizer-state health of the last deterministic lane: the same
    // counters every scheduled cell accumulates, re-derived locally (an RN
    // lane is seed-free, so this costs one deterministic pass).
    if let Some((label, grid, sch)) = cfgs.iter().rev().find(|(_, _, sch)| !sch.is_stochastic()) {
        let mut cfg = GdConfig::new(*grid, *sch, t_step, steps);
        cfg.escape = ctx.escape;
        cfg.optimizer = optimizer;
        let mut e = GdEngine::new(cfg, &p, &x0);
        e.run(None);
        t.note(format!("{label} health: {}", e.health.summary()));
    }
    for note in notes {
        t.note(note);
    }
    t.note(format!(
        "optimizer={}, n={n}, steps={steps}, seeds={}",
        optimizer.canon(),
        ctx.seeds
    ));
    t
}

/// `opt1` — heavy-ball momentum(0.9) on bfloat16: the stagnation-vs-scheme
/// comparison of Figure 2 re-run with a state-carrying optimizer, where
/// the momentum buffer `m` is a second rounding site (the
/// "stochastic rounding 2.0" regime, arXiv:2410.10517). binary32 + RN is
/// the convergent baseline; on bfloat16 RN freezes both `x` and `m` while
/// SR (and SR with signed-SRε on the (8c) subtraction) escape.
pub(crate) fn opt1(ctx: &ExpCtx) -> Table {
    let sr = Scheme::sr();
    let bf: Grid = FpFormat::BFLOAT16.into();
    let cfgs: Vec<(String, Grid, PolicyMap)> = vec![
        ("binary32_RN".into(), FpFormat::BINARY32.into(), PolicyMap::uniform(Scheme::rn())),
        ("bf16_RN".into(), bf, PolicyMap::uniform(Scheme::rn())),
        ("bf16_SR".into(), bf, PolicyMap::uniform(sr)),
        (
            "bf16_SR|signed(0.25)".into(),
            bf,
            PolicyMap::sites(sr, sr, Scheme::signed_sr_eps(0.25)),
        ),
    ];
    opt_family(
        "opt1",
        "Momentum(0.9) on bfloat16: stagnation vs rounding scheme with a rounded state tensor m",
        OptimizerSpec::Momentum { beta: 0.9 },
        0.05,
        cfgs,
        ctx,
    )
}

/// `opt2` — Adam on bfloat16, same lanes as `opt1`. Adam adds a second
/// failure mode: the `(1-β₂)·ĝ²` increment to the second moment `v` sits
/// below bfloat16's half-ulp in relative terms (0.001 < u/2 ≈ 0.002), so
/// RN freezes `v` outright while SR keeps it unbiased.
pub(crate) fn opt2(ctx: &ExpCtx) -> Table {
    let sr = Scheme::sr();
    let bf: Grid = FpFormat::BFLOAT16.into();
    let cfgs: Vec<(String, Grid, PolicyMap)> = vec![
        ("binary32_RN".into(), FpFormat::BINARY32.into(), PolicyMap::uniform(Scheme::rn())),
        ("bf16_RN".into(), bf, PolicyMap::uniform(Scheme::rn())),
        ("bf16_SR".into(), bf, PolicyMap::uniform(sr)),
        (
            "bf16_SR|signed(0.25)".into(),
            bf,
            PolicyMap::sites(sr, sr, Scheme::signed_sr_eps(0.25)),
        ),
    ];
    opt_family(
        "opt2",
        "Adam on bfloat16: stagnation vs rounding scheme with rounded state tensors m and v",
        OptimizerSpec::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
        1.0,
        cfgs,
        ctx,
    )
}

/// `opt3` — master-weights ablation on binary8 momentum(0.9): the same
/// stagnating run under four [`PolicyMap`] bindings — uniform RN, uniform
/// SR, SR with the weights bound to an RN @ binary64 master copy (mixed
/// precision's classic fix: updates land exactly, only the working grid is
/// coarse), and SR with the momentum buffer bound to RN @ binary32.
pub(crate) fn opt3(ctx: &ExpCtx) -> Table {
    let sr = Scheme::sr();
    let b8: Grid = FpFormat::BINARY8.into();
    let cfgs: Vec<(String, Grid, PolicyMap)> = vec![
        ("b8_RN".into(), b8, PolicyMap::uniform(Scheme::rn())),
        ("b8_SR".into(), b8, PolicyMap::uniform(sr)),
        (
            "b8_SR+w=rn@binary64".into(),
            b8,
            PolicyMap::uniform(sr)
                .with_weights(TensorPolicy::new(Scheme::rn()).on(FpFormat::BINARY64)),
        ),
        (
            "b8_SR+m=rn@binary32".into(),
            b8,
            PolicyMap::uniform(sr).with_m(TensorPolicy::new(Scheme::rn()).on(FpFormat::BINARY32)),
        ),
    ];
    opt_family(
        "opt3",
        "Master weights vs fully-low-precision on binary8 momentum(0.9): per-tensor policy bindings",
        OptimizerSpec::Momentum { beta: 0.9 },
        0.05,
        cfgs,
        ctx,
    )
}

/// Shared learning-figure table builder (named-config × epochs grid),
/// fanned out through [`curves_flat`]. The degrade fault policy falls a
/// failed cell back to the binary64 + RN master (exact-arithmetic
/// reference) of the same seed.
#[allow(clippy::too_many_arguments)]
fn learning_table(
    id: &str,
    title: &str,
    setup: &LearnSetup,
    cfgs: Vec<(String, Grid, PolicyMap)>,
    t_step: f64,
    epochs: usize,
    ctx: &ExpCtx,
) -> Table {
    let mut cols = vec!["epoch".to_string()];
    cols.extend(cfgs.iter().map(|(n, _, _)| n.clone()));
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(id, title, &col_refs);
    let labels: Vec<String> = cfgs.iter().map(|(n, _, _)| n.clone()).collect();
    let seeds_per: Vec<usize> =
        cfgs.iter().map(|(_, _, sch)| seeds_for(sch, ctx.seeds)).collect();
    let master = |_ci: usize, s: u64| {
        let exact: Grid = FpFormat::BINARY64.into();
        let rn = PolicyMap::uniform(Scheme::rn());
        mlr_cell(setup, exact, rn, GradModel::RoundAfterOp, t_step, epochs, s, ctx.escape)
    };
    let (curves, sems, notes) = curves_flat(
        id,
        &labels,
        &seeds_per,
        epochs,
        ctx,
        &|ci, s| {
            let (_, fmt, sch) = &cfgs[ci];
            mlr_cell(setup, *fmt, *sch, GradModel::RoundAfterOp, t_step, epochs, s, ctx.escape)
        },
        Some(&master),
    );
    for k in 0..epochs {
        let mut row: Vec<Cell> = vec![k.into()];
        for c in &curves {
            row.push(c[k].into());
        }
        t.row(row);
    }
    for (i, label) in labels.iter().enumerate() {
        if seeds_per[i] > 1 {
            t.band(label.clone(), sems[i].clone());
        }
    }
    for n in notes {
        t.note(n);
    }
    t.note(format!("seeds={} (paper: 20)", ctx.seeds));
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn list_experiments_mirrors_registry() {
        let listed = list_experiments();
        assert_eq!(listed.len(), registry::REGISTRY.len());
        assert!(listed.iter().any(|(id, _)| *id == "fig3a"));
    }

    #[test]
    fn table2_matches_paper() {
        let t = table2();
        assert_eq!(t.rows.len(), 5);
        let csv = t.to_csv();
        assert!(csv.contains("binary8,0.125"));
        assert!(csv.contains("bfloat16"));
    }

    #[test]
    fn fig1_sr_expectation_is_identity() {
        let t = fig1();
        // Column 2 (SR) equals column 0 (y) — zero bias.
        for r in &t.rows {
            let y = match r[0] {
                Cell::Num(v) => v,
                _ => unreachable!(),
            };
            let sr = match r[2] {
                Cell::Num(v) => v,
                _ => unreachable!(),
            };
            assert!((sr - y).abs() < 1e-12);
        }
    }

    #[test]
    fn fig2_stagnates() {
        let t = fig2();
        assert!(t.notes.iter().any(|n| n.contains("stagnates")), "{:?}", t.notes);
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run_experiment("nope", &ExpCtx::quick()).is_err());
    }

    /// The journal digest covers exactly the knobs that shape a cell's
    /// output: scheduling/selection knobs (jobs, seeds, fault policy) leave
    /// it unchanged, cell-shaping knobs (sizes, escape guard) change it.
    #[test]
    fn config_digest_tracks_cell_shaping_knobs_only() {
        let a = ExpCtx::quick();
        let mut b = ExpCtx::quick();
        b.jobs = 7;
        b.seeds = 9;
        b.lanes = 16;
        b.max_retries = 3;
        b.fault_policy = FaultPolicy::SkipCell;
        assert_eq!(a.config_digest(), b.config_digest());
        let mut c = ExpCtx::quick();
        c.quad_steps += 1;
        assert_ne!(a.config_digest(), c.config_digest());
        let mut d = ExpCtx::quick();
        d.escape = Some(0.0);
        assert_ne!(a.config_digest(), d.config_digest());
        let mut e = ExpCtx::quick();
        e.escape = Some(1e9);
        assert_ne!(d.config_digest(), e.config_digest());
    }

    /// A cell that panics under the fail-fast default aborts the experiment
    /// with a clean error (not a process abort) carrying the panic text.
    #[test]
    fn fail_fast_surfaces_as_run_experiment_error() {
        let mut ctx = ExpCtx::quick();
        ctx.jobs = 1;
        ctx.out_dir = std::env::temp_dir()
            .join(format!("lpgd_ff_{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        ctx.injector = Some(Arc::new(FaultInjector::panic_at("plfp1", 0, u32::MAX)));
        let err = run_experiment("plfp1", &ctx).unwrap_err().to_string();
        assert!(err.contains("aborted") && err.contains("cell 0"), "{err}");
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }

    /// plfp1 at smoke scale: SR tracks the PL-SR bound, RN stagnates above
    /// the SR curve, and the exact bound under-runs the fixed-point runs.
    #[test]
    fn quick_plfp1_shapes_hold() {
        let ctx = ExpCtx::quick();
        let t = plfp1(&ctx);
        assert!(t.rows.len() > 10);
        let last = t.rows.last().unwrap();
        let get = |i: usize| match last[i] {
            Cell::Num(v) => v,
            _ => f64::NAN,
        };
        let (sr_bound, rn, sr) = (get(2), get(3), get(4));
        assert!(sr.is_finite() && rn.is_finite());
        // The final SR mean respects the fixed-point PL bound.
        assert!(sr <= sr_bound * 1.05, "sr={sr} bound={sr_bound}");
        // RN stagnates well above SR on the uniform grid.
        assert!(rn > sr, "rn={rn} sr={sr}");
    }

    /// plfp3 at smoke scale: finer grids lower both final gaps, and SR
    /// settles below the worst-case RN stagnation level on every grid.
    #[test]
    fn quick_plfp3_sweep_is_monotone() {
        let ctx = ExpCtx::quick();
        let t = plfp3(&ctx);
        assert_eq!(t.rows.len(), 4);
        let num = |r: &Vec<Cell>, i: usize| match r[i] {
            Cell::Num(v) => v,
            _ => f64::NAN,
        };
        for r in &t.rows {
            let (sr_final, sr_limit, rn_theory) = (num(r, 3), num(r, 4), num(r, 5));
            assert!(sr_final.is_finite());
            assert!(sr_limit < rn_theory, "theory separation must hold");
        }
        // The theory columns shrink 16x per 2 extra fractional bits.
        let l0 = num(&t.rows[0], 4);
        let l1 = num(&t.rows[1], 4);
        assert!((l0 / l1 - 16.0).abs() < 1e-6, "{l0} vs {l1}");
    }

    /// opt1/opt2 at smoke scale: with a state-carrying optimizer on
    /// bfloat16, RN stagnates far above the SR lane (the optimizer state is
    /// a second stagnation site) and the RN lane's health note records the
    /// stalled steps.
    #[test]
    fn quick_opt_momentum_and_adam_stagnate_under_rn() {
        let ctx = ExpCtx::quick();
        for t in [opt1(&ctx), opt2(&ctx)] {
            let last = t.rows.last().unwrap();
            let get = |i: usize| match last[i] {
                Cell::Num(v) => v,
                _ => f64::NAN,
            };
            let (rn, sr) = (get(2), get(3));
            assert!(rn.is_finite() && sr.is_finite(), "{}", t.id);
            assert!(rn > sr, "{}: rn={rn} sr={sr}", t.id);
            assert!(t.notes.iter().any(|n| n.contains("stalled")), "{:?}", t.notes);
        }
    }

    /// opt3 at smoke scale: the RN lane stagnates above uniform SR, and the
    /// binary64 master-weights binding settles far below the fully-binary8
    /// SR lane (its updates land exactly; only the working grid is coarse).
    #[test]
    fn quick_opt3_master_weights_rescue_binary8() {
        let ctx = ExpCtx::quick();
        let t = opt3(&ctx);
        let last = t.rows.last().unwrap();
        let get = |i: usize| match last[i] {
            Cell::Num(v) => v,
            _ => f64::NAN,
        };
        let (rn, sr, master) = (get(1), get(2), get(3));
        assert!(rn.is_finite() && sr.is_finite() && master.is_finite());
        assert!(rn > sr, "rn={rn} sr={sr}");
        assert!(master < sr / 10.0, "master={master} sr={sr}");
    }

    /// `--lanes` is execution-only end to end: the fig3a table (rows, bands
    /// and notes) is identical at lane widths 1 and 4.
    #[test]
    fn fig3a_table_is_lane_width_invariant() {
        let mut ctx = ExpCtx::quick();
        ctx.seeds = 3;
        ctx.quad_n = 20;
        ctx.quad_steps = 60;
        ctx.jobs = 1;
        let narrow = fig3(&ctx, false);
        ctx.lanes = 4;
        let wide = fig3(&ctx, false);
        assert_eq!(narrow.to_csv(), wide.to_csv());
        assert_eq!(narrow.notes, wide.notes);
    }

    #[test]
    fn quick_fig3a_shapes_hold() {
        let ctx = ExpCtx::quick();
        let t = fig3(&ctx, false);
        assert!(t.rows.len() > 10);
        // SR should track binary32 to within an order of magnitude at the end
        // and signed-SRε should not be slower than SR (paper's shape claims).
        let last = t.rows.last().unwrap();
        let get = |i: usize| match last[i] {
            Cell::Num(v) => v,
            _ => f64::NAN,
        };
        let (b32, sr, signed) = (get(2), get(3), get(4));
        assert!(sr.is_finite() && b32.is_finite());
        assert!(signed <= sr * 1.5, "signed={signed} sr={sr}");
    }
}
