//! Sharded experiment scheduler: a `std::thread` worker pool that fans
//! independent (experiment × rounding-mode × repetition) cells out across
//! cores and merges their results deterministically.
//!
//! # Determinism contract
//!
//! Every cell is a *pure function of its index* (and, for stochastic runs,
//! of a [`crate::fp::Rng::split`] stream keyed by a stable cell id): no
//! cell reads another cell's output, a mutable global, or the identity of
//! the worker thread that happens to execute it. Workers pull indices from
//! a shared atomic counter, tag each result with its index, and the merge
//! sorts by index — so the returned vector is *bit-identical* for any
//! worker count and any execution interleaving (`--jobs 1` ≡ `--jobs N`).
//! `rust/tests/integration.rs` asserts this end-to-end on whole
//! experiment CSVs.
//!
//! # Why a bespoke pool
//!
//! The image is offline (no `rayon`/`crossbeam`); scoped threads
//! (`std::thread::scope`, stable since 1.63) borrow the cell closure and
//! the result buffer directly, so the pool is ~40 lines with no `Arc`
//! plumbing. Cells are coarse (one GD run: 10³–10⁶ rounded operations), so
//! a single atomic fetch-add per cell is negligible scheduling overhead.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::coordinator::health::{panic_message, CellOutcome};

/// Number of worker threads the machine can usefully run (≥ 1).
pub fn available_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a user-facing `--jobs` value: `0` means "auto" (all cores).
pub fn resolve_jobs(requested: usize) -> usize {
    if requested == 0 {
        available_jobs()
    } else {
        requested
    }
}

// The cell-identity hash lives in `util::hash` now (the result registry
// needs the same law); re-exported here so every historic call site —
// `coordinator::cell_stream`, the benches, downstream users — still
// resolves. The in-repo figure builders keep the paper's legacy seed-keyed
// streams (`GdConfig::seed = repetition`) for bit-compatibility with
// earlier releases; `cell_stream` + `Rng::split` is the injection path for
// fully-independent per-cell streams, exercised by `benches/sweep.rs`, the
// tests below, and intended for cross-process sharding.
pub use crate::util::hash::cell_stream;

/// Run `f(0), f(1), …, f(n-1)` on a pool of `jobs` worker threads and
/// return the results **in index order** (see the module docs for the
/// determinism contract). `jobs == 0` means auto; `jobs <= 1` (or `n <= 1`)
/// runs inline on the caller's thread with zero pool overhead.
pub fn run_indexed<T, F>(jobs: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let jobs = resolve_jobs(jobs).min(n.max(1));
    if jobs <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let done: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| {
                let mut local = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i)));
                }
                // A panicking sibling poisons the mutex, but the data it
                // guards is a plain append-only buffer — every pair already
                // in it is complete. Recover the guard and keep merging, so
                // one bad cell cannot discard its siblings' finished work.
                done.lock().unwrap_or_else(|e| e.into_inner()).append(&mut local);
            });
        }
    });
    let mut pairs = done.into_inner().unwrap_or_else(|e| e.into_inner());
    assert_eq!(
        pairs.len(),
        n,
        "scheduler lost cells: merged {} of {n} (a worker died without reporting)",
        pairs.len()
    );
    pairs.sort_unstable_by_key(|&(i, _)| i);
    pairs.into_iter().map(|(_, t)| t).collect()
}

/// One cell's result under the fault-aware scheduler: the value (when any
/// attempt succeeded) plus how it was obtained.
#[derive(Debug, Clone)]
pub struct CellRun<T> {
    /// The cell's value; `None` iff `outcome` is [`CellOutcome::Failed`].
    pub value: Option<T>,
    /// First-try success, retried success, or exhausted failure.
    pub outcome: CellOutcome,
}

/// Fault-aware [`run_indexed`]: each cell runs under
/// [`std::panic::catch_unwind`] and is retried up to `retries` extra times
/// before being reported as [`CellOutcome::Failed`]. Because a cell is a
/// pure function of its index (the determinism contract above), a retry
/// re-executes the *identical* computation — a transient fault's successful
/// retry is bit-identical to a first-try success. `on_done(i, &run)` fires
/// once per cell as it completes (on the worker thread, completion order),
/// which is the journaling hook: a kill between calls loses at most the
/// in-flight cells. The returned vector is index-ordered as always.
///
/// Panic isolation note: `catch_unwind` stops the unwind at the cell
/// boundary, so sibling cells, the worker loop, and the result mutex all
/// survive a panicking cell — the caller decides what a `Failed` cell does
/// to the sweep via [`crate::coordinator::health::FaultPolicy`].
pub fn run_indexed_faulted<T, F, D>(
    jobs: usize,
    n: usize,
    retries: u32,
    f: F,
    on_done: D,
) -> Vec<CellRun<T>>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
    D: Fn(usize, &CellRun<T>) + Sync,
{
    let attempt = |i: usize| -> CellRun<T> {
        let mut last = String::new();
        for try_no in 0..=retries {
            match catch_unwind(AssertUnwindSafe(|| f(i))) {
                Ok(v) => {
                    let outcome =
                        if try_no == 0 { CellOutcome::Ok } else { CellOutcome::Retried(try_no) };
                    return CellRun { value: Some(v), outcome };
                }
                Err(payload) => last = panic_message(payload.as_ref()),
            }
        }
        CellRun { value: None, outcome: CellOutcome::Failed(last) }
    };
    let run_one = |i: usize| -> CellRun<T> {
        let r = attempt(i);
        on_done(i, &r);
        r
    };
    let jobs = resolve_jobs(jobs).min(n.max(1));
    if jobs <= 1 || n <= 1 {
        return (0..n).map(run_one).collect();
    }
    let next = AtomicUsize::new(0);
    let done: Mutex<Vec<(usize, CellRun<T>)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| {
                let mut local = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, run_one(i)));
                }
                done.lock().unwrap_or_else(|e| e.into_inner()).append(&mut local);
            });
        }
    });
    let mut pairs = done.into_inner().unwrap_or_else(|e| e.into_inner());
    assert_eq!(
        pairs.len(),
        n,
        "scheduler lost cells: merged {} of {n} (a worker died without reporting)",
        pairs.len()
    );
    pairs.sort_unstable_by_key(|&(i, _)| i);
    pairs.into_iter().map(|(_, t)| t).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::{FpFormat, Rng, Rounding};
    use crate::gd::engine::{GdConfig, GdEngine};
    use crate::problems::Quadratic;

    #[test]
    fn results_arrive_in_index_order() {
        for jobs in [1, 2, 8] {
            let out = run_indexed(jobs, 100, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>(), "jobs={jobs}");
        }
        assert!(run_indexed(4, 0, |i| i).is_empty());
        assert_eq!(run_indexed(0, 3, |i| i), vec![0, 1, 2]); // jobs=0 → auto
    }

    #[test]
    fn uneven_work_still_merges_deterministically() {
        // Cells with wildly different costs exercise out-of-order completion.
        let slow = |i: usize| {
            let mut acc = 0u64;
            let iters = if i % 7 == 0 { 200_000 } else { 10 };
            for k in 0..iters {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            (i, acc)
        };
        let serial = run_indexed(1, 64, slow);
        let parallel = run_indexed(8, 64, slow);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn cell_stream_is_stable_and_injective_in_practice() {
        let a = cell_stream("fig4a", "SR", 0);
        assert_eq!(a, cell_stream("fig4a", "SR", 0));
        assert_ne!(a, cell_stream("fig4a", "SR", 1));
        assert_ne!(a, cell_stream("fig4a", "RN", 0));
        assert_ne!(a, cell_stream("fig4b", "SR", 0));
        // The separator byte keeps ("ab","c") and ("a","bc") distinct.
        assert_ne!(cell_stream("ab", "c", 0), cell_stream("a", "bc", 0));
    }

    /// The headline guarantee: a sweep of stochastic GD cells produces
    /// bit-identical trajectories at jobs=1 and jobs=8, with each cell's
    /// stream derived via `Rng::split` from the root seed.
    #[test]
    fn gd_sweep_is_bit_identical_across_job_counts() {
        let (p, x0, _) = Quadratic::setting1(40);
        let modes = [Rounding::Sr, Rounding::SrEps(0.2), Rounding::SignedSrEps(0.2)];
        let reps = 6u64;
        let root_seed = 42u64;
        let cells: Vec<(usize, u64)> = (0..modes.len())
            .flat_map(|m| (0..reps).map(move |r| (m, r)))
            .collect();
        let run_sweep = |jobs: usize| -> Vec<Vec<f64>> {
            run_indexed(jobs, cells.len(), |k| {
                let (m, r) = cells[k];
                let mode = modes[m];
                let mut cfg = GdConfig::new(FpFormat::BFLOAT16, mode, 0.3, 30);
                cfg.rng =
                    Some(Rng::new(root_seed).split(cell_stream("sweep", &mode.label(), r)));
                let mut e = GdEngine::new(cfg, &p, &x0);
                e.run(None).objective_series()
            })
        };
        let serial = run_sweep(1);
        let parallel = run_sweep(8);
        assert_eq!(serial, parallel);
        // Distinct cells genuinely follow distinct trajectories.
        assert_ne!(serial[0], serial[1]);
    }

    /// A panic-always cell is isolated: the sweep completes, the bad cell
    /// reports `Failed` with its panic message, and every sibling's value is
    /// bit-identical to a fault-free run — at jobs=1 and jobs=4.
    #[test]
    fn faulted_sweep_isolates_a_panicking_cell() {
        use crate::coordinator::health::FaultInjector;
        let clean = run_indexed(1, 12, |i| i * 10);
        for jobs in [1usize, 4] {
            let inj = FaultInjector::panic_at("t", 5, u32::MAX);
            let out = run_indexed_faulted(
                jobs,
                12,
                1,
                |i| {
                    if inj.fire("t", i).is_some() {
                        panic!("injected fault at cell {i}");
                    }
                    i * 10
                },
                |_, _| {},
            );
            assert_eq!(out.len(), 12);
            for (i, run) in out.iter().enumerate() {
                if i == 5 {
                    assert_eq!(run.value, None);
                    match &run.outcome {
                        CellOutcome::Failed(msg) => {
                            assert!(msg.contains("injected fault at cell 5"), "{msg}")
                        }
                        o => panic!("expected Failed, got {o:?}"),
                    }
                } else {
                    assert_eq!(run.value, Some(clean[i]), "jobs={jobs} cell={i}");
                    assert_eq!(run.outcome, CellOutcome::Ok);
                }
            }
        }
    }

    /// A transient fault (panics once, then succeeds) is retried and the
    /// retried value is bit-identical to a first-try success; with zero
    /// retries the same cell stays `Failed`.
    #[test]
    fn retry_makes_a_transient_fault_bit_identical() {
        use crate::coordinator::health::FaultInjector;
        let cell = |i: usize| -> u64 {
            // A "real" cell: value derives only from the identity stream.
            let mut rng = Rng::new(7).split(cell_stream("retry", "SR", i as u64));
            rng.next_u64()
        };
        let clean: Vec<u64> = (0..6).map(cell).collect();
        let inj = FaultInjector::panic_at("retry", 3, 1);
        let out = run_indexed_faulted(
            2,
            6,
            2,
            |i| {
                if inj.fire("retry", i).is_some() {
                    panic!("transient");
                }
                cell(i)
            },
            |_, _| {},
        );
        for (i, run) in out.iter().enumerate() {
            assert_eq!(run.value, Some(clean[i]), "cell {i}");
            let want = if i == 3 { CellOutcome::Retried(1) } else { CellOutcome::Ok };
            assert_eq!(run.outcome, want);
        }
        // No retry budget: the transient fault is terminal.
        let inj0 = FaultInjector::panic_at("retry", 3, 1);
        let out0 = run_indexed_faulted(
            1,
            6,
            0,
            |i| {
                if inj0.fire("retry", i).is_some() {
                    panic!("transient");
                }
                cell(i)
            },
            |_, _| {},
        );
        assert!(!out0[3].outcome.succeeded());
        assert!(out0.iter().enumerate().all(|(i, r)| i == 3 || r.outcome == CellOutcome::Ok));
    }

    /// The `on_done` hook fires exactly once per cell with the final
    /// outcome — the journaling contract.
    #[test]
    fn on_done_fires_once_per_cell() {
        let seen = Mutex::new(Vec::new());
        let out = run_indexed_faulted(
            4,
            9,
            0,
            |i| i + 1,
            |i, run: &CellRun<usize>| {
                seen.lock().unwrap().push((i, run.value));
            },
        );
        assert_eq!(out.len(), 9);
        let mut seen = seen.into_inner().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, (0..9).map(|i| (i, Some(i + 1))).collect::<Vec<_>>());
    }
}
