//! Sharded experiment scheduler: a `std::thread` worker pool that fans
//! independent (experiment × rounding-mode × repetition) cells out across
//! cores and merges their results deterministically.
//!
//! # Determinism contract
//!
//! Every cell is a *pure function of its index* (and, for stochastic runs,
//! of a [`crate::fp::Rng::split`] stream keyed by a stable cell id): no
//! cell reads another cell's output, a mutable global, or the identity of
//! the worker thread that happens to execute it. Workers pull indices from
//! a shared atomic counter, tag each result with its index, and the merge
//! sorts by index — so the returned vector is *bit-identical* for any
//! worker count and any execution interleaving (`--jobs 1` ≡ `--jobs N`).
//! `rust/tests/integration.rs` asserts this end-to-end on whole
//! experiment CSVs.
//!
//! # Why a bespoke pool
//!
//! The image is offline (no `rayon`/`crossbeam`); scoped threads
//! (`std::thread::scope`, stable since 1.63) borrow the cell closure and
//! the result buffer directly, so the pool is ~40 lines with no `Arc`
//! plumbing. Cells are coarse (one GD run: 10³–10⁶ rounded operations), so
//! a single atomic fetch-add per cell is negligible scheduling overhead.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads the machine can usefully run (≥ 1).
pub fn available_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a user-facing `--jobs` value: `0` means "auto" (all cores).
pub fn resolve_jobs(requested: usize) -> usize {
    if requested == 0 {
        available_jobs()
    } else {
        requested
    }
}

/// Stable stream id for an (experiment, config, repetition) cell: FNV-1a
/// over the two labels, mixed with the repetition index. Purely a function
/// of the cell's *identity*, never of scheduling state, so the id — and
/// through [`crate::fp::Rng::split`] the cell's whole random trajectory —
/// survives reordering, re-sharding and resumption.
///
/// The in-repo figure builders keep the paper's legacy seed-keyed streams
/// (`GdConfig::seed = repetition`) for bit-compatibility with earlier
/// releases; `cell_stream` + `Rng::split` is the injection path for
/// fully-independent per-cell streams, exercised by `benches/sweep.rs`,
/// the tests below, and intended for cross-process sharding.
pub fn cell_stream(experiment: &str, config: &str, rep: u64) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in experiment.bytes().chain([0xff]).chain(config.bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ rep.wrapping_mul(0x9E3779B97F4A7C15)
}

/// Run `f(0), f(1), …, f(n-1)` on a pool of `jobs` worker threads and
/// return the results **in index order** (see the module docs for the
/// determinism contract). `jobs == 0` means auto; `jobs <= 1` (or `n <= 1`)
/// runs inline on the caller's thread with zero pool overhead.
pub fn run_indexed<T, F>(jobs: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let jobs = resolve_jobs(jobs).min(n.max(1));
    if jobs <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let done: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| {
                let mut local = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i)));
                }
                done.lock().unwrap().append(&mut local);
            });
        }
    });
    let mut pairs = done.into_inner().unwrap();
    debug_assert_eq!(pairs.len(), n);
    pairs.sort_unstable_by_key(|&(i, _)| i);
    pairs.into_iter().map(|(_, t)| t).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::{FpFormat, Rng, Rounding};
    use crate::gd::engine::{GdConfig, GdEngine, StepSchemes};
    use crate::problems::Quadratic;

    #[test]
    fn results_arrive_in_index_order() {
        for jobs in [1, 2, 8] {
            let out = run_indexed(jobs, 100, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>(), "jobs={jobs}");
        }
        assert!(run_indexed(4, 0, |i| i).is_empty());
        assert_eq!(run_indexed(0, 3, |i| i), vec![0, 1, 2]); // jobs=0 → auto
    }

    #[test]
    fn uneven_work_still_merges_deterministically() {
        // Cells with wildly different costs exercise out-of-order completion.
        let slow = |i: usize| {
            let mut acc = 0u64;
            let iters = if i % 7 == 0 { 200_000 } else { 10 };
            for k in 0..iters {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            (i, acc)
        };
        let serial = run_indexed(1, 64, slow);
        let parallel = run_indexed(8, 64, slow);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn cell_stream_is_stable_and_injective_in_practice() {
        let a = cell_stream("fig4a", "SR", 0);
        assert_eq!(a, cell_stream("fig4a", "SR", 0));
        assert_ne!(a, cell_stream("fig4a", "SR", 1));
        assert_ne!(a, cell_stream("fig4a", "RN", 0));
        assert_ne!(a, cell_stream("fig4b", "SR", 0));
        // The separator byte keeps ("ab","c") and ("a","bc") distinct.
        assert_ne!(cell_stream("ab", "c", 0), cell_stream("a", "bc", 0));
    }

    /// The headline guarantee: a sweep of stochastic GD cells produces
    /// bit-identical trajectories at jobs=1 and jobs=8, with each cell's
    /// stream derived via `Rng::split` from the root seed.
    #[test]
    fn gd_sweep_is_bit_identical_across_job_counts() {
        let (p, x0, _) = Quadratic::setting1(40);
        let modes = [Rounding::Sr, Rounding::SrEps(0.2), Rounding::SignedSrEps(0.2)];
        let reps = 6u64;
        let root_seed = 42u64;
        let cells: Vec<(usize, u64)> = (0..modes.len())
            .flat_map(|m| (0..reps).map(move |r| (m, r)))
            .collect();
        let run_sweep = |jobs: usize| -> Vec<Vec<f64>> {
            run_indexed(jobs, cells.len(), |k| {
                let (m, r) = cells[k];
                let mode = modes[m];
                let mut cfg =
                    GdConfig::new(FpFormat::BFLOAT16, StepSchemes::uniform(mode), 0.3, 30);
                cfg.rng =
                    Some(Rng::new(root_seed).split(cell_stream("sweep", &mode.label(), r)));
                let mut e = GdEngine::new(cfg, &p, &x0);
                e.run(None).objective_series()
            })
        };
        let serial = run_sweep(1);
        let parallel = run_sweep(8);
        assert_eq!(serial, parallel);
        // Distinct cells genuinely follow distinct trajectories.
        assert_ne!(serial[0], serial[1]);
    }
}
