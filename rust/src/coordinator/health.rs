//! Sweep-level fault machinery: what to do when a cell misbehaves.
//!
//! The per-run numeric counters live in [`crate::fp::RunHealth`] (fp layer);
//! this module holds the *scheduling* side — the [`FaultPolicy`] chosen on
//! the CLI, the per-cell [`CellOutcome`] the fault-aware scheduler reports,
//! and the deterministic test-only [`FaultInjector`] that drives the
//! crash/resume coverage. See `docs/robustness.md`.

use std::any::Any;
use std::sync::atomic::{AtomicU32, Ordering};

/// What a sweep does with a cell that still fails after every retry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FaultPolicy {
    /// Abort the whole experiment with the cell's panic message (the
    /// historic behavior, and the default).
    #[default]
    FailFast,
    /// Drop the cell from the aggregate and note it in the fault report;
    /// every healthy cell's contribution stays bit-identical.
    SkipCell,
    /// Replace the failed cell's series with the caller-supplied exact
    /// (binary64 master) fallback, noted in the fault report.
    Degrade,
}

impl FaultPolicy {
    /// Parse a CLI spelling (`fail-fast` / `skip-cell` / `degrade`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fail-fast" => Some(Self::FailFast),
            "skip-cell" => Some(Self::SkipCell),
            "degrade" => Some(Self::Degrade),
            _ => None,
        }
    }

    /// The CLI spelling of this policy.
    pub fn label(&self) -> &'static str {
        match self {
            Self::FailFast => "fail-fast",
            Self::SkipCell => "skip-cell",
            Self::Degrade => "degrade",
        }
    }
}

/// How one cell of a fault-aware sweep ended.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CellOutcome {
    /// First attempt succeeded.
    Ok,
    /// Succeeded after `n` retries (bit-identical to a first-try success:
    /// a cell is a pure function of its identity-split RNG stream).
    Retried(u32),
    /// Every attempt panicked; `reason` is the last panic's message.
    Failed(String),
}

impl CellOutcome {
    /// Did the cell produce a value?
    pub fn succeeded(&self) -> bool {
        !matches!(self, CellOutcome::Failed(_))
    }
}

/// Which failure the [`FaultInjector`] plants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InjectedFault {
    /// Panic inside the cell closure (exercises `catch_unwind` + retry).
    Panic,
    /// Poison the cell's series with a NaN (exercises numeric-health
    /// accounting downstream of a "successful" cell).
    Nan,
}

/// Deterministic test-only fault injector: fires `times` times at one
/// (experiment, cell-index) coordinate, then stays quiet. Thread-safe —
/// the counter is atomic, so concurrent cells race benignly. Never
/// constructed outside tests/CLI test hooks; sweeps run with `None`.
#[derive(Debug)]
pub struct FaultInjector {
    exp: String,
    index: usize,
    kind: InjectedFault,
    times: u32,
    fired: AtomicU32,
}

impl FaultInjector {
    /// An injector that panics the given cell of the given experiment
    /// `times` consecutive attempts, then lets it through.
    pub fn panic_at(exp: &str, index: usize, times: u32) -> Self {
        let exp = exp.to_string();
        Self { exp, index, kind: InjectedFault::Panic, times, fired: AtomicU32::new(0) }
    }

    /// An injector that NaN-poisons the given cell's output once.
    pub fn nan_at(exp: &str, index: usize) -> Self {
        Self { exp: exp.to_string(), index, kind: InjectedFault::Nan, times: 1, fired: AtomicU32::new(0) }
    }

    /// Called by the sweep from inside the cell closure: returns the fault
    /// to inject for this attempt, or `None` to run the cell normally.
    pub fn fire(&self, exp: &str, index: usize) -> Option<InjectedFault> {
        if exp != self.exp || index != self.index {
            return None;
        }
        if self.fired.fetch_add(1, Ordering::Relaxed) < self.times {
            Some(self.kind)
        } else {
            None
        }
    }
}

/// Best-effort text of a `catch_unwind` payload: `&str` and `String`
/// panics (everything `panic!` produces in this crate) are returned
/// verbatim, anything else gets a placeholder.
pub fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_roundtrips_labels() {
        for p in [FaultPolicy::FailFast, FaultPolicy::SkipCell, FaultPolicy::Degrade] {
            assert_eq!(FaultPolicy::parse(p.label()), Some(p));
        }
        assert_eq!(FaultPolicy::parse("explode"), None);
        assert_eq!(FaultPolicy::default(), FaultPolicy::FailFast);
    }

    #[test]
    fn injector_fires_exactly_times_at_its_coordinate() {
        let inj = FaultInjector::panic_at("sweep", 3, 2);
        assert_eq!(inj.fire("sweep", 2), None); // wrong index
        assert_eq!(inj.fire("other", 3), None); // wrong experiment
        assert_eq!(inj.fire("sweep", 3), Some(InjectedFault::Panic));
        assert_eq!(inj.fire("sweep", 3), Some(InjectedFault::Panic));
        assert_eq!(inj.fire("sweep", 3), None); // budget exhausted
        let nan = FaultInjector::nan_at("sweep", 0);
        assert_eq!(nan.fire("sweep", 0), Some(InjectedFault::Nan));
        assert_eq!(nan.fire("sweep", 0), None);
    }

    #[test]
    fn panic_message_handles_both_string_kinds() {
        let s: Box<dyn std::any::Any + Send> = Box::new("boom");
        assert_eq!(panic_message(s.as_ref()), "boom");
        let s: Box<dyn std::any::Any + Send> = Box::new(String::from("kaboom"));
        assert_eq!(panic_message(s.as_ref()), "kaboom");
        let s: Box<dyn std::any::Any + Send> = Box::new(42u32);
        assert_eq!(panic_message(s.as_ref()), "non-string panic payload");
    }

    #[test]
    fn outcome_success_predicate() {
        assert!(CellOutcome::Ok.succeeded());
        assert!(CellOutcome::Retried(1).succeeded());
        assert!(!CellOutcome::Failed("x".into()).succeeded());
    }
}
