//! Content-addressed, append-only result registry (ROADMAP item 2; see
//! `docs/service.md`).
//!
//! A sweep cell is a pure function of its identity, so its result can be
//! *content-addressed*: the key is [`crate::util::hash::registry_key`] over
//! the run-configuration digest and the cell's stream id — exactly the two
//! fields every journal line already carries — and the value is the cell's
//! series plus numeric-health counters and provenance. The store is shared
//! byte-for-byte between the offline CLI (`--registry DIR` on `reproduce`)
//! and the `lpgd serve` daemon ([`crate::serve`]): a sweep warmed by the
//! CLI is served hot by the daemon, and vice versa.
//!
//! Durability follows the journal's contract (`docs/robustness.md`): one
//! complete JSONL line per record, written with a single `write_all` +
//! flush, so a `kill -9` loses at most in-flight cells and a torn trailing
//! line is rejected on load instead of corrupting the store.

mod store;

pub(crate) use store::sweep_provenance;
pub use store::{CellRecord, Provenance, ResultStore};
