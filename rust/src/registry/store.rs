//! The on-disk store: a single append-only `records.jsonl` log inside the
//! registry directory, mirrored by an in-memory key → record map.
//!
//! Line format (stable; rendered by [`CellRecord::to_json`] through the
//! deterministic [`Json`] renderer, so identical records are identical
//! bytes):
//!
//! ```text
//! {"key":"<16-hex>","digest":"<16-hex>","cell":"<16-hex>","series":[...],
//!  "health":{...},"provenance":{...}}
//! ```

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::fp::RunHealth;
use crate::util::json::Json;

/// Where a registry record came from: enough to audit a served result
/// without re-deriving it. Lane width and job count are deliberately
/// absent, mirroring `ExpCtx::config_digest` — they never change a cell's
/// bytes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Provenance {
    /// Crate version that computed the record (`CARGO_PKG_VERSION`).
    pub code_version: String,
    /// Experiment id (`fig3a`, …) or `"run"` for builder-spec cells.
    pub experiment: String,
    /// Config label inside the experiment (`bf16_SR`, …).
    pub label: String,
    /// Repetition index within the sweep.
    pub rep: u64,
    /// Number grid spec (`bfloat16`, `q4.8`, …); empty when the sweep did
    /// not thread it through (experiment cells carry it in the label).
    pub grid: String,
    /// Rounding-scheme spec (`sr`, `signed:0.25`, …); empty as above.
    pub scheme: String,
    /// Root RNG seed of the repetition.
    pub seed: u64,
    /// Random bits drawn per stochastic rounding (0 = scheme default).
    pub sr_bits: u32,
}

/// One content-addressed cell result: the series plus health counters and
/// provenance, stored under [`crate::util::hash::registry_key`].
#[derive(Debug, Clone, PartialEq)]
pub struct CellRecord {
    /// Run-configuration digest the cell was computed under.
    pub digest: u64,
    /// The cell's stream id ([`crate::util::hash::cell_stream`]).
    pub cell: u64,
    /// The cell's output series (objective or metric values).
    pub series: Vec<f64>,
    /// Numeric-health counters of the run (all zero when the computing
    /// path aggregates health elsewhere and only series are threaded).
    pub health: RunHealth,
    /// Where the record came from.
    pub provenance: Provenance,
}

impl CellRecord {
    /// Render as a JSON value (key included) — the single renderer behind
    /// both the on-disk line and the `GET /v1/result/<key>` body, so the
    /// two are bytes of the same law.
    pub fn to_json(&self, key: u64) -> Json {
        let num = |v: u64| Json::Num(v as f64);
        Json::Obj(vec![
            ("key".into(), Json::Str(format!("{key:016x}"))),
            ("digest".into(), Json::Str(format!("{:016x}", self.digest))),
            ("cell".into(), Json::Str(format!("{:016x}", self.cell))),
            (
                "series".into(),
                Json::Arr(self.series.iter().map(|&v| Json::Num(v)).collect()),
            ),
            (
                "health".into(),
                Json::Obj(vec![
                    ("nan_inf".into(), num(self.health.nan_inf)),
                    ("saturations".into(), num(self.health.saturations)),
                    ("underflows".into(), num(self.health.underflows)),
                    ("stalled_steps".into(), num(self.health.stalled_steps)),
                    ("steps".into(), num(self.health.steps)),
                ]),
            ),
            (
                "provenance".into(),
                Json::Obj(vec![
                    ("code_version".into(), Json::Str(self.provenance.code_version.clone())),
                    ("experiment".into(), Json::Str(self.provenance.experiment.clone())),
                    ("label".into(), Json::Str(self.provenance.label.clone())),
                    ("rep".into(), num(self.provenance.rep)),
                    ("grid".into(), Json::Str(self.provenance.grid.clone())),
                    ("scheme".into(), Json::Str(self.provenance.scheme.clone())),
                    ("seed".into(), num(self.provenance.seed)),
                    ("sr_bits".into(), num(self.provenance.sr_bits as u64)),
                ]),
            ),
        ])
    }

    /// Parse one log line back into `(key, record)`. `None` — the line is
    /// skipped on load — for anything malformed, including a line torn by
    /// a mid-write kill (the journal's torn-record contract).
    fn parse(line: &str) -> Option<(u64, CellRecord)> {
        let v = Json::parse(line).ok()?;
        let hex = |k: &str| u64::from_str_radix(v.get(k)?.as_str()?, 16).ok();
        let key = hex("key")?;
        let series =
            v.get("series")?.as_array()?.iter().map(|x| x.as_f64()).collect::<Option<Vec<_>>>()?;
        let h = v.get("health")?;
        let hf = |k: &str| h.get(k)?.as_u64();
        let p = v.get("provenance")?;
        let ps = |k: &str| Some(p.get(k)?.as_str()?.to_string());
        let rec = CellRecord {
            digest: hex("digest")?,
            cell: hex("cell")?,
            series,
            health: RunHealth {
                nan_inf: hf("nan_inf")?,
                saturations: hf("saturations")?,
                underflows: hf("underflows")?,
                stalled_steps: hf("stalled_steps")?,
                steps: hf("steps")?,
            },
            provenance: Provenance {
                code_version: ps("code_version")?,
                label: ps("label")?,
                experiment: ps("experiment")?,
                rep: p.get("rep")?.as_u64()?,
                grid: ps("grid")?,
                scheme: ps("scheme")?,
                seed: p.get("seed")?.as_u64()?,
                sr_bits: p.get("sr_bits")?.as_u64()? as u32,
            },
        };
        Some((key, rec))
    }
}

/// The content-addressed result store: an append-only `records.jsonl` log
/// under a registry directory, loaded into a key → record map at open.
///
/// Thread-safe by construction: lookups clone an `Arc`, inserts append one
/// complete line under a file lock. Hit/miss counters are *not* bumped by
/// [`ResultStore::peek`] — callers count at the resolution level via
/// [`ResultStore::count_hit`]/[`ResultStore::count_miss`], so a request
/// that waits on an in-flight computation and then reads the store counts
/// as exactly one hit, not a miss-then-hit (the `/v1/stats` contract).
#[derive(Debug)]
pub struct ResultStore {
    dir: PathBuf,
    file: Mutex<File>,
    records: Mutex<HashMap<u64, Arc<CellRecord>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ResultStore {
    /// Open (or create) the registry at `dir`, loading every parseable
    /// record from `records.jsonl`. Unparsable lines — torn tails from a
    /// `kill -9`, foreign garbage — are skipped, never fatal: the store is
    /// a cache, and a lost record is recomputed on the next miss.
    pub fn open(dir: &Path) -> std::io::Result<Self> {
        std::fs::create_dir_all(dir)?;
        let log = dir.join("records.jsonl");
        let mut records = HashMap::new();
        if log.exists() {
            let reader = BufReader::new(File::open(&log)?);
            for line in reader.lines() {
                let Ok(line) = line else { break };
                if let Some((key, rec)) = CellRecord::parse(&line) {
                    records.insert(key, Arc::new(rec));
                }
            }
        }
        let mut file = OpenOptions::new().create(true).append(true).open(&log)?;
        // A line torn by a mid-write kill has no trailing newline; terminate
        // it so the next record starts on a fresh line instead of
        // concatenating into the garbage (which would lose that record too).
        if log_lacks_final_newline(&log)? {
            file.write_all(b"\n")?;
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            file: Mutex::new(file),
            records: Mutex::new(records),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        })
    }

    /// The registry directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Look a key up **without touching the hit/miss counters** (see the
    /// type docs for why counting is the caller's job).
    pub fn peek(&self, key: u64) -> Option<Arc<CellRecord>> {
        self.records.lock().unwrap_or_else(|e| e.into_inner()).get(&key).cloned()
    }

    /// Insert a freshly computed record and append it to the log.
    /// Idempotent: a key already present is left untouched (first write
    /// wins — all writers compute the same pure function, so the bytes
    /// are the same either way). Log-write errors are reported on stderr
    /// but do not fail the computation (the store is a cache, not the
    /// result channel — the journal's error contract).
    pub fn insert(&self, key: u64, rec: CellRecord) {
        let line = {
            let mut map = self.records.lock().unwrap_or_else(|e| e.into_inner());
            if map.contains_key(&key) {
                return;
            }
            let mut line = rec.to_json(key).render();
            line.push('\n');
            map.insert(key, Arc::new(rec));
            line
        };
        let mut f = self.file.lock().unwrap_or_else(|e| e.into_inner());
        if let Err(e) = f.write_all(line.as_bytes()).and_then(|()| f.flush()) {
            eprintln!("warning: registry write failed ({}): {e}", self.dir.display());
        }
    }

    /// Count one served-from-store resolution.
    pub fn count_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one computed-on-miss resolution.
    pub fn count_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Cells served from the store so far (this process).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cells computed on a miss so far (this process).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Number of records in the store.
    pub fn len(&self) -> usize {
        self.records.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// True when the store holds no records.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Distinct `provenance.experiment` values with their record counts,
    /// sorted by experiment id (the `lpgd list --registry` view).
    pub fn experiments(&self) -> Vec<(String, usize)> {
        let map = self.records.lock().unwrap_or_else(|e| e.into_inner());
        let mut counts: HashMap<&str, usize> = HashMap::new();
        for rec in map.values() {
            *counts.entry(rec.provenance.experiment.as_str()).or_insert(0) += 1;
        }
        let mut out: Vec<(String, usize)> =
            counts.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
        out.sort();
        out
    }
}

/// True when the log exists, is non-empty, and its last byte is not a
/// newline — the signature of a torn trailing record.
fn log_lacks_final_newline(path: &Path) -> std::io::Result<bool> {
    let mut f = File::open(path)?;
    let len = f.metadata()?.len();
    if len == 0 {
        return Ok(false);
    }
    f.seek(SeekFrom::End(-1))?;
    let mut last = [0u8; 1];
    f.read_exact(&mut last)?;
    Ok(last[0] != b'\n')
}

/// Provenance for a cell computed by an experiment sweep: grid and scheme
/// live inside the experiment's config label, so only the identity triple
/// and the code version are recorded.
pub(crate) fn sweep_provenance(experiment: &str, label: &str, rep: u64) -> Provenance {
    Provenance {
        code_version: env!("CARGO_PKG_VERSION").to_string(),
        experiment: experiment.to_string(),
        label: label.to_string(),
        rep,
        seed: rep,
        ..Provenance::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("lpgd_registry_{}_{tag}", std::process::id()))
    }

    fn record(cell: u64, series: Vec<f64>) -> CellRecord {
        CellRecord {
            digest: 0xabcd,
            cell,
            series,
            health: RunHealth { stalled_steps: 3, steps: 40, ..RunHealth::default() },
            provenance: sweep_provenance("fig3a", "bf16_SR", cell),
        }
    }

    #[test]
    fn roundtrip_is_bit_exact_across_reopen() {
        let dir = tmp_dir("roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
        let series = vec![
            1.5,
            -0.0,
            5e-324,
            1.0 / 3.0,
            f64::MAX,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
        ];
        {
            let store = ResultStore::open(&dir).unwrap();
            store.insert(7, record(7, series.clone()));
            store.insert(9, record(9, vec![]));
            assert_eq!(store.len(), 2);
        }
        let store = ResultStore::open(&dir).unwrap();
        assert_eq!(store.len(), 2);
        let got = store.peek(7).unwrap();
        assert_eq!(got.series.len(), series.len());
        for (a, b) in got.series.iter().zip(&series) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
        assert_eq!(got.health.stalled_steps, 3);
        assert_eq!(got.provenance.experiment, "fig3a");
        assert!(store.peek(9).unwrap().series.is_empty());
        assert_eq!(store.peek(8), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rendering_is_deterministic_and_insert_idempotent() {
        let dir = tmp_dir("determinism");
        let _ = std::fs::remove_dir_all(&dir);
        let store = ResultStore::open(&dir).unwrap();
        let rec = record(1, vec![0.1 + 0.2, 2.0]);
        let line = rec.to_json(1).render();
        assert_eq!(line, rec.to_json(1).render());
        store.insert(1, rec.clone());
        store.insert(1, record(1, vec![999.0])); // loser: first write wins
        assert_eq!(store.peek(1).unwrap().series[1], 2.0);
        // The log holds exactly the one line the renderer produced.
        let log = std::fs::read_to_string(dir.join("records.jsonl")).unwrap();
        assert_eq!(log, format!("{line}\n"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_trailing_record_is_rejected_on_load() {
        let dir = tmp_dir("torn");
        let _ = std::fs::remove_dir_all(&dir);
        {
            let store = ResultStore::open(&dir).unwrap();
            store.insert(1, record(1, vec![1.0, 2.0]));
        }
        {
            use std::io::Write as _;
            let mut f =
                OpenOptions::new().append(true).open(dir.join("records.jsonl")).unwrap();
            // A mid-write kill tears the second record in half.
            let full = record(2, vec![4.0, 5.0]).to_json(2).render();
            f.write_all(full[..full.len() / 2].as_bytes()).unwrap();
        }
        let store = ResultStore::open(&dir).unwrap();
        assert_eq!(store.len(), 1, "torn record must not load");
        assert!(store.peek(1).is_some());
        assert!(store.peek(2).is_none());
        // The store still appends fine after the torn tail...
        store.insert(3, record(3, vec![7.0]));
        drop(store);
        // ...and the fresh record loads even though it sits after garbage
        // (line-oriented recovery: only the torn line itself is lost).
        let store = ResultStore::open(&dir).unwrap();
        assert!(store.peek(3).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn counters_are_caller_driven_and_experiments_summarize() {
        let dir = tmp_dir("counters");
        let _ = std::fs::remove_dir_all(&dir);
        let store = ResultStore::open(&dir).unwrap();
        store.insert(1, record(1, vec![1.0]));
        store.peek(1); // peeking never counts
        assert_eq!((store.hits(), store.misses()), (0, 0));
        store.count_hit();
        store.count_hit();
        store.count_miss();
        assert_eq!((store.hits(), store.misses()), (2, 1));
        let mut other = record(2, vec![2.0]);
        other.provenance.experiment = "fig4a".into();
        store.insert(2, other);
        assert_eq!(
            store.experiments(),
            vec![("fig3a".to_string(), 1), ("fig4a".to_string(), 1)]
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
