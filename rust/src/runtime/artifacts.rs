//! Artifact registry: static shape metadata mirroring `python/compile/aot.py`.
//! A mismatch here would surface as a PJRT shape error at call time; keeping
//! the specs in one place gives Rust callers compile-time constants and a
//! single point of truth to update alongside the Python side.

/// Static description of one AOT artifact.
#[derive(Debug, Clone, Copy)]
pub struct ArtifactSpec {
    /// Artifact file name under the artifacts directory.
    pub file: &'static str,
    /// Flat parameter count (quantizer: element count).
    pub params: usize,
    /// Batch rows consumed per step (0 for the standalone quantizer).
    pub batch: usize,
    /// Feature dimension.
    pub features: usize,
    /// Classes (MLR) / 0 (binary-label NN, quantizer).
    pub classes: usize,
}

/// Standalone Layer-1 quantizer over 8192 f32 elements (binary8 target).
pub const QUANTIZE_SPEC: ArtifactSpec = ArtifactSpec {
    file: "quantize.hlo.txt",
    params: 8192,
    batch: 0,
    features: 0,
    classes: 0,
};

/// MLR rounded train step: N=256, D=196, C=10, P = C·(D+1) = 1970.
pub const MLR_SPEC: ArtifactSpec = ArtifactSpec {
    file: "mlr_step.hlo.txt",
    params: 10 * (196 + 1),
    batch: 256,
    features: 196,
    classes: 10,
};

/// NN rounded train step: N=256, D=196, H=100, P = H·(D+2)+1 = 19801.
pub const NN_SPEC: ArtifactSpec = ArtifactSpec {
    file: "nn_step.hlo.txt",
    params: 100 * (196 + 2) + 1,
    batch: 256,
    features: 196,
    classes: 0,
};

/// Scheme ids shared with the Python side (mode operand of the artifacts).
pub mod mode {
    /// Round-to-nearest (deterministic).
    pub const RN: i32 = 0;
    /// Unbiased stochastic rounding.
    pub const SR: i32 = 1;
    /// ε-biased stochastic rounding (away from zero).
    pub const SR_EPS: i32 = 2;
    /// Signed ε-biased stochastic rounding (steered).
    pub const SIGNED_SR_EPS: i32 = 3;

    /// Map a coordinator [`crate::fp::Rounding`] onto an artifact mode id.
    pub fn from_rounding(r: crate::fp::Rounding) -> (i32, f32) {
        use crate::fp::Rounding::*;
        match r {
            RoundNearestEven => (RN, 0.0),
            Sr => (SR, 0.0),
            SrEps(e) => (SR_EPS, e as f32),
            SignedSrEps(e) => (SIGNED_SR_EPS, e as f32),
            // Directed modes are not part of the artifact ABI (the paper's
            // experiments never use them on the update path); degrade to RN.
            RoundDown | RoundUp | RoundTowardZero => (RN, 0.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_param_counts() {
        assert_eq!(MLR_SPEC.params, 1970);
        assert_eq!(NN_SPEC.params, 19801);
        assert_eq!(QUANTIZE_SPEC.params, 8192);
    }

    #[test]
    fn mode_mapping() {
        use crate::fp::Rounding;
        assert_eq!(mode::from_rounding(Rounding::Sr), (1, 0.0));
        assert_eq!(mode::from_rounding(Rounding::SrEps(0.25)), (2, 0.25));
        assert_eq!(mode::from_rounding(Rounding::SignedSrEps(0.1)), (3, 0.1));
        assert_eq!(mode::from_rounding(Rounding::RoundNearestEven), (0, 0.0));
    }
}
