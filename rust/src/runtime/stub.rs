//! Stub PJRT runtime, compiled when the `pjrt` cargo feature is off.
//!
//! The real client (`client.rs`) wraps the external `xla` crate, which is
//! not vendored in this offline image. This stub mirrors its public API
//! exactly — same types, same signatures — so every caller (the CLI's
//! `pjrt-info` command, the `runtime_pjrt` bench, the `train_mlr_e2e`
//! example) type-checks unconditionally; at run time [`Runtime::cpu`]
//! returns a descriptive error and the callers degrade gracefully.

use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

const UNAVAILABLE: &str =
    "lpgd was built without the `pjrt` feature (the external `xla` crate is \
     not vendored in this offline image); rebuild with `--features pjrt` \
     after adding the xla dependency to Cargo.toml";

/// A compiled PJRT executable (stub: never constructed).
pub struct Executable {
    /// Artifact file name this executable was loaded from.
    pub name: String,
}

/// Argument value for an executable call (f32/i32 tensors cover every
/// artifact this project ships).
pub enum Arg {
    /// Dense f32 tensor with its shape.
    F32(Vec<f32>, Vec<i64>),
    /// Dense i32 tensor with its shape.
    I32(Vec<i32>, Vec<i64>),
    /// Scalar f32 operand.
    ScalarF32(f32),
    /// Scalar i32 operand.
    ScalarI32(i32),
}

impl Arg {
    /// Convenience: f64 slice → f32 tensor arg.
    pub fn f32_from_f64(v: &[f64], shape: &[i64]) -> Arg {
        Arg::F32(v.iter().map(|&x| x as f32).collect(), shape.to_vec())
    }
}

impl Executable {
    /// Execute with the given args (stub: always errors).
    pub fn run_f32(&self, _args: &[Arg]) -> Result<Vec<Vec<f32>>> {
        bail!("{}", UNAVAILABLE)
    }
}

/// The runtime handle (stub: cannot be constructed; `cpu` always errors).
pub struct Runtime {
    /// Directory containing `*.hlo.txt` artifacts.
    pub artifact_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT runtime rooted at `artifact_dir` (stub: errors).
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let _ = artifact_dir.as_ref();
        bail!("{}", UNAVAILABLE)
    }

    /// PJRT platform name (stub: placeholder).
    pub fn platform(&self) -> String {
        "unavailable (built without `pjrt`)".to_string()
    }

    /// Load + compile an HLO-text artifact (stub: always errors).
    pub fn load(&mut self, _file_name: &str) -> Result<&Executable> {
        bail!("{}", UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_missing_feature() {
        let err = Runtime::cpu("artifacts").err().expect("stub must error");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn arg_marshalling_still_works() {
        let a = Arg::f32_from_f64(&[1.0, 2.5], &[2]);
        match a {
            Arg::F32(v, shape) => {
                assert_eq!(v, vec![1.0f32, 2.5]);
                assert_eq!(shape, vec![2]);
            }
            _ => panic!("wrong variant"),
        }
    }
}
