//! PJRT runtime (system S12): load AOT-compiled HLO-text artifacts and run
//! them from the Rust hot path. Python never executes at experiment time.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py`): jax ≥ 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids and round-trips cleanly.

pub mod artifacts;

/// Real PJRT client, only when the `pjrt` feature (and its `xla`
/// dependency) is enabled.
#[cfg(feature = "pjrt")]
pub mod client;

/// API-compatible stub compiled without `pjrt`: `Runtime::cpu` returns a
/// descriptive error so callers degrade gracefully (see `stub.rs`).
#[cfg(not(feature = "pjrt"))]
#[path = "stub.rs"]
pub mod client;

pub use artifacts::{ArtifactSpec, MLR_SPEC, NN_SPEC, QUANTIZE_SPEC};
pub use client::{Arg, Executable, Runtime};
