//! Thin, safe wrapper over the `xla` crate's PJRT CPU client: compile once,
//! execute many times, marshal `f64` coordinator data ↔ `f32` device buffers.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled PJRT executable plus its entry metadata.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact file name this executable was loaded from.
    pub name: String,
}

/// Argument value for an executable call (f32/i32 tensors cover every
/// artifact this project ships).
pub enum Arg {
    /// Dense f32 tensor with its shape.
    F32(Vec<f32>, Vec<i64>),
    /// Dense i32 tensor with its shape.
    I32(Vec<i32>, Vec<i64>),
    /// Scalar f32 operand.
    ScalarF32(f32),
    /// Scalar i32 operand.
    ScalarI32(i32),
}

impl Arg {
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Arg::F32(v, shape) => xla::Literal::vec1(v).reshape(shape)?,
            Arg::I32(v, shape) => xla::Literal::vec1(v).reshape(shape)?,
            Arg::ScalarF32(v) => xla::Literal::from(*v),
            Arg::ScalarI32(v) => xla::Literal::from(*v),
        })
    }

    /// Convenience: f64 slice → f32 tensor arg.
    pub fn f32_from_f64(v: &[f64], shape: &[i64]) -> Arg {
        Arg::F32(v.iter().map(|&x| x as f32).collect(), shape.to_vec())
    }
}

impl Executable {
    /// Execute with the given args; returns every tuple element as a f32 vec
    /// (scalars come back as length-1 vecs; integer outputs unsupported —
    /// none of our artifacts emit them).
    pub fn run_f32(&self, args: &[Arg]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let elems = result.to_tuple()?;
        let mut out = Vec::with_capacity(elems.len());
        for e in elems {
            out.push(e.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// The runtime: one PJRT CPU client + a compile cache keyed by artifact path.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, usize>,
    exes: Vec<Executable>,
    /// Directory containing `*.hlo.txt` artifacts.
    pub artifact_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT runtime rooted at `artifact_dir`.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            cache: HashMap::new(),
            exes: Vec::new(),
            artifact_dir: artifact_dir.as_ref().to_path_buf(),
        })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by path).
    pub fn load(&mut self, file_name: &str) -> Result<&Executable> {
        let path = self.artifact_dir.join(file_name);
        if let Some(&idx) = self.cache.get(&path) {
            return Ok(&self.exes[idx]);
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))
        .with_context(|| "did you run `make artifacts`?")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        let idx = self.exes.len();
        self.exes.push(Executable { exe, name: file_name.to_string() });
        self.cache.insert(path, idx);
        Ok(&self.exes[idx])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/quantize.hlo.txt").exists()
    }

    fn rt() -> Runtime {
        Runtime::cpu(Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap()
    }

    #[test]
    fn client_comes_up() {
        let r = rt();
        assert!(!r.platform().is_empty());
    }

    #[test]
    fn quantize_artifact_matches_rust_substrate_rn() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut r = rt();
        let exe = r.load("quantize.hlo.txt").unwrap();
        let n = 8192usize;
        // Deterministic RN (mode 0) lets us compare bit-for-bit with the
        // Rust substrate without sharing an RNG stream.
        let mut rng = crate::fp::Rng::new(11);
        let x: Vec<f64> = (0..n).map(|_| rng.normal() * 2.0).collect();
        let x32: Vec<f64> = x.iter().map(|&v| v as f32 as f64).collect();
        let u: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let out = exe
            .run_f32(&[
                Arg::f32_from_f64(&x, &[n as i64]),
                Arg::f32_from_f64(&u, &[n as i64]),
                Arg::f32_from_f64(&x, &[n as i64]),
                Arg::ScalarI32(0),
                Arg::ScalarF32(0.0),
            ])
            .unwrap();
        assert_eq!(out.len(), 1);
        let fmt = crate::fp::FpFormat::BINARY8;
        let mut r2 = crate::fp::Rng::new(0);
        for i in 0..n {
            let want = crate::fp::round(&fmt, crate::fp::Rounding::RoundNearestEven, x32[i], &mut r2);
            assert_eq!(out[0][i] as f64, want, "i={i} x={}", x32[i]);
        }
    }

    #[test]
    fn load_caches_by_path() {
        if !artifacts_ready() {
            return;
        }
        let mut r = rt();
        r.load("quantize.hlo.txt").unwrap();
        r.load("quantize.hlo.txt").unwrap();
        assert_eq!(r.exes.len(), 1);
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let mut r = rt();
        let err = match r.load("nope.hlo.txt") {
            Err(e) => e.to_string(),
            Ok(_) => panic!("loading a missing artifact should fail"),
        };
        assert!(err.contains("artifacts"), "{err}");
    }
}
