//! The GD iteration under floating-point rounding — paper eq. (8):
//!
//! ```text
//! (8a)  ĝ = ∇f(x̂) + σ₁          gradient evaluated in low precision
//! (8b)  m = fl₂(t · ĝ)           stepsize multiplication, error δ₂
//! (8c)  x̂⁺ = fl₃(x̂ − m)          subtraction, error δ₃
//! ```
//!
//! Each rounding site is bound independently through the [`PolicyMap`]:
//! the three per-step sites (8a)/(8b)/(8c) hold any registered
//! [`crate::fp::scheme::Scheme`], and named state tensors (`weights`, the
//! optimizer moments `m`/`v`) may additionally carry their own grid and
//! `sr_bits` — the spec-string form is
//! `policy:weights=sr_eps:0.4@bf16,m=rn@fp32`. That is exactly the
//! paper's experimental protocol (e.g. Fig. 4b: SRε for (8a)+(8b),
//! signed-SRε for (8c)) extended to state-carrying optimizers, where
//! master-weights-in-high-precision versus fully-low-precision lanes are
//! policy spellings rather than code paths. For `SignedSrEps` the
//! steering value is
//!
//! * `(8b)`: `v = −ĝᵢ` — bias `−sign(v) = +sign(ĝᵢ)` *enlarges* the step in
//!   the gradient direction (the descent choice; with this steering the law
//!   coincides with `SRε(t·ĝᵢ)` since `sign(t·ĝᵢ) = sign(ĝᵢ)`);
//! * `(8c)`: `v = +ĝᵢ` — bias `−sign(ĝᵢ)` on the new iterate, i.e. a descent
//!   direction, exactly as §4.2.2 prescribes ("replacing v with the
//!   components of the gradient vector").
//!
//! The update law itself is pluggable: [`GdEngine::step`] is a thin
//! driver over the [`crate::gd::optimizer::Optimizer`] trait (plain GD,
//! momentum, Nesterov, Adam — see [`OptimizerSpec`]), with plain-`Gd`
//! trajectories bit-identical to the pre-trait engine for every built-in
//! scheme.

use crate::fp::grid::Grid;
use crate::fp::kernels::Site;
use crate::fp::linalg::{exact, LpCtx};
use crate::fp::rng::Rng;
use crate::fp::round::{RoundPlan, Rounding, RunHealth, DEFAULT_SR_BITS};
use crate::fp::scheme::{Scheme, SchemeError, SchemeRegistry};
use crate::gd::optimizer::{LrSchedule, Optimizer, OptimizerSpec, StepCtx};
use crate::gd::stagnation::tau_k;
use crate::gd::trace::{IterRecord, RunStatus, Trace};
use crate::problems::Problem;

/// Rounding policy of one named state tensor: the scheme, plus an
/// optional grid and `sr_bits` override. A binding with no grid rounds on
/// the run's working grid; `weights=rn@binary64` is the classic
/// master-weights lane, `m=sr@bf16` keeps a momentum buffer in bfloat16.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TensorPolicy {
    /// Rounding scheme applied at this tensor's site.
    pub scheme: Scheme,
    /// Grid override; `None` uses the run's working grid.
    pub grid: Option<Grid>,
    /// `sr_bits` override; `None` uses the run's `sr_bits`.
    pub sr_bits: Option<u32>,
}

impl TensorPolicy {
    /// A binding with the given scheme on the run's grid and `sr_bits`.
    pub fn new(scheme: Scheme) -> Self {
        Self { scheme, grid: None, sr_bits: None }
    }

    /// Override the grid this tensor rounds (and lives) on.
    pub fn on(mut self, grid: impl Into<Grid>) -> Self {
        self.grid = Some(grid.into());
        self
    }

    /// Override the random bits per stochastic slice rounding.
    pub fn with_sr_bits(mut self, bits: u32) -> Self {
        self.sr_bits = Some(bits);
        self
    }

    /// The rounding plan of this site, defaulting omitted overrides to the
    /// run's grid and `sr_bits`.
    pub fn plan(&self, default_grid: Grid, default_sr_bits: u32) -> RoundPlan {
        RoundPlan::new(self.grid.unwrap_or(default_grid))
            .with_sr_bits(self.sr_bits.unwrap_or(default_sr_bits))
    }

    /// Canonical spec token, `<scheme>[@<grid>][#<bits>]` with canonical
    /// scheme/grid names and absent overrides elided.
    pub fn canon_token(&self) -> String {
        let mut s = self.scheme.name();
        if let Some(g) = self.grid {
            s.push('@');
            s.push_str(&g.name());
        }
        if let Some(b) = self.sr_bits {
            s.push('#');
            s.push_str(&b.to_string());
        }
        s
    }
}

/// The per-tensor rounding policy of one run: an independent open-API
/// [`Scheme`] for each of the three rounding sites of eq. (8) — the
/// gradient evaluation (8a), the stepsize multiplication (8b) and the
/// iterate subtraction (8c) — plus optional [`TensorPolicy`] bindings for
/// the named state tensors:
///
/// * `weights` — the (8c) landing site of the iterate itself. Binding it
///   to a wider grid (`weights=rn@binary64`) is the master-weights lane:
///   updates still round on the working grid, the accumulated iterate
///   does not.
/// * `m` / `v` — the optimizer's first/second-moment state tensors
///   (momentum buffer, Adam moments). Unbound state rounds on the working
///   grid with the (8b) scheme.
///
/// Every consumer — [`crate::gd::RunBuilder`], [`GdConfig`], the CLI
/// `train` flags, the serve `/v1/run` spec parser and journal/registry
/// cell identity — speaks this one policy language; [`PolicyMap::parse`]
/// and [`PolicyMap::canon`] are the spec-string round-trip, canonicalized
/// so spelling variants share cache keys.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PolicyMap {
    /// Scheme used *inside* the gradient evaluation (8a).
    pub grad: Scheme,
    /// Scheme for the stepsize multiplication (8b).
    pub mul: Scheme,
    /// Scheme for the final subtraction (8c), unless `weights` is bound.
    pub sub: Scheme,
    /// Binding of the iterate's (8c) landing site (scheme + grid +
    /// `sr_bits`); `None` lands through `sub` on the working grid.
    pub weights: Option<TensorPolicy>,
    /// Binding of the optimizer's first-moment tensor `m`.
    pub m: Option<TensorPolicy>,
    /// Binding of the optimizer's second-moment tensor `v`.
    pub v: Option<TensorPolicy>,
}

impl PolicyMap {
    /// All three sites with the same scheme, no tensor bindings.
    pub fn uniform(scheme: Scheme) -> Self {
        Self::sites(scheme, scheme, scheme)
    }

    /// Per-site schemes for (8a)/(8b)/(8c), no tensor bindings.
    pub fn sites(grad: Scheme, mul: Scheme, sub: Scheme) -> Self {
        Self { grad, mul, sub, weights: None, m: None, v: None }
    }

    /// Bind the iterate's landing site (builder-style).
    pub fn with_weights(mut self, binding: TensorPolicy) -> Self {
        self.weights = Some(binding);
        self
    }

    /// Bind the first-moment tensor `m` (builder-style).
    pub fn with_m(mut self, binding: TensorPolicy) -> Self {
        self.m = Some(binding);
        self
    }

    /// Bind the second-moment tensor `v` (builder-style).
    pub fn with_v(mut self, binding: TensorPolicy) -> Self {
        self.v = Some(binding);
        self
    }

    /// Does any state tensor carry its own binding? (The lane-batched fast
    /// path keys on this.)
    pub fn has_bindings(&self) -> bool {
        self.weights.is_some() || self.m.is_some() || self.v.is_some()
    }

    /// Short label, e.g. `8a=SR 8b=SR 8c=signed-SR_eps(0.1)`, with bound
    /// tensors appended (`weights=rn@binary64`) when present.
    pub fn label(&self) -> String {
        let mut s =
            format!("8a={} 8b={} 8c={}", self.grad.label(), self.mul.label(), self.sub.label());
        for (name, b) in [("weights", self.weights), ("m", self.m), ("v", self.v)] {
            if let Some(b) = b {
                s.push_str(&format!(" {name}={}", b.canon_token()));
            }
        }
        s
    }

    /// Does any site (base or bound) consume randomness?
    pub fn is_stochastic(&self) -> bool {
        self.grad.is_stochastic()
            || self.mul.is_stochastic()
            || self.sub.is_stochastic()
            || [self.weights, self.m, self.v]
                .iter()
                .any(|b| b.map(|b| b.scheme.is_stochastic()).unwrap_or(false))
    }

    /// Parse a policy spec. A bare scheme spec (`"sr"`, `"sr_eps:0.4"`,
    /// any registered name) is the uniform policy; the `policy:` form
    /// binds sites and tensors individually:
    ///
    /// ```text
    /// policy:<entry>,<entry>,...
    /// <entry> := <tensor>=<scheme>[@<grid>][#<sr_bits>]
    /// <tensor> := grad|8a | mul|8b | sub|8c | weights|w|x | m|momentum | v
    /// ```
    ///
    /// `@grid`/`#bits` overrides are only meaningful on the state tensors
    /// (`weights`, `m`, `v`); the base sites take bare schemes. Sites not
    /// mentioned default to `sr` (the builder default). Grids accept
    /// every [`Grid::parse`] spelling, `bf16`/`fp16`/`fp32` aliases
    /// included. Case-insensitive, whitespace-trimmed.
    pub fn parse(spec: &str) -> Result<Self, SchemeError> {
        let trimmed = spec.trim();
        let lower = trimmed.to_ascii_lowercase();
        let body = match lower.strip_prefix("policy:") {
            Some(b) => b,
            None => return Ok(Self::uniform(SchemeRegistry::lookup(trimmed)?)),
        };
        let mut pm = Self::uniform(Scheme::sr());
        for entry in body.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (name, val) = entry.split_once('=').ok_or_else(|| {
                SchemeError::BadSpec(format!(
                    "policy entry '{entry}' is not of the form <tensor>=<scheme>[@<grid>][#<bits>]"
                ))
            })?;
            let name = name.trim();
            let (val, bits) = match val.rsplit_once('#') {
                Some((v, b)) => {
                    let bits: u32 = b.trim().parse().ok().filter(|n| (1..=64).contains(n)).ok_or_else(|| {
                        SchemeError::BadSpec(format!(
                            "bad sr_bits '#{b}' in policy entry '{entry}' (expected an integer in 1..=64)"
                        ))
                    })?;
                    (v, Some(bits))
                }
                None => (val, None),
            };
            let (scheme_spec, grid) = match val.rsplit_once('@') {
                Some((v, g)) => {
                    let grid = Grid::parse(g.trim())
                        .ok_or_else(|| SchemeError::UnknownFormat(g.trim().to_string()))?;
                    (v, Some(grid))
                }
                None => (val, None),
            };
            let scheme = SchemeRegistry::lookup(scheme_spec)?;
            let binding = TensorPolicy { scheme, grid, sr_bits: bits };
            match name {
                "grad" | "8a" | "mul" | "8b" | "sub" | "8c" => {
                    if grid.is_some() || bits.is_some() {
                        return Err(SchemeError::BadSpec(format!(
                            "site '{name}' takes a bare scheme; @grid/#bits bindings apply to state tensors (weights, m, v)"
                        )));
                    }
                    match name {
                        "grad" | "8a" => pm.grad = scheme,
                        "mul" | "8b" => pm.mul = scheme,
                        _ => pm.sub = scheme,
                    }
                }
                "weights" | "w" | "x" => pm.weights = Some(binding),
                "m" | "momentum" => pm.m = Some(binding),
                "v" => pm.v = Some(binding),
                _ => {
                    return Err(SchemeError::BadSpec(format!(
                        "unknown tensor '{name}' in policy spec (known: grad/8a, mul/8b, sub/8c, weights, m, v)"
                    )))
                }
            }
        }
        Ok(pm)
    }

    /// Canonical spec string, re-parseable by [`PolicyMap::parse`]:
    /// uniform unbound policies collapse to the bare canonical scheme name
    /// (`"sr"`), everything else to the `policy:` form with default sites
    /// (`sr`) elided, entries in fixed `grad,mul,sub,weights,m,v` order
    /// and canonical scheme/grid tokens — so spelling variants coalesce to
    /// one cell identity.
    pub fn canon(&self) -> String {
        if !self.has_bindings() && self.grad == self.mul && self.mul == self.sub {
            return self.grad.name();
        }
        let default = Scheme::sr();
        let mut parts = Vec::new();
        if self.grad != default {
            parts.push(format!("grad={}", self.grad.name()));
        }
        if self.mul != default {
            parts.push(format!("mul={}", self.mul.name()));
        }
        if self.sub != default {
            parts.push(format!("sub={}", self.sub.name()));
        }
        for (name, b) in [("weights", self.weights), ("m", self.m), ("v", self.v)] {
            if let Some(b) = b {
                parts.push(format!("{name}={}", b.canon_token()));
            }
        }
        format!("policy:{}", parts.join(","))
    }
}

impl From<Scheme> for PolicyMap {
    fn from(scheme: Scheme) -> Self {
        Self::uniform(scheme)
    }
}

impl From<Rounding> for PolicyMap {
    fn from(mode: Rounding) -> Self {
        Self::uniform(mode.into())
    }
}

/// How the gradient (8a) is evaluated in low precision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradModel {
    /// Exact (binary64) gradient: σ₁ = 0, the `c = 0` case of eq. (9).
    Exact,
    /// chop-style: each matrix/vector *operation result* is rounded
    /// entrywise into the working format (the paper's own §2.4 methodology).
    RoundAfterOp,
    /// Strict model: every scalar elementary operation is rounded (the
    /// [13, §3.1] accumulation; slower, larger effective `c`).
    PerOp,
}

/// Configuration of one GD run.
#[derive(Debug, Clone)]
pub struct GdConfig {
    /// Working number grid for the iterate and every rounding — a
    /// floating-point format or a fixed-point Qm.n grid (both convert
    /// into [`Grid`]); the engine is backend-agnostic.
    pub grid: Grid,
    /// Rounding policy: per-site schemes for (8a)/(8b)/(8c) plus optional
    /// per-state-tensor bindings (see [`PolicyMap`]).
    pub schemes: PolicyMap,
    /// σ₁ model for the gradient evaluation (8a).
    pub grad_model: GradModel,
    /// Fixed base stepsize t (decayed per iteration by [`GdConfig::lr`]).
    pub t: f64,
    /// Number of iterations (epochs for the learning problems).
    pub steps: usize,
    /// Root seed for the run's RNG streams (ignored when [`GdConfig::rng`]
    /// is set).
    pub seed: u64,
    /// Pre-split root RNG for this run, overriding `seed` when set. The
    /// in-repo experiment builders keep the legacy seed-keyed derivation
    /// (`None` → `Rng::new(seed)`, bit-compatible with earlier releases,
    /// where repetitions of *different* configs reuse the same seed
    /// streams); stream injection — `Some(Rng::new(root).split(cell_id))`
    /// with a [`crate::coordinator::scheduler::cell_stream`] id — gives a
    /// cell a stream independent of every other cell's, regardless of
    /// thread placement or execution order (see `benches/sweep.rs` and the
    /// scheduler tests).
    pub rng: Option<Rng>,
    /// Record τ_k each iteration (costs one RN pass over the gradient).
    pub record_tau: bool,
    /// Random bits per stochastic slice rounding (the few-random-bits
    /// knob; see [`crate::fp::round::RoundPlan::with_sr_bits`]). The
    /// default [`DEFAULT_SR_BITS`] keeps trajectories bit-identical to
    /// pre-knob releases.
    pub sr_bits: u32,
    /// Divergence guard: when set, [`GdEngine::run`] terminates early with
    /// [`RunStatus::Diverged`] as soon as the exactly-evaluated loss is
    /// non-finite or exceeds this threshold. `None` (the default) preserves
    /// the historic run-to-`steps` behavior and trace lengths exactly.
    pub escape: Option<f64>,
    /// The update law driving each step (plain GD, momentum, Nesterov,
    /// Adam — see [`OptimizerSpec`]). The default `Gd` keeps trajectories
    /// bit-identical to the pre-trait engine.
    pub optimizer: OptimizerSpec,
    /// Stepsize decay schedule; the default [`LrSchedule::Constant`]
    /// applies `t` untouched.
    pub lr: LrSchedule,
}

impl GdConfig {
    /// A config with the default σ₁ model (`RoundAfterOp`), seed 0, derived
    /// RNG root, default `sr_bits`, plain-GD optimizer, constant stepsize
    /// and no τ_k recording. `grid` is any backend (`FpFormat`,
    /// `FixedPoint` or `Grid`); `schemes` is a [`PolicyMap`] or anything
    /// converting into one (a single [`Scheme`], a built-in [`Rounding`]).
    pub fn new(
        grid: impl Into<Grid>,
        schemes: impl Into<PolicyMap>,
        t: f64,
        steps: usize,
    ) -> Self {
        Self {
            grid: grid.into(),
            schemes: schemes.into(),
            grad_model: GradModel::RoundAfterOp,
            t,
            steps,
            seed: 0,
            rng: None,
            record_tau: false,
            sr_bits: DEFAULT_SR_BITS,
            escape: None,
            optimizer: OptimizerSpec::Gd,
            lr: LrSchedule::Constant,
        }
    }
}

/// The GD engine: owns the iterate, the optimizer state tensors and the
/// per-site rounding streams, and drives the configured
/// [`Optimizer`] once per step.
pub struct GdEngine<'p, P: Problem + ?Sized> {
    /// The run configuration.
    pub cfg: GdConfig,
    /// The objective being minimized.
    pub problem: &'p P,
    /// Current iterate x̂ (always exactly representable on the (8c)
    /// landing grid — `cfg.grid`, or the `weights` binding's grid).
    pub x: Vec<f64>,
    /// Numeric-health counters accumulated over every step taken so far
    /// (NaN/Inf productions, saturation clamps, underflows, stalled steps at
    /// every rounding site — optimizer-state sites included; see
    /// `docs/robustness.md`). [`Self::run`] snapshots this into the
    /// returned trace.
    pub health: RunHealth,
    ctx_grad: LpCtx,
    rng_mul: Rng,
    rng_sub: Rng,
    /// Stream of the `m` state site (untouched by plain GD).
    rng_m: Rng,
    /// Stream of the `v` state site (untouched by plain GD).
    rng_v: Rng,
    ghat: Vec<f64>,
    gexact: Vec<f64>,
    /// Scratch for the staged update of step (8b).
    mbuf: Vec<f64>,
    /// Scratch for the steering vector −ĝ of step (8b).
    vneg: Vec<f64>,
    /// Scratch for the landing point z = x̂ − m of step (8c).
    zbuf: Vec<f64>,
    /// The update law (built from `cfg.optimizer`).
    opt: Box<dyn Optimizer>,
    /// Optimizer state tensors, in [`Optimizer::state_names`] order.
    state: Vec<Vec<f64>>,
}

impl<'p, P: Problem + ?Sized> GdEngine<'p, P> {
    /// Build an engine at `x0` (rounded into the working format with RN).
    ///
    /// The root RNG is `cfg.rng` when set (scheduler-split stream), else
    /// `Rng::new(cfg.seed)`; the per-site streams (σ₁ / δ₂ / δ₃, plus the
    /// optimizer-state streams `opt_m`/`opt_v`) are forked off it. The
    /// historic forks are unchanged and the state streams are only drawn
    /// from by state-carrying optimizers, so legacy `seed`-keyed plain-GD
    /// runs are bit-identical to earlier releases.
    pub fn new(cfg: GdConfig, problem: &'p P, x0: &[f64]) -> Self {
        assert_eq!(x0.len(), problem.dim());
        let root = cfg.rng.clone().unwrap_or_else(|| Rng::new(cfg.seed));
        let mut ctx_grad = LpCtx::new(cfg.grid, cfg.schemes.grad, root.fork("sigma1", 0))
            .with_sr_bits(cfg.sr_bits);
        if cfg.grad_model == GradModel::Exact {
            ctx_grad = LpCtx::exact();
        }
        // The starting point is stored on the working grid.
        let mut x = x0.to_vec();
        let mut rng0 = root.fork("x0", 0);
        RoundPlan::new(cfg.grid).round_slice(Rounding::RoundNearestEven, &mut x, &mut rng0);
        let n = x.len();
        let opt = cfg.optimizer.build();
        let state = opt.init_state(n);
        Self {
            problem,
            x,
            health: RunHealth::default(),
            ctx_grad,
            rng_mul: root.fork("delta2", 0),
            rng_sub: root.fork("delta3", 0),
            rng_m: root.fork("opt_m", 0),
            rng_v: root.fork("opt_v", 0),
            ghat: vec![0.0; n],
            gexact: vec![0.0; n],
            mbuf: vec![0.0; n],
            vneg: vec![0.0; n],
            zbuf: vec![0.0; n],
            opt,
            state,
            cfg,
        }
    }

    /// Evaluate step (8a): the low-precision gradient ĝ = ∇f(x̂) + σ₁.
    fn eval_gradient(&mut self) {
        match self.cfg.grad_model {
            GradModel::Exact => self.problem.gradient_exact(&self.x, &mut self.ghat),
            GradModel::RoundAfterOp => {
                self.problem.gradient_rounded(&self.x, &mut self.ctx_grad, &mut self.ghat)
            }
            GradModel::PerOp => {
                self.problem.gradient_per_op(&self.x, &mut self.ctx_grad, &mut self.ghat)
            }
        }
    }

    /// One full iteration: the (8a) gradient, then the configured
    /// optimizer's update law. Returns true if the iterate moved.
    ///
    /// This is a thin driver: it resolves the [`PolicyMap`] into concrete
    /// rounding sites (run plan, `weights`/`m`/`v` bindings), evaluates the
    /// LR schedule, and hands the [`Optimizer`] a [`StepCtx`] over the
    /// engine's buffers and streams. With the plain `Gd` optimizer the
    /// dispatch lands on exactly the historic fused
    /// [`crate::fp::kernels::gd_update_health`] call — slice roundings over
    /// a precomputed [`RoundPlan`] with mode/format dispatch hoisted out of
    /// the element loop, stochastic draws batched through the
    /// few-random-bits block source, δ₂/δ₃ on their own forked streams —
    /// so trajectories are bit-identical to the pre-trait engine (see
    /// `docs/performance.md`).
    pub fn step(&mut self) -> bool {
        self.eval_gradient();
        // One plan derivation per step (not per element); reading `cfg.grid`
        // here keeps the pre-refactor semantics where a caller may adjust
        // the config between steps.
        let plan = RoundPlan::new(self.cfg.grid).with_sr_bits(self.cfg.sr_bits);
        let pol = self.cfg.schemes;
        let plan_w = pol.weights.map(|b| b.plan(self.cfg.grid, self.cfg.sr_bits));
        let plan_m = pol.m.map(|b| b.plan(self.cfg.grid, self.cfg.sr_bits));
        let plan_v = pol.v.map(|b| b.plan(self.cfg.grid, self.cfg.sr_bits));
        let mul = Site { plan: &plan, scheme: pol.mul };
        let sub = match (&plan_w, pol.weights) {
            (Some(p), Some(b)) => Site { plan: p, scheme: b.scheme },
            _ => Site { plan: &plan, scheme: pol.sub },
        };
        // Unbound state tensors round on the working grid with the (8b)
        // scheme: state accumulation is stepsize-multiplication-shaped
        // arithmetic.
        let m_site = match (&plan_m, pol.m) {
            (Some(p), Some(b)) => Site { plan: p, scheme: b.scheme },
            _ => Site { plan: &plan, scheme: pol.mul },
        };
        let v_site = match (&plan_v, pol.v) {
            (Some(p), Some(b)) => Site { plan: p, scheme: b.scheme },
            _ => Site { plan: &plan, scheme: pol.mul },
        };
        let k = self.health.steps;
        let moved = self.opt.apply_step(StepCtx {
            mul,
            sub,
            m_site,
            v_site,
            t: self.cfg.lr.at(self.cfg.t, k),
            k,
            x: &mut self.x,
            ghat: &self.ghat,
            state: &mut self.state,
            mbuf: &mut self.mbuf,
            vneg: &mut self.vneg,
            zbuf: &mut self.zbuf,
            rng_mul: &mut self.rng_mul,
            rng_sub: &mut self.rng_sub,
            rng_m: &mut self.rng_m,
            rng_v: &mut self.rng_v,
            health: &mut self.health,
        });
        self.health.steps += 1;
        if !moved {
            self.health.stalled_steps += 1;
        }
        moved
    }

    /// Rounding operations performed so far inside the (8a) gradient context
    /// (profiling; powers the rounds/sec report of `train_mlr_e2e`).
    pub fn grad_rounding_ops(&self) -> u64 {
        self.ctx_grad.rounding_ops
    }

    /// The configured update law.
    pub fn optimizer(&self) -> &dyn Optimizer {
        self.opt.as_ref()
    }

    /// Stable names of the optimizer's state tensors, in storage order.
    pub fn state_names(&self) -> &'static [&'static str] {
        self.opt.state_names()
    }

    /// A state tensor by its stable name (`"m"`, `"v"`), or `None` when
    /// the optimizer carries no tensor of that name.
    pub fn state_tensor(&self, name: &str) -> Option<&[f64]> {
        let idx = self.opt.state_names().iter().position(|&n| n == name)?;
        Some(&self.state[idx])
    }

    /// Run the configured number of steps, recording a [`Trace`].
    /// `metric` (optional) computes a task-level number per iteration, e.g.
    /// test error for the MLR/NN figures.
    ///
    /// When [`GdConfig::escape`] is set and the exactly-evaluated loss turns
    /// non-finite or exceeds the threshold, the run stops *before* taking
    /// that step: the trace gains one final record exposing the escaping
    /// loss and the status becomes [`RunStatus::Diverged`]. The engine's
    /// [`Self::health`] counters are snapshotted into the trace either way.
    pub fn run(&mut self, metric: Option<&dyn Fn(&[f64]) -> f64>) -> Trace {
        let mut trace = Trace::default();
        for k in 0..self.cfg.steps {
            // Diagnostics on the *current* iterate.
            self.problem.gradient_exact(&self.x, &mut self.gexact);
            let f = self.problem.objective(&self.x);
            let grad_norm = exact::norm2(&self.gexact);
            let dist = match self.problem.optimum() {
                Some(xs) => exact::norm2(&exact::sub(&self.x, xs)),
                None => f64::NAN,
            };
            let m = metric.map(|f| f(&self.x)).unwrap_or(f64::NAN);
            if let Some(thr) = self.cfg.escape {
                if !f.is_finite() || f > thr {
                    // Record the escaping loss without stepping further —
                    // the iterate no longer moves, so the step is `stalled`.
                    trace.push(IterRecord {
                        k,
                        f,
                        grad_norm,
                        dist_to_opt: dist,
                        tau: f64::NAN,
                        stalled: true,
                        metric: m,
                    });
                    trace.status = RunStatus::Diverged { step: k };
                    break;
                }
            }
            let tau = if self.cfg.record_tau {
                // τ_k is defined w.r.t. the computed gradient ĝ.
                self.eval_gradient();
                tau_k(&self.cfg.grid, &self.x, &self.ghat, self.cfg.t).tau
            } else {
                f64::NAN
            };
            let moved = self.step();
            trace.push(IterRecord {
                k,
                f,
                grad_norm,
                dist_to_opt: dist,
                tau,
                stalled: !moved,
                metric: m,
            });
        }
        trace.health = self.health;
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::format::FpFormat;
    use crate::fp::grid::{FixedPoint, NumberGrid};
    use crate::problems::quadratic::Quadratic;

    fn schemes_rn() -> PolicyMap {
        PolicyMap::uniform(Scheme::rn())
    }

    /// In exact arithmetic (binary64 + RN ≈ exact for these magnitudes) GD on
    /// a quadratic contracts linearly: x⁺ − x* = (1−2tλ)(x − x*) per coord.
    #[test]
    fn exact_gd_contracts_on_quadratic() {
        let p = Quadratic::diagonal(vec![1.0, 0.5], vec![0.0, 0.0]);
        let mut cfg = GdConfig::new(FpFormat::BINARY64, schemes_rn(), 0.1, 200);
        cfg.grad_model = GradModel::Exact;
        let mut e = GdEngine::new(cfg, &p, &[1.0, -1.0]);
        let tr = e.run(None);
        assert!(tr.final_f() < 1e-4 * tr.records[0].f);
        // Monotone decrease.
        for w in tr.records.windows(2) {
            assert!(w[1].f <= w[0].f + 1e-15);
        }
    }

    /// The Figure-2 phenomenon: binary8 + RN on f(x) = (x−1024)² stagnates
    /// at a point strictly away from the optimum, with τ_k ≤ u/2 from the
    /// stagnation onset onwards.
    #[test]
    fn rn_binary8_stagnates_figure2() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]); // f = (x−1024)²
        let mut cfg = GdConfig::new(FpFormat::BINARY8, schemes_rn(), 0.05, 40);
        cfg.record_tau = true;
        let mut e = GdEngine::new(cfg, &p, &[1.0]);
        let tr = e.run(None);
        let onset = tr.stagnation_onset().expect("GD should stagnate under RN");
        assert!(onset < 20, "onset={onset}");
        let xk = e.x[0];
        assert!(xk != 1024.0, "stagnated iterate should be off-optimum, got {xk}");
        // τ_k below threshold at the stalled iterations.
        let u = FpFormat::BINARY8.unit_roundoff();
        for r in tr.records.iter().filter(|r| r.k > onset) {
            assert!(r.tau <= u / 2.0 + 1e-15, "k={} tau={}", r.k, r.tau);
        }
    }

    /// SR rescues the same run: the expected objective keeps decreasing and
    /// ends far below the RN stagnation level (Gupta et al. phenomenon the
    /// paper analyses).
    #[test]
    fn sr_escapes_stagnation() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        // RN run.
        let mut cfg = GdConfig::new(FpFormat::BINARY8, schemes_rn(), 0.05, 200);
        cfg.seed = 1;
        let mut ern = GdEngine::new(cfg.clone(), &p, &[1.0]);
        let f_rn = ern.run(None).final_f();
        // SR runs (average of a few seeds).
        let mut acc = 0.0;
        let nseed = 8;
        for s in 0..nseed {
            let mut c =
                GdConfig::new(FpFormat::BINARY8, PolicyMap::uniform(Scheme::sr()), 0.05, 200);
            c.seed = 100 + s;
            let mut esr = GdEngine::new(c, &p, &[1.0]);
            acc += esr.run(None).final_f();
        }
        let f_sr = acc / nseed as f64;
        assert!(
            f_sr < 0.25 * f_rn,
            "SR should end much lower than stagnated RN: f_sr={f_sr} f_rn={f_rn}"
        );
    }

    /// signed-SRε converges faster than SR on the stagnation-prone run
    /// (the paper's headline claim, ≈2× in §5). Speed is measured as the
    /// cumulative objective along the trajectory (area under the loss curve):
    /// both runs eventually reach the representable optimum, so the *final*
    /// value does not discriminate, but the faster method accumulates less.
    #[test]
    fn signed_sr_eps_beats_sr() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        let steps = 120;
        let avg_auc = |sub: Scheme| -> f64 {
            let mut acc = 0.0;
            let nseed = 10;
            for s in 0..nseed {
                let schemes = PolicyMap::sites(Scheme::sr(), Scheme::sr(), sub);
                let mut c = GdConfig::new(FpFormat::BINARY8, schemes, 0.05, steps);
                c.seed = 10 + s;
                let mut e = GdEngine::new(c, &p, &[1.0]);
                acc += e.run(None).objective_series().iter().sum::<f64>();
            }
            acc / nseed as f64
        };
        let auc_sr = avg_auc(Scheme::sr());
        let auc_signed = avg_auc(Scheme::signed_sr_eps(0.25));
        assert!(
            auc_signed < auc_sr,
            "signed-SRε should beat SR: signed={auc_signed} sr={auc_sr}"
        );
    }

    /// A pre-split RNG stream (`cfg.rng`) fully determines the trajectory
    /// and overrides `cfg.seed` — the scheduler's determinism contract.
    #[test]
    fn explicit_rng_stream_overrides_seed() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        let mk = |rng: Option<Rng>, seed: u64| {
            let mut cfg =
                GdConfig::new(FpFormat::BINARY8, PolicyMap::uniform(Scheme::sr()), 0.05, 60);
            cfg.seed = seed;
            cfg.rng = rng;
            let mut e = GdEngine::new(cfg, &p, &[1.0]);
            e.run(None).objective_series()
        };
        let root = Rng::new(3);
        let a = mk(Some(root.split(5)), 0);
        let b = mk(Some(root.split(5)), 99); // seed must be ignored
        let c = mk(Some(root.split(6)), 0);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    /// The engine runs unchanged on a fixed-point grid: RN stagnates off
    /// the optimum once the update falls below δ/2, SR escapes (the
    /// companion paper's arXiv:2301.09511 story on the uniform grid), and
    /// the iterate stays grid-resident throughout.
    #[test]
    fn fixed_point_rn_stagnates_and_sr_escapes() {
        let fx = FixedPoint::q(3, 6); // δ = 2^-6, range [-8, 8)
        let p = Quadratic::diagonal(vec![2.0], vec![1.0]); // f = (x-1)²
        // t·∇f = 0.02·2·(x−1): far from the optimum the update exceeds
        // δ/2 ≈ 0.0078; near it RN freezes strictly away from x* = 1.
        let mut cfg = GdConfig::new(fx, schemes_rn(), 0.02, 120);
        cfg.seed = 1;
        let mut ern = GdEngine::new(cfg, &p, &[6.0]);
        let f_rn = ern.run(None).final_f();
        assert!(ern.x[0] != 1.0, "RN should stagnate off-optimum, got {}", ern.x[0]);
        assert!(NumberGrid::contains(&fx, ern.x[0]));
        // SR (averaged over seeds) ends well below the RN stagnation level.
        let mut acc = 0.0;
        let nseed = 8;
        for s in 0..nseed {
            let mut c = GdConfig::new(fx, PolicyMap::uniform(Scheme::sr()), 0.02, 120);
            c.seed = 50 + s;
            let mut esr = GdEngine::new(c, &p, &[6.0]);
            acc += esr.run(None).final_f();
            assert!(esr.x.iter().all(|&v| NumberGrid::contains(&fx, v)));
        }
        let f_sr = acc / nseed as f64;
        assert!(f_sr < 0.5 * f_rn, "SR should beat stagnated RN: sr={f_sr} rn={f_rn}");
    }

    /// The iterate always remains exactly representable in the working format.
    #[test]
    fn iterate_stays_in_format() {
        let p = Quadratic::diagonal(vec![1.0, 3.0, 0.2], vec![0.3, -2.0, 5.0]);
        let mut cfg =
            GdConfig::new(FpFormat::BINARY8, PolicyMap::uniform(Scheme::sr()), 0.07, 60);
        cfg.seed = 5;
        let mut e = GdEngine::new(cfg, &p, &[2.0, 2.0, 2.0]);
        for _ in 0..60 {
            e.step();
            for &xi in &e.x {
                assert!(FpFormat::BINARY8.contains(xi), "xi={xi}");
            }
        }
    }

    /// The divergence guard cuts an exploding run short: with t beyond the
    /// stability limit GD on a quadratic grows the loss 9× per step, so the
    /// escape threshold fires deterministically and the trace reports
    /// `Diverged` with the escaping loss in its final record. Without the
    /// guard the same run burns all configured steps.
    #[test]
    fn escape_threshold_terminates_diverging_run() {
        let p = Quadratic::diagonal(vec![2.0], vec![0.0]);
        let mk = |escape: Option<f64>| {
            let mut cfg = GdConfig::new(FpFormat::BINARY64, schemes_rn(), 1.0, 100);
            cfg.grad_model = GradModel::Exact;
            cfg.escape = escape;
            let mut e = GdEngine::new(cfg, &p, &[1.0]);
            e.run(None)
        };
        let tr = mk(Some(1e8));
        let step = match tr.status {
            RunStatus::Diverged { step } => step,
            RunStatus::Completed => panic!("guard should have fired"),
        };
        assert_eq!(tr.len(), step + 1);
        assert!(tr.len() < 100, "len={}", tr.len());
        assert!(tr.final_f() > 1e8);
        // No guard: historic behavior, full-length trace.
        let tr_off = mk(None);
        assert!(tr_off.status.is_completed());
        assert_eq!(tr_off.len(), 100);
    }

    /// A non-finite loss also trips the guard, and the (8b) overflow that
    /// caused it shows up in the trace's health counters.
    #[test]
    fn nonfinite_loss_trips_guard_and_counts_nan_inf() {
        // t beyond the stability limit: |1 − 2tλ| = 3, so the iterate grows
        // ~3× per step until t·ĝ overflows binary8's range and RN produces
        // an Inf at the (8b) rounding site.
        let p = Quadratic::diagonal(vec![2.0], vec![0.0]);
        let mut cfg = GdConfig::new(FpFormat::BINARY8, schemes_rn(), 1.0, 2000);
        cfg.grad_model = GradModel::Exact;
        cfg.escape = Some(f64::INFINITY); // only non-finiteness can fire it
        let mut e = GdEngine::new(cfg, &p, &[1.0]);
        let tr = e.run(None);
        assert!(matches!(tr.status, RunStatus::Diverged { .. }));
        assert!(!tr.final_f().is_finite());
        assert!(tr.health.nan_inf > 0, "{}", tr.health.summary());
    }

    /// The stalled-step counter agrees with the per-record `stalled` flags on
    /// the Figure-2 stagnation run, and the stagnated RN run is otherwise
    /// numerically clean (no overflow, no saturation).
    #[test]
    fn health_counts_stalled_steps_on_stagnating_run() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        let mut cfg = GdConfig::new(FpFormat::BINARY8, schemes_rn(), 0.05, 40);
        cfg.seed = 1;
        let mut e = GdEngine::new(cfg, &p, &[1.0]);
        let tr = e.run(None);
        let stalled = tr.records.iter().filter(|r| r.stalled).count() as u64;
        assert!(stalled > 0, "Figure-2 run should stall");
        assert_eq!(tr.health.stalled_steps, stalled);
        assert_eq!(tr.health.steps, 40);
        assert_eq!(tr.health.nan_inf, 0, "{}", tr.health.summary());
    }

    /// The bit-identity contract of the refactor: with the plain `Gd`
    /// optimizer the engine reproduces the pre-trait engine body — the
    /// same forked streams ("sigma1"/"x0"/"delta2"/"delta3"), the same
    /// per-step plan derivation, the same fused kernel call — bit-exactly,
    /// for every built-in scheme.
    #[test]
    fn gd_path_is_bit_identical_to_direct_kernel_loop() {
        use crate::fp::kernels;
        let p = Quadratic::diagonal(vec![2.0, 0.7, 1.3], vec![1024.0, -3.0, 0.5]);
        let x0 = [1.0, 2.0, -0.5];
        let steps = 50;
        for scheme in [
            Scheme::rn(),
            Scheme::rd(),
            Scheme::ru(),
            Scheme::rz(),
            Scheme::sr(),
            Scheme::sr_eps(0.25),
            Scheme::signed_sr_eps(0.25),
        ] {
            let mut cfg =
                GdConfig::new(FpFormat::BINARY8, PolicyMap::uniform(scheme), 0.05, steps);
            cfg.seed = 7;
            let mut e = GdEngine::new(cfg.clone(), &p, &x0);
            for _ in 0..steps {
                e.step();
            }
            // The pre-refactor engine body, inlined.
            let root = Rng::new(cfg.seed);
            let mut ctx =
                LpCtx::new(cfg.grid, scheme, root.fork("sigma1", 0)).with_sr_bits(cfg.sr_bits);
            let mut x = x0.to_vec();
            RoundPlan::new(cfg.grid).round_slice(
                Rounding::RoundNearestEven,
                &mut x,
                &mut root.fork("x0", 0),
            );
            let (mut rng_mul, mut rng_sub) = (root.fork("delta2", 0), root.fork("delta3", 0));
            let n = x.len();
            let (mut ghat, mut mbuf, mut vneg, mut zbuf) =
                (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
            let mut health = RunHealth::default();
            for _ in 0..steps {
                p.gradient_rounded(&x, &mut ctx, &mut ghat);
                let plan = RoundPlan::new(cfg.grid).with_sr_bits(cfg.sr_bits);
                kernels::gd_update_health(
                    &plan, scheme, scheme, cfg.t, &mut x, &ghat, &mut mbuf, &mut vneg,
                    &mut zbuf, &mut rng_mul, &mut rng_sub, &mut health,
                );
            }
            for (a, b) in e.x.iter().zip(&x) {
                assert_eq!(a.to_bits(), b.to_bits(), "scheme {}", scheme.name());
            }
        }
    }

    /// PolicyMap spec strings parse, canonicalize with default elision, and
    /// round-trip — so spelling variants coalesce to one identity.
    #[test]
    fn policy_specs_parse_and_canonicalize() {
        // Uniform spellings collapse to the bare canonical scheme name.
        for spec in ["sr", "SR", " sr "] {
            assert_eq!(PolicyMap::parse(spec).unwrap().canon(), "sr");
        }
        assert_eq!(PolicyMap::parse("signed:0.4").unwrap().canon(), "signed_sr_eps:0.4");
        // The headline grammar: per-tensor bindings with grid aliases.
        let p = PolicyMap::parse("policy:weights=sr_eps:0.4@bf16,m=rn@fp32").unwrap();
        assert_eq!(p.weights.unwrap().scheme, Scheme::sr_eps(0.4));
        assert_eq!(p.weights.unwrap().grid, Some(Grid::from(FpFormat::BFLOAT16)));
        assert_eq!(p.m.unwrap().grid, Some(Grid::from(FpFormat::BINARY32)));
        assert_eq!(p.canon(), "policy:weights=sr_eps:0.4@bfloat16,m=rn@binary32");
        assert_eq!(PolicyMap::parse(&p.canon()).unwrap(), p);
        // Base sites take bare schemes; default (sr) sites are elided.
        let q = PolicyMap::parse("policy:8a=sr,8b=SR,8c=signed_sr_eps:0.25").unwrap();
        assert_eq!(q.sub, Scheme::signed_sr_eps(0.25));
        assert_eq!(q.canon(), "policy:sub=signed_sr_eps:0.25");
        assert_eq!(PolicyMap::parse(&q.canon()).unwrap(), q);
        // sr_bits bindings round-trip too.
        let r = PolicyMap::parse("policy:m=sr@bf16#8,v=sr@fp16").unwrap();
        assert_eq!(r.m.unwrap().sr_bits, Some(8));
        assert_eq!(r.v.unwrap().grid, Some(Grid::from(FpFormat::BINARY16)));
        assert_eq!(PolicyMap::parse(&r.canon()).unwrap(), r);
        // Errors: malformed entries, unknown tensors/grids/schemes, and
        // @grid on a base site.
        for bad in [
            "policy:q=rn",
            "policy:8b=rn@bf16",
            "policy:weights=rn@nosuch",
            "policy:weights=bogus",
            "policy:weights",
            "policy:m=sr@bf16#99",
        ] {
            assert!(PolicyMap::parse(bad).is_err(), "{bad}");
        }
    }

    /// Momentum, Nesterov and Adam all contract on a well-conditioned
    /// quadratic in exact arithmetic — the update laws are wired correctly.
    #[test]
    fn stateful_optimizers_converge_in_exact_arithmetic() {
        let p = Quadratic::diagonal(vec![1.0, 0.5], vec![0.0, 0.0]);
        for opt in [
            OptimizerSpec::Momentum { beta: 0.9 },
            OptimizerSpec::Nesterov { beta: 0.9 },
            OptimizerSpec::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
        ] {
            let mut cfg = GdConfig::new(FpFormat::BINARY64, schemes_rn(), 0.05, 400);
            cfg.grad_model = GradModel::Exact;
            cfg.optimizer = opt;
            let mut e = GdEngine::new(cfg, &p, &[1.0, -1.0]);
            let tr = e.run(None);
            assert!(
                tr.final_f() < 1e-4 * tr.records[0].f,
                "{opt:?}: f0={} fT={}",
                tr.records[0].f,
                tr.final_f()
            );
        }
    }

    /// The paper's stagnation-vs-scheme story carries over to the momentum
    /// buffer: on bfloat16 with RN everywhere the run freezes off-optimum,
    /// while SR state rounding keeps moving (averaged over seeds).
    #[test]
    fn momentum_rn_stagnates_and_sr_state_escapes_on_bf16() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        let run = |policy: PolicyMap, seed: u64| {
            let mut cfg = GdConfig::new(FpFormat::BFLOAT16, policy, 0.02, 300);
            cfg.optimizer = OptimizerSpec::Momentum { beta: 0.9 };
            cfg.seed = seed;
            let mut e = GdEngine::new(cfg, &p, &[1.0]);
            e.run(None).final_f()
        };
        let f_rn = run(PolicyMap::uniform(Scheme::rn()), 1);
        let mut acc = 0.0;
        let nseed = 6;
        for s in 0..nseed {
            acc += run(PolicyMap::uniform(Scheme::sr()), 100 + s);
        }
        let f_sr = acc / nseed as f64;
        assert!(
            f_sr < 0.5 * f_rn,
            "SR should escape the momentum stagnation: sr={f_sr} rn={f_rn}"
        );
    }

    /// A `weights=rn@binary64` binding is the master-weights lane: updates
    /// still round on the working grid, but the iterate accumulates in high
    /// precision and leaves the low-precision grid.
    #[test]
    fn master_weights_binding_accumulates_off_the_working_grid() {
        let p = Quadratic::diagonal(vec![2.0, 0.7], vec![0.3, -1.2]);
        let policy = PolicyMap::uniform(Scheme::sr())
            .with_weights(TensorPolicy::new(Scheme::rn()).on(FpFormat::BINARY64));
        let mut cfg = GdConfig::new(FpFormat::BINARY8, policy, 0.05, 60);
        cfg.seed = 3;
        let mut e = GdEngine::new(cfg, &p, &[2.0, 2.0]);
        let tr = e.run(None);
        assert!(tr.status.is_completed());
        // The iterate escaped binary8 (sums of rounded updates are not
        // representable in a 2-bit significand), and the run got closer to
        // the optimum than the format could express.
        assert!(
            e.x.iter().any(|&xi| !FpFormat::BINARY8.contains(xi)),
            "master weights should leave the working grid: {:?}",
            e.x
        );
        assert!(tr.final_f() < tr.records[0].f);
    }

    /// LR schedules decay the effective stepsize: in exact arithmetic the
    /// staircase schedule reproduces the hand-computed trajectory.
    #[test]
    fn lr_schedule_decays_effective_stepsize() {
        let p = Quadratic::diagonal(vec![0.5], vec![0.0]); // ∇f = x
        let mut cfg = GdConfig::new(FpFormat::BINARY64, schemes_rn(), 0.5, 4);
        cfg.grad_model = GradModel::Exact;
        cfg.lr = LrSchedule::Step { gamma: 0.5, period: 2 };
        let mut e = GdEngine::new(cfg, &p, &[1.0]);
        let mut want = 1.0f64;
        for k in 0u64..4 {
            e.step();
            let tk = 0.5 * 0.5f64.powi((k / 2) as i32);
            want -= tk * want;
            assert_eq!(e.x[0], want, "k={k}");
        }
    }

    /// State tensors are reachable by their stable names, and absent on
    /// plain GD.
    #[test]
    fn state_tensors_are_enumerable_by_name() {
        let p = Quadratic::diagonal(vec![2.0], vec![0.0]);
        let mut cfg = GdConfig::new(FpFormat::BINARY64, schemes_rn(), 0.1, 10);
        cfg.optimizer = OptimizerSpec::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 };
        let mut e = GdEngine::new(cfg, &p, &[1.0]);
        assert_eq!(e.state_names(), &["m", "v"]);
        e.step();
        assert!(e.state_tensor("m").unwrap()[0] != 0.0);
        assert!(e.state_tensor("v").unwrap()[0] != 0.0);
        assert!(e.state_tensor("bogus").is_none());
        let cfg_gd = GdConfig::new(FpFormat::BINARY64, schemes_rn(), 0.1, 10);
        let e_gd = GdEngine::new(cfg_gd, &p, &[1.0]);
        assert_eq!(e_gd.state_names(), &[] as &[&str]);
        assert!(e_gd.state_tensor("m").is_none());
    }
}
