//! The GD iteration under floating-point rounding — paper eq. (8):
//!
//! ```text
//! (8a)  ĝ = ∇f(x̂) + σ₁          gradient evaluated in low precision
//! (8b)  m = fl₂(t · ĝ)           stepsize multiplication, error δ₂
//! (8c)  x̂⁺ = fl₃(x̂ − m)          subtraction, error δ₃
//! ```
//!
//! Each step's rounding scheme is chosen independently ([`SchemePolicy`],
//! holding any registered [`crate::fp::scheme::Scheme`]; the legacy
//! enum-typed [`StepSchemes`] converts into it), which is exactly the
//! paper's experimental protocol (e.g. Fig. 4b: SRε for (8a)+(8b),
//! signed-SRε for (8c)). For `SignedSrEps` the steering value is
//!
//! * `(8b)`: `v = −ĝᵢ` — bias `−sign(v) = +sign(ĝᵢ)` *enlarges* the step in
//!   the gradient direction (the descent choice; with this steering the law
//!   coincides with `SRε(t·ĝᵢ)` since `sign(t·ĝᵢ) = sign(ĝᵢ)`);
//! * `(8c)`: `v = +ĝᵢ` — bias `−sign(ĝᵢ)` on the new iterate, i.e. a descent
//!   direction, exactly as §4.2.2 prescribes ("replacing v with the
//!   components of the gradient vector").

use crate::fp::grid::Grid;
use crate::fp::linalg::{exact, LpCtx};
use crate::fp::rng::Rng;
use crate::fp::round::{Rounding, RunHealth, DEFAULT_SR_BITS};
use crate::fp::scheme::Scheme;
use crate::gd::stagnation::tau_k;
use crate::gd::trace::{IterRecord, RunStatus, Trace};
use crate::problems::Problem;

/// Per-tensor rounding policy of one GD run: an independent open-API
/// [`Scheme`] for each of the three rounding sites of eq. (8) — the
/// gradient evaluation (8a), the stepsize multiplication (8b) and the
/// iterate subtraction (8c). This generalizes the legacy enum-typed
/// [`StepSchemes`] (which converts via `From`) to any registered scheme,
/// including user schemes added through
/// [`crate::fp::scheme::SchemeRegistry::register`].
#[derive(Debug, Clone, Copy)]
pub struct SchemePolicy {
    /// Scheme used *inside* the gradient evaluation (8a).
    pub grad: Scheme,
    /// Scheme for the stepsize multiplication (8b).
    pub mul: Scheme,
    /// Scheme for the final subtraction (8c).
    pub sub: Scheme,
}

impl SchemePolicy {
    /// All three steps with the same scheme.
    pub fn uniform(scheme: Scheme) -> Self {
        Self { grad: scheme, mul: scheme, sub: scheme }
    }

    /// Short per-step label, e.g. `8a=SR 8b=SR 8c=signed-SR_eps(0.1)`.
    pub fn label(&self) -> String {
        format!("8a={} 8b={} 8c={}", self.grad.label(), self.mul.label(), self.sub.label())
    }

    /// Does any of the three steps consume randomness?
    pub fn is_stochastic(&self) -> bool {
        self.grad.is_stochastic() || self.mul.is_stochastic() || self.sub.is_stochastic()
    }
}

impl From<StepSchemes> for SchemePolicy {
    fn from(s: StepSchemes) -> Self {
        Self { grad: s.grad.into(), mul: s.mul.into(), sub: s.sub.into() }
    }
}

impl From<Scheme> for SchemePolicy {
    fn from(scheme: Scheme) -> Self {
        Self::uniform(scheme)
    }
}

impl From<Rounding> for SchemePolicy {
    fn from(mode: Rounding) -> Self {
        Self::uniform(mode.into())
    }
}

/// Rounding scheme per GD step, over the closed built-in enum.
///
/// **Deprecated shim**: kept so pre-redesign call sites keep compiling;
/// it converts losslessly into the open [`SchemePolicy`] (which
/// [`GdConfig::new`] and [`crate::gd::RunBuilder`] accept directly).
#[derive(Debug, Clone, Copy)]
pub struct StepSchemes {
    /// Scheme used *inside* the gradient evaluation (8a).
    pub grad: Rounding,
    /// Scheme for the stepsize multiplication (8b).
    pub mul: Rounding,
    /// Scheme for the final subtraction (8c).
    pub sub: Rounding,
}

impl StepSchemes {
    /// All three steps with the same scheme.
    pub fn uniform(mode: Rounding) -> Self {
        Self { grad: mode, mul: mode, sub: mode }
    }

    /// This legacy triple as an open-API [`SchemePolicy`].
    pub fn policy(self) -> SchemePolicy {
        self.into()
    }

    /// Short per-step label, e.g. `8a=SR 8b=SR 8c=signed-SR_eps(0.1)`.
    pub fn label(&self) -> String {
        self.policy().label()
    }
}

/// How the gradient (8a) is evaluated in low precision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradModel {
    /// Exact (binary64) gradient: σ₁ = 0, the `c = 0` case of eq. (9).
    Exact,
    /// chop-style: each matrix/vector *operation result* is rounded
    /// entrywise into the working format (the paper's own §2.4 methodology).
    RoundAfterOp,
    /// Strict model: every scalar elementary operation is rounded (the
    /// [13, §3.1] accumulation; slower, larger effective `c`).
    PerOp,
}

/// Configuration of one GD run.
#[derive(Debug, Clone)]
pub struct GdConfig {
    /// Working number grid for the iterate and every rounding — a
    /// floating-point format or a fixed-point Qm.n grid (both convert
    /// into [`Grid`]); the engine is backend-agnostic.
    pub grid: Grid,
    /// Rounding scheme per GD step (8a)/(8b)/(8c) — any registered
    /// [`Scheme`] per step.
    pub schemes: SchemePolicy,
    /// σ₁ model for the gradient evaluation (8a).
    pub grad_model: GradModel,
    /// Fixed stepsize t.
    pub t: f64,
    /// Number of iterations (epochs for the learning problems).
    pub steps: usize,
    /// Root seed for the run's RNG streams (ignored when [`GdConfig::rng`]
    /// is set).
    pub seed: u64,
    /// Pre-split root RNG for this run, overriding `seed` when set. The
    /// in-repo experiment builders keep the legacy seed-keyed derivation
    /// (`None` → `Rng::new(seed)`, bit-compatible with earlier releases,
    /// where repetitions of *different* configs reuse the same seed
    /// streams); stream injection — `Some(Rng::new(root).split(cell_id))`
    /// with a [`crate::coordinator::scheduler::cell_stream`] id — gives a
    /// cell a stream independent of every other cell's, regardless of
    /// thread placement or execution order (see `benches/sweep.rs` and the
    /// scheduler tests).
    pub rng: Option<Rng>,
    /// Record τ_k each iteration (costs one RN pass over the gradient).
    pub record_tau: bool,
    /// Random bits per stochastic slice rounding (the few-random-bits
    /// knob; see [`crate::fp::round::RoundPlan::with_sr_bits`]). The
    /// default [`DEFAULT_SR_BITS`] keeps trajectories bit-identical to
    /// pre-knob releases.
    pub sr_bits: u32,
    /// Divergence guard: when set, [`GdEngine::run`] terminates early with
    /// [`RunStatus::Diverged`] as soon as the exactly-evaluated loss is
    /// non-finite or exceeds this threshold. `None` (the default) preserves
    /// the historic run-to-`steps` behavior and trace lengths exactly.
    pub escape: Option<f64>,
}

impl GdConfig {
    /// A config with the default σ₁ model (`RoundAfterOp`), seed 0, derived
    /// RNG root, default `sr_bits` and no τ_k recording. `grid` is any
    /// backend (`FpFormat`, `FixedPoint` or `Grid`); `schemes` is a
    /// [`SchemePolicy`] or anything converting into one ([`StepSchemes`],
    /// a single [`Scheme`], a legacy [`Rounding`]).
    pub fn new(
        grid: impl Into<Grid>,
        schemes: impl Into<SchemePolicy>,
        t: f64,
        steps: usize,
    ) -> Self {
        Self {
            grid: grid.into(),
            schemes: schemes.into(),
            grad_model: GradModel::RoundAfterOp,
            t,
            steps,
            seed: 0,
            rng: None,
            record_tau: false,
            sr_bits: DEFAULT_SR_BITS,
            escape: None,
        }
    }
}

/// The GD engine. Owns the iterate and the per-step rounding streams.
pub struct GdEngine<'p, P: Problem + ?Sized> {
    /// The run configuration.
    pub cfg: GdConfig,
    /// The objective being minimized.
    pub problem: &'p P,
    /// Current iterate x̂ (always exactly representable on `cfg.grid`).
    pub x: Vec<f64>,
    /// Numeric-health counters accumulated over every step taken so far
    /// (NaN/Inf productions, saturation clamps, underflows, stalled steps at
    /// the (8b)/(8c) rounding sites — see `docs/robustness.md`). [`Self::run`]
    /// snapshots this into the returned trace.
    pub health: RunHealth,
    ctx_grad: LpCtx,
    rng_mul: Rng,
    rng_sub: Rng,
    ghat: Vec<f64>,
    gexact: Vec<f64>,
    /// Scratch for the rounded update m = fl₂(t·ĝ) of step (8b).
    mbuf: Vec<f64>,
    /// Scratch for the steering vector −ĝ of step (8b).
    vneg: Vec<f64>,
    /// Scratch for the landing point z = x̂ − m of step (8c).
    zbuf: Vec<f64>,
}

impl<'p, P: Problem + ?Sized> GdEngine<'p, P> {
    /// Build an engine at `x0` (rounded into the working format with RN).
    ///
    /// The root RNG is `cfg.rng` when set (scheduler-split stream), else
    /// `Rng::new(cfg.seed)`; the three per-step streams (σ₁ / δ₂ / δ₃) are
    /// forked off it exactly as before, so legacy `seed`-keyed runs are
    /// bit-identical to earlier releases.
    pub fn new(cfg: GdConfig, problem: &'p P, x0: &[f64]) -> Self {
        assert_eq!(x0.len(), problem.dim());
        let root = cfg.rng.clone().unwrap_or_else(|| Rng::new(cfg.seed));
        let mut ctx_grad = LpCtx::new(cfg.grid, cfg.schemes.grad, root.fork("sigma1", 0))
            .with_sr_bits(cfg.sr_bits);
        if cfg.grad_model == GradModel::Exact {
            ctx_grad = LpCtx::exact();
        }
        // The starting point is stored on the working grid.
        let mut x = x0.to_vec();
        let mut rng0 = root.fork("x0", 0);
        crate::fp::round::RoundPlan::new(cfg.grid)
            .round_slice(Rounding::RoundNearestEven, &mut x, &mut rng0);
        let n = x.len();
        Self {
            problem,
            x,
            health: RunHealth::default(),
            ctx_grad,
            rng_mul: root.fork("delta2", 0),
            rng_sub: root.fork("delta3", 0),
            ghat: vec![0.0; n],
            gexact: vec![0.0; n],
            mbuf: vec![0.0; n],
            vneg: vec![0.0; n],
            zbuf: vec![0.0; n],
            cfg,
        }
    }

    /// Evaluate step (8a): the low-precision gradient ĝ = ∇f(x̂) + σ₁.
    fn eval_gradient(&mut self) {
        match self.cfg.grad_model {
            GradModel::Exact => self.problem.gradient_exact(&self.x, &mut self.ghat),
            GradModel::RoundAfterOp => {
                self.problem.gradient_rounded(&self.x, &mut self.ctx_grad, &mut self.ghat)
            }
            GradModel::PerOp => {
                self.problem.gradient_per_op(&self.x, &mut self.ctx_grad, &mut self.ghat)
            }
        }
    }

    /// One full GD iteration (8a)+(8b)+(8c). Returns true if the iterate moved.
    ///
    /// Steps (8b) and (8c) run through the fused
    /// [`crate::fp::kernels::gd_update`] kernel: slice roundings over a
    /// precomputed [`crate::fp::round::RoundPlan`] with mode/format dispatch
    /// hoisted out of the element loop, and the stochastic draws batched
    /// through the few-random-bits block source. δ₂ and δ₃ draw from their
    /// own forked streams as before; deterministic modes consume no
    /// randomness, so their trajectories are bit-identical to the historic
    /// per-element path (see `docs/performance.md`).
    pub fn step(&mut self) -> bool {
        self.eval_gradient();
        // One plan derivation per step (not per element); reading `cfg.grid`
        // here keeps the pre-refactor semantics where a caller may adjust
        // the config between steps.
        let plan =
            crate::fp::round::RoundPlan::new(self.cfg.grid).with_sr_bits(self.cfg.sr_bits);
        let moved = crate::fp::kernels::gd_update_health(
            &plan,
            self.cfg.schemes.mul,
            self.cfg.schemes.sub,
            self.cfg.t,
            &mut self.x,
            &self.ghat,
            &mut self.mbuf,
            &mut self.vneg,
            &mut self.zbuf,
            &mut self.rng_mul,
            &mut self.rng_sub,
            &mut self.health,
        );
        self.health.steps += 1;
        if !moved {
            self.health.stalled_steps += 1;
        }
        moved
    }

    /// Rounding operations performed so far inside the (8a) gradient context
    /// (profiling; powers the rounds/sec report of `train_mlr_e2e`).
    pub fn grad_rounding_ops(&self) -> u64 {
        self.ctx_grad.rounding_ops
    }

    /// Run the configured number of steps, recording a [`Trace`].
    /// `metric` (optional) computes a task-level number per iteration, e.g.
    /// test error for the MLR/NN figures.
    ///
    /// When [`GdConfig::escape`] is set and the exactly-evaluated loss turns
    /// non-finite or exceeds the threshold, the run stops *before* taking
    /// that step: the trace gains one final record exposing the escaping
    /// loss and the status becomes [`RunStatus::Diverged`]. The engine's
    /// [`Self::health`] counters are snapshotted into the trace either way.
    pub fn run(&mut self, metric: Option<&dyn Fn(&[f64]) -> f64>) -> Trace {
        let mut trace = Trace::default();
        for k in 0..self.cfg.steps {
            // Diagnostics on the *current* iterate.
            self.problem.gradient_exact(&self.x, &mut self.gexact);
            let f = self.problem.objective(&self.x);
            let grad_norm = exact::norm2(&self.gexact);
            let dist = match self.problem.optimum() {
                Some(xs) => exact::norm2(&exact::sub(&self.x, xs)),
                None => f64::NAN,
            };
            let m = metric.map(|f| f(&self.x)).unwrap_or(f64::NAN);
            if let Some(thr) = self.cfg.escape {
                if !f.is_finite() || f > thr {
                    // Record the escaping loss without stepping further —
                    // the iterate no longer moves, so the step is `stalled`.
                    trace.push(IterRecord {
                        k,
                        f,
                        grad_norm,
                        dist_to_opt: dist,
                        tau: f64::NAN,
                        stalled: true,
                        metric: m,
                    });
                    trace.status = RunStatus::Diverged { step: k };
                    break;
                }
            }
            let tau = if self.cfg.record_tau {
                // τ_k is defined w.r.t. the computed gradient ĝ.
                self.eval_gradient();
                tau_k(&self.cfg.grid, &self.x, &self.ghat, self.cfg.t).tau
            } else {
                f64::NAN
            };
            let moved = self.step();
            trace.push(IterRecord {
                k,
                f,
                grad_norm,
                dist_to_opt: dist,
                tau,
                stalled: !moved,
                metric: m,
            });
        }
        trace.health = self.health;
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::format::FpFormat;
    use crate::fp::grid::{FixedPoint, NumberGrid};
    use crate::problems::quadratic::Quadratic;

    fn schemes_rn() -> StepSchemes {
        StepSchemes::uniform(Rounding::RoundNearestEven)
    }

    /// In exact arithmetic (binary64 + RN ≈ exact for these magnitudes) GD on
    /// a quadratic contracts linearly: x⁺ − x* = (1−2tλ)(x − x*) per coord.
    #[test]
    fn exact_gd_contracts_on_quadratic() {
        let p = Quadratic::diagonal(vec![1.0, 0.5], vec![0.0, 0.0]);
        let mut cfg = GdConfig::new(FpFormat::BINARY64, schemes_rn(), 0.1, 200);
        cfg.grad_model = GradModel::Exact;
        let mut e = GdEngine::new(cfg, &p, &[1.0, -1.0]);
        let tr = e.run(None);
        assert!(tr.final_f() < 1e-4 * tr.records[0].f);
        // Monotone decrease.
        for w in tr.records.windows(2) {
            assert!(w[1].f <= w[0].f + 1e-15);
        }
    }

    /// The Figure-2 phenomenon: binary8 + RN on f(x) = (x−1024)² stagnates
    /// at a point strictly away from the optimum, with τ_k ≤ u/2 from the
    /// stagnation onset onwards.
    #[test]
    fn rn_binary8_stagnates_figure2() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]); // f = (x−1024)²
        let mut cfg = GdConfig::new(FpFormat::BINARY8, schemes_rn(), 0.05, 40);
        cfg.record_tau = true;
        let mut e = GdEngine::new(cfg, &p, &[1.0]);
        let tr = e.run(None);
        let onset = tr.stagnation_onset().expect("GD should stagnate under RN");
        assert!(onset < 20, "onset={onset}");
        let xk = e.x[0];
        assert!(xk != 1024.0, "stagnated iterate should be off-optimum, got {xk}");
        // τ_k below threshold at the stalled iterations.
        let u = FpFormat::BINARY8.unit_roundoff();
        for r in tr.records.iter().filter(|r| r.k > onset) {
            assert!(r.tau <= u / 2.0 + 1e-15, "k={} tau={}", r.k, r.tau);
        }
    }

    /// SR rescues the same run: the expected objective keeps decreasing and
    /// ends far below the RN stagnation level (Gupta et al. phenomenon the
    /// paper analyses).
    #[test]
    fn sr_escapes_stagnation() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        // RN run.
        let mut cfg = GdConfig::new(FpFormat::BINARY8, schemes_rn(), 0.05, 200);
        cfg.seed = 1;
        let mut ern = GdEngine::new(cfg.clone(), &p, &[1.0]);
        let f_rn = ern.run(None).final_f();
        // SR runs (average of a few seeds).
        let mut acc = 0.0;
        let nseed = 8;
        for s in 0..nseed {
            let mut c = GdConfig::new(FpFormat::BINARY8, StepSchemes::uniform(Rounding::Sr), 0.05, 200);
            c.seed = 100 + s;
            let mut esr = GdEngine::new(c, &p, &[1.0]);
            acc += esr.run(None).final_f();
        }
        let f_sr = acc / nseed as f64;
        assert!(
            f_sr < 0.25 * f_rn,
            "SR should end much lower than stagnated RN: f_sr={f_sr} f_rn={f_rn}"
        );
    }

    /// signed-SRε converges faster than SR on the stagnation-prone run
    /// (the paper's headline claim, ≈2× in §5). Speed is measured as the
    /// cumulative objective along the trajectory (area under the loss curve):
    /// both runs eventually reach the representable optimum, so the *final*
    /// value does not discriminate, but the faster method accumulates less.
    #[test]
    fn signed_sr_eps_beats_sr() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        let steps = 120;
        let avg_auc = |sub: Rounding| -> f64 {
            let mut acc = 0.0;
            let nseed = 10;
            for s in 0..nseed {
                let schemes = StepSchemes { grad: Rounding::Sr, mul: Rounding::Sr, sub };
                let mut c = GdConfig::new(FpFormat::BINARY8, schemes, 0.05, steps);
                c.seed = 10 + s;
                let mut e = GdEngine::new(c, &p, &[1.0]);
                acc += e.run(None).objective_series().iter().sum::<f64>();
            }
            acc / nseed as f64
        };
        let auc_sr = avg_auc(Rounding::Sr);
        let auc_signed = avg_auc(Rounding::SignedSrEps(0.25));
        assert!(
            auc_signed < auc_sr,
            "signed-SRε should beat SR: signed={auc_signed} sr={auc_sr}"
        );
    }

    /// A pre-split RNG stream (`cfg.rng`) fully determines the trajectory
    /// and overrides `cfg.seed` — the scheduler's determinism contract.
    #[test]
    fn explicit_rng_stream_overrides_seed() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        let mk = |rng: Option<Rng>, seed: u64| {
            let mut cfg =
                GdConfig::new(FpFormat::BINARY8, StepSchemes::uniform(Rounding::Sr), 0.05, 60);
            cfg.seed = seed;
            cfg.rng = rng;
            let mut e = GdEngine::new(cfg, &p, &[1.0]);
            e.run(None).objective_series()
        };
        let root = Rng::new(3);
        let a = mk(Some(root.split(5)), 0);
        let b = mk(Some(root.split(5)), 99); // seed must be ignored
        let c = mk(Some(root.split(6)), 0);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    /// The engine runs unchanged on a fixed-point grid: RN stagnates off
    /// the optimum once the update falls below δ/2, SR escapes (the
    /// companion paper's arXiv:2301.09511 story on the uniform grid), and
    /// the iterate stays grid-resident throughout.
    #[test]
    fn fixed_point_rn_stagnates_and_sr_escapes() {
        let fx = FixedPoint::q(3, 6); // δ = 2^-6, range [-8, 8)
        let p = Quadratic::diagonal(vec![2.0], vec![1.0]); // f = (x-1)²
        // t·∇f = 0.02·2·(x−1): far from the optimum the update exceeds
        // δ/2 ≈ 0.0078; near it RN freezes strictly away from x* = 1.
        let mut cfg = GdConfig::new(fx, schemes_rn(), 0.02, 120);
        cfg.seed = 1;
        let mut ern = GdEngine::new(cfg, &p, &[6.0]);
        let f_rn = ern.run(None).final_f();
        assert!(ern.x[0] != 1.0, "RN should stagnate off-optimum, got {}", ern.x[0]);
        assert!(NumberGrid::contains(&fx, ern.x[0]));
        // SR (averaged over seeds) ends well below the RN stagnation level.
        let mut acc = 0.0;
        let nseed = 8;
        for s in 0..nseed {
            let mut c = GdConfig::new(fx, StepSchemes::uniform(Rounding::Sr), 0.02, 120);
            c.seed = 50 + s;
            let mut esr = GdEngine::new(c, &p, &[6.0]);
            acc += esr.run(None).final_f();
            assert!(esr.x.iter().all(|&v| NumberGrid::contains(&fx, v)));
        }
        let f_sr = acc / nseed as f64;
        assert!(f_sr < 0.5 * f_rn, "SR should beat stagnated RN: sr={f_sr} rn={f_rn}");
    }

    /// The iterate always remains exactly representable in the working format.
    #[test]
    fn iterate_stays_in_format() {
        let p = Quadratic::diagonal(vec![1.0, 3.0, 0.2], vec![0.3, -2.0, 5.0]);
        let mut cfg =
            GdConfig::new(FpFormat::BINARY8, StepSchemes::uniform(Rounding::Sr), 0.07, 60);
        cfg.seed = 5;
        let mut e = GdEngine::new(cfg, &p, &[2.0, 2.0, 2.0]);
        for _ in 0..60 {
            e.step();
            for &xi in &e.x {
                assert!(FpFormat::BINARY8.contains(xi), "xi={xi}");
            }
        }
    }

    /// The divergence guard cuts an exploding run short: with t beyond the
    /// stability limit GD on a quadratic grows the loss 9× per step, so the
    /// escape threshold fires deterministically and the trace reports
    /// `Diverged` with the escaping loss in its final record. Without the
    /// guard the same run burns all configured steps.
    #[test]
    fn escape_threshold_terminates_diverging_run() {
        let p = Quadratic::diagonal(vec![2.0], vec![0.0]);
        let mk = |escape: Option<f64>| {
            let mut cfg = GdConfig::new(FpFormat::BINARY64, schemes_rn(), 1.0, 100);
            cfg.grad_model = GradModel::Exact;
            cfg.escape = escape;
            let mut e = GdEngine::new(cfg, &p, &[1.0]);
            e.run(None)
        };
        let tr = mk(Some(1e8));
        let step = match tr.status {
            RunStatus::Diverged { step } => step,
            RunStatus::Completed => panic!("guard should have fired"),
        };
        assert_eq!(tr.len(), step + 1);
        assert!(tr.len() < 100, "len={}", tr.len());
        assert!(tr.final_f() > 1e8);
        // No guard: historic behavior, full-length trace.
        let tr_off = mk(None);
        assert!(tr_off.status.is_completed());
        assert_eq!(tr_off.len(), 100);
    }

    /// A non-finite loss also trips the guard, and the (8b) overflow that
    /// caused it shows up in the trace's health counters.
    #[test]
    fn nonfinite_loss_trips_guard_and_counts_nan_inf() {
        // t beyond the stability limit: |1 − 2tλ| = 3, so the iterate grows
        // ~3× per step until t·ĝ overflows binary8's range and RN produces
        // an Inf at the (8b) rounding site.
        let p = Quadratic::diagonal(vec![2.0], vec![0.0]);
        let mut cfg = GdConfig::new(FpFormat::BINARY8, schemes_rn(), 1.0, 2000);
        cfg.grad_model = GradModel::Exact;
        cfg.escape = Some(f64::INFINITY); // only non-finiteness can fire it
        let mut e = GdEngine::new(cfg, &p, &[1.0]);
        let tr = e.run(None);
        assert!(matches!(tr.status, RunStatus::Diverged { .. }));
        assert!(!tr.final_f().is_finite());
        assert!(tr.health.nan_inf > 0, "{}", tr.health.summary());
    }

    /// The stalled-step counter agrees with the per-record `stalled` flags on
    /// the Figure-2 stagnation run, and the stagnated RN run is otherwise
    /// numerically clean (no overflow, no saturation).
    #[test]
    fn health_counts_stalled_steps_on_stagnating_run() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        let mut cfg = GdConfig::new(FpFormat::BINARY8, schemes_rn(), 0.05, 40);
        cfg.seed = 1;
        let mut e = GdEngine::new(cfg, &p, &[1.0]);
        let tr = e.run(None);
        let stalled = tr.records.iter().filter(|r| r.stalled).count() as u64;
        assert!(stalled > 0, "Figure-2 run should stall");
        assert_eq!(tr.health.stalled_steps, stalled);
        assert_eq!(tr.health.steps, 40);
        assert_eq!(tr.health.nan_inf, 0, "{}", tr.health.summary());
    }
}
