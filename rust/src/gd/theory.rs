//! Convergence-theory calculators (paper §4): the bounds and precondition
//! checkers behind Table 1. Each function mirrors one numbered result so the
//! `table1` experiment can verify, on a live run, that (i) the preconditions
//! hold and (ii) the claimed conclusion holds.

use crate::fp::format::FpFormat;

/// Theorem 2 (exact arithmetic): `f(x^{(k)}) − f(x*) ≤ 2L‖x⁰−x*‖² / (4+Ltk)`.
pub fn theorem2_bound(lip: f64, t: f64, k: usize, dist0: f64) -> f64 {
    2.0 * lip * dist0 * dist0 / (4.0 + lip * t * k as f64)
}

/// Theorem 6(i) (SR, condition (14)): `E[f−f*] ≤ 2Lχ² / (4+Ltk(1−2a))`.
pub fn theorem6_bound(lip: f64, t: f64, k: usize, chi: f64, a: f64) -> f64 {
    2.0 * lip * chi * chi / (4.0 + lip * t * k as f64 * (1.0 - 2.0 * a))
}

/// Theorem 6(ii) (SR, condition (15)): denominator uses `1 − 2a²`.
pub fn theorem6_bound_ii(lip: f64, t: f64, k: usize, chi: f64, a: f64) -> f64 {
    2.0 * lip * chi * chi / (4.0 + lip * t * k as f64 * (1.0 - 2.0 * a * a))
}

/// Corollary 7(i) (SRε for (8b)): `E[f−f*] ≤ 2Lχ² / (4+Ltk(1+2b−2a))`
/// for some `0 < b ≤ 2εu`.
pub fn corollary7_bound(lip: f64, t: f64, k: usize, chi: f64, a: f64, b: f64) -> f64 {
    2.0 * lip * chi * chi / (4.0 + lip * t * k as f64 * (1.0 + 2.0 * b - 2.0 * a))
}

/// The paper's precision gate: `u ≤ a / (c + 4a + 4)` (Prop. 3 / Lemma 4 /
/// Thms. 5–6). Returns the max admissible `u` for a given `(a, c)`.
pub fn u_upper_bound(a: f64, c: f64) -> f64 {
    a / (c + 4.0 * a + 4.0)
}

/// Stepsize gate used throughout §4: `t ≤ 1 / (L(1+2u)²)`.
pub fn t_upper_bound(lip: f64, u: f64) -> f64 {
    1.0 / (lip * (1.0 + 2.0 * u) * (1.0 + 2.0 * u))
}

/// Proposition 3 gradient-norm gate (17):
/// `‖∇f‖ ≥ (1−a)⁻¹ (2+4u+√(1−a)) √n c u`.
pub fn prop3_grad_gate(a: f64, u: f64, n: usize, c: f64) -> f64 {
    (2.0 + 4.0 * u + (1.0 - a).sqrt()) / (1.0 - a) * (n as f64).sqrt() * c * u
}

/// Lemma 4 gradient-norm gate (24): `‖∇f‖ ≥ a⁻¹ (2+4u+√a) √n c u`.
pub fn lemma4_grad_gate(a: f64, u: f64, n: usize, c: f64) -> f64 {
    (2.0 + 4.0 * u + a.sqrt()) / a * (n as f64).sqrt() * c * u
}

/// Theorem 6(i) gate (33): `E‖∇f‖ ≥ a⁻¹ (2+√a) √n c u`.
pub fn theorem6_grad_gate(a: f64, u: f64, n: usize, c: f64) -> f64 {
    (2.0 + a.sqrt()) / a * (n as f64).sqrt() * c * u
}

/// Theorem 6(ii) gate (35): `E‖∇f‖ ≥ 3 a⁻¹ √n c u`.
pub fn theorem6_grad_gate_ii(a: f64, u: f64, n: usize, c: f64) -> f64 {
    3.0 / a * (n as f64).sqrt() * c * u
}

/// Corollary 7(i) gate (44): `E‖∇f‖ ≥ a⁻¹ (2+√a+4εu) √n c u`.
pub fn corollary7_grad_gate(a: f64, u: f64, n: usize, c: f64, eps: f64) -> f64 {
    (2.0 + a.sqrt() + 4.0 * eps * u) / a * (n as f64).sqrt() * c * u
}

/// Proposition 9(i) gate (51), the stagnation-scenario SR monotonicity:
/// `E‖∇f‖ ≥ cu√n/(1−cu) + (u/t)·√(1/(1−cu))·√E‖x̂‖²`.
pub fn prop9_grad_gate(u: f64, t: f64, n: usize, c: f64, x_norm2: f64) -> f64 {
    let cu = c * u;
    cu * (n as f64).sqrt() / (1.0 - cu) + u / t * (1.0 / (1.0 - cu)).sqrt() * x_norm2.sqrt()
}

/// Proposition 9(ii) gate (52): `E‖∇f‖ ≥ (u/t)·√E‖x̂‖²`.
pub fn prop9_grad_gate_ii(u: f64, t: f64, x_norm2: f64) -> f64 {
    u / t * x_norm2.sqrt()
}

/// Proposition 11(i) gate (62), signed-SRε version of (51): extra `(1+2ε)`.
pub fn prop11_grad_gate(u: f64, t: f64, n: usize, c: f64, eps: f64, x_norm2: f64) -> f64 {
    let cu = c * u;
    cu * (n as f64).sqrt() / (1.0 - cu)
        + u / t * ((1.0 + 2.0 * eps) / (1.0 - cu)).sqrt() * x_norm2.sqrt()
}

/// Proposition 11(ii) gate (63): `E‖∇f‖ ≥ (u/t)·√(1+2ε)·√E‖x̂‖²`.
pub fn prop11_grad_gate_ii(u: f64, t: f64, eps: f64, x_norm2: f64) -> f64 {
    u / t * (1.0 + 2.0 * eps).sqrt() * x_norm2.sqrt()
}

/// Condition (25) of Lemma 4 viewed as an upper bound on u:
/// `u ≤ ¼(1−2a) t ‖∇f(x̂^{(k−1)})‖² / (‖∇f(x̂^{(k)})‖ ‖z^{(k)}‖)`.
pub fn lemma4_u_gate(a: f64, t: f64, g_prev: f64, g_cur: f64, z_norm: f64) -> f64 {
    0.25 * (1.0 - 2.0 * a) * t * g_prev * g_prev / (g_cur * z_norm)
}

/// Does a format pass the `u ≤ a/(c+4a+4)` gate for given (a, c)?
pub fn format_admissible(fmt: &FpFormat, a: f64, c: f64) -> bool {
    fmt.unit_roundoff() <= u_upper_bound(a, c)
}

// ------------------------------------------------------------------------
// Polyak–Łojasiewicz bounds for the *fixed-point* backend (the companion
// paper, arXiv:2301.09511). For an L-smooth f satisfying the PL inequality
// ‖∇f(x)‖² ≥ 2μ(f(x) − f*), one GD step with stepsize t contracts the gap
// by ρ = 1 − 2μt(1 − Lt/2) (the descent lemma + PL; ρ ≤ 1 − μt for
// t ≤ 1/L). On a uniform grid of spacing δ = 2^{−f}, unbiased SR adds a
// zero-mean per-coordinate rounding error of magnitude < δ — variance at
// most δ²/4 — to the iterate update, so the smoothness term contributes at
// most (L/2)·nδ²/4 per step:
//
//   E[f(x_{k+1}) − f*] ≤ ρ · E[f(x_k) − f*] + L·n·δ²/8.
//
// Unrolling gives the geometric bound with an O(δ²) limiting-accuracy
// floor — the fixed-point analogue of the paper's Theorem 6 — while RN can
// stagnate as soon as every |t·∇f(x)_i| drops below δ/2, i.e. at a gap as
// large as nδ²/(8μt²): the δ² floor shrinks with the grid but the RN
// stagnation level dominates it by the factor 1/(Lt(1−ρ-ish)) ≫ 1, which
// is exactly the stagnation-threshold sweep of the `plfp3` experiment.
// ------------------------------------------------------------------------

/// PL contraction factor `ρ = 1 − 2μt(1 − Lt/2)` of one exact GD step
/// (clamped into `[0, 1]`; meaningful for `0 < t ≤ 1/L`, `0 < μ ≤ L`).
pub fn pl_contraction_factor(mu: f64, lip: f64, t: f64) -> f64 {
    (1.0 - 2.0 * mu * t * (1.0 - lip * t / 2.0)).clamp(0.0, 1.0)
}

/// Exact-arithmetic PL bound: `f(x_k) − f* ≤ ρ^k (f(x⁰) − f*)`.
pub fn pl_exact_bound(mu: f64, lip: f64, t: f64, k: usize, gap0: f64) -> f64 {
    pl_contraction_factor(mu, lip, t).powi(k as i32) * gap0
}

/// Fixed-point SR bound under PL (companion paper, Theorem-4 shape):
/// `E[f(x_k) − f*] ≤ ρ^k gap0 + (Lnδ²/8)·(1−ρ^k)/(1−ρ)`.
pub fn pl_fixed_sr_bound(
    mu: f64,
    lip: f64,
    t: f64,
    k: usize,
    gap0: f64,
    delta: f64,
    n: usize,
) -> f64 {
    let rho = pl_contraction_factor(mu, lip, t);
    let noise = lip * n as f64 * delta * delta / 8.0;
    let rk = rho.powi(k as i32);
    if rho >= 1.0 {
        rk * gap0 + noise * k as f64
    } else {
        rk * gap0 + noise * (1.0 - rk) / (1.0 - rho)
    }
}

/// Limiting accuracy of fixed-point SR under PL (the `k → ∞` floor of
/// [`pl_fixed_sr_bound`]): `Lnδ² / (8(1−ρ))`.
pub fn pl_fixed_sr_limit(mu: f64, lip: f64, t: f64, delta: f64, n: usize) -> f64 {
    let rho = pl_contraction_factor(mu, lip, t);
    if rho >= 1.0 {
        f64::INFINITY
    } else {
        lip * n as f64 * delta * delta / (8.0 * (1.0 - rho))
    }
}

/// The gap at which RN can stagnate on a uniform grid: RN freezes once
/// every `|t·∇f(x)_i| ≤ δ/2`, and under PL that can happen with
/// `f − f* ≤ ‖∇f‖²/(2μ) ≤ nδ²/(8μt²)` — the worst-case stagnation level.
pub fn pl_rn_stagnation_gap(mu: f64, t: f64, delta: f64, n: usize) -> f64 {
    n as f64 * delta * delta / (8.0 * mu * t * t)
}

/// Smallest `frac_bits` whose SR limiting accuracy ([`pl_fixed_sr_limit`])
/// is at or below `target` — how fine a Qm.n grid must be for SR-GD to
/// reach a given objective gap (the design question behind `plfp3`).
/// Searches `frac_bits ∈ [0, 51]`; returns `None` when even the finest
/// admissible grid misses the target.
pub fn frac_bits_for_target_gap(
    mu: f64,
    lip: f64,
    t: f64,
    n: usize,
    target: f64,
) -> Option<u32> {
    (0..=51u32).find(|&f| {
        let delta = crate::fp::format::pow2(-(f as i32));
        pl_fixed_sr_limit(mu, lip, t, delta, n) <= target
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theorem2_decreases_in_k() {
        let b0 = theorem2_bound(1.0, 0.1, 0, 2.0);
        let b10 = theorem2_bound(1.0, 0.1, 10, 2.0);
        let b100 = theorem2_bound(1.0, 0.1, 100, 2.0);
        assert_eq!(b0, 2.0); // 2L d²/4 = d²/2·L... 2·1·4/4
        assert!(b10 < b0 && b100 < b10);
        // O(1/k) tail: k·bound approaches a constant.
        let t1 = 1e6 as usize;
        let r = theorem2_bound(1.0, 0.1, t1, 2.0) * t1 as f64;
        assert!((r - 2.0 * 4.0 / 0.1).abs() / r < 1e-3);
    }

    #[test]
    fn corollary7_tighter_than_theorem6() {
        // Any b > 0 strictly improves the denominator.
        let (l, t, k, chi, a) = (1.0, 0.1, 100, 2.0, 0.1);
        let t6 = theorem6_bound(l, t, k, chi, a);
        let c7 = corollary7_bound(l, t, k, chi, a, 1e-3);
        assert!(c7 < t6);
        // And both are looser than exact-arithmetic Theorem 2.
        assert!(theorem2_bound(l, t, k, chi) < t6);
    }

    #[test]
    fn precision_gates_table() {
        // With c = 2 and a = 0.45: u ≤ 0.45/(2+1.8+4) = 0.0577 — binary8's
        // u = 0.125 FAILS, bfloat16's u = 2⁻⁸ passes. This is exactly why the
        // paper runs the quadratic study in bfloat16.
        let a = 0.45;
        let c = 2.0;
        assert!(!format_admissible(&FpFormat::BINARY8, a, c));
        assert!(format_admissible(&FpFormat::BFLOAT16, a, c));
        assert!(format_admissible(&FpFormat::BINARY32, a, c));
    }

    #[test]
    fn stepsize_gate_slightly_below_one_over_l() {
        let u = FpFormat::BFLOAT16.unit_roundoff();
        let t = t_upper_bound(1000.0, u);
        assert!(t < 1e-3);
        assert!(t > 0.98e-3);
    }

    #[test]
    fn gates_scale_with_dimension_and_u() {
        let (a, c) = (0.25, 2.0);
        let u8 = FpFormat::BINARY8.unit_roundoff();
        let u16 = FpFormat::BFLOAT16.unit_roundoff();
        assert!(lemma4_grad_gate(a, u8, 1000, c) > lemma4_grad_gate(a, u16, 1000, c));
        assert!(lemma4_grad_gate(a, u16, 4000, c) > lemma4_grad_gate(a, u16, 1000, c));
        // Theorem 6(ii) gate is stricter than (i) for small a (paper remark).
        let small_a = 0.05;
        assert!(
            theorem6_grad_gate_ii(small_a, u16, 1000, c)
                > theorem6_grad_gate(small_a, u16, 1000, c) * 0.9
        );
    }

    #[test]
    fn pl_bounds_shapes() {
        let (mu, lip, t, n) = (0.1, 1.0, 0.5, 100);
        let rho = pl_contraction_factor(mu, lip, t);
        assert!(rho > 0.0 && rho < 1.0, "rho={rho}");
        // Exact bound decays geometrically; SR bound converges to the floor.
        assert!(pl_exact_bound(mu, lip, t, 50, 1.0) < pl_exact_bound(mu, lip, t, 10, 1.0));
        let delta = (2.0f64).powi(-8);
        let b10 = pl_fixed_sr_bound(mu, lip, t, 10, 1.0, delta, n);
        let b1000 = pl_fixed_sr_bound(mu, lip, t, 1000, 1.0, delta, n);
        let floor = pl_fixed_sr_limit(mu, lip, t, delta, n);
        assert!(b1000 < b10);
        assert!(b1000 >= floor && (b1000 - floor) / floor < 1e-6, "{b1000} vs {floor}");
        // Finer grids push the floor down by exactly 4x per extra bit.
        let floor9 = pl_fixed_sr_limit(mu, lip, t, delta / 2.0, n);
        assert!((floor / floor9 - 4.0).abs() < 1e-9);
        // The RN stagnation level dominates the SR floor in this regime.
        assert!(pl_rn_stagnation_gap(mu, t, delta, n) > floor);
        // Target-gap inversion is monotone and consistent with the floor.
        let f = frac_bits_for_target_gap(mu, lip, t, n, 1e-6).unwrap();
        let d = (2.0f64).powi(-(f as i32));
        assert!(pl_fixed_sr_limit(mu, lip, t, d, n) <= 1e-6);
        if f > 0 {
            let d2 = (2.0f64).powi(-(f as i32 - 1));
            assert!(pl_fixed_sr_limit(mu, lip, t, d2, n) > 1e-6);
        }
        // Unstable stepsize: no contraction, no finite floor.
        assert_eq!(pl_contraction_factor(0.0, lip, t), 1.0);
        assert_eq!(pl_fixed_sr_limit(0.0, lip, t, delta, n), f64::INFINITY);
    }

    #[test]
    fn prop11_gate_exceeds_prop9_gate() {
        // signed-SRε pays a (1+2ε) factor on the ‖x̂‖ term (Prop 11 vs 9).
        let u = FpFormat::BINARY8.unit_roundoff();
        let g9 = prop9_grad_gate(u, 0.5, 100, 2.0, 50.0);
        let g11 = prop11_grad_gate(u, 0.5, 100, 2.0, 0.5, 50.0);
        assert!(g11 > g9);
        assert!(prop11_grad_gate_ii(u, 0.5, 0.5, 50.0) > prop9_grad_gate_ii(u, 0.5, 50.0));
    }
}
