//! Convergence-theory calculators (paper §4): the bounds and precondition
//! checkers behind Table 1. Each function mirrors one numbered result so the
//! `table1` experiment can verify, on a live run, that (i) the preconditions
//! hold and (ii) the claimed conclusion holds.

use crate::fp::format::FpFormat;

/// Theorem 2 (exact arithmetic): `f(x^{(k)}) − f(x*) ≤ 2L‖x⁰−x*‖² / (4+Ltk)`.
pub fn theorem2_bound(lip: f64, t: f64, k: usize, dist0: f64) -> f64 {
    2.0 * lip * dist0 * dist0 / (4.0 + lip * t * k as f64)
}

/// Theorem 6(i) (SR, condition (14)): `E[f−f*] ≤ 2Lχ² / (4+Ltk(1−2a))`.
pub fn theorem6_bound(lip: f64, t: f64, k: usize, chi: f64, a: f64) -> f64 {
    2.0 * lip * chi * chi / (4.0 + lip * t * k as f64 * (1.0 - 2.0 * a))
}

/// Theorem 6(ii) (SR, condition (15)): denominator uses `1 − 2a²`.
pub fn theorem6_bound_ii(lip: f64, t: f64, k: usize, chi: f64, a: f64) -> f64 {
    2.0 * lip * chi * chi / (4.0 + lip * t * k as f64 * (1.0 - 2.0 * a * a))
}

/// Corollary 7(i) (SRε for (8b)): `E[f−f*] ≤ 2Lχ² / (4+Ltk(1+2b−2a))`
/// for some `0 < b ≤ 2εu`.
pub fn corollary7_bound(lip: f64, t: f64, k: usize, chi: f64, a: f64, b: f64) -> f64 {
    2.0 * lip * chi * chi / (4.0 + lip * t * k as f64 * (1.0 + 2.0 * b - 2.0 * a))
}

/// The paper's precision gate: `u ≤ a / (c + 4a + 4)` (Prop. 3 / Lemma 4 /
/// Thms. 5–6). Returns the max admissible `u` for a given `(a, c)`.
pub fn u_upper_bound(a: f64, c: f64) -> f64 {
    a / (c + 4.0 * a + 4.0)
}

/// Stepsize gate used throughout §4: `t ≤ 1 / (L(1+2u)²)`.
pub fn t_upper_bound(lip: f64, u: f64) -> f64 {
    1.0 / (lip * (1.0 + 2.0 * u) * (1.0 + 2.0 * u))
}

/// Proposition 3 gradient-norm gate (17):
/// `‖∇f‖ ≥ (1−a)⁻¹ (2+4u+√(1−a)) √n c u`.
pub fn prop3_grad_gate(a: f64, u: f64, n: usize, c: f64) -> f64 {
    (2.0 + 4.0 * u + (1.0 - a).sqrt()) / (1.0 - a) * (n as f64).sqrt() * c * u
}

/// Lemma 4 gradient-norm gate (24): `‖∇f‖ ≥ a⁻¹ (2+4u+√a) √n c u`.
pub fn lemma4_grad_gate(a: f64, u: f64, n: usize, c: f64) -> f64 {
    (2.0 + 4.0 * u + a.sqrt()) / a * (n as f64).sqrt() * c * u
}

/// Theorem 6(i) gate (33): `E‖∇f‖ ≥ a⁻¹ (2+√a) √n c u`.
pub fn theorem6_grad_gate(a: f64, u: f64, n: usize, c: f64) -> f64 {
    (2.0 + a.sqrt()) / a * (n as f64).sqrt() * c * u
}

/// Theorem 6(ii) gate (35): `E‖∇f‖ ≥ 3 a⁻¹ √n c u`.
pub fn theorem6_grad_gate_ii(a: f64, u: f64, n: usize, c: f64) -> f64 {
    3.0 / a * (n as f64).sqrt() * c * u
}

/// Corollary 7(i) gate (44): `E‖∇f‖ ≥ a⁻¹ (2+√a+4εu) √n c u`.
pub fn corollary7_grad_gate(a: f64, u: f64, n: usize, c: f64, eps: f64) -> f64 {
    (2.0 + a.sqrt() + 4.0 * eps * u) / a * (n as f64).sqrt() * c * u
}

/// Proposition 9(i) gate (51), the stagnation-scenario SR monotonicity:
/// `E‖∇f‖ ≥ cu√n/(1−cu) + (u/t)·√(1/(1−cu))·√E‖x̂‖²`.
pub fn prop9_grad_gate(u: f64, t: f64, n: usize, c: f64, x_norm2: f64) -> f64 {
    let cu = c * u;
    cu * (n as f64).sqrt() / (1.0 - cu) + u / t * (1.0 / (1.0 - cu)).sqrt() * x_norm2.sqrt()
}

/// Proposition 9(ii) gate (52): `E‖∇f‖ ≥ (u/t)·√E‖x̂‖²`.
pub fn prop9_grad_gate_ii(u: f64, t: f64, x_norm2: f64) -> f64 {
    u / t * x_norm2.sqrt()
}

/// Proposition 11(i) gate (62), signed-SRε version of (51): extra `(1+2ε)`.
pub fn prop11_grad_gate(u: f64, t: f64, n: usize, c: f64, eps: f64, x_norm2: f64) -> f64 {
    let cu = c * u;
    cu * (n as f64).sqrt() / (1.0 - cu)
        + u / t * ((1.0 + 2.0 * eps) / (1.0 - cu)).sqrt() * x_norm2.sqrt()
}

/// Proposition 11(ii) gate (63): `E‖∇f‖ ≥ (u/t)·√(1+2ε)·√E‖x̂‖²`.
pub fn prop11_grad_gate_ii(u: f64, t: f64, eps: f64, x_norm2: f64) -> f64 {
    u / t * (1.0 + 2.0 * eps).sqrt() * x_norm2.sqrt()
}

/// Condition (25) of Lemma 4 viewed as an upper bound on u:
/// `u ≤ ¼(1−2a) t ‖∇f(x̂^{(k−1)})‖² / (‖∇f(x̂^{(k)})‖ ‖z^{(k)}‖)`.
pub fn lemma4_u_gate(a: f64, t: f64, g_prev: f64, g_cur: f64, z_norm: f64) -> f64 {
    0.25 * (1.0 - 2.0 * a) * t * g_prev * g_prev / (g_cur * z_norm)
}

/// Does a format pass the `u ≤ a/(c+4a+4)` gate for given (a, c)?
pub fn format_admissible(fmt: &FpFormat, a: f64, c: f64) -> bool {
    fmt.unit_roundoff() <= u_upper_bound(a, c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theorem2_decreases_in_k() {
        let b0 = theorem2_bound(1.0, 0.1, 0, 2.0);
        let b10 = theorem2_bound(1.0, 0.1, 10, 2.0);
        let b100 = theorem2_bound(1.0, 0.1, 100, 2.0);
        assert_eq!(b0, 2.0); // 2L d²/4 = d²/2·L... 2·1·4/4
        assert!(b10 < b0 && b100 < b10);
        // O(1/k) tail: k·bound approaches a constant.
        let t1 = 1e6 as usize;
        let r = theorem2_bound(1.0, 0.1, t1, 2.0) * t1 as f64;
        assert!((r - 2.0 * 4.0 / 0.1).abs() / r < 1e-3);
    }

    #[test]
    fn corollary7_tighter_than_theorem6() {
        // Any b > 0 strictly improves the denominator.
        let (l, t, k, chi, a) = (1.0, 0.1, 100, 2.0, 0.1);
        let t6 = theorem6_bound(l, t, k, chi, a);
        let c7 = corollary7_bound(l, t, k, chi, a, 1e-3);
        assert!(c7 < t6);
        // And both are looser than exact-arithmetic Theorem 2.
        assert!(theorem2_bound(l, t, k, chi) < t6);
    }

    #[test]
    fn precision_gates_table() {
        // With c = 2 and a = 0.45: u ≤ 0.45/(2+1.8+4) = 0.0577 — binary8's
        // u = 0.125 FAILS, bfloat16's u = 2⁻⁸ passes. This is exactly why the
        // paper runs the quadratic study in bfloat16.
        let a = 0.45;
        let c = 2.0;
        assert!(!format_admissible(&FpFormat::BINARY8, a, c));
        assert!(format_admissible(&FpFormat::BFLOAT16, a, c));
        assert!(format_admissible(&FpFormat::BINARY32, a, c));
    }

    #[test]
    fn stepsize_gate_slightly_below_one_over_l() {
        let u = FpFormat::BFLOAT16.unit_roundoff();
        let t = t_upper_bound(1000.0, u);
        assert!(t < 1e-3);
        assert!(t > 0.98e-3);
    }

    #[test]
    fn gates_scale_with_dimension_and_u() {
        let (a, c) = (0.25, 2.0);
        let u8 = FpFormat::BINARY8.unit_roundoff();
        let u16 = FpFormat::BFLOAT16.unit_roundoff();
        assert!(lemma4_grad_gate(a, u8, 1000, c) > lemma4_grad_gate(a, u16, 1000, c));
        assert!(lemma4_grad_gate(a, u16, 4000, c) > lemma4_grad_gate(a, u16, 1000, c));
        // Theorem 6(ii) gate is stricter than (i) for small a (paper remark).
        let small_a = 0.05;
        assert!(
            theorem6_grad_gate_ii(small_a, u16, 1000, c)
                > theorem6_grad_gate(small_a, u16, 1000, c) * 0.9
        );
    }

    #[test]
    fn prop11_gate_exceeds_prop9_gate() {
        // signed-SRε pays a (1+2ε) factor on the ‖x̂‖ term (Prop 11 vs 9).
        let u = FpFormat::BINARY8.unit_roundoff();
        let g9 = prop9_grad_gate(u, 0.5, 100, 2.0, 50.0);
        let g11 = prop11_grad_gate(u, 0.5, 100, 2.0, 0.5, 50.0);
        assert!(g11 > g9);
        assert!(prop11_grad_gate_ii(u, 0.5, 0.5, 50.0) > prop9_grad_gate_ii(u, 0.5, 50.0));
    }
}
