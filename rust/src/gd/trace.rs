//! Iteration traces: everything the figures need, recorded per GD step,
//! plus the run's terminal [`RunStatus`] and aggregated numeric health
//! (see `docs/robustness.md`).

use crate::fp::RunHealth;

/// How a GD run ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RunStatus {
    /// The run executed every configured step.
    #[default]
    Completed,
    /// The run was cut short by the divergence guard (loss non-finite or
    /// above the configured escape threshold) at step `step`; the trace
    /// holds `step + 1` records, the last one showing the escaping loss.
    Diverged {
        /// Iteration index at which the guard fired.
        step: usize,
    },
}

impl RunStatus {
    /// True unless the divergence guard fired.
    pub fn is_completed(&self) -> bool {
        matches!(self, RunStatus::Completed)
    }
}

/// One GD iteration's worth of diagnostics (exact-arithmetic monitoring of a
/// low-precision run; the monitored quantities never feed back into the run).
#[derive(Debug, Clone)]
pub struct IterRecord {
    /// Iteration index k.
    pub k: usize,
    /// Objective f(x̂^(k)), evaluated exactly.
    pub f: f64,
    /// ‖∇f(x̂^(k))‖ (exact gradient).
    pub grad_norm: f64,
    /// ‖x̂^(k) − x*‖ when the optimum is known, else NaN.
    pub dist_to_opt: f64,
    /// τ_k from §3.2 (NaN when not recorded).
    pub tau: f64,
    /// Did the iterate fail to move this step (x̂^(k+1) == x̂^(k))?
    pub stalled: bool,
    /// Task-level metric (test error for MLR/NN figures; NaN otherwise).
    pub metric: f64,
}

/// A full GD run trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// One record per completed iteration, in order.
    pub records: Vec<IterRecord>,
    /// How the run ended (default: ran to completion).
    pub status: RunStatus,
    /// Numeric-health counters aggregated over the whole run.
    pub health: RunHealth,
}

impl Trace {
    /// Append one iteration's record.
    pub fn push(&mut self, r: IterRecord) {
        self.records.push(r);
    }

    /// Number of recorded iterations.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The objective values f(x̂^(k)), in iteration order.
    pub fn objective_series(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.f).collect()
    }

    /// The task-level metric values (NaN when no metric was supplied).
    pub fn metric_series(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.metric).collect()
    }

    /// The τ_k values (NaN unless `record_tau` was set).
    pub fn tau_series(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.tau).collect()
    }

    /// Final recorded objective (NaN for an empty trace).
    pub fn final_f(&self) -> f64 {
        self.records.last().map(|r| r.f).unwrap_or(f64::NAN)
    }

    /// First iteration index from which the iterate never moves again
    /// (`None` if the run keeps moving). This is the paper's "stagnation
    /// from step k" notion used in Figure 2.
    pub fn stagnation_onset(&self) -> Option<usize> {
        let mut onset = None;
        for r in &self.records {
            if r.stalled {
                if onset.is_none() {
                    onset = Some(r.k);
                }
            } else {
                onset = None;
            }
        }
        onset
    }
}

/// Pointwise mean of many traces' series — the paper's E[·] over 20 runs.
pub fn mean_series(series: &[Vec<f64>]) -> Vec<f64> {
    if series.is_empty() {
        return vec![];
    }
    let n = series.iter().map(|s| s.len()).min().unwrap_or(0);
    (0..n).map(|k| series.iter().map(|s| s[k]).sum::<f64>() / series.len() as f64).collect()
}

/// Pointwise population variance of many traces' series (paper §5.2 reports
/// population variance over the 20 simulations).
pub fn variance_series(series: &[Vec<f64>]) -> Vec<f64> {
    if series.is_empty() {
        return vec![];
    }
    let n = series.iter().map(|s| s.len()).min().unwrap_or(0);
    let m = mean_series(series);
    (0..n)
        .map(|k| {
            series.iter().map(|s| (s[k] - m[k]) * (s[k] - m[k])).sum::<f64>() / series.len() as f64
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(k: usize, f: f64, stalled: bool) -> IterRecord {
        IterRecord { k, f, grad_norm: 0.0, dist_to_opt: f64::NAN, tau: f64::NAN, stalled, metric: f64::NAN }
    }

    #[test]
    fn stagnation_onset_finds_terminal_stall() {
        let mut t = Trace::default();
        for (k, st) in [(0, false), (1, true), (2, false), (3, true), (4, true)] {
            t.push(rec(k, 1.0, st));
        }
        assert_eq!(t.stagnation_onset(), Some(3));
    }

    #[test]
    fn stagnation_onset_none_when_moving() {
        let mut t = Trace::default();
        t.push(rec(0, 1.0, false));
        t.push(rec(1, 0.5, false));
        assert_eq!(t.stagnation_onset(), None);
    }

    #[test]
    fn default_trace_is_completed_and_clean() {
        let t = Trace::default();
        assert!(t.status.is_completed());
        assert!(t.health.is_clean());
        assert_ne!(t.status, RunStatus::Diverged { step: 0 });
    }

    #[test]
    fn mean_and_variance() {
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![3.0, 2.0, 1.0];
        let m = mean_series(&[a.clone(), b.clone()]);
        assert_eq!(m, vec![2.0, 2.0, 2.0]);
        let v = variance_series(&[a, b]);
        assert_eq!(v, vec![1.0, 0.0, 1.0]);
    }
}
