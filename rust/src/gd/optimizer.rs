//! The optimizer zoo: the [`Optimizer`] trait, its built-in
//! implementations (plain [`Gd`], heavy-ball [`Momentum`], [`Nesterov`],
//! [`Adam`]) and the [`LrSchedule`] stepsize decay laws.
//!
//! The paper's mechanism — roundoff bias in a descent direction rescuing
//! low-precision GD from stagnation (§4.2.2) — has a second battlefield
//! in state-carrying optimizers: momentum buffers and Adam moments are
//! accumulated with exactly the small-update arithmetic where RN
//! stagnates, so *optimizer state* is a rounding site in its own right
//! ("Stochastic Rounding 2.0", arXiv:2410.10517). The trait makes the
//! update law pluggable while [`crate::gd::GdEngine`] stays the one
//! driver: it owns the iterate, the gradient context, the per-site RNG
//! streams and the state tensors, and hands an [`Optimizer`] a
//! [`StepCtx`] view of them once per iteration.
//!
//! Rounding-wise each optimizer is a composition of the fused kernels in
//! [`crate::fp::kernels`]: every state tensor has a named rounding site
//! resolved through the engine's [`crate::gd::PolicyMap`] (scheme + grid
//! + `sr_bits` per tensor), so master-weights-in-high-precision versus
//! fully-low-precision-state lanes are policy spellings, not code paths.
//! With the plain [`Gd`] optimizer the driver issues exactly the historic
//! fused `gd_update_health` call on the historic streams — trajectories
//! are bit-identical to the pre-trait engine for every built-in scheme.

use crate::fp::kernels::{self, AdamParams, Site};
use crate::fp::rng::Rng;
use crate::fp::round::RunHealth;
use crate::fp::scheme::SchemeError;

/// Default momentum coefficient β for `momentum`/`nesterov` specs given
/// without a parameter (the conventional value).
pub const DEFAULT_BETA: f64 = 0.9;
/// Default Adam second-moment coefficient β₂.
pub const DEFAULT_ADAM_BETA2: f64 = 0.999;
/// Default Adam denominator offset ε.
pub const DEFAULT_ADAM_EPS: f64 = 1e-8;

fn bad(msg: String) -> SchemeError {
    SchemeError::BadSpec(msg)
}

// ---------------------------------------------------------- LR schedules --

/// Stepsize decay schedule: the effective stepsize of iteration `k` is
/// [`LrSchedule::at`]`(t, k)` over the configured base stepsize `t`.
/// [`LrSchedule::Constant`] returns the base *untouched* (no arithmetic),
/// so constant-schedule trajectories are bit-identical to pre-schedule
/// releases.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    /// Fixed stepsize `t_k = t` (the paper's protocol; the default).
    Constant,
    /// Inverse-time decay `t_k = t / (1 + rate·k)`.
    InvTime {
        /// Decay rate per iteration.
        rate: f64,
    },
    /// Staircase decay `t_k = t · γ^⌊k/period⌋`.
    Step {
        /// Multiplicative factor per stage, in `(0, 1]`.
        gamma: f64,
        /// Iterations per stage.
        period: u32,
    },
}

impl LrSchedule {
    /// The effective stepsize at iteration `k` (0-based) for base `t`.
    pub fn at(&self, t: f64, k: u64) -> f64 {
        match *self {
            LrSchedule::Constant => t,
            LrSchedule::InvTime { rate } => t / (1.0 + rate * k as f64),
            LrSchedule::Step { gamma, period } => t * gamma.powi((k / period as u64) as i32),
        }
    }

    /// Is this the constant (identity) schedule?
    pub fn is_constant(&self) -> bool {
        matches!(self, LrSchedule::Constant)
    }

    /// Parse a schedule spec: `"const"` (aliases `constant`, `none`,
    /// `fixed`), `"inv:<rate>"` (alias `inv_time`), `"step:<gamma>:<period>"`.
    /// Case-insensitive, whitespace-trimmed.
    pub fn parse(spec: &str) -> Result<Self, SchemeError> {
        let s = spec.trim().to_ascii_lowercase();
        let mut it = s.split(':');
        let family = it.next().unwrap_or("");
        let params: Vec<&str> = it.collect();
        let want = |n: usize| -> Result<(), SchemeError> {
            if params.len() == n {
                Ok(())
            } else {
                Err(bad(format!(
                    "lr schedule '{spec}' is malformed (known: const, inv:<rate>, step:<gamma>:<period>)"
                )))
            }
        };
        let num = |p: &str| -> Result<f64, SchemeError> {
            p.trim()
                .parse::<f64>()
                .map_err(|_| bad(format!("bad number '{p}' in lr schedule '{spec}'")))
        };
        match family {
            "const" | "constant" | "none" | "fixed" => {
                want(0)?;
                Ok(LrSchedule::Constant)
            }
            "inv" | "inv_time" | "invtime" => {
                want(1)?;
                let rate = num(params[0])?;
                if !(rate.is_finite() && rate >= 0.0) {
                    return Err(bad(format!("inv-time rate must be finite and >= 0, got '{}'", params[0])));
                }
                Ok(LrSchedule::InvTime { rate })
            }
            "step" => {
                want(2)?;
                let gamma = num(params[0])?;
                let period: u32 = params[1].trim().parse().map_err(|_| {
                    bad(format!("bad period '{}' in lr schedule '{spec}'", params[1]))
                })?;
                if !(gamma > 0.0 && gamma <= 1.0) || period == 0 {
                    return Err(bad(format!(
                        "step schedule needs gamma in (0,1] and period >= 1, got '{spec}'"
                    )));
                }
                Ok(LrSchedule::Step { gamma, period })
            }
            _ => Err(bad(format!(
                "unknown lr schedule '{spec}' (known: const, inv:<rate>, step:<gamma>:<period>)"
            ))),
        }
    }

    /// Canonical spec string, re-parseable by [`LrSchedule::parse`]:
    /// `"const"`, `"inv:<rate>"`, `"step:<gamma>:<period>"`.
    pub fn canon(&self) -> String {
        match *self {
            LrSchedule::Constant => "const".into(),
            LrSchedule::InvTime { rate } => format!("inv:{rate}"),
            LrSchedule::Step { gamma, period } => format!("step:{gamma}:{period}"),
        }
    }
}

impl Default for LrSchedule {
    fn default() -> Self {
        LrSchedule::Constant
    }
}

// ------------------------------------------------------- optimizer specs --

/// Value-level description of an optimizer: what flows through
/// [`crate::gd::GdConfig`], CLI flags, serve specs and cell identity.
/// [`OptimizerSpec::build`] instantiates the matching [`Optimizer`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptimizerSpec {
    /// Plain gradient descent, eq. (8) — the paper's protocol and the
    /// default. No state tensors; trajectories bit-identical to the
    /// pre-trait engine.
    Gd,
    /// Heavy-ball momentum: `m⁺ = fl(β·m + t·ĝ)`, `x⁺ = fl(x − m⁺)`.
    Momentum {
        /// Momentum coefficient β ∈ [0, 1).
        beta: f64,
    },
    /// Nesterov momentum: `m⁺ = fl(β·m + t·ĝ)`, then the lookahead update
    /// `u = fl(β·m⁺ + t·ĝ)`, `x⁺ = fl(x − u)`.
    Nesterov {
        /// Momentum coefficient β ∈ [0, 1).
        beta: f64,
    },
    /// Adam (Kingma & Ba) with bias correction; moments are state tensors
    /// `m` and `v` with their own rounding sites.
    Adam {
        /// First-moment coefficient β₁ ∈ [0, 1).
        beta1: f64,
        /// Second-moment coefficient β₂ ∈ [0, 1).
        beta2: f64,
        /// Denominator offset ε > 0.
        eps: f64,
    },
}

impl OptimizerSpec {
    /// Is this plain GD? (The engine's lane-batched fast path and the
    /// bit-identity guarantees key on it.)
    pub fn is_gd(&self) -> bool {
        matches!(self, OptimizerSpec::Gd)
    }

    /// Stable names of the state tensors this optimizer carries, in
    /// [`Optimizer::init_state`] order — the names the
    /// [`crate::gd::PolicyMap`] binds rounding policies to.
    pub fn state_names(&self) -> &'static [&'static str] {
        match self {
            OptimizerSpec::Gd => &[],
            OptimizerSpec::Momentum { .. } | OptimizerSpec::Nesterov { .. } => &["m"],
            OptimizerSpec::Adam { .. } => &["m", "v"],
        }
    }

    /// Parse an optimizer spec: `"gd"` (alias `sgd`), `"momentum[:β]"`
    /// (aliases `heavy_ball`, `polyak`), `"nesterov[:β]"` (alias `nag`),
    /// `"adam[:β₁[:β₂[:ε]]]"`. Omitted parameters take the conventional
    /// defaults ([`DEFAULT_BETA`], [`DEFAULT_ADAM_BETA2`],
    /// [`DEFAULT_ADAM_EPS`]). Case-insensitive, whitespace-trimmed.
    pub fn parse(spec: &str) -> Result<Self, SchemeError> {
        let s = spec.trim().to_ascii_lowercase();
        let mut it = s.split(':');
        let family = it.next().unwrap_or("");
        let params: Vec<&str> = it.collect();
        let num = |p: &str| -> Result<f64, SchemeError> {
            p.trim()
                .parse::<f64>()
                .map_err(|_| bad(format!("bad parameter '{p}' in optimizer spec '{spec}'")))
        };
        let beta_ok = |b: f64| b.is_finite() && (0.0..1.0).contains(&b);
        match family {
            "gd" | "sgd" => {
                if !params.is_empty() {
                    return Err(bad(format!("optimizer 'gd' takes no parameters, got '{spec}'")));
                }
                Ok(OptimizerSpec::Gd)
            }
            "momentum" | "heavy_ball" | "heavyball" | "polyak" | "nesterov" | "nag" => {
                if params.len() > 1 {
                    return Err(bad(format!(
                        "momentum optimizers take at most one ':<beta>' parameter, got '{spec}'"
                    )));
                }
                let beta = params.first().map(|p| num(p)).transpose()?.unwrap_or(DEFAULT_BETA);
                if !beta_ok(beta) {
                    return Err(bad(format!("momentum beta must be in [0, 1), got {beta}")));
                }
                if matches!(family, "nesterov" | "nag") {
                    Ok(OptimizerSpec::Nesterov { beta })
                } else {
                    Ok(OptimizerSpec::Momentum { beta })
                }
            }
            "adam" => {
                if params.len() > 3 {
                    return Err(bad(format!(
                        "adam takes at most ':<beta1>:<beta2>:<eps>', got '{spec}'"
                    )));
                }
                let beta1 = params.first().map(|p| num(p)).transpose()?.unwrap_or(DEFAULT_BETA);
                let beta2 =
                    params.get(1).map(|p| num(p)).transpose()?.unwrap_or(DEFAULT_ADAM_BETA2);
                let eps = params.get(2).map(|p| num(p)).transpose()?.unwrap_or(DEFAULT_ADAM_EPS);
                if !beta_ok(beta1) || !beta_ok(beta2) {
                    return Err(bad(format!("adam betas must be in [0, 1), got '{spec}'")));
                }
                if !(eps.is_finite() && eps > 0.0) {
                    return Err(bad(format!("adam eps must be finite and > 0, got '{spec}'")));
                }
                Ok(OptimizerSpec::Adam { beta1, beta2, eps })
            }
            _ => Err(bad(format!(
                "unknown optimizer '{spec}' (known: gd, momentum[:beta], nesterov[:beta], adam[:b1[:b2[:eps]]])"
            ))),
        }
    }

    /// Canonical spec string, re-parseable by [`OptimizerSpec::parse`],
    /// with default parameters elided (`"momentum"` not `"momentum:0.9"`)
    /// so spelling variants share one cell identity.
    pub fn canon(&self) -> String {
        match *self {
            OptimizerSpec::Gd => "gd".into(),
            OptimizerSpec::Momentum { beta } => {
                if beta == DEFAULT_BETA {
                    "momentum".into()
                } else {
                    format!("momentum:{beta}")
                }
            }
            OptimizerSpec::Nesterov { beta } => {
                if beta == DEFAULT_BETA {
                    "nesterov".into()
                } else {
                    format!("nesterov:{beta}")
                }
            }
            OptimizerSpec::Adam { beta1, beta2, eps } => {
                let with_eps = eps != DEFAULT_ADAM_EPS;
                let with_b2 = with_eps || beta2 != DEFAULT_ADAM_BETA2;
                let with_b1 = with_b2 || beta1 != DEFAULT_BETA;
                let mut s = String::from("adam");
                if with_b1 {
                    s.push_str(&format!(":{beta1}"));
                }
                if with_b2 {
                    s.push_str(&format!(":{beta2}"));
                }
                if with_eps {
                    s.push_str(&format!(":{eps}"));
                }
                s
            }
        }
    }

    /// Instantiate the matching [`Optimizer`] implementation.
    pub fn build(&self) -> Box<dyn Optimizer> {
        match *self {
            OptimizerSpec::Gd => Box::new(Gd),
            OptimizerSpec::Momentum { beta } => Box::new(Momentum { beta }),
            OptimizerSpec::Nesterov { beta } => Box::new(Nesterov { beta }),
            OptimizerSpec::Adam { beta1, beta2, eps } => Box::new(Adam { beta1, beta2, eps }),
        }
    }
}

impl Default for OptimizerSpec {
    fn default() -> Self {
        OptimizerSpec::Gd
    }
}

// --------------------------------------------------------- the step view --

/// Everything an [`Optimizer`] sees for one iteration: the resolved
/// rounding sites, the effective stepsize, the iterate and gradient, the
/// optimizer's state tensors, the engine's scratch buffers and the
/// per-site RNG streams. Built by [`crate::gd::GdEngine::step`]; the
/// borrows are disjoint fields of the engine.
///
/// Site resolution (engine-side): `mul` is the run plan with the (8b)
/// scheme; `sub` is the `weights` binding when the policy has one, else
/// the run plan with the (8c) scheme; `m_site`/`v_site` are the `m`/`v`
/// bindings, defaulting to the run plan with the (8b) scheme (state
/// accumulation is stepsize-multiplication-shaped arithmetic).
pub struct StepCtx<'a> {
    /// The (8b) update-staging site (run grid + `mul` scheme).
    pub mul: Site<'a>,
    /// The (8c) iterate-landing site (`weights` binding or run grid +
    /// `sub` scheme).
    pub sub: Site<'a>,
    /// Rounding site of the first-moment / momentum state tensor `m`.
    pub m_site: Site<'a>,
    /// Rounding site of the second-moment state tensor `v`.
    pub v_site: Site<'a>,
    /// Effective stepsize `t_k` (base stepsize through the LR schedule).
    pub t: f64,
    /// 0-based iteration index (drives Adam's bias correction).
    pub k: u64,
    /// The iterate x̂ (updated in place).
    pub x: &'a mut [f64],
    /// The low-precision gradient ĝ of step (8a).
    pub ghat: &'a [f64],
    /// State tensors in [`Optimizer::state_names`] order.
    pub state: &'a mut [Vec<f64>],
    /// Scratch: staged update values.
    pub mbuf: &'a mut [f64],
    /// Scratch: steering vector −ĝ for steered schemes.
    pub vneg: &'a mut [f64],
    /// Scratch: landing point x̂ − u.
    pub zbuf: &'a mut [f64],
    /// δ₂ stream of the (8b) site.
    pub rng_mul: &'a mut Rng,
    /// δ₃ stream of the (8c) site.
    pub rng_sub: &'a mut Rng,
    /// Stream of the `m` state site (fork `"opt_m"`; untouched by plain GD).
    pub rng_m: &'a mut Rng,
    /// Stream of the `v` state site (fork `"opt_v"`; untouched by plain GD).
    pub rng_v: &'a mut Rng,
    /// Run-wide numeric-health counters; every rounding site classifies
    /// into it, so optimizer-state stalls/saturations surface in
    /// [`crate::gd::Trace::health`] like the (8b)/(8c) sites always have.
    pub health: &'a mut RunHealth,
}

/// One optimizer update law, driven by [`crate::gd::GdEngine::step`].
///
/// # Contract
///
/// * [`Optimizer::apply_step`] must round every value it commits (iterate
///   and state tensors) through the sites in the [`StepCtx`], draw
///   randomness only from the matching streams, and return whether the
///   iterate moved.
/// * State tensors are enumerated by [`Optimizer::state_names`] with
///   stable names — the names [`crate::gd::PolicyMap`] bindings and
///   [`crate::gd::GdEngine::state_tensor`] resolve.
/// * Implementations must not consume randomness for deterministic
///   schemes (the kernels guarantee this; the conformance suite checks).
pub trait Optimizer {
    /// The value-level spec this optimizer was built from.
    fn spec(&self) -> OptimizerSpec;

    /// Canonical spec string (see [`OptimizerSpec::canon`]).
    fn name(&self) -> String {
        self.spec().canon()
    }

    /// Stable names of the state tensors, in `init_state` order.
    fn state_names(&self) -> &'static [&'static str] {
        self.spec().state_names()
    }

    /// Allocate the zero-initialized state tensors for dimension `dim`.
    fn init_state(&self, dim: usize) -> Vec<Vec<f64>> {
        self.state_names().iter().map(|_| vec![0.0; dim]).collect()
    }

    /// Apply one update to `ctx.x` (and the state tensors). Returns `true`
    /// when any coordinate of the iterate moved.
    fn apply_step(&self, ctx: StepCtx<'_>) -> bool;
}

// ------------------------------------------------------- implementations --

/// Plain gradient descent — eq. (8) exactly, via the same fused kernel
/// call (and the same RNG streams) as the pre-trait engine.
pub struct Gd;

impl Optimizer for Gd {
    fn spec(&self) -> OptimizerSpec {
        OptimizerSpec::Gd
    }

    fn apply_step(&self, ctx: StepCtx<'_>) -> bool {
        kernels::gd_update_split_health(
            ctx.mul, ctx.sub, ctx.t, ctx.x, ctx.ghat, ctx.mbuf, ctx.vneg, ctx.zbuf,
            ctx.rng_mul, ctx.rng_sub, ctx.health,
        )
    }
}

/// Heavy-ball momentum. The buffer update `m⁺ = fl(β·m + t·ĝ)` rounds at
/// the `m` state site (steering −ĝ, the descent choice of §4.2.2); the
/// iterate lands through the (8c)/`weights` site.
pub struct Momentum {
    /// Momentum coefficient β.
    pub beta: f64,
}

impl Optimizer for Momentum {
    fn spec(&self) -> OptimizerSpec {
        OptimizerSpec::Momentum { beta: self.beta }
    }

    fn apply_step(&self, ctx: StepCtx<'_>) -> bool {
        let m = &mut ctx.state[0];
        kernels::momentum_update_health(
            ctx.m_site, ctx.mul, ctx.sub, self.beta, false, ctx.t, ctx.x, ctx.ghat, m,
            ctx.mbuf, ctx.vneg, ctx.zbuf, ctx.rng_m, ctx.rng_mul, ctx.rng_sub, ctx.health,
        )
    }
}

/// Nesterov momentum: same buffer update as [`Momentum`], plus the
/// lookahead blend `u = fl(β·m⁺ + t·ĝ)` rounded at the (8b) site before
/// the iterate lands.
pub struct Nesterov {
    /// Momentum coefficient β.
    pub beta: f64,
}

impl Optimizer for Nesterov {
    fn spec(&self) -> OptimizerSpec {
        OptimizerSpec::Nesterov { beta: self.beta }
    }

    fn apply_step(&self, ctx: StepCtx<'_>) -> bool {
        let m = &mut ctx.state[0];
        kernels::momentum_update_health(
            ctx.m_site, ctx.mul, ctx.sub, self.beta, true, ctx.t, ctx.x, ctx.ghat, m,
            ctx.mbuf, ctx.vneg, ctx.zbuf, ctx.rng_m, ctx.rng_mul, ctx.rng_sub, ctx.health,
        )
    }
}

/// Adam with bias correction. Moments round at their `m`/`v` state sites;
/// the assembled update `u = fl(t·m̂/(√v̂ + ε))` rounds at the (8b) site
/// and the iterate lands through the (8c)/`weights` site. Bias
/// corrections are computed exactly in f64 (they are scalars, not tensor
/// arithmetic).
pub struct Adam {
    /// First-moment coefficient β₁.
    pub beta1: f64,
    /// Second-moment coefficient β₂.
    pub beta2: f64,
    /// Denominator offset ε.
    pub eps: f64,
}

impl Optimizer for Adam {
    fn spec(&self) -> OptimizerSpec {
        OptimizerSpec::Adam { beta1: self.beta1, beta2: self.beta2, eps: self.eps }
    }

    fn apply_step(&self, ctx: StepCtx<'_>) -> bool {
        let (m, rest) = ctx.state.split_first_mut().expect("adam carries m and v");
        let v = &mut rest[0];
        let step1 = (ctx.k + 1).min(i32::MAX as u64) as i32;
        let params = AdamParams {
            beta1: self.beta1,
            beta2: self.beta2,
            eps: self.eps,
            bc1: 1.0 - self.beta1.powi(step1),
            bc2: 1.0 - self.beta2.powi(step1),
        };
        kernels::adam_update_health(
            ctx.m_site, ctx.v_site, ctx.mul, ctx.sub, &params, ctx.t, ctx.x, ctx.ghat, m, v,
            ctx.mbuf, ctx.vneg, ctx.zbuf, ctx.rng_m, ctx.rng_v, ctx.rng_mul, ctx.rng_sub,
            ctx.health,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimizer_specs_parse_and_canonicalize() {
        for (spec, want) in [
            ("gd", OptimizerSpec::Gd),
            ("SGD", OptimizerSpec::Gd),
            ("momentum", OptimizerSpec::Momentum { beta: DEFAULT_BETA }),
            ("Momentum:0.9", OptimizerSpec::Momentum { beta: 0.9 }),
            ("heavy_ball:0.8", OptimizerSpec::Momentum { beta: 0.8 }),
            ("nesterov", OptimizerSpec::Nesterov { beta: DEFAULT_BETA }),
            ("nag:0.95", OptimizerSpec::Nesterov { beta: 0.95 }),
            (
                "adam",
                OptimizerSpec::Adam {
                    beta1: DEFAULT_BETA,
                    beta2: DEFAULT_ADAM_BETA2,
                    eps: DEFAULT_ADAM_EPS,
                },
            ),
            (
                " ADAM:0.9:0.999:0.00000001 ",
                OptimizerSpec::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            ),
            (
                "adam:0.8:0.99",
                OptimizerSpec::Adam { beta1: 0.8, beta2: 0.99, eps: DEFAULT_ADAM_EPS },
            ),
        ] {
            let got = OptimizerSpec::parse(spec).unwrap();
            assert_eq!(got, want, "{spec}");
            // Canon round-trips and is idempotent.
            let canon = got.canon();
            assert_eq!(OptimizerSpec::parse(&canon).unwrap(), got, "{spec} -> {canon}");
            assert_eq!(OptimizerSpec::parse(&canon).unwrap().canon(), canon);
        }
        // Spelling variants of the defaults coalesce to one canonical form.
        assert_eq!(OptimizerSpec::parse("momentum:0.9").unwrap().canon(), "momentum");
        assert_eq!(OptimizerSpec::parse("ADAM:0.9:0.999").unwrap().canon(), "adam");
        assert_eq!(OptimizerSpec::parse("adam:0.9:0.999:0.00000001").unwrap().canon(), "adam");
    }

    #[test]
    fn optimizer_spec_errors_are_descriptive() {
        for spec in ["bogus", "momentum:1.5", "momentum:x", "adam:0.9:0.999:0", "gd:0.1", "momentum:0.1:0.2"] {
            let err = OptimizerSpec::parse(spec).unwrap_err();
            assert!(matches!(err, SchemeError::BadSpec(_)), "{spec}: {err:?}");
        }
        let msg = OptimizerSpec::parse("bogus").unwrap_err().to_string();
        assert!(msg.contains("bogus") && msg.contains("momentum"), "{msg}");
    }

    #[test]
    fn state_names_are_stable() {
        assert_eq!(OptimizerSpec::Gd.state_names(), &[] as &[&str]);
        assert_eq!(OptimizerSpec::Momentum { beta: 0.9 }.state_names(), &["m"]);
        assert_eq!(OptimizerSpec::Nesterov { beta: 0.9 }.state_names(), &["m"]);
        assert_eq!(
            OptimizerSpec::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }.state_names(),
            &["m", "v"]
        );
        // The built optimizers agree with their specs, and init_state
        // allocates one zeroed tensor per name.
        for spec in [
            OptimizerSpec::Gd,
            OptimizerSpec::Momentum { beta: 0.9 },
            OptimizerSpec::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
        ] {
            let opt = spec.build();
            assert_eq!(opt.spec(), spec);
            assert_eq!(opt.state_names(), spec.state_names());
            let state = opt.init_state(7);
            assert_eq!(state.len(), spec.state_names().len());
            assert!(state.iter().all(|t| t.len() == 7 && t.iter().all(|&x| x == 0.0)));
        }
    }

    #[test]
    fn lr_schedules_parse_evaluate_and_canonicalize() {
        assert_eq!(LrSchedule::parse("const").unwrap(), LrSchedule::Constant);
        assert_eq!(LrSchedule::parse("NONE").unwrap(), LrSchedule::Constant);
        assert_eq!(LrSchedule::parse("inv:0.5").unwrap(), LrSchedule::InvTime { rate: 0.5 });
        assert_eq!(
            LrSchedule::parse("step:0.5:10").unwrap(),
            LrSchedule::Step { gamma: 0.5, period: 10 }
        );
        for bad in ["inv", "step:0.5", "step:2.0:10", "step:0.5:0", "warmup:3"] {
            assert!(LrSchedule::parse(bad).is_err(), "{bad}");
        }
        // Constant returns the base bit-identically.
        let t = 0.1f64;
        assert_eq!(LrSchedule::Constant.at(t, 12).to_bits(), t.to_bits());
        // Inverse-time halves at k = 1/rate; staircase steps at the period.
        let inv = LrSchedule::InvTime { rate: 0.5 };
        assert!((inv.at(1.0, 2) - 0.5).abs() < 1e-15);
        let st = LrSchedule::Step { gamma: 0.5, period: 10 };
        assert_eq!(st.at(1.0, 9), 1.0);
        assert_eq!(st.at(1.0, 10), 0.5);
        assert_eq!(st.at(1.0, 25), 0.25);
        for s in [LrSchedule::Constant, inv, st] {
            assert_eq!(LrSchedule::parse(&s.canon()).unwrap(), s);
        }
    }
}
