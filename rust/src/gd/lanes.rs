//! Multi-seed lane execution of the GD iteration (8a)/(8b)/(8c).
//!
//! [`run_lane_batch`] runs `roots.len()` repetitions of one experiment cell
//! as interleaved lanes of a structure-of-arrays slab
//! ([`crate::fp::LaneBatch`] layout: element `i` of lane `l` at
//! `i * lanes + l`), sharing every data pass — the gradient evaluation, the
//! diagnostics and the fused (8b)/(8c) update kernel — across all lanes.
//! Each lane keeps its own RNG streams (σ₁ / δ₂ / δ₃, forked from its root
//! exactly as [`GdEngine::new`] forks them), so lane `l`'s trace is **bit
//! identical** to a scalar [`GdEngine`] run with `cfg.rng = Some(roots[l])`:
//! lanes are an execution strategy, never part of a result's identity (the
//! contract asserted by this module's tests and relied on by the journal
//! and golden layers — see `docs/performance.md`).
//!
//! Features that are inherently per-lane-sequential — τ_k recording (an
//! extra gradient evaluation interleaved with the σ₁ stream), the
//! divergence guard (early exit at different steps per lane), state-carrying
//! optimizers and per-tensor policy bindings (their state streams and extra
//! rounding sites have no lane kernel yet), and non-constant LR schedules —
//! fall back to per-lane scalar engines, which satisfies the identity
//! trivially.

use crate::fp::kernels;
use crate::fp::lanes::LaneBatch;
use crate::fp::linalg::LpCtx;
use crate::fp::rng::Rng;
use crate::fp::round::{RoundPlan, Rounding, RunHealth};
use crate::gd::engine::{GdConfig, GdEngine, GradModel};
use crate::gd::trace::{IterRecord, Trace};
use crate::problems::Problem;

/// Run `roots.len()` repetitions of the configured GD run as parallel lanes
/// over one shared data pass. `roots[l]` is lane `l`'s root RNG (the stream
/// a scalar run would receive via [`GdConfig::rng`]); `x0` is the shared
/// starting point (rounded onto the working grid with RN, as in
/// [`GdEngine::new`]); `metric` is evaluated per lane on gathered columns.
/// Returns one [`Trace`] per lane, bit-identical to the corresponding
/// scalar runs.
pub fn run_lane_batch<P: Problem + ?Sized>(
    cfg: &GdConfig,
    problem: &P,
    x0: &[f64],
    roots: &[Rng],
    metric: Option<&dyn Fn(&[f64]) -> f64>,
) -> Vec<Trace> {
    assert!(!roots.is_empty(), "run_lane_batch needs at least one lane");
    let n = problem.dim();
    assert_eq!(x0.len(), n);

    // τ_k interleaves an extra (8a) evaluation with the per-lane σ₁ stream
    // and the escape guard ends lanes at different steps; both are
    // per-lane-sequential, so serve them with scalar engines (identical
    // results by construction). State-carrying optimizers, per-tensor
    // policy bindings and LR schedules likewise take the scalar path.
    if cfg.record_tau
        || cfg.escape.is_some()
        || !cfg.optimizer.is_gd()
        || cfg.schemes.has_bindings()
        || !cfg.lr.is_constant()
    {
        return roots
            .iter()
            .map(|root| {
                let mut c = cfg.clone();
                c.rng = Some(root.clone());
                GdEngine::new(c, problem, x0).run(metric)
            })
            .collect();
    }

    let lanes = roots.len();
    // Per-lane streams, forked exactly as `GdEngine::new` forks them.
    let mut ctxs: Vec<LpCtx> = roots
        .iter()
        .map(|root| {
            if cfg.grad_model == GradModel::Exact {
                LpCtx::exact()
            } else {
                LpCtx::new(cfg.grid, cfg.schemes.grad, root.fork("sigma1", 0))
                    .with_sr_bits(cfg.sr_bits)
            }
        })
        .collect();
    let mut rngs_mul: Vec<Rng> = roots.iter().map(|r| r.fork("delta2", 0)).collect();
    let mut rngs_sub: Vec<Rng> = roots.iter().map(|r| r.fork("delta3", 0)).collect();

    // The shared x0 lands on the working grid via RN, exactly as in
    // `GdEngine::new`. RN consumes no randomness, so one pass (with lane
    // 0's "x0" fork, unread) serves every lane.
    let mut x0g = x0.to_vec();
    let mut rng0 = roots[0].fork("x0", 0);
    RoundPlan::new(cfg.grid).round_slice(Rounding::RoundNearestEven, &mut x0g, &mut rng0);
    let mut x = LaneBatch::broadcast(&x0g, lanes);

    // One plan for the whole run: `cfg` is borrowed immutably, so the
    // per-step re-derivation of the scalar engine cannot observe changes.
    let plan = RoundPlan::new(cfg.grid).with_sr_bits(cfg.sr_bits);

    let mut gexact = vec![0.0; n * lanes];
    let mut ghat = vec![0.0; n * lanes];
    let mut mbuf = vec![0.0; n * lanes];
    let mut vneg = vec![0.0; n * lanes];
    let mut zbuf = vec![0.0; n * lanes];
    let mut fs = vec![0.0; lanes];
    let mut gn2 = vec![0.0; lanes];
    let mut d2 = vec![0.0; lanes];
    let mut mvals = vec![f64::NAN; lanes];
    let mut health = vec![RunHealth::default(); lanes];
    let mut moved = vec![false; lanes];
    let mut traces = vec![Trace::default(); lanes];

    for k in 0..cfg.steps {
        // Diagnostics on the *current* iterates — per-lane accumulation in
        // element order, matching the sequential fold of `exact::norm2`.
        problem.gradient_exact_lanes(x.as_slice(), lanes, &mut gexact);
        problem.objective_lanes(x.as_slice(), lanes, &mut fs);
        gn2.fill(0.0);
        for i in 0..n {
            for (l, s) in gn2.iter_mut().enumerate() {
                let g = gexact[i * lanes + l];
                *s += g * g;
            }
        }
        let opt = problem.optimum();
        if let Some(xs) = opt {
            d2.fill(0.0);
            for (i, &xsi) in xs.iter().enumerate() {
                for (l, s) in d2.iter_mut().enumerate() {
                    let r = x.get(i, l) - xsi;
                    *s += r * r;
                }
            }
        }
        if let Some(m) = metric {
            for (l, v) in mvals.iter_mut().enumerate() {
                *v = m(&x.lane(l));
            }
        }

        // (8a): the low-precision gradient, one shared pass over the slab.
        match cfg.grad_model {
            GradModel::Exact => ghat.copy_from_slice(&gexact),
            GradModel::RoundAfterOp => {
                problem.gradient_rounded_lanes(x.as_slice(), lanes, &mut ctxs, &mut ghat)
            }
            GradModel::PerOp => {
                problem.gradient_per_op_lanes(x.as_slice(), lanes, &mut ctxs, &mut ghat)
            }
        }

        // (8b)+(8c): the fused lane kernel.
        moved.fill(false);
        kernels::gd_update_lanes(
            &plan,
            cfg.schemes.mul,
            cfg.schemes.sub,
            cfg.t,
            x.as_mut_slice(),
            &ghat,
            lanes,
            &mut mbuf,
            &mut vneg,
            &mut zbuf,
            &mut rngs_mul,
            &mut rngs_sub,
            &mut health,
            &mut moved,
        );
        for (l, trace) in traces.iter_mut().enumerate() {
            health[l].steps += 1;
            if !moved[l] {
                health[l].stalled_steps += 1;
            }
            trace.push(IterRecord {
                k,
                f: fs[l],
                grad_norm: gn2[l].sqrt(),
                dist_to_opt: if opt.is_some() { d2[l].sqrt() } else { f64::NAN },
                tau: f64::NAN,
                stalled: !moved[l],
                metric: mvals[l],
            });
        }
    }
    for (trace, h) in traces.iter_mut().zip(&health) {
        trace.health = *h;
    }
    traces
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::format::FpFormat;
    use crate::fp::scheme::Scheme;
    use crate::gd::engine::PolicyMap;
    use crate::gd::optimizer::OptimizerSpec;
    use crate::problems::quadratic::Quadratic;

    fn scalar_oracle<P: Problem + ?Sized>(
        cfg: &GdConfig,
        p: &P,
        x0: &[f64],
        root: &Rng,
        metric: Option<&dyn Fn(&[f64]) -> f64>,
    ) -> Trace {
        let mut c = cfg.clone();
        c.rng = Some(root.clone());
        GdEngine::new(c, p, x0).run(metric)
    }

    fn assert_traces_bit_equal(lane: &Trace, oracle: &Trace, tag: &str) {
        assert_eq!(lane.len(), oracle.len(), "{tag}: trace length");
        assert_eq!(lane.status, oracle.status, "{tag}: status");
        for (a, b) in lane.records.iter().zip(&oracle.records) {
            assert_eq!(a.k, b.k, "{tag}");
            assert_eq!(a.f.to_bits(), b.f.to_bits(), "{tag} k={}: f", a.k);
            assert_eq!(
                a.grad_norm.to_bits(),
                b.grad_norm.to_bits(),
                "{tag} k={}: grad_norm",
                a.k
            );
            assert_eq!(
                a.dist_to_opt.to_bits(),
                b.dist_to_opt.to_bits(),
                "{tag} k={}: dist",
                a.k
            );
            assert_eq!(a.metric.to_bits(), b.metric.to_bits(), "{tag} k={}: metric", a.k);
            assert_eq!(a.stalled, b.stalled, "{tag} k={}: stalled", a.k);
        }
        assert_eq!(lane.health, oracle.health, "{tag}: health");
    }

    /// The core contract: every lane of a batch is bit-identical — records,
    /// health, status — to a scalar engine run with that lane's root stream,
    /// across lane widths, problems (diagonal and dense), schemes
    /// (deterministic, SR, mixed signed-SRε) and σ₁ models.
    #[test]
    fn lane_batch_matches_scalar_engines_bitwise() {
        let diag = Quadratic::diagonal(vec![2.0, 0.7, 1.3], vec![4.0, -1.0, 0.5]);
        let (dense, _, _) = Quadratic::setting2(9, 1);
        let policies: Vec<(&str, PolicyMap)> = vec![
            ("rn", PolicyMap::uniform(Scheme::rn())),
            ("sr", PolicyMap::uniform(Scheme::sr())),
            (
                "mixed",
                PolicyMap::sites(Scheme::sr(), Scheme::sr_eps(0.2), Scheme::signed_sr_eps(0.25)),
            ),
        ];
        let metric: Option<&dyn Fn(&[f64]) -> f64> = Some(&|x: &[f64]| x[0] * 2.0);
        for (pname, problem) in [("diag", &diag), ("dense", &dense)] {
            let x0: Vec<f64> = (0..problem.dim()).map(|i| 1.0 + 0.25 * i as f64).collect();
            for (sname, policy) in &policies {
                for model in [GradModel::Exact, GradModel::RoundAfterOp, GradModel::PerOp] {
                    for lanes in [1usize, 4, 8] {
                        let mut cfg =
                            GdConfig::new(FpFormat::BFLOAT16, *policy, 0.05, 25);
                        cfg.grad_model = model;
                        let roots: Vec<Rng> =
                            (0..lanes).map(|l| Rng::new(40).split(l as u64)).collect();
                        let traces = run_lane_batch(&cfg, problem, &x0, &roots, metric);
                        assert_eq!(traces.len(), lanes);
                        for (l, tr) in traces.iter().enumerate() {
                            let oracle =
                                scalar_oracle(&cfg, problem, &x0, &roots[l], metric);
                            let tag =
                                format!("{pname}/{sname}/{model:?}/L={lanes}/lane={l}");
                            assert_traces_bit_equal(tr, &oracle, &tag);
                        }
                    }
                }
            }
        }
    }

    /// Lane width never leaks into results: the same roots run at widths 1,
    /// 2 and 8 produce identical traces lane for lane.
    #[test]
    fn lane_width_does_not_change_results() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        let cfg = GdConfig::new(FpFormat::BINARY8, Scheme::sr(), 0.05, 60);
        let roots: Vec<Rng> = (0..8).map(|l| Rng::new(7).split(l)).collect();
        let wide = run_lane_batch(&cfg, &p, &[1.0], &roots, None);
        for l in 0..8 {
            let solo = run_lane_batch(&cfg, &p, &[1.0], &roots[l..l + 1], None);
            assert_traces_bit_equal(&wide[l], &solo[0], &format!("width lane {l}"));
        }
        let pair = run_lane_batch(&cfg, &p, &[1.0], &roots[2..4], None);
        assert_traces_bit_equal(&wide[2], &pair[0], "pair lane 2");
        assert_traces_bit_equal(&wide[3], &pair[1], "pair lane 3");
    }

    /// τ_k recording and the divergence guard take the scalar fallback and
    /// still reproduce scalar engines exactly (including tau values and
    /// per-lane `Diverged` statuses).
    #[test]
    fn sequential_features_fall_back_to_scalar_engines() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        let mut cfg = GdConfig::new(FpFormat::BINARY8, Scheme::sr(), 0.05, 30);
        cfg.record_tau = true;
        let roots: Vec<Rng> = (0..3).map(|l| Rng::new(11).split(l)).collect();
        let traces = run_lane_batch(&cfg, &p, &[1.0], &roots, None);
        for (l, tr) in traces.iter().enumerate() {
            let oracle = scalar_oracle(&cfg, &p, &[1.0], &roots[l], None);
            assert_eq!(tr.tau_series(), oracle.tau_series(), "lane {l} tau");
            assert_traces_bit_equal(tr, &oracle, &format!("tau lane {l}"));
        }
        // Divergence guard: an unstable stepsize trips `escape` per lane.
        let mut cfg2 = GdConfig::new(FpFormat::BINARY64, Scheme::rn(), 1.0, 100);
        cfg2.grad_model = GradModel::Exact;
        cfg2.escape = Some(1e8);
        let p2 = Quadratic::diagonal(vec![2.0], vec![0.0]);
        let traces2 = run_lane_batch(&cfg2, &p2, &[1.0], &roots, None);
        for (l, tr) in traces2.iter().enumerate() {
            let oracle = scalar_oracle(&cfg2, &p2, &[1.0], &roots[l], None);
            assert_traces_bit_equal(tr, &oracle, &format!("escape lane {l}"));
            assert!(!tr.status.is_completed(), "lane {l} should diverge");
        }
    }

    /// Stateful optimizers, per-tensor policy bindings and LR schedules
    /// also fall back to scalar engines — the lane kernel knows nothing of
    /// state streams or binding sites, so the fallback predicate must fire.
    #[test]
    fn optimizer_and_policy_bindings_fall_back_to_scalar_engines() {
        use crate::gd::engine::TensorPolicy;
        use crate::gd::optimizer::LrSchedule;
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        let roots: Vec<Rng> = (0..3).map(|l| Rng::new(13).split(l)).collect();
        let mut variants: Vec<(&str, GdConfig)> = Vec::new();
        let mut c1 = GdConfig::new(FpFormat::BFLOAT16, Scheme::sr(), 0.02, 40);
        c1.optimizer = OptimizerSpec::Momentum { beta: 0.9 };
        variants.push(("momentum", c1));
        let mut c2 = GdConfig::new(FpFormat::BFLOAT16, Scheme::sr(), 0.02, 40);
        c2.optimizer = OptimizerSpec::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 };
        variants.push(("adam", c2));
        let bound = PolicyMap::uniform(Scheme::sr())
            .with_weights(TensorPolicy::new(Scheme::rn()).on(FpFormat::BINARY64));
        variants.push(("bound", GdConfig::new(FpFormat::BINARY8, bound, 0.05, 40)));
        let mut c3 = GdConfig::new(FpFormat::BINARY8, Scheme::sr(), 0.05, 40);
        c3.lr = LrSchedule::InvTime { rate: 0.1 };
        variants.push(("lr", c3));
        for (tag, cfg) in &variants {
            let traces = run_lane_batch(cfg, &p, &[1.0], &roots, None);
            for (l, tr) in traces.iter().enumerate() {
                let oracle = scalar_oracle(cfg, &p, &[1.0], &roots[l], None);
                assert_traces_bit_equal(tr, &oracle, &format!("{tag} lane {l}"));
            }
        }
    }
}
