//! [`RunBuilder`] — the documented front door for configuring and running
//! one low-precision GD experiment, replacing the historic sprawl of
//! `GdConfig::new` + rounding-enum plumbing + free rounding functions:
//!
//! ```no_run
//! use lpgd::gd::RunBuilder;
//! use lpgd::fp::FpFormat;
//! use lpgd::problems::Quadratic;
//!
//! let (p, x0, t) = Quadratic::setting1(1000);
//! let mut session = RunBuilder::new(&p)
//!     .format(FpFormat::BFLOAT16)
//!     .scheme("sr_eps:0.1")     // any registered scheme, per-tensor overridable
//!     .sub_scheme("signed:0.1") // mixed policy: distinct scheme for (8c)
//!     .sr_bits(8)               // few-random-bits knob
//!     .stepsize(t)
//!     .steps(4000)
//!     .seed(7)
//!     .start(&x0)
//!     .build()
//!     .unwrap();
//! let trace = session.run(None);
//! println!("final f = {}", trace.final_f());
//! ```
//!
//! Scheme specs go through [`crate::fp::scheme::SchemeRegistry`], policy
//! specs through [`PolicyMap::parse`] and optimizer / LR-schedule specs
//! through [`OptimizerSpec::parse`] / [`LrSchedule::parse`], so user
//! schemes registered at runtime work everywhere a built-in does. Spec
//! errors are deferred: setters never panic, and [`RunBuilder::build`]
//! reports the first one. See `docs/api.md` for the quick-start and the
//! migration table from the old API.

use crate::fp::format::FpFormat;
use crate::fp::grid::Grid;
use crate::fp::rng::Rng;
use crate::fp::round::DEFAULT_SR_BITS;
use crate::fp::scheme::{Scheme, SchemeError, SchemeRegistry};
use crate::gd::engine::{GdConfig, GdEngine, GradModel, PolicyMap};
use crate::gd::lanes::run_lane_batch;
use crate::gd::optimizer::{LrSchedule, OptimizerSpec};
use crate::gd::trace::Trace;
use crate::problems::Problem;

/// Builder-style configuration of one GD run over a [`Problem`].
///
/// Defaults: binary8, SR on all three steps, no tensor bindings, plain-GD
/// optimizer with a constant stepsize, the chop-style `RoundAfterOp` σ₁
/// model, `t = 0.5`, 100 steps, seed 0, default `sr_bits`, `x0 = 0`.
pub struct RunBuilder<'p> {
    problem: &'p dyn Problem,
    grid: Grid,
    policy: PolicyMap,
    optimizer: OptimizerSpec,
    lr: LrSchedule,
    grad_model: GradModel,
    t: f64,
    steps: usize,
    seed: u64,
    rng: Option<Rng>,
    sr_bits: u32,
    record_tau: bool,
    escape: Option<f64>,
    x0: Option<Vec<f64>>,
    lanes: usize,
    err: Option<SchemeError>,
}

impl<'p> RunBuilder<'p> {
    /// Start configuring a run of `problem` with the documented defaults.
    pub fn new(problem: &'p dyn Problem) -> Self {
        Self {
            problem,
            grid: Grid::Float(FpFormat::BINARY8),
            policy: PolicyMap::uniform(Scheme::sr()),
            optimizer: OptimizerSpec::Gd,
            lr: LrSchedule::Constant,
            grad_model: GradModel::RoundAfterOp,
            t: 0.5,
            steps: 100,
            seed: 0,
            rng: None,
            sr_bits: DEFAULT_SR_BITS,
            record_tau: false,
            escape: None,
            x0: None,
            lanes: 1,
            err: None,
        }
    }

    /// Working number grid: a floating-point [`FpFormat`], a fixed-point
    /// [`crate::fp::FixedPoint`], or a [`Grid`].
    pub fn format(mut self, grid: impl Into<Grid>) -> Self {
        self.grid = grid.into();
        self
    }

    /// Working grid by spec string — a float format name (`"binary8"`,
    /// `"bfloat16"`, …) or a fixed-point spec (`"q3.8"`, `"uq4.8"`,
    /// `"fixed:Q3.8"`); unknown specs surface as an error from
    /// [`RunBuilder::build`].
    pub fn format_name(mut self, name: &str) -> Self {
        match Grid::parse(name) {
            Some(g) => self.grid = g,
            None => self.stash(SchemeError::UnknownFormat(name.to_string())),
        }
        self
    }

    /// Alias of [`RunBuilder::format_name`] in CLI vocabulary: the
    /// `--backend` spec (`"binary8"` / `"fixed:Q3.8"` / …).
    pub fn backend(self, spec: &str) -> Self {
        self.format_name(spec)
    }

    /// One scheme spec for all three rounding sites (8a)/(8b)/(8c),
    /// clearing any tensor bindings.
    pub fn scheme(mut self, spec: &str) -> Self {
        match SchemeRegistry::lookup(spec) {
            Ok(s) => self.policy = PolicyMap::uniform(s),
            Err(e) => self.stash(e),
        }
        self
    }

    /// Scheme for the gradient evaluation (8a) only.
    pub fn grad_scheme(mut self, spec: &str) -> Self {
        match SchemeRegistry::lookup(spec) {
            Ok(s) => self.policy.grad = s,
            Err(e) => self.stash(e),
        }
        self
    }

    /// Scheme for the stepsize multiplication (8b) only.
    pub fn mul_scheme(mut self, spec: &str) -> Self {
        match SchemeRegistry::lookup(spec) {
            Ok(s) => self.policy.mul = s,
            Err(e) => self.stash(e),
        }
        self
    }

    /// Scheme for the iterate subtraction (8c) only.
    pub fn sub_scheme(mut self, spec: &str) -> Self {
        match SchemeRegistry::lookup(spec) {
            Ok(s) => self.policy.sub = s,
            Err(e) => self.stash(e),
        }
        self
    }

    /// Set the whole per-tensor policy from already-resolved handles.
    pub fn policy(mut self, policy: impl Into<PolicyMap>) -> Self {
        self.policy = policy.into();
        self
    }

    /// Set the whole policy from a spec string — a bare scheme (`"sr"`) or
    /// the full per-tensor grammar
    /// (`"policy:weights=sr_eps:0.4@bf16,m=rn@fp32"`; see
    /// [`PolicyMap::parse`]).
    pub fn policy_spec(mut self, spec: &str) -> Self {
        match PolicyMap::parse(spec) {
            Ok(p) => self.policy = p,
            Err(e) => self.stash(e),
        }
        self
    }

    /// The update law driving each step (plain GD, momentum, Nesterov,
    /// Adam).
    pub fn optimizer(mut self, opt: OptimizerSpec) -> Self {
        self.optimizer = opt;
        self
    }

    /// Optimizer by spec string — `"gd"`, `"momentum:0.9"`,
    /// `"nesterov:0.9"`, `"adam:0.9:0.999:1e-8"` (see
    /// [`OptimizerSpec::parse`]).
    pub fn optimizer_name(mut self, spec: &str) -> Self {
        match OptimizerSpec::parse(spec) {
            Ok(o) => self.optimizer = o,
            Err(e) => self.stash(e),
        }
        self
    }

    /// Stepsize decay schedule (constant by default).
    pub fn lr(mut self, lr: LrSchedule) -> Self {
        self.lr = lr;
        self
    }

    /// LR schedule by spec string — `"const"`, `"inv:0.1"`,
    /// `"step:0.5:100"` (see [`LrSchedule::parse`]).
    pub fn lr_name(mut self, spec: &str) -> Self {
        match LrSchedule::parse(spec) {
            Ok(l) => self.lr = l,
            Err(e) => self.stash(e),
        }
        self
    }

    /// Random bits per stochastic slice rounding (few-random-bits knob).
    pub fn sr_bits(mut self, bits: u32) -> Self {
        self.sr_bits = bits;
        self
    }

    /// Fixed stepsize `t`.
    pub fn stepsize(mut self, t: f64) -> Self {
        self.t = t;
        self
    }

    /// Number of GD iterations (epochs for the learning problems).
    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    /// Root seed for the run's RNG streams.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Inject a pre-split RNG stream (overrides the seed — the
    /// scheduler's determinism primitive; see [`GdConfig::rng`]).
    pub fn rng(mut self, rng: Rng) -> Self {
        self.rng = Some(rng);
        self
    }

    /// σ₁ model for the gradient evaluation (8a).
    pub fn grad_model(mut self, gm: GradModel) -> Self {
        self.grad_model = gm;
        self
    }

    /// Record τ_k each iteration (stagnation diagnostics).
    pub fn record_tau(mut self, yes: bool) -> Self {
        self.record_tau = yes;
        self
    }

    /// Divergence guard: terminate the run with
    /// [`crate::gd::trace::RunStatus::Diverged`] as soon as the loss is
    /// non-finite or exceeds `threshold` (see `docs/robustness.md`).
    pub fn escape(mut self, threshold: f64) -> Self {
        self.escape = Some(threshold);
        self
    }

    /// Starting point `x0` (defaults to the zero vector of the problem's
    /// dimension; rounded into the working format on build, as always).
    pub fn start(mut self, x0: &[f64]) -> Self {
        self.x0 = Some(x0.to_vec());
        self
    }

    /// Lane width for [`RunBuilder::run_reps`]: repetitions execute in
    /// chunks of `n` interleaved lanes sharing one data pass (the
    /// structure-of-arrays fast path of [`crate::gd::run_lane_batch`];
    /// see `docs/performance.md`). Clamped to ≥ 1. This is purely an
    /// execution knob — per-repetition results are bit-identical at
    /// every width.
    pub fn lanes(mut self, n: usize) -> Self {
        self.lanes = n.max(1);
        self
    }

    fn stash(&mut self, e: SchemeError) {
        if self.err.is_none() {
            self.err = Some(e);
        }
    }

    /// Materialize the run: validate the deferred spec errors, assemble
    /// the [`GdConfig`] and build the engine. The resulting session runs
    /// bit-identically to a hand-assembled `GdConfig` with the same
    /// fields (asserted by `rust/tests/scheme_conformance.rs`).
    pub fn build(self) -> Result<GdSession<'p>, SchemeError> {
        if let Some(e) = self.err {
            return Err(e);
        }
        let mut cfg = GdConfig::new(self.grid, self.policy, self.t, self.steps);
        cfg.grad_model = self.grad_model;
        cfg.seed = self.seed;
        cfg.rng = self.rng;
        cfg.record_tau = self.record_tau;
        cfg.sr_bits = self.sr_bits;
        cfg.escape = self.escape;
        cfg.optimizer = self.optimizer;
        cfg.lr = self.lr;
        let x0 = self.x0.unwrap_or_else(|| vec![0.0; self.problem.dim()]);
        Ok(GdSession { engine: GdEngine::new(cfg, self.problem, &x0) })
    }

    /// Run `reps` independent repetitions of this configuration and return
    /// one [`Trace`] per repetition, executing them [`RunBuilder::lanes`]
    /// at a time as interleaved lane batches over one shared data pass.
    ///
    /// Stream derivation matches the scalar conventions exactly, so every
    /// repetition is bit-identical to a single [`RunBuilder::build`] +
    /// `run` at any lane width: without an injected stream, repetition `r`
    /// uses the legacy seed-keyed root `Rng::new(seed + r)`; with
    /// [`RunBuilder::rng`] set, repetition `r` uses `root.split(r)` (the
    /// scheduler's per-cell stream convention).
    pub fn run_reps(
        self,
        reps: usize,
        metric: Option<&dyn Fn(&[f64]) -> f64>,
    ) -> Result<Vec<Trace>, SchemeError> {
        if let Some(e) = self.err {
            return Err(e);
        }
        let mut cfg = GdConfig::new(self.grid, self.policy, self.t, self.steps);
        cfg.grad_model = self.grad_model;
        cfg.seed = self.seed;
        cfg.record_tau = self.record_tau;
        cfg.sr_bits = self.sr_bits;
        cfg.escape = self.escape;
        cfg.optimizer = self.optimizer;
        cfg.lr = self.lr;
        let x0 = self.x0.unwrap_or_else(|| vec![0.0; self.problem.dim()]);
        let roots: Vec<Rng> = (0..reps as u64)
            .map(|r| match &self.rng {
                Some(root) => root.split(r),
                None => Rng::new(self.seed.wrapping_add(r)),
            })
            .collect();
        let mut traces = Vec::with_capacity(reps);
        for chunk in roots.chunks(self.lanes) {
            traces.extend(run_lane_batch(&cfg, self.problem, &x0, chunk, metric));
        }
        Ok(traces)
    }
}

/// A configured, runnable GD session produced by [`RunBuilder::build`]: a
/// [`GdEngine`] over a dyn [`Problem`] with convenience accessors.
pub struct GdSession<'p> {
    engine: GdEngine<'p, dyn Problem + 'p>,
}

impl<'p> GdSession<'p> {
    /// Run the configured number of steps, optionally recording a
    /// per-iteration task metric (e.g. test error).
    pub fn run(&mut self, metric: Option<&dyn Fn(&[f64]) -> f64>) -> Trace {
        self.engine.run(metric)
    }

    /// One GD iteration (8a)+(8b)+(8c); returns true if the iterate moved.
    pub fn step(&mut self) -> bool {
        self.engine.step()
    }

    /// The current iterate x̂ (always representable in the working format).
    pub fn x(&self) -> &[f64] {
        &self.engine.x
    }

    /// The run configuration.
    pub fn config(&self) -> &GdConfig {
        &self.engine.cfg
    }

    /// Rounding operations performed inside the (8a) gradient context.
    pub fn grad_rounding_ops(&self) -> u64 {
        self.engine.grad_rounding_ops()
    }

    /// The underlying engine, for callers needing full control.
    pub fn engine(&mut self) -> &mut GdEngine<'p, dyn Problem + 'p> {
        &mut self.engine
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::Quadratic;

    /// The builder path is bit-identical to a hand-assembled legacy
    /// config for a mixed policy.
    #[test]
    fn builder_matches_legacy_config_bitwise() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        let schemes =
            PolicyMap::sites(Scheme::sr(), Scheme::sr(), Scheme::signed_sr_eps(0.25));
        let mut cfg = GdConfig::new(FpFormat::BINARY8, schemes, 0.05, 80);
        cfg.seed = 11;
        let mut legacy = GdEngine::new(cfg, &p, &[1.0]);
        let legacy_series = legacy.run(None).objective_series();

        let mut session = RunBuilder::new(&p)
            .format_name("binary8")
            .scheme("sr")
            .sub_scheme("signed:0.25")
            .stepsize(0.05)
            .steps(80)
            .seed(11)
            .start(&[1.0])
            .build()
            .unwrap();
        let built_series = session.run(None).objective_series();
        assert_eq!(legacy_series, built_series);
        assert_eq!(legacy.x, session.x());
    }

    #[test]
    fn builder_surfaces_spec_errors_at_build() {
        let p = Quadratic::diagonal(vec![1.0], vec![0.0]);
        let err = RunBuilder::new(&p).scheme("no_such_scheme").build().unwrap_err();
        assert!(err.to_string().contains("no_such_scheme"), "{err}");
        let err = RunBuilder::new(&p).format_name("binary7").build().unwrap_err();
        assert!(matches!(err, SchemeError::UnknownFormat(_)), "{err}");
        // First error wins over later valid setters.
        let err = RunBuilder::new(&p).scheme("bogus").scheme("sr").build().unwrap_err();
        assert!(err.to_string().contains("bogus"), "{err}");
    }

    /// `--backend fixed:Qm.n` plumbing: the builder parses fixed-point
    /// specs, the session runs on the uniform grid, and the two spec
    /// spellings produce bit-identical trajectories.
    #[test]
    fn builder_accepts_fixed_backend_specs() {
        use crate::fp::grid::{FixedPoint, NumberGrid};
        let p = Quadratic::diagonal(vec![2.0], vec![1.0]);
        let run = |spec: &str| {
            let mut s = RunBuilder::new(&p)
                .backend(spec)
                .scheme("sr")
                .stepsize(0.05)
                .steps(40)
                .seed(3)
                .start(&[4.0])
                .build()
                .unwrap();
            (s.run(None).objective_series(), s.x().to_vec())
        };
        let (fa, xa) = run("fixed:Q3.8");
        let (fb, xb) = run("q3.8");
        assert_eq!(fa, fb);
        assert_eq!(xa, xb);
        let fx = FixedPoint::q(3, 8);
        assert!(xa.iter().all(|&v| NumberGrid::contains(&fx, v)));
        // And the typed entry point agrees with the spec path.
        let mut s = RunBuilder::new(&p)
            .format(fx)
            .scheme("sr")
            .stepsize(0.05)
            .steps(40)
            .seed(3)
            .start(&[4.0])
            .build()
            .unwrap();
        assert_eq!(s.run(None).objective_series(), fa);
    }

    /// `run_reps` is bit-identical to looping scalar sessions over
    /// `seed + r`, at every lane width (the lanes knob is execution-only).
    #[test]
    fn run_reps_is_bit_identical_to_seed_looped_runs() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        let mk = |lanes: usize| {
            RunBuilder::new(&p)
                .format_name("binary8")
                .scheme("sr")
                .stepsize(0.05)
                .steps(50)
                .seed(20)
                .start(&[1.0])
                .lanes(lanes)
                .run_reps(6, None)
                .unwrap()
        };
        let wide = mk(4);
        let narrow = mk(1);
        assert_eq!(wide.len(), 6);
        for (r, tr) in wide.iter().enumerate() {
            assert_eq!(
                tr.objective_series(),
                narrow[r].objective_series(),
                "rep {r}: lane width leaked into results"
            );
            let mut s = RunBuilder::new(&p)
                .format_name("binary8")
                .scheme("sr")
                .stepsize(0.05)
                .steps(50)
                .seed(20 + r as u64)
                .start(&[1.0])
                .build()
                .unwrap();
            assert_eq!(tr.objective_series(), s.run(None).objective_series(), "rep {r}");
        }
    }

    /// With an injected root stream, repetition `r` runs on `root.split(r)`
    /// — the scheduler's per-cell convention.
    #[test]
    fn run_reps_with_injected_stream_splits_per_rep() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        let root = Rng::new(9);
        let reps = RunBuilder::new(&p)
            .format_name("binary8")
            .scheme("sr")
            .stepsize(0.05)
            .steps(40)
            .rng(root.clone())
            .lanes(3)
            .start(&[1.0])
            .run_reps(5, None)
            .unwrap();
        assert_eq!(reps.len(), 5);
        for (r, tr) in reps.iter().enumerate() {
            let mut s = RunBuilder::new(&p)
                .format_name("binary8")
                .scheme("sr")
                .stepsize(0.05)
                .steps(40)
                .rng(root.split(r as u64))
                .start(&[1.0])
                .build()
                .unwrap();
            assert_eq!(tr.objective_series(), s.run(None).objective_series(), "rep {r}");
        }
    }

    /// The optimizer / policy / LR spec setters are bit-identical to the
    /// typed setters, and malformed specs surface at build.
    #[test]
    fn builder_optimizer_and_policy_specs_match_typed_setters() {
        let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
        let series = |b: RunBuilder| b.build().unwrap().run(None).objective_series();
        let base = || {
            RunBuilder::new(&p)
                .format_name("bfloat16")
                .stepsize(0.02)
                .steps(60)
                .seed(4)
                .start(&[1.0])
        };
        let typed = series(
            base()
                .policy(PolicyMap::uniform(Scheme::sr()))
                .optimizer(OptimizerSpec::Momentum { beta: 0.9 })
                .lr(LrSchedule::InvTime { rate: 0.01 }),
        );
        let specced =
            series(base().policy_spec("sr").optimizer_name("momentum:0.9").lr_name("inv:0.01"));
        assert_eq!(typed, specced);
        // Binding specs flow through to the config.
        let s = base().policy_spec("policy:weights=rn@binary64").build().unwrap();
        assert!(s.config().schemes.has_bindings());
        // Malformed specs defer to build().
        for bad in [
            base().optimizer_name("adamw"),
            base().lr_name("step:2.0:5"),
            base().policy_spec("policy:q=rn"),
        ] {
            assert!(matches!(bad.build().unwrap_err(), SchemeError::BadSpec(_)));
        }
    }

    #[test]
    fn builder_defaults_run_and_round_x0() {
        let p = Quadratic::diagonal(vec![1.0, 0.5], vec![0.0, 0.0]);
        let mut s = RunBuilder::new(&p).steps(5).build().unwrap();
        let tr = s.run(None);
        assert_eq!(tr.records.len(), 5);
        assert!(s.x().iter().all(|&v| FpFormat::BINARY8.contains(v)));
        assert_eq!(s.config().sr_bits, DEFAULT_SR_BITS);
    }
}
