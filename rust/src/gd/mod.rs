//! The gradient-descent engine under floating-point rounding (systems
//! S5–S7): the three-step iteration (8a)/(8b)/(8c), stagnation analysis
//! (§3.2), and the paper's convergence-theory calculators (§4).

pub mod builder;
pub mod engine;
pub mod lanes;
pub mod optimizer;
pub mod stagnation;
pub mod theory;
pub mod trace;

pub use builder::{GdSession, RunBuilder};
pub use engine::{GdConfig, GdEngine, GradModel, PolicyMap, TensorPolicy};
pub use lanes::run_lane_batch;
pub use optimizer::{LrSchedule, Optimizer, OptimizerSpec, StepCtx};
pub use stagnation::{lsb_is_even, tau_k, StagnationReport};
pub use trace::{IterRecord, RunStatus, Trace};
