//! Stagnation analysis of GD under RN (paper §3.2).
//!
//! Write `z_i^{(k+1)} = x̂_i^{(k)} − RN(t·RN(∇f(x̂^{(k)})_i)) = μ_i 2^{e_i − s}`
//! with `μ_i ∈ [2^{s−1}, 2^s)`. The paper defines
//!
//! `τ_k := max_i 2^{−e_i} RN(t · RN(∇f(x̂^{(k)})_i))`
//!
//! and shows GD stagnates under RN when `τ_k ≤ u/2` and the least significant
//! bit of `x̂_{i_k}` is 0: the scaled update falls below half an ulp of the
//! landing binade, so RN maps `z` back to `x̂`.

use crate::fp::format::exponent_of;
use crate::fp::grid::{Grid, NumberGrid};
use crate::fp::round::{round, Rounding};
use crate::fp::rng::Rng;

/// Result of the τ_k computation for one iteration.
#[derive(Debug, Clone, Copy)]
pub struct StagnationReport {
    /// τ_k as defined above (0 when the update is identically zero). On a
    /// fixed-point grid τ_k is the update measured in grid spacings,
    /// `max_i RN(t·ĝ_i)/δ` (the binade scaling degenerates to one uniform
    /// scale).
    pub tau: f64,
    /// The arg-max coordinate i_k.
    pub argmax: usize,
    /// τ_k at or below the grid's stagnation threshold
    /// ([`Grid::stagnation_threshold`]: `u/2` float, `1/2` fixed).
    pub below_threshold: bool,
    /// Is the least significant bit of x̂_{i_k} zero (even significand /
    /// even stored integer)?
    pub lsb_even: bool,
}

/// Least-significant-bit parity of a representable value `x ∈ G`:
/// true iff the significand (float) or stored integer `k` (fixed) is even.
pub fn lsb_is_even(grid: impl Into<Grid>, x: f64) -> bool {
    if x == 0.0 {
        return true;
    }
    let m = match grid.into() {
        Grid::Float(fmt) => (x / fmt.spacing_at(x)).abs(),
        Grid::Fixed(fx) => (x / fx.delta()).abs(),
    };
    debug_assert_eq!(m, m.trunc(), "lsb_is_even requires x ∈ G");
    (m as u64) % 2 == 0
}

/// Compute τ_k for the current iterate `x` and *computed* (already rounded,
/// step-(8a)) gradient `ghat`, with stepsize `t`, under RN on `grid`.
///
/// Float backend — `2^{e_i - s}`-scaling: with `μ ∈ [2^{s−1}, 2^s)` we have
/// `e_i = exponent_of(|z_i|) + 1`, so `2^{−e_i} = 2^{−(⌊log₂|z_i|⌋+1)}`.
/// Fixed backend — the spacing is uniform, so the scaled update is simply
/// `RN(t·ĝ_i)/δ` and the threshold is `1/2` (RN maps the landing point
/// back to x̂ exactly when the update is below half a spacing).
pub fn tau_k(grid: impl Into<Grid>, x: &[f64], ghat: &[f64], t: f64) -> StagnationReport {
    let grid = grid.into();
    debug_assert_eq!(x.len(), ghat.len());
    let mut rng = Rng::new(0); // RN consumes no randomness
    let mut tau = 0.0f64;
    let mut argmax = 0usize;
    for i in 0..x.len() {
        // RN(t · RN(ĝ_i)): ĝ is already on the grid (RN(ĝ)=ĝ); round the
        // product.
        let upd = round(grid, Rounding::RoundNearestEven, t * ghat[i], &mut rng).abs();
        let scaled = match grid {
            Grid::Float(_) => {
                let z = x[i] - upd * ghat[i].signum(); // landing point (exact probe)
                if z == 0.0 {
                    continue; // landing exactly on zero cannot stagnate via binade scaling
                }
                let e = exponent_of(z.abs()) + 1;
                upd * crate::fp::format::pow2(-e)
            }
            Grid::Fixed(fx) => upd / fx.delta(),
        };
        if scaled > tau {
            tau = scaled;
            argmax = i;
        }
    }
    let below = tau <= grid.stagnation_threshold();
    StagnationReport {
        tau,
        argmax,
        below_threshold: below,
        lsb_even: lsb_is_even(grid, x[argmax]),
    }
}

/// Scenario classification per coordinate (conditions (11)/(12)): does the
/// scaled update exceed half the gap to the strict neighbors of x̂_i?
/// Returns the fraction of coordinates in Scenario 1 (no stagnation).
pub fn scenario1_fraction(grid: impl Into<Grid>, x: &[f64], update: &[f64]) -> f64 {
    let grid = grid.into();
    debug_assert_eq!(x.len(), update.len());
    if x.is_empty() {
        return 1.0;
    }
    let mut n1 = 0usize;
    for i in 0..x.len() {
        let su = grid.successor(x[i]);
        let pr = grid.predecessor(x[i]);
        let up = update[i].abs();
        let gap_up = su - x[i];
        let gap_dn = x[i] - pr;
        // Condition (11): the update is large relative to either gap.
        if (gap_up.is_finite() && up / gap_up > 0.5) || (gap_dn.is_finite() && up / gap_dn > 0.5) {
            n1 += 1;
        }
    }
    n1 as f64 / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::format::FpFormat;
    use crate::fp::grid::FixedPoint;

    const B8: FpFormat = FpFormat::BINARY8;

    #[test]
    fn lsb_parity() {
        // At binade [1024, 2048), spacing 256: 1024 → m=4 even; 1280 → m=5 odd.
        assert!(lsb_is_even(&B8, 1024.0));
        assert!(!lsb_is_even(&B8, 1280.0));
        assert!(lsb_is_even(&B8, 1536.0));
        assert!(lsb_is_even(&B8, 0.0));
        assert!(lsb_is_even(&B8, -1024.0));
    }

    /// The paper's Figure 2 example: f(x) = (x−1024)², binary8 + RN. Once the
    /// update is small relative to ulp(x̂)≈256, τ_k ≤ u/2 and GD stalls.
    #[test]
    fn tau_detects_stagnation_near_1024() {
        // x̂ = 1280, gradient 2(x−1024) = 512, t small ⇒ t·g = 5.12 ≪ 128.
        let x = [1280.0];
        let g = [512.0];
        let rep = tau_k(&B8, &x, &g, 0.01);
        assert!(rep.below_threshold, "tau={}", rep.tau);
        // Large update ⇒ no stagnation flag.
        let rep2 = tau_k(&B8, &x, &g, 0.5);
        assert!(!rep2.below_threshold, "tau={}", rep2.tau);
    }

    #[test]
    fn tau_zero_update() {
        let rep = tau_k(&B8, &[1.0, 2.0], &[0.0, 0.0], 0.1);
        assert_eq!(rep.tau, 0.0);
        assert!(rep.below_threshold);
    }

    /// Fixed-point τ_k: the scaled update is upd/δ, the threshold is ½ —
    /// RN on a uniform grid freezes exactly when the rounded update is 0.
    #[test]
    fn tau_on_fixed_grid() {
        let fx = FixedPoint::q(3, 6); // δ = 2^-6
        let d = fx.delta();
        // Update t·g = 0.3δ < δ/2 ⇒ RN(t·g) = 0 ⇒ τ = 0, below threshold.
        let rep = tau_k(&fx, &[1.0], &[0.3 * d / 0.1], 0.1);
        assert_eq!(rep.tau, 0.0);
        assert!(rep.below_threshold);
        // Update 3δ ⇒ τ = 3 > ½ ⇒ not stagnating.
        let rep2 = tau_k(&fx, &[1.0], &[3.0 * d / 0.1], 0.1);
        assert!((rep2.tau - 3.0).abs() < 1e-12, "tau={}", rep2.tau);
        assert!(!rep2.below_threshold);
        // LSB parity on the stored integer: 1.0 = 64δ even, 1.0+δ odd.
        assert!(lsb_is_even(&fx, 1.0));
        assert!(!lsb_is_even(&fx, 1.0 + d));
        assert!(lsb_is_even(&fx, 0.0));
        // Scenario split on the uniform grid: both gaps are δ.
        assert_eq!(scenario1_fraction(&fx, &[1.0], &[0.6 * d]), 1.0);
        assert_eq!(scenario1_fraction(&fx, &[1.0], &[0.4 * d]), 0.0);
    }

    #[test]
    fn scenario_fraction() {
        // x=1.0 in binary8: su−x = 0.25, x−pr = 0.125.
        // update 0.2 > 0.5·0.125 ⇒ scenario 1; update 0.01 ⇒ scenario 2.
        assert_eq!(scenario1_fraction(&B8, &[1.0], &[0.2]), 1.0);
        assert_eq!(scenario1_fraction(&B8, &[1.0], &[0.01]), 0.0);
        assert_eq!(scenario1_fraction(&B8, &[1.0, 1.0], &[0.2, 0.01]), 0.5);
    }
}
