//! `lpgd serve` — the HTTP/1.1 experiment service over the
//! content-addressed result registry ([`crate::registry`]; API reference
//! and curl examples in `docs/service.md`).
//!
//! The daemon answers `POST /v1/run` requests — builder-shaped cell specs
//! or whole-experiment specs — *from the registry when it can*: a cell
//! whose content address is already stored is served byte-identically to
//! the run that computed it, misses fan out across the in-process
//! scheduler ([`crate::coordinator::scheduler`]) and are written back.
//! Because the store is the same one `reproduce --registry DIR` uses, a
//! sweep warmed offline is served hot, and vice versa.
//!
//! Guarantees (asserted by `rust/tests/serve.rs` and the unit tests):
//!
//! * **Bit-identity** — identical specs return byte-identical bodies
//!   whether computed, registry-served, or CLI-warmed; responses render
//!   from the stored records through one deterministic JSON law.
//! * **Coalescing** — identical concurrent requests share one
//!   computation; `/v1/stats` shows one miss per cell, ever.
//! * **Back-pressure** — the in-flight cell set is bounded (`--queue`);
//!   overflowing requests get `429` immediately instead of queueing.
//!
//! Everything is hand-rolled on `std::net` because the image is offline —
//! see [`http`] for the deliberately narrow HTTP/1.1 subset.
//!
//! Routes: `GET /v1/experiments` (the [`Catalog`], shared with
//! `lpgd list`), `GET /v1/stats`, `GET /v1/result/<16-hex-key>`,
//! `POST /v1/run`.

pub mod catalog;
pub mod http;
pub mod service;
pub mod spec;

pub use catalog::Catalog;
pub use service::{ExperimentService, Server};
pub use spec::RunSpec;
