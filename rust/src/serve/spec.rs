//! `POST /v1/run` body parsing, validation and cell planning.
//!
//! Two spec shapes are accepted (see `docs/service.md`):
//!
//! * **builder-shaped cells** — a `"problem"` object plus the
//!   [`crate::gd::RunBuilder`] knobs (`grid`, `scheme`, `stepsize`,
//!   `steps`, `seed`, `sr_bits`, `reps`, and the optimizer-zoo knobs
//!   `optimizer`, `lr` and `policy` — the [`PolicyMap`] spec language,
//!   mutually exclusive with the per-site `*_scheme` fields). Each
//!   repetition is one content-addressed cell: the key is derived from a
//!   *canonical spec string* (resolved scheme labels, normalized grid
//!   spelling, stepsize as raw bits, optimizer/policy/LR specs
//!   re-canonicalized with defaults elided), so equivalent spellings of
//!   the same run — `"SR"` vs `"sr"`, `"fixed:Q3.8"` vs `"q3.8"`,
//!   `"ADAM"` vs `"adam:0.9:0.999:0.00000001"` — share registry entries,
//!   and a spec that leaves the optimizer at plain GD keys exactly as it
//!   did before the optimizer surface existed.
//! * **whole experiments** — an `"experiment"` id plus the `ExpCtx` knobs
//!   the CLI exposes. The service threads its registry into the context,
//!   so experiment cells share the store with `reproduce --registry`.
//!
//! Every parse error is a complete human-readable sentence; it becomes the
//! body of the `400` response verbatim.

use crate::coordinator::experiments::ExpCtx;
use crate::coordinator::registry as experiments;
use crate::fp::{Grid, SchemeRegistry};
use crate::gd::optimizer::{LrSchedule, OptimizerSpec};
use crate::gd::trace::Trace;
use crate::gd::{PolicyMap, RunBuilder};
use crate::problems::Quadratic;
use crate::registry::{CellRecord, Provenance};
use crate::util::hash::{cell_stream, fnv1a, registry_key, Fnv1a};
use crate::util::json::Json;

/// Problem selector for builder-shaped specs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProblemSpec {
    /// Paper Setting I (§5.1): the ill-conditioned diagonal quadratic.
    Quadratic1 {
        /// Problem dimension (paper: 1000).
        dim: usize,
    },
    /// Paper Setting II (§5.1): the dense Householder quadratic with
    /// spectrum `{1, …, n}`.
    Quadratic2 {
        /// Problem dimension.
        dim: usize,
        /// Seed of the random orthogonal factor.
        data_seed: u64,
    },
}

impl ProblemSpec {
    /// Materialize `(problem, x0, paper default stepsize)`.
    fn build(&self) -> (Quadratic, Vec<f64>, f64) {
        match *self {
            ProblemSpec::Quadratic1 { dim } => Quadratic::setting1(dim),
            ProblemSpec::Quadratic2 { dim, data_seed } => Quadratic::setting2(dim, data_seed),
        }
    }

    /// Canonical identity fragment for the cache key.
    fn canon(&self) -> String {
        match *self {
            ProblemSpec::Quadratic1 { dim } => format!("quadratic1:{dim}"),
            ProblemSpec::Quadratic2 { dim, data_seed } => {
                format!("quadratic2:{dim}:{data_seed}")
            }
        }
    }
}

/// One planned repetition of a [`CellSpec`]: its content-addressed
/// identity, ready for registry lookup or compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedCell {
    /// Registry key ([`registry_key`] over the spec digest and cell id).
    pub key: u64,
    /// Cell stream id ([`cell_stream`] over the canonical spec string).
    pub cell: u64,
    /// Repetition index.
    pub rep: u64,
}

/// A validated builder-shaped run spec: everything needed to compute the
/// request's cells plus their content-addressed identities. Construct via
/// [`RunSpec::parse`] — validation happens there, so the compute path
/// cannot fail on spec errors.
#[derive(Debug, Clone)]
pub struct CellSpec {
    problem: ProblemSpec,
    grid: String,
    grad: String,
    mul: String,
    sub: String,
    scheme_label: String,
    policy: Option<PolicyMap>,
    optimizer: OptimizerSpec,
    lr: LrSchedule,
    stepsize: f64,
    steps: usize,
    seed: u64,
    sr_bits: u32,
    reps: usize,
    canon: String,
    digest: u64,
}

impl CellSpec {
    /// The spec's configuration digest (FNV-1a over the canonical string);
    /// hex-rendered in the response envelope.
    pub fn digest(&self) -> u64 {
        self.digest
    }

    /// The request's cells, one per repetition. Keys derive from the
    /// canonical spec string, so equivalent spellings share identity.
    pub fn plan(&self) -> Vec<PlannedCell> {
        (0..self.reps as u64)
            .map(|rep| {
                let cell = cell_stream("run", &self.canon, rep);
                PlannedCell { key: registry_key(self.digest, cell), cell, rep }
            })
            .collect()
    }

    /// Compute one repetition. Pure: identical specs and reps produce
    /// bit-identical traces, which is what makes the records cacheable.
    /// Repetition `r` runs on seed `seed + r`, the
    /// [`RunBuilder::run_reps`] convention.
    pub fn compute(&self, rep: u64) -> Trace {
        let (p, x0, _) = self.problem.build();
        let mut b = RunBuilder::new(&p)
            .format_name(&self.grid)
            .optimizer(self.optimizer)
            .lr(self.lr)
            .stepsize(self.stepsize)
            .steps(self.steps)
            .seed(self.seed.wrapping_add(rep))
            .start(&x0);
        b = match self.policy {
            Some(pol) => b.policy(pol),
            None => b.grad_scheme(&self.grad).mul_scheme(&self.mul).sub_scheme(&self.sub),
        };
        if self.sr_bits != 0 {
            b = b.sr_bits(self.sr_bits);
        }
        b.build().expect("spec validated at parse time").run(None)
    }

    /// Package a computed trace as the registry record for `pc`.
    pub fn record(&self, pc: &PlannedCell, trace: &Trace) -> CellRecord {
        CellRecord {
            digest: self.digest,
            cell: pc.cell,
            series: trace.objective_series(),
            health: trace.health,
            provenance: Provenance {
                code_version: env!("CARGO_PKG_VERSION").to_string(),
                experiment: "run".to_string(),
                label: format!("{}_{}", self.grid, self.scheme_label),
                rep: pc.rep,
                grid: self.grid.clone(),
                scheme: self.scheme_label.clone(),
                seed: self.seed.wrapping_add(pc.rep),
                sr_bits: self.sr_bits,
            },
        }
    }

    fn parse(v: &Json) -> Result<CellSpec, String> {
        reject_unknown(
            v,
            "spec",
            &[
                "problem", "grid", "scheme", "grad_scheme", "mul_scheme", "sub_scheme",
                "policy", "optimizer", "lr", "stepsize", "steps", "seed", "sr_bits", "reps",
            ],
        )?;
        let p = v.get("problem").expect("dispatched on 'problem' by RunSpec::parse");
        reject_unknown(p, "problem", &["kind", "dim", "data_seed"])?;
        let kind = req_str(p, "problem.kind")?;
        let dim = req_int(p, "problem.dim", 1, 4096)?;
        let data_seed = opt_u64(p, "problem.data_seed", 0)?;
        let problem = match kind.as_str() {
            "quadratic1" => ProblemSpec::Quadratic1 { dim },
            "quadratic2" => ProblemSpec::Quadratic2 { dim, data_seed },
            other => {
                return Err(format!(
                    "problem.kind must be 'quadratic1' or 'quadratic2', got '{other}'"
                ))
            }
        };

        let grid_raw = req_str(v, "grid")?;
        // Canonicalize through Grid::name() so every alias spelling —
        // "BF16", "bfloat16", "fixed:Q3.8", "q3.8" — shares one identity.
        let grid = match Grid::parse(&grid_raw) {
            Some(g) => g.name(),
            None => {
                return Err(format!(
                    "unknown grid '{grid_raw}' (float formats: binary8, bfloat16, binary16, \
                     binary32, binary64; fixed point: qM.F / uqM.F / fixed:QM.F)"
                ))
            }
        };

        // The whole-policy spec and the per-site scheme fields are two
        // spellings of the same surface; accepting both in one request
        // would make the canonical identity ambiguous.
        let policy_raw = opt_str(v, "policy")?;
        if policy_raw.is_some() {
            for k in ["scheme", "grad_scheme", "mul_scheme", "sub_scheme"] {
                if v.get(k).is_some() {
                    return Err(format!(
                        "'policy' sets the whole rounding policy; it conflicts with '{k}'"
                    ));
                }
            }
        }
        let scheme = opt_str(v, "scheme")?.unwrap_or_else(|| "sr".to_string());
        let grad = opt_str(v, "grad_scheme")?.unwrap_or_else(|| scheme.clone());
        let mul = opt_str(v, "mul_scheme")?.unwrap_or_else(|| scheme.clone());
        let sub = opt_str(v, "sub_scheme")?.unwrap_or_else(|| scheme.clone());
        let label = |spec: &str| -> Result<String, String> {
            SchemeRegistry::lookup(spec).map(|s| s.label()).map_err(|e| e.to_string())
        };
        let policy = match &policy_raw {
            Some(s) => Some(PolicyMap::parse(s).map_err(|e| e.to_string())?),
            None => None,
        };
        // Site labels come from the policy when one is given, so
        // {"scheme":"sr"} and {"policy":"sr"} canonicalize identically.
        let (grad_l, mul_l, sub_l) = match policy {
            Some(pol) => (pol.grad.label(), pol.mul.label(), pol.sub.label()),
            None => (label(&grad)?, label(&mul)?, label(&sub)?),
        };
        let scheme_label = if grad_l == mul_l && mul_l == sub_l {
            grad_l.clone()
        } else {
            format!("{grad_l}/{mul_l}/{sub_l}")
        };
        let optimizer = match opt_str(v, "optimizer")? {
            Some(s) => OptimizerSpec::parse(&s).map_err(|e| e.to_string())?,
            None => OptimizerSpec::Gd,
        };
        let lr = match opt_str(v, "lr")? {
            Some(s) => LrSchedule::parse(&s).map_err(|e| e.to_string())?,
            None => LrSchedule::Constant,
        };

        let stepsize = req_f64(v, "stepsize")?;
        if !(stepsize.is_finite() && stepsize > 0.0) {
            return Err(format!("stepsize must be a finite positive number, got {stepsize}"));
        }
        let steps = req_int(v, "steps", 1, 1_000_000)?;
        let seed = opt_u64(v, "seed", 0)?;
        let sr_bits = opt_int(v, "sr_bits", 0, 0, 53)? as u32;
        let reps = opt_int(v, "reps", 1, 1, 512)?;

        // The canonical string is the cache identity: resolved labels and
        // raw stepsize bits, so float formatting and spelling never split
        // or alias entries. The optimizer-zoo fragments are appended only
        // when they deviate from the plain-GD defaults, so every pre-zoo
        // spec keeps the digest it had before the surface existed.
        let mut canon = format!(
            "problem={};grid={};grad={};mul={};sub={};t={:016x};steps={};seed={};sr_bits={}",
            problem.canon(),
            grid,
            grad_l,
            mul_l,
            sub_l,
            stepsize.to_bits(),
            steps,
            seed,
            sr_bits
        );
        if !optimizer.is_gd() {
            canon.push_str(&format!(";opt={}", optimizer.canon()));
        }
        if !lr.is_constant() {
            canon.push_str(&format!(";lr={}", lr.canon()));
        }
        if let Some(pol) = policy {
            if pol.has_bindings() {
                let toks: Vec<String> = [("w", pol.weights), ("m", pol.m), ("v", pol.v)]
                    .iter()
                    .filter_map(|(name, b)| b.map(|b| format!("{name}={}", b.canon_token())))
                    .collect();
                canon.push_str(&format!(";bind={}", toks.join(",")));
            }
        }
        let digest = fnv1a(canon.as_bytes());
        Ok(CellSpec {
            problem,
            grid,
            grad,
            mul,
            sub,
            scheme_label,
            policy,
            optimizer,
            lr,
            stepsize,
            steps,
            seed,
            sr_bits,
            reps,
            canon,
            digest,
        })
    }
}

/// Response shape for experiment-form requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutFormat {
    /// JSON envelope with every produced table embedded as CSV text.
    Json,
    /// Raw `text/csv` of one table — byte-identical to the file
    /// `reproduce` writes, which is what the CI smoke `cmp`s.
    Csv,
}

/// A validated experiment-form spec: an experiment id plus the context
/// knobs that shape its cells.
#[derive(Debug, Clone)]
pub struct ExpSpec {
    /// Experiment id (validated against the experiment registry).
    pub id: String,
    /// Context assembled from the spec fields. The service fills in the
    /// registry handle and the jobs default before running.
    pub ctx: ExpCtx,
    /// Worker-thread override from the spec (absent → service default).
    pub jobs: Option<usize>,
    /// Response shape.
    pub format: OutFormat,
    /// For `format = "csv"`: id of the table to return (default: first).
    pub table: Option<String>,
}

impl ExpSpec {
    /// Coalescing identity: requests with equal keys compute identical
    /// cells, so concurrent duplicates share one computation slot. Folds
    /// everything that changes the computed values — the id, the
    /// config digest and the seed count — and nothing that doesn't.
    pub fn coalesce_key(&self) -> u64 {
        Fnv1a::new()
            .str("exp")
            .byte(0xff)
            .str(&self.id)
            .u64(self.ctx.config_digest())
            .u64(self.ctx.seeds as u64)
            .finish()
    }

    fn parse(v: &Json) -> Result<ExpSpec, String> {
        reject_unknown(
            v,
            "spec",
            &[
                "experiment", "quick", "seeds", "jobs", "lanes", "side", "mlr_train",
                "mlr_test", "nn_train", "nn_test", "mlr_epochs", "nn_epochs", "quad_steps",
                "quad_n", "escape", "format", "table",
            ],
        )?;
        let id = req_str(v, "experiment")?;
        if id == "all" || experiments::find(&id).is_none() {
            let ids: Vec<&str> = experiments::REGISTRY.iter().map(|s| s.id).collect();
            return Err(format!("unknown experiment '{id}' (known: {})", ids.join(", ")));
        }
        // The quick profile is the service default: a stray full-size
        // request should be an explicit opt-out, not an accident.
        let quick = opt_bool(v, "quick", true)?;
        let mut ctx = if quick { ExpCtx::quick() } else { ExpCtx::default() };
        ctx.seeds = opt_int(v, "seeds", ctx.seeds, 1, 100)?;
        ctx.lanes = opt_int(v, "lanes", ctx.lanes, 1, 64)?;
        ctx.side = opt_int(v, "side", ctx.side, 4, 64)?;
        ctx.mlr_train = opt_int(v, "mlr_train", ctx.mlr_train, 1, 100_000)?;
        ctx.mlr_test = opt_int(v, "mlr_test", ctx.mlr_test, 1, 100_000)?;
        ctx.nn_train = opt_int(v, "nn_train", ctx.nn_train, 1, 100_000)?;
        ctx.nn_test = opt_int(v, "nn_test", ctx.nn_test, 1, 100_000)?;
        ctx.mlr_epochs = opt_int(v, "mlr_epochs", ctx.mlr_epochs, 1, 10_000)?;
        ctx.nn_epochs = opt_int(v, "nn_epochs", ctx.nn_epochs, 1, 10_000)?;
        ctx.quad_steps = opt_int(v, "quad_steps", ctx.quad_steps, 1, 1_000_000)?;
        ctx.quad_n = opt_int(v, "quad_n", ctx.quad_n, 1, 4096)?;
        if let Some(x) = v.get("escape") {
            let e = x.as_f64().ok_or("escape must be a number")?;
            if !(e.is_finite() && e > 0.0) {
                return Err(format!("escape must be a finite positive number, got {e}"));
            }
            ctx.escape = Some(e);
        }
        let jobs = match v.get("jobs") {
            Some(_) => Some(opt_int(v, "jobs", 0, 0, 256)?),
            None => None,
        };
        let format = match opt_str(v, "format")?.as_deref() {
            None | Some("json") => OutFormat::Json,
            Some("csv") => OutFormat::Csv,
            Some(other) => {
                return Err(format!("format must be 'json' or 'csv', got '{other}'"))
            }
        };
        let table = opt_str(v, "table")?;
        Ok(ExpSpec { id, ctx, jobs, format, table })
    }
}

/// A parsed `POST /v1/run` body: one of the two accepted spec shapes.
#[derive(Debug, Clone)]
pub enum RunSpec {
    /// Builder-shaped cells.
    Cells(CellSpec),
    /// A whole-experiment run.
    Experiment(ExpSpec),
}

impl RunSpec {
    /// Validate a request body. Every error string is a complete sentence
    /// — it becomes the `400` response body verbatim.
    pub fn parse(v: &Json) -> Result<RunSpec, String> {
        if v.get("experiment").is_some() {
            ExpSpec::parse(v).map(RunSpec::Experiment)
        } else if v.get("problem").is_some() {
            CellSpec::parse(v).map(RunSpec::Cells)
        } else {
            Err("spec must contain either 'problem' (builder-shaped cells) or 'experiment' \
                 (a whole experiment); see docs/service.md"
                .to_string())
        }
    }
}

// ------------------------------------------------- field-access helpers --

fn reject_unknown(v: &Json, what: &str, known: &[&str]) -> Result<(), String> {
    let Json::Obj(pairs) = v else {
        return Err(format!("{what} must be a JSON object"));
    };
    for (k, _) in pairs {
        if !known.contains(&k.as_str()) {
            return Err(format!("unknown {what} field '{k}' (known: {})", known.join(", ")));
        }
    }
    Ok(())
}

fn req_str(v: &Json, name: &str) -> Result<String, String> {
    let key = name.rsplit('.').next().unwrap_or(name);
    match v.get(key) {
        None => Err(format!("missing field '{name}'")),
        Some(x) => {
            x.as_str().map(str::to_string).ok_or_else(|| format!("{name} must be a string"))
        }
    }
}

fn opt_str(v: &Json, name: &str) -> Result<Option<String>, String> {
    match v.get(name) {
        None => Ok(None),
        Some(x) => x
            .as_str()
            .map(|s| Some(s.to_string()))
            .ok_or_else(|| format!("{name} must be a string")),
    }
}

fn req_f64(v: &Json, name: &str) -> Result<f64, String> {
    match v.get(name) {
        None => Err(format!("missing field '{name}'")),
        Some(x) => x.as_f64().ok_or_else(|| format!("{name} must be a number")),
    }
}

fn req_int(v: &Json, name: &str, lo: usize, hi: usize) -> Result<usize, String> {
    let key = name.rsplit('.').next().unwrap_or(name);
    match v.get(key) {
        None => Err(format!("missing field '{name}'")),
        Some(x) => int_in_range(x, name, lo, hi),
    }
}

fn opt_int(v: &Json, name: &str, default: usize, lo: usize, hi: usize) -> Result<usize, String> {
    match v.get(name) {
        None => Ok(default),
        Some(x) => int_in_range(x, name, lo, hi),
    }
}

fn int_in_range(x: &Json, name: &str, lo: usize, hi: usize) -> Result<usize, String> {
    let n = x.as_usize().ok_or_else(|| format!("{name} must be a non-negative integer"))?;
    if (lo..=hi).contains(&n) {
        Ok(n)
    } else {
        Err(format!("{name} must be in {lo}..={hi}, got {n}"))
    }
}

fn opt_u64(v: &Json, name: &str, default: u64) -> Result<u64, String> {
    let key = name.rsplit('.').next().unwrap_or(name);
    match v.get(key) {
        None => Ok(default),
        Some(x) => x.as_u64().ok_or_else(|| format!("{name} must be a non-negative integer")),
    }
}

fn opt_bool(v: &Json, name: &str, default: bool) -> Result<bool, String> {
    match v.get(name) {
        None => Ok(default),
        Some(x) => x.as_bool().ok_or_else(|| format!("{name} must be true or false")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Result<RunSpec, String> {
        RunSpec::parse(&Json::parse(text).unwrap())
    }

    fn cells(text: &str) -> CellSpec {
        match parse(text).unwrap() {
            RunSpec::Cells(c) => c,
            RunSpec::Experiment(_) => panic!("expected cell spec"),
        }
    }

    const MINIMAL: &str = r#"{"problem":{"kind":"quadratic1","dim":16},
        "grid":"bfloat16","stepsize":0.05,"steps":20}"#;

    #[test]
    fn equivalent_spellings_share_cell_identity() {
        let a = cells(MINIMAL);
        let b = cells(
            r#"{"problem":{"kind":"quadratic1","dim":16},"grid":"BF16",
                "scheme":"SR","stepsize":0.05,"steps":20,"seed":0,"reps":1}"#,
        );
        assert_eq!(a.digest(), b.digest());
        assert_eq!(a.plan(), b.plan());
        // And a genuinely different run gets different keys.
        let c = cells(
            r#"{"problem":{"kind":"quadratic1","dim":16},"grid":"bfloat16",
                "stepsize":0.05,"steps":21}"#,
        );
        assert_ne!(a.digest(), c.digest());
        assert_ne!(a.plan()[0].key, c.plan()[0].key);
    }

    /// Optimizer / policy / LR spellings canonicalize before FNV-1a
    /// keying: every variant of the same run coalesces to one registry
    /// record, explicit defaults elide entirely, and plain-GD specs keep
    /// the digest they had before the optimizer surface existed.
    #[test]
    fn optimizer_and_policy_spellings_coalesce() {
        let with = |extra: &str| {
            cells(&format!(
                r#"{{"problem":{{"kind":"quadratic1","dim":16}},"grid":"bfloat16",
                    "stepsize":0.05,"steps":20{extra}}}"#
            ))
        };
        let base = cells(MINIMAL);
        // Explicit plain-GD defaults are elided from the canonical string.
        let explicit = with(r#","optimizer":"gd","lr":"const""#);
        assert_eq!(base.digest(), explicit.digest());
        assert_eq!(base.plan(), explicit.plan());
        // {"policy":"SR"} is the default {"scheme":"sr"} run, spelled big.
        assert_eq!(base.digest(), with(r#","policy":"SR""#).digest());
        // Adam spelled four ways: case, full and partial explicit defaults,
        // momentum-family aliases — one record each way.
        let a = with(r#","optimizer":"ADAM""#);
        assert_eq!(a.digest(), with(r#","optimizer":"adam:0.9:0.999:0.00000001""#).digest());
        assert_eq!(a.digest(), with(r#","optimizer":"adam:0.9""#).digest());
        assert_eq!(a.plan(), with(r#","optimizer":"adam""#).plan());
        assert_ne!(a.digest(), base.digest());
        assert_ne!(a.digest(), with(r#","optimizer":"adam:0.8""#).digest());
        let m = with(r#","optimizer":"momentum:0.90""#);
        assert_eq!(m.digest(), with(r#","optimizer":"heavy_ball:0.9""#).digest());
        assert_ne!(m.digest(), a.digest());
        // LR schedules key canonically too, and non-defaults split.
        let lr = with(r#","lr":"inv:0.01""#);
        assert_eq!(lr.digest(), with(r#","lr":"inv_time:0.01""#).digest());
        assert_ne!(lr.digest(), base.digest());
        // Policy bindings: grid aliases, case and sr-site default elision
        // normalize into one identity; a different binding splits.
        let b1 = with(r#","policy":"policy:grad=sr,mul=sr,sub=sr,weights=rn@binary64""#);
        let b2 = with(r#","policy":"policy:w=RN@FP64""#);
        assert_eq!(b1.digest(), b2.digest());
        assert_eq!(b1.plan(), b2.plan());
        assert_ne!(b1.digest(), base.digest());
        assert_ne!(b1.digest(), with(r#","policy":"policy:m=rn@fp64""#).digest());
        // The whole-policy field refuses to mix with per-site fields.
        let e = parse(
            r#"{"problem":{"kind":"quadratic1","dim":16},"grid":"bfloat16",
                "stepsize":0.05,"steps":20,"policy":"sr","scheme":"rn"}"#,
        )
        .unwrap_err();
        assert!(e.contains("conflicts with 'scheme'"), "{e}");
        // Malformed optimizer specs read back as complete sentences.
        let e = parse(
            r#"{"problem":{"kind":"quadratic1","dim":16},"grid":"bfloat16",
                "stepsize":0.05,"steps":20,"optimizer":"adamw"}"#,
        )
        .unwrap_err();
        assert!(e.contains("adamw"), "{e}");
    }

    /// A stateful-optimizer cell computes through the same RunBuilder
    /// surface the public API exposes, bit for bit.
    #[test]
    fn optimizer_cells_compute_matches_run_builder() {
        let spec = cells(
            r#"{"problem":{"kind":"quadratic1","dim":8},"grid":"bfloat16",
                "stepsize":0.05,"steps":12,"seed":3,
                "optimizer":"momentum:0.9","policy":"policy:w=rn@binary64"}"#,
        );
        let (p, x0, _) = Quadratic::setting1(8);
        let mut direct = RunBuilder::new(&p)
            .format_name("bfloat16")
            .optimizer_name("momentum:0.9")
            .policy_spec("policy:w=rn@binary64")
            .stepsize(0.05)
            .steps(12)
            .seed(3)
            .start(&x0)
            .build()
            .unwrap();
        assert_eq!(spec.compute(0).objective_series(), direct.run(None).objective_series());
    }

    #[test]
    fn planned_reps_are_distinct_and_compute_matches_run_builder() {
        let spec = cells(
            r#"{"problem":{"kind":"quadratic1","dim":8},"grid":"binary8",
                "stepsize":0.05,"steps":12,"seed":5,"reps":3}"#,
        );
        let plan = spec.plan();
        assert_eq!(plan.len(), 3);
        assert_ne!(plan[0].key, plan[1].key);
        // compute(rep) follows the run_reps convention: seed + rep.
        let (p, x0, _) = Quadratic::setting1(8);
        let mut direct = RunBuilder::new(&p)
            .format_name("binary8")
            .scheme("sr")
            .stepsize(0.05)
            .steps(12)
            .seed(7)
            .start(&x0)
            .build()
            .unwrap();
        assert_eq!(spec.compute(2).objective_series(), direct.run(None).objective_series());
        let rec = spec.record(&plan[2], &spec.compute(2));
        assert_eq!(rec.provenance.seed, 7);
        assert_eq!(rec.provenance.experiment, "run");
        assert_eq!(rec.provenance.label, "binary8_SR");
        assert_eq!(rec.series.len(), 12);
    }

    #[test]
    fn errors_are_descriptive() {
        let err = |t: &str| parse(t).unwrap_err();
        assert!(err("{}").contains("'problem'"), "{}", err("{}"));
        let e = err(r#"{"problem":{"kind":"cubic","dim":4},"grid":"binary8",
            "stepsize":0.1,"steps":5}"#);
        assert!(e.contains("quadratic1") && e.contains("cubic"), "{e}");
        let e = err(r#"{"problem":{"kind":"quadratic1","dim":4},"grid":"binary7",
            "stepsize":0.1,"steps":5}"#);
        assert!(e.contains("binary7") && e.contains("bfloat16"), "{e}");
        let e = err(r#"{"problem":{"kind":"quadratic1","dim":4},"grid":"binary8",
            "stepsize":0.1,"steps":5,"scheme":"nope"}"#);
        assert!(e.contains("nope"), "{e}");
        let e = err(r#"{"problem":{"kind":"quadratic1","dim":4},"grid":"binary8",
            "stepsize":0.1}"#);
        assert!(e.contains("missing field 'steps'"), "{e}");
        let e = err(r#"{"problem":{"kind":"quadratic1","dim":4},"grid":"binary8",
            "stepsize":0.1,"step":5}"#);
        assert!(e.contains("unknown spec field 'step'"), "{e}");
        let e = err(r#"{"experiment":"nope"}"#);
        assert!(e.contains("unknown experiment 'nope'") && e.contains("fig3a"), "{e}");
        let e = err(r#"{"experiment":"fig3a","format":"xml"}"#);
        assert!(e.contains("'json' or 'csv'"), "{e}");
    }

    #[test]
    fn experiment_specs_build_contexts_and_coalesce_keys() {
        let RunSpec::Experiment(a) = parse(r#"{"experiment":"fig3a"}"#).unwrap() else {
            panic!("expected experiment spec")
        };
        assert_eq!(a.ctx.seeds, ExpCtx::quick().seeds, "quick is the service default");
        assert_eq!(a.format, OutFormat::Json);
        let RunSpec::Experiment(b) =
            parse(r#"{"experiment":"fig3a","format":"csv"}"#).unwrap()
        else {
            panic!("expected experiment spec")
        };
        // The output format never splits the computation identity…
        assert_eq!(a.coalesce_key(), b.coalesce_key());
        // …but a cell-shaping knob does.
        let RunSpec::Experiment(c) =
            parse(r#"{"experiment":"fig3a","quad_n":64}"#).unwrap()
        else {
            panic!("expected experiment spec")
        };
        assert_ne!(a.coalesce_key(), c.coalesce_key());
    }
}
