//! The experiment/scheme/grid catalog shared by `lpgd list` and
//! `GET /v1/experiments`: one gathering pass, two renderers, so the CLI
//! listing and the service endpoint can never drift apart.

use std::collections::HashMap;

use crate::coordinator::registry as experiments;
use crate::fp::{FpFormat, SchemeRegistry};
use crate::registry::ResultStore;
use crate::util::json::Json;

/// One experiment row: the registry entry plus how many of its cells the
/// result registry holds (when one is open).
#[derive(Debug, Clone)]
pub struct ExperimentRow {
    /// Experiment id (`fig3a`, …).
    pub id: String,
    /// Human-readable description.
    pub description: String,
    /// Paper table/figure reference.
    pub paper_ref: String,
    /// Cached cell count in the result registry; `None` when the catalog
    /// was gathered without a store.
    pub cached: Option<usize>,
}

/// The full catalog: experiments, rounding schemes and number grids.
#[derive(Debug, Clone)]
pub struct Catalog {
    /// Every registered experiment, registry order.
    pub experiments: Vec<ExperimentRow>,
    /// `(name-with-hint, aliases, summary)` per registered scheme.
    pub schemes: Vec<(String, String, String)>,
    /// Number-grid spec strings the builders accept.
    pub grids: Vec<String>,
    /// Total records in the result registry (`None` without one).
    pub cached_total: Option<usize>,
}

impl Catalog {
    /// Gather the catalog, joining per-experiment registry record counts
    /// when a store is supplied.
    pub fn gather(store: Option<&ResultStore>) -> Self {
        let counts: Option<HashMap<String, usize>> =
            store.map(|s| s.experiments().into_iter().collect());
        let experiments = experiments::REGISTRY
            .iter()
            .map(|s| ExperimentRow {
                id: s.id.to_string(),
                description: s.description.to_string(),
                paper_ref: s.paper_ref.to_string(),
                cached: counts.as_ref().map(|c| c.get(s.id).copied().unwrap_or(0)),
            })
            .collect();
        let mut grids: Vec<String> = [
            FpFormat::BINARY8,
            FpFormat::BFLOAT16,
            FpFormat::BINARY16,
            FpFormat::BINARY32,
            FpFormat::BINARY64,
        ]
        .iter()
        .map(|f| f.name().to_string())
        .collect();
        grids.push("qM.F (signed fixed point, e.g. q3.8)".to_string());
        grids.push("uqM.F (unsigned fixed point)".to_string());
        Self {
            experiments,
            schemes: SchemeRegistry::entries(),
            grids,
            cached_total: store.map(ResultStore::len),
        }
    }

    /// The `GET /v1/experiments` body.
    pub fn to_json(&self) -> Json {
        let exps = self
            .experiments
            .iter()
            .map(|e| {
                let mut o = vec![
                    ("id".to_string(), Json::Str(e.id.clone())),
                    ("description".to_string(), Json::Str(e.description.clone())),
                    ("paper_ref".to_string(), Json::Str(e.paper_ref.clone())),
                ];
                if let Some(n) = e.cached {
                    o.push(("cached_cells".to_string(), Json::Num(n as f64)));
                }
                Json::Obj(o)
            })
            .collect();
        let schemes = self
            .schemes
            .iter()
            .map(|(name, aliases, summary)| {
                Json::Obj(vec![
                    ("name".to_string(), Json::Str(name.clone())),
                    ("aliases".to_string(), Json::Str(aliases.clone())),
                    ("summary".to_string(), Json::Str(summary.clone())),
                ])
            })
            .collect();
        let grids = self.grids.iter().map(|g| Json::Str(g.clone())).collect();
        let mut top = vec![
            ("experiments".to_string(), Json::Arr(exps)),
            ("schemes".to_string(), Json::Arr(schemes)),
            ("grids".to_string(), Json::Arr(grids)),
        ];
        if let Some(total) = self.cached_total {
            top.push(("cached_total".to_string(), Json::Num(total as f64)));
        }
        Json::Obj(top)
    }

    /// The `lpgd list` text rendering.
    pub fn render_text(&self) -> String {
        let mut out = String::from("experiments:\n");
        for e in &self.experiments {
            out.push_str(&format!("  {:<8} {:<10} {}", e.id, e.paper_ref, e.description));
            if let Some(n) = e.cached {
                if n > 0 {
                    out.push_str(&format!("  [{n} cells cached]"));
                }
            }
            out.push('\n');
        }
        out.push_str("\nrounding schemes:\n");
        for (name, aliases, summary) in &self.schemes {
            out.push_str(&format!("  {name:<16} {summary}"));
            if !aliases.is_empty() {
                out.push_str(&format!(" (aliases: {aliases})"));
            }
            out.push('\n');
        }
        out.push_str("\nnumber grids:\n");
        for g in &self.grids {
            out.push_str(&format!("  {g}\n"));
        }
        if let Some(total) = self.cached_total {
            out.push_str(&format!("\nregistry: {total} cached cells\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_lists_experiments_schemes_and_grids_in_both_renderings() {
        let cat = Catalog::gather(None);
        assert!(cat.experiments.iter().any(|e| e.id == "fig3a"));
        assert!(cat.experiments.iter().all(|e| e.cached.is_none()));
        assert!(cat.grids.iter().any(|g| g == "bfloat16"));
        assert!(!cat.schemes.is_empty());
        let text = cat.render_text();
        assert!(text.contains("fig3a"), "{text}");
        assert!(text.contains("rounding schemes:"), "{text}");
        assert!(!text.contains("registry:"), "no store, no registry footer: {text}");
        let json = cat.to_json().render();
        assert!(json.contains("\"experiments\""), "{json}");
        assert!(json.contains("\"fig3a\""), "{json}");
        assert!(!json.contains("cached_total"), "{json}");
    }

    #[test]
    fn registry_counts_join_into_both_renderings() {
        use crate::registry::CellRecord;
        let dir = std::env::temp_dir()
            .join(format!("lpgd_catalog_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = ResultStore::open(&dir).unwrap();
        let mut rec = CellRecord {
            digest: 1,
            cell: 2,
            series: vec![1.0],
            health: Default::default(),
            provenance: Default::default(),
        };
        rec.provenance.experiment = "fig3a".to_string();
        store.insert(11, rec);
        let cat = Catalog::gather(Some(&store));
        let row = cat.experiments.iter().find(|e| e.id == "fig3a").unwrap();
        assert_eq!(row.cached, Some(1));
        assert_eq!(cat.cached_total, Some(1));
        assert!(cat.render_text().contains("[1 cells cached]"));
        assert!(cat.to_json().render().contains("\"cached_total\":1"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
