//! Minimal HTTP/1.1 plumbing for the experiment service: request parsing
//! and response writing over a raw `TcpStream`, hand-rolled because the
//! image is offline (no `hyper`/`tiny_http`) and the API surface is four
//! routes.
//!
//! Deliberately narrow: every response carries `Connection: close` (no
//! keep-alive state machine), headers are capped at [`MAX_HEADER_BYTES`],
//! bodies at [`MAX_BODY_BYTES`], and reads time out after
//! [`READ_TIMEOUT`], so a slow or malicious client cannot pin a worker
//! thread indefinitely.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::util::json::Json;

/// Maximum bytes of request line + headers; beyond this the request is
/// answered with `431`.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;

/// Maximum request-body bytes; a larger declared `Content-Length` is
/// answered with `413` without reading the body.
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// Per-connection socket read timeout.
pub const READ_TIMEOUT: Duration = Duration::from_secs(5);

/// One parsed request: method, path and raw body bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    /// Request method (`GET`, `POST`, …).
    pub method: String,
    /// Request path as sent (`/v1/run`).
    pub path: String,
    /// Raw body bytes (`Content-Length`-delimited; empty when absent).
    pub body: Vec<u8>,
}

/// A response ready to serialize: status code, content type, body.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Response {
    /// HTTP status code (`200`, `400`, `429`, …).
    pub status: u16,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// Body bytes.
    pub body: Vec<u8>,
}

impl Response {
    /// A `text/plain` response; the error-path constructor. The message is
    /// newline-terminated so `curl` output reads cleanly.
    pub fn text(status: u16, msg: &str) -> Self {
        let mut body = msg.as_bytes().to_vec();
        if !body.ends_with(b"\n") {
            body.push(b'\n');
        }
        Self { status, content_type: "text/plain; charset=utf-8", body }
    }

    /// An `application/json` response rendered from a [`Json`] value
    /// through the deterministic renderer (identical values → identical
    /// bytes, the bit-identity contract of `docs/service.md`).
    pub fn json(status: u16, v: &Json) -> Self {
        Self { status, content_type: "application/json", body: v.render().into_bytes() }
    }

    /// A response with an explicit content type and raw body bytes
    /// (the `text/csv` experiment path).
    pub fn bytes(status: u16, content_type: &'static str, body: Vec<u8>) -> Self {
        Self { status, content_type, body }
    }

    /// Serialize to the wire. Always `Connection: close`: the client gets
    /// exactly one response per connection.
    pub fn write(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(&self.body)?;
        stream.flush()
    }
}

/// Standard reason phrase for every status the service emits.
fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

/// Read and parse one request from `stream`. `Err` carries the response
/// that should be written back (when the socket still works) before
/// closing the connection.
pub fn read_request(stream: &mut TcpStream) -> Result<Request, Response> {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = find_header_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEADER_BYTES {
            return Err(Response::text(
                431,
                &format!("request headers exceed {MAX_HEADER_BYTES} bytes"),
            ));
        }
        let n = stream
            .read(&mut chunk)
            .map_err(|e| Response::text(408, &format!("read failed or timed out: {e}")))?;
        if n == 0 {
            return Err(Response::text(400, "connection closed before the headers completed"));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..header_end])
        .map_err(|_| Response::text(400, "request headers are not valid UTF-8"))?;
    let (method, path, content_length) = parse_head(head)?;
    if content_length > MAX_BODY_BYTES {
        return Err(Response::text(
            413,
            &format!("request body of {content_length} bytes exceeds the {MAX_BODY_BYTES}-byte cap"),
        ));
    }
    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream
            .read(&mut chunk)
            .map_err(|e| Response::text(408, &format!("body read failed or timed out: {e}")))?;
        if n == 0 {
            return Err(Response::text(400, "connection closed before the body completed"));
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    Ok(Request { method, path, body })
}

/// Byte offset of the `\r\n\r\n` header terminator, if present.
fn find_header_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Parse the request line + headers into `(method, path, content_length)`.
fn parse_head(head: &str) -> Result<(String, String, usize), Response> {
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_ascii_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) => (m, p, v),
        _ => {
            return Err(Response::text(
                400,
                &format!("malformed request line '{request_line}' (want 'METHOD /path HTTP/1.1')"),
            ))
        }
    };
    if !version.starts_with("HTTP/1.") {
        return Err(Response::text(400, &format!("unsupported protocol version '{version}'")));
    }
    let mut content_length = 0usize;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().map_err(|_| {
                    Response::text(400, &format!("invalid Content-Length '{}'", value.trim()))
                })?;
            }
        }
    }
    Ok((method.to_string(), path.to_string(), content_length))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn head_parsing_extracts_method_path_and_length() {
        let (m, p, n) =
            parse_head("POST /v1/run HTTP/1.1\r\nHost: x\r\ncOnTeNt-LeNgTh:  42").unwrap();
        assert_eq!((m.as_str(), p.as_str(), n), ("POST", "/v1/run", 42));
        let (_, _, n) = parse_head("GET /v1/stats HTTP/1.1").unwrap();
        assert_eq!(n, 0);
        assert_eq!(parse_head("garbage").unwrap_err().status, 400);
        assert_eq!(parse_head("GET / SPDY/3").unwrap_err().status, 400);
        assert_eq!(
            parse_head("GET / HTTP/1.1\r\nContent-Length: ten").unwrap_err().status,
            400
        );
    }

    #[test]
    fn header_end_is_found_across_chunk_boundaries() {
        assert_eq!(find_header_end(b"GET / HTTP/1.1\r\n\r\nbody"), Some(14));
        assert_eq!(find_header_end(b"GET / HTTP/1.1\r\n"), None);
    }

    /// Full loop over a real socket: a pipelined write of headers + body in
    /// one segment parses, and the response wire format is well-formed.
    #[test]
    fn request_roundtrips_over_a_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"POST /v1/run HTTP/1.1\r\nContent-Length: 9\r\n\r\n{\"a\":1}\r\n")
                .unwrap();
            let mut reply = Vec::new();
            s.read_to_end(&mut reply).unwrap();
            reply
        });
        let (mut conn, _) = listener.accept().unwrap();
        let req = read_request(&mut conn).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/run");
        assert_eq!(req.body, b"{\"a\":1}\r\n");
        Response::text(200, "ok").write(&mut conn).unwrap();
        drop(conn);
        let reply = String::from_utf8(client.join().unwrap()).unwrap();
        assert!(reply.starts_with("HTTP/1.1 200 OK\r\n"), "{reply}");
        assert!(reply.contains("Connection: close\r\n"), "{reply}");
        assert!(reply.ends_with("\r\n\r\nok\n"), "{reply}");
    }

    #[test]
    fn oversized_declared_body_is_rejected_without_reading_it() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(
                format!("POST /v1/run HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1)
                    .as_bytes(),
            )
            .unwrap();
            s
        });
        let (mut conn, _) = listener.accept().unwrap();
        let err = read_request(&mut conn).unwrap_err();
        assert_eq!(err.status, 413);
        drop(client.join().unwrap());
    }
}
