//! The experiment service proper: request routing, registry-backed cell
//! resolution with request coalescing and bounded back-pressure, and the
//! `std::net` accept loop with its worker pool.
//!
//! # Coalescing contract
//!
//! Identical concurrent `POST /v1/run` requests must not compute the same
//! cell twice. A shared *in-flight set* holds the keys currently being
//! computed; a request claims every free missing key of its plan in one
//! locked pass, computes the claims on the scheduler, and only then —
//! holding no claims — waits for keys another request claimed first.
//! Claims are never held across a wait, so claim-cycle deadlocks between
//! overlapping requests are impossible by construction. A waiter reads the
//! finished records from the registry and counts them as *hits*: the first
//! request pays exactly one miss per cell, every other request pure hits,
//! which is what `rust/tests/serve.rs` asserts via `/v1/stats`.
//!
//! # Back-pressure contract
//!
//! The in-flight set is bounded (`--queue`). A request whose fresh claims
//! would push the set past capacity is answered `429` immediately, claiming
//! nothing — clients retry with backoff. Served-from-registry requests
//! never consume capacity, so a warmed registry keeps answering under
//! overload.

use std::collections::HashSet;
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::time::Instant;

use crate::coordinator::health::{panic_message, CellOutcome};
use crate::coordinator::registry as experiments;
use crate::coordinator::scheduler::run_indexed_faulted;
use crate::registry::{CellRecord, ResultStore};
use crate::serve::catalog::Catalog;
use crate::serve::http::{read_request, Request, Response};
use crate::serve::spec::{CellSpec, ExpSpec, OutFormat, PlannedCell, RunSpec};
use crate::util::json::Json;

/// Shared state of the `lpgd serve` daemon: the result registry plus the
/// coalescing / back-pressure machinery. One instance serves all workers.
pub struct ExperimentService {
    store: Arc<ResultStore>,
    inflight: Mutex<HashSet<u64>>,
    done: Condvar,
    capacity: usize,
    jobs: usize,
    requests: AtomicU64,
    started: Instant,
}

impl ExperimentService {
    /// Build a service over `store`. `capacity` bounds the in-flight cell
    /// set (the back-pressure knob); `jobs` is the scheduler width for
    /// computing misses (0 = all cores).
    pub fn new(store: Arc<ResultStore>, capacity: usize, jobs: usize) -> Self {
        Self {
            store,
            inflight: Mutex::new(HashSet::new()),
            done: Condvar::new(),
            capacity,
            jobs,
            requests: AtomicU64::new(0),
            started: Instant::now(),
        }
    }

    /// The underlying result registry.
    pub fn store(&self) -> &Arc<ResultStore> {
        &self.store
    }

    /// Route one parsed request — the worker entry point, also callable
    /// in-process (the unit tests exercise the full dispatch without
    /// sockets).
    pub fn handle(&self, req: &Request) -> Response {
        self.requests.fetch_add(1, Ordering::Relaxed);
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/v1/experiments") => {
                Response::json(200, &Catalog::gather(Some(&self.store)).to_json())
            }
            ("GET", "/v1/stats") => self.stats(),
            ("POST", "/v1/run") => self.run(req),
            ("GET", path) if path.starts_with("/v1/result/") => {
                self.result(&path["/v1/result/".len()..])
            }
            (_, "/v1/experiments") | (_, "/v1/stats") | (_, "/v1/run") => {
                Response::text(405, "method not allowed on this route")
            }
            _ => Response::text(
                404,
                "unknown route (GET /v1/experiments | GET /v1/stats | \
                 GET /v1/result/<key> | POST /v1/run)",
            ),
        }
    }

    fn lock_inflight(&self) -> MutexGuard<'_, HashSet<u64>> {
        self.inflight.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// `GET /v1/stats`: the hit/miss proof of the hot path. `requests`
    /// includes the call itself.
    fn stats(&self) -> Response {
        let num = |v: u64| Json::Num(v as f64);
        let in_flight = self.lock_inflight().len();
        Response::json(
            200,
            &Json::Obj(vec![
                ("requests".to_string(), num(self.requests.load(Ordering::Relaxed))),
                ("hits".to_string(), num(self.store.hits())),
                ("misses".to_string(), num(self.store.misses())),
                ("in_flight".to_string(), num(in_flight as u64)),
                ("queue_capacity".to_string(), num(self.capacity as u64)),
                ("cached_cells".to_string(), num(self.store.len() as u64)),
                (
                    "registry".to_string(),
                    Json::Str(self.store.dir().display().to_string()),
                ),
                ("uptime_secs".to_string(), num(self.started.elapsed().as_secs())),
            ]),
        )
    }

    /// `GET /v1/result/<16-hex-key>`: one record, rendered by the same
    /// `CellRecord::to_json` law as the on-disk line. Reads never touch
    /// the hit/miss counters (those measure `/v1/run` resolution only).
    fn result(&self, hex: &str) -> Response {
        let key = match u64::from_str_radix(hex, 16) {
            Ok(k) if hex.len() == 16 => k,
            _ => {
                return Response::text(
                    400,
                    &format!("'{hex}' is not a 16-hex-digit registry key"),
                )
            }
        };
        match self.store.peek(key) {
            Some(rec) => Response::json(200, &rec.to_json(key)),
            None => Response::text(404, &format!("no record under key {key:016x}")),
        }
    }

    /// `POST /v1/run`: parse, validate, dispatch to the cell or experiment
    /// path.
    fn run(&self, req: &Request) -> Response {
        let text = match std::str::from_utf8(&req.body) {
            Ok(t) => t,
            Err(_) => return Response::text(400, "request body is not UTF-8"),
        };
        let v = match Json::parse(text) {
            Ok(v) => v,
            Err(e) => {
                return Response::text(400, &format!("request body is not valid JSON: {e}"))
            }
        };
        match RunSpec::parse(&v) {
            Err(e) => Response::text(400, &format!("invalid run spec: {e}")),
            Ok(RunSpec::Cells(spec)) => self.run_cells(&spec),
            Ok(RunSpec::Experiment(spec)) => self.run_experiment(&spec),
        }
    }

    /// Builder-shaped cells: resolve every planned repetition against the
    /// registry and render the response from the stored records — so a
    /// computed answer and a served answer are bytes of the same law.
    fn run_cells(&self, spec: &CellSpec) -> Response {
        let planned = spec.plan();
        let records = match self.resolve_cells(spec, &planned) {
            Ok(r) => r,
            Err(resp) => return resp,
        };
        let cells: Vec<Json> =
            planned.iter().zip(&records).map(|(pc, rec)| rec.to_json(pc.key)).collect();
        Response::json(
            200,
            &Json::Obj(vec![
                ("digest".to_string(), Json::Str(format!("{:016x}", spec.digest()))),
                ("cells".to_string(), Json::Arr(cells)),
            ]),
        )
    }

    /// Resolve every planned cell to a registry record (see the module
    /// docs for the coalescing and back-pressure contracts).
    fn resolve_cells(
        &self,
        spec: &CellSpec,
        planned: &[PlannedCell],
    ) -> Result<Vec<Arc<CellRecord>>, Response> {
        let mut computed: HashSet<u64> = HashSet::new();
        // Two rounds suffice without faults (claim + compute, then
        // wait-and-read); the third absorbs a foreign computation dying
        // and this request re-claiming its cells.
        for _round in 0..3 {
            // Claim phase: every free missing key in one locked pass,
            // all-or-nothing against capacity.
            let mut mine: Vec<usize> = Vec::new();
            let mut wait_keys: Vec<u64> = Vec::new();
            {
                let mut inflight = self.lock_inflight();
                for (i, pc) in planned.iter().enumerate() {
                    if self.store.peek(pc.key).is_some() {
                        continue;
                    }
                    if inflight.contains(&pc.key) {
                        wait_keys.push(pc.key);
                    } else if !mine.iter().any(|&j| planned[j].key == pc.key) {
                        mine.push(i);
                    }
                }
                if !mine.is_empty() {
                    if inflight.len() + mine.len() > self.capacity {
                        return Err(Response::text(
                            429,
                            &format!(
                                "queue full: {} cells in flight, request needs {} more \
                                 (capacity {}) — retry later",
                                inflight.len(),
                                mine.len(),
                                self.capacity
                            ),
                        ));
                    }
                    for &i in &mine {
                        inflight.insert(planned[i].key);
                    }
                }
            }
            // Compute phase: fan the claims across the scheduler; each
            // finished cell is journaled into the registry from the
            // worker (`on_done`), so a kill mid-request loses at most
            // in-flight cells — the registry is never torn.
            if !mine.is_empty() {
                let runs = run_indexed_faulted(
                    self.jobs,
                    mine.len(),
                    1,
                    |k| spec.compute(planned[mine[k]].rep),
                    |k, r| {
                        if let Some(trace) = &r.value {
                            let pc = &planned[mine[k]];
                            self.store.insert(pc.key, spec.record(pc, trace));
                            self.store.count_miss();
                        }
                    },
                );
                {
                    let mut inflight = self.lock_inflight();
                    for &i in &mine {
                        inflight.remove(&planned[i].key);
                    }
                }
                self.done.notify_all();
                for &i in &mine {
                    computed.insert(planned[i].key);
                }
                for r in &runs {
                    if let CellOutcome::Failed(msg) = &r.outcome {
                        return Err(Response::text(
                            500,
                            &format!("cell computation failed: {msg}"),
                        ));
                    }
                }
            }
            // Wait phase: no claims held here, so overlapping requests
            // can never deadlock on each other's claims.
            {
                let mut inflight = self.lock_inflight();
                while wait_keys.iter().any(|k| inflight.contains(k)) {
                    inflight = self.done.wait(inflight).unwrap_or_else(|e| e.into_inner());
                }
            }
            // Read phase: serve from the store; anything this request did
            // not compute itself counts as a hit.
            let records: Option<Vec<Arc<CellRecord>>> =
                planned.iter().map(|pc| self.store.peek(pc.key)).collect();
            if let Some(records) = records {
                for pc in planned {
                    if !computed.contains(&pc.key) {
                        self.store.count_hit();
                    }
                }
                return Ok(records);
            }
            // A cell claimed by another request failed to materialize (its
            // computation panicked); loop and claim it ourselves.
        }
        Err(Response::text(500, "cells failed to materialize after retry"))
    }

    /// Whole-experiment requests: coalesce on the spec's computation
    /// identity, run the experiment builder with the service registry
    /// threaded into the context (cells hit the same store the CLI
    /// warms), and render the tables.
    fn run_experiment(&self, spec: &ExpSpec) -> Response {
        let key = spec.coalesce_key();
        {
            let mut inflight = self.lock_inflight();
            // Wait for an identical in-flight request, then run anyway:
            // every cell is now a registry hit and aggregation is
            // deterministic, so the bytes match the first answer.
            while inflight.contains(&key) {
                inflight = self.done.wait(inflight).unwrap_or_else(|e| e.into_inner());
            }
            if inflight.len() >= self.capacity {
                return Response::text(
                    429,
                    &format!(
                        "queue full: {} units in flight (capacity {}) — retry later",
                        inflight.len(),
                        self.capacity
                    ),
                );
            }
            inflight.insert(key);
        }
        let mut ctx = spec.ctx.clone();
        ctx.registry = Some(Arc::clone(&self.store));
        ctx.jobs = spec.jobs.unwrap_or(self.jobs);
        let exp = experiments::find(&spec.id).expect("id validated at parse time");
        let result = catch_unwind(AssertUnwindSafe(|| (exp.run)(&ctx)));
        {
            let mut inflight = self.lock_inflight();
            inflight.remove(&key);
        }
        self.done.notify_all();
        let tables = match result {
            Ok(t) => t,
            Err(payload) => {
                return Response::text(
                    500,
                    &format!(
                        "experiment '{}' aborted: {}",
                        spec.id,
                        panic_message(payload.as_ref())
                    ),
                )
            }
        };
        match spec.format {
            OutFormat::Csv => {
                let table = match &spec.table {
                    Some(id) => tables.iter().find(|t| &t.id == id),
                    None => tables.first(),
                };
                match table {
                    Some(t) => Response::bytes(200, "text/csv", t.to_csv().into_bytes()),
                    None => Response::text(
                        400,
                        &format!(
                            "experiment '{}' has no table '{}' (tables: {})",
                            spec.id,
                            spec.table.as_deref().unwrap_or("<first>"),
                            tables.iter().map(|t| t.id.as_str()).collect::<Vec<_>>().join(", ")
                        ),
                    ),
                }
            }
            OutFormat::Json => {
                let tables_json: Vec<Json> = tables
                    .iter()
                    .map(|t| {
                        Json::Obj(vec![
                            ("id".to_string(), Json::Str(t.id.clone())),
                            ("title".to_string(), Json::Str(t.title.clone())),
                            ("csv".to_string(), Json::Str(t.to_csv())),
                        ])
                    })
                    .collect();
                Response::json(
                    200,
                    &Json::Obj(vec![
                        ("experiment".to_string(), Json::Str(spec.id.clone())),
                        ("tables".to_string(), Json::Arr(tables_json)),
                    ]),
                )
            }
        }
    }
}

/// The TCP front end: a bound listener plus a fixed worker pool draining
/// an accept queue.
pub struct Server {
    listener: TcpListener,
    service: Arc<ExperimentService>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:7878`; port `0` picks an ephemeral
    /// port — read it back via [`Server::local_addr`]).
    pub fn bind(addr: &str, service: Arc<ExperimentService>) -> io::Result<Self> {
        Ok(Self { listener: TcpListener::bind(addr)?, service })
    }

    /// The bound socket address.
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Serve forever with `threads` workers (min 1). Accept errors are
    /// logged and survived; the call only returns if the listener dies.
    pub fn run(self, threads: usize) -> io::Result<()> {
        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        std::thread::scope(|scope| {
            for _ in 0..threads.max(1) {
                let rx = Arc::clone(&rx);
                let service = Arc::clone(&self.service);
                scope.spawn(move || loop {
                    let next = rx.lock().unwrap_or_else(|e| e.into_inner()).recv();
                    match next {
                        Ok(mut stream) => handle_connection(&mut stream, &service),
                        Err(_) => break, // sender dropped: listener is gone
                    }
                });
            }
            for stream in self.listener.incoming() {
                match stream {
                    Ok(s) => {
                        if tx.send(s).is_err() {
                            break;
                        }
                    }
                    Err(e) => eprintln!("warning: accept failed: {e}"),
                }
            }
            drop(tx);
        });
        Ok(())
    }
}

/// Read one request, dispatch it, answer it; every error that can be
/// answered is, then the connection closes (`Connection: close` always).
fn handle_connection(stream: &mut TcpStream, service: &ExperimentService) {
    let response = match read_request(stream) {
        Ok(req) => service.handle(&req),
        Err(resp) => resp,
    };
    if let Err(e) = response.write(stream) {
        eprintln!("warning: response write failed: {e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("lpgd_serve_{}_{tag}", std::process::id()))
    }

    fn service(tag: &str, capacity: usize) -> ExperimentService {
        let dir = tmp_dir(tag);
        let _ = std::fs::remove_dir_all(&dir);
        ExperimentService::new(Arc::new(ResultStore::open(&dir).unwrap()), capacity, 1)
    }

    fn post_run(body: &str) -> Request {
        Request {
            method: "POST".to_string(),
            path: "/v1/run".to_string(),
            body: body.as_bytes().to_vec(),
        }
    }

    fn get(path: &str) -> Request {
        Request { method: "GET".to_string(), path: path.to_string(), body: vec![] }
    }

    const SPEC: &str = r#"{"problem":{"kind":"quadratic1","dim":8},"grid":"bfloat16",
        "stepsize":0.05,"steps":10,"seed":3,"reps":2}"#;

    /// The headline contract: compute-then-serve is byte-identical, and
    /// the counters prove the second answer never recomputed.
    #[test]
    fn identical_requests_are_byte_identical_and_hit_the_registry() {
        let svc = service("bitident", 64);
        let cold = svc.handle(&post_run(SPEC));
        assert_eq!(cold.status, 200, "{}", String::from_utf8_lossy(&cold.body));
        assert_eq!((svc.store.hits(), svc.store.misses()), (0, 2));
        let warm = svc.handle(&post_run(SPEC));
        assert_eq!(warm.status, 200);
        assert_eq!(cold.body, warm.body, "served bytes must equal computed bytes");
        assert_eq!((svc.store.hits(), svc.store.misses()), (2, 2));
        // GET /v1/result serves the same record the run response embeds.
        let body = String::from_utf8(cold.body).unwrap();
        let v = Json::parse(&body).unwrap();
        let key = v.get("cells").unwrap().as_array().unwrap()[0]
            .get("key")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        let one = svc.handle(&get(&format!("/v1/result/{key}")));
        assert_eq!(one.status, 200);
        assert!(body.contains(std::str::from_utf8(&one.body).unwrap()));
        let _ = std::fs::remove_dir_all(tmp_dir("bitident"));
    }

    /// Two overlapping identical requests coalesce: exactly one pays the
    /// misses, regardless of interleaving.
    #[test]
    fn concurrent_duplicates_coalesce_onto_one_computation() {
        let svc = service("coalesce", 64);
        let (a, b) = std::thread::scope(|scope| {
            let ta = scope.spawn(|| svc.handle(&post_run(SPEC)));
            let tb = scope.spawn(|| svc.handle(&post_run(SPEC)));
            (ta.join().unwrap(), tb.join().unwrap())
        });
        assert_eq!((a.status, b.status), (200, 200));
        assert_eq!(a.body, b.body);
        assert_eq!(svc.store.misses(), 2, "two cells, each computed exactly once");
        assert_eq!(svc.store.hits(), 2, "the duplicate request is pure hits");
        let _ = std::fs::remove_dir_all(tmp_dir("coalesce"));
    }

    #[test]
    fn malformed_specs_get_descriptive_400s_and_unknown_routes_404() {
        let svc = service("badspec", 64);
        let r = svc.handle(&post_run("not json"));
        assert_eq!(r.status, 400);
        assert!(String::from_utf8_lossy(&r.body).contains("not valid JSON"));
        let r = svc.handle(&post_run(r#"{"problem":{"kind":"cubic","dim":4},
            "grid":"binary8","stepsize":0.1,"steps":5}"#));
        assert_eq!(r.status, 400);
        assert!(String::from_utf8_lossy(&r.body).contains("quadratic1"));
        assert_eq!(svc.handle(&get("/nope")).status, 404);
        assert_eq!(svc.handle(&get("/v1/run")).status, 405);
        assert_eq!(svc.handle(&get("/v1/result/xyz")).status, 400);
        assert_eq!(svc.handle(&get("/v1/result/0000000000000abc")).status, 404);
        // Spec failures never consume queue capacity.
        assert_eq!(svc.lock_inflight().len(), 0);
        let _ = std::fs::remove_dir_all(tmp_dir("badspec"));
    }

    /// Zero capacity: misses shed with 429, but registry hits still serve.
    #[test]
    fn back_pressure_sheds_misses_but_serves_hits() {
        let warm = service("bp_warm", 64);
        assert_eq!(warm.handle(&post_run(SPEC)).status, 200);
        // Re-open the same registry with zero compute capacity.
        let store = Arc::new(ResultStore::open(warm.store.dir()).unwrap());
        let cold = ExperimentService::new(store, 0, 1);
        assert_eq!(cold.handle(&post_run(SPEC)).status, 200, "hits need no capacity");
        let other = SPEC.replace("\"seed\":3", "\"seed\":4");
        let shed = cold.handle(&post_run(&other));
        assert_eq!(shed.status, 429);
        assert!(String::from_utf8_lossy(&shed.body).contains("queue full"));
        let _ = std::fs::remove_dir_all(tmp_dir("bp_warm"));
    }

    /// Experiment-form requests run the real builders against the shared
    /// store and render CSV bytes identical across a warm repeat.
    #[test]
    fn experiment_requests_serve_tables_and_reuse_the_registry() {
        let svc = service("exp", 64);
        let body = r#"{"experiment":"fig3a","quick":true,"seeds":2,"quad_n":24,
            "quad_steps":40,"format":"csv"}"#;
        let cold = svc.handle(&post_run(body));
        assert_eq!(cold.status, 200, "{}", String::from_utf8_lossy(&cold.body));
        assert_eq!(cold.content_type, "text/csv");
        let misses = svc.store.misses();
        assert!(misses > 0, "cold experiment must compute cells");
        let warm = svc.handle(&post_run(body));
        assert_eq!(warm.body, cold.body, "warm CSV must be byte-identical");
        assert_eq!(svc.store.misses(), misses, "warm run must not recompute");
        assert!(svc.store.hits() >= misses, "warm run is served from the store");
        let _ = std::fs::remove_dir_all(tmp_dir("exp"));
    }
}
