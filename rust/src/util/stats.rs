//! Summary statistics used by the aggregator and the bench harness, plus
//! the tolerance engine behind the golden-figure harness and the
//! Monte-Carlo tests (see `docs/testing.md`).
//!
//! # Tolerance engine
//!
//! Two concentration bounds back every statistical tolerance in the repo,
//! each exposed with its failure probability as an explicit argument so
//! tests can *document* their false-failure bound instead of hard-coding
//! a magic multiple of `1/sqrt(n)`:
//!
//! * **Hoeffding** ([`hoeffding_halfwidth`], [`hoeffding_samples`]) — for
//!   empirical means of bounded draws (a rounding output always lies in
//!   `[⌊x⌋, ⌈x⌉]`, a range of one gap):
//!   `P(|mean − E| ≥ t) ≤ 2·exp(−2·n·t²/range²)`. Non-asymptotic, so the
//!   bound is valid at every `n`, not just in the CLT limit.
//! * **Gaussian tail** ([`gaussian_z`], [`clt_halfwidth`]) — for
//!   CLT-normalized statistics (difference of two independent empirical
//!   means with known standard errors): `P(|Z| ≥ z) ≤ 2·exp(−z²/2)`, i.e.
//!   `z(p) = sqrt(2·ln(2/p))` gives a two-sided tail ≤ `p`. The Chernoff
//!   form avoids an `erfinv` dependency and is conservative (never
//!   tighter than the exact Gaussian quantile).

/// Mean of a slice (NaN for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance (the paper's §5.2 metric over 20 simulations).
pub fn population_variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Percentile (nearest-rank) on a copy of the data.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Index of the first element ≤ `threshold`, i.e. "epochs to reach the
/// baseline error" (the paper's headline speedup metric in §5.2/§5.3).
pub fn first_at_or_below(series: &[f64], threshold: f64) -> Option<usize> {
    series.iter().position(|&v| v <= threshold)
}

/// Standard error of the mean of `xs`: `sqrt(s²/n)` with the *unbiased*
/// sample variance `s² = Σ(x−m)²/(n−1)`. Zero for `n ≤ 1` (a single seed
/// carries no spread information; callers treat such columns as exact).
pub fn sem(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n <= 1 {
        return 0.0;
    }
    (population_variance(xs) * n as f64 / (n - 1) as f64 / n as f64).sqrt()
}

/// Standard error of a mean from a precomputed *population* variance over
/// `n` samples: `sqrt(var·n/(n−1)/n)` — the slice-free twin of [`sem`]
/// for aggregates that only kept the variance (e.g.
/// `coordinator::aggregate::ExpectationResult`). Zero for `n ≤ 1`.
pub fn sem_from_population_variance(var: f64, n: usize) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    (var * n as f64 / (n - 1) as f64 / n as f64).sqrt()
}

/// Two-sided Gaussian-tail critical value: the `z` with
/// `P(|N(0,1)| ≥ z) ≤ p_fail`, from the Chernoff bound
/// `P(|Z| ≥ z) ≤ 2·exp(−z²/2)` ⇒ `z = sqrt(2·ln(2/p_fail))`.
/// Conservative (≥ the exact quantile); e.g. `z(1e-6) ≈ 5.39`,
/// `z(1e-9) ≈ 6.55`.
pub fn gaussian_z(p_fail: f64) -> f64 {
    assert!(p_fail > 0.0 && p_fail < 1.0, "p_fail must be in (0,1), got {p_fail}");
    (2.0 * (2.0 / p_fail).ln()).sqrt()
}

/// Hoeffding half-width for the empirical mean of `n` i.i.d. draws bounded
/// in an interval of width `range`: the `t` with
/// `P(|mean − E| ≥ t) ≤ p_fail`, i.e. `t = range·sqrt(ln(2/p_fail)/(2n))`.
/// Valid at every `n` (non-asymptotic), so a test asserting
/// `|mean − E| < hoeffding_halfwidth(range, n, p)` fails spuriously with
/// probability at most `p` — the number to quote in the test's comment.
pub fn hoeffding_halfwidth(range: f64, n: usize, p_fail: f64) -> f64 {
    assert!(p_fail > 0.0 && p_fail < 1.0, "p_fail must be in (0,1), got {p_fail}");
    assert!(n > 0, "need at least one sample");
    range * ((2.0 / p_fail).ln() / (2.0 * n as f64)).sqrt()
}

/// Smallest sample count `n` for which
/// [`hoeffding_halfwidth`]`(range, n, p_fail) ≤ halfwidth` — use it to
/// *size* a Monte-Carlo test from the tolerance it needs instead of
/// guessing: `n = ⌈range²·ln(2/p_fail)/(2·t²)⌉`.
pub fn hoeffding_samples(range: f64, halfwidth: f64, p_fail: f64) -> usize {
    assert!(halfwidth > 0.0, "halfwidth must be positive");
    assert!(p_fail > 0.0 && p_fail < 1.0, "p_fail must be in (0,1), got {p_fail}");
    let n = (range / halfwidth).powi(2) * (2.0 / p_fail).ln() / 2.0;
    n.ceil() as usize
}

/// CLT band half-width for the difference of two independent empirical
/// means with standard errors `sem_a` and `sem_b`:
/// `z(p_fail)·sqrt(sem_a² + sem_b²)`. Under the CLT the difference is
/// `N(0, sem_a² + sem_b²)`, so `|mean_a − mean_b|` exceeds this with
/// probability at most `p_fail` — the golden harness's stochastic-column
/// acceptance band (see `docs/testing.md`).
pub fn clt_halfwidth(sem_a: f64, sem_b: f64, p_fail: f64) -> f64 {
    gaussian_z(p_fail) * (sem_a * sem_a + sem_b * sem_b).sqrt()
}

/// Distance between two finite `f64`s in units in the last place: the
/// number of representable binary64 values strictly between them, plus
/// one if they differ (0 ⇔ bit-identical up to `−0.0 == +0.0`). Uses the
/// monotone ordered-integer mapping of the IEEE bit pattern, so it is
/// exact across binades and signs. NaN on either side → `u64::MAX`.
pub fn ulp_distance(a: f64, b: f64) -> u64 {
    if a.is_nan() || b.is_nan() {
        return u64::MAX;
    }
    // Map the sign-magnitude bit pattern onto a monotone ordered integer.
    let ordered = |x: f64| -> i64 {
        let bits = x.to_bits() as i64;
        if bits < 0 {
            i64::MIN.wrapping_sub(bits)
        } else {
            bits
        }
    };
    let (oa, ob) = (ordered(a), ordered(b));
    oa.abs_diff(ob)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(population_variance(&[1.0, 3.0]), 1.0);
        assert!(mean(&[]).is_nan());
    }

    #[test]
    fn percentiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn epochs_to_threshold() {
        let s = [0.9, 0.5, 0.3, 0.09, 0.05];
        assert_eq!(first_at_or_below(&s, 0.1), Some(3));
        assert_eq!(first_at_or_below(&s, 0.01), None);
    }

    #[test]
    fn sem_matches_by_hand() {
        // {1, 3}: unbiased s² = 2, sem = sqrt(2/2) = 1.
        assert!((sem(&[1.0, 3.0]) - 1.0).abs() < 1e-12);
        assert_eq!(sem(&[5.0]), 0.0);
        assert_eq!(sem(&[]), 0.0);
        // Population-variance twin agrees with the slice form.
        let xs = [1.0, 2.0, 4.0, 8.0];
        let twin = sem_from_population_variance(population_variance(&xs), xs.len());
        assert!((sem(&xs) - twin).abs() < 1e-12);
        assert_eq!(sem_from_population_variance(1.0, 1), 0.0);
    }

    #[test]
    fn gaussian_z_is_conservative_and_monotone() {
        // Exact two-sided 1e-6 quantile is ≈ 4.89; the Chernoff z must
        // dominate it and shrink as p grows.
        let z6 = gaussian_z(1e-6);
        assert!(z6 > 4.89 && z6 < 6.0, "{z6}");
        assert!(gaussian_z(1e-9) > z6);
        assert!(gaussian_z(0.05) < z6);
    }

    #[test]
    fn hoeffding_roundtrips() {
        let (range, p) = (0.25, 1e-9);
        let t = hoeffding_halfwidth(range, 60_000, p);
        // Sizing from that half-width must land at (or just under) 60k.
        let n = hoeffding_samples(range, t, p);
        assert!(n <= 60_000 && n > 59_000, "{n}");
        // Bigger n → tighter band; smaller p → wider band.
        assert!(hoeffding_halfwidth(range, 240_000, p) < t);
        assert!(hoeffding_halfwidth(range, 60_000, 1e-12) > t);
    }

    #[test]
    fn ulp_distance_counts_representables() {
        assert_eq!(ulp_distance(1.0, 1.0), 0);
        assert_eq!(ulp_distance(0.0, -0.0), 0);
        assert_eq!(ulp_distance(1.0, f64::from_bits(1.0f64.to_bits() + 1)), 1);
        assert_eq!(ulp_distance(-1.0, f64::from_bits((-1.0f64).to_bits() + 1)), 1);
        // Across the sign boundary: smallest positive vs smallest negative
        // subnormal are two steps apart (through ±0).
        assert_eq!(ulp_distance(f64::from_bits(1), -f64::from_bits(1)), 2);
        assert_eq!(ulp_distance(1.0, f64::NAN), u64::MAX);
    }
}
