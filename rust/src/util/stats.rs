//! Summary statistics used by the aggregator and the bench harness.

/// Mean of a slice (NaN for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance (the paper's §5.2 metric over 20 simulations).
pub fn population_variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Percentile (nearest-rank) on a copy of the data.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Index of the first element ≤ `threshold`, i.e. "epochs to reach the
/// baseline error" (the paper's headline speedup metric in §5.2/§5.3).
pub fn first_at_or_below(series: &[f64], threshold: f64) -> Option<usize> {
    series.iter().position(|&v| v <= threshold)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(population_variance(&[1.0, 3.0]), 1.0);
        assert!(mean(&[]).is_nan());
    }

    #[test]
    fn percentiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn epochs_to_threshold() {
        let s = [0.9, 0.5, 0.3, 0.09, 0.05];
        assert_eq!(first_at_or_below(&s, 0.1), Some(3));
        assert_eq!(first_at_or_below(&s, 0.01), None);
    }
}
