//! FNV-1a hashing shared by the sweep journal, the cell scheduler and the
//! content-addressed result registry.
//!
//! Historically the 64-bit FNV-1a fold lived twice: inline in
//! [`crate::coordinator::scheduler`] (`cell_stream`) and inline in
//! [`crate::coordinator::experiments`] (`ExpCtx::config_digest`, the digest
//! carried by every journal line). The result registry
//! ([`crate::registry`]) needs the *same* bytes-to-u64 law so that a cell
//! journaled by `lpgd reproduce` and a cell cached for `lpgd serve` agree
//! on identity — so the fold now lives here and everything else reuses it.
//!
//! **Byte-compatibility contract:** [`Fnv1a`] folds exactly the historic
//! constants (offset `0xcbf29ce484222325`, prime `0x100000001b3`) one byte
//! at a time, and [`cell_stream`] reproduces the historic scheduler id
//! (FNV-1a over `experiment ‖ 0xff ‖ config`, xor-mixed with the golden-ratio
//! spread of the repetition index) bit for bit. Journal files and golden
//! config digests written before the extraction parse and replay unchanged
//! — pinned by the test vectors below and by the kill/resume integration
//! test (`rust/tests/integration.rs::fault_tolerance`).

/// The FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;

/// The FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x100000001b3;

/// Incremental 64-bit FNV-1a hasher with a builder-style API:
///
/// ```
/// use lpgd::util::hash::Fnv1a;
/// let digest = Fnv1a::new().bytes(b"fig3a").u64(42).finish();
/// assert_ne!(digest, Fnv1a::new().finish());
/// ```
#[derive(Debug, Clone, Copy)]
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv1a {
    /// Start a fresh hash at the FNV-1a offset basis.
    pub fn new() -> Self {
        Fnv1a(FNV_OFFSET)
    }

    /// Fold a byte slice, one byte at a time (xor, then multiply — the
    /// FNV-1a order, as the historic inline copies did).
    pub fn bytes(mut self, bytes: &[u8]) -> Self {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
        self
    }

    /// Fold one byte.
    pub fn byte(self, b: u8) -> Self {
        self.bytes(&[b])
    }

    /// Fold a `u64` as its 8 little-endian bytes (the `config_digest`
    /// convention for numeric knobs).
    pub fn u64(self, v: u64) -> Self {
        self.bytes(&v.to_le_bytes())
    }

    /// Fold a string's UTF-8 bytes.
    pub fn str(self, s: &str) -> Self {
        self.bytes(s.as_bytes())
    }

    /// The folded digest.
    pub fn finish(self) -> u64 {
        self.0
    }
}

/// One-shot FNV-1a of a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    Fnv1a::new().bytes(bytes).finish()
}

/// Stable stream id for an (experiment, config, repetition) cell: FNV-1a
/// over the two labels (separated by a `0xff` byte so `("ab","c")` and
/// `("a","bc")` stay distinct), mixed with the repetition index. Purely a
/// function of the cell's *identity*, never of scheduling state, so the id
/// — and through [`crate::fp::Rng::split`] the cell's whole random
/// trajectory — survives reordering, re-sharding and resumption.
///
/// This is the historic `coordinator::scheduler::cell_stream` law moved
/// here verbatim (the scheduler re-exports it); journal lines keyed by it
/// replay bit-identically across the move.
pub fn cell_stream(experiment: &str, config: &str, rep: u64) -> u64 {
    Fnv1a::new().str(experiment).byte(0xff).str(config).finish()
        ^ rep.wrapping_mul(0x9E3779B97F4A7C15)
}

/// Content address of one sweep cell in the result registry
/// ([`crate::registry`]): the run-configuration digest
/// ([`crate::coordinator::experiments::ExpCtx::config_digest`]) folded with
/// the cell's stream id ([`cell_stream`]). Two cells share a registry key
/// iff they share both the config shape *and* the cell identity — exactly
/// the pair the journal stores as separate fields on every line.
pub fn registry_key(config_digest: u64, cell: u64) -> u64 {
    Fnv1a::new().u64(config_digest).u64(cell).finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The published FNV-1a 64-bit test vectors: the extraction must not
    /// have changed the law (journals and registries on disk depend on it).
    #[test]
    fn fnv1a_matches_published_test_vectors() {
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    /// Byte-for-byte equivalence with the historic inline scheduler fold.
    #[test]
    fn cell_stream_matches_the_historic_inline_fold() {
        fn legacy(experiment: &str, config: &str, rep: u64) -> u64 {
            let mut h = 0xcbf29ce484222325u64;
            for b in experiment.bytes().chain([0xff]).chain(config.bytes()) {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
            h ^ rep.wrapping_mul(0x9E3779B97F4A7C15)
        }
        for (exp, cfg, rep) in
            [("fig4a", "SR", 0u64), ("fig3a", "signed:0.25", 17), ("", "", u64::MAX)]
        {
            assert_eq!(cell_stream(exp, cfg, rep), legacy(exp, cfg, rep), "{exp}/{cfg}/{rep}");
        }
    }

    #[test]
    fn builder_folds_match_one_shot() {
        assert_eq!(Fnv1a::new().bytes(b"foobar").finish(), fnv1a(b"foobar"));
        assert_eq!(Fnv1a::new().str("foo").str("bar").finish(), fnv1a(b"foobar"));
        assert_eq!(
            Fnv1a::new().u64(0x0102030405060708).finish(),
            fnv1a(&[8, 7, 6, 5, 4, 3, 2, 1])
        );
    }

    #[test]
    fn registry_key_separates_config_and_cell() {
        let k = registry_key(1, 2);
        assert_eq!(k, registry_key(1, 2));
        assert_ne!(k, registry_key(2, 1));
        assert_ne!(k, registry_key(1, 3));
    }
}
