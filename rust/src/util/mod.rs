//! In-repo plumbing: CLI argument parsing, CSV/markdown table writing and
//! summary statistics. (The image is offline; `clap`/`serde`/`csv` are not
//! vendored, so these ~200 lines replace them.)

pub mod cli;
pub mod stats;
pub mod table;

pub use cli::Args;
pub use table::Table;
