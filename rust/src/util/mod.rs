//! In-repo plumbing: CLI argument parsing, CSV/markdown table writing,
//! summary statistics, FNV-1a content hashing and minimal JSON. (The image
//! is offline; `clap`/`serde`/`csv`/`serde_json` are not vendored, so
//! these modules replace them.)

pub mod cli;
pub mod hash;
pub mod json;
pub mod stats;
pub mod table;

pub use cli::Args;
pub use hash::Fnv1a;
pub use json::Json;
pub use table::Table;
