//! Minimal JSON for the result registry and the `lpgd serve` API. (The
//! image is offline; `serde_json` is not vendored, so these ~300 lines
//! replace it for the small, fully-known documents the registry log and
//! the `/v1/*` endpoints exchange.)
//!
//! Two properties matter more here than generality:
//!
//! 1. **Deterministic rendering.** [`Json::render`] emits objects in
//!    insertion order (an object is an ordered `Vec` of pairs, not a map)
//!    and floats via Rust's shortest-roundtrip `{}` formatting — the same
//!    convention the sweep journal uses — so identical values render to
//!    identical bytes. The serve tier's bit-identical-response guarantee
//!    rests on this.
//! 2. **Lossless floats.** The parser accepts `NaN`, `inf` and `-inf`
//!    (the spellings `{}` produces for non-finite `f64`), matching the
//!    journal's private-format precedent: registry records round-trip
//!    every value a run can produce, including diverged series.
use std::fmt::Write as _;

/// A parsed JSON value. Objects preserve insertion order so rendering is
/// deterministic (see the module docs).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number, including the non-finite spellings `NaN`/`inf`/`-inf`.
    Num(f64),
    /// A string (escapes resolved).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object as an *ordered* list of `(key, value)` pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a JSON document, requiring it to span the whole input.
    /// Errors carry a byte offset and a description.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(v)
    }

    /// Look up a key in an object; `None` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// The numeric payload as a non-negative integer, if it is one exactly.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= u64::MAX as f64 => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    /// [`Json::as_u64`] narrowed to `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|v| usize::try_from(v).ok())
    }

    /// The boolean payload, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element list, if this is an array.
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Render to a compact, deterministic JSON string (see module docs for
    /// the byte-stability contract).
    pub fn render(&self) -> String {
        let mut out = String::new();
        render_into(self, &mut out);
        out
    }
}

/// Escape a string into a JSON string literal (quotes included).
pub fn escape_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn render_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        // `{}` is Rust's shortest round-trip form: `Json::parse(render(v))`
        // recovers the identical bits (NaN/inf spellings included — the
        // journal's precedent for a private, lossless float format).
        Json::Num(n) => {
            let _ = write!(out, "{n}");
        }
        Json::Str(s) => out.push_str(&escape_str(s)),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                render_into(item, out);
            }
            out.push(']');
        }
        Json::Obj(pairs) => {
            out.push('{');
            for (i, (k, val)) in pairs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&escape_str(k));
                out.push(':');
                render_into(val, out);
            }
            out.push('}');
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else {
        return Err(format!("unexpected end of input at byte {pos}", pos = *pos));
    };
    match c {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_keyword(b, pos, "true", Json::Bool(true)),
        b'f' => parse_keyword(b, pos, "false", Json::Bool(false)),
        b'n' => parse_keyword(b, pos, "null", Json::Null),
        _ => parse_number(b, pos),
    }
}

fn parse_keyword(b: &[u8], pos: &mut usize, word: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        Err(format!("bad keyword at byte {pos} (expected '{word}')", pos = *pos))
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    // Token = everything a number (or the non-finite spellings `NaN`,
    // `inf`, `-inf`) can contain; `f64::from_str` does the real validation.
    while *pos < b.len()
        && matches!(b[*pos],
            b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E' | b'a' | b'f' | b'i' | b'n' | b'N')
    {
        *pos += 1;
    }
    let token = std::str::from_utf8(&b[start..*pos]).map_err(|_| "non-UTF8 number".to_string())?;
    token
        .parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("bad number '{token}' at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        let Some(&c) = b.get(*pos) else {
            return Err("unterminated string".to_string());
        };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&e) = b.get(*pos) else {
                    return Err("unterminated escape".to_string());
                };
                *pos += 1;
                match e {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let hex = b
                            .get(*pos..*pos + 4)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .ok_or("truncated \\u escape")?;
                        *pos += 4;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape digits")?;
                        // Surrogates and other invalid scalars degrade to
                        // U+FFFD; the registry never writes them.
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(format!("bad escape '\\{}'", e as char)),
                }
            }
            c if c < 0x80 => out.push(c as char),
            _ => {
                // Multi-byte UTF-8: find the full scalar at pos-1.
                let rest = std::str::from_utf8(&b[*pos - 1..])
                    .map_err(|_| "non-UTF8 string".to_string())?;
                let ch = rest.chars().next().unwrap();
                out.push(ch);
                *pos += ch.len_utf8() - 1;
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // consume '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(format!("expected ',' or ']' at byte {pos}", pos = *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // consume '{'
    let mut pairs = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(pairs));
    }
    loop {
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b'"') {
            return Err(format!("expected object key at byte {pos}", pos = *pos));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected ':' at byte {pos}", pos = *pos));
        }
        *pos += 1;
        pairs.push((key, parse_value(b, pos)?));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(pairs));
            }
            _ => return Err(format!("expected ',' or '}}' at byte {pos}", pos = *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_usual_shapes() {
        let v = Json::parse(r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
        let b = v.get("b").unwrap().as_array().unwrap();
        assert_eq!(b[0].as_bool(), Some(true));
        assert_eq!(b[1], Json::Null);
        assert_eq!(b[2].as_str(), Some("x\ny"));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
    }

    #[test]
    fn rejects_malformed_documents_with_positions() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("123 456").unwrap_err().contains("trailing"));
        assert!(Json::parse("").is_err());
    }

    /// The byte-stability contract: render → parse → render is a fixed
    /// point, and floats round-trip bit-exactly (including non-finite,
    /// which the journal precedent spells NaN / inf / -inf).
    #[test]
    fn render_parse_roundtrip_is_bit_exact() {
        let v = Json::Obj(vec![
            ("series".to_string(), Json::Arr(vec![
                Json::Num(0.1 + 0.2), // classic non-representable sum
                Json::Num(f64::INFINITY),
                Json::Num(f64::NEG_INFINITY),
                Json::Num(1e-308),
            ])),
            ("label".to_string(), Json::Str("signed:0.25 \"q\"".to_string())),
        ]);
        let text = v.render();
        let re = Json::parse(&text).unwrap();
        assert_eq!(re.render(), text);
        let series = re.get("series").unwrap().as_array().unwrap();
        assert_eq!(series[0].as_f64().unwrap().to_bits(), (0.1f64 + 0.2).to_bits());
        assert!(series[1].as_f64().unwrap().is_infinite());
    }

    #[test]
    fn nan_round_trips_through_the_private_spelling() {
        let text = Json::Arr(vec![Json::Num(f64::NAN)]).render();
        assert_eq!(text, "[NaN]");
        let re = Json::parse(&text).unwrap();
        assert!(re.as_array().unwrap()[0].as_f64().unwrap().is_nan());
    }

    #[test]
    fn object_order_is_preserved_not_sorted() {
        let text = r#"{"z": 1, "a": 2}"#;
        assert_eq!(Json::parse(text).unwrap().render(), r#"{"z":1,"a":2}"#);
    }

    #[test]
    fn unicode_escapes_and_multibyte_text_parse() {
        let v = Json::parse(r#""café µ""#).unwrap();
        assert_eq!(v.as_str(), Some("café µ"));
        assert_eq!(Json::parse(&v.render()).unwrap(), v);
        // \uXXXX escapes resolve to the scalar value.
        assert_eq!(Json::parse("\"\\u00e9\\u0041\"").unwrap().as_str(), Some("éA"));
    }
}
