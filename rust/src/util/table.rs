//! Result tables: one per experiment, rendered as CSV (for plotting),
//! markdown (for EXPERIMENTS.md) and aligned text (for the terminal).

use anyhow::Result;
use std::fmt::Write as _;
use std::fs;
use std::path::Path;

/// A cell is either text or a number (numbers get compact formatting).
#[derive(Debug, Clone)]
pub enum Cell {
    /// Free-form text.
    Text(String),
    /// A float, rendered compactly (NaN as "-").
    Num(f64),
    /// An integer, rendered as-is.
    Int(i64),
}

impl From<&str> for Cell {
    fn from(s: &str) -> Self {
        Cell::Text(s.to_string())
    }
}
impl From<String> for Cell {
    fn from(s: String) -> Self {
        Cell::Text(s)
    }
}
impl From<f64> for Cell {
    fn from(v: f64) -> Self {
        Cell::Num(v)
    }
}
impl From<usize> for Cell {
    fn from(v: usize) -> Self {
        Cell::Int(v as i64)
    }
}
impl From<i64> for Cell {
    fn from(v: i64) -> Self {
        Cell::Int(v)
    }
}

impl Cell {
    fn render(&self) -> String {
        match self {
            Cell::Text(s) => s.clone(),
            Cell::Int(v) => v.to_string(),
            Cell::Num(v) => {
                if v.is_nan() {
                    "-".into()
                } else if *v == 0.0 {
                    "0".into()
                } else if v.abs() >= 1e5 || v.abs() < 1e-4 {
                    format!("{v:.4e}")
                } else {
                    format!("{v:.6}")
                }
            }
        }
    }
}

/// An experiment result table.
#[derive(Debug, Clone)]
pub struct Table {
    /// Stable id; also the CSV file stem.
    pub id: String,
    /// Human-readable title.
    pub title: String,
    /// Column headers.
    pub columns: Vec<String>,
    /// Data rows (each the width of `columns`).
    pub rows: Vec<Vec<Cell>>,
    /// Free-form footnotes (rendered in text/markdown, not CSV).
    pub notes: Vec<String>,
    /// Per-column standard errors of the mean for the *stochastic*
    /// columns: `(column label, sem per data row)`. Populated by the
    /// experiment builders for seed-averaged expectation curves and
    /// consumed by the golden harness to derive CLT tolerance bands
    /// (`coordinator::goldens`, `docs/testing.md`). Columns without an
    /// entry are deterministic and diffed byte-exactly. Rendered to the
    /// `<id>.band.csv` sidecar, never to the main CSV.
    pub bands: Vec<(String, Vec<f64>)>,
}

impl Table {
    /// An empty table with the given id, title and column headers.
    pub fn new(id: &str, title: &str, columns: &[&str]) -> Self {
        Self {
            id: id.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
            notes: vec![],
            bands: vec![],
        }
    }

    /// Append one data row (must match the column count).
    pub fn row(&mut self, cells: Vec<Cell>) {
        debug_assert_eq!(cells.len(), self.columns.len());
        self.rows.push(cells);
    }

    /// Append a footnote.
    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Attach the per-row standard errors of the mean for a stochastic
    /// column (marking it as seed-averaged for the golden harness). The
    /// label must name an existing column; the series is aligned with the
    /// data rows, padded/truncated to the row count at render time.
    pub fn band(&mut self, label: impl Into<String>, sems: Vec<f64>) {
        let label = label.into();
        debug_assert!(self.columns.iter().any(|c| *c == label), "band for unknown column {label}");
        self.bands.push((label, sems));
    }

    /// Render the SEM sidecar as CSV: one `row` index column plus one
    /// column per banded label, values in shortest-roundtrip form so a
    /// read-back reconstructs the exact `f64`. Empty string when the
    /// table has no bands.
    pub fn bands_to_csv(&self) -> String {
        if self.bands.is_empty() {
            return String::new();
        }
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let hdr: Vec<String> = std::iter::once("row".to_string())
            .chain(self.bands.iter().map(|(l, _)| esc(l)))
            .collect();
        let _ = writeln!(out, "{}", hdr.join(","));
        for i in 0..self.rows.len() {
            let mut cells = vec![i.to_string()];
            for (_, sems) in &self.bands {
                cells.push(format!("{}", sems.get(i).copied().unwrap_or(0.0)));
            }
            let _ = writeln!(out, "{}", cells.join(","));
        }
        out
    }

    /// Write `<dir>/<id>.band.csv` when the table carries bands; returns
    /// the path written, or `None` for band-free (fully deterministic)
    /// tables.
    pub fn write_band_csv(&self, dir: impl AsRef<Path>) -> Result<Option<std::path::PathBuf>> {
        if self.bands.is_empty() {
            return Ok(None);
        }
        fs::create_dir_all(&dir)?;
        let path = dir.as_ref().join(format!("{}.band.csv", self.id));
        fs::write(&path, self.bands_to_csv())?;
        Ok(Some(path))
    }

    /// Render as CSV (header + rows; notes omitted).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(out, "{}", self.columns.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| esc(&c.render())).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Render as a GitHub-style markdown table with blockquoted notes.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {} — {}\n", self.id, self.title);
        let _ = writeln!(out, "| {} |", self.columns.join(" | "));
        let _ = writeln!(out, "|{}|", self.columns.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for r in &self.rows {
            let _ =
                writeln!(out, "| {} |", r.iter().map(|c| c.render()).collect::<Vec<_>>().join(" | "));
        }
        for n in &self.notes {
            let _ = writeln!(out, "\n> {n}");
        }
        out
    }

    /// Aligned plain-text rendering for the terminal.
    pub fn to_text(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        let rendered: Vec<Vec<String>> =
            self.rows.iter().map(|r| r.iter().map(|c| c.render()).collect()).collect();
        for r in &rendered {
            for (i, cell) in r.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = format!("== {} — {} ==\n", self.id, self.title);
        let hdr: Vec<String> =
            self.columns.iter().enumerate().map(|(i, c)| format!("{:>w$}", c, w = widths[i])).collect();
        let _ = writeln!(out, "{}", hdr.join("  "));
        for r in &rendered {
            let line: Vec<String> =
                r.iter().enumerate().map(|(i, c)| format!("{:>w$}", c, w = widths[i])).collect();
            let _ = writeln!(out, "{}", line.join("  "));
        }
        for n in &self.notes {
            let _ = writeln!(out, "note: {n}");
        }
        out
    }

    /// Write `<dir>/<id>.csv` (and return the path).
    pub fn write_csv(&self, dir: impl AsRef<Path>) -> Result<std::path::PathBuf> {
        fs::create_dir_all(&dir)?;
        let path = dir.as_ref().join(format!("{}.csv", self.id));
        fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Terminal sparkline of a series (log-scale friendly: pass pre-logged data).
pub fn sparkline(series: &[f64], width: usize) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if series.is_empty() {
        return String::new();
    }
    let step = (series.len() as f64 / width as f64).max(1.0);
    let vals: Vec<f64> = (0..series.len().min(width))
        .map(|i| series[((i as f64 * step) as usize).min(series.len() - 1)])
        .collect();
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in &vals {
        if v.is_finite() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    if !lo.is_finite() || hi == lo {
        return "▄".repeat(vals.len());
    }
    vals.iter()
        .map(|&v| {
            if !v.is_finite() {
                ' '
            } else {
                BARS[(((v - lo) / (hi - lo)) * 7.0).round() as usize]
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("t1", "demo", &["k", "f", "who"]);
        t.row(vec![0usize.into(), 1.5.into(), "a,b".into()]);
        t.row(vec![1usize.into(), f64::NAN.into(), "x".into()]);
        t.note("a note");
        t
    }

    #[test]
    fn csv_escapes_commas() {
        let csv = sample().to_csv();
        assert!(csv.starts_with("k,f,who\n"));
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("-")); // NaN rendered as dash
    }

    #[test]
    fn markdown_has_header_separator() {
        let md = sample().to_markdown();
        assert!(md.contains("|---|---|---|"));
        assert!(md.contains("> a note"));
    }

    #[test]
    fn text_is_aligned() {
        let txt = sample().to_text();
        assert!(txt.contains("demo"));
        let lines: Vec<&str> = txt.lines().collect();
        assert!(lines.len() >= 4);
    }

    #[test]
    fn write_csv_roundtrip() {
        let dir = std::env::temp_dir().join("lpgd_table_test");
        let p = sample().write_csv(&dir).unwrap();
        let content = std::fs::read_to_string(p).unwrap();
        assert!(content.contains("a,b"));
    }

    #[test]
    fn band_sidecar_roundtrips_exact_f64() {
        let mut t = sample();
        assert_eq!(t.bands_to_csv(), "");
        assert!(t.write_band_csv(std::env::temp_dir()).unwrap().is_none());
        let sems = vec![0.1, 1.0 / 3.0];
        t.band("f", sems.clone());
        let csv = t.bands_to_csv();
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some("row,f"));
        for (i, line) in lines.enumerate() {
            let (row, v) = line.split_once(',').unwrap();
            assert_eq!(row, i.to_string());
            // Shortest-roundtrip rendering: the parse is bit-exact.
            assert_eq!(v.parse::<f64>().unwrap().to_bits(), sems[i].to_bits());
        }
    }

    #[test]
    fn sparkline_basic() {
        let s = sparkline(&[0.0, 1.0, 2.0, 3.0], 4);
        assert_eq!(s.chars().count(), 4);
        assert!(s.starts_with('▁'));
        assert!(s.ends_with('█'));
    }
}
