//! Minimal CLI parser: positionals + `--key value` / `--flag` options.

use std::collections::HashMap;

/// Boolean flags (never consume a value). Everything else written as
/// `--key value` takes the next token as its value.
const BOOL_FLAGS: &[&str] = &["quick", "full", "verbose", "help", "pjrt", "json"];

/// Parsed command line: positionals, `--key value` options, bare flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Non-option arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: HashMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare `--flag`.
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if !BOOL_FLAGS.contains(&key)
                    && it.peek().map_or(false, |n| !n.starts_with("--"))
                {
                    out.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Raw value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// `--key` parsed as usize, or `default`.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--key` parsed as u64, or `default`.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--key` parsed as f64, or `default`.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Was the bare `--name` flag given?
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positionals_options_flags() {
        let a = parse("reproduce fig3a --seeds 20 --out-dir results --quick");
        assert_eq!(a.positional, vec!["reproduce", "fig3a"]);
        assert_eq!(a.get("seeds"), Some("20"));
        assert_eq!(a.get_usize("seeds", 5), 20);
        assert_eq!(a.get("out-dir"), Some("results"));
        assert!(a.has_flag("quick"));
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn equals_form_and_defaults() {
        let a = parse("run --t=0.5 --steps=100");
        assert_eq!(a.get_f64("t", 1.0), 0.5);
        assert_eq!(a.get_usize("steps", 10), 100);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn flag_before_positional() {
        let a = parse("--quick fig2");
        assert!(a.has_flag("quick"));
        assert_eq!(a.positional, vec!["fig2"]);
    }
}
