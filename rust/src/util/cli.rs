//! Minimal CLI parser: positionals + `--key value` / `--flag` options.

use std::collections::HashMap;

/// Boolean flags (never consume a value). Everything else written as
/// `--key value` takes the next token as its value.
const BOOL_FLAGS: &[&str] =
    &["quick", "full", "verbose", "help", "pjrt", "json", "resume", "require", "stream-change"];

/// Parsed command line: positionals, `--key value` options, bare flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Non-option arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: HashMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare `--flag`.
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if !BOOL_FLAGS.contains(&key)
                    && it.peek().map_or(false, |n| !n.starts_with("--"))
                {
                    out.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Raw value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// `--key` parsed as usize, or `default`.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--key` parsed as u64, or `default`.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--key` parsed as f64, or `default`.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Was the bare `--name` flag given?
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Every `--key`/`--flag` that is neither in `known` nor a recognized
    /// boolean flag, sorted. Commands reject argv with a descriptive error
    /// when this is non-empty, instead of the historic silent ignore
    /// (`lpgd train --sceme sr` used to train with the default scheme).
    pub fn unknown_keys(&self, known: &[&str]) -> Vec<String> {
        let mut bad: Vec<String> = self
            .options
            .keys()
            .filter(|k| !known.contains(&k.as_str()))
            .cloned()
            .collect();
        bad.extend(
            self.flags
                .iter()
                .filter(|f| !BOOL_FLAGS.contains(&f.as_str()) && !known.contains(&f.as_str()))
                .cloned(),
        );
        bad.sort_unstable();
        bad
    }

    /// Known value-options given as bare `--key` with no value (e.g.
    /// `--scheme` as the last token, or `--scheme --t 0.1`), sorted.
    /// These parse as flags, so without this check the command would
    /// silently fall back to the option's default — the same silent-ignore
    /// class [`Args::unknown_keys`] eliminates for typos.
    pub fn missing_values(&self, known: &[&str]) -> Vec<String> {
        let mut bad: Vec<String> = self
            .flags
            .iter()
            .filter(|f| known.contains(&f.as_str()) && !BOOL_FLAGS.contains(&f.as_str()))
            .cloned()
            .collect();
        bad.sort_unstable();
        bad
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positionals_options_flags() {
        let a = parse("reproduce fig3a --seeds 20 --out-dir results --quick");
        assert_eq!(a.positional, vec!["reproduce", "fig3a"]);
        assert_eq!(a.get("seeds"), Some("20"));
        assert_eq!(a.get_usize("seeds", 5), 20);
        assert_eq!(a.get("out-dir"), Some("results"));
        assert!(a.has_flag("quick"));
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn equals_form_and_defaults() {
        let a = parse("run --t=0.5 --steps=100");
        assert_eq!(a.get_f64("t", 1.0), 0.5);
        assert_eq!(a.get_usize("steps", 10), 100);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn flag_before_positional() {
        let a = parse("--quick fig2");
        assert!(a.has_flag("quick"));
        assert_eq!(a.positional, vec!["fig2"]);
    }

    #[test]
    fn resume_is_a_bool_flag() {
        // `--resume` must never swallow the token after it (here the
        // positional experiment id).
        let a = parse("reproduce plfp1 --journal sweep.jsonl --resume plfp1extra");
        assert!(a.has_flag("resume"));
        assert_eq!(a.get("journal"), Some("sweep.jsonl"));
        assert_eq!(a.positional, vec!["reproduce", "plfp1", "plfp1extra"]);
    }

    #[test]
    fn unknown_keys_flags_typos_but_allows_known() {
        let a = parse("train --sceme sr --quik --fmt binary8 --quick --help");
        let bad = a.unknown_keys(&["fmt", "scheme"]);
        assert_eq!(bad, vec!["quik".to_string(), "sceme".to_string()]);
        // Nothing unknown when everything is declared or a bool flag.
        let b = parse("round 1.1 --fmt binary8 --mode sr --json");
        assert!(b.unknown_keys(&["fmt", "mode", "samples", "seed"]).is_empty());
    }

    #[test]
    fn missing_values_catches_bare_value_options() {
        // `--scheme` swallowed its value (`--t` follows) and `--fmt` is the
        // last token: both parse as flags and must be reported.
        let a = parse("train --scheme --t 0.1 --fmt");
        assert_eq!(
            a.missing_values(&["scheme", "t", "fmt"]),
            vec!["fmt".to_string(), "scheme".to_string()]
        );
        assert!(a.unknown_keys(&["scheme", "t", "fmt"]).is_empty());
        // Well-formed argv reports nothing missing; bool flags never do.
        let b = parse("train --scheme sr --quick");
        assert!(b.missing_values(&["scheme"]).is_empty());
    }
}
