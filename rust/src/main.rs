//! `lpgd` — the Layer-3 coordinator CLI.
//!
//! ```text
//! lpgd list                             list reproducible experiments
//! lpgd reproduce <id|all> [opts]        regenerate a paper table/figure
//!     --seeds N      (default 5; paper uses 20)
//!     --jobs N       worker threads (default 0 = all cores; results are
//!                    bit-identical for every N — see docs/architecture.md)
//!     --out-dir D    (default results/)
//!     --quick        smoke-scale profile
//!     --side N --mlr-train N --mlr-epochs N ... (see ExpCtx)
//! lpgd train <mlr|nn> [opts]            one training run with any schemes
//!     --fmt binary8  --t 0.5 --epochs 50 --seed 0
//!     --s8a sr --s8b sr --s8c signed:0.1   per-step rounding schemes
//! lpgd round <value> [opts]             inspect rounding of one value
//!     --fmt binary8 --mode sr_eps:0.25 --samples 10000
//! lpgd pjrt-info                        PJRT platform + artifact check
//! ```

use anyhow::{bail, Result};
use lpgd::coordinator::experiments::{list_experiments, run_experiment, ExpCtx};
use lpgd::data::load_or_synth;
use lpgd::fp::{FpFormat, Rng, Rounding};
use lpgd::gd::engine::{GdConfig, GdEngine, StepSchemes};
use lpgd::problems::{Mlr, TwoLayerNn};
use lpgd::util::cli::Args;
use lpgd::util::table::sparkline;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn ctx_from_args(a: &Args) -> ExpCtx {
    let mut ctx = if a.has_flag("quick") { ExpCtx::quick() } else { ExpCtx::default() };
    ctx.seeds = a.get_usize("seeds", ctx.seeds);
    ctx.jobs = a.get_usize("jobs", ctx.jobs);
    ctx.out_dir = a.get("out-dir").unwrap_or(&ctx.out_dir).to_string();
    ctx.side = a.get_usize("side", ctx.side);
    ctx.mlr_train = a.get_usize("mlr-train", ctx.mlr_train);
    ctx.mlr_test = a.get_usize("mlr-test", ctx.mlr_test);
    ctx.nn_train = a.get_usize("nn-train", ctx.nn_train);
    ctx.nn_test = a.get_usize("nn-test", ctx.nn_test);
    ctx.mlr_epochs = a.get_usize("mlr-epochs", ctx.mlr_epochs);
    ctx.nn_epochs = a.get_usize("nn-epochs", ctx.nn_epochs);
    ctx.quad_steps = a.get_usize("quad-steps", ctx.quad_steps);
    ctx.quad_n = a.get_usize("quad-n", ctx.quad_n);
    ctx.mnist_dir = a.get("mnist-dir").map(String::from);
    ctx
}

fn scheme_arg(a: &Args, key: &str, default: Rounding) -> Result<Rounding> {
    match a.get(key) {
        None => Ok(default),
        Some(s) => {
            Rounding::parse(s).ok_or_else(|| anyhow::anyhow!("bad scheme '{s}' for --{key}"))
        }
    }
}

fn run() -> Result<()> {
    let a = Args::from_env();
    let cmd = a.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "list" => {
            println!("{:<8}  {}", "id", "description");
            for (id, desc) in list_experiments() {
                println!("{id:<8}  {desc}");
            }
            println!("\nusage: lpgd reproduce <id|all> [--seeds N] [--jobs N] [--quick] [--out-dir D]");
        }
        "reproduce" => {
            let id = a.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            let ctx = ctx_from_args(&a);
            let jobs = if ctx.jobs == 0 { "auto".to_string() } else { ctx.jobs.to_string() };
            let t0 = std::time::Instant::now();
            let tables = run_experiment(id, &ctx)?;
            for t in &tables {
                println!("{}", t.to_text());
            }
            println!(
                "wrote {} CSV file(s) to {}/ in {:.1}s (--jobs {jobs})",
                tables.len(),
                ctx.out_dir,
                t0.elapsed().as_secs_f64()
            );
        }
        "train" => {
            let which = a.positional.get(1).map(|s| s.as_str()).unwrap_or("mlr");
            let ctx = ctx_from_args(&a);
            let fmt = FpFormat::by_name(a.get("fmt").unwrap_or("binary8"))
                .ok_or_else(|| anyhow::anyhow!("unknown --fmt"))?;
            let schemes = StepSchemes {
                grad: scheme_arg(&a, "s8a", Rounding::Sr)?,
                mul: scheme_arg(&a, "s8b", Rounding::Sr)?,
                sub: scheme_arg(&a, "s8c", Rounding::Sr)?,
            };
            let seed = a.get_u64("seed", 0);
            match which {
                "mlr" => {
                    let splits = load_or_synth(
                        ctx.mnist_dir.as_deref(),
                        ctx.mlr_train,
                        ctx.mlr_test,
                        ctx.side,
                        42,
                    );
                    let p = Mlr::new(splits.train, 10);
                    let t_step = a.get_f64("t", 0.5);
                    let epochs = a.get_usize("epochs", ctx.mlr_epochs);
                    let mut cfg = GdConfig::new(fmt, schemes, t_step, epochs);
                    cfg.seed = seed;
                    let x0 = vec![0.0; lpgd::problems::Problem::dim(&p)];
                    let mut e = GdEngine::new(cfg, &p, &x0);
                    let metric = |x: &[f64]| p.test_error(x, &splits.test);
                    let tr = e.run(Some(&metric));
                    print_training("MLR", fmt, &schemes, t_step, &tr.metric_series());
                }
                "nn" => {
                    let splits = load_or_synth(
                        ctx.mnist_dir.as_deref(),
                        ctx.nn_train * 5,
                        ctx.nn_test * 5,
                        ctx.side,
                        77,
                    );
                    let train = splits.train.filter_classes(&[3, 8]);
                    let test = splits.test.filter_classes(&[3, 8]);
                    let p = TwoLayerNn::new(train, 100);
                    let t_step = a.get_f64("t", 0.09375);
                    let epochs = a.get_usize("epochs", ctx.nn_epochs);
                    let mut cfg = GdConfig::new(fmt, schemes, t_step, epochs);
                    cfg.seed = seed;
                    let x0 = p.init_params(seed);
                    let mut e = GdEngine::new(cfg, &p, &x0);
                    let metric = |x: &[f64]| p.test_error(x, &test);
                    let tr = e.run(Some(&metric));
                    print_training("NN(3v8)", fmt, &schemes, t_step, &tr.metric_series());
                }
                other => bail!("unknown model '{other}' (mlr|nn)"),
            }
        }
        "round" => {
            let val: f64 = a
                .positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("usage: lpgd round <value>"))?
                .parse()?;
            let fmt = FpFormat::by_name(a.get("fmt").unwrap_or("binary8"))
                .ok_or_else(|| anyhow::anyhow!("unknown --fmt"))?;
            let mode = Rounding::parse(a.get("mode").unwrap_or("sr")).unwrap();
            let samples = a.get_usize("samples", 10000);
            let (lo, hi) = fmt.floor_ceil(val);
            println!("format {}  u={}  neighbors: [{lo}, {hi}]", fmt.name(), fmt.unit_roundoff());
            let mut rng = Rng::new(a.get_u64("seed", 0));
            let mut mean = 0.0;
            let mut n_up = 0usize;
            for _ in 0..samples {
                let y = lpgd::fp::round(&fmt, mode, val, &mut rng);
                mean += y;
                if y == hi && hi != lo {
                    n_up += 1;
                }
            }
            mean /= samples as f64;
            println!(
                "{}({val}) over {samples} samples: mean={mean}  bias={:+.3e}  P(up)={:.4}",
                mode.label(),
                mean - val,
                n_up as f64 / samples as f64
            );
            println!(
                "closed-form E[fl(x)]={}",
                lpgd::fp::expected_round(&fmt, mode, val, val)
            );
        }
        "pjrt-info" => {
            let dir = a.get("artifacts").unwrap_or("artifacts");
            let mut rt = lpgd::runtime::Runtime::cpu(dir)?;
            println!("platform: {}", rt.platform());
            for spec in [
                lpgd::runtime::QUANTIZE_SPEC,
                lpgd::runtime::MLR_SPEC,
                lpgd::runtime::NN_SPEC,
            ] {
                match rt.load(spec.file) {
                    Ok(e) => println!("  {} .. compiled OK ({} params)", e.name, spec.params),
                    Err(err) => println!("  {} .. FAILED: {err}", spec.file),
                }
            }
        }
        _ => {
            println!("lpgd — low-precision GD with stochastic rounding (paper reproduction)");
            println!("commands: list | reproduce <id|all> | train <mlr|nn> | round <value> | pjrt-info");
            println!("see `lpgd list` and README.md");
        }
    }
    Ok(())
}

fn print_training(name: &str, fmt: FpFormat, schemes: &StepSchemes, t: f64, err: &[f64]) {
    println!(
        "{name} fmt={} {} t={t}: final test error {:.4}",
        fmt.name(),
        schemes.label(),
        err.last().unwrap_or(&f64::NAN)
    );
    println!("test-error curve: {}", sparkline(err, 60));
}
